"""End-to-end CNN training on synthetic CIFAR-like data — the paper's own
workload, built from core.conv_layer / core.fc_layer (Pallas forward,
reference VJP backward).

    PYTHONPATH=src python examples/train_cnn.py --steps 60
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.configs.registry import smoke_config
from repro.models import cnn
from repro.models.module import init_params
from repro.optim import adamw


def synthetic_batch(rng, batch, classes):
    """Class-dependent blobs so the task is learnable."""
    labels = rng.integers(0, classes, (batch,))
    base = rng.standard_normal((batch, cnn.IMG, cnn.IMG, cnn.IN_CH)) * 0.3
    for i, c in enumerate(labels):
        base[i, (c * 3) % 28 : (c * 3) % 28 + 4, 4:28, c % 3] += 1.5
    return (jnp.asarray(base, jnp.float32), jnp.asarray(labels, jnp.int32))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--use-kernels", action="store_true",
                    help="Pallas forward (interpret mode; slower on CPU)")
    args = ap.parse_args()

    cfg = smoke_config("cnn-vgg11")
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=args.steps,
                       weight_decay=0.0, grad_clip=1.0)
    params = init_params(cnn.param_defs(cfg), jax.random.PRNGKey(0), jnp.float32)
    opt = adamw.init(params)

    @jax.jit
    def step(params, opt, images, labels):
        def loss_fn(p):
            logits = cnn.forward(cfg, p, images, use_kernels=False)
            lp = jax.nn.log_softmax(logits.astype(jnp.float32))
            nll = -lp[jnp.arange(labels.shape[0]), labels]
            acc = (logits.argmax(-1) == labels).mean()
            return nll.mean(), acc

        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt, m = adamw.apply_updates(params, grads, opt, tcfg)
        return params, opt, loss, acc

    rng = np.random.default_rng(0)
    losses = []
    t0 = time.time()
    for i in range(args.steps):
        images, labels = synthetic_batch(rng, args.batch, cfg.vocab)
        if args.use_kernels and i == 0:  # demo the kernel path once
            logits = cnn.forward(cfg, params, images, use_kernels=True)
            print(f"kernel-forward logits[0,:3] = {np.asarray(logits)[0,:3]}")
        params, opt, loss, acc = step(params, opt, images, labels)
        losses.append(float(loss))
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(loss):.4f}  acc {float(acc):.3f}  "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")

    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    print(f"loss {first:.3f} -> {last:.3f} ({'LEARNED' if last < first * 0.8 else 'check hyperparams'})")


if __name__ == "__main__":
    main()
