"""Batched serving example: prefill a batch of prompts, then greedy-decode
continuation tokens against the KV cache.

    PYTHONPATH=src python examples/serve_lm.py --batch 4 --gen 16
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import smoke_config
from repro.models.module import init_params
from repro.models.registry import get_family
from repro.runtime.serve import make_decode_step, make_prefill_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    fam = get_family(cfg.family)
    params = init_params(fam.param_defs(cfg), jax.random.PRNGKey(0), jnp.float32)
    max_seq = args.prompt_len + args.gen

    prefill = jax.jit(make_prefill_step(cfg, max_seq, "float32", "float32"))
    decode = jax.jit(make_decode_step(cfg, "float32"))

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)),
                          jnp.int32)

    t0 = time.time()
    cache, logits = prefill(params, {"tokens": prompts})
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits[:, -1], -1)

    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        cache, logits = decode(params, cache, tok[:, None], args.prompt_len + i)
        tok = jnp.argmax(logits[:, -1], -1)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.asarray(jnp.stack(out, 1))
    print(f"prefill: {args.batch}x{args.prompt_len} tokens in {t_prefill*1e3:.1f} ms")
    print(f"decode:  {args.gen-1} steps x {args.batch} seqs in {t_decode*1e3:.1f} ms "
          f"({(args.gen-1)*args.batch/max(t_decode,1e-9):.1f} tok/s)")
    for b in range(min(args.batch, 2)):
        print(f"  seq{b}: {gen[b].tolist()}")
    assert np.isfinite(np.asarray(logits, np.float32)).all()


if __name__ == "__main__":
    main()
