"""Continuous-batching serving example on the ``repro.serve`` engine.

Boots the engine on a smoke-sized model — a 2-bucket ladder whose
prefill/decode schedules resolve once at warmup through the autotune
cache — then drives it with Poisson traffic at an offered QPS and prints
the latency/throughput/padding report plus a couple of token streams.

The warmup resolves the transformer's *planned* cells (qkv/attn/mlp/
logits per bucket rung) through the plan layer: the same
``TransformerBlockPlanner`` delegation the training path uses
(DESIGN.md Sec. 11, docs/plan-layer.md), with ``--autotune tune``
measuring each cell's candidates and ``cache-only`` replaying the
committed winners — a warmed engine never plans or times at request
time.  Any registered family with ``init_cache_slots`` can serve;
cache-less families (cnn) are rejected with a named ValueError.

Install the package first (``pip install -e .`` from the repo root), or
prefix with ``PYTHONPATH=src``:

    python examples/serve_lm.py --requests 12 --qps 50
    python examples/serve_lm.py --autotune tune     # first boot: measure
    python examples/serve_lm.py --autotune cache-only  # prod-style boot
"""

import argparse

import jax
import jax.numpy as jnp

from repro.configs.registry import smoke_config
from repro.models.module import init_params
from repro.models.registry import get_family
from repro.serve import BucketLadder, Engine, LoadSpec, run_load


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--qps", type=float, default=50.0)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=48)
    ap.add_argument("--slots", type=int, default=None,
                    help="KV slots (default: the ladder's widest bucket)")
    ap.add_argument("--autotune", default="off",
                    choices=["off", "cache-only", "tune"],
                    help="warmup schedule-resolution policy")
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    fam = get_family(cfg.family)
    params = init_params(fam.param_defs(cfg), jax.random.PRNGKey(0),
                         jnp.float32)

    ladder = BucketLadder([(2, 16), (4, 32)], max_seq=args.max_seq)
    engine = Engine(cfg, params, ladder, n_slots=args.slots,
                    queue_depth=max(16, args.requests))
    sources = engine.warmup(policy=args.autotune)
    flat = [s for cells in sources.values() for s in cells.values()]
    print(f"warmup: {len(ladder.buckets)} buckets, {len(flat)} cells "
          f"({flat.count('cached')} cached, {flat.count('tuned')} tuned, "
          f"{flat.count('modeled')} modeled)")

    spec = LoadSpec(qps=args.qps, n_requests=args.requests,
                    prompt_len=(4, min(24, args.max_seq - args.gen)),
                    new_tokens=(args.gen // 2 + 1, args.gen))
    rep = run_load(engine, spec)
    print(f"offered {rep.offered_qps:.0f} qps: {rep.completed}/"
          f"{rep.n_requests} completed, {rep.shed} shed, "
          f"{rep.timed_out} timed out")
    print(f"latency p50 {rep.p50_s * 1e3:.1f} ms  p99 {rep.p99_s * 1e3:.1f} ms  "
          f"ttft p50 {rep.ttft_p50_s * 1e3:.1f} ms")
    print(f"throughput {rep.tokens_per_sec:.1f} tok/s over "
          f"{rep.clock_seconds:.2f} s ({rep.engine_steps} engine steps, "
          f"padding waste {rep.padding_waste:.1%})")
    for r in engine.retired[:2]:
        print(f"  {r.rid}: prompt[{len(r.prompt)}] -> {r.tokens}")


if __name__ == "__main__":
    main()
