"""Quickstart: the paper's layers + analysis, plus both model families.

    PYTHONPATH=src python examples/quickstart.py

Full plan-layer lifecycle guide (Schedule -> Planner -> registry ->
ShardedSchedule -> autotune cache): docs/plan-layer.md.
"""

import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core import ccr
from repro.core.conv_layer import conv_layer, traffic as conv_traffic
from repro.core.fc_layer import fc_layer
from repro.core.machine import MANTICORE, TPU_V5E
from repro.kernels.conv2d import conv2d_ref
from repro.plan import ConvPlanner, get_op, to_roofline

# --- 1. The paper's analysis: CCR of the running example ------------------
shape = ccr.ConvShape(W_I=32, D_I=128, D_O=128, F=3, S=1, P=1)
print("conv layer", shape)
for strat in ("alg1", "alg2", "alg3"):
    t = conv_traffic(shape, strat, "sp")
    print(f"  {strat}: CCR={t.ccr:6.1f} MAC/word  off-chip={t.ccr_offchip:6.1f}"
          f"  -> {ccr.bound_kind(t, MANTICORE, 'sp')} on Manticore")

# --- 2. One capacity rule, two machines: repro.plan ------------------------
# The same ConvPlanner reproduces the paper's Manticore Delta_O (24 at sp,
# core/ccr.py parity) and picks Pallas blocks against TPU VMEM.
man = ConvPlanner(MANTICORE).plan(
    H_O=32, W_O=32, F=3, S=1, d_in=128, d_out=128,
    in_bytes=4, padding=1, H_I=32, W_I=32, block_h=32,  # full-plane Alg 2
)
tpu = ConvPlanner(TPU_V5E).plan(
    H_O=32, W_O=32, F=3, S=1, d_in=128, d_out=1024, in_bytes=2,
)
print(f"Manticore Delta_O from the capacity rule: {man.block('block_do')}"
      f"  (modeled words match Eq. 7: "
      f"{man.modeled_words == ccr.alg2_traffic(shape, 24).main_words})")
print(f"TPU schedule: blocks={dict(tpu.blocks)} grid={tpu.grid}"
      f"  modeled_words={tpu.modeled_words}  fits_vmem={tpu.fits(TPU_V5E)}")
print(f"  roofline t_memory at 819 GB/s: {to_roofline(tpu).t_memory:.2e} s")

# --- 3. Run the layers (Pallas kernels, interpret mode on CPU) ------------
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((16, 16, 8)), jnp.float32)
f = jnp.asarray(rng.standard_normal((3, 3, 8, 12)), jnp.float32)
y = conv_layer(x, f, 1, 1, "alg2")
np.testing.assert_allclose(np.asarray(y), np.asarray(conv2d_ref(x, f, padding=1)),
                           rtol=2e-4, atol=2e-4)
print("conv_layer (Alg 2 kernel) matches reference:", y.shape)

# An explicit Schedule round-trips through any kernel: plan once, pass it
# back in (the planner is the default, never a requirement).
conv2d_op = get_op("conv2d")
sched = conv2d_op.plan(x, f, jnp.zeros((12,), jnp.float32), padding=1)
y2 = conv_layer(x, f, 1, 1, "strip", sched)
np.testing.assert_allclose(np.asarray(y), np.asarray(y2), rtol=1e-6, atol=1e-6)
print("explicit Schedule round-trips:", dict(sched.blocks))

xf = jnp.asarray(rng.standard_normal((4, 49 * 8)), jnp.float32)
wf = jnp.asarray(rng.standard_normal((49 * 8, 64)), jnp.float32)
o = fc_layer(xf, wf)
np.testing.assert_allclose(np.asarray(o), np.asarray(xf @ wf), rtol=2e-4, atol=2e-4)
print("fc_layer (Alg 4/5 kernel) matches reference:", o.shape)

print("machine balance points (flop/B): manticore(sp)=",
      MANTICORE.peak_flops / MANTICORE.main_mem_bw,
      " tpu_v5e(bf16)=", TPU_V5E.peak_flops / TPU_V5E.main_mem_bw)

# --- 4. Training: jax.grad runs *planned* backward kernels ----------------
# dgrad (flipped-filter strip conv), wgrad (on-cluster dW accumulation) and
# the FC dX/dW kernels are pallas_ops with their own planners; pin them via
# bwd_schedules= or let the planner choose (DESIGN.md Sec. 4).
import jax

from repro.core.conv_layer import plan_bwd

bwd = plan_bwd(x.shape, f.shape, stride=1, padding=1)
gx, gf = jax.grad(lambda x, f: (conv_layer(x, f, 1, 1, "strip", None, bwd) ** 2).sum(),
                  argnums=(0, 1))(x, f)
print("planned backward grads:", gx.shape, gf.shape,
      " dgrad words=", bwd["dgrad"].modeled_words,
      " wgrad words=", bwd["wgrad"].modeled_words,
      " both fit:", bwd["dgrad"].fits(TPU_V5E) and bwd["wgrad"].fits(TPU_V5E))

# --- 5. Two model families through one registry ----------------------------
# The family registry (models/registry.py, DESIGN.md Sec. 11.3) dispatches
# params/data/loss/plans uniformly; the transformer block planner delegates
# each cell to the matmul/attention planners the way the conv planner
# delegates its im2col GEMM.  Train either family the same way:
#   python -m repro.launch.train --family cnn --planned-kernels
#   python -m repro.launch.train --family transformer --planned-kernels
from repro.configs.registry import smoke_config
from repro.models.module import count_params
from repro.models.registry import get_family
from repro.plan import MeshSpec, TransformerBlockPlanner

cfg = smoke_config("qwen1.5-0.5b")
fam = get_family("transformer")
print(f"transformer family: {count_params(fam.param_defs(cfg))/1e6:.1f}M params")
tb = TransformerBlockPlanner(MANTICORE, MeshSpec((("cluster", 16),)), "cluster")
picks = tb.plan(batch=4, seq=128, d_model=256, n_heads=8, d_ff=1024, in_bytes=4)
print("block plan on the 16-cluster quadrant:",
      {name: getattr(s, "strategy", "single") for name, s in picks.items()})
