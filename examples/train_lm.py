"""End-to-end LM training driver: data pipeline -> train loop -> sharded
checkpoints -> resume, with heartbeats and straggler watchdog.

With ``--planned-kernels`` the train step runs the planned transformer
path (DESIGN.md Sec. 11, docs/plan-layer.md): every block GEMM through
the planned ``fc_layer`` (fused QKV and gate+up), attention through the
planned flash kernel, and the planned dX/dW backward — dispatched by the
family's ``make_loss_fn`` hook, numerically equal to the XLA path (slow
off-TPU: Pallas interpret mode).

Install the package first (``pip install -e .`` from the repo root), or
prefix with ``PYTHONPATH=src``:

    python examples/train_lm.py --steps 200             # ~10M model
    python examples/train_lm.py --preset 100m --steps 300
    python examples/train_lm.py --steps 20 --planned-kernels
    # kill it mid-run, run again with the same --ckpt dir: it resumes.
"""

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import TrainConfig
from repro.configs.registry import smoke_config
from repro.data.pipeline import ShardInfo, SyntheticSource
from repro.models.module import init_params
from repro.models.registry import get_family
from repro.runtime import train as tr
from repro.runtime.fault_tolerance import Heartbeat, StragglerWatchdog


def build_cfg(preset: str):
    cfg = smoke_config("qwen3-1.7b")
    if preset == "100m":
        cfg = dataclasses.replace(
            cfg, name="qwen3-100m", n_layers=8, d_model=512, n_heads=8,
            n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32768,
        )
    return cfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--preset", default="10m", choices=["10m", "100m"])
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--planned-kernels", action="store_true",
                    help="run the planned transformer path (block GEMMs, "
                         "flash attention, planned dX/dW) instead of XLA")
    args = ap.parse_args()

    cfg = build_cfg(args.preset)
    tcfg = TrainConfig(param_dtype="float32", compute_dtype="float32",
                       learning_rate=1e-3, warmup_steps=20,
                       total_steps=args.steps, remat="none", loss_chunks=4,
                       planned_kernels=args.planned_kernels)
    fam = get_family(cfg.family)
    defs = fam.param_defs(cfg)
    params = init_params(defs, jax.random.PRNGKey(tcfg.seed), jnp.float32)
    state = tr.init_state(cfg, tcfg, params)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params")

    # Resume from the last committed checkpoint if present.
    start = 0
    last = ckpt.latest_step(args.ckpt)
    if last is not None:
        abstract = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        state = ckpt.restore(args.ckpt, last, abstract)
        start = last + 1
        print(f"resumed from step {last}")

    source = SyntheticSource(cfg.vocab, args.seq, args.batch,
                             ShardInfo(0, 1), seed=tcfg.seed)
    step_fn = jax.jit(tr.make_train_step(cfg, tcfg))
    hb = Heartbeat("host0", args.ckpt + "/hb")
    os.makedirs(args.ckpt + "/hb", exist_ok=True)
    watchdog = StragglerWatchdog(factor=3.0)

    for i in range(start, args.steps):
        t0 = time.time()
        batch = {k: jnp.asarray(v) for k, v in source(i).items()}
        state, metrics = step_fn(state, batch)
        dt = time.time() - t0
        hb.beat(i)
        if watchdog.observe(dt):
            print(f"  [watchdog] step {i} straggled ({dt:.2f}s)")
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  {dt:.2f}s")
        if i and i % args.ckpt_every == 0:
            ckpt.save(args.ckpt, i, state, n_chunks=2)
            ckpt.retain(args.ckpt, keep=2)
            print(f"  [ckpt] saved step {i}")

    ckpt.save(args.ckpt, args.steps - 1, state, n_chunks=2)
    print("done; final checkpoint committed")


if __name__ == "__main__":
    main()
