#!/usr/bin/env bash
# Tier-1 verify (ROADMAP.md): full test suite from the repo root.
# Usage: scripts/tier1.sh [--bench-smoke] [--grad-smoke] [--dist-smoke]
#                         [--autotune-smoke] [--fault-smoke] [--serve-smoke]
#                         [--transformer-smoke] [extra pytest args...]
#   --bench-smoke     additionally run one tiny planner+kernel case per
#                     registered op in interpret mode (benchmarks/run.py
#                     smoke) plus the autotune smoke's cells: the
#                     two-algorithm conv crossover (direct vs im2col-GEMM)
#                     and the fused-epilogue dgrad backward (synthesized
#                     int8 mask residual), each tune-and-replay
#   --grad-smoke      run ONLY the gradient parity harness's fast subset
#                     (tests/test_backward_plan.py TestGradSmoke) and exit
#   --dist-smoke      run ONLY the sharded-parity subset (ShardedSchedule
#                     planning pins + the forced 4-device host-mesh execution
#                     tests, which set XLA_FLAGS=--xla_force_host_platform_
#                     device_count=4 in their subprocesses) and exit
#   --autotune-smoke  run ONLY the measured-time autotuner smoke and exit:
#                     tune one tiny conv cell, one FC cell, and one
#                     two-algorithm MANTICORE conv cell (both families
#                     measured, the winner's algorithm tag replayed) in
#                     interpret mode against a tmpdir cache and assert
#                     every winner replays from it
#                     (python -m repro.plan.autotune --smoke)
#   --fault-smoke     run ONLY the elastic fault-tolerance suite and exit:
#                     seeded chaos runs (tests/test_chaos.py) — injected
#                     host death at step k on a forced multi-device
#                     subprocess mesh, assert the run recovers without
#                     operator input (mesh shrinks, ShardedSchedules
#                     re-planned for the new MeshSpec, resume from the
#                     last committed checkpoint, post-recovery losses
#                     bit-for-bit vs a no-failure run), plus corrupt-chunk
#                     fallback and non-finite-loss rollback
#   --serve-smoke     run ONLY the serving-engine smoke and exit: boot the
#                     continuous-batching engine on the smoke config twice
#                     against a mktemp autotune cache — first boot tunes
#                     the 2-bucket ladder's cells, second boot must replay
#                     every winner cache-only — push a handful of ragged
#                     requests through each and assert all complete with
#                     identical tokens (python -m repro.serve --smoke)
#   --transformer-smoke  run ONLY the transformer-wing gate and exit: the
#                     TP/EP closed-form-vs-walker parity pins, the
#                     quadrant picks, and the planned-vs-XLA train-step
#                     parity (tests/test_transformer_plan.py), then one
#                     tiny planned transformer train step through the
#                     launcher (--family transformer --planned-kernels)
# The default invocation runs the grad-smoke subset first, so backward
# regressions fail fast before the full suite spins up.  The CI matrix
# (.github/workflows/ci.yml) runs each stage as its own fast-fail job.
set -euo pipefail
cd "$(dirname "$0")/.."

BENCH_SMOKE=0
GRAD_SMOKE_ONLY=0
DIST_SMOKE_ONLY=0
AUTOTUNE_SMOKE_ONLY=0
FAULT_SMOKE_ONLY=0
SERVE_SMOKE_ONLY=0
TRANSFORMER_SMOKE_ONLY=0
while [[ "${1:-}" == "--bench-smoke" || "${1:-}" == "--grad-smoke" \
        || "${1:-}" == "--dist-smoke" || "${1:-}" == "--autotune-smoke" \
        || "${1:-}" == "--fault-smoke" || "${1:-}" == "--serve-smoke" \
        || "${1:-}" == "--transformer-smoke" ]]; do
  case "$1" in
    --bench-smoke) BENCH_SMOKE=1 ;;
    --grad-smoke) GRAD_SMOKE_ONLY=1 ;;
    --dist-smoke) DIST_SMOKE_ONLY=1 ;;
    --autotune-smoke) AUTOTUNE_SMOKE_ONLY=1 ;;
    --fault-smoke) FAULT_SMOKE_ONLY=1 ;;
    --serve-smoke) SERVE_SMOKE_ONLY=1 ;;
    --transformer-smoke) TRANSFORMER_SMOKE_ONLY=1 ;;
  esac
  shift
done

run_grad_smoke() {
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q \
    tests/test_backward_plan.py -k TestGradSmoke
}

run_dist_smoke() {
  # Sharded-plan model pins (no devices needed) + the multi-device
  # execution parity tests (each subprocess forces a 4-device host mesh).
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q \
    tests/test_plan.py -k TestShardedPlans
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q \
    tests/test_distributed.py -k "sharded or ring"
}

run_autotune_smoke() {
  # Winners land in (and replay from) a throwaway cache: the smoke must
  # prove persistence without touching the user's real cache file.
  local tmp
  tmp="$(mktemp -d)"
  trap 'rm -rf "$tmp"' RETURN
  REPRO_AUTOTUNE_CACHE="$tmp/autotune.json" \
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.plan.autotune --smoke
}

run_serve_smoke() {
  # The serving gate: two engine boots against a throwaway autotune cache
  # (tune, then cache-only) must replay every winner and produce
  # identical token streams — without touching the user's real cache.
  local tmp
  tmp="$(mktemp -d)"
  trap 'rm -rf "$tmp"' RETURN
  REPRO_AUTOTUNE_CACHE="$tmp/autotune.json" \
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.serve --smoke
}

run_transformer_smoke() {
  # The transformer-wing gate: the TP/EP ShardedSchedule pins (every ccr
  # closed form word-for-word against its executed schedule_sim walker),
  # the MANTICORE quadrant picks, the family-registry error paths, and
  # the planned-vs-XLA train-step parity — then one tiny planned
  # transformer train step end to end through the family launcher.
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q \
    tests/test_transformer_plan.py
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m repro.launch.train \
    --family transformer --planned-kernels --steps 2 --batch 2 --seq 32 \
    --mesh 1x1
}

run_fault_smoke() {
  # The elastic-recovery gate: seeded chaos (kill-at-step-k in a forced
  # multi-device subprocess, corrupt chunk, non-finite loss) must recover
  # without operator input and resume from the last committed checkpoint
  # on the shrunk mesh.
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q \
    tests/test_chaos.py
}

if [[ "$GRAD_SMOKE_ONLY" == 1 ]]; then
  run_grad_smoke
  exit 0
fi

if [[ "$TRANSFORMER_SMOKE_ONLY" == 1 ]]; then
  run_transformer_smoke
  exit 0
fi

if [[ "$FAULT_SMOKE_ONLY" == 1 ]]; then
  run_fault_smoke
  exit 0
fi

if [[ "$SERVE_SMOKE_ONLY" == 1 ]]; then
  run_serve_smoke
  exit 0
fi

if [[ "$AUTOTUNE_SMOKE_ONLY" == 1 ]]; then
  run_autotune_smoke
  exit 0
fi

if [[ "$DIST_SMOKE_ONLY" == 1 ]]; then
  run_dist_smoke
  exit 0
fi

run_grad_smoke
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"

if [[ "$BENCH_SMOKE" == 1 ]]; then
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python benchmarks/run.py smoke
  # The autotune cells ride with the bench smoke: the measured
  # direct-vs-im2col conv crossover and the fused-epilogue dgrad cell
  # (mask-aux residual synthesized) must each tune, cache, and replay.
  run_autotune_smoke
fi
