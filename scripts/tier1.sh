#!/usr/bin/env bash
# Tier-1 verify (ROADMAP.md): full test suite from the repo root.
# Usage: scripts/tier1.sh [--bench-smoke] [--grad-smoke] [extra pytest args...]
#   --bench-smoke  additionally run one tiny planner+kernel case per
#                  registered op in interpret mode (benchmarks/run.py smoke)
#   --grad-smoke   run ONLY the gradient parity harness's fast subset
#                  (tests/test_backward_plan.py TestGradSmoke) and exit
# The default invocation runs the grad-smoke subset first, so backward
# regressions fail fast before the full suite spins up.
set -euo pipefail
cd "$(dirname "$0")/.."

BENCH_SMOKE=0
GRAD_SMOKE_ONLY=0
while [[ "${1:-}" == "--bench-smoke" || "${1:-}" == "--grad-smoke" ]]; do
  case "$1" in
    --bench-smoke) BENCH_SMOKE=1 ;;
    --grad-smoke) GRAD_SMOKE_ONLY=1 ;;
  esac
  shift
done

run_grad_smoke() {
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q \
    tests/test_backward_plan.py -k TestGradSmoke
}

if [[ "$GRAD_SMOKE_ONLY" == 1 ]]; then
  run_grad_smoke
  exit 0
fi

run_grad_smoke
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"

if [[ "$BENCH_SMOKE" == 1 ]]; then
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python benchmarks/run.py smoke
fi
