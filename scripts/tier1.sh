#!/usr/bin/env bash
# Tier-1 verify (ROADMAP.md): full test suite from the repo root.
# Usage: scripts/tier1.sh [--bench-smoke] [--grad-smoke] [--dist-smoke] [extra pytest args...]
#   --bench-smoke  additionally run one tiny planner+kernel case per
#                  registered op in interpret mode (benchmarks/run.py smoke)
#   --grad-smoke   run ONLY the gradient parity harness's fast subset
#                  (tests/test_backward_plan.py TestGradSmoke) and exit
#   --dist-smoke   run ONLY the sharded-parity subset (ShardedSchedule
#                  planning pins + the forced 4-device host-mesh execution
#                  tests, which set XLA_FLAGS=--xla_force_host_platform_
#                  device_count=4 in their subprocesses) and exit
# The default invocation runs the grad-smoke subset first, so backward
# regressions fail fast before the full suite spins up.
set -euo pipefail
cd "$(dirname "$0")/.."

BENCH_SMOKE=0
GRAD_SMOKE_ONLY=0
DIST_SMOKE_ONLY=0
while [[ "${1:-}" == "--bench-smoke" || "${1:-}" == "--grad-smoke" || "${1:-}" == "--dist-smoke" ]]; do
  case "$1" in
    --bench-smoke) BENCH_SMOKE=1 ;;
    --grad-smoke) GRAD_SMOKE_ONLY=1 ;;
    --dist-smoke) DIST_SMOKE_ONLY=1 ;;
  esac
  shift
done

run_grad_smoke() {
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q \
    tests/test_backward_plan.py -k TestGradSmoke
}

run_dist_smoke() {
  # Sharded-plan model pins (no devices needed) + the multi-device
  # execution parity tests (each subprocess forces a 4-device host mesh).
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q \
    tests/test_plan.py -k TestShardedPlans
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q \
    tests/test_distributed.py -k "sharded or ring"
}

if [[ "$GRAD_SMOKE_ONLY" == 1 ]]; then
  run_grad_smoke
  exit 0
fi

if [[ "$DIST_SMOKE_ONLY" == 1 ]]; then
  run_dist_smoke
  exit 0
fi

run_grad_smoke
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"

if [[ "$BENCH_SMOKE" == 1 ]]; then
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python benchmarks/run.py smoke
fi
