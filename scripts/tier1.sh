#!/usr/bin/env bash
# Tier-1 verify (ROADMAP.md): full test suite from the repo root.
# Usage: scripts/tier1.sh [--bench-smoke] [extra pytest args...]
#   --bench-smoke  additionally run one tiny planner+kernel case per
#                  registered op in interpret mode (benchmarks/run.py smoke)
set -euo pipefail
cd "$(dirname "$0")/.."

BENCH_SMOKE=0
if [[ "${1:-}" == "--bench-smoke" ]]; then
  BENCH_SMOKE=1
  shift
fi

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"

if [[ "$BENCH_SMOKE" == 1 ]]; then
  PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python benchmarks/run.py smoke
fi
