"""Deterministic, shard-aware token data pipeline.

Three sources:
  * ``SyntheticSource`` - structured pseudo-text (Zipfian unigrams with a
    Markov flavour) generated deterministically from (seed, step, shard),
    so every host produces exactly its shard with no coordination;
  * ``MemmapSource``   - packed uint16/uint32 token files (np.memmap),
    strided by (host, step) for disjoint coverage; the standard format a
    real run would use;
  * ``SyntheticImageSource`` - CIFAR-shaped image/label batches for the
    cnn family (the paper's own domain), same (seed, step, shard)
    determinism.

Token sources yield {"tokens": [B_local, S], "labels": [B_local, S]} with
labels = next-token shifted and the final position masked via label -1
(the loss ignores label < 0); the image source yields
{"images": [B_local, IMG, IMG, C], "labels": [B_local]}.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ShardInfo:
    index: int  # this host's shard index
    count: int  # number of data shards


class SyntheticSource:
    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 shard: ShardInfo = ShardInfo(0, 1), seed: int = 0):
        assert global_batch % shard.count == 0
        self.vocab, self.seq, self.batch = vocab, seq_len, global_batch // shard.count
        self.shard, self.seed = shard, seed
        # Zipf-ish unigram table (clipped to vocab).
        probs = 1.0 / np.arange(1, min(vocab, 50000) + 1) ** 1.1
        self._probs = probs / probs.sum()

    def __call__(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard.index])
        )
        base = rng.choice(len(self._probs), size=(self.batch, self.seq + 1),
                          p=self._probs).astype(np.int64)
        # Markov flavour: each token mixes in the previous one.
        mixed = (base + np.roll(base, 1, axis=1) // 2) % self.vocab
        tokens = mixed[:, :-1].astype(np.int32)
        labels = mixed[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}


class SyntheticImageSource:
    """Deterministic image/label batches for the cnn family: class-coded
    blobs on noise, so the training loss can actually fall."""

    def __init__(self, img: int, channels: int, classes: int,
                 global_batch: int, shard: ShardInfo = ShardInfo(0, 1),
                 seed: int = 0):
        assert global_batch % shard.count == 0
        self.img, self.channels, self.classes = img, channels, classes
        self.batch = global_batch // shard.count
        self.shard, self.seed = shard, seed

    def __call__(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard.index])
        )
        labels = rng.integers(0, self.classes, size=(self.batch,)).astype(np.int32)
        images = rng.standard_normal(
            (self.batch, self.img, self.img, self.channels)).astype(np.float32)
        # A learnable class signal: shift each image's mean by its label.
        images += (labels / max(1, self.classes - 1) - 0.5)[:, None, None, None]
        return {"images": images, "labels": labels}


class MemmapSource:
    def __init__(self, path: str, vocab: int, seq_len: int, global_batch: int,
                 shard: ShardInfo = ShardInfo(0, 1), dtype=np.uint16):
        self.data = np.memmap(path, dtype=dtype, mode="r")
        assert global_batch % shard.count == 0
        self.vocab, self.seq = vocab, seq_len
        self.batch = global_batch // shard.count
        self.shard = shard
        self.n_windows = (len(self.data) - 1) // seq_len
        if self.n_windows < global_batch:
            raise ValueError("dataset too small for one global batch")

    def __call__(self, step: int) -> dict:
        g = self.batch * self.shard.count
        start = (step * g + self.shard.index * self.batch) % self.n_windows
        idx = (np.arange(self.batch) + start) % self.n_windows
        rows = np.stack([self.data[i * self.seq : i * self.seq + self.seq + 1] for i in idx])
        rows = rows.astype(np.int32) % self.vocab
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


def write_token_file(path: str, tokens: np.ndarray, dtype=np.uint16) -> None:
    np.asarray(tokens, dtype).tofile(path)
