"""Deterministic, seeded fault injection for the elastic training loop.

Production failure modes the Manticore many-cluster story must survive —
a host dying mid-run, a straggling cluster, a checkpoint chunk torn by a
mid-write death, a non-finite loss — injected on a fixed schedule so the
recovery state machine (runtime/train.py ``run_elastic``) can be tested
end to end and *reproducibly*: the same ``ChaosConfig`` (spec + seed)
always injects the same faults at the same steps, which is what lets the
fault smoke assert bit-for-bit recovery parity.

Spec grammar (``launch/train.py --chaos``), comma-separated events:

    kill@K        host death detected at step K (before the step runs)
    kill@KxH      ... H host groups die at once
    straggle@K    the step at K sleeps (watchdog fodder)
    straggle@KxS  ... for S seconds
    corrupt@K     the checkpoint committed at step K gets one chunk torn
    nan@K         the loss at step K comes back non-finite
    nan@KxN       ... for N consecutive steps

Every event fires at most once (its configured burst), so a recovered run
replaying the same step numbers is not re-killed — exactly the semantics
of a real one-off hardware failure.
"""

from __future__ import annotations

import dataclasses
import math
import os
import time

import numpy as np


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """The injection schedule.  ``None`` step means "never"."""

    seed: int = 0
    kill_at_step: int | None = None
    kill_hosts: int = 1  # data-parallel host groups lost at once
    straggle_at_step: int | None = None
    straggle_seconds: float = 0.05
    corrupt_at_step: int | None = None  # tear a chunk of the ckpt saved here
    nan_at_step: int | None = None
    nan_steps: int = 1  # consecutive non-finite losses

    @classmethod
    def parse(cls, spec: str, seed: int = 0) -> "ChaosConfig":
        """Parse the ``--chaos`` grammar above (``"kill@5,nan@7x3"``)."""
        kw: dict = {"seed": seed}
        for tok in filter(None, (t.strip() for t in spec.split(","))):
            name, _, rest = tok.partition("@")
            if not rest:
                raise ValueError(f"chaos event {tok!r}: expected NAME@STEP")
            at, _, extra = rest.partition("x")
            step = int(at)
            if name == "kill":
                kw["kill_at_step"] = step
                if extra:
                    kw["kill_hosts"] = int(extra)
            elif name == "straggle":
                kw["straggle_at_step"] = step
                if extra:
                    kw["straggle_seconds"] = float(extra)
            elif name == "corrupt":
                kw["corrupt_at_step"] = step
            elif name == "nan":
                kw["nan_at_step"] = step
                if extra:
                    kw["nan_steps"] = int(extra)
            else:
                raise ValueError(
                    f"unknown chaos event {name!r} "
                    "(have kill/straggle/corrupt/nan)")
        return cls(**kw)

    def __str__(self) -> str:
        parts = []
        if self.kill_at_step is not None:
            parts.append(f"kill@{self.kill_at_step}x{self.kill_hosts}")
        if self.straggle_at_step is not None:
            parts.append(f"straggle@{self.straggle_at_step}"
                         f"x{self.straggle_seconds}")
        if self.corrupt_at_step is not None:
            parts.append(f"corrupt@{self.corrupt_at_step}")
        if self.nan_at_step is not None:
            parts.append(f"nan@{self.nan_at_step}x{self.nan_steps}")
        return ",".join(parts) or "none"


def corrupt_chunk(ckpt_dir: str, step: int, seed: int = 0) -> str:
    """Tear one chunk of a committed checkpoint step, the way a host dying
    mid-flush would: truncate the file part-way and scribble on the tail.
    The victim chunk is chosen by the seeded rng (deterministic per
    (seed, step)).  Returns the path torn."""
    import json

    step_dir = os.path.join(ckpt_dir, f"step_{step:07d}")
    with open(os.path.join(step_dir, "index.json")) as f:
        index = json.load(f)
    files = sorted(ch["file"] for meta in index["leaves"].values()
                   for ch in meta["chunks"])
    if not files:
        raise ValueError(f"step {step}: no chunks to corrupt")
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    victim = os.path.join(step_dir, files[int(rng.integers(len(files)))])
    size = os.path.getsize(victim)
    keep = max(1, size // 2)
    with open(victim, "r+b") as f:
        f.truncate(keep)
        f.seek(max(0, keep - 8))
        f.write(rng.integers(0, 256, size=8, dtype=np.uint8).tobytes())
    return victim


class ChaosMonkey:
    """Stateful driver of one ChaosConfig: the elastic loop calls the
    hooks below each step; each event fires its configured burst exactly
    once across the whole run (recoveries replay step numbers)."""

    def __init__(self, cfg: ChaosConfig, devices_per_host: int = 1):
        self.cfg = cfg
        self.devices_per_host = devices_per_host
        self._fired: set[str] = set()
        self._nan_left = cfg.nan_steps

    def on_step_start(self, step: int) -> None:
        """Straggler injection: this step runs slow."""
        c = self.cfg
        if (c.straggle_at_step == step and "straggle" not in self._fired):
            self._fired.add("straggle")
            time.sleep(c.straggle_seconds)

    def host_death(self, step: int, n_devices: int):
        """At the kill step: the dead host names and the surviving device
        count, else None.  Raising is the caller's job (the loop turns
        this into fault_tolerance.HostFailure)."""
        c = self.cfg
        if c.kill_at_step != step or "kill" in self._fired:
            return None
        self._fired.add("kill")
        dead = [f"host{n_devices // self.devices_per_host - 1 - i}"
                for i in range(c.kill_hosts)]
        survivors = n_devices - c.kill_hosts * self.devices_per_host
        if survivors <= 0:
            raise ValueError(
                f"chaos kill@{step} leaves no survivors "
                f"({c.kill_hosts} hosts x {self.devices_per_host} devices "
                f"from {n_devices})")
        return dead, survivors

    def poison_loss(self, step: int, loss: float) -> float:
        """Non-finite-loss injection for ``nan_steps`` consecutive steps."""
        c = self.cfg
        if (c.nan_at_step is not None and self._nan_left > 0
                and step >= c.nan_at_step):
            self._nan_left -= 1
            return math.nan
        return loss

    def after_save(self, ckpt_dir: str, step: int) -> str | None:
        """Corrupt-chunk injection, right after the commit of step's
        checkpoint (the torn-write window)."""
        c = self.cfg
        if c.corrupt_at_step != step or "corrupt" in self._fired:
            return None
        self._fired.add("corrupt")
        return corrupt_chunk(ckpt_dir, step, seed=c.seed)
