"""Fault tolerance: heartbeats, straggler detection, elastic re-mesh.

On real multi-host TPU the coordinator sees worker liveness through the
heartbeat files (one per host on shared storage) and drives the restart
protocol below; here the same machinery runs single-process and is
exercised by failure-injection tests.

Restart protocol (train.py launcher):
  1. every worker writes ``hb_<host>.json`` (step, walltime) each step;
  2. the monitor flags a host stale after ``timeout`` seconds;
  3. surviving hosts abort the step, a new mesh is built from the
     remaining host count (``shrink_mesh``: the data axis shrinks, model
     axis is preserved — TP groups must stay intact);
  4. the last committed checkpoint restores with the *new* shardings
     (checkpoint/checkpoint.py reshard-on-restore), and training resumes.

Straggler mitigation: per-step wall-clock watchdog against a rolling
median; every trip is logged, and after
``RecoveryPolicy.straggler_patience`` consecutive trips the elastic loop
escalates to :class:`HostFailure` so the slow host is actually evicted
(shrink + re-plan + restore).  ``straggler_patience=0`` keeps the
report-only behavior (step skipping is never silent either way).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time


@dataclasses.dataclass
class Heartbeat:
    host: str
    dir: str

    def beat(self, step: int) -> None:
        path = os.path.join(self.dir, f"hb_{self.host}.json")
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "time": time.time()}, f)
        os.replace(tmp, path)


class HostFailure(RuntimeError):
    """A host (data-parallel group) died mid-run.  The elastic loop
    (runtime/train.py run_elastic) catches this, shrinks the mesh to the
    survivors, re-plans every ShardedSchedule, and restores the last
    committed checkpoint with the new shardings."""

    def __init__(self, dead: list[str], survivors: int):
        super().__init__(f"dead hosts {dead}; {survivors} devices survive")
        self.dead = list(dead)
        self.survivors = survivors


class Monitor:
    def __init__(self, dir: str, timeout: float = 60.0):
        self.dir, self.timeout = dir, timeout

    def _read(self, fn: str) -> dict | None:
        """One heartbeat, or None if unreadable.  A host that dies mid-write
        leaves a torn/empty hb_*.json — that's evidence of failure, so it
        must read as *stale*, never crash the coordinator with a
        JSONDecodeError."""
        try:
            with open(os.path.join(self.dir, fn)) as f:
                hb = json.load(f)
            if not isinstance(hb.get("time"), (int, float)):
                return None
            return hb
        except (OSError, json.JSONDecodeError, AttributeError):
            return None

    def _hosts(self, now: float | None):
        now = now if now is not None else time.time()
        for fn in sorted(os.listdir(self.dir)):
            if fn.startswith("hb_") and fn.endswith(".json"):
                hb = self._read(fn)
                alive = hb is not None and now - hb["time"] <= self.timeout
                yield fn[3:-5], alive

    def stale_hosts(self, now: float | None = None) -> list[str]:
        return [h for h, alive in self._hosts(now) if not alive]

    def live_hosts(self, now: float | None = None) -> list[str]:
        return [h for h, alive in self._hosts(now) if alive]


class StragglerWatchdog:
    """Rolling-median step-time watchdog."""

    def __init__(self, factor: float = 2.0, window: int = 32):
        self.factor, self.window = factor, window
        self.times: list[float] = []

    def observe(self, step_seconds: float) -> bool:
        """Returns True if this step is a straggler."""
        self.times.append(step_seconds)
        self.times = self.times[-self.window :]
        if len(self.times) < 8:
            return False
        med = sorted(self.times)[len(self.times) // 2]
        return step_seconds > self.factor * med


def shrink_mesh_shape(n_devices: int, model: int = 16, pod: int | None = None):
    """Largest (data, model) [or (pod, data, model)] mesh from survivors;
    the model (TP) extent is preserved, data shrinks."""
    if n_devices % model:
        raise ValueError(f"survivors ({n_devices}) not divisible by model={model}")
    rest = n_devices // model
    if pod:
        if rest % pod:
            pod = 1
        return (pod, rest // pod, model)
    return (rest, model)
