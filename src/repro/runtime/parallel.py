"""Parallelism context + divisibility-aware sharding policy.

The production mesh is fixed by the assignment: ``(data=16, model=16)``
single-pod and ``(pod=2, data=16, model=16)`` multi-pod.  Within that
constraint the policy adapts per architecture/shape:

  * batch dims shard over as many of (pod, data) as divide the batch;
  * the TP axis ('model') lands on the first divisible candidate dim
    (kv-heads, then head_dim, then sequence for KV caches);
  * when batch can't use the data axes (long_500k, batch=1), the KV/state
    sequence or head dims take them instead so no axis idles.
"""

from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    mesh: Mesh
    dp_axes: tuple[str, ...] = ("data",)
    tp_axis: str = "model"

    def plan_mesh(self):
        """This context's mesh as a hashable ``repro.plan.MeshSpec`` — the
        handle the mesh-aware planners take, so launchers and the runtime
        resolve ShardedSchedules from the same mesh they execute on."""
        from repro.plan import mesh_spec

        return mesh_spec(self.mesh)

    def sharded_shardings(self, sharded) -> tuple[NamedSharding, ...]:
        """Lower a ShardedSchedule's partition (operands..., output) into
        NamedShardings on this context's mesh — the uniform bridge from
        planner output to pjit/shard_map placement."""
        from repro.plan import partition_specs

        return tuple(NamedSharding(self.mesh, sp)
                     for sp in partition_specs(sharded))

    @property
    def dp_size(self) -> int:
        n = 1
        for a in self.dp_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.tp_axis]

    def batch_axes(self, batch: int) -> tuple[str, ...]:
        """Largest prefix-product of dp axes dividing ``batch``.
        dp_axes ordered outermost-first (('pod','data'))."""
        axes: tuple[str, ...] = ()
        n = 1
        for a in self.dp_axes:
            if batch % (n * self.mesh.shape[a]) == 0:
                axes += (a,)
                n *= self.mesh.shape[a]
        return axes

    def spare_dp_axes(self, batch: int) -> tuple[str, ...]:
        used = self.batch_axes(batch)
        return tuple(a for a in self.dp_axes if a not in used)

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)


def first_divisible(size_by_candidate: list[tuple[int, int]], axis_size: int) -> int:
    """Index of the first (dim_index, dim_size) whose size divides; -1 if none."""
    for i, (_, n) in enumerate(size_by_candidate):
        if n % axis_size == 0 and n >= axis_size:
            return i
    return -1


def kv_cache_spec(ctx: ParallelCtx, cache_shape: tuple, batch_dim: int = 1,
                  seq_dim: int = 2, head_dim: int = 3, dh_dim: int = 4) -> P:
    """Spec for a [L, B, S, H, Dh]-like cache tensor."""
    entries: list = [None] * len(cache_shape)
    B = cache_shape[batch_dim]
    baxes = ctx.batch_axes(B)
    if baxes:
        entries[batch_dim] = baxes if len(baxes) > 1 else baxes[0]
    # TP axis: kv heads > head_dim > sequence.
    tp = ctx.tp_size
    cands = [(head_dim, cache_shape[head_dim]), (dh_dim, cache_shape[dh_dim]),
             (seq_dim, cache_shape[seq_dim])]
    pick = first_divisible(cands, tp)
    if pick >= 0:
        entries[cands[pick][0]] = ctx.tp_axis
    # Idle dp axes (batch too small): spread the sequence.
    spare = ctx.spare_dp_axes(B)
    if spare and entries[seq_dim] is None:
        n = 1
        for a in spare:
            n *= ctx.mesh.shape[a]
        if cache_shape[seq_dim] % n == 0:
            entries[seq_dim] = spare if len(spare) > 1 else spare[0]
    return P(*entries)


def state_spec(ctx: ParallelCtx, shape: tuple, batch_dim: int = 1) -> P:
    """Spec for recurrent state tensors [L, B, ...]: batch over dp, first
    divisible trailing dim over model."""
    entries: list = [None] * len(shape)
    baxes = ctx.batch_axes(shape[batch_dim])
    if baxes:
        entries[batch_dim] = baxes if len(baxes) > 1 else baxes[0]
    cands = [(i, shape[i]) for i in range(batch_dim + 1, len(shape))]
    pick = first_divisible(cands, ctx.tp_size)
    if pick >= 0:
        entries[cands[pick][0]] = ctx.tp_axis
    return P(*entries)


def cache_specs(ctx: ParallelCtx, cache_tree) -> dict:
    """Specs for a family's cache pytree by shape pattern."""

    def one(leaf):
        shp = leaf.shape
        if len(shp) == 5:  # [L/A, B, S, H, Dh] KV cache or [L,B,H,hd,N] state
            # Heuristic: KV caches have S (dim 2) much larger than H (dim 3).
            if shp[2] >= shp[3]:
                return kv_cache_spec(ctx, shp)
            return state_spec(ctx, shp)
        return state_spec(ctx, shp)

    return jax.tree.map(one, cache_tree)


def batch_spec(ctx: ParallelCtx, batch: int, ndim: int = 2) -> P:
    baxes = ctx.batch_axes(batch)
    lead = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)
    return P(lead, *([None] * (ndim - 1)))


def constrain(x, ctx: ParallelCtx | None, entries: tuple):
    """with_sharding_constraint that no-ops without a ctx (smoke tests).

    ``entries`` may contain the sentinel string "dp": it resolves to the
    dp axes that divide that dim's size (or None).  Anchoring activations
    at layer boundaries keeps GSPMD from silently replicating the batch
    through reshape/transpose/scan chains (observed on the CPU backend).
    """
    if ctx is None:
        return x
    resolved = []
    for i, e in enumerate(entries):
        if e == "dp":
            ax = ctx.batch_axes(x.shape[i])
            resolved.append(ax if len(ax) > 1 else (ax[0] if ax else None))
        elif e == "tp?":
            resolved.append(ctx.tp_axis if x.shape[i] % ctx.tp_size == 0 else None)
        else:
            resolved.append(e)
    return jax.lax.with_sharding_constraint(x, P(*resolved))
