"""Serving step builders: prefill (KV-cache fill + last-token logits) and
decode (one token against a long cache)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.registry import get_family


def make_prefill_step(cfg: ModelConfig, max_seq: int, compute_dtype="bfloat16",
                      cache_dtype="bfloat16", parallel=None):
    fam = get_family(cfg.family)
    dt = jnp.dtype(compute_dtype)

    def prefill(params, batch):
        tokens = batch["tokens"]
        B = tokens.shape[0]
        cache = fam.init_cache(cfg, B, max_seq, jnp.dtype(cache_dtype))
        extra = {"frames": batch["frames"].astype(dt)} if "frames" in batch else {}
        h, cache = fam.forward(
            cfg, params, tokens, pos0=0, cache=cache, compute_dtype=dt,
            parallel=parallel, **extra,
        )
        logits = fam.logits(cfg, params, h[:, -1:, :])
        return cache, logits

    return prefill


def make_decode_step(cfg: ModelConfig, compute_dtype="bfloat16", parallel=None):
    """decode(params, cache, tokens [B,1], pos scalar) -> (cache, logits)."""
    fam = get_family(cfg.family)
    dt = jnp.dtype(compute_dtype)

    def decode(params, cache, tokens, pos):
        h, cache = fam.forward(
            cfg, params, tokens, pos0=pos, cache=cache, compute_dtype=dt,
            parallel=parallel,
        )
        logits = fam.logits(cfg, params, h)
        return cache, logits

    return decode


def greedy_generate(cfg: ModelConfig, params, prompt, steps: int, max_seq: int,
                    compute_dtype="float32"):
    """Reference loop for tests/examples: prefill then greedy decode."""
    fam = get_family(cfg.family)
    prefill = make_prefill_step(cfg, max_seq, compute_dtype, compute_dtype)
    decode = jax.jit(make_decode_step(cfg, compute_dtype))
    cache, logits = prefill(params, {"tokens": prompt})
    B, S = prompt.shape
    toks = [jnp.argmax(logits[:, -1], -1)]
    pos = S
    for _ in range(steps - 1):
        cache, logits = decode(params, cache, toks[-1][:, None], pos)
        toks.append(jnp.argmax(logits[:, -1], -1))
        pos += 1
    return jnp.stack(toks, 1)
