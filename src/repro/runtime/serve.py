"""Serving step builders: prefill (KV-cache fill + last-token logits) and
decode (one token against a long cache).

Two tiers:

  * ``make_prefill_step`` / ``make_decode_step`` — the simple whole-batch
    builders (shared scalar decode position) used by tests/examples and
    ``greedy_generate``.
  * ``make_bucket_prefill_step`` / ``make_slot_decode_step`` — the
    continuous-batching builders ``repro.serve.Engine`` compiles once per
    warmup bucket: ragged prompts padded to the bucket shape with the
    last-token logits gathered at each row's true length, and per-slot
    decode positions (vmap over the cache's slot axis) so every KV slot
    advances independently.  Both accept the bucket's warmup-resolved
    ``schedules`` (``BucketLadder.plans[bucket]``) and fail fast when a
    planned cell does not fit the machine — request-time dispatch never
    re-plans.

Bit-identity contract (asserted by tests/test_serve.py): the bucketed
builders produce the same greedy tokens, bitwise, as the unbucketed path —
causal masking makes padded positions contribute exactly-zero softmax
weight (the -1e30 mask underflows), rows of every matmul are independent,
and decode overwrites cache positions >= the true prompt length as it
generates.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.registry import get_family


def _check_schedules(schedules, machine) -> None:
    """Warmup-resolved cells must fit the serving machine — a plan that
    spills VMEM should fail at boot, not at request time."""
    if not schedules or machine is None:
        return
    for name, sched in schedules.items():
        fits = getattr(sched, "fits", None)
        if fits is not None and not fits(machine):
            raise ValueError(
                f"serving cell {name!r} does not fit {machine.name}: "
                f"{sched}")


def make_prefill_step(cfg: ModelConfig, max_seq: int, compute_dtype="bfloat16",
                      cache_dtype="bfloat16", parallel=None):
    fam = get_family(cfg.family)
    dt = jnp.dtype(compute_dtype)

    def prefill(params, batch):
        tokens = batch["tokens"]
        B = tokens.shape[0]
        cache = fam.init_cache(cfg, B, max_seq, jnp.dtype(cache_dtype))
        extra = {"frames": batch["frames"].astype(dt)} if "frames" in batch else {}
        h, cache = fam.forward(
            cfg, params, tokens, pos0=0, cache=cache, compute_dtype=dt,
            parallel=parallel, **extra,
        )
        logits = fam.logits(cfg, params, h[:, -1:, :])
        return cache, logits

    return prefill


def make_decode_step(cfg: ModelConfig, compute_dtype="bfloat16", parallel=None):
    """decode(params, cache, tokens [B,1], pos scalar) -> (cache, logits)."""
    fam = get_family(cfg.family)
    dt = jnp.dtype(compute_dtype)

    def decode(params, cache, tokens, pos):
        h, cache = fam.forward(
            cfg, params, tokens, pos0=pos, cache=cache, compute_dtype=dt,
            parallel=parallel,
        )
        logits = fam.logits(cfg, params, h)
        return cache, logits

    return decode


def make_bucket_prefill_step(cfg: ModelConfig, max_seq: int,
                             compute_dtype="float32", cache_dtype="float32",
                             parallel=None, schedules=None, machine=None):
    """``prefill(params, tokens [B, S_bucket], lengths [B]) ->
    (cache, logits [B, vocab])`` for ragged prompts padded to a bucket.

    The hidden state is gathered at each row's true last position
    (``lengths - 1``), not at the padded ``S_bucket - 1`` — with causal
    masking that makes the returned logits independent of the padding.
    The cache is allocated at the full ``max_seq`` extent so the engine
    can scatter rows straight into its slot pool."""
    fam = get_family(cfg.family)
    dt = jnp.dtype(compute_dtype)
    _check_schedules(schedules, machine)

    def prefill(params, tokens, lengths):
        B, S = tokens.shape
        cache = fam.init_cache(cfg, B, max_seq, jnp.dtype(cache_dtype))
        h, cache = fam.forward(
            cfg, params, tokens, pos0=0, cache=cache, compute_dtype=dt,
            parallel=parallel,
        )
        last = jnp.clip(lengths - 1, 0, S - 1).astype(jnp.int32)
        h_last = h[jnp.arange(B), last]  # [B, d]
        logits = fam.logits(cfg, params, h_last[:, None, :])
        return cache, logits[:, 0]

    return prefill


def make_slot_decode_step(cfg: ModelConfig, compute_dtype="float32",
                          parallel=None, schedules=None, machine=None):
    """``decode(params, cache, tokens [B], pos [B]) ->
    (cache, logits [B, vocab])`` with a *per-slot* position.

    The simple ``make_decode_step`` advances every row at one shared
    scalar position — useless for continuous batching, where each slot is
    mid-way through its own sequence.  Here the single-row decode is
    vmapped over the cache's slot axis (axis 1 of every leaf, see
    ``models.registry.init_cache_slots``) so each slot reads and writes
    its own cache row at its own position."""
    fam = get_family(cfg.family)
    dt = jnp.dtype(compute_dtype)
    _check_schedules(schedules, machine)

    def one_slot(params, cache_row, tok, pos):
        # cache_row leaves have the slot axis stripped; re-insert a
        # batch=1 axis for the family forward and strip it again after.
        cache1 = jax.tree.map(lambda c: c[:, None], cache_row)
        h, cache1 = fam.forward(
            cfg, params, tok[None, None], pos0=pos, cache=cache1,
            compute_dtype=dt, parallel=parallel,
        )
        logits = fam.logits(cfg, params, h)
        return jax.tree.map(lambda c: c[:, 0], cache1), logits[0, 0]

    def decode(params, cache, tokens, pos):
        axes = jax.tree.map(lambda _: 1, cache)
        new_cache, logits = jax.vmap(
            lambda c, t, p: one_slot(params, c, t, p),
            in_axes=(axes, 0, 0), out_axes=(axes, 0),
        )(cache, tokens.astype(jnp.int32), pos.astype(jnp.int32))
        return new_cache, logits

    return decode


def greedy_generate(cfg: ModelConfig, params, prompt, steps: int, max_seq: int,
                    compute_dtype="float32"):
    """Reference loop for tests/examples: prefill then greedy decode."""
    fam = get_family(cfg.family)
    prefill = make_prefill_step(cfg, max_seq, compute_dtype, compute_dtype)
    decode = jax.jit(make_decode_step(cfg, compute_dtype))
    cache, logits = prefill(params, {"tokens": prompt})
    B, S = prompt.shape
    toks = [jnp.argmax(logits[:, -1], -1)]
    pos = S
    for _ in range(steps - 1):
        cache, logits = decode(params, cache, toks[-1][:, None], pos)
        toks.append(jnp.argmax(logits[:, -1], -1))
        pos += 1
    return jnp.stack(toks, 1)
