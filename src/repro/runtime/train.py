"""Train-step builder: chunked cross-entropy, microbatch gradient
accumulation (lax.scan), AdamW, optional error-feedback int8 compression.

The FC-layer insight of the paper shows up twice here: the logits head is
a batched FC layer (vocab = D_O) computed in Delta_O-style *token chunks*
so the [tokens, vocab] logits volume is never resident at once; and the
gradient all-reduce over the data axes is Alg 4's private-output reduction
at datacenter scale.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models.registry import get_family
from repro.optim import adamw
from repro.optim.compression import compress_tree, init_error_buffers


@dataclasses.dataclass(frozen=True)
class TrainState:
    params: Any
    opt: adamw.AdamWState
    err: Any = None  # error-feedback buffers (compression) or None


jax.tree_util.register_dataclass(
    TrainState, data_fields=["params", "opt", "err"], meta_fields=[]
)


def chunked_ce(cfg: ModelConfig, fam, params, hidden, labels, n_chunks: int,
               parallel=None, schedules: dict | None = None):
    """Cross-entropy without materializing [B, S, vocab]: scan over token
    chunks; labels < 0 are masked.  ``schedules`` (a planned-kernel
    schedule set with a "logits" entry, e.g. ``transformer.plan_training``)
    routes the per-chunk logits GEMM through the family's planned head —
    the plan layer sized that cell at exactly this chunk M."""
    from repro.runtime.parallel import constrain

    B, S, d = hidden.shape
    n = n_chunks
    while S % n:
        n -= 1
    hs = hidden.reshape(B, n, S // n, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n, S // n).transpose(1, 0, 2)
    hs = constrain(hs, parallel, (None, "dp", None, None))
    ls = constrain(ls, parallel, (None, "dp", None))
    lkw = {"schedules": schedules} if schedules else {}

    def step(carry, xs):
        h, lab = xs
        logits = fam.logits(cfg, params, h, **lkw).astype(jnp.float32)
        logits = constrain(logits, parallel, ("dp", None, "tp?"))
        lse = jax.nn.logsumexp(logits, -1)
        tgt = jnp.take_along_axis(logits, jnp.maximum(lab, 0)[..., None], -1)[..., 0]
        mask = (lab >= 0).astype(jnp.float32)
        nll = (lse - tgt) * mask
        return (carry[0] + nll.sum(), carry[1] + mask.sum()), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.zeros(()), jnp.zeros(())), (hs, ls))
    return tot / jnp.maximum(cnt, 1.0)


def make_loss_fn(cfg: ModelConfig, tcfg: TrainConfig, parallel=None):
    """The family registry owns the loss: a family providing a
    ``make_loss_fn(cfg, tcfg, parallel)`` hook (cnn's image
    cross-entropy with planned conv/FC kernels, the dense transformer's
    planned-GEMM chunked CE) builds it here; every other token family
    falls back to the generic forward + chunked-CE composition below —
    no family branching at this call site."""
    fam = get_family(cfg.family)
    hook = getattr(fam, "make_loss_fn", None)
    if hook is not None:
        return hook(cfg, tcfg, parallel)

    dt = jnp.dtype(tcfg.compute_dtype)

    def loss_fn(params, batch):
        extra = {"frames": batch["frames"].astype(dt)} if "frames" in batch else {}
        h, _ = fam.forward(
            cfg, params, batch["tokens"], remat=tcfg.remat, compute_dtype=dt,
            parallel=parallel, **extra,
        )
        return chunked_ce(cfg, fam, params, h, batch["labels"], tcfg.loss_chunks,
                          parallel)

    return loss_fn


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, parallel=None,
                    grad_specs=None):
    """Returns train_step(state, batch) -> (state, metrics).

    If the batch leaves have an extra leading accumulation dim
    ([n_accum, micro, ...]), gradients are accumulated over it with a scan.
    ``grad_specs`` (PartitionSpec pytree, usually the FSDP/ZeRO specs of
    the optimizer moments) pins the f32 accumulator's sharding: without it
    GSPMD replicates the accumulated gradient over the data axes (an extra
    full-param f32 buffer per device — 78 GiB on grok-1 — fed by an
    all-reduce per microbatch; pinned, the per-micro reduction becomes a
    reduce-scatter, ZeRO-2 style).
    """
    loss_fn = make_loss_fn(cfg, tcfg, parallel)

    def _pin(tree):
        if grad_specs is None or parallel is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree, grad_specs)

    def train_step(state: TrainState, batch: dict):
        params = state.params
        accum = "tokens" in batch and batch["tokens"].ndim == 3
        accum = accum or ("images" in batch and batch["images"].ndim == 5)

        if accum:
            n = jax.tree.leaves(batch)[0].shape[0]

            def acc(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (_pin(gsum), lsum + l), None

            g0 = _pin(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (grads, loss), _ = jax.lax.scan(acc, (g0, jnp.zeros(())), batch)
            grads = jax.tree.map(lambda g: g / n, grads)
            loss = loss / n
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = _pin(jax.tree.map(lambda g: g.astype(jnp.float32), grads))

        err = state.err
        if tcfg.grad_compression == "int8_ef" and err is not None:
            grads, err = compress_tree(
                jax.tree.map(lambda g: g.astype(jnp.float32), grads), err
            )

        params, opt, metrics = adamw.apply_updates(params, grads, state.opt, tcfg)
        metrics = dict(metrics, loss=loss)
        return TrainState(params, opt, err), metrics

    return train_step


def init_state(cfg: ModelConfig, tcfg: TrainConfig, params) -> TrainState:
    err = init_error_buffers(params) if tcfg.grad_compression == "int8_ef" else None
    return TrainState(params=params, opt=adamw.init(params), err=err)


# ---------------------------------------------------------------------------
# Elastic fault-tolerant training loop (DESIGN.md Sec. 7)
#
# The many-cluster premise of the paper meets production reality here: a
# host WILL die mid-run, and since PR 4 made partitioning a planner output,
# surviving is a *plan-layer* operation — a shrunk mesh is a new MeshSpec,
# so every ShardedSchedule must be re-planned (the ring/psum argmin can
# flip at the new device count) before the checkpoint restores with the
# new shardings.  run_elastic() owns the generic state machine; the
# launcher owns build() (mesh + step_fn + plans + restore for a given
# device count).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """Bounds on the recovery state machine: how many re-meshes before
    giving up, how long to back off between them (doubled per retry), how
    many consecutive non-finite losses are skipped before rolling back
    to the last committed checkpoint, and how many consecutive straggler
    watchdog trips escalate to a :class:`HostFailure` eviction
    (``straggler_patience=0``, the default, keeps the old report-only
    behavior: trips are logged but never acted on)."""

    max_recoveries: int = 3
    backoff_seconds: float = 0.0
    nonfinite_patience: int = 3
    straggler_patience: int = 0


@dataclasses.dataclass
class ElasticRun:
    """Everything run_elastic needs for one incarnation of the run — the
    launcher's ``build(n_devices)`` returns a fresh one after every
    re-mesh (new mesh, re-planned step_fn, restored state)."""

    step_fn: Callable  # (state, batch) -> (state, metrics)
    state: Any
    start: int  # first step this incarnation executes
    n_devices: int = 1
    mesh: Any = None  # context manager (jax Mesh); None -> nullcontext
    # save(step, state): commit a checkpoint.  May return an async handle
    # (anything with .join(), e.g. checkpoint.AsyncSave) — run_elastic then
    # overlaps the write with training and joins it before the *next*
    # commit, at recovery, and at the end, surfacing writer failures at
    # the join point.  A None return means the save was synchronous.
    save: Callable | None = None
    ckpt_dir: str | None = None
    ckpt_every: int = 0
    devices_per_host: int = 1  # devices lost per dead host (TP extent)
    heartbeat: Any = None  # fault_tolerance.Heartbeat
    monitor: Any = None  # fault_tolerance.Monitor
    watchdog: Any = None  # fault_tolerance.StragglerWatchdog
    log_every: int = 10


def run_elastic(build: Callable, source: Callable, steps: int, *,
                policy: RecoveryPolicy | None = None, chaos=None,
                log: Callable = print):
    """Drive training to ``steps`` through failures.

    ``build(n_devices | None)`` -> :class:`ElasticRun`; ``None`` means the
    initial (full) device set.  Per step: heartbeat, monitor poll,
    straggler watchdog; a detected host failure (stale heartbeats, or
    injected via ``chaos``) aborts the step and recovers — shrink to the
    survivors, ``build`` re-meshes + re-plans + restores the last
    committed checkpoint — with bounded retries/backoff.  A non-finite
    loss skips the update (the poisoned state is never committed) and
    after ``nonfinite_patience`` consecutive bad steps rolls back to the
    last good checkpoint.  Returns ``(final_state, history)`` where
    history is one record per *executed* step."""
    from repro.runtime.fault_tolerance import HostFailure

    policy = policy or RecoveryPolicy()
    run: ElasticRun = build(None)
    recoveries = 0
    bad = 0  # consecutive non-finite losses
    slow = 0  # consecutive straggler watchdog trips
    history: list[dict] = []
    step = run.start
    pending = None  # in-flight async checkpoint write (ElasticRun.save)

    def _join_pending() -> None:
        """Wait for the in-flight checkpoint write.  This is THE join
        point: a writer-thread failure surfaces here (before the next
        commit / before a restore reads the directory / at the end) —
        never silently."""
        nonlocal pending
        if pending is not None:
            handle, pending = pending, None
            handle.join()

    def _commit(at_step: int, state) -> None:
        nonlocal pending
        _join_pending()
        handle = run.save(at_step, state)
        if handle is not None and hasattr(handle, "join"):
            pending = handle

    def _recover(survivors: int, why: str) -> None:
        nonlocal run, recoveries, bad, slow, step
        # The last committed write must be on disk before build() restores
        # from it (and a broken writer must not be papered over by
        # restoring something older).
        _join_pending()
        recoveries += 1
        if recoveries > policy.max_recoveries:
            raise RuntimeError(
                f"giving up after {policy.max_recoveries} recoveries ({why})")
        if policy.backoff_seconds:
            time.sleep(policy.backoff_seconds * 2 ** (recoveries - 1))
        log(f"[recover #{recoveries}] {why} -> rebuilding on "
            f"{survivors} device(s)")
        run = build(survivors)
        bad = 0
        slow = 0
        step = run.start

    while step < steps:
        try:
            t0 = time.time()
            if chaos is not None:
                death = chaos.host_death(step, run.n_devices)
                if death is not None:
                    raise HostFailure(dead=death[0], survivors=death[1])
                chaos.on_step_start(step)  # straggle: counts into dt
            batch = {k: jnp.asarray(v) for k, v in source(step).items()}
            with (run.mesh if run.mesh is not None
                  else contextlib.nullcontext()):
                new_state, metrics = run.step_fn(run.state, batch)
            loss = float(jax.block_until_ready(metrics["loss"]))
            dt = time.time() - t0
            if chaos is not None:
                loss = chaos.poison_loss(step, loss)

            if run.heartbeat is not None:
                run.heartbeat.beat(step)
            if run.monitor is not None:
                stale = run.monitor.stale_hosts()
                if stale:
                    live = len(run.monitor.live_hosts())
                    raise HostFailure(dead=stale,
                                      survivors=live * run.devices_per_host)
            if run.watchdog is not None and run.watchdog.observe(dt):
                slow += 1
                log(f"  [watchdog] step {step} straggled ({dt:.2f}s; "
                    f"trip {slow})")
                # A log line nobody reads is not mitigation: after
                # straggler_patience consecutive trips the slow host is
                # treated as failed, so run_elastic actually evicts it
                # (shrink + re-plan + restore) instead of limping forever.
                if (policy.straggler_patience
                        and slow >= policy.straggler_patience):
                    host = (run.heartbeat.host if run.heartbeat is not None
                            else "straggler")
                    # Evicting the only host degenerates to a same-size
                    # rebuild (a restart is the sole mitigation left).
                    survivors = max(run.devices_per_host,
                                    run.n_devices - run.devices_per_host)
                    raise HostFailure(dead=[host], survivors=survivors)
            else:
                slow = 0

            if not math.isfinite(loss):
                bad += 1
                log(f"  [guard] step {step}: non-finite loss — update "
                    f"skipped ({bad}/{policy.nonfinite_patience})")
                history.append({"step": step, "loss": loss, "time": dt,
                                "skipped": True})
                if bad >= policy.nonfinite_patience:
                    _recover(run.n_devices,
                             f"{bad} consecutive non-finite losses; rolling "
                             "back to the last committed checkpoint")
                else:
                    step += 1
                continue

            bad = 0
            recoveries = 0  # the cap is on CONSECUTIVE recoveries:
            # a committed step in between proves real progress
            run.state = new_state  # committed only on a finite loss
            history.append({"step": step, "loss": loss, "time": dt,
                            "skipped": False})
            if step % run.log_every == 0 or step == steps - 1:
                extra = "".join(
                    f"  {k} {float(metrics[k]):.3g}"
                    for k in ("grad_norm", "lr") if k in metrics)
                log(f"step {step:5d}  loss {loss:.4f}{extra}  {dt:.2f}s")
            if (run.save is not None and run.ckpt_every
                    and step and step % run.ckpt_every == 0):
                _commit(step, run.state)
                if chaos is not None and run.ckpt_dir:
                    # Chaos corrupts the checkpoint just written — it must
                    # be on disk first (no overlap under chaos).
                    _join_pending()
                    torn = chaos.after_save(run.ckpt_dir, step)
                    if torn:
                        log(f"  [chaos] tore checkpoint chunk {torn}")
            step += 1
        except HostFailure as e:
            _recover(e.survivors, f"host failure: dead={e.dead}")

    if run.save is not None:
        _commit(steps - 1, run.state)
        _join_pending()
    return run.state, history
