"""Train-step builder: chunked cross-entropy, microbatch gradient
accumulation (lax.scan), AdamW, optional error-feedback int8 compression.

The FC-layer insight of the paper shows up twice here: the logits head is
a batched FC layer (vocab = D_O) computed in Delta_O-style *token chunks*
so the [tokens, vocab] logits volume is never resident at once; and the
gradient all-reduce over the data axes is Alg 4's private-output reduction
at datacenter scale.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import cnn
from repro.models.registry import get_family
from repro.optim import adamw
from repro.optim.compression import compress_tree, init_error_buffers


@dataclasses.dataclass(frozen=True)
class TrainState:
    params: Any
    opt: adamw.AdamWState
    err: Any = None  # error-feedback buffers (compression) or None


jax.tree_util.register_dataclass(
    TrainState, data_fields=["params", "opt", "err"], meta_fields=[]
)


def chunked_ce(cfg: ModelConfig, fam, params, hidden, labels, n_chunks: int,
               parallel=None):
    """Cross-entropy without materializing [B, S, vocab]: scan over token
    chunks; labels < 0 are masked."""
    from repro.runtime.parallel import constrain

    B, S, d = hidden.shape
    n = n_chunks
    while S % n:
        n -= 1
    hs = hidden.reshape(B, n, S // n, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n, S // n).transpose(1, 0, 2)
    hs = constrain(hs, parallel, (None, "dp", None, None))
    ls = constrain(ls, parallel, (None, "dp", None))

    def step(carry, xs):
        h, lab = xs
        logits = fam.logits(cfg, params, h).astype(jnp.float32)
        logits = constrain(logits, parallel, ("dp", None, "tp?"))
        lse = jax.nn.logsumexp(logits, -1)
        tgt = jnp.take_along_axis(logits, jnp.maximum(lab, 0)[..., None], -1)[..., 0]
        mask = (lab >= 0).astype(jnp.float32)
        nll = (lse - tgt) * mask
        return (carry[0] + nll.sum(), carry[1] + mask.sum()), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.zeros(()), jnp.zeros(())), (hs, ls))
    return tot / jnp.maximum(cnt, 1.0)


def make_loss_fn(cfg: ModelConfig, tcfg: TrainConfig, parallel=None):
    dt = jnp.dtype(tcfg.compute_dtype)

    if cfg.family == "cnn":

        def loss_fn(params, batch):
            imgs = batch["images"].astype(dt)
            if tcfg.planned_kernels:
                # The full planned training step: fused forward kernels plus
                # the planned dgrad/wgrad/dX/dW backward kernels, every
                # Schedule pinned by plan_training (cached per shape).
                logits = cnn.forward(
                    cfg, params, imgs, use_kernels=True,
                    schedules=cnn.plan_training(cfg, imgs.shape[0],
                                                in_bytes=imgs.dtype.itemsize))
            else:
                logits = cnn.forward(cfg, params, imgs, use_kernels=False)
            logits = logits.astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, -1)
            tgt = jnp.take_along_axis(logits, batch["labels"][:, None], -1)[:, 0]
            return (lse - tgt).mean()

        return loss_fn

    fam = get_family(cfg.family)

    def loss_fn(params, batch):
        extra = {"frames": batch["frames"].astype(dt)} if "frames" in batch else {}
        h, _ = fam.forward(
            cfg, params, batch["tokens"], remat=tcfg.remat, compute_dtype=dt,
            parallel=parallel, **extra,
        )
        return chunked_ce(cfg, fam, params, h, batch["labels"], tcfg.loss_chunks,
                          parallel)

    return loss_fn


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, parallel=None,
                    grad_specs=None):
    """Returns train_step(state, batch) -> (state, metrics).

    If the batch leaves have an extra leading accumulation dim
    ([n_accum, micro, ...]), gradients are accumulated over it with a scan.
    ``grad_specs`` (PartitionSpec pytree, usually the FSDP/ZeRO specs of
    the optimizer moments) pins the f32 accumulator's sharding: without it
    GSPMD replicates the accumulated gradient over the data axes (an extra
    full-param f32 buffer per device — 78 GiB on grok-1 — fed by an
    all-reduce per microbatch; pinned, the per-micro reduction becomes a
    reduce-scatter, ZeRO-2 style).
    """
    loss_fn = make_loss_fn(cfg, tcfg, parallel)

    def _pin(tree):
        if grad_specs is None or parallel is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree, grad_specs)

    def train_step(state: TrainState, batch: dict):
        params = state.params
        accum = "tokens" in batch and batch["tokens"].ndim == 3
        accum = accum or ("images" in batch and batch["images"].ndim == 5)

        if accum:
            n = jax.tree.leaves(batch)[0].shape[0]

            def acc(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (_pin(gsum), lsum + l), None

            g0 = _pin(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (grads, loss), _ = jax.lax.scan(acc, (g0, jnp.zeros(())), batch)
            grads = jax.tree.map(lambda g: g / n, grads)
            loss = loss / n
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = _pin(jax.tree.map(lambda g: g.astype(jnp.float32), grads))

        err = state.err
        if tcfg.grad_compression == "int8_ef" and err is not None:
            grads, err = compress_tree(
                jax.tree.map(lambda g: g.astype(jnp.float32), grads), err
            )

        params, opt, metrics = adamw.apply_updates(params, grads, state.opt, tcfg)
        metrics = dict(metrics, loss=loss)
        return TrainState(params, opt, err), metrics

    return train_step


def init_state(cfg: ModelConfig, tcfg: TrainConfig, params) -> TrainState:
    err = init_error_buffers(params) if tcfg.grad_compression == "int8_ef" else None
    return TrainState(params=params, opt=adamw.init(params), err=err)
