"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax
initialization, and smoke tests/benches must keep seeing 1 device.
"""

from __future__ import annotations

from repro.core.shard_compat import make_auto_mesh
from repro.runtime.parallel import ParallelCtx


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def make_ctx(mesh=None, *, multi_pod: bool = False) -> ParallelCtx:
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return ParallelCtx(mesh=mesh, dp_axes=dp, tp_axis="model")


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for 8-virtual-device tests."""
    return make_auto_mesh(shape, axes)


def production_mesh_spec(*, multi_pod: bool = False):
    """The production mesh as a ``repro.plan.MeshSpec`` — lets the
    mesh-aware planners model the 16x16 (or 2x16x16) partitioning without
    allocating a single jax device (same no-device-state discipline as the
    dry-run)."""
    from repro.plan import MeshSpec

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return MeshSpec(axes=tuple(zip(axes, shape)))
