import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory/cost/roofline terms.

MUST be run as its own process (the two lines above must execute before
any jax device initialization):

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun.json

Results are cached incrementally in a JSON file keyed by
(arch, shape, mesh); re-runs skip completed cells unless --force.
"""

import argparse
import json
import time
import traceback

import jax


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    from repro.analysis import roofline as rl
    from repro.launch.mesh import make_ctx
    from repro.launch.specs import build_cell

    ctx = make_ctx(multi_pod=multi_pod)
    chips = ctx.mesh.size
    cell = build_cell(arch, shape_name, ctx)

    t0 = time.time()
    with ctx.mesh:
        jitted = jax.jit(cell.step_fn, in_shardings=cell.in_shardings)
        lowered = jitted.lower(*cell.args)
        compiled = lowered.compile()
    t1 = time.time()

    mem = compiled.memory_analysis()
    mem_info = {}
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                mem_info[k] = int(v)

    counts = cell.meta["counts"]
    roof = rl.from_compiled(
        compiled, cell.meta["kind"], counts["active"], cell.meta["tokens"], chips
    )
    coll = rl.collective_bytes(compiled.as_text())

    return {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "compile_seconds": round(t1 - t0, 1),
        "params_total": counts["total"],
        "params_active_body": counts["active"],
        "memory": mem_info,
        "bytes_per_device": (mem_info.get("argument_size_in_bytes", 0)
                             + mem_info.get("temp_size_in_bytes", 0)),
        "collectives": coll,
        "roofline": roof.as_dict(),
        "ok": True,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun.json")
    args = ap.parse_args()

    from repro.configs.registry import ARCH_IDS, cells

    targets: list[tuple[str, str, bool]] = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in (cells(a) if not args.shape else [args.shape]):
            for mp in meshes:
                targets.append((a, s, mp))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            results = json.load(f)

    for arch, shape, mp in targets:
        key = f"{arch}|{shape}|{'2x16x16' if mp else '16x16'}"
        if key in results and results[key].get("ok") and not args.force:
            print(f"[skip] {key} (cached)", flush=True)
            continue
        print(f"[run ] {key} ...", flush=True)
        try:
            res = run_cell(arch, shape, mp)
            r = res["roofline"]
            print(
                f"[ ok ] {key}: compile={res['compile_seconds']}s "
                f"flops={r['flops']:.3e} hbmB={r['bytes_hbm']:.3e} "
                f"collB={r['bytes_coll']:.3e} bound={r['bottleneck']} "
                f"frac={r['roofline_fraction']:.3f}",
                flush=True,
            )
        except Exception as e:  # a failing cell is a bug; record it
            res = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16",
                   "ok": False, "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
            print(f"[FAIL] {key}: {res['error']}", flush=True)
        results[key] = res
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)

    n_ok = sum(1 for r in results.values() if r.get("ok"))
    print(f"done: {n_ok}/{len(results)} cells ok -> {args.out}", flush=True)


if __name__ == "__main__":
    main()
