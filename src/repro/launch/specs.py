"""Cell builder: (arch x shape x mesh) -> abstract inputs + shardings +
step function, for the dry-run and the roofline analysis.

Everything here is ShapeDtypeStruct-based: no weight, cache, or batch is
ever allocated (the assignment's "weak-type-correct, shardable, no device
allocation" pattern).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, TrainConfig
from repro.configs.registry import get_config, get_shape
from repro.models import cnn
from repro.models.module import abstract_params, count_params, flatten_defs, param_specs
from repro.models.registry import get_family
from repro.optim import adamw
from repro.runtime import serve as serve_rt
from repro.runtime import train as train_rt
from repro.runtime.parallel import ParallelCtx, batch_spec, cache_specs


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    cfg: ModelConfig
    step_fn: Callable
    args: tuple  # abstract ShapeDtypeStructs
    in_shardings: tuple
    meta: dict[str, Any]


def shard_extra_axis(spec: P, shape: tuple, axes: tuple, mesh_shape: dict) -> P:
    """FSDP/ZeRO: add the data axes to the first unsharded divisible dim."""
    n = 1
    for a in axes:
        n *= mesh_shape[a]
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i, (e, dim) in enumerate(zip(entries, shape)):
        if e is None and dim % n == 0 and dim >= n:
            entries[i] = axes if len(axes) > 1 else axes[0]
            return P(*entries)
    return P(*entries)


def fsdp_specs(specs, abstract, ctx: ParallelCtx):
    mesh_shape = dict(ctx.mesh.shape)
    return jax.tree.map(
        lambda s, a: shard_extra_axis(s, a.shape, ctx.dp_axes, mesh_shape),
        specs, abstract,
    )


def param_counts(cfg: ModelConfig, defs) -> dict:
    """Total, embedding, and active (MoE-scaled) parameter counts."""
    total = count_params(defs)
    embed = 0
    moe_ffn = 0
    for path, d in flatten_defs(defs):
        if path.split("/")[-1] in ("embed", "w_out"):
            embed += math.prod(d.shape)
        if "/moe/w_" in path:
            moe_ffn += math.prod(d.shape)
    n_body = total - embed
    active = n_body
    if cfg.n_experts:
        active = n_body - moe_ffn + moe_ffn * cfg.moe_top_k // cfg.n_experts
    return {"total": total, "embed": embed, "body": n_body, "active": active}


def default_train_config(cfg: ModelConfig, global_batch: int, ctx: ParallelCtx) -> TrainConfig:
    # Microbatch: ~8 accumulation steps, divisible by the dp extent.
    micro = max(ctx.dp_size, global_batch // 8)
    while global_batch % micro:
        micro -= 1
    big = cfg.n_layers * cfg.d_model >= 64 * 4096
    return TrainConfig(
        param_dtype="bfloat16" if big else "float32",
        microbatch=micro,
        remat="block",
        loss_chunks=16,
    )


def _batch_struct(cfg: ModelConfig, kind: str, seq: int, batch: int,
                  tcfg: TrainConfig, ctx: ParallelCtx):
    """(abstract batch pytree, matching sharding-spec pytree)."""
    i32 = jnp.int32
    if cfg.family == "cnn":
        n = batch // tcfg.microbatch if tcfg.microbatch else 1
        m = tcfg.microbatch or batch
        bs = batch_spec(ctx, m, 2)
        return (
            {"images": jax.ShapeDtypeStruct((n, m, cnn.IMG, cnn.IMG, cnn.IN_CH), jnp.float32),
             "labels": jax.ShapeDtypeStruct((n, m), i32)},
            {"images": P(None, bs[0], None, None, None), "labels": P(None, bs[0])},
        )
    if kind == "train":
        n = batch // tcfg.microbatch if tcfg.microbatch else 1
        m = tcfg.microbatch or batch
        if n > 1:
            shp, lead = (n, m, seq), (None,) + tuple(batch_spec(ctx, m, 1))
        else:
            shp, lead = (m, seq), tuple(batch_spec(ctx, m, 1))
        b = {"tokens": jax.ShapeDtypeStruct(shp, i32),
             "labels": jax.ShapeDtypeStruct(shp, i32)}
        s = {"tokens": P(*lead, None), "labels": P(*lead, None)}
        if cfg.family == "encdec":
            fs = (n, m, cfg.enc_seq, cfg.d_model) if n > 1 else (m, cfg.enc_seq, cfg.d_model)
            b["frames"] = jax.ShapeDtypeStruct(fs, jnp.bfloat16)
            s["frames"] = P(*lead, None, None)
        return b, s
    # prefill
    b = {"tokens": jax.ShapeDtypeStruct((batch, seq), i32)}
    s = {"tokens": batch_spec(ctx, batch, 2)}
    if cfg.family == "encdec":
        b["frames"] = jax.ShapeDtypeStruct((batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        s["frames"] = P(*tuple(batch_spec(ctx, batch, 1)), None, None)
    return b, s


def build_cell(arch: str, shape_name: str, ctx: ParallelCtx) -> Cell:
    cfg = get_config(arch)
    shp = get_shape(shape_name)
    # Every family (cnn included) is registered, so params come through
    # the registry uniformly — no family branching here.
    fam = get_family(cfg.family)
    mesh = ctx.mesh

    defs = fam.param_defs(cfg)
    specs = param_specs(defs)
    counts = param_counts(cfg, defs)

    ns = lambda tree: jax.tree.map(lambda sp: NamedSharding(mesh, sp), tree)

    if shp.kind == "train":
        tcfg = default_train_config(cfg, shp.global_batch, ctx)
        pdt = jnp.dtype(tcfg.param_dtype)
        aparams = abstract_params(defs, pdt)
        pspecs = fsdp_specs(specs, aparams, ctx)
        aopt = adamw.abstract_state(aparams)
        ospecs = adamw.AdamWState(
            step=P(),
            m=fsdp_specs(specs, aparams, ctx),
            v=fsdp_specs(specs, aparams, ctx),
        )
        astate = train_rt.TrainState(params=aparams, opt=aopt, err=None)
        sstate = train_rt.TrainState(params=pspecs, opt=ospecs, err=None)
        batch, bspecs = _batch_struct(cfg, "train", shp.seq_len, shp.global_batch, tcfg, ctx)
        step = train_rt.make_train_step(cfg, tcfg, parallel=_moe_ctx(cfg, ctx),
                                        grad_specs=fsdp_specs(specs, aparams, ctx))
        return Cell(arch, shape_name, cfg, step, (astate, batch),
                    (ns(sstate), ns(bspecs)),
                    {"counts": counts, "tcfg": tcfg, "kind": "train",
                     "tokens": shp.global_batch * shp.seq_len})

    pdt = jnp.bfloat16
    aparams = abstract_params(defs, pdt)
    pspecs = fsdp_specs(specs, aparams, ctx)

    if shp.kind == "prefill":
        batch, bspecs = _batch_struct(cfg, "prefill", shp.seq_len, shp.global_batch, None, ctx)
        step = serve_rt.make_prefill_step(cfg, shp.seq_len, parallel=_moe_ctx(cfg, ctx))
        return Cell(arch, shape_name, cfg, step, (aparams, batch),
                    (ns(pspecs), ns(bspecs)),
                    {"counts": counts, "kind": "prefill",
                     "tokens": shp.global_batch * shp.seq_len})

    # decode: one new token against a seq_len cache
    acache = jax.eval_shape(
        lambda: fam.init_cache(cfg, shp.global_batch, shp.seq_len, jnp.bfloat16)
    )
    cspecs = cache_specs(ctx, acache)
    tokens = jax.ShapeDtypeStruct((shp.global_batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    step = serve_rt.make_decode_step(cfg, parallel=_moe_ctx(cfg, ctx))
    return Cell(arch, shape_name, cfg, step,
                (aparams, acache, tokens, pos),
                (ns(pspecs), ns(cspecs), ns(batch_spec(ctx, shp.global_batch, 2)), ns(P())),
                {"counts": counts, "kind": "decode", "tokens": shp.global_batch})


def _moe_ctx(cfg: ModelConfig, ctx: ParallelCtx):
    # All families receive the ctx (sharding-constraint anchors + the MoE
    # shard_map dispatch); name kept for history.
    return ctx
