import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Per-cell diagnosis for the perf loop: per-op FLOPs/bytes attribution and
the largest collective ops with shapes.

    PYTHONPATH=src python -m repro.launch.diagnose gemma3-4b prefill_32k
"""

import re
import sys

import jax


def main() -> None:
    arch, shape = sys.argv[1], sys.argv[2]
    multi = "--multi-pod" in sys.argv
    from repro.analysis import hlo_cost
    from repro.launch.mesh import make_ctx
    from repro.launch.specs import build_cell

    ctx = make_ctx(multi_pod=multi)
    cell = build_cell(arch, shape, ctx)
    with ctx.mesh:
        compiled = jax.jit(cell.step_fn, in_shardings=cell.in_shardings).lower(
            *cell.args).compile()
    txt = compiled.as_text()
    c = hlo_cost.analyze(txt)

    print(f"== {arch} {shape} ({'2x16x16' if multi else '16x16'}) per-device ==")
    print(f"flops {c.flops:.3e}  bytes {c.bytes:.3e}  coll {sum(c.coll.values()):.3e}")
    print(f"t_compute {c.flops/197e12:.2f}s  t_memory {c.bytes/819e9:.2f}s  "
          f"t_coll {sum(c.coll.values())/50e9:.2f}s")
    print("-- by op (top bytes) --")
    for op, (f, b) in sorted(c.by_op.items(), key=lambda kv: -kv[1][1])[:10]:
        print(f"  {op:22s} flops={f:.3e} bytes={b:.3e}")
    print("-- by collective --")
    for k, v in sorted(c.coll.items(), key=lambda kv: -kv[1]):
        if v:
            print(f"  {k:22s} {v:.3e}")
    print("-- largest collective instructions (static shapes) --")
    seen = {}
    for m in re.finditer(
        r"= ((?:\([^=)]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*)) (all-reduce|all-gather|"
        r"reduce-scatter|all-to-all|collective-permute)", txt):
        b = hlo_cost.shape_bytes(m.group(1))
        key = (m.group(2), m.group(1)[:60])
        seen[key] = (seen.get(key, (0, 0))[0] + 1, b)
    for (op, shp), (n, b) in sorted(seen.items(), key=lambda kv: -kv[1][1])[:12]:
        print(f"  {op:20s} x{n:3d}  {b/1e6:9.1f}MB  {shp}")
    mem = compiled.memory_analysis()
    if mem:
        print(f"-- memory: args {mem.argument_size_in_bytes/2**30:.2f}GiB "
              f"out {mem.output_size_in_bytes/2**30:.2f}GiB "
              f"temp {mem.temp_size_in_bytes/2**30:.2f}GiB")


if __name__ == "__main__":
    main()
