"""Production training launcher: config -> mesh -> sharded state -> data ->
elastic train loop with checkpoints, heartbeats, straggler watchdog, resume.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 50 --mesh 1x1 --ckpt /tmp/run1
    # re-run the same command after killing it: resumes from the last
    # committed checkpoint (possibly onto a different mesh - resharding
    # restore).

On a real multi-host TPU slice the same entrypoint runs under
``jax.distributed.initialize()`` with ``--mesh 16x16`` / ``--mesh 2x16x16``;
on this CPU container use ``--mesh 1x1`` (or 2x4 under forced host
devices).  Elastic restart (DESIGN.md Sec. 7): on a detected host failure
the loop aborts the step, shrinks the mesh to the survivors
(fault_tolerance.shrink_mesh_shape — the model/TP extent is preserved),
re-plans every ShardedSchedule against the new MeshSpec (autotune
cache-only on the degraded cell, modeled argmin on miss), restores the
last *intact* committed checkpoint with the new shardings, and resumes —
bounded by --max-recoveries.  ``--chaos "kill@5,corrupt@4,nan@7"``
injects deterministic seeded faults to exercise exactly that path
(runtime/chaos.py; scripts/tier1.sh --fault-smoke).
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import TrainConfig
from repro.configs.registry import FAMILY_DEFAULT_ARCH, get_config, smoke_config
from repro.data.pipeline import ShardInfo
from repro.models.module import abstract_params, init_params, param_specs
from repro.models.registry import (
    FAMILIES, batch_shard_specs, get_family, make_data_source,
)
from repro.optim import adamw
from repro.runtime import train as tr
from repro.runtime.chaos import ChaosConfig, ChaosMonkey
from repro.runtime.fault_tolerance import (
    Heartbeat, Monitor, StragglerWatchdog, shrink_mesh_shape,
)
from repro.runtime.parallel import ParallelCtx
from repro.launch.specs import fsdp_specs


def parse_mesh(spec: str):
    dims = tuple(int(x) for x in spec.split("x"))
    if len(dims) == 3:
        return dims, ("pod", "data", "model")
    if len(dims) == 2:
        return dims, ("data", "model")
    raise ValueError(f"--mesh must be DxM or PxDxM, got {spec!r}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--family", default=None, choices=sorted(FAMILIES),
                    help="train a model family's reference arch (reduced "
                         "smoke config) instead of naming an --arch; the "
                         "family-registry hooks provide params, data and "
                         "loss, so e.g. '--family transformer "
                         "--planned-kernels' trains the planned "
                         "transformer wing exactly like '--family cnn'")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--remat", default="none", choices=["none", "dots", "block"])
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--grad-compression", default="none", choices=["none", "int8_ef"])
    ap.add_argument("--planned-kernels", action="store_true",
                    help="run the family's planned Pallas forward AND "
                         "backward kernels in the train step instead of "
                         "the XLA reference path (cnn: fused conv + "
                         "dgrad/wgrad + dX/dW matmul; transformer: every "
                         "block GEMM + flash attention + dX/dW)")
    ap.add_argument("--autotune", default="off",
                    choices=["off", "cache-only", "tune"],
                    help="schedule resolution policy: cached measured-time "
                         "winners override the planners' modeled argmin "
                         "('tune' additionally measures top-k candidates on "
                         "a cache miss; see repro.plan.autotune)")
    ap.add_argument("--autotune-cache", default=None,
                    help="autotune winner-cache file (default: "
                         "$REPRO_AUTOTUNE_CACHE or ~/.cache/repro/"
                         "autotune.json)")
    ap.add_argument("--chaos", default=None,
                    help="seeded fault injection, e.g. "
                         "'kill@5,straggle@3x0.2,corrupt@4,nan@7x3' "
                         "(runtime/chaos.py)")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--max-recoveries", type=int, default=3,
                    help="consecutive elastic recoveries before giving up")
    ap.add_argument("--recovery-backoff", type=float, default=0.0,
                    help="base seconds between recoveries (doubles each)")
    ap.add_argument("--nonfinite-patience", type=int, default=3,
                    help="consecutive non-finite losses skipped before "
                         "rolling back to the last good checkpoint")
    args = ap.parse_args()

    if args.autotune != "off" or args.autotune_cache:
        from repro.plan import autotune as at

        at.set_policy(args.autotune, args.autotune_cache)
        print(f"autotune: policy={args.autotune} "
              f"cache={at.get_cache().path} ({len(at.get_cache())} cells)")

    if args.arch is None:
        if args.family is None:
            ap.error("one of --arch or --family is required")
        args.arch = FAMILY_DEFAULT_ARCH[args.family]
        args.smoke = True  # family mode trains the reduced reference config

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.family is not None:
        if FAMILIES[args.family] is not FAMILIES[cfg.family]:
            ap.error(f"--family {args.family} does not match arch "
                     f"{args.arch} (family {cfg.family!r})")
        # Address the family under the requested registry name (e.g.
        # "transformer" aliases "dense") so every hook dispatch uses it.
        import dataclasses

        cfg = dataclasses.replace(cfg, family=args.family)
    tcfg = TrainConfig(
        param_dtype="float32", compute_dtype="float32" if args.smoke else "bfloat16",
        learning_rate=args.lr, warmup_steps=min(100, args.steps // 10 + 1),
        total_steps=args.steps, remat=args.remat, microbatch=args.microbatch,
        loss_chunks=4, seed=args.seed, grad_compression=args.grad_compression,
        planned_kernels=args.planned_kernels,
    )

    shape0, axes = parse_mesh(args.mesh)
    n_dev_full = int(np.prod(shape0))
    if n_dev_full > len(jax.devices()):
        raise SystemExit(
            f"mesh {args.mesh} needs {n_dev_full} devices, have {len(jax.devices())} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=N)"
        )

    # Everything family-specific comes through the registry hooks
    # (models/registry.py): params, data source, loss, batch sharding,
    # planned schedules — the launcher never branches on the family name.
    fam = get_family(cfg.family)
    defs = fam.param_defs(cfg)
    aparams = abstract_params(defs, jnp.dtype(tcfg.param_dtype))
    n_params = sum(int(np.prod(a.shape)) for a in jax.tree.leaves(aparams))
    print(f"params: {n_params/1e6:.1f}M | arch {cfg.name} "
          f"| {tcfg.compute_dtype} compute")

    # Data: one shard per data-parallel host group (single process here).
    source = make_data_source(cfg, args.batch, args.seq, ShardInfo(0, 1),
                              seed=tcfg.seed)

    def build(n_devices: int | None) -> tr.ElasticRun:
        """One incarnation of the run for a device count: mesh, sharded
        step_fn, re-planned ShardedSchedules, state restored from the last
        intact committed checkpoint.  ``None`` = initial full mesh; an
        explicit count = elastic recovery onto the survivors."""
        degraded = n_devices is not None
        n_dev = n_dev_full if n_devices is None else n_devices
        if n_dev == n_dev_full:
            shape = shape0
        else:
            shape = shrink_mesh_shape(
                n_dev, model=shape0[-1],
                pod=shape0[0] if len(shape0) == 3 else None)
        from repro.core.shard_compat import make_auto_mesh

        mesh = make_auto_mesh(shape, axes)
        dp_axes = tuple(a for a in axes if a != "model")
        ctx = ParallelCtx(mesh=mesh, dp_axes=dp_axes, tp_axis="model")
        print(f"mesh {dict(mesh.shape)} ({n_dev} devices"
              f"{', degraded' if degraded else ''})")

        use_sharding = n_dev > 1
        specs = param_specs(defs)
        pspecs = fsdp_specs(specs, aparams, ctx) if use_sharding else None

        params = init_params(defs, jax.random.PRNGKey(tcfg.seed),
                             jnp.dtype(tcfg.param_dtype))
        state = tr.init_state(cfg, tcfg, params)

        # Resume from the newest *intact* committed step (corrupt steps
        # fall back with a logged warning — reshard-on-restore works even
        # if the mesh changed).
        start = 0
        shardings = None
        if use_sharding:
            sstate = tr.TrainState(
                params=pspecs,
                opt=adamw.AdamWState(step=P(), m=pspecs, v=pspecs),
                err=None if state.err is None else pspecs)
            shardings = jax.tree.map(lambda sp: NamedSharding(mesh, sp), sstate)
        if args.ckpt:
            astate = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
            restored, last = ckpt.restore_latest(args.ckpt, astate, shardings)
            if restored is not None:
                state, start = restored, last + 1
                print(f"resumed from step {last} ({args.ckpt})")

        if use_sharding and hasattr(fam, "plan_training"):
            # Re-plan the full schedule set against THIS mesh: the
            # mesh-aware planners' model of the run (the ring/psum argmin
            # can flip at the new device count).  A degraded (recovery)
            # build resolves autotune cache-only — never measure while
            # recovering; a cache miss falls back to the modeled argmin.
            # The hook signature is uniform across families (cnn ignores
            # the token axes; the transformer sizes its logits cell off
            # them) — docs/plan-layer.md.
            from repro.plan import validate_sharded_plan
            from repro.plan.autotune import recovery_policy

            tune = recovery_policy(args.autotune) if degraded else args.autotune
            splan = fam.plan_training(cfg, args.batch, seq=args.seq,
                                      loss_chunks=tcfg.loss_chunks,
                                      mesh=ctx.plan_mesh(),
                                      shard_axis=dp_axes[-1], autotune=tune)
            validate_sharded_plan(splan, ctx.plan_mesh())
            hbm = sum(s.hbm_words for s in splan.values())
            ici = sum(s.ici_words for s in splan.values())
            print(f"sharded plan: {len(splan)} kernels | modeled step words "
                  f"hbm={hbm} ici={ici}")

        step_fn = tr.make_train_step(
            cfg, tcfg, parallel=ctx if use_sharding else None,
            grad_specs=pspecs)
        if use_sharding:
            dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
            # The family registry owns the batch sharding spec (cnn shards
            # its image batch, token families their token batch) — no
            # family branching in the launcher.
            bspec = {k: NamedSharding(mesh, s)
                     for k, s in batch_shard_specs(cfg, dp).items()}
            step_fn = jax.jit(step_fn, in_shardings=(shardings, bspec))
        else:
            step_fn = jax.jit(step_fn)

        hb = mon = save = None
        if args.ckpt:
            os.makedirs(os.path.join(args.ckpt, "hb"), exist_ok=True)
            hb = Heartbeat(f"host{jax.process_index()}",
                           os.path.join(args.ckpt, "hb"))
            mon = Monitor(os.path.join(args.ckpt, "hb"), timeout=600)

            def save(step, st):
                # Async commit: run_elastic joins this handle before the
                # next save / a restore / the end, so writer failures
                # surface there instead of stalling the step here.  retain
                # only touches *committed* step dirs (the in-flight write
                # lives under a .tmp name), so pruning now is safe.
                handle = ckpt.save_async(args.ckpt, step, st,
                                         n_chunks=max(1, min(8, n_dev)))
                ckpt.retain(args.ckpt, keep=3)
                return handle

        return tr.ElasticRun(
            step_fn=step_fn, state=state, start=start, n_devices=n_dev,
            mesh=mesh, save=save, ckpt_dir=args.ckpt,
            ckpt_every=args.ckpt_every if args.ckpt else 0,
            devices_per_host=shape0[-1], heartbeat=hb, monitor=mon,
            watchdog=StragglerWatchdog(factor=3.0),
            log_every=args.log_every)

    chaos = None
    if args.chaos:
        ccfg = ChaosConfig.parse(args.chaos, seed=args.chaos_seed)
        chaos = ChaosMonkey(ccfg, devices_per_host=shape0[-1])
        print(f"chaos: {ccfg} (seed {ccfg.seed})")

    policy = tr.RecoveryPolicy(max_recoveries=args.max_recoveries,
                               backoff_seconds=args.recovery_backoff,
                               nonfinite_patience=args.nonfinite_patience)
    state, history = tr.run_elastic(build, source, args.steps,
                                    policy=policy, chaos=chaos)
    if args.ckpt:
        print(f"final checkpoint: step {args.steps - 1}")
    print(f"done: {len(history)} steps executed, "
          f"final loss {history[-1]['loss']:.4f}" if history else "done")


if __name__ == "__main__":
    main()
