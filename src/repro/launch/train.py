"""Production training launcher: config -> mesh -> sharded state -> data ->
train loop with checkpoints, heartbeats, straggler watchdog, resume.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 50 --mesh 1x1 --ckpt /tmp/run1
    # re-run the same command after killing it: resumes from the last
    # committed checkpoint (possibly onto a different mesh - resharding
    # restore).

On a real multi-host TPU slice the same entrypoint runs under
``jax.distributed.initialize()`` with ``--mesh 16x16`` / ``--mesh 2x16x16``;
on this CPU container use ``--mesh 1x1`` (or 2x4 under forced host
devices).  Elastic restart: if the monitor finds stale hosts, the launcher
recomputes the mesh from survivors (fault_tolerance.shrink_mesh_shape) and
restores the checkpoint with the new shardings.
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import TrainConfig
from repro.configs.registry import get_config, smoke_config
from repro.data.pipeline import ShardInfo, SyntheticImageSource, SyntheticSource
from repro.models import cnn
from repro.models.module import abstract_params, init_params, param_specs
from repro.models.registry import batch_shard_specs, get_family
from repro.optim import adamw
from repro.runtime import train as tr
from repro.runtime.fault_tolerance import Heartbeat, Monitor, StragglerWatchdog
from repro.runtime.parallel import ParallelCtx
from repro.launch.specs import fsdp_specs


def parse_mesh(spec: str):
    dims = tuple(int(x) for x in spec.split("x"))
    if len(dims) == 3:
        return dims, ("pod", "data", "model")
    if len(dims) == 2:
        return dims, ("data", "model")
    raise ValueError(f"--mesh must be DxM or PxDxM, got {spec!r}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--remat", default="none", choices=["none", "dots", "block"])
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--grad-compression", default="none", choices=["none", "int8_ef"])
    ap.add_argument("--planned-kernels", action="store_true",
                    help="cnn: run the planned Pallas forward AND backward "
                         "kernels (dgrad/wgrad conv, dX/dW matmul) in the "
                         "train step instead of the XLA reference path")
    ap.add_argument("--autotune", default="off",
                    choices=["off", "cache-only", "tune"],
                    help="schedule resolution policy: cached measured-time "
                         "winners override the planners' modeled argmin "
                         "('tune' additionally measures top-k candidates on "
                         "a cache miss; see repro.plan.autotune)")
    ap.add_argument("--autotune-cache", default=None,
                    help="autotune winner-cache file (default: "
                         "$REPRO_AUTOTUNE_CACHE or ~/.cache/repro/"
                         "autotune.json)")
    args = ap.parse_args()

    if args.autotune != "off" or args.autotune_cache:
        from repro.plan import autotune as at

        at.set_policy(args.autotune, args.autotune_cache)
        print(f"autotune: policy={args.autotune} "
              f"cache={at.get_cache().path} ({len(at.get_cache())} cells)")

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tcfg = TrainConfig(
        param_dtype="float32", compute_dtype="float32" if args.smoke else "bfloat16",
        learning_rate=args.lr, warmup_steps=min(100, args.steps // 10 + 1),
        total_steps=args.steps, remat=args.remat, microbatch=args.microbatch,
        loss_chunks=4, seed=args.seed, grad_compression=args.grad_compression,
        planned_kernels=args.planned_kernels,
    )

    shape, axes = parse_mesh(args.mesh)
    n_dev = int(np.prod(shape))
    if n_dev > len(jax.devices()):
        raise SystemExit(
            f"mesh {args.mesh} needs {n_dev} devices, have {len(jax.devices())} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=N)"
        )
    from repro.core.shard_compat import make_auto_mesh

    mesh = make_auto_mesh(shape, axes)
    dp_axes = tuple(a for a in axes if a != "model")
    ctx = ParallelCtx(mesh=mesh, dp_axes=dp_axes, tp_axis="model")
    print(f"mesh {dict(mesh.shape)} | arch {cfg.name} | {tcfg.compute_dtype} compute")

    # The cnn family (the paper's own domain) has no LM-style family
    # module; its param_defs / forward live in models/cnn.py and the loss
    # comes from runtime.train.make_loss_fn (planned Pallas fwd+bwd
    # kernels under --planned-kernels).
    defs = (cnn.param_defs(cfg) if cfg.family == "cnn"
            else get_family(cfg.family).param_defs(cfg))
    aparams = abstract_params(defs, jnp.dtype(tcfg.param_dtype))
    n_params = sum(int(np.prod(a.shape)) for a in jax.tree.leaves(aparams))
    print(f"params: {n_params/1e6:.1f}M")

    use_sharding = n_dev > 1
    specs = param_specs(defs)
    pspecs = fsdp_specs(specs, aparams, ctx) if use_sharding else None

    params = init_params(defs, jax.random.PRNGKey(tcfg.seed),
                         jnp.dtype(tcfg.param_dtype))
    state = tr.init_state(cfg, tcfg, params)

    # Resume (reshard-on-restore: works even if the mesh changed).
    start = 0
    if args.ckpt:
        last = ckpt.latest_step(args.ckpt)
        if last is not None:
            astate = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
            shardings = None
            if use_sharding:
                sstate = tr.TrainState(
                    params=pspecs,
                    opt=adamw.AdamWState(step=P(), m=pspecs, v=pspecs),
                    err=None if state.err is None else pspecs)
                shardings = jax.tree.map(lambda sp: NamedSharding(mesh, sp), sstate)
            state = ckpt.restore(args.ckpt, last, astate, shardings)
            start = last + 1
            print(f"resumed from step {last} ({args.ckpt})")

    # Data: one shard per data-parallel host group (single process here).
    if cfg.family == "cnn":
        source = SyntheticImageSource(cnn.IMG, cnn.IN_CH, cfg.vocab,
                                      args.batch, ShardInfo(0, 1),
                                      seed=tcfg.seed)
    else:
        source = SyntheticSource(cfg.vocab, args.seq, args.batch,
                                 ShardInfo(0, 1), seed=tcfg.seed)

    step_fn = tr.make_train_step(cfg, tcfg, parallel=ctx if use_sharding else None,
                                 grad_specs=pspecs)
    if use_sharding:
        sstate = tr.TrainState(
            params=jax.tree.map(lambda sp: NamedSharding(mesh, sp), pspecs),
            opt=adamw.AdamWState(
                step=NamedSharding(mesh, P()),
                m=jax.tree.map(lambda sp: NamedSharding(mesh, sp), pspecs),
                v=jax.tree.map(lambda sp: NamedSharding(mesh, sp), pspecs)),
            err=None)
        dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
        # The family registry owns the batch sharding spec (cnn shards its
        # image batch, token families their token batch) — no family
        # branching in the launcher.
        bspec = {k: NamedSharding(mesh, s)
                 for k, s in batch_shard_specs(cfg, dp).items()}
        step_fn = jax.jit(step_fn, in_shardings=(sstate, bspec))
    else:
        step_fn = jax.jit(step_fn)

    if cfg.family == "cnn" and use_sharding:
        # The mesh-aware planners' model of this run: every stage's device
        # partitioning plus the step's words split HBM vs interconnect
        # (the sharded wgrad/dw entries carry the gradient all-reduce).
        splan = cnn.plan_training(cfg, args.batch, mesh=ctx.plan_mesh(),
                                  shard_axis=dp_axes[-1],
                                  autotune=args.autotune)
        hbm = sum(s.hbm_words for s in splan.values())
        ici = sum(s.ici_words for s in splan.values())
        print(f"sharded plan: {len(splan)} kernels | modeled step words "
              f"hbm={hbm} ici={ici}")

    hb = wd = mon = None
    if args.ckpt:
        os.makedirs(os.path.join(args.ckpt, "hb"), exist_ok=True)
        hb = Heartbeat(f"host{jax.process_index()}", os.path.join(args.ckpt, "hb"))
        mon = Monitor(os.path.join(args.ckpt, "hb"), timeout=600)
    wd = StragglerWatchdog(factor=3.0)

    with mesh:
        for i in range(start, args.steps):
            t0 = time.time()
            batch = {k: jnp.asarray(v) for k, v in source(i).items()}
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            if hb:
                hb.beat(i)
            if wd.observe(dt):
                print(f"  [watchdog] step {i} straggled ({dt:.2f}s)")
            if mon and i % 50 == 0 and mon.stale_hosts():
                print(f"  [monitor] stale hosts: {mon.stale_hosts()} — "
                      "on a real slice the launcher would re-mesh + restore here")
            if i % args.log_every == 0 or i == args.steps - 1:
                print(f"step {i:5d}  loss {float(metrics['loss']):.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}  "
                      f"lr {float(metrics['lr']):.2e}  {dt:.2f}s")
            if args.ckpt and i and i % args.ckpt_every == 0:
                ckpt.save(args.ckpt, i, state, n_chunks=max(1, min(8, n_dev)))
                ckpt.retain(args.ckpt, keep=3)

    if args.ckpt:
        ckpt.save(args.ckpt, args.steps - 1, state,
                  n_chunks=max(1, min(8, n_dev)))
        print(f"final checkpoint: step {args.steps - 1}")


if __name__ == "__main__":
    main()
