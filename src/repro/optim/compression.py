"""Error-feedback int8 gradient compression (cross-pod DCN hop).

``compress_decompress`` quantizes a gradient tensor to int8 with a
per-tensor scale, carrying the quantization error into the next step
(error feedback keeps the compressed SGD/Adam iterates convergent).  The
wire format is demonstrated by ``int8_psum`` — a shard_map all-reduce that
actually sums int8 payloads over an axis (values are summed in int32 and
rescaled), which is what the cross-pod hop would ship: 4x fewer bytes than
f32 gradients.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.shard_compat import shard_map


def compress_decompress(g: jax.Array, err: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Returns (decompressed gradient, new error buffer)."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, gf - deq


def compress_tree(grads, errs):
    flat = jax.tree.map(compress_decompress, grads, errs)
    is_pair = lambda x: isinstance(x, tuple)
    return (
        jax.tree.map(lambda t: t[0], flat, is_leaf=is_pair),
        jax.tree.map(lambda t: t[1], flat, is_leaf=is_pair),
    )


def init_error_buffers(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def int8_psum(x: jax.Array, mesh, axis: str):
    """All-reduce whose wire payload is int8 (sum in int32, rescale)."""

    def fn(xl):
        scale = jnp.maximum(jnp.max(jnp.abs(xl)), 1e-12) / 127.0
        scale = jax.lax.pmax(scale, axis)  # shared scale across the axis
        q = jnp.clip(jnp.round(xl / scale), -127, 127).astype(jnp.int8)
        s = jax.lax.psum(q.astype(jnp.int32), axis)  # int payload on the wire
        return s.astype(jnp.float32) * scale

    return shard_map(
        fn, mesh=mesh, in_specs=P(*([None] * x.ndim)), out_specs=P(*([None] * x.ndim)),
        check_vma=False,
    )(x)
