"""AdamW in pure JAX, with ZeRO-1 optimizer-state sharding.

Optimizer moments are f32 regardless of param dtype (bf16-param training
keeps full-precision statistics).  ``zero1_specs`` extends each param's
PartitionSpec by sharding the first *unsharded, divisible* dimension over
the data axes — the moments (2 x f32 per param) dominate optimizer memory,
so this is where ZeRO-1 pays.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import TrainConfig


@dataclasses.dataclass(frozen=True)
class AdamWState:
    step: Any
    m: Any
    v: Any


jax.tree_util.register_dataclass(
    AdamWState, data_fields=["step", "m", "v"], meta_fields=[]
)


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def abstract_state(params) -> AdamWState:
    z = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        m=jax.tree.map(z, params),
        v=jax.tree.map(z, params),
    )


def lr_schedule(cfg: TrainConfig, step):
    """Linear warmup then cosine decay to 10%."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1
    )
    cos = 0.1 + 0.45 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.learning_rate * warm * cos


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def apply_updates(params, grads, state: AdamWState, cfg: TrainConfig):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip else 1.0
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    flat = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step, new_m, new_v), {"lr": lr, "grad_norm": gnorm}


def zero1_specs(param_specs, params_abstract, dp_axes: tuple, mesh_shape: dict):
    """ZeRO-1: shard each moment over the data axes on the first dimension
    that is unsharded and divisible by the data-parallel extent."""
    dp = 1
    for a in dp_axes:
        dp *= mesh_shape[a]

    def one(spec: P, aval):
        entries = list(spec) + [None] * (len(aval.shape) - len(spec))
        for i, (e, n) in enumerate(zip(entries, aval.shape)):
            if e is None and n % dp == 0 and n > 0:
                entries[i] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
                return P(*entries)
        return P(*entries)

    moments = jax.tree.map(one, param_specs, params_abstract)
    return AdamWState(step=P(), m=moments, v=moments)
