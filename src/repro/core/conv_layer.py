"""The paper's convolutional layer as a composable, differentiable module.

``strategy`` selects the paper algorithm:
  * "alg1"  - one output depth slice at a time (block_do = 1);
  * "alg2"  - Delta_O output stacking at the full-plane strip, Delta_O from
              the capacity planner;
  * "strip" - Alg 2 + spatial strip tiling: the planner trades strip height
              against Delta_O (the schedule the Pallas kernel actually runs);
  * "alg3"  - Alg 2 blocking within each device + ring input-slice reuse
              across devices (core/ring.py) when input channels are sharded.

Blocking flows through the ``repro.plan`` layer: each strategy is a
different constraint handed to :class:`repro.plan.ConvPlanner`, and an
explicit :class:`repro.plan.Schedule` (``schedule=``) overrides the
planner entirely.  Forward runs the batched strip-tiled Pallas kernel
(interpret mode off-TPU); :func:`conv_block` additionally fuses the layer
epilogue (bias + ReLU + optional 2x2 max-pool) into the kernel's flush
step.

Backward is *also* planned (DESIGN.md Sec. 4): ``jax.grad`` runs the
``conv2d_dgrad`` strip kernel (flipped-filter transposed conv) for dX and
the ``conv2d_wgrad`` accumulation kernel for dF, each scheduled by its own
planner — override with ``bwd_schedules={"dgrad": ..., "wgrad": ...,
"recompute": ...}`` (see :func:`plan_bwd`).  When a backward schedule does
not fit the machine the layer falls back to the XLA reference VJP, which
also remains the parity oracle (tests/test_backward_plan.py).  Traffic
accounting for any strategy comes from core/ccr.py.
"""

from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

from repro.core import ccr
from repro.core.machine import MANTICORE, TPU_V5E, machine_named
from repro.kernels.conv2d.bwd import conv2d_dgrad, conv2d_wgrad, epilogue_scatter
from repro.kernels.conv2d.ops import conv2d, conv2d_with_mask
from repro.kernels.conv2d.ref import conv2d_fused_ref, conv2d_ref, maxpool_ref
from repro.plan import (
    Schedule, ShardedSchedule, freeze_schedules, get_op, local_schedule,
    with_reference_vjp,
)

# The machine backward schedules are planned (and fit-checked) against.
_BWD_MACHINE = TPU_V5E

_WARNED_SCHEDULES: set = set()  # (role, schedule) pairs already reported


def warn_unfit_schedule(role: str, sched: Schedule, machine) -> None:
    """Warn exactly once per (role, schedule) when a fit gate silently
    drops a pinned backward schedule to the XLA/recompute fallback —
    the autotune cache's loud-first-fallback discipline (see
    ``repro.plan.autotune._warn_once``) applied to the layers' gates.
    Steady-state replays of the same unfit cell stay quiet."""
    key = (role, sched)
    if key in _WARNED_SCHEDULES:
        return
    _WARNED_SCHEDULES.add(key)
    warnings.warn(
        f"backward schedule {role!r} (op={sched.op!r}, grid={sched.grid}) "
        f"overflows VMEM: working set {sched.vmem_bytes} B > "
        f"{machine.usable_for_working_set(2)} B usable on {machine.name!r}; "
        f"falling back to the XLA reference path",
        stacklevel=3)


def _strategy_blocks(strategy, x, f, stride, padding):
    """Map a paper strategy onto planner constraints (block_do, block_h)."""
    from repro.kernels.conv2d.ops import conv_out_extent

    block_do = 1 if strategy == "alg1" else None  # None -> capacity planner
    block_h = None if strategy in ("strip", "alg1") else -1  # -1 -> full plane
    if block_h == -1:
        block_h = max(1, conv_out_extent(x.shape[-3], padding, f.shape[0], stride))
    return block_do, block_h


def _planned_conv_backward(x, f, dy, stride, padding, sd, *, mask=None, pool=1):
    """dX/dW through the planned Pallas backward kernels; ``sd`` maps
    {"dgrad"/"wgrad": Schedule} overrides.  With ``mask``/``pool`` (the
    fused forward's epilogue-VJP residual) ``dy`` is the *pooled*
    cotangent and the kernels scatter it to full rate in-jit — the
    fused_epilogue backward, no recompute conv.  Returns None when a
    schedule does not fit the machine (or the geometry is out of the
    dgrad contract) — the caller then falls back to the XLA reference
    VJP, loudly on the first unfit cell."""
    F = f.shape[0]
    if padding > F - 1:
        return None
    out_hw = (x.shape[-3], x.shape[-2])
    s_dg = local_schedule(sd.get("dgrad"))  # sharded pins run their local blocking
    if s_dg is None:
        s_dg = get_op("conv2d_dgrad").plan(
            dy, f, stride=stride, padding=padding, out_hw=out_hw,
            mask=mask, pool=pool)
    s_wg = local_schedule(sd.get("wgrad"))
    if s_wg is None:
        s_wg = get_op("conv2d_wgrad").plan(
            x, dy, F=F, stride=stride, padding=padding, mask=mask, pool=pool)
    # Each schedule is fit-checked against the machine it was planned for
    # (a user-pinned Manticore schedule must not pass a TPU-sized gate).
    m_dg = machine_named(s_dg.machine, _BWD_MACHINE)
    m_wg = machine_named(s_wg.machine, _BWD_MACHINE)
    if not s_dg.fits(m_dg):
        warn_unfit_schedule("dgrad", s_dg, m_dg)
        return None
    if not s_wg.fits(m_wg):
        warn_unfit_schedule("wgrad", s_wg, m_wg)
        return None
    dx = conv2d_dgrad(dy, f, stride=stride, padding=padding, out_hw=out_hw,
                      mask=mask, pool=pool, schedule=s_dg,
                      out_dtype=jnp.float32)
    dw = conv2d_wgrad(x, dy, F=F, stride=stride, padding=padding,
                      mask=mask, pool=pool, schedule=s_wg,
                      out_dtype=jnp.float32)
    return dx.astype(x.dtype), dw.astype(f.dtype)


def _conv_layer_kernel(x, f, stride, padding, strategy, schedule, bwd_schedules):
    del bwd_schedules  # consumed by the backward pass
    block_do, block_h = _strategy_blocks(strategy, x, f, stride, padding)
    return conv2d(
        x, f, stride=stride, padding=padding, schedule=schedule,
        block_do=block_do, block_h=block_h,
    )


def _conv_layer_ref(x, f, stride, padding, strategy, schedule, bwd_schedules):
    del strategy, schedule, bwd_schedules  # schedule knobs never change numerics
    return conv2d_ref(x, f, stride=stride, padding=padding)


def _conv_layer_bwd(x, f, g, stride, padding, strategy, schedule, bwd_schedules):
    del strategy, schedule
    planned = _planned_conv_backward(
        x, f, g.astype(jnp.float32), stride, padding, dict(bwd_schedules or ()))
    if planned is None:  # XLA reference VJP fallback
        _, vjp = jax.vjp(
            lambda xx, ff: conv2d_ref(xx, ff, stride=stride, padding=padding),
            x, f)
        return vjp(g)
    return planned


_conv_layer_vjp = with_reference_vjp(
    _conv_layer_kernel, _conv_layer_ref, nondiff_argnums=(2, 3, 4, 5, 6),
    bwd_fn=_conv_layer_bwd,
)


def conv_layer(x, f, stride=1, padding=0, strategy="alg2",
               schedule: Schedule | ShardedSchedule | None = None,
               bwd_schedules=None):
    """x: [B, H, W, D_I] or [H, W, D_I]; f: [F, F, D_I, D_O].

    ``schedule`` accepts either flavor — a ShardedSchedule contributes its
    per-device local blocking (a single-device mesh plan is exactly
    today's Schedule).  ``bwd_schedules`` optionally maps
    {"dgrad"/"wgrad": Schedule} to pin the planned backward kernels'
    blocking (see :func:`plan_bwd`)."""
    return _conv_layer_vjp(x, f, stride, padding, strategy,
                           local_schedule(schedule),
                           freeze_schedules(bwd_schedules))


def _conv_block_kernel(x, f, b, stride, padding, pool, strategy, schedule,
                       bwd_schedules):
    del bwd_schedules  # consumed by the backward pass
    block_do, block_h = _strategy_blocks(strategy, x, f, stride, padding)
    return conv2d(
        x, f, bias=b, stride=stride, padding=padding,
        relu=True, pool=pool, schedule=schedule,
        block_do=block_do, block_h=block_h,
    )


def _conv_block_ref(x, f, b, stride, padding, pool, strategy, schedule,
                    bwd_schedules):
    del strategy, schedule, bwd_schedules
    return conv2d_fused_ref(
        x, f, b, stride=stride, padding=padding, relu=True, pool=pool
    )


def _conv_block_fwd(x, f, b, stride, padding, pool, strategy, schedule,
                    bwd_schedules):
    """The differentiated forward: same output as the primal kernel, plus
    the int8 epilogue-VJP mask as the auxiliary residual (None on the
    paths the fused flush can't emit it — im2col schedules, ragged pool
    tails — where the backward recomputes as before)."""
    del bwd_schedules  # consumed by the backward pass
    block_do, block_h = _strategy_blocks(strategy, x, f, stride, padding)
    if schedule is None:
        bias = b if b is not None else jnp.zeros((f.shape[3],), jnp.float32)
        schedule = get_op("conv2d").plan(
            x, f, bias, stride=stride, padding=padding, relu=True,
            pool=pool, block_do=block_do, block_h=block_h)
    schedule = local_schedule(schedule)
    return conv2d_with_mask(
        x, f, bias=b, stride=stride, padding=padding, pool=pool,
        schedule=schedule)


def _conv_block_bwd(x, f, b, aux, g, stride, padding, pool, strategy,
                    schedule, bwd_schedules):
    del strategy, schedule
    sd = dict(bwd_schedules or ())
    g = g.astype(jnp.float32)
    if aux is not None:
        # Fused-epilogue backward: the saved int8 mask replaces the
        # recompute conv entirely — dY scatters through the pool-argmax /
        # ReLU-liveness mask inside the dgrad/wgrad kernels; the bias
        # gradient reads the same scattered full-rate dY (XLA CSE merges
        # this scatter with the kernels' identical in-jit prologue under
        # the one enclosing backward jit).
        dy_full = epilogue_scatter(g, aux, pool)
        db = dy_full.sum(tuple(range(dy_full.ndim - 1))).astype(b.dtype)
        planned = _planned_conv_backward(x, f, g, stride, padding, sd,
                                         mask=aux, pool=pool)
        if planned is None:  # XLA reference VJP fallback for the conv itself
            _, vjp = jax.vjp(
                lambda xx, ff: conv2d_ref(xx, ff, stride=stride,
                                          padding=padding,
                                          out_dtype=jnp.float32), x, f)
            dx, dw = vjp(dy_full)
            dx, dw = dx.astype(x.dtype), dw.astype(f.dtype)
        else:
            dx, dw = planned
        return dx, dw, db
    # No mask residual: rematerialize the pre-pool activation with the
    # planned forward kernel (the fused forward never stores it), backprop
    # the elementwise/pool epilogue in XLA, then run the planned transposed
    # kernels on dY.  A pinned recompute Schedule gets the same fit gate as
    # dgrad/wgrad: if it overflows its machine, drop it (loudly, once) and
    # let the planner re-plan a fitting blocking instead of launching a
    # known-oversized kernel.
    recompute = local_schedule(sd.get("recompute"))
    if recompute is not None and not recompute.fits(
            machine_named(recompute.machine, _BWD_MACHINE)):
        warn_unfit_schedule(
            "recompute", recompute,
            machine_named(recompute.machine, _BWD_MACHINE))
        recompute = None
    y0 = conv2d(x, f, bias=b, stride=stride, padding=padding, relu=False,
                pool=1, schedule=recompute, out_dtype=jnp.float32)

    def _epilogue(y):
        y = jnp.maximum(y, 0.0)
        return maxpool_ref(y, pool) if pool > 1 else y

    _, evjp = jax.vjp(_epilogue, y0)
    dy, = evjp(g)
    db = dy.sum(tuple(range(dy.ndim - 1))).astype(b.dtype)
    planned = _planned_conv_backward(x, f, dy, stride, padding, sd)
    if planned is None:  # XLA reference VJP fallback for the conv itself
        _, vjp = jax.vjp(
            lambda xx, ff: conv2d_ref(xx, ff, stride=stride, padding=padding,
                                      out_dtype=jnp.float32), x, f)
        dx, dw = vjp(dy)
        dx, dw = dx.astype(x.dtype), dw.astype(f.dtype)
    else:
        dx, dw = planned
    return dx, dw, db


_conv_block_vjp = with_reference_vjp(
    _conv_block_kernel, _conv_block_ref, nondiff_argnums=(3, 4, 5, 6, 7, 8),
    bwd_fn=_conv_block_bwd, fwd_fn=_conv_block_fwd,
)


def conv_block(x, f, b, stride=1, padding=0, pool=1, strategy="strip",
               schedule: Schedule | None = None, bwd_schedules=None):
    """Fused conv + bias + ReLU (+ optional ``pool x pool`` max-pool).

    The whole epilogue runs in the Pallas kernel's flush step on the
    VMEM-resident output strip — the activation never round-trips HBM
    between the conv and the pool.  ``x``: [B, H, W, D_I] or [H, W, D_I];
    ``f``: [F, F, D_I, D_O]; ``b``: [D_O].  An explicit ``schedule``
    overrides the strategy's planner constraints; ``bwd_schedules``
    ({"dgrad"/"wgrad"/"recompute": Schedule}) pins the planned backward.
    """
    return _conv_block_vjp(x, f, b, stride, padding, pool, strategy,
                           local_schedule(schedule),
                           freeze_schedules(bwd_schedules))


def plan(
    x_shape, f_shape, *, stride=1, padding=0, pool=1, in_bytes=4,
    machine=None, strategy="strip", mesh=None, shard_axis="data",
    shard_strategy=None, autotune=None, algorithm=None,
):
    """Plan this layer without running it: the Schedule the kernel would
    use for operands of these shapes (report `.modeled_words` next to
    measured time, or pass it back in via ``schedule=``).  With ``mesh=``
    the mesh-aware planner returns a ShardedSchedule — the device
    partitioning ("batch" or "stack" data parallelism over
    ``shard_axis``, pinnable with ``shard_strategy=``) plus the HBM/ICI
    word split; a single-device mesh degenerates to today's Schedule.
    ``autotune`` ("off" | "cache-only" | "tune", default the process
    policy) lets a measured winner for this cell override the argmin.
    ``algorithm`` pins one family of the two-level argmin ("direct" /
    "im2col"); the default lets both compete — the paper strategies
    ("alg1"/"alg2"/"alg3") pin direct-kernel blocks and therefore already
    imply the direct family."""
    from repro.core.machine import TPU_V5E
    from repro.kernels.conv2d.ops import _fused_pool, conv_out_extent
    from repro.plan import autotune as at

    machine = machine or TPU_V5E
    batched = len(x_shape) == 4
    B = x_shape[0] if batched else 1
    H, W, d_in = x_shape[-3], x_shape[-2], x_shape[-1]
    F, d_out = f_shape[0], f_shape[3]
    H_O = conv_out_extent(H, padding, F, stride)
    W_O = conv_out_extent(W, padding, F, stride)
    fused = _fused_pool(H_O, W_O, pool)
    block_do = 1 if strategy == "alg1" else None
    block_h = H_O if strategy in ("alg2", "alg3") else None
    return at.resolve("conv2d", dict(
        H_O=H_O, W_O=W_O, F=F, S=stride, d_in=d_in, d_out=d_out,
        in_bytes=in_bytes, pool=fused, batch=B, padding=padding,
        H_I=H, W_I=W, block_do=block_do, block_h=block_h,
        algorithm=algorithm,
    ), machine=machine, mesh=mesh, axis=shard_axis,
        strategy=shard_strategy, policy=autotune)


def plan_bwd(
    x_shape, f_shape, *, stride=1, padding=0, pool=None, in_bytes=4,
    machine=None, mesh=None, shard_axis="data", autotune=None,
) -> dict:
    """Backward-pass Schedules for this layer's shapes: the dgrad and
    wgrad kernels ``jax.grad`` will run, plus — on the recompute path
    only — the pre-epilogue recompute conv of :func:`conv_block`.  Pass
    (a subset of) the result back via ``bwd_schedules=`` to pin the
    blocking; sum ``.modeled_words`` to model the layer's training-step
    traffic.

    ``pool`` opts into the fused-epilogue backward: when given and the
    output plane tiles evenly (the fused forward emits the int8 mask
    residual), the dgrad cell is planned as its ``fused_epilogue``
    variant — dY scatters through the saved mask inside the kernels —
    and the "recompute" entry is dropped entirely (recompute_words = 0).
    A ragged pool (or ``pool=None``) keeps today's recompute plan.

    Geometries outside the dgrad kernel's contract (padding > F-1, where
    the layer trains via the XLA fallback) return only the plannable
    subset — no "dgrad" key.  With ``mesh=`` every entry is a
    ShardedSchedule: dgrad and the recompute shard with the batch (no
    collective), while the sharded wgrad charges the Alg-4 tree reduction
    of dW as ici_words.  The backward cells autotune through the same
    ``autotune=`` policy as the forward (each op is its own cache cell).
    """
    from repro.kernels.conv2d.ops import _fused_pool, conv_out_extent
    from repro.plan import autotune as at

    machine = machine or _BWD_MACHINE
    batched = len(x_shape) == 4
    B = x_shape[0] if batched else 1
    H, W, d_in = x_shape[-3], x_shape[-2], x_shape[-1]
    F, d_out = f_shape[0], f_shape[3]
    H_O = conv_out_extent(H, padding, F, stride)
    W_O = conv_out_extent(W, padding, F, stride)
    fused = pool is not None and _fused_pool(H_O, W_O, pool) == pool

    def res(op, **shape):
        return at.resolve(op, shape, machine=machine, mesh=mesh,
                          axis=shard_axis, policy=autotune)

    out = {
        "wgrad": res(
            "conv2d_wgrad",
            H_O=H_O, W_O=W_O, F=F, S=stride, d_in=d_in, d_out=d_out,
            in_bytes=in_bytes, batch=B, padding=padding, H_I=H, W_I=W),
    }
    if not fused:
        out["recompute"] = res(
            "conv2d",
            H_O=H_O, W_O=W_O, F=F, S=stride, d_in=d_in, d_out=d_out,
            in_bytes=in_bytes, pool=1, batch=B, padding=padding,
            H_I=H, W_I=W)
    if padding <= F - 1:
        out["dgrad"] = res(
            "conv2d_dgrad",
            H_O=H_O, W_O=W_O, F=F, S=stride, P=padding, d_in=d_in,
            d_out=d_out, in_bytes=in_bytes, batch=B, H_I=H, W_I=W,
            pool=pool if fused else None)
    return out


def traffic(
    shape: ccr.ConvShape, strategy: str = "alg2", precision: str = "sp",
    machine=MANTICORE, h_block: int | None = None,
) -> ccr.Traffic:
    """Predicted word traffic for this layer under the chosen algorithm."""
    if strategy == "alg1":
        return ccr.alg1_traffic(shape)
    if strategy == "alg2":
        return ccr.alg2_traffic(shape, max(1, ccr.alg2_max_stack(shape, machine, precision)))
    if strategy == "strip":
        hb = h_block or max(1, shape.W_O // 2)
        stack = max(1, ccr.alg2_strip_max_stack(shape, machine, precision, hb))
        return ccr.alg2_strip_traffic(shape, stack, hb)
    if strategy == "alg3":
        return ccr.alg3_traffic(shape, max(1, ccr.alg3_max_stack(shape, machine, precision)))
    raise ValueError(strategy)
