"""The paper's convolutional layer as a composable, differentiable module.

``strategy`` selects the paper algorithm:
  * "alg1"  - one output depth slice at a time (block_do = 1);
  * "alg2"  - Delta_O output stacking, Delta_O from the capacity chooser;
  * "strip" - Alg 2 + spatial strip tiling: the accumulator holds an
              h_block x W_O strip, trading strip height against Delta_O
              (the schedule the Pallas kernel actually runs);
  * "alg3"  - Alg 2 blocking within each device + ring input-slice reuse
              across devices (core/ring.py) when input channels are sharded.

Forward runs the batched strip-tiled Pallas kernel (interpret mode
off-TPU); :func:`conv_block` additionally fuses the layer epilogue (bias +
ReLU + optional 2x2 max-pool) into the kernel's flush step.  Backward is
the XLA reference VJP (custom_vjp), so CNNs built from these layers train.
Traffic accounting for any strategy comes from core/ccr.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import ccr
from repro.core.machine import TPU_V5E, MANTICORE
from repro.kernels.conv2d.ops import conv2d
from repro.kernels.conv2d.ref import conv2d_fused_ref, conv2d_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def conv_layer(x, f, stride=1, padding=0, strategy="alg2"):
    """x: [B, H, W, D_I] or [H, W, D_I]; f: [F, F, D_I, D_O]."""
    block_do = 1 if strategy == "alg1" else None  # None -> capacity chooser
    return conv2d(x, f, stride=stride, padding=padding, block_do=block_do)


def _fwd(x, f, stride, padding, strategy):
    return conv_layer(x, f, stride, padding, strategy), (x, f)


def _bwd(stride, padding, strategy, res, g):
    x, f = res
    _, vjp = jax.vjp(
        lambda xx, ff: conv2d_ref(xx, ff, stride=stride, padding=padding), x, f
    )
    return vjp(g)


conv_layer.defvjp(_fwd, _bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def conv_block(x, f, b, stride=1, padding=0, pool=1, strategy="strip"):
    """Fused conv + bias + ReLU (+ optional ``pool x pool`` max-pool).

    The whole epilogue runs in the Pallas kernel's flush step on the
    VMEM-resident output strip — the activation never round-trips HBM
    between the conv and the pool.  ``x``: [B, H, W, D_I] or [H, W, D_I];
    ``f``: [F, F, D_I, D_O]; ``b``: [D_O].
    """
    block_do = 1 if strategy == "alg1" else None
    block_h = None if strategy in ("strip", "alg1") else -1  # -1 -> full plane
    if block_h == -1:
        F = f.shape[0]
        H = x.shape[-3]
        block_h = max(1, (H + 2 * padding - F) // stride + 1)
    return conv2d(
        x, f, bias=b, stride=stride, padding=padding,
        relu=True, pool=pool, block_do=block_do, block_h=block_h,
    )


def _block_fwd(x, f, b, stride, padding, pool, strategy):
    return conv_block(x, f, b, stride, padding, pool, strategy), (x, f, b)


def _block_bwd(stride, padding, pool, strategy, res, g):
    x, f, b = res
    _, vjp = jax.vjp(
        lambda xx, ff, bb: conv2d_fused_ref(
            xx, ff, bb, stride=stride, padding=padding, relu=True, pool=pool
        ),
        x, f, b,
    )
    return vjp(g)


conv_block.defvjp(_block_fwd, _block_bwd)


def traffic(
    shape: ccr.ConvShape, strategy: str = "alg2", precision: str = "sp",
    machine=MANTICORE, h_block: int | None = None,
) -> ccr.Traffic:
    """Predicted word traffic for this layer under the chosen algorithm."""
    if strategy == "alg1":
        return ccr.alg1_traffic(shape)
    if strategy == "alg2":
        return ccr.alg2_traffic(shape, max(1, ccr.alg2_max_stack(shape, machine, precision)))
    if strategy == "strip":
        hb = h_block or max(1, shape.W_O // 2)
        stack = max(1, ccr.alg2_strip_max_stack(shape, machine, precision, hb))
        return ccr.alg2_strip_traffic(shape, stack, hb)
    if strategy == "alg3":
        return ccr.alg3_traffic(shape, max(1, ccr.alg3_max_stack(shape, machine, precision)))
    raise ValueError(strategy)
