"""The paper's convolutional layer as a composable, differentiable module.

``strategy`` selects the paper algorithm:
  * "alg1"  - one output depth slice at a time (block_do = 1);
  * "alg2"  - Delta_O output stacking at the full-plane strip, Delta_O from
              the capacity planner;
  * "strip" - Alg 2 + spatial strip tiling: the planner trades strip height
              against Delta_O (the schedule the Pallas kernel actually runs);
  * "alg3"  - Alg 2 blocking within each device + ring input-slice reuse
              across devices (core/ring.py) when input channels are sharded.

Blocking flows through the ``repro.plan`` layer: each strategy is a
different constraint handed to :class:`repro.plan.ConvPlanner`, and an
explicit :class:`repro.plan.Schedule` (``schedule=``) overrides the
planner entirely.  Forward runs the batched strip-tiled Pallas kernel
(interpret mode off-TPU); :func:`conv_block` additionally fuses the layer
epilogue (bias + ReLU + optional 2x2 max-pool) into the kernel's flush
step.  Backward is the XLA reference VJP (``repro.plan.with_reference_vjp``),
so CNNs built from these layers train.  Traffic accounting for any
strategy comes from core/ccr.py.
"""

from __future__ import annotations

from repro.core import ccr
from repro.core.machine import MANTICORE
from repro.kernels.conv2d.ops import conv2d
from repro.kernels.conv2d.ref import conv2d_fused_ref, conv2d_ref
from repro.plan import Schedule, with_reference_vjp


def _strategy_blocks(strategy, x, f, stride, padding):
    """Map a paper strategy onto planner constraints (block_do, block_h)."""
    from repro.kernels.conv2d.ops import conv_out_extent

    block_do = 1 if strategy == "alg1" else None  # None -> capacity planner
    block_h = None if strategy in ("strip", "alg1") else -1  # -1 -> full plane
    if block_h == -1:
        block_h = max(1, conv_out_extent(x.shape[-3], padding, f.shape[0], stride))
    return block_do, block_h


def _conv_layer_kernel(x, f, stride, padding, strategy, schedule):
    block_do, block_h = _strategy_blocks(strategy, x, f, stride, padding)
    return conv2d(
        x, f, stride=stride, padding=padding, schedule=schedule,
        block_do=block_do, block_h=block_h,
    )


def _conv_layer_ref(x, f, stride, padding, strategy, schedule):
    del strategy, schedule  # schedule knobs never change numerics
    return conv2d_ref(x, f, stride=stride, padding=padding)


_conv_layer_vjp = with_reference_vjp(
    _conv_layer_kernel, _conv_layer_ref, nondiff_argnums=(2, 3, 4, 5)
)


def conv_layer(x, f, stride=1, padding=0, strategy="alg2",
               schedule: Schedule | None = None):
    """x: [B, H, W, D_I] or [H, W, D_I]; f: [F, F, D_I, D_O]."""
    return _conv_layer_vjp(x, f, stride, padding, strategy, schedule)


def _conv_block_kernel(x, f, b, stride, padding, pool, strategy, schedule):
    block_do, block_h = _strategy_blocks(strategy, x, f, stride, padding)
    return conv2d(
        x, f, bias=b, stride=stride, padding=padding,
        relu=True, pool=pool, schedule=schedule,
        block_do=block_do, block_h=block_h,
    )


def _conv_block_ref(x, f, b, stride, padding, pool, strategy, schedule):
    del strategy, schedule
    return conv2d_fused_ref(
        x, f, b, stride=stride, padding=padding, relu=True, pool=pool
    )


_conv_block_vjp = with_reference_vjp(
    _conv_block_kernel, _conv_block_ref, nondiff_argnums=(3, 4, 5, 6, 7)
)


def conv_block(x, f, b, stride=1, padding=0, pool=1, strategy="strip",
               schedule: Schedule | None = None):
    """Fused conv + bias + ReLU (+ optional ``pool x pool`` max-pool).

    The whole epilogue runs in the Pallas kernel's flush step on the
    VMEM-resident output strip — the activation never round-trips HBM
    between the conv and the pool.  ``x``: [B, H, W, D_I] or [H, W, D_I];
    ``f``: [F, F, D_I, D_O]; ``b``: [D_O].  An explicit ``schedule``
    overrides the strategy's planner constraints.
    """
    return _conv_block_vjp(x, f, b, stride, padding, pool, strategy, schedule)


def plan(
    x_shape, f_shape, *, stride=1, padding=0, pool=1, in_bytes=4,
    machine=None, strategy="strip",
) -> Schedule:
    """Plan this layer without running it: the Schedule the kernel would
    use for operands of these shapes (report `.modeled_words` next to
    measured time, or pass it back in via ``schedule=``)."""
    from repro.core.machine import TPU_V5E
    from repro.kernels.conv2d.ops import _fused_pool, conv_out_extent
    from repro.plan import ConvPlanner

    machine = machine or TPU_V5E
    batched = len(x_shape) == 4
    B = x_shape[0] if batched else 1
    H, W, d_in = x_shape[-3], x_shape[-2], x_shape[-1]
    F, d_out = f_shape[0], f_shape[3]
    H_O = conv_out_extent(H, padding, F, stride)
    W_O = conv_out_extent(W, padding, F, stride)
    fused = _fused_pool(H_O, W_O, pool)
    block_do = 1 if strategy == "alg1" else None
    block_h = H_O if strategy in ("alg2", "alg3") else None
    return ConvPlanner(machine).plan(
        H_O=H_O, W_O=W_O, F=F, S=stride, d_in=d_in, d_out=d_out,
        in_bytes=in_bytes, pool=fused, batch=B, padding=padding,
        H_I=H, W_I=W, block_do=block_do, block_h=block_h,
    )


def traffic(
    shape: ccr.ConvShape, strategy: str = "alg2", precision: str = "sp",
    machine=MANTICORE, h_block: int | None = None,
) -> ccr.Traffic:
    """Predicted word traffic for this layer under the chosen algorithm."""
    if strategy == "alg1":
        return ccr.alg1_traffic(shape)
    if strategy == "alg2":
        return ccr.alg2_traffic(shape, max(1, ccr.alg2_max_stack(shape, machine, precision)))
    if strategy == "strip":
        hb = h_block or max(1, shape.W_O // 2)
        stack = max(1, ccr.alg2_strip_max_stack(shape, machine, precision, hb))
        return ccr.alg2_strip_traffic(shape, stack, hb)
    if strategy == "alg3":
        return ccr.alg3_traffic(shape, max(1, ccr.alg3_max_stack(shape, machine, precision)))
    raise ValueError(strategy)
