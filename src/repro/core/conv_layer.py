"""The paper's convolutional layer as a composable, differentiable module.

``strategy`` selects the paper algorithm:
  * "alg1"  - one output depth slice at a time (block_do = 1);
  * "alg2"  - Delta_O output stacking, Delta_O from the capacity chooser;
  * "alg3"  - Alg 2 blocking within each device + ring input-slice reuse
              across devices (core/ring.py) when input channels are sharded.

Forward runs the Pallas kernel (interpret mode off-TPU); backward is the
XLA reference VJP (custom_vjp), so CNNs built from this layer train.
Traffic accounting for any strategy comes from core/ccr.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import ccr
from repro.core.machine import TPU_V5E, MANTICORE
from repro.kernels.conv2d.ops import conv2d
from repro.kernels.conv2d.ref import conv2d_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def conv_layer(x, f, stride=1, padding=0, strategy="alg2"):
    """x: [B, H, W, D_I] or [H, W, D_I]; f: [F, F, D_I, D_O]."""
    block_do = 1 if strategy == "alg1" else None  # None -> capacity chooser
    return conv2d(x, f, stride=stride, padding=padding, block_do=block_do)


def _fwd(x, f, stride, padding, strategy):
    return conv_layer(x, f, stride, padding, strategy), (x, f)


def _bwd(stride, padding, strategy, res, g):
    x, f = res
    _, vjp = jax.vjp(
        lambda xx, ff: conv2d_ref(xx, ff, stride=stride, padding=padding), x, f
    )
    return vjp(g)


conv_layer.defvjp(_fwd, _bwd)


def traffic(
    shape: ccr.ConvShape, strategy: str = "alg2", precision: str = "sp",
    machine=MANTICORE,
) -> ccr.Traffic:
    """Predicted word traffic for this layer under the chosen algorithm."""
    if strategy == "alg1":
        return ccr.alg1_traffic(shape)
    if strategy == "alg2":
        return ccr.alg2_traffic(shape, max(1, ccr.alg2_max_stack(shape, machine, precision)))
    if strategy == "alg3":
        return ccr.alg3_traffic(shape, max(1, ccr.alg3_max_stack(shape, machine, precision)))
    raise ValueError(strategy)
