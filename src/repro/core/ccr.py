"""The paper's analysis framework as code: Eqs. (1)-(14).

For each of the five algorithms (conv Algs 1-3, FC Algs 4-5) this module
gives the closed-form *compute*, *space*, and *communication* complexity and
the resulting compute-to-communication ratio (CCR), exactly as derived in
the paper.  ``schedule_sim.py`` cross-checks every closed form by actually
walking the loop nests and counting DMA words.

Conventions (paper Sec. 1.2.2): one MAC = 2 flops; a "word" is one element
(4 B single precision, 8 B double precision); CCR is MAC/word.

Known paper slip, reproduced deliberately: the numerical intuition in
Sec. 2.3.4 (541.4 / 540.6 MAC/word) does not follow from the paper's own
Eq. (10); it matches Eq. (10) with the ``D_I`` factor dropped from the
input-slice term.  ``alg3_ccr_offchip_as_quoted`` reproduces the quoted
numbers; ``.ccr_offchip`` on :func:`alg3_traffic` follows Eq. (10)
faithfully.  EXPERIMENTS.md documents both.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.machine import MachineModel, word_bytes

# ---------------------------------------------------------------------------
# Layer shapes (hyperparameters of Table 1)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ConvShape:
    """Convolutional layer hyperparameters (paper Table 1)."""

    W_I: int  # input width and height
    D_I: int  # input depth
    D_O: int  # output depth
    F: int  # receptive field
    S: int = 1  # stride
    P: int = 1  # zero padding

    @property
    def W_O(self) -> int:
        """Output width/height: W_O = (W_I + 2P - F)/S + 1 (paper Sec. 1.1)."""
        num = self.W_I + 2 * self.P - self.F
        if num % self.S:
            raise ValueError(f"(W_I+2P-F)={num} not divisible by stride {self.S}")
        return num // self.S + 1

    def validate(self) -> None:
        if self.F > self.W_I + 2 * self.P:
            raise ValueError("receptive field larger than padded input")
        for f in ("W_I", "D_I", "D_O", "F", "S"):
            if getattr(self, f) <= 0:
                raise ValueError(f"{f} must be positive")
        if self.P < 0:
            raise ValueError("padding must be non-negative")


@dataclasses.dataclass(frozen=True)
class FCShape:
    """Fully-connected layer hyperparameters.

    An FC layer is a conv layer with F = W_I, S = 1, P = 0 (paper Sec. 1.1),
    plus a batch dimension B (paper Sec. 3).
    """

    W_I: int
    D_I: int
    D_O: int
    B: int

    def validate(self) -> None:
        for f in ("W_I", "D_I", "D_O", "B"):
            if getattr(self, f) <= 0:
                raise ValueError(f"{f} must be positive")


# ---------------------------------------------------------------------------
# Traffic model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Traffic:
    """Word-granular traffic of one layer execution under one algorithm."""

    macs: int  # total multiply-accumulates across all clusters
    main_loads: int  # words loaded from main (off-chip) memory
    main_stores: int  # words stored to main memory
    intercluster: int = 0  # words moved cluster-to-cluster (on-chip)

    @property
    def main_words(self) -> int:
        return self.main_loads + self.main_stores

    @property
    def ccr(self) -> float:
        """Overall CCR in MAC/word: all memory traffic, on- or off-chip
        (paper Sec. 2.3.4: 'the overall CCR is not affected' by Alg 3)."""
        return self.macs / (self.main_words + self.intercluster)

    @property
    def ccr_offchip(self) -> float:
        """CCR counting only off-chip main-memory words."""
        return self.macs / self.main_words

    def flops_per_byte(self, precision: str, offchip_only: bool = False) -> float:
        """CCR converted to flop/B for a given precision (2 flop per MAC)."""
        ccr = self.ccr_offchip if offchip_only else self.ccr
        return ccr * 2.0 / word_bytes(precision)


# ---------------------------------------------------------------------------
# Conv layers
# ---------------------------------------------------------------------------


def conv_macs(s: ConvShape) -> int:
    """Total MACs of the layer: W_I^2 * F^2 * D_I * D_O (paper Sec. 2.1.1).

    NOTE the paper counts Conv() as W_I^2*F^2 MACs (it slides the filter over
    the *input* extent); we keep that convention for fidelity.  For S=1, P
    'same' padding this equals W_O^2*F^2.
    """
    return s.W_I**2 * s.F**2 * s.D_I * s.D_O


def alg1_traffic(s: ConvShape) -> Traffic:
    """Alg 1: parallelize output depth slices over clusters (Sec. 2.1.3)."""
    loads = s.D_O * s.D_I * (s.W_I**2 + s.F**2)
    stores = s.D_O * s.W_O**2
    return Traffic(macs=conv_macs(s), main_loads=loads, main_stores=stores)


def alg1_ccr(s: ConvShape) -> float:
    """Eq. (2): D_I*W_I^2*F^2 / (D_I*(W_I^2+F^2) + W_O^2)."""
    return (s.D_I * s.W_I**2 * s.F**2) / (s.D_I * (s.W_I**2 + s.F**2) + s.W_O**2)


def alg1_ccr_approx(s: ConvShape) -> float:
    """Eq. (6): CCR ~= F^2  (for W_O=W_I, D_I>>1, W_I^2>>F^2)."""
    return float(s.F**2)


def alg2_traffic(s: ConvShape, stack: int) -> Traffic:
    """Alg 2: stacks of Delta_O output slices per cluster (Sec. 2.2.3, Eq. 7)."""
    n_stacks = math.ceil(s.D_O / stack)
    loads = n_stacks * s.D_I * s.W_I**2 + s.D_O * s.D_I * s.F**2
    stores = s.D_O * s.W_O**2
    return Traffic(macs=conv_macs(s), main_loads=loads, main_stores=stores)


def _strip_rows(s: ConvShape, h_block: int):
    """Real (non-padding) input rows each halo'd strip streams, plus the
    strip's real output rows.  Strip ``h`` covers output rows
    ``[h*h_block, h*h_block + h_block)``; its halo'd input window is rows
    ``[h*h_block*S - P, h*h_block*S - P + (h_block-1)*S + F)`` of the
    unpadded image — zero-padding rows cost no traffic (paper convention:
    Eq. (7) charges W_I^2 input words, not (W_I+2P)^2)."""
    h_in = (h_block - 1) * s.S + s.F
    H_O = s.W_O  # square images throughout the paper
    for h0 in range(0, H_O, h_block):
        lo = h0 * s.S - s.P
        rows_in = min(lo + h_in, s.W_I) - max(lo, 0)
        yield max(0, rows_in), min(h_block, H_O - h0)


def alg2_strip_traffic(s: ConvShape, stack: int, h_block: int) -> Traffic:
    """Strip-tiled Alg 2 (the Pallas kernel's schedule): the output stack is
    held as an ``h_block x W_O`` strip, so each of the ``ceil(H_O/h_block)``
    strips re-streams its halo'd input rows once per stack.  Degenerates to
    Eq. (7) exactly at ``h_block = H_O`` (one strip, halo covers the image).
    """
    n_stacks = math.ceil(s.D_O / stack)
    n_strips = math.ceil(s.W_O / h_block)
    input_words = sum(r_in * s.W_I for r_in, _ in _strip_rows(s, h_block))
    # Each strip is a full Alg 2 pass over its rows: input rows once per
    # stack, filter slabs once per (strip, d_i, d_o) — the kernel's grid
    # order re-streams filters per strip, so the model charges it.
    loads = n_stacks * s.D_I * input_words + n_strips * s.D_O * s.D_I * s.F**2
    stores = s.D_O * s.W_O**2
    return Traffic(macs=conv_macs(s), main_loads=loads, main_stores=stores)


def conv_dgrad_shape(s: ConvShape) -> ConvShape:
    """The backward-data (dgrad) geometry of a conv layer: dX is a
    *stride-1* conv over the S-dilated gradient with spatially flipped
    filters and swapped channel roles (DESIGN.md Sec. 4) — itself a
    ConvShape, so every Alg 1-3 closed form and capacity rule applies to
    the backward pass unchanged.  Requires P <= F-1 (the transposed
    padding F-1-P stays non-negative)."""
    if s.P > s.F - 1:
        raise ValueError(f"dgrad needs P <= F-1, got P={s.P} for F={s.F}")
    return ConvShape(W_I=(s.W_O - 1) * s.S + 1, D_I=s.D_O, D_O=s.D_I,
                     F=s.F, S=1, P=s.F - 1 - s.P)


def conv_dgrad_traffic(s: ConvShape, stack: int, h_block: int,
                       batch: int = 1) -> Traffic:
    """Strip-tiled dgrad traffic: alg2_strip_traffic on the transposed
    geometry (gradient slices stream, Delta_I output slices of dX stack),
    once per batch element."""
    t = alg2_strip_traffic(conv_dgrad_shape(s), stack, h_block)
    return Traffic(macs=batch * t.macs, main_loads=batch * t.main_loads,
                   main_stores=batch * t.main_stores)


def conv_wgrad_traffic(s: ConvShape, stack: int, h_block: int,
                       di_block: int = 1, batch: int = 1) -> Traffic:
    """Backward-filter (wgrad) traffic of the strip-tiled schedule: the
    F^2 x Delta_I x Delta_O filter-gradient accumulator is the resident
    stack.  Each of the ceil(D_O/stack) gradient stacks re-streams every
    halo'd input strip (zero-padding rows free, as in Eq. 7); each of the
    ceil(D_I/di_block) input blocks re-streams the whole gradient plane;
    dW stores exactly once, accumulated over batch and strips on-cluster.
    MACs are counted over the *output* extent (each dW MAC pairs one
    gradient element with one input element) — equal to conv_macs when
    W_O = W_I."""
    n_do = math.ceil(s.D_O / stack)
    n_di = math.ceil(s.D_I / di_block)
    H_O = s.W_O  # square images throughout the paper
    input_words = sum(r_in * s.W_I for r_in, _ in _strip_rows(s, h_block))
    loads = batch * (n_do * s.D_I * input_words + n_di * s.D_O * H_O * s.W_O)
    stores = s.F**2 * s.D_I * s.D_O
    macs = batch * H_O * s.W_O * s.F**2 * s.D_I * s.D_O
    return Traffic(macs=macs, main_loads=loads, main_stores=stores)


def alg3_traffic(s: ConvShape, stack: int, group: int = 16) -> Traffic:
    """Alg 3: Alg 2 + ring reuse of input slices within an L2 quadrant
    (Sec. 2.3.3, Eqs. 9-10).  ``group`` is the quadrant size (16 clusters).
    """
    n_stacks = math.ceil(s.D_O / stack)
    input_words = n_stacks * s.D_I * s.W_I**2
    # 15/16 of input-slice loads come from a neighbouring cluster, 1/16 from
    # main memory (Eq. 9 / Eq. 10).
    inter = (group - 1) * input_words // group
    main_in = input_words - inter
    loads = main_in + s.D_O * s.D_I * s.F**2
    stores = s.D_O * s.W_O**2
    return Traffic(
        macs=conv_macs(s), main_loads=loads, main_stores=stores, intercluster=inter
    )


def alg3_ccr_offchip_as_quoted(s: ConvShape, stack: int, group: int = 16) -> float:
    """The paper's *quoted* Sec. 2.3.4 numbers (541.4 / 540.6 MAC/word).

    These match Eq. (10) with the D_I factor dropped from the input term —
    an arithmetic slip in the paper's numerical intuition.  Kept so tests can
    pin the published numbers while `alg3_traffic().ccr_offchip` stays
    faithful to Eq. (10).
    """
    n_stacks = math.ceil(s.D_O / stack)
    input_main = n_stacks * s.W_I**2 // group  # paper slip: no * D_I
    denom = input_main + s.D_O * s.D_I * s.F**2 + s.D_O * s.W_O**2
    return conv_macs(s) / denom


# Space complexity (words) -------------------------------------------------


def alg1_space_words(s: ConvShape) -> int:
    """Sec. 2.1.2: W_O^2 + W_I^2 + F^2 words minimum."""
    return s.W_O**2 + s.W_I**2 + s.F**2


def alg2_space_words(s: ConvShape, stack: int) -> int:
    """Sec. 2.2.2: Delta_O*W_O^2 + W_I^2 + F^2 words minimum."""
    return stack * s.W_O**2 + s.W_I**2 + s.F**2


def alg3_space_words(s: ConvShape, stack: int) -> int:
    """Sec. 2.3.2: Alg 2 + one forwarding buffer of W_I^2 words."""
    return alg2_space_words(s, stack) + s.W_I**2


def alg2_strip_space_words(s: ConvShape, stack: int, h_block: int) -> int:
    """Strip-tiled working set: Delta_O strips of h_block*W_O output words
    plus one halo'd input strip of ((h_block-1)S+F) x (W_I+2P) and F^2
    filter words — the accumulator no longer scales with the full plane."""
    h_in = (h_block - 1) * s.S + s.F
    return stack * h_block * s.W_O + h_in * (s.W_I + 2 * s.P) + s.F**2


def alg2_max_stack(s: ConvShape, machine: MachineModel, precision: str) -> int:
    """Largest Delta_O fitting local memory (Sec. 2.2.2).

    The paper reserves 2 x 16 KiB DMA buffers for the input slice and the
    filter parameters; the rest of the 128 KiB holds the output stack.
    """
    wb = word_bytes(precision)
    budget = machine.usable_for_working_set(streams=2)
    return budget // (wb * s.W_O**2)


def alg2_strip_max_stack(
    s: ConvShape, machine: MachineModel, precision: str, h_block: int
) -> int:
    """Largest Delta_O fitting local memory under strip tiling: the strip
    accumulator costs h_block*W_O words per output slice instead of W_O^2,
    so shrinking the strip grows the stack the capacity rule can pick —
    the two-dimensional (h_block, Delta_O) trade-off the kernel schedules."""
    wb = word_bytes(precision)
    budget = machine.usable_for_working_set(streams=2)
    return budget // (wb * h_block * s.W_O)


def alg3_max_stack(s: ConvShape, machine: MachineModel, precision: str) -> int:
    """Largest Delta_O for Alg 3 (Sec. 2.3.2): additionally keep one input
    depth slice resident so the neighbouring cluster can read it."""
    wb = word_bytes(precision)
    budget = machine.usable_for_working_set(streams=2) - wb * s.W_I**2
    return budget // (wb * s.W_O**2)


# ---------------------------------------------------------------------------
# FC layers
# ---------------------------------------------------------------------------


def fc_macs(s: FCShape) -> int:
    """Sec. 3.1.1: W_I^2 * B * D_O * D_I MACs across all clusters."""
    return s.W_I**2 * s.B * s.D_O * s.D_I


def alg4_traffic(s: FCShape, clusters: int = 128) -> Traffic:
    """Alg 4: parallel input depth slices, private outputs, tree reduction
    (Sec. 3.1.3)."""
    loads = s.D_I * s.W_I**2 * (s.B + s.D_O)
    stores = s.D_O * s.B
    inter = (clusters - 1) * s.D_O * s.B  # 127 * D_O * B for 128 clusters
    return Traffic(macs=fc_macs(s), main_loads=loads, main_stores=stores, intercluster=inter)


def alg4_ccr(s: FCShape) -> float:
    """Eq. (11): B*D_O/(B+D_O) — the in-parallel-region CCR."""
    return (s.B * s.D_O) / (s.B + s.D_O)


def alg5_traffic(s: FCShape, stack: int, clusters: int = 128) -> Traffic:
    """Alg 5: output stacks of Delta_O + parallel input slices
    (Sec. 3.2.3, Eqs. 12-13)."""
    n_stacks = math.ceil(s.D_O / stack)
    loads = n_stacks * s.D_I * s.B * s.W_I**2 + s.D_O * s.D_I * s.W_I**2
    stores = s.D_O * s.B
    inter = (clusters - 1) * s.D_O * s.B
    return Traffic(macs=fc_macs(s), main_loads=loads, main_stores=stores, intercluster=inter)


def alg5_ccr(s: FCShape, stack: int) -> float:
    """Eq. (14): B*D_O / (ceil(D_O/Delta_O)*B + D_O)."""
    n_stacks = math.ceil(s.D_O / stack)
    return (s.B * s.D_O) / (n_stacks * s.B + s.D_O)


def alg4_space_words(s: FCShape) -> int:
    """Sec. 3.1.2: D_O*B + W_I^2*(B+1) words minimum."""
    return s.D_O * s.B + s.W_I**2 * (s.B + 1)


def alg5_space_words(s: FCShape, stack: int) -> int:
    """Sec. 3.2.2: Delta_O*B + W_I^2*(B+1) words minimum."""
    return stack * s.B + s.W_I**2 * (s.B + 1)


def alg45_max_stack(s: FCShape, machine: MachineModel, precision: str) -> int:
    """Largest Delta_O (Alg 5) / D_O (Alg 4) whose private output volume fits
    after reserving 2 x 16 KiB DMA buffers (Sec. 3.1.2): 96 KiB on Manticore,
    giving D_O <= 768 (sp) / 384 (dp) at B = 32."""
    wb = word_bytes(precision)
    budget = machine.usable_for_working_set(streams=2)
    return budget // (wb * s.B)


# ---------------------------------------------------------------------------
# Sharded (multi-device) closed forms: the mesh-aware planner's word model
# ---------------------------------------------------------------------------


def tree_reduce_words(n_parts: int, words_each: int) -> int:
    """Pairwise tree reduction of ``n_parts`` private volumes: each merge
    reads one full volume over the network — (n_parts - 1) * words_each
    total (paper Sec. 3.1.3: 127 * D_O * B for 128 clusters).  The closed
    form behind every psum/batch-contraction ``ici_words`` count."""
    total = 0
    live = n_parts
    while live > 1:
        merges = live // 2
        total += merges * words_each
        live -= merges
    return total


def matmul_block_traffic(*, m: int, n: int, k: int, block_m: int,
                         block_n: int, block_k: int) -> Traffic:
    """Closed form of the blocked-matmul grid walk on the padded problem
    (== schedule_sim.simulate_matmul_blocks): an x block and a w block per
    (i, j, kk) step, one output block store per (i, j) — i.e. x re-streams
    once per output stack, w once per m-block, Alg 5's Eqs. (12)-(13) when
    one m-block covers the batch."""
    mp = math.ceil(m / block_m) * block_m
    np_ = math.ceil(n / block_n) * block_n
    kp = math.ceil(k / block_k) * block_k
    loads = (np_ // block_n) * mp * kp + (mp // block_m) * kp * np_
    stores = mp * np_
    return Traffic(macs=mp * np_ * kp, main_loads=loads, main_stores=stores)


def conv_im2col_traffic(*, H_O: int, W_O: int, F: int, S: int, d_in: int,
                        d_out: int, block_h: int, block_m: int, block_n: int,
                        block_k: int, pool: int = 1, batch: int = 1) -> Traffic:
    """im2col-GEMM conv traffic (== schedule_sim.simulate_conv_im2col).

    The layer runs strip by strip: each strip of ``block_h`` output rows
    expands its receptive fields into a patch matrix A of
    ``batch * rows * W_O`` rows by ``F*F*d_in`` columns and multiplies it
    against the reshaped filter matrix [F*F*d_in, d_out] with the blocked
    GEMM (``matmul_block_traffic``).  The patch matrix never materializes
    whole in HBM — only strip-at-a-time — but its *words are charged in
    full*: every output position reads its complete F x F x d_in patch, an
    input read amplification of ``F*F/S**2`` relative to the raw image
    (each input pixel belongs to up to F^2/S^2 patches, and zero-padding
    pixels are charged like real ones — the patch matrix materializes
    them).  That amplification is the direct kernel's structural edge at
    F > S; im2col wins it back when S > F (strided convs read only the
    pixels their patches use, while the strip kernel streams whole rows)
    or when the GEMM's blocking beats the strip accumulator's.

    With ``pool > 1`` the pool epilogue is *not* fused into the GEMM (the
    direct kernel fuses it into the flush): the un-pooled strip outputs
    store from the GEMM, then the pool pass re-reads each window and
    stores the pooled plane.
    """
    k = F * F * d_in
    loads = stores = macs = 0
    for h0 in range(0, H_O, block_h):
        rows = min(block_h, H_O - h0)
        t = matmul_block_traffic(m=batch * rows * W_O, n=d_out, k=k,
                                 block_m=block_m, block_n=block_n,
                                 block_k=block_k)
        loads += t.main_loads
        stores += t.main_stores
        macs += t.macs
    if pool > 1:
        pooled = (H_O // pool) * (W_O // pool)
        loads += batch * pooled * pool * pool * d_out
        stores += batch * pooled * d_out
    return Traffic(macs=macs, main_loads=loads, main_stores=stores)


def ring_traffic(*, m: int, n: int, k: int, devices: int) -> Traffic:
    """Alg 3's ring reuse on the FC/matmul mesh (core/ring.py): X is
    K-sharded, W is N-sharded with full K, and each device multiplies the
    resident X shard while permuting it to its ring neighbour — so every
    X word is loaded from main memory exactly once (by its home device)
    and travels the ring (devices - 1) times, exactly like the paper's
    DmaLoad from cluster (CID - 1) mod 16.

    Per device: loads = M*K/P (own shard) + K*N/P (its weight columns),
    stores = M*N/P, interconnect sends = (P-1) * M*K/P.
    """
    if devices <= 0 or k % devices or n % devices:
        raise ValueError(
            f"ring needs K and N divisible by the mesh: k={k}, n={n}, "
            f"devices={devices}")
    k_loc, n_loc = k // devices, n // devices
    loads = devices * (m * k_loc + k * n_loc)  # == m*k + k*n
    stores = devices * m * n_loc  # == m*n
    inter = devices * (devices - 1) * m * k_loc  # == (P-1) * m*k
    return Traffic(macs=m * n * k, main_loads=loads, main_stores=stores,
                   intercluster=inter)


def fc_psum_traffic(*, m: int, n: int, k: int, devices: int, block_m: int,
                    block_n: int, block_k: int) -> Traffic:
    """The sharded FC layer's "psum" strategy (Alg 4 over a mesh axis):
    every device runs the blocked matmul on its K-shard and the private
    [M, N] partial outputs merge by tree reduction."""
    if devices <= 0 or k % devices:
        raise ValueError(f"psum needs K divisible by the mesh: k={k}, "
                         f"devices={devices}")
    local = matmul_block_traffic(m=m, n=n, k=k // devices, block_m=block_m,
                                 block_n=block_n, block_k=block_k)
    return Traffic(
        macs=devices * local.macs,
        main_loads=devices * local.main_loads,
        main_stores=devices * local.main_stores,
        intercluster=tree_reduce_words(devices, m * n),
    )


def tp_matmul_traffic(*, m: int, n: int, k: int, devices: int, block_m: int,
                      block_n: int, block_k: int) -> Traffic:
    """Megatron-style tensor-parallel matmul: W is column (N) sharded, X
    replicated, so each device runs the blocked matmul on its [k, n/P]
    weight columns and the private [m, n/P] activation shards all-gather
    over the interconnect — (P - 1) * m * n words, the same count whether
    the gather runs as a ring or a tree (``tree_reduce_words``).

    The trade against "batch" data parallelism is weight words vs
    activation words: batch re-streams the *full* weight per device
    (P * k * n loads total) while TP streams each weight column once
    (k * n total) but pays the activation gather — at small m (serving
    decode, small microbatches) the weight term dominates and TP wins;
    at large m batch parallelism's zero ici wins."""
    if devices <= 0 or n % devices:
        raise ValueError(
            f"tp needs N divisible by the mesh: n={n}, devices={devices}")
    local = matmul_block_traffic(m=m, n=n // devices, k=k, block_m=block_m,
                                 block_n=block_n, block_k=block_k)
    return Traffic(
        macs=devices * local.macs,
        main_loads=devices * local.main_loads,
        main_stores=devices * local.main_stores,
        intercluster=tree_reduce_words(devices, m * n),
    )


def moe_all_to_all_words(*, tokens: int, d_model: int, top_k: int,
                         n_experts: int, devices: int) -> int:
    """Expert-parallel MoE all-to-all interconnect words (dispatch +
    return): each device owns ``tokens / P`` rows routed to ``top_k``
    experts each; experts are sharded ``E / P`` per device, and with the
    balanced slot-major dispatch (models/moe.py's capacity argsort) every
    expert receives an equal share of each device's routed rows.  A row
    bound for a remote expert crosses the interconnect twice — d_model
    words out to the expert's device, d_model back after the FFN — and a
    fraction (P - 1) / P of every device's routed rows are remote:

        2 * d_model * top_k * (tokens / P) * (P - 1)

    Pinned word-for-word against ``schedule_sim.simulate_moe_all_to_all``
    (the literal per-device, per-expert dispatch walk)."""
    if devices <= 0 or tokens % devices:
        raise ValueError(f"ep needs tokens divisible by the mesh: "
                         f"tokens={tokens}, devices={devices}")
    if n_experts % devices:
        raise ValueError(f"ep needs experts divisible by the mesh: "
                         f"n_experts={n_experts}, devices={devices}")
    t_loc = tokens // devices
    if (t_loc * top_k) % n_experts:
        raise ValueError(
            f"balanced dispatch needs local routed rows divisible by the "
            f"experts: tokens/P * top_k = {t_loc * top_k}, "
            f"n_experts={n_experts}")
    return 2 * d_model * top_k * t_loc * (devices - 1)


def conv_sharded_traffic(s: ConvShape, stack: int, h_block: int, *,
                         devices: int, strategy: str = "batch",
                         batch: int = 1) -> Traffic:
    """Sharded strip-tiled conv (forward): pure data parallelism.

    "batch" shards the batch dimension (each device walks the full strip
    schedule on batch/devices images); "stack" shards output depth (each
    device owns D_O/devices slices and re-streams the whole input for its
    stacks).  Neither moves interconnect words in the forward pass — the
    split matters because the sharded *wgrad* pays the tree reduction.
    """
    if strategy == "batch":
        if batch % devices:
            raise ValueError(f"batch {batch} not divisible by {devices}")
        t = alg2_strip_traffic(s, stack, h_block)
        return Traffic(macs=batch * t.macs, main_loads=batch * t.main_loads,
                       main_stores=batch * t.main_stores)
    if strategy == "stack":
        if s.D_O % devices:
            raise ValueError(f"D_O {s.D_O} not divisible by {devices}")
        sl = dataclasses.replace(s, D_O=s.D_O // devices)
        t = alg2_strip_traffic(sl, min(stack, sl.D_O), h_block)
        return Traffic(macs=batch * devices * t.macs,
                       main_loads=batch * devices * t.main_loads,
                       main_stores=batch * devices * t.main_stores)
    raise ValueError(strategy)


# ---------------------------------------------------------------------------
# Critical-path steps: the overlap-aware cost axis (words -> words + steps)
# ---------------------------------------------------------------------------
#
# A planned kernel is a software pipeline: each grid step's input DMA
# overlaps the previous step's compute, so once per-step words are hidden
# the wall time scales with the number of *sequential steps on the critical
# path*.  The closed forms below must equal the executed walkers in
# schedule_sim (house rule); planners record the result in
# ``Schedule.critical_path_steps`` and the backward planners argmin
# ``modeled_words + critical_path_steps``.


def grid_steps(grid) -> int:
    """Sequential steps of a plain software-pipelined grid
    (== schedule_sim.simulate_grid_steps): one step per grid point plus
    one pipeline-fill step (the first fetch overlaps no compute)."""
    steps = 1
    for g in grid:
        steps *= g
    return steps + 1


def conv_dgrad_fused_steps(*, H_I: int, d_in: int, block_h: int,
                           block_do: int, batch: int = 1) -> int:
    """Critical-path steps of the fused-epilogue dgrad variant
    (== schedule_sim.simulate_conv_dgrad_fused_steps).  The d_out stream
    is folded *inside* each grid step by the double-buffered DMA loop, so
    the sequential grid walks only (batch, dX strip, dX channel stack);
    plus one pipeline-fill step and one step for the mask-scatter
    prologue that rebuilds the full-rate dY from the pooled gradient."""
    n_h = -(-H_I // block_h)
    n_do = -(-d_in // block_do)
    return batch * n_h * n_do + 2


def conv_wgrad_steps(*, H_O: int, d_in: int, d_out: int, block_h: int,
                     block_di: int, block_do: int, batch: int = 1,
                     pipelined: bool = False) -> int:
    """Critical-path steps of the wgrad kernel
    (== schedule_sim.simulate_conv_wgrad_steps).  The direct grid walks
    (d_i block, d_o stack, batch, strip) + fill; the pipelined variant
    folds the (batch, strip) accumulation sweep into each (d_i, d_o) step
    with double-buffered strip DMA, leaving only n_di * n_do sequential
    steps."""
    n_di = -(-d_in // block_di)
    n_do = -(-d_out // block_do)
    n_h = -(-H_O // block_h)
    inner = 1 if pipelined else batch * n_h
    return n_di * n_do * inner + 1


def epilogue_scatter_traffic(*, H_O: int, W_O: int, d_out: int, pool: int,
                             batch: int = 1, in_bytes: int = 4) -> Traffic:
    """The fused epilogue VJP's scatter pass
    (== schedule_sim.simulate_epilogue_scatter): read the pooled gradient
    and the int8 pool-argmax/ReLU mask (charged in words — ``in_bytes``
    mask bytes pack into one word), store the full-rate dY that the dgrad
    and wgrad streams then consume.  This replaces the recompute path's
    full forward-conv re-run (``alg2_strip_traffic`` words) whose only
    purpose was rebuilding the same mask."""
    pooled = batch * (H_O // pool) * (W_O // pool) * d_out
    loads = pooled + -(-pooled // in_bytes)  # pooled dY + packed int8 mask
    stores = batch * H_O * W_O * d_out  # scattered full-rate dY
    return Traffic(macs=0, main_loads=loads, main_stores=stores)


# ---------------------------------------------------------------------------
# Roofline hook: is the algorithm memory-bound on a machine?
# ---------------------------------------------------------------------------


def bound_kind(t: Traffic, machine: MachineModel, precision: str) -> str:
    """Classify compute- vs memory-bound: compare the layer's off-chip
    arithmetic intensity (flop/B) against the machine balance point."""
    intensity = t.flops_per_byte(precision, offchip_only=True)
    balance = machine.peak_flops / machine.main_mem_bw
    return "compute-bound" if intensity >= balance else "memory-bound"
