"""Algorithm 3 on the mesh: ring reuse of input shards with overlap.

The paper's Alg 3 replaces main-memory loads of input depth slices with
loads from the neighbouring cluster in the L2 quadrant.  On TPU the
analogue replaces HBM/all-gather traffic with neighbour `ppermute` hops on
the ICI ring, overlapped with the matmul of the currently-resident shard:

  * each device owns one K-shard of the activations (an "input depth
    slice") and the full-K weight columns for its N-shard (its Delta_O
    output stack's filter parameters);
  * at every step it multiplies the resident activation shard against the
    matching weight rows while ppermute-ing the shard to its ring
    neighbour — compute hides the transfer exactly like the paper's
    double-buffered DmaLoad from cluster (CID-1) mod 16.

After P steps every device has accumulated its complete output shard with
zero all-gather traffic; the only collective is P-1 neighbour permutes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.shard_compat import axis_size, shard_map


def ring_matmul_local(x_shard, w_cols, axis: str):
    """Inside shard_map.  x_shard: [M, K/P] (this device's input slice);
    w_cols: [K, N/P] (full-K weight columns for this device's output
    stack).  Returns [M, N/P]."""
    p = axis_size(axis)
    idx = jax.lax.axis_index(axis)
    k_loc = x_shard.shape[1]
    n_loc = w_cols.shape[1]
    perm = [(j, (j + 1) % p) for j in range(p)]

    def step(i, carry):
        acc, xs = carry
        src = (idx - i) % p  # which K block is resident this step
        w_blk = jax.lax.dynamic_slice(w_cols, (src * k_loc, 0), (k_loc, n_loc))
        acc = acc + jnp.dot(xs, w_blk, preferred_element_type=jnp.float32)
        xs = jax.lax.ppermute(xs, axis, perm)  # overlapped with next dot
        return acc, xs

    acc = jnp.zeros((x_shard.shape[0], n_loc), jnp.float32)
    acc, _ = jax.lax.fori_loop(0, p, step, (acc, x_shard))
    return acc.astype(x_shard.dtype)


def ring_matmul(x, w, mesh, axis: str = "model"):
    """O = X @ W with X K-sharded and W N-sharded over ``axis``.
    x: [M, K]; w: [K, N]; out: [M, N] N-sharded."""
    fn = functools.partial(ring_matmul_local, axis=axis)
    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(None, axis), P(None, axis)),
        out_specs=P(None, axis),
        check_vma=False,
    )(x, w)
