"""Algorithm 3 on the mesh: ring reuse of input shards with overlap.

The paper's Alg 3 replaces main-memory loads of input depth slices with
loads from the neighbouring cluster in the L2 quadrant.  On TPU the
analogue replaces HBM/all-gather traffic with neighbour `ppermute` hops on
the ICI ring, overlapped with the matmul of the currently-resident shard:

  * each device owns one K-shard of the activations (an "input depth
    slice") and the full-K weight columns for its N-shard (its Delta_O
    output stack's filter parameters);
  * at every step it multiplies the resident activation shard against the
    matching weight rows while ppermute-ing the shard to its ring
    neighbour — compute hides the transfer exactly like the paper's
    double-buffered DmaLoad from cluster (CID-1) mod 16.

After P steps every device has accumulated its complete output shard with
zero all-gather traffic; the only collective is P-1 neighbour permutes
(the loop body permutes P-1 times; the final step's shard is already
resident — `schedule_sim.simulate_ring` walks exactly this loop).

The partitioning is a *planner output*: :func:`ring_matmul` resolves a
:class:`~repro.plan.ShardedSchedule` through the ``matmul`` pallas_op
(``strategy="ring"``) and executes it via the registry's sharded dispatch,
so the shard_map specs come from ``schedule.partition``, the modeled
words from ``ccr.ring_traffic``, and nothing here is hand-wired.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from repro.core.shard_compat import axis_size


def ring_matmul_local(x_shard, w_cols, axis: str):
    """Inside shard_map.  x_shard: [M, K/P] (this device's input slice);
    w_cols: [K, N/P] (full-K weight columns for this device's output
    stack).  Returns [M, N/P]."""
    p = axis_size(axis)
    idx = jax.lax.axis_index(axis)
    k_loc = x_shard.shape[1]
    n_loc = w_cols.shape[1]
    perm = [(j, (j + 1) % p) for j in range(p)]

    def w_block(step):
        src = (idx - step) % p  # which K block is resident this step
        return jax.lax.dynamic_slice(w_cols, (src * k_loc, 0), (k_loc, n_loc))

    def step(i, carry):
        acc, xs = carry
        acc = acc + jnp.dot(xs, w_block(i), preferred_element_type=jnp.float32)
        xs = jax.lax.ppermute(xs, axis, perm)  # overlapped with next dot
        return acc, xs

    acc = jnp.zeros((x_shard.shape[0], n_loc), jnp.float32)
    # P-1 permute steps, then the last resident shard with no trailing hop
    # (Alg 3's P-1 loads from cluster (CID-1) mod 16).
    acc, xs = jax.lax.fori_loop(0, p - 1, step, (acc, x_shard))
    acc = acc + jnp.dot(xs, w_block(p - 1), preferred_element_type=jnp.float32)
    return acc.astype(x_shard.dtype)


def ring_matmul(x, w, mesh, axis: str = "model", schedule=None):
    """O = X @ W with X K-sharded and W N-sharded over ``axis``.
    x: [M, K]; w: [K, N]; out: [M, N] N-sharded.

    ``schedule`` (a ShardedSchedule) pins the partitioning; by default the
    mesh-aware MatmulPlanner plans it with the ring strategy pinned.
    """
    from repro.plan import get_op

    op = get_op("matmul")
    if schedule is None:
        schedule = op.plan_sharded(x, w, mesh=mesh, axis=axis,
                                   strategy="ring")
    return op.sharded(x, w, schedule=schedule, mesh=mesh)
