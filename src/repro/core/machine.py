"""Machine models: Manticore (the paper's target) and TPU v5e (ours).

The paper's space-complexity arguments (Sections 2.1.2, 2.2.2, 2.3.2, 3.1.2,
3.2.2) are all of the form "working set + DMA double-buffers must fit the
128 KiB cluster scratchpad".  We encode that capacity argument once, here,
parameterized by the machine, so the *same* chooser that reproduces the
paper's Manticore numbers (Delta_O <= 24/12/23/11, D_O <= 768/384) also picks
Pallas BlockSpec block sizes against TPU VMEM.
"""

from __future__ import annotations

import dataclasses

KIB = 1024
MIB = 1024 * 1024


@dataclasses.dataclass(frozen=True)
class MachineModel:
    """Capacity/bandwidth model of one compute unit and its fabric."""

    name: str
    # Fast local memory per compute unit (Manticore: L1 SPM; TPU: VMEM).
    local_mem_bytes: int
    # Bytes reserved per DMA stream to cover main-memory round-trip latency
    # (paper Sec. 2.1.2: 256 cycles x 64 B/cycle = 16 KiB per stream).
    dma_buffer_bytes: int
    # Compute units that can share data over the fast local network
    # (paper: 16 clusters per L2 quadrant; TPU: chips on an ICI ring axis).
    local_group_size: int
    # Peak compute, main-memory BW, and local-link BW (for rooflines).
    peak_flops: float
    main_mem_bw: float
    link_bw: float
    # Number of compute units in one "chip" (Manticore chiplet: 128 clusters).
    units: int = 1
    # Block-size granularity the compute unit wants (TPU MXU/VPU lane width:
    # 128; Manticore clusters have no alignment constraint: 1).  Planners in
    # repro.plan emit blocks in multiples of this.
    lane: int = 1
    # Whether streamed input blocks are double-buffered *inside* the local
    # memory budget (Pallas holds whole blocks in VMEM: True) or flow through
    # the fixed reserved DMA buffers (Manticore's 16 KiB stream buffers,
    # paper Sec. 2.1.2: False — only the working set is charged).
    charge_stream_blocks: bool = True

    def dma_reserve(self, streams: int) -> int:
        """Bytes reserved for ``streams`` double-buffered DMA streams."""
        return streams * self.dma_buffer_bytes

    def usable_for_working_set(self, streams: int) -> int:
        return self.local_mem_bytes - self.dma_reserve(streams)


# The paper's machine (Sec. 1): 128 KiB L1 per cluster, 16 KiB per DMA
# stream buffer, 16 clusters per L2 quadrant, 8 FPUs x 1 dp-MAC/cycle
# (2 sp-MACs/cycle) @ 1 GHz nominal, 512-bit DMA @ 1 GHz into the tree NoC.
MANTICORE = MachineModel(
    name="manticore",
    local_mem_bytes=128 * KIB,
    dma_buffer_bytes=16 * KIB,
    local_group_size=16,
    peak_flops=128 * 8 * 2 * 2 * 1e9,  # chiplet, sp: 128 cl x 8 FPU x 2 MAC x 2 flop
    main_mem_bw=64 * 1e9,  # one 512-bit HBM2E port @ 1 GHz
    link_bw=64 * 1e9,  # 512-bit cluster DMA port @ 1 GHz
    units=128,
    lane=1,
    charge_stream_blocks=False,  # streams ride the reserved 16 KiB buffers
)

# TPU v5e (the adaptation target; constants fixed by the assignment):
# 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s per ICI link.  VMEM is ~128 MiB
# on v5e-class chips but a Pallas kernel should budget well under that; we
# model 64 MiB usable and 4 MiB per double-buffered pipeline stream.
TPU_V5E = MachineModel(
    name="tpu_v5e",
    local_mem_bytes=64 * MIB,
    dma_buffer_bytes=4 * MIB,
    local_group_size=16,  # one axis of a 16x16 pod slice
    peak_flops=197e12,
    main_mem_bw=819e9,
    link_bw=50e9,
    units=1,
    lane=128,
    charge_stream_blocks=True,  # Pallas double-buffers whole blocks in VMEM
)

MACHINES = {m.name: m for m in (MANTICORE, TPU_V5E)}


def machine_named(name: str, default: MachineModel = TPU_V5E) -> MachineModel:
    """The registered MachineModel for a Schedule's ``machine`` name
    (falls back to ``default`` for unregistered names)."""
    return MACHINES.get(name, default)


WORD_BYTES = {"sp": 4, "dp": 8, "bf16": 2, "f32": 4, "f64": 8}


def word_bytes(precision: str) -> int:
    try:
        return WORD_BYTES[precision]
    except KeyError:
        raise ValueError(f"unknown precision {precision!r}") from None
