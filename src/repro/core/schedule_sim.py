"""Word-counting simulators for the paper's Algorithms 1-5.

Each simulator walks the *exact* loop nest of the corresponding pseudocode
(including the software-pipelined prefetch structure, ragged final stacks,
and Alg 3's modulo-16 ring schedule) and tallies every DmaLoad/DmaStore and
inter-cluster transfer in words.  Tests assert these counts equal the
closed forms in :mod:`repro.core.ccr` — i.e. we *validate the paper's
analysis by executing its schedules*.
"""

from __future__ import annotations

import math

from repro.core.ccr import ConvShape, FCShape, Traffic, conv_macs, fc_macs


def simulate_alg1(s: ConvShape) -> Traffic:
    """Algorithm 1: one output depth slice per cluster task."""
    loads = stores = macs = 0
    for _d_o in range(s.D_O):  # parallelize over clusters
        # Prefetch of iteration 0 + in-loop prefetch of d_i+1 together load
        # exactly one input slice + one filter slab per d_i.
        for _d_i in range(s.D_I):
            loads += s.W_I**2  # DmaLoad(I[:,:,d_i])
            loads += s.F**2  # DmaLoad(F[:,:,d_i,d_o])
            macs += s.W_I**2 * s.F**2  # Conv()
        stores += s.W_O**2  # DmaStore(O[:,:,d_o])
    assert macs == conv_macs(s)
    return Traffic(macs=macs, main_loads=loads, main_stores=stores)


def _stacks(D_O: int, stack: int):
    for begin in range(0, D_O, stack):
        yield begin, min(begin + stack, D_O)


def simulate_alg2(s: ConvShape, stack: int) -> Traffic:
    """Algorithm 2: stacks of Delta_O output depth slices per cluster task."""
    loads = stores = macs = 0
    for begin, end in _stacks(s.D_O, stack):  # parallelize over clusters
        for _d_i in range(s.D_I):
            loads += s.W_I**2  # input slice, loaded once per stack
            for _d_o in range(begin, end):
                loads += s.F**2  # filter slab per (d_i, d_o)
                macs += s.W_I**2 * s.F**2
        stores += (end - begin) * s.W_O**2
    assert macs == conv_macs(s)
    return Traffic(macs=macs, main_loads=loads, main_stores=stores)


def simulate_alg2_strip(s: ConvShape, stack: int, h_block: int) -> Traffic:
    """Strip-tiled Algorithm 2 (the Pallas kernel's schedule, DESIGN.md
    Sec. 2): the outer loops walk (strip, stack), the inner loop is the
    paper's ``for d_i``; each strip streams only its halo'd input rows
    (zero-padding rows are free) and re-streams filter slabs, and the
    flush stores the strip of the output stack exactly once."""
    H_O = s.W_O  # square images throughout the paper
    h_in = (h_block - 1) * s.S + s.F
    loads = stores = macs = 0
    for h0 in range(0, H_O, h_block):  # spatial strips
        lo = h0 * s.S - s.P  # first halo'd input row (unpadded coords)
        rows_in = max(0, min(lo + h_in, s.W_I) - max(lo, 0))
        rows_out = min(h_block, H_O - h0)
        for begin, end in _stacks(s.D_O, stack):  # parallelize over clusters
            for _d_i in range(s.D_I):
                loads += rows_in * s.W_I  # halo'd input strip, once per stack
                for _d_o in range(begin, end):
                    loads += s.F**2  # filter slab per (strip, d_i, d_o)
                    macs += rows_out * s.W_I * s.F**2
            stores += (end - begin) * rows_out * s.W_O
    if s.W_O == s.W_I:  # paper convention counts MACs over the input extent
        assert macs == conv_macs(s)
    return Traffic(macs=conv_macs(s), main_loads=loads, main_stores=stores)


def simulate_alg3(s: ConvShape, stack: int, group: int = 16) -> Traffic:
    """Algorithm 3: Alg 2 + ring reuse of input slices inside an L2 quadrant.

    Each task runs on a cluster; CID_in_L2 = CID mod ``group``.  A cluster
    loads input slice ``d`` from main memory iff ``d % group == CID_in_L2``
    (it is that slice's "home"), otherwise from its ring predecessor.
    Faithful to the pseudocode including the wrap-around loop order
    ``d_i <- CID..D_I then 0..CID``.
    """
    loads = stores = macs = inter = 0
    for task, (begin, end) in enumerate(_stacks(s.D_O, stack)):
        cid = task % group  # round-robin placement inside a quadrant
        start = cid % s.D_I if s.D_I else 0
        # Initial load: DmaLoad(I[:,:,CID_in_L2]) from main memory.
        loads += s.W_I**2
        order = list(range(start, s.D_I)) + list(range(0, start))
        for d_i in order:
            d_next = (d_i + 1) % s.D_I
            if d_next != start:  # prefetch next slice
                if d_next % group == cid:
                    loads += s.W_I**2  # home slice: from main memory
                else:
                    inter += s.W_I**2  # from ring predecessor's L1
            for _d_o in range(begin, end):
                loads += s.F**2
                macs += s.W_I**2 * s.F**2
        stores += (end - begin) * s.W_O**2
    assert macs == conv_macs(s)
    return Traffic(macs=macs, main_loads=loads, main_stores=stores, intercluster=inter)


def simulate_conv_dgrad(s: ConvShape, stack: int, h_block: int,
                        batch: int = 1) -> Traffic:
    """Walk the dgrad schedule: the strip-tiled Alg 2 loop nest over the
    transposed geometry (ccr.conv_dgrad_shape — S-dilated gradient in,
    flipped channel-swapped filters, Delta_I output stacking), executed
    once per batch element."""
    from repro.core.ccr import conv_dgrad_shape

    sT = conv_dgrad_shape(s)
    loads = stores = macs = 0
    for _b in range(batch):
        t = simulate_alg2_strip(sT, stack, h_block)
        loads += t.main_loads
        stores += t.main_stores
        macs += t.macs
    return Traffic(macs=macs, main_loads=loads, main_stores=stores)


def simulate_conv_wgrad(s: ConvShape, stack: int, h_block: int,
                        di_block: int = 1, batch: int = 1) -> Traffic:
    """Walk the wgrad kernel's grid (d_i-block, d_o-stack, batch, strip):
    every step streams the halo'd input strip (zero-padding rows free) and
    the gradient strip; the F^2 x Delta_I x Delta_O accumulator stays
    resident across the whole (batch, strip) sweep and flushes exactly
    once at the end."""
    H_O = s.W_O  # square images throughout the paper
    h_in = (h_block - 1) * s.S + s.F
    loads = macs = 0
    for di0 in range(0, s.D_I, di_block):
        ndi = min(di_block, s.D_I - di0)
        for do0 in range(0, s.D_O, stack):
            ndo = min(stack, s.D_O - do0)
            for _b in range(batch):
                for h0 in range(0, H_O, h_block):
                    lo = h0 * s.S - s.P
                    rows_in = max(0, min(lo + h_in, s.W_I) - max(lo, 0))
                    rows_out = min(h_block, H_O - h0)
                    loads += rows_in * s.W_I * ndi   # DmaLoad input strip
                    loads += rows_out * s.W_O * ndo  # DmaLoad gradient strip
                    macs += rows_out * s.W_O * s.F**2 * ndi * ndo
    stores = s.F**2 * s.D_I * s.D_O  # single DmaStore of accumulated dW
    return Traffic(macs=macs, main_loads=loads, main_stores=stores)


def simulate_matmul_blocks(m: int, n: int, k: int,
                           bm: int, bn: int, bk: int) -> Traffic:
    """Walk the blocked-matmul grid (i, j, kk) exactly as the kernel's
    BlockSpecs fetch: an x block (bm x bk) and a w block (bk x bn) per
    step, one (bm x bn) store per (i, j); the walk is over the padded
    problem, as on the device.  The dX kernel is this walk with roles
    (m, n, k) -> (m, k, n); the dW kernel with (k, n, m)."""
    mp = -(-m // bm) * bm
    np_ = -(-n // bn) * bn
    kp = -(-k // bk) * bk
    loads = stores = macs = 0
    for _i in range(mp // bm):
        for _j in range(np_ // bn):
            for _kk in range(kp // bk):
                loads += bm * bk + bk * bn
                macs += bm * bn * bk
            stores += bm * bn
    return Traffic(macs=macs, main_loads=loads, main_stores=stores)


def simulate_conv_im2col(*, H_O: int, W_O: int, F: int, S: int, d_in: int,
                         d_out: int, block_h: int, block_m: int,
                         block_n: int, block_k: int, pool: int = 1,
                         batch: int = 1) -> Traffic:
    """Walk the im2col-GEMM conv schedule strip by strip: each strip of
    ``block_h`` output rows expands into a patch matrix of
    ``batch * rows * W_O`` x ``F*F*d_in`` (every patch word charged —
    the F*F/S^2 read amplification of im2col, zero-padding included) and
    runs the blocked-matmul grid walk against the [F*F*d_in, d_out]
    filter matrix; with ``pool > 1`` the unfused pool epilogue re-reads
    every pool window of the stored conv output and stores the pooled
    plane.  ``ccr.conv_im2col_traffic`` must equal this executed count."""
    k = F * F * d_in
    loads = stores = macs = 0
    for h0 in range(0, H_O, block_h):  # spatial strips, patch matrix per strip
        rows = min(block_h, H_O - h0)
        t = simulate_matmul_blocks(batch * rows * W_O, d_out, k,
                                   block_m, block_n, block_k)
        loads += t.main_loads
        stores += t.main_stores
        macs += t.macs
    if pool > 1:  # unfused pool epilogue over the stored conv output
        for _b in range(batch):
            for _ph in range(H_O // pool):
                for _pw in range(W_O // pool):
                    loads += pool * pool * d_out  # re-read the window
                    stores += d_out  # pooled element per output slice
    return Traffic(macs=macs, main_loads=loads, main_stores=stores)


def simulate_attention_blocks(
    *, seq_q: int, seq_kv: int, head_dim: int, block_q: int, block_kv: int,
    n_q_heads: int = 1, n_kv_heads: int = 1, batch: int = 1,
    causal: bool = False, window: int | None = None,
) -> Traffic:
    """Walk the flash-attention grid (batch*head, q block, kv block)
    applying the kernel's block-level `run` predicate verbatim: causal
    skips KV blocks entirely in the future, a sliding window skips blocks
    entirely before the window.  Counts q/k/v block loads, output stores
    and both matmuls' MACs — AttentionPlanner's closed form must equal
    this executed count.  The skips are real DMA savings on the kernel
    too: its kv BlockSpec clamps the block index into the run range, so
    skipped steps revisit an adjacent block and the pipeline copies
    nothing new (modulo one boundary copy when adjacent q blocks' ranges
    touch)."""
    del n_kv_heads  # GQA shares no HBM traffic: the grid refetches per q head
    sqp = -(-seq_q // block_q) * block_q
    skvp = -(-seq_kv // block_kv) * block_kv
    loads = stores = macs = 0
    for _h in range(batch * n_q_heads):
        for qb in range(sqp // block_q):
            q_start = qb * block_q
            loads += block_q * head_dim  # q block, once per (head, qb)
            for kb in range(skvp // block_kv):
                k_start = kb * block_kv
                run = True
                if causal:  # kernel: k_start <= q_start + block_q - 1
                    run = run and k_start <= q_start + block_q - 1
                if window is not None:  # kernel: block not fully pre-window
                    run = run and k_start + block_kv - 1 > q_start - window
                if run:
                    loads += 2 * block_kv * head_dim  # k and v blocks
                    macs += 2 * block_q * block_kv * head_dim  # qk^T and pv
            stores += block_q * head_dim
    return Traffic(macs=macs, main_loads=loads, main_stores=stores)


# The tree-reduction closed form lives in ccr (the planners charge it as
# ici_words); keep the old private name for the Alg 4/5 walkers below.
from repro.core.ccr import tree_reduce_words as _tree_reduce_words  # noqa: E402


def simulate_ring(*, m: int, n: int, k: int, devices: int) -> Traffic:
    """Walk core/ring.py's Alg-3 ring schedule device by device: each
    device loads its own X shard [m, k/P] and its full-K weight columns
    [k, n/P] from main memory, then runs P multiply steps, permuting the
    resident shard to its ring neighbour after each of the first P-1
    (the last step's shard is already resident — Alg 3's P-1 hops)."""
    if devices <= 0 or k % devices or n % devices:  # as ccr.ring_traffic
        raise ValueError(
            f"ring needs K and N divisible by the mesh: k={k}, n={n}, "
            f"devices={devices}")
    k_loc, n_loc = k // devices, n // devices
    loads = stores = macs = inter = 0
    for _dev in range(devices):
        loads += m * k_loc  # DmaLoad of the device's own input shard
        loads += k * n_loc  # full-K weight columns for its output shard
        for step in range(devices):
            macs += m * n_loc * k_loc  # resident shard @ matching W rows
            if step < devices - 1:
                inter += m * k_loc  # ppermute to ring neighbour
        stores += m * n_loc  # its N-shard of the output
    return Traffic(macs=macs, main_loads=loads, main_stores=stores,
                   intercluster=inter)


def simulate_fc_psum(*, m: int, n: int, k: int, devices: int, block_m: int,
                     block_n: int, block_k: int) -> Traffic:
    """Walk the sharded FC "psum" strategy: every device executes the
    blocked-matmul grid on its K-shard (simulate_matmul_blocks), then the
    private [m, n] partial outputs merge by pairwise tree reduction.
    Devices are symmetric, so one device's grid is walked and scaled."""
    t = simulate_matmul_blocks(m, n, k // devices, block_m, block_n,
                               block_k)
    inter = _tree_reduce_words(devices, m * n)
    return Traffic(macs=devices * t.macs, main_loads=devices * t.main_loads,
                   main_stores=devices * t.main_stores, intercluster=inter)


def simulate_tp_matmul(*, m: int, n: int, k: int, devices: int, block_m: int,
                       block_n: int, block_k: int) -> Traffic:
    """Walk the tensor-parallel (megatron column-split) matmul device by
    device: each device runs the blocked-matmul grid on its [k, n/P]
    weight columns (simulate_matmul_blocks), then ring-all-gathers its
    private [m, n/P] activation shard — P - 1 hops per device, each
    moving the m * n/P shard.  == ccr.tp_matmul_traffic (the gather's
    total (P-1) * m * n words match the tree form exactly)."""
    if devices <= 0 or n % devices:  # as ccr.tp_matmul_traffic
        raise ValueError(
            f"tp needs N divisible by the mesh: n={n}, devices={devices}")
    n_loc = n // devices
    loads = stores = macs = inter = 0
    for _dev in range(devices):
        t = simulate_matmul_blocks(m, n_loc, k, block_m, block_n, block_k)
        loads += t.main_loads
        stores += t.main_stores
        macs += t.macs
        for _step in range(devices - 1):
            inter += m * n_loc  # ppermute its shard around the ring
    return Traffic(macs=macs, main_loads=loads, main_stores=stores,
                   intercluster=inter)


def simulate_moe_all_to_all(*, tokens: int, d_model: int, top_k: int,
                            n_experts: int, devices: int) -> int:
    """Walk the expert-parallel dispatch literally: for every device, for
    every routed row (tokens/P rows * top_k routes, spread evenly over
    the experts by the balanced slot-major dispatch), find the expert's
    owner device (experts are contiguously sharded E/P per device, as in
    models/moe.py's ``e_offset = axis_index * n_local``); a remote row
    crosses the interconnect twice (d_model out, d_model back).
    == ccr.moe_all_to_all_words."""
    if devices <= 0 or tokens % devices:
        raise ValueError(f"ep needs tokens divisible by the mesh: "
                         f"tokens={tokens}, devices={devices}")
    if n_experts % devices:
        raise ValueError(f"ep needs experts divisible by the mesh: "
                         f"n_experts={n_experts}, devices={devices}")
    t_loc = tokens // devices
    if (t_loc * top_k) % n_experts:
        raise ValueError(
            f"balanced dispatch needs local routed rows divisible by the "
            f"experts: tokens/P * top_k = {t_loc * top_k}, "
            f"n_experts={n_experts}")
    rows_per_expert = t_loc * top_k // n_experts
    e_local = n_experts // devices
    inter = 0
    for p in range(devices):
        for e in range(n_experts):
            owner = e // e_local
            if owner != p:
                for _row in range(rows_per_expert):
                    inter += 2 * d_model  # dispatch out + FFN result back
    return inter


def simulate_sharded_conv_strip(s: ConvShape, stack: int, h_block: int, *,
                                devices: int, strategy: str = "batch",
                                batch: int = 1) -> Traffic:
    """Walk the sharded strip-tiled conv forward: under "batch" each device
    runs the full simulate_alg2_strip nest on its batch/devices images;
    under "stack" each device owns D_O/devices output slices and walks the
    nest on that local depth.  No interconnect words move (forward data
    parallelism; the backward wgrad pays the tree reduction).  One
    (device, image) nest is walked and scaled — every iteration of the
    symmetric outer loops is identical."""
    import dataclasses as _dc

    if strategy == "batch":
        if batch % devices:
            raise ValueError(f"batch {batch} not divisible by {devices}")
        t = simulate_alg2_strip(s, stack, h_block)
        n = batch  # devices * (batch // devices) identical image walks
    elif strategy == "stack":
        if s.D_O % devices:
            raise ValueError(f"D_O {s.D_O} not divisible by {devices}")
        sl = _dc.replace(s, D_O=s.D_O // devices)
        t = simulate_alg2_strip(sl, min(stack, sl.D_O), h_block)
        n = devices * batch
    else:
        raise ValueError(strategy)
    return Traffic(macs=n * t.macs, main_loads=n * t.main_loads,
                   main_stores=n * t.main_stores)


def simulate_alg4(s: FCShape, clusters: int = 128) -> Traffic:
    """Algorithm 4: input depth slices parallel over clusters, private
    outputs, tree reduction."""
    loads = stores = macs = 0
    for _d_i in range(s.D_I):  # parallelize over clusters
        loads += s.W_I**2 * s.B  # DmaLoad(I[:,:,d_i,:]) - whole batch
        for _d_o in range(s.D_O):
            loads += s.W_I**2  # DmaLoad(F[:,:,d_i,d_o])
            for _b in range(s.B):
                macs += s.W_I**2  # ElemMac()
    inter = _tree_reduce_words(clusters, s.D_O * s.B)
    stores = s.D_O * s.B  # one cluster stores O
    assert macs == fc_macs(s)
    return Traffic(macs=macs, main_loads=loads, main_stores=stores, intercluster=inter)


def simulate_alg5(s: FCShape, stack: int, clusters: int = 128) -> Traffic:
    """Algorithm 5: outer loop over output stacks, Alg 4 inside."""
    loads = stores = macs = inter = 0
    for begin, end in _stacks(s.D_O, stack):
        for _d_i in range(s.D_I):  # parallelize over clusters
            loads += s.W_I**2 * s.B
            for _d_o in range(begin, end):
                loads += s.W_I**2
                macs += s.W_I**2 * s.B
        inter += _tree_reduce_words(clusters, (end - begin) * s.B)
        stores += (end - begin) * s.B
    assert macs == fc_macs(s)
    return Traffic(macs=macs, main_loads=loads, main_stores=stores, intercluster=inter)


# ---------------------------------------------------------------------------
# Critical-path step walkers (the overlap-aware cost axis).  Each walks the
# literal sequential loop structure of the kernel's software pipeline and
# counts steps; tests assert the counts equal the ccr closed forms.
# ---------------------------------------------------------------------------


def simulate_grid_steps(grid) -> int:
    """Walk a plain software-pipelined grid point by point: every grid
    point is one sequential step, plus the pipeline-fill fetch before the
    first compute.  == ccr.grid_steps."""
    import itertools

    steps = 1  # pipeline fill: the first fetch overlaps no compute
    for _pt in itertools.product(*(range(g) for g in grid)):
        steps += 1
    return steps


def simulate_conv_dgrad_fused_steps(*, H_I: int, d_in: int, block_h: int,
                                    block_do: int, batch: int = 1) -> int:
    """Walk the fused-epilogue dgrad pipeline: one mask-scatter prologue
    step, one double-buffer warm-up fetch, then one step per
    (batch, dX strip, dX stack) grid point — the d_out stream is folded
    inside each step by the overlapped DMA loop, so it adds no sequential
    steps.  == ccr.conv_dgrad_fused_steps."""
    steps = 1  # scatter prologue: pooled dY + mask -> full-rate dY
    steps += 1  # pipeline fill: warm-up fetch of the first d_out slab
    for _b in range(batch):
        for _h0 in range(0, H_I, block_h):
            for _do0 in range(0, d_in, block_do):
                steps += 1
    return steps


def simulate_conv_wgrad_steps(*, H_O: int, d_in: int, d_out: int,
                              block_h: int, block_di: int, block_do: int,
                              batch: int = 1,
                              pipelined: bool = False) -> int:
    """Walk the wgrad grid: direct runs every (d_i, d_o, batch, strip)
    point sequentially; pipelined folds the (batch, strip) accumulation
    sweep into each (d_i, d_o) step behind double-buffered strip DMA.
    == ccr.conv_wgrad_steps."""
    steps = 1  # pipeline fill
    for _di0 in range(0, d_in, block_di):
        for _do0 in range(0, d_out, block_do):
            if pipelined:
                steps += 1  # (batch, strip) sweep hidden inside the step
            else:
                for _b in range(batch):
                    for _h0 in range(0, H_O, block_h):
                        steps += 1
    return steps


def simulate_epilogue_scatter(*, H_O: int, W_O: int, d_out: int, pool: int,
                              batch: int = 1, in_bytes: int = 4) -> Traffic:
    """Walk the fused epilogue VJP's scatter: per pooled output pixel read
    the pooled gradient element, route it to the argmax position of its
    pool window (zeros elsewhere), store the full pool*pool window of the
    full-rate dY; the int8 mask is read once, packed in_bytes per word.
    == ccr.epilogue_scatter_traffic."""
    loads = stores = 0
    for _b in range(batch):
        for _ph in range(H_O // pool):
            for _pw in range(W_O // pool):
                loads += d_out  # pooled gradient element per slice
                for _py in range(pool):
                    for _px in range(pool):
                        stores += d_out  # scattered full-rate dY
    pooled = batch * (H_O // pool) * (W_O // pool) * d_out
    loads += -(-pooled // in_bytes)  # int8 mask, in_bytes packed per word
    return Traffic(macs=0, main_loads=loads, main_stores=stores)


def n_stacks(D_O: int, stack: int) -> int:
    return math.ceil(D_O / stack)
