"""Version-compatibility shims for ``jax.sharding`` across jax releases
(same pattern as ``kernels/pallas_compat.py``).

Newer jax exposes ``jax.sharding.AxisType`` and the ``axis_types=`` kwarg
on ``jax.make_mesh``; jax 0.4.x has neither (every mesh axis is implicitly
Auto there, which is exactly what this repo requests).  Route all mesh
construction through :func:`make_auto_mesh` so both vintages work.
"""

from __future__ import annotations

import jax

AxisType = getattr(jax.sharding, "AxisType", None)

# jax.shard_map landed as a top-level API after 0.4.x; before that it lives
# in jax.experimental.shard_map, and its replication-check kwarg is spelled
# check_rep instead of check_vma.
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:  # pragma: no cover - exercised on jax 0.4.x only
    from jax.experimental.shard_map import shard_map as _shard_map_old

    def _shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_vma)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    """``jax.shard_map`` on new jax, ``jax.experimental.shard_map`` (with
    ``check_vma`` -> ``check_rep``) on 0.4.x."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)


def axis_size(axis_name) -> int:
    """``jax.lax.axis_size`` where it exists; the classic ``psum(1, axis)``
    idiom (which constant-folds to a Python int) on 0.4.x."""
    f = getattr(jax.lax, "axis_size", None)
    if f is not None:
        return f(axis_name)
    return jax.lax.psum(1, axis_name)


def auto_axis_types(n_axes: int) -> dict:
    """``axis_types`` kwargs for ``n_axes`` Auto mesh axes ({} when this
    jax predates AxisType — Auto is its only behavior)."""
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_auto_mesh(shape, axes):
    """``jax.make_mesh`` with every axis in Auto sharding mode."""
    return jax.make_mesh(shape, axes, **auto_axis_types(len(axes)))
