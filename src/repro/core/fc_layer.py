"""The paper's fully-connected layer as a composable, differentiable module.

Single-device: the Alg 4/5 Pallas kernel (output stacking = block_n, K-loop
accumulator = the private partial output).  Distributed ("alg4_sharded"):
the input-depth dimension is sharded over a mesh axis and each device's
private partial output is combined by one psum — the paper's tree
reduction, lowered to the ICI collective.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.core import ccr
from repro.core.machine import MANTICORE
from repro.kernels.matmul.ops import fc_matmul
from repro.kernels.matmul.ref import fc_matmul_ref
from repro.plan import Schedule, with_reference_vjp
from repro.core.shard_compat import shard_map


def _fc_kernel(x, w, schedule):
    return fc_matmul(x, w, schedule=schedule)


def _fc_ref(x, w, schedule):
    del schedule  # blocking never changes numerics
    return fc_matmul_ref(x, w)


_fc_layer_vjp = with_reference_vjp(_fc_kernel, _fc_ref, nondiff_argnums=(2,))


def fc_layer(x, w, schedule: Schedule | None = None):
    """x: [..., K]; w: [K, D_O].  Forward = Pallas Alg 4/5 kernel; the
    MatmulPlanner picks blocks unless an explicit ``schedule`` is given."""
    return _fc_layer_vjp(x, w, schedule)


def plan(x_shape, w_shape, *, in_bytes=4, machine=None) -> Schedule:
    """Plan this layer without running it (see conv_layer.plan)."""
    from repro.core.machine import TPU_V5E
    from repro.plan import MatmulPlanner

    m = 1
    for d in x_shape[:-1]:
        m *= d
    k, n = w_shape
    return MatmulPlanner(machine or TPU_V5E).plan(m=m, n=n, k=k, in_bytes=in_bytes)


def fc_layer_sharded(x, w, mesh, axis: str = "model"):
    """Alg 4 over a mesh axis: K (input depth) sharded, psum of private
    partial outputs.  x: [M, K]; w: [K, N]; returns [M, N] replicated."""

    def fn(xl, wl):
        return jax.lax.psum(xl @ wl, axis)

    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(None, axis), P(axis, None)),
        out_specs=P(None, None),
        check_vma=False,
    )(x, w)


def traffic(
    shape: ccr.FCShape, strategy: str = "alg5", precision: str = "sp",
    machine=MANTICORE, clusters: int = 128,
) -> ccr.Traffic:
    if strategy == "alg4":
        return ccr.alg4_traffic(shape, clusters)
    if strategy == "alg5":
        stack = max(1, ccr.alg45_max_stack(shape, machine, precision))
        return ccr.alg5_traffic(shape, min(stack, shape.D_O), clusters)
    raise ValueError(strategy)
