"""The paper's fully-connected layer as a composable, differentiable module.

Single-device: the Alg 4/5 Pallas kernel (output stacking = block_n, K-loop
accumulator = the private partial output).  Distributed ("alg4_sharded"):
the input-depth dimension is sharded over a mesh axis and each device's
private partial output is combined by one psum — the paper's tree
reduction, lowered to the ICI collective.

Backward is planned too (DESIGN.md Sec. 4): ``jax.grad`` runs the
``matmul_dx`` kernel (dX = dY @ W^T, contraction on N, no W^T in HBM) and
the ``matmul_dw`` kernel (dW = X^T @ dY, batch streams as the
contraction), each scheduled by its own planner — override with
``bwd_schedules={"dx": ..., "dw": ...}`` (see :func:`plan_bwd`); the XLA
reference VJP remains the fallback when a schedule does not fit and the
parity oracle in tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import ccr
from repro.core.machine import MANTICORE, TPU_V5E, machine_named
from repro.kernels.matmul.bwd import matmul_dw, matmul_dx
from repro.kernels.matmul.ops import fc_matmul
from repro.kernels.matmul.ref import fc_matmul_ref
from repro.plan import Schedule, freeze_schedules, get_op, with_reference_vjp
from repro.core.shard_compat import shard_map

# The machine backward schedules are planned (and fit-checked) against.
_BWD_MACHINE = TPU_V5E


def _fc_kernel(x, w, schedule, bwd_schedules):
    del bwd_schedules  # consumed by the backward pass
    return fc_matmul(x, w, schedule=schedule)


def _fc_ref(x, w, schedule, bwd_schedules):
    del schedule, bwd_schedules  # blocking never changes numerics
    return fc_matmul_ref(x, w)


def _fc_bwd(x, w, g, schedule, bwd_schedules):
    del schedule
    sd = dict(bwd_schedules or ())
    s_dx = sd.get("dx") or get_op("matmul_dx").plan(g, w)
    s_dw = sd.get("dw") or get_op("matmul_dw").plan(x, g)
    # Fit-check each schedule against the machine it was planned for.
    if not (s_dx.fits(machine_named(s_dx.machine, _BWD_MACHINE))
            and s_dw.fits(machine_named(s_dw.machine, _BWD_MACHINE))):
        _, vjp = jax.vjp(fc_matmul_ref, x, w)  # XLA reference fallback
        return vjp(g)
    dx = matmul_dx(g, w, schedule=s_dx, out_dtype=jnp.float32).astype(x.dtype)
    dw = matmul_dw(x, g, schedule=s_dw, out_dtype=jnp.float32).astype(w.dtype)
    return dx, dw


_fc_layer_vjp = with_reference_vjp(_fc_kernel, _fc_ref, nondiff_argnums=(2, 3),
                                   bwd_fn=_fc_bwd)


def fc_layer(x, w, schedule: Schedule | None = None, bwd_schedules=None):
    """x: [..., K]; w: [K, D_O].  Forward = Pallas Alg 4/5 kernel; the
    MatmulPlanner picks blocks unless an explicit ``schedule`` is given.
    ``bwd_schedules`` ({"dx"/"dw": Schedule}) pins the planned backward
    kernels' blocking (see :func:`plan_bwd`)."""
    return _fc_layer_vjp(x, w, schedule, freeze_schedules(bwd_schedules))


def plan(x_shape, w_shape, *, in_bytes=4, machine=None) -> Schedule:
    """Plan this layer without running it (see conv_layer.plan)."""
    from repro.core.machine import TPU_V5E
    from repro.plan import MatmulPlanner

    m = 1
    for d in x_shape[:-1]:
        m *= d
    k, n = w_shape
    return MatmulPlanner(machine or TPU_V5E).plan(m=m, n=n, k=k, in_bytes=in_bytes)


def plan_bwd(x_shape, w_shape, *, in_bytes=4, machine=None) -> dict[str, Schedule]:
    """Backward-pass Schedules for this layer's shapes: the dX and dW
    kernels ``jax.grad`` will run.  Pass back via ``bwd_schedules=`` to
    pin the blocking."""
    from repro.plan import MatmulDwPlanner, MatmulDxPlanner

    machine = machine or _BWD_MACHINE
    m = 1
    for d in x_shape[:-1]:
        m *= d
    k, n = w_shape
    return {
        "dx": MatmulDxPlanner(machine).plan(m=m, n=n, k=k, in_bytes=in_bytes),
        "dw": MatmulDwPlanner(machine).plan(m=m, n=n, k=k, in_bytes=in_bytes),
    }


def fc_layer_sharded(x, w, mesh, axis: str = "model"):
    """Alg 4 over a mesh axis: K (input depth) sharded, psum of private
    partial outputs.  x: [M, K]; w: [K, N]; returns [M, N] replicated."""

    def fn(xl, wl):
        return jax.lax.psum(xl @ wl, axis)

    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(None, axis), P(axis, None)),
        out_specs=P(None, None),
        check_vma=False,
    )(x, w)


def traffic(
    shape: ccr.FCShape, strategy: str = "alg5", precision: str = "sp",
    machine=MANTICORE, clusters: int = 128,
) -> ccr.Traffic:
    if strategy == "alg4":
        return ccr.alg4_traffic(shape, clusters)
    if strategy == "alg5":
        stack = max(1, ccr.alg45_max_stack(shape, machine, precision))
        return ccr.alg5_traffic(shape, min(stack, shape.D_O), clusters)
    raise ValueError(strategy)
