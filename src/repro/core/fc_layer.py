"""The paper's fully-connected layer as a composable, differentiable module.

Single-device: the Alg 4/5 Pallas kernel (output stacking = block_n, K-loop
accumulator = the private partial output).  Distributed: the partitioning
is a *planner output* — :func:`fc_layer_sharded` resolves a
:class:`repro.plan.ShardedSchedule` through the ``matmul`` pallas_op and
the registry's sharded dispatch executes it ("psum": input depth sharded,
private partial outputs combined by the Alg-4 tree reduction lowered to
one psum; "ring": Alg 3's neighbour-permute reuse, core/ring.py; the
planner picks by modeled HBM+ICI words unless ``strategy=`` pins one).

Backward is planned too (DESIGN.md Sec. 4): ``jax.grad`` runs the
``matmul_dx`` kernel (dX = dY @ W^T, contraction on N, no W^T in HBM) and
the ``matmul_dw`` kernel (dW = X^T @ dY, batch streams as the
contraction), each scheduled by its own planner — override with
``bwd_schedules={"dx": ..., "dw": ...}`` (see :func:`plan_bwd`); the XLA
reference VJP remains the fallback when a schedule does not fit and the
parity oracle in tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ccr
from repro.core.conv_layer import warn_unfit_schedule
from repro.core.machine import MANTICORE, TPU_V5E, machine_named
from repro.kernels.matmul.bwd import matmul_dw, matmul_dx, matmul_dx_dw
from repro.kernels.matmul.ops import fc_matmul
from repro.kernels.matmul.ref import fc_matmul_ref
from repro.plan import (
    Schedule, ShardedSchedule, freeze_schedules, get_op, local_schedule,
    with_reference_vjp,
)

# The machine backward schedules are planned (and fit-checked) against.
_BWD_MACHINE = TPU_V5E


def _fc_kernel(x, w, schedule, bwd_schedules):
    del bwd_schedules  # consumed by the backward pass
    return fc_matmul(x, w, schedule=schedule)


def _fc_ref(x, w, schedule, bwd_schedules):
    del schedule, bwd_schedules  # blocking never changes numerics
    return fc_matmul_ref(x, w)


def _fc_bwd(x, w, g, schedule, bwd_schedules):
    del schedule
    sd = dict(bwd_schedules or ())
    s_dx = local_schedule(sd.get("dx")) or get_op("matmul_dx").plan(g, w)
    s_dw = local_schedule(sd.get("dw")) or get_op("matmul_dw").plan(x, g)
    # Fit-check each schedule against the machine it was planned for; an
    # unfit pin drops to the XLA reference, loudly on the first cell.
    m_dx = machine_named(s_dx.machine, _BWD_MACHINE)
    m_dw = machine_named(s_dw.machine, _BWD_MACHINE)
    if not s_dx.fits(m_dx):
        warn_unfit_schedule("dx", s_dx, m_dx)
    if not s_dw.fits(m_dw):
        warn_unfit_schedule("dw", s_dw, m_dw)
    if not (s_dx.fits(m_dx) and s_dw.fits(m_dw)):
        _, vjp = jax.vjp(fc_matmul_ref, x, w)  # XLA reference fallback
        return vjp(g)
    if getattr(s_dx, "algorithm", None) == "fused_dxdw":
        # One kernel, one dY stream for both gradients: the fused dX
        # schedule carries the combined cost model (including the whole-M
        # dX accumulator), so the fits() gate above already covered it.
        dx, dw = matmul_dx_dw(g, w, x, schedule=s_dx, out_dtype=jnp.float32)
        return dx.astype(x.dtype), dw.astype(w.dtype)
    dx = matmul_dx(g, w, schedule=s_dx, out_dtype=jnp.float32).astype(x.dtype)
    dw = matmul_dw(x, g, schedule=s_dw, out_dtype=jnp.float32).astype(w.dtype)
    return dx, dw


_fc_layer_vjp = with_reference_vjp(_fc_kernel, _fc_ref, nondiff_argnums=(2, 3),
                                   bwd_fn=_fc_bwd)


def fc_layer(x, w, schedule: Schedule | ShardedSchedule | None = None,
             bwd_schedules=None):
    """x: [..., K]; w: [K, D_O].  Forward = Pallas Alg 4/5 kernel; the
    MatmulPlanner picks blocks unless an explicit ``schedule`` is given
    (a ShardedSchedule contributes its per-device local blocking).
    ``bwd_schedules`` ({"dx"/"dw": Schedule}) pins the planned backward
    kernels' blocking (see :func:`plan_bwd`)."""
    return _fc_layer_vjp(x, w, local_schedule(schedule),
                         freeze_schedules(bwd_schedules))


def _fc_m(x_shape) -> int:
    m = 1
    for d in x_shape[:-1]:
        m *= d
    return m


def plan(x_shape, w_shape, *, in_bytes=4, machine=None, mesh=None,
         shard_axis="model", strategy=None, autotune=None):
    """Plan this layer without running it (see conv_layer.plan).  With
    ``mesh=`` the returned ShardedSchedule also carries the device
    partitioning and the HBM/ICI word split.  ``autotune=`` lets a
    measured winner for this cell override the modeled argmin."""
    from repro.core.machine import TPU_V5E
    from repro.plan import autotune as at

    k, n = w_shape
    return at.resolve(
        "matmul", dict(m=_fc_m(x_shape), n=n, k=k, in_bytes=in_bytes),
        machine=machine or TPU_V5E, mesh=mesh, axis=shard_axis,
        strategy=strategy, policy=autotune)


def plan_bwd(x_shape, w_shape, *, in_bytes=4, machine=None, mesh=None,
             shard_axis="data", autotune=None) -> dict:
    """Backward-pass Schedules for this layer's shapes: the dX and dW
    kernels ``jax.grad`` will run.  Pass back via ``bwd_schedules=`` to
    pin the blocking.  The "dx" cell prefers the fused dX/dW kernel
    (``algorithm="fused_dxdw"``: both gradients from one kernel sharing
    the single dY read — ``_fc_bwd`` dispatches on the tag and the "dw"
    schedule goes unused at run time) and falls back to the direct
    variant when the fused whole-M accumulator overflows the machine.
    With ``mesh=`` both come back as ShardedSchedules (dX shards with the
    batch; dW additionally charges the Alg-4 tree reduction of the weight
    gradient as ici_words).  Both cells honor the ``autotune=`` policy
    like the forward."""
    from repro.plan import autotune as at

    machine = machine or _BWD_MACHINE
    m = _fc_m(x_shape)
    k, n = w_shape
    shape = dict(m=m, n=n, k=k, in_bytes=in_bytes)

    def res(op, **extra):
        return at.resolve(op, dict(shape, **extra), machine=machine,
                          mesh=mesh, axis=shard_axis, policy=autotune)

    dx = res("matmul_dx", algorithm="fused_dxdw")
    if not local_schedule(dx).fits(machine):
        dx = res("matmul_dx")
    return {"dx": dx, "dw": res("matmul_dw")}


def fc_layer_sharded(x, w, mesh, axis: str = "model",
                     schedule: ShardedSchedule | None = None,
                     strategy: str | None = "psum",
                     machine=None, autotune=None):
    """The FC layer across a mesh axis, partitioned by the planner.

    x: [M, K]; w: [K, N]; returns the global [M, N].  The default pins the
    paper's Alg 4 ("psum": K sharded, one psum of private partial
    outputs); ``strategy=None`` lets the mesh-aware MatmulPlanner choose
    between psum and the Alg-3 ring by modeled HBM+ICI words; an explicit
    ``schedule`` (from :func:`plan` with ``mesh=``) overrides planning
    entirely.  Execution goes through the ``matmul`` op's registered
    sharded impl — the shard_map specs come from ``schedule.partition``.
    Under an active ``autotune`` policy (argument or process-wide), a
    measured winner cached for this ``(op, shapes, machine, mesh)`` cell
    silently replaces the modeled pick.
    """
    op = get_op("matmul")
    if schedule is None:
        schedule = op.plan_sharded(x, w, mesh=mesh, axis=axis,
                                   strategy=strategy,
                                   machine=machine or TPU_V5E,
                                   autotune=autotune)
    return op.sharded(x, w, schedule=schedule, mesh=mesh)


def traffic(
    shape: ccr.FCShape, strategy: str = "alg5", precision: str = "sp",
    machine=MANTICORE, clusters: int = 128,
) -> ccr.Traffic:
    if strategy == "alg4":
        return ccr.alg4_traffic(shape, clusters)
    if strategy == "alg5":
        stack = max(1, ccr.alg45_max_stack(shape, machine, precision))
        return ccr.alg5_traffic(shape, min(stack, shape.D_O), clusters)
    raise ValueError(strategy)
