"""The paper's fully-connected layer as a composable, differentiable module.

Single-device: the Alg 4/5 Pallas kernel (output stacking = block_n, K-loop
accumulator = the private partial output).  Distributed ("alg4_sharded"):
the input-depth dimension is sharded over a mesh axis and each device's
private partial output is combined by one psum — the paper's tree
reduction, lowered to the ICI collective.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import ccr
from repro.core.machine import MANTICORE
from repro.kernels.matmul.ops import fc_matmul
from repro.kernels.matmul.ref import fc_matmul_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def fc_layer(x, w):
    """x: [..., K]; w: [K, D_O].  Forward = Pallas Alg 4/5 kernel."""
    return fc_matmul(x, w)


def _fwd(x, w):
    return fc_layer(x, w), (x, w)


def _bwd(res, g):
    x, w = res
    _, vjp = jax.vjp(fc_matmul_ref, x, w)
    return vjp(g)


fc_layer.defvjp(_fwd, _bwd)


def fc_layer_sharded(x, w, mesh, axis: str = "model"):
    """Alg 4 over a mesh axis: K (input depth) sharded, psum of private
    partial outputs.  x: [M, K]; w: [K, N]; returns [M, N] replicated."""

    def fn(xl, wl):
        return jax.lax.psum(xl @ wl, axis)

    return jax.shard_map(
        fn, mesh=mesh,
        in_specs=(P(None, axis), P(axis, None)),
        out_specs=P(None, None),
        check_vma=False,
    )(x, w)


def traffic(
    shape: ccr.FCShape, strategy: str = "alg5", precision: str = "sp",
    machine=MANTICORE, clusters: int = 128,
) -> ccr.Traffic:
    if strategy == "alg4":
        return ccr.alg4_traffic(shape, clusters)
    if strategy == "alg5":
        stack = max(1, ccr.alg45_max_stack(shape, machine, precision))
        return ccr.alg5_traffic(shape, min(stack, shape.D_O), clusters)
    raise ValueError(strategy)
