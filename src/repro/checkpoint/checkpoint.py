"""Fault-tolerant sharded checkpointing.

Layout (one directory per step, atomic via tmp-dir + rename + COMMIT marker):

    ckpt/step_0000012/
      index.json              tree structure + per-leaf chunk table
      <leaf>.c00.npy ...      chunks split along axis 0 (one per saver shard)
      COMMIT                  written last; restore ignores dirs without it

Chunking along axis 0 makes restore *resharding-capable*: a checkpoint
written by N hosts restores onto M devices with any sharding — each leaf is
reassembled lazily from its chunks (np.memmap) inside
``jax.make_array_from_callback``, so each device only materializes its own
slice.  This is the restart path for elastic re-meshing after node failure
(runtime/fault_tolerance.py).

Integrity: every chunk's sha256 (of the on-disk ``.npy`` bytes) is recorded
in ``index.json`` and re-checked on restore, so a torn write from a host
that died mid-flush surfaces as :class:`CheckpointCorruptError` instead of
silently restoring garbage — :func:`restore_latest` then falls back to the
previous committed step (logged, never silent).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import re
import shutil
import threading
import warnings

import jax
import jax.numpy as jnp
import numpy as np

_SAFE = re.compile(r"[^A-Za-z0-9_.-]")


class CheckpointError(RuntimeError):
    """Base class for checkpoint failures."""


class CheckpointCorruptError(CheckpointError):
    """A chunk file is missing, torn, or fails its sha256 digest."""


class CheckpointMismatchError(CheckpointError):
    """The checkpoint's tree doesn't match the abstract tree being
    restored (missing leaf or shape mismatch) — unlike a bare ``assert``
    this survives ``python -O``."""


def _sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def _leaf_paths(tree):
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for kp, leaf in paths:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))) for k in kp
        )
        out.append((name, leaf))
    return out


def _fname(leaf_path: str, chunk: int) -> str:
    return f"{_SAFE.sub('_', leaf_path)}.c{chunk:02d}.npy"


def save(ckpt_dir: str, step: int, tree, n_chunks: int = 1) -> str:
    """Write a checkpoint; returns the final directory path."""
    final = os.path.join(ckpt_dir, f"step_{step:07d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    index = {"step": step, "leaves": {}}
    for path, leaf in _leaf_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = jnp.dtype(arr.dtype).name
        bits = arr.dtype.kind not in "fiub" or logical_dtype == "bfloat16"
        if bits:  # ml_dtypes (bf16/f8) don't survive np memmap casts
            arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
        chunks = []
        n = max(1, min(n_chunks, arr.shape[0] if arr.ndim else 1))
        splits = np.array_split(np.arange(arr.shape[0] if arr.ndim else 1), n)
        off = 0
        for ci, idx in enumerate(splits):
            if arr.ndim:
                part = arr[idx[0] : idx[-1] + 1] if len(idx) else arr[0:0]
            else:
                part = arr
            fn = _fname(path, ci)
            np.save(os.path.join(tmp, fn), part)
            chunks.append({"file": fn, "offset": off,
                           "rows": int(len(idx)) if arr.ndim else 1,
                           "sha256": _sha256_file(os.path.join(tmp, fn))})
            off += len(idx) if arr.ndim else 1
        index["leaves"][path] = {
            "shape": list(arr.shape),
            "dtype": logical_dtype,
            "bits": bits,
            "chunks": chunks,
        }

    with open(os.path.join(tmp, "index.json"), "w") as f:
        json.dump(index, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


@dataclasses.dataclass
class AsyncSave:
    """Handle for a background save.  ``join()`` re-raises anything the
    writer thread hit (a silently-dropped IO error here means the next
    restore finds no checkpoint where the trainer believes one exists)."""

    step: int
    _thread: threading.Thread
    _exc: list = dataclasses.field(default_factory=list)
    path: str | None = None

    def join(self, timeout: float | None = None) -> str | None:
        self._thread.join(timeout)
        if self._thread.is_alive():
            raise TimeoutError(f"save of step {self.step} still running")
        if self._exc:
            raise self._exc[0]
        return self.path

    def is_alive(self) -> bool:
        return self._thread.is_alive()


def save_async(ckpt_dir: str, step: int, tree, n_chunks: int = 1) -> AsyncSave:
    """Device-get on the caller thread (cheap on CPU; on TPU this is the
    copy-out), file IO on a background thread.  The returned handle's
    ``join()`` re-raises background failures instead of swallowing them."""
    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    handle = AsyncSave(step=step, _thread=None)  # type: ignore[arg-type]

    def _run():
        try:
            handle.path = save(ckpt_dir, step, host_tree, n_chunks)
        except BaseException as e:  # re-raised from join()
            handle._exc.append(e)

    t = threading.Thread(target=_run, daemon=True)
    handle._thread = t
    t.start()
    return handle


def _committed(ckpt_dir: str, d: str) -> bool:
    """A step dir counts only if COMMIT exists AND index.json parses — a
    COMMIT with an unreadable index (partial rename, disk fault) must not
    be offered as the resume point."""
    if not os.path.exists(os.path.join(ckpt_dir, d, "COMMIT")):
        return False
    try:
        with open(os.path.join(ckpt_dir, d, "index.json")) as f:
            json.load(f)
        return True
    except (OSError, json.JSONDecodeError):
        return False


def committed_steps(ckpt_dir: str) -> list[int]:
    """All restorable steps, ascending (COMMIT present, index readable)."""
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(
        int(m.group(1))
        for d in os.listdir(ckpt_dir)
        if (m := re.fullmatch(r"step_(\d+)", d)) and _committed(ckpt_dir, d)
    )


def latest_step(ckpt_dir: str) -> int | None:
    steps = committed_steps(ckpt_dir)
    return steps[-1] if steps else None


def _read_leaf(step_dir: str, meta: dict, np_dtype) -> np.ndarray:
    """Reassemble a leaf lazily; returns a callable slicer to avoid
    materializing the full array when only a shard is needed."""
    mms = []
    for ch in meta["chunks"]:
        mms.append((ch["offset"], np.load(os.path.join(step_dir, ch["file"]), mmap_mode="r")))
    shape = tuple(meta["shape"])
    bits = meta.get("bits", False)

    def _cast(a: np.ndarray) -> np.ndarray:
        if bits:
            return np.asarray(a).view(np_dtype)
        return np.asarray(a).astype(np_dtype, copy=False)

    def read(index: tuple[slice, ...]) -> np.ndarray:
        if not shape:  # scalar
            return _cast(mms[0][1])
        s0 = index[0] if index else slice(None)
        start, stop, _ = s0.indices(shape[0])
        parts = []
        for off, mm in mms:
            rows = mm.shape[0]
            lo, hi = max(start, off), min(stop, off + rows)
            if lo < hi:
                parts.append(np.asarray(mm[lo - off : hi - off][(slice(None),) + tuple(index[1:])]))
        out = np.concatenate(parts, 0) if len(parts) != 1 else parts[0]
        return _cast(out)

    return read


def verify_step(ckpt_dir: str, step: int) -> None:
    """Check every chunk of a committed step against its recorded sha256.
    Raises :class:`CheckpointCorruptError` on a missing/torn/corrupt chunk
    (chunks written before digests existed are skipped)."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:07d}")
    try:
        with open(os.path.join(step_dir, "index.json")) as f:
            index = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointCorruptError(f"step {step}: unreadable index.json ({e})")
    for path, meta in index["leaves"].items():
        for ch in meta["chunks"]:
            fpath = os.path.join(step_dir, ch["file"])
            if not os.path.exists(fpath):
                raise CheckpointCorruptError(
                    f"step {step}: leaf {path!r} chunk {ch['file']} missing")
            want = ch.get("sha256")
            if want is None:
                continue  # pre-digest checkpoint
            got = _sha256_file(fpath)
            if got != want:
                raise CheckpointCorruptError(
                    f"step {step}: leaf {path!r} chunk {ch['file']} failed "
                    f"sha256 verification (torn or corrupt write): "
                    f"recorded {want[:12]}…, found {got[:12]}…")


def restore(ckpt_dir: str, step: int, abstract_tree, shardings=None,
            verify: bool = True):
    """Restore onto the given abstract tree (ShapeDtypeStructs).  With
    ``shardings`` (matching pytree of jax.sharding.Sharding), each device
    reads only its slice — reshard-on-restore.  ``verify`` (default) checks
    every chunk's sha256 first, so a torn write raises
    :class:`CheckpointCorruptError` up front instead of feeding garbage
    into devices mid-reassembly."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:07d}")
    if verify:
        verify_step(ckpt_dir, step)
    with open(os.path.join(step_dir, "index.json")) as f:
        index = json.load(f)

    leaves_meta = index["leaves"]
    flat_abs = _leaf_paths(abstract_tree)
    flat_shard = dict(_leaf_paths(shardings)) if shardings is not None else {}

    out = {}
    for path, aval in flat_abs:
        if path not in leaves_meta:
            raise CheckpointMismatchError(
                f"step {step}: leaf {path!r} not in checkpoint "
                f"(has {sorted(leaves_meta)[:8]}…)")
        meta = leaves_meta[path]
        if tuple(meta["shape"]) != tuple(aval.shape):
            raise CheckpointMismatchError(
                f"step {step}: leaf {path!r} shape mismatch — checkpoint "
                f"holds {tuple(meta['shape'])}, restore target expects "
                f"{tuple(aval.shape)}")
        np_dtype = jnp.dtype(aval.dtype)
        reader = _read_leaf(step_dir, meta, np_dtype)
        if path in flat_shard and flat_shard[path] is not None:
            arr = jax.make_array_from_callback(
                tuple(aval.shape), flat_shard[path], lambda idx, r=reader: r(idx)
            )
        else:
            arr = jnp.asarray(reader((slice(None),) * len(aval.shape)))
        out[path] = arr

    # Rebuild the tree structure from abstract_tree.
    leaves, treedef = jax.tree_util.tree_flatten(abstract_tree)
    ordered = [out[p] for p, _ in flat_abs]
    return jax.tree_util.tree_unflatten(treedef, ordered)


def restore_latest(ckpt_dir: str, abstract_tree, shardings=None,
                   verify: bool = True):
    """Restore the newest *intact* committed step: integrity failures on
    the latest step fall back to the previous committed one (and so on),
    each fallback logged via ``warnings.warn`` — never silent, never an
    unhandled corrupt read.  Returns ``(tree, step)`` or ``(None, None)``
    when no restorable checkpoint exists.  Mismatch errors (wrong tree
    shape) are NOT absorbed: older steps would mismatch identically, and
    masking them would hide a real caller bug."""
    for step in reversed(committed_steps(ckpt_dir)):
        try:
            return restore(ckpt_dir, step, abstract_tree, shardings,
                           verify=verify), step
        except (CheckpointCorruptError, OSError, json.JSONDecodeError) as e:
            warnings.warn(
                f"checkpoint step {step} in {ckpt_dir} is corrupt "
                f"({e}); falling back to the previous committed step",
                stacklevel=2)
    return None, None


def retain(ckpt_dir: str, keep: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(m.group(1))
        for d in os.listdir(ckpt_dir)
        if (m := re.fullmatch(r"step_(\d+)", d))
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:07d}"), ignore_errors=True)
