"""Fault-tolerant sharded checkpointing.

Layout (one directory per step, atomic via tmp-dir + rename + COMMIT marker):

    ckpt/step_0000012/
      index.json              tree structure + per-leaf chunk table
      <leaf>.c00.npy ...      chunks split along axis 0 (one per saver shard)
      COMMIT                  written last; restore ignores dirs without it

Chunking along axis 0 makes restore *resharding-capable*: a checkpoint
written by N hosts restores onto M devices with any sharding — each leaf is
reassembled lazily from its chunks (np.memmap) inside
``jax.make_array_from_callback``, so each device only materializes its own
slice.  This is the restart path for elastic re-meshing after node failure
(runtime/fault_tolerance.py).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading

import jax
import jax.numpy as jnp
import numpy as np

_SAFE = re.compile(r"[^A-Za-z0-9_.-]")


def _leaf_paths(tree):
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for kp, leaf in paths:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))) for k in kp
        )
        out.append((name, leaf))
    return out


def _fname(leaf_path: str, chunk: int) -> str:
    return f"{_SAFE.sub('_', leaf_path)}.c{chunk:02d}.npy"


def save(ckpt_dir: str, step: int, tree, n_chunks: int = 1) -> str:
    """Write a checkpoint; returns the final directory path."""
    final = os.path.join(ckpt_dir, f"step_{step:07d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    index = {"step": step, "leaves": {}}
    for path, leaf in _leaf_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = jnp.dtype(arr.dtype).name
        bits = arr.dtype.kind not in "fiub" or logical_dtype == "bfloat16"
        if bits:  # ml_dtypes (bf16/f8) don't survive np memmap casts
            arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
        chunks = []
        n = max(1, min(n_chunks, arr.shape[0] if arr.ndim else 1))
        splits = np.array_split(np.arange(arr.shape[0] if arr.ndim else 1), n)
        off = 0
        for ci, idx in enumerate(splits):
            if arr.ndim:
                part = arr[idx[0] : idx[-1] + 1] if len(idx) else arr[0:0]
            else:
                part = arr
            fn = _fname(path, ci)
            np.save(os.path.join(tmp, fn), part)
            chunks.append({"file": fn, "offset": off, "rows": int(len(idx)) if arr.ndim else 1})
            off += len(idx) if arr.ndim else 1
        index["leaves"][path] = {
            "shape": list(arr.shape),
            "dtype": logical_dtype,
            "bits": bits,
            "chunks": chunks,
        }

    with open(os.path.join(tmp, "index.json"), "w") as f:
        json.dump(index, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def save_async(ckpt_dir: str, step: int, tree, n_chunks: int = 1) -> threading.Thread:
    """Device-get on the caller thread (cheap on CPU; on TPU this is the
    copy-out), file IO on a background thread."""
    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    t = threading.Thread(target=save, args=(ckpt_dir, step, host_tree, n_chunks))
    t.start()
    return t


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for d in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, "COMMIT")):
            best = max(best or -1, int(m.group(1)))
    return best


def _read_leaf(step_dir: str, meta: dict, np_dtype) -> np.ndarray:
    """Reassemble a leaf lazily; returns a callable slicer to avoid
    materializing the full array when only a shard is needed."""
    mms = []
    for ch in meta["chunks"]:
        mms.append((ch["offset"], np.load(os.path.join(step_dir, ch["file"]), mmap_mode="r")))
    shape = tuple(meta["shape"])
    bits = meta.get("bits", False)

    def _cast(a: np.ndarray) -> np.ndarray:
        if bits:
            return np.asarray(a).view(np_dtype)
        return np.asarray(a).astype(np_dtype, copy=False)

    def read(index: tuple[slice, ...]) -> np.ndarray:
        if not shape:  # scalar
            return _cast(mms[0][1])
        s0 = index[0] if index else slice(None)
        start, stop, _ = s0.indices(shape[0])
        parts = []
        for off, mm in mms:
            rows = mm.shape[0]
            lo, hi = max(start, off), min(stop, off + rows)
            if lo < hi:
                parts.append(np.asarray(mm[lo - off : hi - off][(slice(None),) + tuple(index[1:])]))
        out = np.concatenate(parts, 0) if len(parts) != 1 else parts[0]
        return _cast(out)

    return read


def restore(ckpt_dir: str, step: int, abstract_tree, shardings=None):
    """Restore onto the given abstract tree (ShapeDtypeStructs).  With
    ``shardings`` (matching pytree of jax.sharding.Sharding), each device
    reads only its slice — reshard-on-restore."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:07d}")
    with open(os.path.join(step_dir, "index.json")) as f:
        index = json.load(f)

    leaves_meta = index["leaves"]
    flat_abs = _leaf_paths(abstract_tree)
    flat_shard = dict(_leaf_paths(shardings)) if shardings is not None else {}

    out = {}
    for path, aval in flat_abs:
        meta = leaves_meta[path]
        assert tuple(meta["shape"]) == tuple(aval.shape), (path, meta["shape"], aval.shape)
        np_dtype = jnp.dtype(aval.dtype)
        reader = _read_leaf(step_dir, meta, np_dtype)
        if path in flat_shard and flat_shard[path] is not None:
            arr = jax.make_array_from_callback(
                tuple(aval.shape), flat_shard[path], lambda idx, r=reader: r(idx)
            )
        else:
            arr = jnp.asarray(reader((slice(None),) * len(aval.shape)))
        out[path] = arr

    # Rebuild the tree structure from abstract_tree.
    leaves, treedef = jax.tree_util.tree_flatten(abstract_tree)
    ordered = [out[p] for p, _ in flat_abs]
    return jax.tree_util.tree_unflatten(treedef, ordered)


def retain(ckpt_dir: str, keep: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(m.group(1))
        for d in os.listdir(ckpt_dir)
        if (m := re.fullmatch(r"step_(\d+)", d))
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:07d}"), ignore_errors=True)
