"""Mamba-2 (SSD) block: chunked state-space duality implementation.

The selective-scan is computed with the SSD chunked algorithm: intra-chunk
quadratic matmuls + inter-chunk recurrence over per-chunk states — i.e.
MXU-friendly blocking of a recurrence, which is the paper's Alg 2 insight
(keep a block resident, stream the sequence) applied to SSMs.  G = 1
(single B/C group), conv1d width ``cfg.conv_width`` over the x/B/C streams.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.module import ParamDef

CHUNK = 128


def dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    return d_in, H, cfg.ssm_head_dim, cfg.ssm_state


def block_defs(cfg: ModelConfig, L: int) -> dict:
    d = cfg.d_model
    d_in, H, hd, N = dims(cfg)
    ds = "model" if d_in % 16 == 0 else None
    conv_ch = d_in + 2 * N
    return {
        "ln": ParamDef((L, d), (None, None), init="zeros"),
        # in_proj -> [z, x, B, C, dt]
        "w_in": ParamDef((L, d, 2 * d_in + 2 * N + H), (None, None, ds), fan_in_axis=1),
        "conv_w": ParamDef((L, cfg.conv_width, conv_ch), (None, None, ds), scale=0.5, fan_in_axis=1),
        "conv_b": ParamDef((L, conv_ch), (None, ds), init="zeros"),
        "A_log": ParamDef((L, H), (None, None), init="zeros"),
        "D": ParamDef((L, H), (None, None), init="ones"),
        "dt_bias": ParamDef((L, H), (None, None), init="zeros"),
        "gn": ParamDef((L, d_in), (None, ds), init="zeros"),
        "w_out": ParamDef((L, d_in, d), (None, ds, None), fan_in_axis=1),
    }


def _depthwise_conv(x, w, b, state):
    """Causal depthwise conv1d.  x: [B, S, C]; w: [W, C]; state: [B, W-1, C]
    (trailing inputs of the previous segment).  Returns (y, new_state)."""
    W = w.shape[0]
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)  # [B, S+W-1, C]
    y = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(W))
    new_state = xp[:, -(W - 1) :, :] if W > 1 else state
    return jax.nn.silu(y + b), new_state


def _segsum(a):
    """a: [..., Q] -> lower-triangular cumulative sums L[i, j] = sum_{j<t<=i} a_t."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, -1)
    diff = cs[..., :, None] - cs[..., None, :]  # sum_(j, i]
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A_log, B, C, D, state):
    """SSD forward.

    x: [B, S, H, P]; dt: [B, S, H] (post-softplus); A_log: [H];
    B, C: [B, S, N]; D: [H]; state: [Bb, H, P, N] carried across segments.
    Returns (y [B, S, H, P], new_state).
    """
    Bb, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(CHUNK, S)
    assert S % Q == 0
    nc = S // Q

    a = -jnp.exp(A_log.astype(jnp.float32))[None, None, :] * dt  # [B, S, H] (<0)
    xr = (x * dt[..., None]).reshape(Bb, nc, Q, H, P).astype(jnp.float32)
    ar = a.reshape(Bb, nc, Q, H)
    Br = B.reshape(Bb, nc, Q, N).astype(jnp.float32)
    Cr = C.reshape(Bb, nc, Q, N).astype(jnp.float32)

    # Intra-chunk (quadratic, MXU): Y_diag = (C B^T * L) @ x
    Lmat = jnp.exp(_segsum(ar.transpose(0, 1, 3, 2)))  # [B, nc, H, Q, Q]
    G = jnp.einsum("bcqn,bckn->bcqk", Cr, Br)  # [B, nc, Q, Q]
    Y = jnp.einsum("bchqk,bckhp->bcqhp", G[:, :, None] * Lmat, xr)

    # Per-chunk input states and decays.
    a_cum = jnp.cumsum(ar, 2)  # [B, nc, Q, H]
    a_tail = a_cum[:, :, -1:, :] - a_cum  # decay from t to chunk end
    states = jnp.einsum("bckn,bckh,bckhp->bchpn", Br, jnp.exp(a_tail), xr)

    # Inter-chunk recurrence over nc chunk states.
    a_tot = a_cum[:, :, -1, :]  # [B, nc, H]

    def step(s, inp):
        st, at = inp  # [B, H, P, N], [B, H]
        s_out = s  # state *entering* the chunk
        s = s * jnp.exp(at)[..., None, None] + st
        return s, s_out

    state, s_in = jax.lax.scan(
        step, state.astype(jnp.float32),
        (states.transpose(1, 0, 2, 3, 4), a_tot.transpose(1, 0, 2)),
    )
    s_in = s_in.transpose(1, 0, 2, 3, 4)  # [B, nc, H, P, N]

    # Contribution of the entering state to each position.
    Y += jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cr, jnp.exp(a_cum), s_in)
    Y = Y.reshape(Bb, S, H, P) + D[None, None, :, None] * x.astype(jnp.float32)
    return Y, state


def apply_block(p, x, cfg: ModelConfig, state):
    """One Mamba-2 block.  x: [B, S, d]; state: {"conv": ..., "ssd": ...}."""
    Bb, S, d = x.shape
    d_in, H, hd, N = dims(cfg)
    cd = x.dtype

    proj = x @ p["w_in"].astype(cd)  # [B, S, 2*d_in + 2N + H]
    z, xc, Bc, Cc, dt = jnp.split(proj, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], -1)

    conv_in = jnp.concatenate([xc, Bc, Cc], -1)
    conv_out, conv_state = _depthwise_conv(conv_in, p["conv_w"].astype(cd),
                                           p["conv_b"].astype(cd), state["conv"])
    xc, Bc, Cc = jnp.split(conv_out, [d_in, d_in + N], -1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    y, ssd_state = ssd_chunked(
        xc.reshape(Bb, S, H, hd), dt, p["A_log"], Bc, Cc, p["D"], state["ssd"]
    )
    y = y.reshape(Bb, S, d_in).astype(cd)
    y = y * jax.nn.silu(z)
    # Gated RMS norm (f32).
    yf = y.astype(jnp.float32)
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + cfg.norm_eps)
    y = (yf * (1.0 + p["gn"].astype(jnp.float32))).astype(cd)
    out = y @ p["w_out"].astype(cd)
    return out, {"conv": conv_state, "ssd": ssd_state}


def init_block_state(cfg: ModelConfig, L: int, batch: int, dtype=jnp.bfloat16):
    d_in, H, hd, N = dims(cfg)
    conv_ch = d_in + 2 * N
    return {
        "conv": jnp.zeros((L, batch, cfg.conv_width - 1, conv_ch), dtype),
        "ssd": jnp.zeros((L, batch, H, hd, N), jnp.float32),
    }
