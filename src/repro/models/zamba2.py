"""Zamba2: Mamba-2 backbone with a *shared* attention+MLP block applied
every ``cfg.shared_attn_every`` layers (params reused at every application,
per the Zamba2 design: one transformer block amortized over the depth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as ll
from repro.models import mamba2
from repro.models.module import ParamDef


def _segments(cfg: ModelConfig) -> list[int]:
    """Mamba-layer run lengths between shared-attn applications."""
    every = cfg.shared_attn_every or cfg.n_layers
    segs, left = [], cfg.n_layers
    while left > 0:
        segs.append(min(every, left))
        left -= every
    return segs


def n_shared_applications(cfg: ModelConfig) -> int:
    return sum(1 for s in _segments(cfg) if s == (cfg.shared_attn_every or cfg.n_layers))


def param_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    return {
        **ll.embed_defs(cfg),
        "mamba": mamba2.block_defs(cfg, cfg.n_layers),
        "shared": {  # single shared transformer block (unstacked)
            "ln1": ParamDef((d,), (None,), init="zeros"),
            "ln2": ParamDef((d,), (None,), init="zeros"),
            "attn": ll.attn_defs(cfg, 0, layers_prefix=False),
            "mlp": {k: ParamDef(v.shape[1:], v.spec[1:], fan_in_axis=0)
                    for k, v in ll.mlp_defs(cfg, 1).items()},
        },
    }


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    n_app = n_shared_applications(cfg)
    Dh = cfg.resolved_head_dim
    return {
        "mamba": mamba2.init_block_state(cfg, cfg.n_layers, batch, dtype),
        "k": jnp.zeros((n_app, batch, max_seq, cfg.n_kv_heads, Dh), dtype),
        "v": jnp.zeros((n_app, batch, max_seq, cfg.n_kv_heads, Dh), dtype),
    }


def _shared_block(p, x, cfg, pos0, cache, parallel=None):
    h = ll.rms_norm(x, p["ln1"], cfg.norm_eps)
    h, new_cache = ll.apply_attention(p["attn"], h, cfg, pos0=pos0, cache=cache,
                                      parallel=parallel)
    x = x + h
    h = ll.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + ll.apply_mlp(p["mlp"], h, cfg.act, parallel)
    return x, new_cache


def forward(
    cfg: ModelConfig, params: dict, tokens, *, pos0=0, cache=None,
    remat: str = "none", compute_dtype=jnp.bfloat16, parallel=None,
):
    from repro.runtime.parallel import constrain

    B, S = tokens.shape
    x = ll.embed_tokens(params, tokens, cfg, compute_dtype)
    x = constrain(x, parallel, ("dp", None, None))
    state = cache["mamba"] if cache is not None else mamba2.init_block_state(
        cfg, cfg.n_layers, B, compute_dtype
    )

    def seg_body(x, xs):
        lp, st = xs
        h = ll.rms_norm(x, lp["ln"], cfg.norm_eps)
        h, st = mamba2.apply_block(lp, h, cfg, st)
        return x + h, st

    if remat == "block":
        seg_body = jax.checkpoint(seg_body, prevent_cse=False)

    slice_tree = lambda t, i0, i1: jax.tree.map(lambda a: a[i0:i1], t)
    new_mamba, new_k, new_v = [], [], []
    off = app = 0
    for seg in _segments(cfg):
        xs = (slice_tree(params["mamba"], off, off + seg),
              slice_tree(state, off, off + seg))
        x, st = jax.lax.scan(seg_body, x, xs)
        new_mamba.append(st)
        off += seg
        if seg == (cfg.shared_attn_every or cfg.n_layers):
            kv = None
            if cache is not None:
                kv = (cache["k"][app], cache["v"][app])
            x, kv = _shared_block(params["shared"], x, cfg, pos0, kv, parallel)
            if cache is not None:
                new_k.append(kv[0])
                new_v.append(kv[1])
            app += 1

    new_cache = None
    mstate = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_mamba)
    if cache is not None:
        new_cache = {
            "mamba": mstate,
            "k": jnp.stack(new_k, 0),
            "v": jnp.stack(new_v, 0),
        }
    return x, new_cache


def logits(cfg, params, hidden):
    return ll.logits_from_hidden(params, hidden, cfg)


def layer_meta(cfg):
    return {}
