"""Attention used inside models: GQA, causal, sliding-window, KV cache.

Two execution paths, one math:

* ``direct`` - one materialized logits tensor; used for decode (Sq == 1)
  and small problems.
* ``blockwise`` - flash-style two-level scan over query/KV chunks with
  running (m, l) statistics; O(chunk^2) live memory, differentiable, and
  GSPMD-partitionable (pure jnp/lax).  This is the paper's Alg 2 "output
  stack resident, inputs streamed" schedule expressed at the XLA level;
  the Pallas kernel in kernels/flash_attention is the same schedule one
  level down, used on the TPU hot path.

``window`` may be a static int/None or a traced per-layer scalar (gemma3's
5:1 local:global pattern runs as one scanned layer body).  A window value
< 0 means "no window" when traced.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from repro.core.shard_compat import shard_map

NEG = -1e30


def _mask(q_pos, k_pos, causal, window):
    """[Sq, Skv] boolean visibility mask from position vectors."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        w = jnp.asarray(window, jnp.int32)
        m &= jnp.where(w < 0, True, q_pos[:, None] - k_pos[None, :] < w)
    return m


def _direct(q, k, v, q_pos, k_pos, scale, causal, window):
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32)
    s = s * scale + jnp.where(_mask(q_pos, k_pos, causal, window), 0.0, NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)


def _blockwise(q, k, v, q_pos, k_pos, scale, causal, window, chunk_q, chunk_kv):
    B, H, Sq, D = q.shape
    Skv = k.shape[2]
    cq, ckv = min(chunk_q, Sq), min(chunk_kv, Skv)
    assert Sq % cq == 0 and Skv % ckv == 0, (Sq, cq, Skv, ckv)
    nq, nkv = Sq // cq, Skv // ckv

    qs = q.reshape(B, H, nq, cq, D).transpose(2, 0, 1, 3, 4)
    qp = q_pos.reshape(nq, cq)
    ks = k.reshape(B, H, nkv, ckv, D).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(B, H, nkv, ckv, D).transpose(2, 0, 1, 3, 4)
    kp = k_pos.reshape(nkv, ckv)

    def q_step(_, qx):
        qc, qpc = qx

        # qc/qpc are loop-invariant for the KV scan: close over them rather
        # than carrying them (a carried q chunk is copied every KV step —
        # measured ~50 TB/device of copy traffic on qwen3-moe prefill).
        @functools.partial(jax.checkpoint, prevent_cse=False)
        def kv_step(carry, kv):
            acc, m, l = carry
            kc, vc, kpc = kv
            s = jnp.einsum("bhqd,bhkd->bhqk", qc, kc, preferred_element_type=jnp.float32)
            s = s * scale + jnp.where(_mask(qpc, kpc, causal, window), 0.0, NEG)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vc.dtype), vc
            ).astype(jnp.float32)
            return (acc, m_new, l), None

        acc0 = jnp.zeros((B, H, cq, D), jnp.float32)
        m0 = jnp.full((B, H, cq), NEG, jnp.float32)
        l0 = jnp.zeros((B, H, cq), jnp.float32)
        (acc, _, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0), (ks, vs, kp))
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows stay finite
        return None, (acc / l[..., None]).astype(q.dtype)

    _, out = jax.lax.scan(q_step, None, (qs, qp))
    return out.transpose(1, 2, 0, 3, 4).reshape(B, H, Sq, D)


def _attention_core(q, k, v, q_pos, k_pos, causal, window, scale,
                    chunk_q, chunk_kv):
    """q: [B, Sq, Hq, D]; k/v: [B, Skv, Hkv, D] -> [B, Sq, Hq, D]."""
    B, Sq, Hq, D = q.shape
    Hkv, Skv = k.shape[2], k.shape[1]
    assert Hq % Hkv == 0
    g = Hq // Hkv

    # Fold the GQA group into the query-sequence axis so KV is never
    # repeated in memory: [B, Hkv, g*Sq, D] queries vs [B, Hkv, Skv, D] KV.
    qh = q.transpose(0, 2, 1, 3).reshape(B, Hkv, g * Sq, D)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    qpos_g = jnp.tile(q_pos, (g,))

    big = (g * Sq) * Skv > 4 * 1024 * 1024 and (g * Sq) % chunk_q == 0 and Skv % chunk_kv == 0
    if Sq == 1 or not big:
        out = _direct(qh, kh, vh, qpos_g, k_pos, scale, causal, window)
    else:
        out = _blockwise(
            qh, kh, vh, qpos_g, k_pos, scale, causal, window, chunk_q, chunk_kv
        )
    out = out.reshape(B, Hkv, g, Sq, D).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


def attention(
    q: jax.Array,  # [B, Sq, Hq, D]
    k: jax.Array,  # [B, Skv, Hkv, D]
    v: jax.Array,
    *,
    q_pos: jax.Array,  # [Sq] int32 absolute positions
    k_pos: jax.Array,  # [Skv]
    causal: bool = True,
    window=None,
    scale: float | None = None,
    chunk_q: int = 512,
    chunk_kv: int = 1024,
    parallel=None,
) -> jax.Array:
    """GQA attention; returns [B, Sq, Hq, D].

    When Q heads don't divide the TP axis (gemma3: 8 heads on tp=16), the
    computation runs *sequence-parallel* under shard_map: each device owns
    a slice of the query sequence against replicated KV — no collective
    inside the softmax loop (vs. the Dh-sharded alternative, which psums
    every logits block).  KV replication costs one gather per layer.
    """
    B, Sq, Hq, D = q.shape
    scale = scale if scale is not None else D**-0.5

    use_seqp = (
        parallel is not None
        and Sq > 1
        and Hq % parallel.tp_size != 0
        and Sq % parallel.tp_size == 0
        and (Sq // parallel.tp_size) * (Hq // k.shape[2]) % 8 == 0
    )
    if not use_seqp:
        return _attention_core(q, k, v, q_pos, k_pos, causal, window, scale,
                               chunk_q, chunk_kv)

    from jax.sharding import PartitionSpec as P

    tp = parallel.tp_axis
    bax = parallel.batch_axes(B)
    blead = bax if len(bax) > 1 else (bax[0] if bax else None)
    wnd = jnp.asarray(-1 if window is None else window, jnp.int32)

    def local_fn(q_l, k_l, v_l, qpos_l, kpos_l, wnd_l):
        return _attention_core(q_l, k_l, v_l, qpos_l, kpos_l, causal, wnd_l,
                               scale, chunk_q, chunk_kv)

    return shard_map(
        local_fn, mesh=parallel.mesh,
        in_specs=(P(blead, tp, None, None), P(blead, None, None, None),
                  P(blead, None, None, None), P(tp), P(None), P()),
        out_specs=P(blead, tp, None, None),
        check_vma=False,
    )(q, k, v, q_pos, k_pos, wnd)
