"""Model-family registry: family name -> module implementing
param_defs / forward / logits / init_cache / layer_meta."""

from __future__ import annotations

from repro.models import encdec, moe, rwkv6, transformer, zamba2

FAMILIES = {
    "dense": transformer,
    "moe": moe,
    "rwkv6": rwkv6,
    "zamba2": zamba2,
    "encdec": encdec,
}


def get_family(name: str):
    try:
        return FAMILIES[name]
    except KeyError:
        raise ValueError(f"unknown model family {name!r}; have {list(FAMILIES)}") from None
