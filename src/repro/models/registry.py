"""Model-family registry: family name -> module implementing
param_defs / forward / logits / init_cache / layer_meta.

The cnn family (the paper's own domain) is registered too: it implements
the core protocol subset it needs (param_defs / forward) plus the
family-registry hooks the launcher dispatches on — currently
``batch_shard_specs`` (how the family's batch pytree shards over the data
axes), the first step of making cnn fully first-class (ROADMAP)."""

from __future__ import annotations

from jax.sharding import PartitionSpec as P

from repro.models import cnn, encdec, moe, rwkv6, transformer, zamba2

FAMILIES = {
    "dense": transformer,
    "moe": moe,
    "rwkv6": rwkv6,
    "zamba2": zamba2,
    "encdec": encdec,
    "cnn": cnn,
}


def get_family(name: str):
    try:
        return FAMILIES[name]
    except KeyError:
        raise ValueError(f"unknown model family {name!r}; have {list(FAMILIES)}") from None


def init_cache_slots(cfg, n_slots: int, max_seq: int, dtype):
    """Allocate the serving engine's decode-state slot pool: the family's
    ``init_cache`` with one batch row per slot.  Every registered LM
    family lays its cache leaves out with the batch (= slot) dimension on
    axis 1 — ``[L, B, ...]`` — which is what the engine's slot
    scatter/backfill relies on.  Families without a cache hook (cnn) are
    not servable and raise."""
    fam = FAMILIES.get(cfg.family)
    hook = getattr(fam, "init_cache", None) if fam else None
    if hook is None:
        raise ValueError(
            f"model family {cfg.family!r} has no init_cache hook; it cannot "
            "be served through repro.serve (no decode state to slot)")
    return hook(cfg, n_slots, max_seq, dtype)


def batch_shard_specs(cfg, dp) -> dict:
    """The family's batch sharding specs over the data axes ``dp`` (an
    axis name or tuple).  Families provide a ``batch_shard_specs(dp)``
    hook (models/cnn.py does — images shard their batch dim, matching the
    sharded ConvPlanner's "batch" partition); token families fall back to
    the LM default.  launch/train.py dispatches here instead of branching
    on the family name."""
    fam = FAMILIES.get(cfg.family)
    hook = getattr(fam, "batch_shard_specs", None) if fam else None
    if hook is not None:
        return hook(dp)
    return {k: P(dp, None) for k in ("tokens", "labels")}
