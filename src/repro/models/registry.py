"""Model-family registry: family name -> module implementing
param_defs / forward / logits / init_cache / layer_meta.

Beyond that core protocol, the launcher and the train runtime dispatch
on *hooks* the family module may provide — no family branching at the
call sites (docs/plan-layer.md spells out the contract):

* ``batch_shard_specs(dp)`` — how the family's batch pytree shards over
  the data axes (:func:`batch_shard_specs`; LM token default);
* ``data_source(cfg, batch, shard, seed=)`` — the family's synthetic
  data source (:func:`make_data_source`; token-stream default);
* ``make_loss_fn(cfg, tcfg, parallel)`` — the family's training loss,
  including its planned-kernel path (``runtime.train.make_loss_fn``
  dispatches; generic forward + chunked-CE default);
* ``plan_training(cfg, batch, *, seq=, loss_chunks=, mesh=, ...)`` — the
  family's full planned schedule set (the launcher's sharded-plan
  re-plan keys off its presence).

The cnn family (the paper's own domain) and the dense ``transformer``
family provide all four; ``transformer`` is also registered under its
own name so ``--family transformer`` addresses it directly."""

from __future__ import annotations

from jax.sharding import PartitionSpec as P

from repro.models import cnn, encdec, moe, rwkv6, transformer, zamba2

FAMILIES = {
    "dense": transformer,
    "transformer": transformer,  # the planned wing's first-class name
    "moe": moe,
    "rwkv6": rwkv6,
    "zamba2": zamba2,
    "encdec": encdec,
    "cnn": cnn,
}


def get_family(name: str):
    try:
        return FAMILIES[name]
    except KeyError:
        raise ValueError(f"unknown model family {name!r}; have {list(FAMILIES)}") from None


def init_cache_slots(cfg, n_slots: int, max_seq: int, dtype):
    """Allocate the serving engine's decode-state slot pool: the family's
    ``init_cache`` with one batch row per slot.  Every registered LM
    family lays its cache leaves out with the batch (= slot) dimension on
    axis 1 — ``[L, B, ...]`` — which is what the engine's slot
    scatter/backfill relies on.  Families without a cache hook (cnn) are
    not servable and raise."""
    fam = FAMILIES.get(cfg.family)
    hook = getattr(fam, "init_cache", None) if fam else None
    if hook is None:
        raise ValueError(
            f"model family {cfg.family!r} has no init_cache hook; it cannot "
            "be served through repro.serve (no decode state to slot)")
    return hook(cfg, n_slots, max_seq, dtype)


def batch_shard_specs(cfg, dp) -> dict:
    """The family's batch sharding specs over the data axes ``dp`` (an
    axis name or tuple).  Families provide a ``batch_shard_specs(dp)``
    hook (models/cnn.py does — images shard their batch dim, matching the
    sharded ConvPlanner's "batch" partition); token families fall back to
    the LM default.  launch/train.py dispatches here instead of branching
    on the family name."""
    fam = FAMILIES.get(cfg.family)
    hook = getattr(fam, "batch_shard_specs", None) if fam else None
    if hook is not None:
        return hook(dp)
    return {k: P(dp, None) for k in ("tokens", "labels")}


def make_data_source(cfg, batch: int, seq: int, shard, seed: int = 0):
    """The family's synthetic data source.  Families provide a
    ``data_source(cfg, batch, shard, seed=)`` hook (models/cnn.py does —
    image/label batches); token families fall back to the LM default
    (``SyntheticSource`` over ``cfg.vocab``, where ``seq`` applies).
    launch/train.py dispatches here instead of branching on the family
    name."""
    fam = FAMILIES.get(cfg.family)
    hook = getattr(fam, "data_source", None) if fam else None
    if hook is not None:
        return hook(cfg, batch, shard, seed=seed)
    from repro.data.pipeline import SyntheticSource

    return SyntheticSource(cfg.vocab, seq, batch, shard, seed=seed)
