"""The paper's own domain: a CNN built directly from core.conv_layer and
core.fc_layer (VGG-style conv/pool stages + two FC layers).

Config reuse: ``n_layers`` = conv stages, ``d_model`` = base channel width
(doubled per stage), ``d_ff`` = FC hidden width, ``vocab`` = classes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.conv_layer import conv_block
from repro.core.fc_layer import fc_layer
from repro.models.module import ParamDef

IMG = 32  # input resolution (CIFAR-like)
IN_CH = 3
F = 3  # receptive field of every conv filter (the paper's running F)


def _stage_channels(cfg: ModelConfig) -> list[tuple[int, int]]:
    chans, c_in = [], IN_CH
    for i in range(cfg.n_layers):
        c_out = cfg.d_model * (2**i)
        chans.append((c_in, c_out))
        c_in = c_out
    return chans


def param_defs(cfg: ModelConfig) -> dict:
    stages = {}
    for i, (ci, co) in enumerate(_stage_channels(cfg)):
        stages[f"conv{i}"] = ParamDef((F, F, ci, co), (None, None, None, None), fan_in_axis=2)
        stages[f"bias{i}"] = ParamDef((co,), (None,), init="zeros")
    spatial = IMG // (2 ** cfg.n_layers)
    flat = spatial * spatial * cfg.d_model * (2 ** (cfg.n_layers - 1))
    return {
        **stages,
        "fc1": ParamDef((flat, cfg.d_ff), (None, "model")),
        "fc1_b": ParamDef((cfg.d_ff,), (None,), init="zeros"),
        "fc2": ParamDef((cfg.d_ff, cfg.vocab), ("model", None)),
        "fc2_b": ParamDef((cfg.vocab,), (None,), init="zeros"),
    }


def forward(cfg: ModelConfig, params: dict, images: jax.Array, *,
            use_kernels: bool = True, schedules: dict | None = None, **_):
    """images: [B, IMG, IMG, 3] -> logits [B, classes].

    ``schedules`` optionally maps stage names ("conv0", ..., "fc1", "fc2")
    to explicit :class:`repro.plan.Schedule` objects (e.g. from
    :func:`plan_forward`), overriding the per-stage capacity planner.
    """
    sched = schedules or {}
    x = images
    for i in range(cfg.n_layers):
        f, b = params[f"conv{i}"], params[f"bias{i}"]
        if use_kernels:
            # One batched kernel launch per stage: conv + bias + ReLU + 2x2
            # max-pool all fused in the flush — no HBM round-trip between
            # the conv and its epilogue.
            x = conv_block(x, f, b, 1, F // 2, 2, "strip", sched.get(f"conv{i}"))
        else:
            from repro.kernels.conv2d.ref import conv2d_fused_ref

            x = conv2d_fused_ref(x, f, b, stride=1, padding=F // 2,
                                 relu=True, pool=2)
    x = x.reshape(x.shape[0], -1)
    if use_kernels:
        x = jax.nn.relu(fc_layer(x, params["fc1"], sched.get("fc1")) + params["fc1_b"])
        return fc_layer(x, params["fc2"], sched.get("fc2")) + params["fc2_b"]
    x = jax.nn.relu(x @ params["fc1"] + params["fc1_b"])
    return x @ params["fc2"] + params["fc2_b"]


def plan_forward(cfg: ModelConfig, batch: int, *, in_bytes: int = 4,
                 machine=None) -> dict:
    """Plan every kernel launch of :func:`forward` without running it.

    Returns {stage name: Schedule} — pass back in via ``schedules=`` to pin
    the blocking, or sum ``.modeled_words`` to connect the whole model's
    planned traffic to analysis/roofline.py (repro.plan.to_roofline).
    """
    from repro.core import conv_layer as cl
    from repro.core import fc_layer as fl

    out = {}
    H = IMG
    for i, (ci, co) in enumerate(_stage_channels(cfg)):
        out[f"conv{i}"] = cl.plan(
            (batch, H, H, ci), (F, F, ci, co), stride=1, padding=F // 2,
            pool=2, in_bytes=in_bytes, machine=machine,
        )
        H //= 2
    flat = H * H * cfg.d_model * (2 ** (cfg.n_layers - 1))
    out["fc1"] = fl.plan((batch, flat), (flat, cfg.d_ff),
                         in_bytes=in_bytes, machine=machine)
    out["fc2"] = fl.plan((batch, cfg.d_ff), (cfg.d_ff, cfg.vocab),
                         in_bytes=in_bytes, machine=machine)
    return out
