"""The paper's own domain: a CNN built directly from core.conv_layer and
core.fc_layer (VGG-style conv/pool stages + two FC layers).

Config reuse: ``n_layers`` = conv stages, ``d_model`` = base channel width
(doubled per stage), ``d_ff`` = FC hidden width, ``vocab`` = classes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.conv_layer import conv_block
from repro.core.fc_layer import fc_layer
from repro.models.module import ParamDef

IMG = 32  # input resolution (CIFAR-like)
IN_CH = 3
F = 3  # receptive field of every conv filter (the paper's running F)


def _stage_channels(cfg: ModelConfig) -> list[tuple[int, int]]:
    chans, c_in = [], IN_CH
    for i in range(cfg.n_layers):
        c_out = cfg.d_model * (2**i)
        chans.append((c_in, c_out))
        c_in = c_out
    return chans


def _stage_geometry(cfg: ModelConfig, batch: int):
    """The single source of every stage's operand shapes: yields
    ``(name, x_shape, w_shape)`` for each conv stage (halving the plane
    per 2x2 pool) and each FC stage — consumed by param_defs (widths),
    plan_forward and plan_training, so a topology change lands in one
    place."""
    H = IMG
    for i, (ci, co) in enumerate(_stage_channels(cfg)):
        yield f"conv{i}", (batch, H, H, ci), (F, F, ci, co)
        H //= 2
    flat = H * H * cfg.d_model * (2 ** (cfg.n_layers - 1))
    yield "fc1", (batch, flat), (flat, cfg.d_ff)
    yield "fc2", (batch, cfg.d_ff), (cfg.d_ff, cfg.vocab)


def param_defs(cfg: ModelConfig) -> dict:
    defs = {}
    for name, _x_shape, w_shape in _stage_geometry(cfg, batch=1):
        if name.startswith("conv"):
            i = name[len("conv"):]
            defs[name] = ParamDef(w_shape, (None, None, None, None), fan_in_axis=2)
            defs[f"bias{i}"] = ParamDef((w_shape[3],), (None,), init="zeros")
        else:
            spec = (None, "model") if name == "fc1" else ("model", None)
            defs[name] = ParamDef(w_shape, spec)
            defs[f"{name}_b"] = ParamDef((w_shape[1],), (None,), init="zeros")
    return defs


def batch_shard_specs(dp) -> dict:
    """Family-registry hook: how this family's batch shards over the data
    axes (``dp`` is an axis name or tuple).  Images shard their batch
    dimension — the same "batch" partition the mesh-aware ConvPlanner
    emits for every conv stage (plan_forward(..., mesh=)) — so the
    launcher needs no family special-casing."""
    return {"images": P(dp, None, None, None), "labels": P(dp)}


def data_source(cfg: ModelConfig, batch: int, shard, seed: int = 0):
    """Family-registry hook (registry.make_data_source dispatches here):
    this family trains on image/label batches, not token streams."""
    from repro.data.pipeline import SyntheticImageSource

    return SyntheticImageSource(IMG, IN_CH, cfg.vocab, batch, shard,
                                seed=seed)


def make_loss_fn(cfg: ModelConfig, tcfg, parallel=None):
    """Family-registry hook (runtime.train.make_loss_fn dispatches here):
    image-classification cross-entropy over :func:`forward`.  Under
    ``tcfg.planned_kernels`` the step runs the full planned set — fused
    forward kernels plus the planned dgrad/wgrad/dX/dW backward kernels,
    every Schedule pinned by :func:`plan_training` (cached per shape)."""
    del parallel  # batch sharding rides on batch_shard_specs instead
    dt = jnp.dtype(tcfg.compute_dtype)

    def loss_fn(params, batch):
        imgs = batch["images"].astype(dt)
        if tcfg.planned_kernels:
            out = forward(cfg, params, imgs, use_kernels=True,
                          schedules=plan_training(cfg, imgs.shape[0],
                                                  in_bytes=imgs.dtype.itemsize))
        else:
            out = forward(cfg, params, imgs, use_kernels=False)
        out = out.astype(jnp.float32)
        lse = jax.nn.logsumexp(out, -1)
        tgt = jnp.take_along_axis(out, batch["labels"][:, None], -1)[:, 0]
        return (lse - tgt).mean()

    return loss_fn


def _bwd_for(sched: dict, stage: str) -> dict | None:
    """The backward-Schedule overrides of one stage: ``{"conv0.dgrad": s}``
    style keys (see :func:`plan_training`) become ``{"dgrad": s}``."""
    prefix = stage + "."
    out = {k[len(prefix):]: v for k, v in sched.items() if k.startswith(prefix)}
    return out or None


def forward(cfg: ModelConfig, params: dict, images: jax.Array, *,
            use_kernels: bool = True, schedules: dict | None = None, **_):
    """images: [B, IMG, IMG, 3] -> logits [B, classes].

    ``schedules`` optionally maps stage names ("conv0", ..., "fc1", "fc2")
    to explicit :class:`repro.plan.Schedule` objects (e.g. from
    :func:`plan_forward`), overriding the per-stage capacity planner.
    Backward-pass overrides ride in the same dict under
    "<stage>.dgrad"/"<stage>.wgrad" (conv; plus "<stage>.recompute" on
    ragged geometries where the fused forward can't emit the mask
    residual) and "<stage>.dx"/"<stage>.dw" (FC) keys —
    :func:`plan_training` emits the full set, so ``jax.grad`` through
    this forward runs pinned planned backward kernels.
    """
    sched = schedules or {}
    x = images
    for i in range(cfg.n_layers):
        f, b = params[f"conv{i}"], params[f"bias{i}"]
        if use_kernels:
            # One batched kernel launch per stage: conv + bias + ReLU + 2x2
            # max-pool all fused in the flush — no HBM round-trip between
            # the conv and its epilogue.
            x = conv_block(x, f, b, 1, F // 2, 2, "strip",
                           sched.get(f"conv{i}"), _bwd_for(sched, f"conv{i}"))
        else:
            from repro.kernels.conv2d.ref import conv2d_fused_ref

            x = conv2d_fused_ref(x, f, b, stride=1, padding=F // 2,
                                 relu=True, pool=2)
    x = x.reshape(x.shape[0], -1)
    if use_kernels:
        x = jax.nn.relu(
            fc_layer(x, params["fc1"], sched.get("fc1"), _bwd_for(sched, "fc1"))
            + params["fc1_b"])
        return fc_layer(x, params["fc2"], sched.get("fc2"),
                        _bwd_for(sched, "fc2")) + params["fc2_b"]
    x = jax.nn.relu(x @ params["fc1"] + params["fc1_b"])
    return x @ params["fc2"] + params["fc2_b"]


def plan_forward(cfg: ModelConfig, batch: int, *, in_bytes: int = 4,
                 machine=None, mesh=None, shard_axis: str = "data",
                 autotune=None, conv_algorithm=None) -> dict:
    """Plan every kernel launch of :func:`forward` without running it.

    Returns {stage name: Schedule} — pass back in via ``schedules=`` to pin
    the blocking, or sum ``.modeled_words`` to connect the whole model's
    planned traffic to analysis/roofline.py (repro.plan.to_roofline).
    With ``mesh=`` every stage comes back as a ShardedSchedule (the conv
    stages shard the batch over ``shard_axis``, the FC stages pick their
    psum/ring/single dataflow by modeled words) — ``forward`` consumes
    either flavor, a 1-device mesh reproducing today's plans exactly.
    ``autotune=`` ("cache-only"/"tune") resolves every stage through the
    measured-winner cache (repro.plan.autotune) before the argmin.
    ``conv_algorithm=`` pins one family of the conv stages' two-level
    algorithm x blocking argmin ("direct"/"im2col"); the default lets
    both compete per stage, and :func:`forward` executes whichever kernel
    each stage's schedule tag names.
    """
    from repro.core import conv_layer as cl
    from repro.core import fc_layer as fl

    out = {}
    for name, x_shape, w_shape in _stage_geometry(cfg, batch):
        if name.startswith("conv"):
            out[name] = cl.plan(x_shape, w_shape, stride=1, padding=F // 2,
                                pool=2, in_bytes=in_bytes, machine=machine,
                                mesh=mesh, shard_axis=shard_axis,
                                autotune=autotune,
                                algorithm=conv_algorithm)
        else:
            out[name] = fl.plan(x_shape, w_shape, in_bytes=in_bytes,
                                machine=machine, mesh=mesh,
                                shard_axis=shard_axis, autotune=autotune)
    return out


def plan_training(cfg: ModelConfig, batch: int, *, in_bytes: int = 4,
                  machine=None, mesh=None, shard_axis: str = "data",
                  autotune=None, conv_algorithm=None, seq=None,
                  loss_chunks: int = 1) -> dict:
    """:func:`plan_forward` plus every backward kernel ``jax.grad`` runs:
    "<stage>.dgrad"/"<stage>.wgrad" for conv stages (the fused-epilogue
    backward — a "<stage>.recompute" entry appears only on ragged
    geometries), "<stage>.dx"/"<stage>.dw" for FC stages.  Pass the result via
    ``schedules=`` so the whole training step executes pinned planned
    kernels; sum ``.modeled_words`` for the step's modeled HBM traffic.
    With ``mesh=`` the wgrad/dw entries additionally charge the gradient
    all-reduce (Alg 4's tree reduction) as ``ici_words`` — the modeled
    cost of data-parallel training, split HBM vs interconnect.  The
    backward stages resolve through the same ``autotune=`` policy.
    ``seq``/``loss_chunks`` belong to the uniform family-hook signature
    (token families size their logits cell with them); the image family
    has no sequence axis or chunked logits head and ignores both.
    """
    del seq, loss_chunks  # image batches: no token axes
    from repro.core import conv_layer as cl
    from repro.core import fc_layer as fl

    out = plan_forward(cfg, batch, in_bytes=in_bytes, machine=machine,
                       mesh=mesh, shard_axis=shard_axis, autotune=autotune,
                       conv_algorithm=conv_algorithm)
    for name, x_shape, w_shape in _stage_geometry(cfg, batch):
        if name.startswith("conv"):
            # pool=2 matches forward()'s fused conv_block epilogue, so the
            # conv stages plan the fused-epilogue backward (mask-scatter
            # dgrad, no recompute entry) whenever the plane tiles evenly.
            bwd = cl.plan_bwd(x_shape, w_shape, stride=1, padding=F // 2,
                              pool=2, in_bytes=in_bytes, machine=machine,
                              mesh=mesh, shard_axis=shard_axis,
                              autotune=autotune)
        else:
            bwd = fl.plan_bwd(x_shape, w_shape, in_bytes=in_bytes,
                              machine=machine, mesh=mesh,
                              shard_axis=shard_axis, autotune=autotune)
        for k, s in bwd.items():
            out[f"{name}.{k}"] = s
    return out
