"""Dense decoder-only transformer (gemma3 / qwen3 / qwen1.5 / chameleon).

Layers are stacked (leading L dim) and executed with lax.scan so the HLO is
one layer body regardless of depth.  Per-layer heterogeneity (gemma3's 5:1
local:global attention with different RoPE bases) is expressed as scanned
per-layer scalars (window, theta), not as distinct HLO.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as ll
from repro.models.module import ParamDef


def param_defs(cfg: ModelConfig) -> dict:
    L, d = cfg.n_layers, cfg.d_model
    return {
        **ll.embed_defs(cfg),
        "layers": {
            "ln1": ParamDef((L, d), (None, None), init="zeros"),
            "ln2": ParamDef((L, d), (None, None), init="zeros"),
            "attn": ll.attn_defs(cfg, L),
            "mlp": ll.mlp_defs(cfg, L),
        },
    }


def layer_meta(cfg: ModelConfig) -> dict:
    """Per-layer (window, theta) arrays; window -1 means full attention."""
    L = cfg.n_layers
    idx = jnp.arange(L)
    if cfg.global_every:
        is_global = (idx + 1) % cfg.global_every == 0
        window = jnp.where(is_global, -1, cfg.local_window or -1)
        theta = jnp.where(
            is_global, cfg.rope_theta_global or cfg.rope_theta, cfg.rope_theta
        )
    else:
        window = jnp.full((L,), cfg.local_window or -1, jnp.int32)
        theta = jnp.full((L,), cfg.rope_theta, jnp.float32)
    return {"window": window.astype(jnp.int32), "theta": theta.astype(jnp.float32)}


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    """KV cache [L, B, Smax, Hkv, Dh] per tensor."""
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.resolved_head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def forward(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,  # [B, S] int32
    *,
    pos0=0,
    cache: dict | None = None,
    remat: str = "none",
    compute_dtype=jnp.bfloat16,
    parallel=None,
):
    """Returns (hidden [B, S, d], new_cache)."""
    from repro.runtime.parallel import constrain

    x = ll.embed_tokens(params, tokens, cfg, compute_dtype)
    x = constrain(x, parallel, ("dp", None, None))
    meta = layer_meta(cfg)

    def body(x, xs):
        lp, window, theta, ck, cv = xs
        h, new_cache = _block(x, lp, cfg, window, theta, pos0,
                              (ck, cv) if cache is not None else None, parallel)
        return h, new_cache

    if remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
            prevent_cse=False,
        )

    ck = cache["k"] if cache is not None else jnp.zeros((cfg.n_layers,))
    cv = cache["v"] if cache is not None else jnp.zeros((cfg.n_layers,))
    x, caches = jax.lax.scan(
        body, x, (params["layers"], meta["window"], meta["theta"], ck, cv)
    )
    new_cache = None
    if cache is not None:
        new_cache = {"k": caches[0], "v": caches[1]}
    return x, new_cache


def _block(x, lp, cfg, window, theta, pos0, cache, parallel=None):
    h = ll.rms_norm(x, lp["ln1"], cfg.norm_eps)
    h, new_cache = ll.apply_attention(
        lp["attn"], h, cfg, pos0=pos0, window=window, theta=theta, cache=cache,
        parallel=parallel,
    )
    x = x + h
    h = ll.rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = x + ll.apply_mlp(lp["mlp"], h, cfg.act, parallel)
    if cache is None:
        new_cache = (jnp.zeros((), x.dtype), jnp.zeros((), x.dtype))
    return x, new_cache


def logits(cfg: ModelConfig, params: dict, hidden: jax.Array) -> jax.Array:
    return ll.logits_from_hidden(params, hidden, cfg)
