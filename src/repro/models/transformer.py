"""Dense decoder-only transformer (gemma3 / qwen3 / qwen1.5 / chameleon).

Layers are stacked (leading L dim) and executed with lax.scan so the HLO is
one layer body regardless of depth.  Per-layer heterogeneity (gemma3's 5:1
local:global attention with different RoPE bases) is expressed as scanned
per-layer scalars (window, theta), not as distinct HLO.

The planned wing (DESIGN.md Sec. 11): ``forward(..., use_kernels=True,
schedules=plan_training(...))`` runs every GEMM of the block through the
planned ``fc_layer`` (Alg 4/5 Pallas kernel, planned dX/dW backward) and
the attention cell through the planned flash-attention kernel — the same
schedule-pinning contract as ``models/cnn.py``, with
:class:`repro.plan.TransformerBlockPlanner` owning the delegation table
(qkv/wo/mlp GEMMs -> MatmulPlanner, attn -> AttentionPlanner).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.fc_layer import fc_layer
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.models import layers as ll
from repro.models.module import ParamDef
from repro.plan import local_schedule, with_reference_vjp


def param_defs(cfg: ModelConfig) -> dict:
    L, d = cfg.n_layers, cfg.d_model
    return {
        **ll.embed_defs(cfg),
        "layers": {
            "ln1": ParamDef((L, d), (None, None), init="zeros"),
            "ln2": ParamDef((L, d), (None, None), init="zeros"),
            "attn": ll.attn_defs(cfg, L),
            "mlp": ll.mlp_defs(cfg, L),
        },
    }


def layer_meta(cfg: ModelConfig) -> dict:
    """Per-layer (window, theta) arrays; window -1 means full attention."""
    L = cfg.n_layers
    idx = jnp.arange(L)
    if cfg.global_every:
        is_global = (idx + 1) % cfg.global_every == 0
        window = jnp.where(is_global, -1, cfg.local_window or -1)
        theta = jnp.where(
            is_global, cfg.rope_theta_global or cfg.rope_theta, cfg.rope_theta
        )
    else:
        window = jnp.full((L,), cfg.local_window or -1, jnp.int32)
        theta = jnp.full((L,), cfg.rope_theta, jnp.float32)
    return {"window": window.astype(jnp.int32), "theta": theta.astype(jnp.float32)}


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    """KV cache [L, B, Smax, Hkv, Dh] per tensor."""
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.resolved_head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def forward(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,  # [B, S] int32
    *,
    pos0=0,
    cache: dict | None = None,
    remat: str = "none",
    compute_dtype=jnp.bfloat16,
    parallel=None,
    use_kernels: bool = False,
    schedules: dict | None = None,
):
    """Returns (hidden [B, S, d], new_cache).

    ``use_kernels=True`` (training only — no cache) runs the planned
    wing: every projection GEMM through the Pallas ``fc_layer`` and the
    attention cell through the planned flash-attention kernel.
    ``schedules`` maps cell names ("qkv", "attn", "wo", "mlp_up",
    "mlp_down") to explicit :class:`repro.plan.Schedule` objects (from
    :func:`plan_forward`); backward overrides ride in the same dict under
    "<cell>.dx"/"<cell>.dw" keys, which :func:`plan_training` emits — so
    ``jax.grad`` through this forward runs pinned planned backward
    kernels (attention differentiates its XLA reference)."""
    from repro.runtime.parallel import constrain

    if use_kernels and cache is None:
        return _forward_planned(cfg, params, tokens, compute_dtype,
                                schedules, remat=remat), None

    x = ll.embed_tokens(params, tokens, cfg, compute_dtype)
    x = constrain(x, parallel, ("dp", None, None))
    meta = layer_meta(cfg)

    def body(x, xs):
        lp, window, theta, ck, cv = xs
        h, new_cache = _block(x, lp, cfg, window, theta, pos0,
                              (ck, cv) if cache is not None else None, parallel)
        return h, new_cache

    if remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
            prevent_cse=False,
        )

    ck = cache["k"] if cache is not None else jnp.zeros((cfg.n_layers,))
    cv = cache["v"] if cache is not None else jnp.zeros((cfg.n_layers,))
    x, caches = jax.lax.scan(
        body, x, (params["layers"], meta["window"], meta["theta"], ck, cv)
    )
    new_cache = None
    if cache is not None:
        new_cache = {"k": caches[0], "v": caches[1]}
    return x, new_cache


def _block(x, lp, cfg, window, theta, pos0, cache, parallel=None):
    h = ll.rms_norm(x, lp["ln1"], cfg.norm_eps)
    h, new_cache = ll.apply_attention(
        lp["attn"], h, cfg, pos0=pos0, window=window, theta=theta, cache=cache,
        parallel=parallel,
    )
    x = x + h
    h = ll.rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = x + ll.apply_mlp(lp["mlp"], h, cfg.act, parallel)
    if cache is None:
        new_cache = (jnp.zeros((), x.dtype), jnp.zeros((), x.dtype))
    return x, new_cache


def _bwd_for(sched: dict, cell: str) -> dict | None:
    """The backward-Schedule overrides of one cell: ``{"qkv.dx": s}`` style
    keys (see :func:`plan_training`) become ``{"dx": s}``."""
    prefix = cell + "."
    out = {k[len(prefix):]: v for k, v in sched.items() if k.startswith(prefix)}
    return out or None


def _attn_kernel(q, k, v, causal, window, schedule):
    return flash_attention(q, k, v, causal=causal, window=window,
                           schedule=schedule)


def _attn_ref(q, k, v, causal, window, schedule):
    del schedule  # blocking never changes numerics
    return attention_ref(q, k, v, causal=causal, window=window)


# The planned attention cell: forward is the flash-attention Pallas kernel
# under its AttentionPlanner schedule, backward differentiates the XLA
# reference composition (the flash op itself registers no custom VJP — no
# planned attention backward exists yet; the GEMM cells do, via fc_layer).
_attn_vjp = with_reference_vjp(_attn_kernel, _attn_ref,
                               nondiff_argnums=(3, 4, 5))


def _forward_planned(cfg: ModelConfig, params: dict, tokens: jax.Array,
                     compute_dtype, schedules: dict | None,
                     remat: str = "none") -> jax.Array:
    """The planned training forward: hidden [B, S, d] (no cache).

    Cell decomposition mirrors ``TransformerBlockPlanner.cell_planners``:
    q/k/v fold into ONE fused ``[B*S, d] @ [d, (Hq+2*Hkv)*Dh]`` GEMM (one
    x stream for all three projections), gate+up into one
    ``[B*S, d] @ [d, 2*ff]`` GEMM, and attention runs on the [B, H, S, D]
    layout the flash kernel wants.  Per-layer heterogeneous windows
    (``global_every``) would make the attention cell's window a traced
    scan carry, which a pinned static schedule cannot express.
    """
    if cfg.global_every:
        raise ValueError(
            "planned transformer forward needs one static attention "
            f"window; global_every={cfg.global_every} mixes per-layer "
            "windows inside the scanned block (use the XLA path)")
    sched = schedules or {}
    cd = jnp.dtype(compute_dtype)
    x = ll.embed_tokens(params, tokens, cfg, cd)
    B, S, d = x.shape
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    pos = jnp.arange(S, dtype=jnp.int32)
    window = cfg.local_window or None
    s_attn = local_schedule(sched.get("attn"))

    def body(x, lp):
        ap, mp = lp["attn"], lp["mlp"]
        h = ll.rms_norm(x, lp["ln1"], cfg.norm_eps)
        w_qkv = jnp.concatenate(
            [ap["wq"].reshape(d, Hq * Dh), ap["wk"].reshape(d, Hkv * Dh),
             ap["wv"].reshape(d, Hkv * Dh)], axis=1).astype(cd)
        qkv = fc_layer(h.reshape(B * S, d), w_qkv, sched.get("qkv"),
                       _bwd_for(sched, "qkv"))
        q, k, v = jnp.split(qkv, [Hq * Dh, (Hq + Hkv) * Dh], axis=-1)
        q = q.reshape(B, S, Hq, Dh)
        k = k.reshape(B, S, Hkv, Dh)
        v = v.reshape(B, S, Hkv, Dh)
        if cfg.qkv_bias:
            q = q + ap["bq"].astype(cd)
            k = k + ap["bk"].astype(cd)
            v = v + ap["bv"].astype(cd)
        if cfg.qk_norm:
            q = ll.rms_norm(q, ap["q_norm"], cfg.norm_eps)
            k = ll.rms_norm(k, ap["k_norm"], cfg.norm_eps)
        q = ll.rope(q, pos, cfg.rope_theta)
        k = ll.rope(k, pos, cfg.rope_theta)
        o = _attn_vjp(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                      v.transpose(0, 2, 1, 3), True, window, s_attn)
        o = o.transpose(0, 2, 1, 3).reshape(B * S, Hq * Dh)
        wo = ap["wo"].reshape(Hq * Dh, d).astype(cd)
        x = x + fc_layer(o, wo, sched.get("wo"),
                         _bwd_for(sched, "wo")).reshape(B, S, d)
        h = ll.rms_norm(x, lp["ln2"], cfg.norm_eps)
        w_gu = jnp.concatenate([mp["w_gate"], mp["w_up"]], axis=1).astype(cd)
        gu = fc_layer(h.reshape(B * S, d), w_gu, sched.get("mlp_up"),
                      _bwd_for(sched, "mlp_up"))
        g, u = jnp.split(gu, 2, axis=-1)
        down = fc_layer(ll._ACT[cfg.act](g) * u, mp["w_down"].astype(cd),
                        sched.get("mlp_down"), _bwd_for(sched, "mlp_down"))
        return x + down.reshape(B, S, d), None

    if remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
            prevent_cse=False,
        )
    x, _ = jax.lax.scan(body, x, params["layers"])
    return x


def logits(cfg: ModelConfig, params: dict, hidden: jax.Array, *,
           schedules: dict | None = None) -> jax.Array:
    """Hidden -> [B, S, vocab].  With a "logits" entry in ``schedules``
    (from :func:`plan_forward`, planned at the chunked-CE token-chunk
    size) the head runs the planned ``fc_layer`` GEMM; backward overrides
    ride under "logits.dx"/"logits.dw"."""
    sched = schedules or {}
    s = sched.get("logits")
    if s is None:
        return ll.logits_from_hidden(params, hidden, cfg)
    x = ll.rms_norm(hidden, params["final_norm"], cfg.norm_eps)
    B, S, d = x.shape
    w = (params["embed"].T if cfg.tie_embeddings
         else params["w_out"]).astype(x.dtype)
    out = fc_layer(x.reshape(B * S, d), w, s, _bwd_for(sched, "logits"))
    return out.reshape(B, S, -1)


def _chunk_m(batch: int, seq: int, loss_chunks: int) -> int:
    """The logits GEMM's M: chunked_ce's token-chunk row count — its
    ``while S % n: n -= 1`` divisor adjustment, verbatim."""
    n = max(1, loss_chunks)
    while seq % n:
        n -= 1
    return batch * (seq // n)


def plan_forward(cfg: ModelConfig, batch: int, seq: int, *,
                 loss_chunks: int = 1, in_bytes: int = 4, machine=None,
                 mesh=None, shard_axis: str = "data",
                 autotune=None) -> dict:
    """Plan every kernel launch of the planned :func:`forward` plus the
    :func:`logits` head, without running them.

    Returns {cell name: Schedule} keyed qkv/attn/wo/mlp_up/mlp_down/logits
    — the delegation table is ``TransformerBlockPlanner.cell_planners``
    (matmul cells to MatmulPlanner, the attention cell to
    AttentionPlanner), each cell resolved through the autotune cache like
    every other op.  The logits cell is planned at the *chunk* M that
    ``runtime.train.chunked_ce`` actually calls (``loss_chunks``), not the
    full token count.  With ``mesh=`` every cell comes back as a
    ShardedSchedule — the GEMM cells' tp/batch/psum/ring argmin per cell
    (DESIGN.md Sec. 11).
    """
    from repro.core.machine import TPU_V5E
    from repro.plan import autotune as at
    from repro.plan.planners import TransformerBlockPlanner

    machine = machine or TPU_V5E
    cells = TransformerBlockPlanner(machine).cell_planners(
        batch=batch, seq=seq, d_model=cfg.d_model, n_heads=cfg.n_heads,
        d_ff=cfg.d_ff, n_kv_heads=cfg.n_kv_heads, in_bytes=in_bytes,
        causal=True)
    out = {name: at.resolve(planner.op, kw, machine=machine, mesh=mesh,
                            axis=shard_axis, policy=autotune)
           for name, (planner, kw) in cells.items()}
    out["logits"] = at.resolve(
        "matmul",
        dict(m=_chunk_m(batch, seq, loss_chunks), n=cfg.vocab,
             k=cfg.d_model, in_bytes=in_bytes),
        machine=machine, mesh=mesh, axis=shard_axis, policy=autotune)
    return out


def plan_training(cfg: ModelConfig, batch: int, seq: int, *,
                  loss_chunks: int = 1, in_bytes: int = 4, machine=None,
                  mesh=None, shard_axis: str = "data",
                  autotune=None) -> dict:
    """:func:`plan_forward` plus every planned backward kernel
    ``jax.grad`` runs: "<cell>.dx"/"<cell>.dw" for each GEMM cell (the
    fused dX/dW kernel when it fits; the attention cell differentiates
    its XLA reference, so it contributes no backward entries).  Pass the
    result via ``schedules=`` so the whole train step executes pinned
    planned kernels — the same contract as ``cnn.plan_training``."""
    from repro.core import fc_layer as fl

    out = plan_forward(cfg, batch, seq, loss_chunks=loss_chunks,
                       in_bytes=in_bytes, machine=machine, mesh=mesh,
                       shard_axis=shard_axis, autotune=autotune)
    d, ff = cfg.d_model, cfg.d_ff
    Hq = cfg.n_heads
    Hkv = cfg.n_kv_heads or Hq
    Dh = cfg.resolved_head_dim
    m = batch * seq
    gemms = {
        "qkv": (m, d, (Hq + 2 * Hkv) * Dh),
        "wo": (m, Hq * Dh, d),
        "mlp_up": (m, d, 2 * ff),
        "mlp_down": (m, ff, d),
        "logits": (_chunk_m(batch, seq, loss_chunks), d, cfg.vocab),
    }
    for name, (mm, k, n) in gemms.items():
        bwd = fl.plan_bwd((mm, k), (k, n), in_bytes=in_bytes,
                          machine=machine, mesh=mesh, shard_axis=shard_axis,
                          autotune=autotune)
        for kk, s in bwd.items():
            out[f"{name}.{kk}"] = s
    return out


def make_loss_fn(cfg: ModelConfig, tcfg, parallel=None):
    """Family-registry hook (runtime.train.make_loss_fn dispatches here):
    the dense-transformer training loss.  Under ``tcfg.planned_kernels``
    the whole step runs planned Pallas kernels — :func:`plan_training`
    pins every cell's Schedule at trace time (batch/seq are static there,
    exactly like the cnn hook reads ``imgs.shape``), the planned forward
    executes them, and ``chunked_ce`` routes its logits GEMM through the
    planned head."""
    import sys

    from repro.runtime.train import chunked_ce

    dt = jnp.dtype(tcfg.compute_dtype)
    fam = sys.modules[__name__]

    def loss_fn(params, batch):
        tokens = batch["tokens"]
        if tcfg.planned_kernels:
            B, S = tokens.shape
            sched = plan_training(cfg, B, S, loss_chunks=tcfg.loss_chunks,
                                  in_bytes=dt.itemsize)
            h, _ = forward(cfg, params, tokens, compute_dtype=dt,
                           remat=tcfg.remat, use_kernels=True,
                           schedules=sched)
            return chunked_ce(cfg, fam, params, h, batch["labels"],
                              tcfg.loss_chunks, parallel, schedules=sched)
        h, _ = forward(cfg, params, tokens, remat=tcfg.remat,
                       compute_dtype=dt, parallel=parallel)
        return chunked_ce(cfg, fam, params, h, batch["labels"],
                          tcfg.loss_chunks, parallel)

    return loss_fn
