"""Mixture-of-Experts transformer (grok-1, qwen3-moe).

Expert dispatch follows the paper's Alg 4 structure (DESIGN.md Sec. 4):
every TP device keeps a *private partial output* for the experts it owns
and the partials are summed by one reduction (psum over the `model` axis),
exactly like Manticore clusters reducing their private FC output volumes.

Concretely, inside ``shard_map`` over the mesh:
  * routing (softmax + top-k) is computed redundantly on every device from
    replicated router weights - no collective;
  * if E % tp == 0 (qwen3-moe): experts are sharded over `model` (EP);
    each device scatters only the tokens routed to *its* experts into an
    [E_loc, C_loc, d] buffer (local capacity C_loc = ceil(k*T_loc/E * cf)),
    runs its expert FFNs, and contributes zeros elsewhere;
  * else (grok-1, E=8 < tp=16): experts are replicated and d_ff is sharded
    (TP-within-expert); every device computes all experts on a 1/tp slice
    of the hidden dim;
  * one psum over `model` combines the partials. Tokens stay sharded over
    the data axes throughout - token traffic never crosses the data axis.

Single-device (smoke-test) path is the same math without the psum.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers as ll
from repro.models import transformer as tf
from repro.models.module import ParamDef
from repro.core.shard_compat import shard_map

param_count_note = "MoE params = dense attn + E * expert FFN"


def param_defs(cfg: ModelConfig) -> dict:
    L, d, ff, E = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.n_experts
    ep = E % 16 == 0  # expert-parallel vs TP-within-expert
    es = "model" if ep else None
    ffs = None if ep else ll.ff_spec(ff)
    return {
        **ll.embed_defs(cfg),
        "layers": {
            "ln1": ParamDef((L, d), (None, None), init="zeros"),
            "ln2": ParamDef((L, d), (None, None), init="zeros"),
            "attn": ll.attn_defs(cfg, L),
            "moe": {
                "router": ParamDef((L, d, E), (None, None, None), fan_in_axis=1),
                "w_gate": ParamDef((L, E, d, ff), (None, es, None, ffs), fan_in_axis=2),
                "w_up": ParamDef((L, E, d, ff), (None, es, None, ffs), fan_in_axis=2),
                # stored [E, d, ff] like w_gate/w_up: avoids XLA layout-transposing
                # the whole stack at the shard_map boundary (see EXPERIMENTS Perf)
                "w_down": ParamDef((L, E, d, ff), (None, es, None, ffs), fan_in_axis=3),
            },
        },
    }


def _moe_local(xt, mp, cfg: ModelConfig, e_offset: int, n_local: int, act: str):
    """Token dispatch + expert FFN for the local expert slice.

    ``xt``: [T, d] local tokens; ``mp``: router [d, E] + expert weights with
    a leading local-expert dim [E_loc, ...]. Returns the *partial* output
    [T, d] (zero rows for tokens owned by other devices' experts).
    """
    T, d = xt.shape
    E, k = cfg.n_experts, cfg.moe_top_k
    E_loc = mp["w_gate"].shape[0]

    logits = (xt.astype(jnp.float32) @ mp["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, -1)  # [T, E]
    gates, idx = jax.lax.top_k(probs, k)  # [T, k]
    gates = gates / gates.sum(-1, keepdims=True)

    # Slot-major flattening: slot 0 (highest gate) gets capacity priority.
    idx_f = idx.T.reshape(k * T)  # [kT]
    gate_f = gates.T.reshape(k * T)
    tok_f = jnp.tile(jnp.arange(T, dtype=jnp.int32), (k,))

    cap = max(1, math.ceil(k * T / E * cfg.capacity_factor))
    # Position-within-expert via stable sort over int32 keys: O(kT log kT)
    # int traffic instead of the [kT, E] one-hot cumsum (which cost
    # ~80 TB/device of HBM on qwen3-moe prefill — see EXPERIMENTS Sec. Perf).
    # Stable sort preserves row order within an expert, so positions are
    # bit-identical to the cumsum formulation.
    order = jnp.argsort(idx_f, stable=True)  # [kT]
    sorted_e = idx_f[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=idx_f.dtype))  # [E]
    rank_sorted = jnp.arange(k * T, dtype=jnp.int32) - starts[sorted_e].astype(jnp.int32)
    pos_f = jnp.zeros((k * T,), jnp.int32).at[order].set(rank_sorted)

    e_loc = idx_f - e_offset
    valid = (pos_f < cap) & (e_loc >= 0) & (e_loc < n_local)
    slot = jnp.where(valid, e_loc * cap + pos_f, n_local * cap)  # overflow row

    buf = jnp.zeros((n_local * cap + 1, d), xt.dtype).at[slot].set(xt[tok_f])
    expert_in = buf[:-1].reshape(n_local, cap, d)

    cd = xt.dtype
    h = ll._ACT[act](
        jnp.einsum("ecd,edf->ecf", expert_in, mp["w_gate"].astype(cd))
    ) * jnp.einsum("ecd,edf->ecf", expert_in, mp["w_up"].astype(cd))
    h = jnp.einsum("ecf,edf->ecd", h, mp["w_down"].astype(cd))  # [E_loc, C, d]

    h_pad = jnp.concatenate([h.reshape(n_local * cap, d), jnp.zeros((1, d), cd)], 0)
    y_rows = jnp.where(valid[:, None], h_pad[slot], 0.0)  # [kT, d]
    y = (gate_f[:, None].astype(cd) * y_rows).reshape(k, T, d).sum(0)
    del E_loc
    return y


def apply_moe_ffn(mp, x, cfg: ModelConfig, parallel=None):
    """x: [B, S, d] -> [B, S, d].  ``parallel``: runtime ParallelCtx or None."""
    B, S, d = x.shape
    E = cfg.n_experts
    ep = E % 16 == 0

    if parallel is None:
        xt = x.reshape(B * S, d)
        y = _moe_local(xt, mp, cfg, e_offset=0, n_local=E, act=cfg.act)
        return y.reshape(B, S, d)

    mesh, dp, tp = parallel.mesh, parallel.dp_axes, parallel.tp_axis
    tp_size = mesh.shape[tp]
    n_local = E // tp_size if ep else E

    # w_down shares [E, d, ff] layout/spec with w_gate/w_up.
    wspec = dspec = P(tp, None, None) if ep else P(None, None, ll.ff_spec(cfg.d_ff))

    def fn(xl, router, wg, wu, wd):
        Bl, Sl, _ = xl.shape
        xt = xl.reshape(Bl * Sl, d)
        e_off = jax.lax.axis_index(tp) * n_local if ep else 0
        mp_loc = {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}
        y = _moe_local(xt, mp_loc, cfg, e_offset=e_off, n_local=n_local, act=cfg.act)
        y = jax.lax.psum(y, tp)
        return y.reshape(Bl, Sl, d)

    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(dp, None, None), P(None, None), wspec, wspec, dspec),
        out_specs=P(dp, None, None),
        check_vma=False,
    )(x, mp["router"], mp["w_gate"], mp["w_up"], mp["w_down"])


def layer_meta(cfg):
    return tf.layer_meta(cfg)


def init_cache(cfg, batch, max_seq, dtype=jnp.bfloat16):
    return tf.init_cache(cfg, batch, max_seq, dtype)


def forward(
    cfg: ModelConfig, params: dict, tokens, *, pos0=0, cache=None,
    remat: str = "none", compute_dtype=jnp.bfloat16, parallel=None,
):
    from repro.runtime.parallel import constrain

    x = ll.embed_tokens(params, tokens, cfg, compute_dtype)
    x = constrain(x, parallel, ("dp", None, None))
    meta = tf.layer_meta(cfg)

    def body(x, xs):
        lp, window, theta, ck, cv = xs
        h = ll.rms_norm(x, lp["ln1"], cfg.norm_eps)
        h, new_cache = ll.apply_attention(
            lp["attn"], h, cfg, pos0=pos0, window=window, theta=theta,
            cache=(ck, cv) if cache is not None else None, parallel=parallel,
        )
        x = x + h
        h = ll.rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + apply_moe_ffn(lp["moe"], h, cfg, parallel)
        if cache is None:
            new_cache = (jnp.zeros((), x.dtype), jnp.zeros((), x.dtype))
        return x, new_cache

    if remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)

    ck = cache["k"] if cache is not None else jnp.zeros((cfg.n_layers,))
    cv = cache["v"] if cache is not None else jnp.zeros((cfg.n_layers,))
    x, caches = jax.lax.scan(
        body, x, (params["layers"], meta["window"], meta["theta"], ck, cv)
    )
    new_cache = {"k": caches[0], "v": caches[1]} if cache is not None else None
    return x, new_cache


def logits(cfg, params, hidden):
    return ll.logits_from_hidden(params, hidden, cfg)
