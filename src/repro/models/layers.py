"""Shared layer library: norms, RoPE, GQA attention block, MLPs, embeddings.

Parameter layout conventions (see module.py):
* stacked-layer params carry a leading L dim with spec entry None;
* attention projections are kept 4D ([d, H, Dh]) so head/head-dim sharding
  is expressed directly in the PartitionSpec (no reshape ambiguity under
  GSPMD);
* sharding spec helpers pick the TP axis by divisibility (DESIGN.md Sec. 5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import attention
from repro.models.module import ParamDef

MODEL_AXIS = "model"


def head_axis_spec(n_heads: int, head_dim: int, tp: int = 16):
    """(head_axis, dh_axis): shard heads if divisible, else replicate.

    Never shard head_dim: a Dh-sharded QK^T contraction forces a psum of
    every logits block (measured: 2.4 TB/device of all-reduce on gemma3
    prefill) plus involuntary SPMD rematerialization.  GQA KV heads that
    don't divide tp are replicated, Megatron-style; undividable Q heads
    fall back to sequence-parallel attention (attention.py)."""
    if n_heads % tp == 0:
        return (MODEL_AXIS, None)
    return (None, None)


def ff_spec(d_ff: int, tp: int = 16):
    return MODEL_AXIS if d_ff % tp == 0 else None


# --- norms -----------------------------------------------------------------


def rms_norm(x, w, eps=1e-6):
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (xf * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, w, b, eps=1e-6):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (xf * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


# --- RoPE ------------------------------------------------------------------


def rope(x, pos, theta):
    """x: [B, S, H, D]; pos: [S] int32; theta: scalar (may be traced)."""
    B, S, H, D = x.shape
    half = D // 2
    freq = jnp.exp(
        -jnp.log(jnp.asarray(theta, jnp.float32)) * jnp.arange(half, dtype=jnp.float32) / half
    )
    ang = pos.astype(jnp.float32)[:, None] * freq[None, :]  # [S, half]
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1).astype(x.dtype)


# --- attention block -------------------------------------------------------


def attn_defs(cfg: ModelConfig, L: int, layers_prefix: bool = True) -> dict:
    """Parameter defs for one (stacked) GQA attention block."""
    d, Hq, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    hs = head_axis_spec(Hq, Dh)
    khs = head_axis_spec(Hkv, Dh)
    lead = (L,) if layers_prefix else ()
    ls = (None,) if layers_prefix else ()
    defs = {
        "wq": ParamDef(lead + (d, Hq, Dh), ls + (None,) + hs, fan_in_axis=len(lead)),
        "wk": ParamDef(lead + (d, Hkv, Dh), ls + (None,) + khs, fan_in_axis=len(lead)),
        "wv": ParamDef(lead + (d, Hkv, Dh), ls + (None,) + khs, fan_in_axis=len(lead)),
        "wo": ParamDef(lead + (Hq, Dh, d), ls + hs + (None,), fan_in_axis=len(lead)),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef(lead + (Hq, Dh), ls + hs, init="zeros")
        defs["bk"] = ParamDef(lead + (Hkv, Dh), ls + khs, init="zeros")
        defs["bv"] = ParamDef(lead + (Hkv, Dh), ls + khs, init="zeros")
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef(lead + (Dh,), ls + (None,), init="zeros")
        defs["k_norm"] = ParamDef(lead + (Dh,), ls + (None,), init="zeros")
    return defs


def apply_attention(
    p: dict,
    x: jax.Array,  # [B, S, d]
    cfg: ModelConfig,
    *,
    pos0,  # scalar int: absolute position of x[:, 0]
    window=None,  # None | int | traced scalar (<0 = full)
    theta=None,  # rope theta (scalar, may be traced)
    cache: tuple | None = None,  # (k_cache, v_cache) [B, Smax, Hkv, Dh]
    causal: bool = True,
    parallel=None,
):
    """Returns (out [B, S, d], new_cache)."""
    from repro.runtime.parallel import constrain

    B, S, d = x.shape
    Dh = cfg.resolved_head_dim
    theta = cfg.rope_theta if theta is None else theta
    cd = x.dtype

    tp = parallel.tp_size if parallel is not None else 16
    hspec = ("dp", None) + head_axis_spec(cfg.n_heads, Dh, tp)
    kspec = ("dp", None) + head_axis_spec(cfg.n_kv_heads, Dh, tp)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cd))
    q = constrain(q, parallel, hspec)
    k = constrain(k, parallel, kspec)
    v = constrain(v, parallel, kspec)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)

    q_pos = pos0 + jnp.arange(S, dtype=jnp.int32)
    q = rope(q, q_pos, theta)
    k = rope(k, q_pos, theta)

    if cache is not None:
        ck, cv = cache
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, pos0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, pos0, 0, 0))
        k_pos = jnp.arange(ck.shape[1], dtype=jnp.int32)
        out = attention(
            q, ck.astype(cd), cv.astype(cd),
            q_pos=q_pos, k_pos=k_pos, causal=causal, window=window, scale=Dh**-0.5,
            parallel=parallel,
        )
        new_cache = (ck, cv)
    else:
        out = attention(
            q, k, v, q_pos=q_pos, k_pos=q_pos, causal=causal, window=window,
            scale=Dh**-0.5, parallel=parallel,
        )
        new_cache = None

    out = constrain(out, parallel, hspec)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))
    out = constrain(out, parallel, ("dp", None, None))
    return out, new_cache


# --- MLP -------------------------------------------------------------------

_ACT = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def mlp_defs(cfg: ModelConfig, L: int, d_ff: int | None = None) -> dict:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    s = ff_spec(ff)
    return {
        "w_gate": ParamDef((L, d, ff), (None, None, s), fan_in_axis=1),
        "w_up": ParamDef((L, d, ff), (None, None, s), fan_in_axis=1),
        "w_down": ParamDef((L, ff, d), (None, s, None), fan_in_axis=1),
    }


def apply_mlp(p: dict, x: jax.Array, act: str = "silu", parallel=None) -> jax.Array:
    from repro.runtime.parallel import constrain

    cd = x.dtype
    h = _ACT[act](x @ p["w_gate"].astype(cd)) * (x @ p["w_up"].astype(cd))
    h = constrain(h, parallel, ("dp", None, "tp?"))
    out = h @ p["w_down"].astype(cd)
    return constrain(out, parallel, ("dp", None, None))


# --- embeddings ------------------------------------------------------------


def embed_defs(cfg: ModelConfig, tp: int = 16) -> dict:
    # Vocab-shard when divisible (most archs); else shard d_model
    # (seamless-m4t's 256206 vocab is not 16-divisible).
    if cfg.vocab % tp == 0:
        espec, ospec = (MODEL_AXIS, None), (None, MODEL_AXIS)
    elif cfg.d_model % tp == 0:
        espec, ospec = (None, MODEL_AXIS), (MODEL_AXIS, None)
    else:
        espec, ospec = (None, None), (None, None)
    defs = {
        "embed": ParamDef((cfg.vocab, cfg.d_model), espec, scale=1.0),
        "final_norm": ParamDef((cfg.d_model,), (None,), init="zeros"),
    }
    if not cfg.tie_embeddings:
        defs["w_out"] = ParamDef((cfg.d_model, cfg.vocab), ospec)
    return defs


def embed_tokens(p: dict, tokens: jax.Array, cfg: ModelConfig, dtype) -> jax.Array:
    x = p["embed"].astype(dtype)[tokens]
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model**0.5, dtype)  # gemma-style scale
    return x


def logits_from_hidden(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = rms_norm(x, p["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, p["embed"].astype(x.dtype))
    return x @ p["w_out"].astype(x.dtype)
