"""RWKV-6 "Finch" (attention-free, data-dependent decay).

Faithful structure: time-mix (token shift, r/k/v/g projections, per-channel
*data-dependent* decay w_t = exp(-exp(w0 + lora(x))), bonus u, per-head WKV
state S in R^{Dk x Dv}, group-norm, gate) + channel-mix (token shift,
squared-ReLU FFN with receptance gate).  Simplification recorded in
DESIGN.md: the 5-way dynamic token-shift interpolation of the reference
implementation is reduced to static per-channel lerps; the decay stays
data-dependent (the feature the assignment calls out).

The WKV recurrence is a lax.scan over time; the paper's conv/FC schedules
do not apply to it (DESIGN.md Sec. Arch-applicability) but every projection
uses the FC-layer (Alg 4/5) blocking/sharding rules.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as ll
from repro.models.module import ParamDef

_LORA = 64


def _heads(cfg: ModelConfig) -> tuple[int, int]:
    hd = cfg.ssm_head_dim
    return cfg.d_model // hd, hd


def param_defs(cfg: ModelConfig) -> dict:
    L, d, ff = cfg.n_layers, cfg.d_model, cfg.d_ff
    H, hd = _heads(cfg)
    hs = ll.head_axis_spec(H, hd)
    ds = "model" if d % 16 == 0 else None
    ffs = ll.ff_spec(ff)
    lead, ls = (L,), (None,)
    return {
        **ll.embed_defs(cfg),
        "layers": {
            "ln1": ParamDef(lead + (d,), ls + (None,), init="zeros"),
            "ln2": ParamDef(lead + (d,), ls + (None,), init="zeros"),
            "tm": {  # time mix
                "maa_r": ParamDef(lead + (d,), ls + (None,), init="zeros"),
                "maa_k": ParamDef(lead + (d,), ls + (None,), init="zeros"),
                "maa_v": ParamDef(lead + (d,), ls + (None,), init="zeros"),
                "maa_w": ParamDef(lead + (d,), ls + (None,), init="zeros"),
                "maa_g": ParamDef(lead + (d,), ls + (None,), init="zeros"),
                "w0": ParamDef(lead + (d,), ls + (None,), init="zeros"),
                "w_lora_a": ParamDef(lead + (d, _LORA), ls + (None, None), fan_in_axis=1),
                "w_lora_b": ParamDef(lead + (_LORA, d), ls + (None, ds), scale=0.01, fan_in_axis=1),
                "u": ParamDef(lead + (H, hd), ls + hs, init="zeros"),
                "wr": ParamDef(lead + (d, d), ls + (None, ds), fan_in_axis=1),
                "wk": ParamDef(lead + (d, d), ls + (None, ds), fan_in_axis=1),
                "wv": ParamDef(lead + (d, d), ls + (None, ds), fan_in_axis=1),
                "wg": ParamDef(lead + (d, d), ls + (None, ds), fan_in_axis=1),
                "wo": ParamDef(lead + (d, d), ls + (ds, None), fan_in_axis=1),
                "gn": ParamDef(lead + (d,), ls + (None,), init="zeros"),
            },
            "cm": {  # channel mix
                "maa_k": ParamDef(lead + (d,), ls + (None,), init="zeros"),
                "maa_r": ParamDef(lead + (d,), ls + (None,), init="zeros"),
                "wk": ParamDef(lead + (d, ff), ls + (None, ffs), fan_in_axis=1),
                "wv": ParamDef(lead + (ff, d), ls + (ffs, None), fan_in_axis=1),
                "wr": ParamDef(lead + (d, d), ls + (None, ds), fan_in_axis=1),
            },
        },
    }


def _shift(x, last):
    """Token shift: x_{t-1} with ``last`` filling t = 0.  x: [B, S, d]."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def _wkv(r, k, v, w, u, state):
    """WKV6 recurrence.  r/k/w: [B, S, H, Dk]; v: [B, S, H, Dv];
    u: [H, Dk]; state: [B, H, Dk, Dv].  Returns (y [B, S, H, Dv], state)."""

    def step(S, xs):
        rt, kt, vt, wt = xs  # [B, H, Dk] / [B, H, Dv]
        a = kt[..., :, None] * vt[..., None, :]  # [B, H, Dk, Dv]
        y = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * a)
        S = wt[..., :, None] * S + a
        return S, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, y = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(y, 0, 1), state


def _time_mix(p, x, cfg, H, hd, last_x, wkv_state):
    B, S, d = x.shape
    cd = x.dtype
    xx = _shift(x, last_x) - x
    mix = lambda m: x + xx * p[m].astype(cd)
    r = (mix("maa_r") @ p["wr"].astype(cd)).reshape(B, S, H, hd)
    k = (mix("maa_k") @ p["wk"].astype(cd)).reshape(B, S, H, hd)
    v = (mix("maa_v") @ p["wv"].astype(cd)).reshape(B, S, H, hd)
    g = jax.nn.silu(mix("maa_g") @ p["wg"].astype(cd))
    # Data-dependent decay (the Finch feature): w in (0, 1).
    xw = mix("maa_w").astype(jnp.float32)
    dec = p["w0"].astype(jnp.float32) + jnp.tanh(
        xw @ p["w_lora_a"].astype(jnp.float32)
    ) @ p["w_lora_b"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(dec)).reshape(B, S, H, hd)

    y, wkv_state = _wkv(
        r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        w, p["u"].astype(jnp.float32), wkv_state,
    )
    y = y.reshape(B, S, d)
    # Head-wise group norm (approximated per-channel RMS over head dim).
    y = y.reshape(B, S, H, hd)
    y = y * jax.lax.rsqrt(jnp.mean(y * y, -1, keepdims=True) + 1e-5)
    y = y.reshape(B, S, d) * (1.0 + p["gn"].astype(jnp.float32))
    out = (y.astype(cd) * g) @ p["wo"].astype(cd)
    return out, x[:, -1, :], wkv_state


def _channel_mix(p, x, cfg, last_x):
    cd = x.dtype
    xx = _shift(x, last_x) - x
    xk = x + xx * p["maa_k"].astype(cd)
    xr = x + xx * p["maa_r"].astype(cd)
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(cd)))
    return jax.nn.sigmoid(xr @ p["wr"].astype(cd)) * (k @ p["wv"].astype(cd)), x[:, -1, :]


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    """Recurrent state: O(1) in sequence length (why long_500k runs here)."""
    H, hd = _heads(cfg)
    L, d = cfg.n_layers, cfg.d_model
    return {
        "tm_x": jnp.zeros((L, batch, d), dtype),
        "cm_x": jnp.zeros((L, batch, d), dtype),
        "wkv": jnp.zeros((L, batch, H, hd, hd), jnp.float32),
    }


def forward(
    cfg: ModelConfig, params: dict, tokens, *, pos0=0, cache=None,
    remat: str = "none", compute_dtype=jnp.bfloat16, parallel=None,
):
    from repro.runtime.parallel import constrain

    B, S = tokens.shape
    H, hd = _heads(cfg)
    x = ll.embed_tokens(params, tokens, cfg, compute_dtype)
    x = constrain(x, parallel, ("dp", None, None))
    if cache is None:
        zero = {
            "tm_x": jnp.zeros((cfg.n_layers, B, cfg.d_model), compute_dtype),
            "cm_x": jnp.zeros((cfg.n_layers, B, cfg.d_model), compute_dtype),
            "wkv": jnp.zeros((cfg.n_layers, B, H, hd, hd), jnp.float32),
        }
        state = zero
    else:
        state = cache

    def body(x, xs):
        lp, tm_x, cm_x, wkv_s = xs
        h = ll.rms_norm(x, lp["ln1"], cfg.norm_eps)
        h, tm_x2, wkv_s2 = _time_mix(lp["tm"], h, cfg, H, hd, tm_x, wkv_s)
        x = x + h
        h = ll.rms_norm(x, lp["ln2"], cfg.norm_eps)
        h, cm_x2 = _channel_mix(lp["cm"], h, cfg, cm_x)
        x = x + h
        return x, (tm_x2, cm_x2, wkv_s2)

    if remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)

    x, new = jax.lax.scan(
        body, x, (params["layers"], state["tm_x"], state["cm_x"], state["wkv"])
    )
    new_cache = None
    if cache is not None:
        new_cache = {"tm_x": new[0], "cm_x": new[1], "wkv": new[2]}
    return x, new_cache


def logits(cfg, params, hidden):
    return ll.logits_from_hidden(params, hidden, cfg)


def layer_meta(cfg):
    return {}
