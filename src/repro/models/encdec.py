"""Encoder-decoder backbone (seamless-m4t-medium).

The audio frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings [B, T_enc, d_model]; a linear adapter maps them
into the encoder.  Encoder: bidirectional self-attention; decoder: causal
self-attention + cross-attention.  At prefill the cross K/V are computed
once from the encoder output and cached; decode never re-runs the encoder.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as ll
from repro.models.attention import attention
from repro.models.module import ParamDef


def param_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    Le, Ld = cfg.n_enc_layers, cfg.n_layers
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    hs = ll.head_axis_spec(Hq, Dh)
    khs = ll.head_axis_spec(Hkv, Dh)
    cross = {
        "wq": ParamDef((Ld, d, Hq, Dh), (None, None) + hs, fan_in_axis=1),
        "wk": ParamDef((Ld, d, Hkv, Dh), (None, None) + khs, fan_in_axis=1),
        "wv": ParamDef((Ld, d, Hkv, Dh), (None, None) + khs, fan_in_axis=1),
        "wo": ParamDef((Ld, Hq, Dh, d), (None,) + hs + (None,), fan_in_axis=1),
    }
    return {
        **ll.embed_defs(cfg),
        "adapter": ParamDef((d, d), (None, None)),
        "enc": {
            "ln1": ParamDef((Le, d), (None, None), init="zeros"),
            "ln2": ParamDef((Le, d), (None, None), init="zeros"),
            "attn": ll.attn_defs(cfg, Le),
            "mlp": ll.mlp_defs(cfg, Le),
        },
        "enc_norm": ParamDef((d,), (None,), init="zeros"),
        "dec": {
            "ln1": ParamDef((Ld, d), (None, None), init="zeros"),
            "ln_x": ParamDef((Ld, d), (None, None), init="zeros"),
            "ln2": ParamDef((Ld, d), (None, None), init="zeros"),
            "attn": ll.attn_defs(cfg, Ld),
            "cross": cross,
            "mlp": ll.mlp_defs(cfg, Ld),
        },
    }


def encode(cfg, params, frames, remat="none"):
    """frames: [B, T_enc, d_model] stub embeddings -> encoder output."""
    x = (frames @ params["adapter"].astype(frames.dtype))

    def body(x, lp):
        h = ll.rms_norm(x, lp["ln1"], cfg.norm_eps)
        h, _ = ll.apply_attention(lp["attn"], h, cfg, pos0=0, causal=False)
        x = x + h
        h = ll.rms_norm(x, lp["ln2"], cfg.norm_eps)
        return x + ll.apply_mlp(lp["mlp"], h, cfg.act), None

    if remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc"])
    return ll.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kv(lp_cross, memory):
    """Precompute cross-attention K/V from encoder memory: [B,T,Hkv,Dh]."""
    cd = memory.dtype
    k = jnp.einsum("btd,dhk->bthk", memory, lp_cross["wk"].astype(cd))
    v = jnp.einsum("btd,dhk->bthk", memory, lp_cross["wv"].astype(cd))
    return k, v


def _dec_block(x, lp, cfg, pos0, self_cache, xk, xv):
    cd = x.dtype
    Dh = cfg.resolved_head_dim
    h = ll.rms_norm(x, lp["ln1"], cfg.norm_eps)
    h, new_cache = ll.apply_attention(lp["attn"], h, cfg, pos0=pos0, cache=self_cache)
    x = x + h
    # Cross attention over encoder memory (no RoPE, not causal).
    h = ll.rms_norm(x, lp["ln_x"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, lp["cross"]["wq"].astype(cd))
    S, T = q.shape[1], xk.shape[1]
    out = attention(
        q, xk, xv,
        q_pos=pos0 + jnp.arange(S, dtype=jnp.int32),
        k_pos=jnp.arange(T, dtype=jnp.int32),
        causal=False, scale=Dh**-0.5,
    )
    x = x + jnp.einsum("bshk,hkd->bsd", out, lp["cross"]["wo"].astype(cd))
    h = ll.rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = x + ll.apply_mlp(lp["mlp"], h, cfg.act)
    return x, new_cache


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    Ld, Hkv, Dh = cfg.n_layers, cfg.n_kv_heads, cfg.resolved_head_dim
    T = cfg.enc_seq
    return {
        "k": jnp.zeros((Ld, batch, max_seq, Hkv, Dh), dtype),
        "v": jnp.zeros((Ld, batch, max_seq, Hkv, Dh), dtype),
        "xk": jnp.zeros((Ld, batch, T, Hkv, Dh), dtype),
        "xv": jnp.zeros((Ld, batch, T, Hkv, Dh), dtype),
    }


def forward(
    cfg: ModelConfig, params: dict, tokens, *, frames=None, pos0=0, cache=None,
    remat: str = "none", compute_dtype=jnp.bfloat16, parallel=None,
):
    """Train: frames + tokens, no cache.  Prefill: frames + cache.  Decode:
    cache only (cross K/V already cached)."""
    from repro.runtime.parallel import constrain

    x = ll.embed_tokens(params, tokens, cfg, compute_dtype)
    x = constrain(x, parallel, ("dp", None, None))

    if frames is not None:
        memory = encode(cfg, params, frames.astype(compute_dtype), remat)
        xk, xv = jax.vmap(
            lambda lp: _cross_kv(lp, memory), in_axes=(0,)
        )(params["dec"]["cross"])  # [Ld, B, T, Hkv, Dh]
    else:
        assert cache is not None, "decode needs cached cross K/V"
        xk, xv = cache["xk"], cache["xv"]

    def body(x, xs):
        lp, xk_l, xv_l, ck, cv = xs
        sc = (ck, cv) if cache is not None else None
        x, new_cache = _dec_block(x, lp, cfg, pos0, sc, xk_l.astype(x.dtype), xv_l.astype(x.dtype))
        if cache is None:
            new_cache = (jnp.zeros((), x.dtype), jnp.zeros((), x.dtype))
        return x, new_cache

    if remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)

    ck = cache["k"] if cache is not None else jnp.zeros((cfg.n_layers,))
    cv = cache["v"] if cache is not None else jnp.zeros((cfg.n_layers,))
    x, caches = jax.lax.scan(body, x, (params["dec"], xk, xv, ck, cv))

    new_cache = None
    if cache is not None:
        new_cache = {
            "k": caches[0], "v": caches[1],
            "xk": xk.astype(cache["xk"].dtype), "xv": xv.astype(cache["xv"].dtype),
        }
    return x, new_cache


def logits(cfg, params, hidden):
    return ll.logits_from_hidden(params, hidden, cfg)


def layer_meta(cfg):
    return {}
