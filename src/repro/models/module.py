"""Minimal pure-JAX module system.

Models are *data*, not objects: each model family provides

  ``param_defs(cfg) -> nested dict of ParamDef``
  ``apply(cfg, params, batch, ...) -> outputs``

From the defs we derive, without ever allocating a weight:
  * ``init_params``      - materialized pytree (deterministic per-path RNG)
  * ``abstract_params``  - jax.ShapeDtypeStruct pytree (dry-run / .lower())
  * ``param_specs``      - jax.sharding.PartitionSpec pytree (pjit shardings)
  * ``count_params``     - closed-form parameter count

Stacked layers (lax.scan over a leading L dim) are expressed simply by a
leading dimension in the def's shape with ``None`` as its spec entry.
"""

from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    # PartitionSpec entries: None | axis name | tuple of axis names.
    spec: tuple = ()
    init: str = "normal"  # normal | zeros | ones
    scale: float | None = None  # stddev; default 1/sqrt(fan_in)
    dtype: Any = None  # None -> the model's param dtype
    fan_in_axis: int = -2  # which axis is fan-in for default init scale

    def partition_spec(self) -> P:
        spec = self.spec or (None,) * len(self.shape)
        assert len(spec) == len(self.shape), (self.shape, spec)
        return P(*spec)


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _map_defs(fn, defs: dict, path: str = ""):
    out = {}
    for k, v in defs.items():
        p = f"{path}/{k}" if path else k
        out[k] = fn(p, v) if _is_def(v) else _map_defs(fn, v, p)
    return out


def init_params(defs: dict, key: jax.Array, dtype=jnp.float32) -> dict:
    """Materialize parameters; each leaf's RNG is folded from its path so
    init is order- and structure-stable."""

    def leaf(path: str, d: ParamDef):
        dt = d.dtype or dtype
        if d.init == "zeros":
            return jnp.zeros(d.shape, dt)
        if d.init == "ones":
            return jnp.ones(d.shape, dt)
        k = jax.random.fold_in(key, zlib.crc32(path.encode()) & 0x7FFFFFFF)
        fan_in = d.shape[d.fan_in_axis] if len(d.shape) >= 2 else d.shape[-1]
        scale = d.scale if d.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(k, d.shape, jnp.float32) * scale).astype(dt)

    return _map_defs(leaf, defs)


def abstract_params(defs: dict, dtype=jnp.float32) -> dict:
    return _map_defs(
        lambda _, d: jax.ShapeDtypeStruct(d.shape, d.dtype or dtype), defs
    )


def param_specs(defs: dict) -> dict:
    return _map_defs(lambda _, d: d.partition_spec(), defs)


def count_params(defs: dict) -> int:
    total = 0

    def leaf(_, d):
        nonlocal total
        total += math.prod(d.shape)
        return None

    _map_defs(leaf, defs)
    return total


def flatten_defs(defs: dict, path: str = ""):
    """Yield (path, ParamDef) pairs."""
    for k, v in defs.items():
        p = f"{path}/{k}" if path else k
        if _is_def(v):
            yield p, v
        else:
            yield from flatten_defs(v, p)
