"""``python -m repro.serve --smoke`` — the tier1.sh --serve-smoke gate."""

from repro.serve.loadgen import main

raise SystemExit(main())
