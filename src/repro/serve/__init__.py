"""repro.serve — planned inference serving (DESIGN.md Sec. 8).

The autotune cache as a serving artifact: a :class:`BucketLadder` of
pre-planned (batch, seq) shapes resolved once at warmup, a continuous-
batching :class:`Engine` over a KV slot pool, and a load generator with a
deterministic modeled-time mode for the committed serve benchmark.
"""

from repro.serve.bucket import Bucket, BucketLadder, bucket_cells
from repro.serve.engine import (
    ACTIVE,
    DONE,
    QUEUED,
    SHED,
    TIMEOUT,
    Engine,
    Request,
    RequestQueue,
    StepInfo,
    VirtualClock,
    WallClock,
)
from repro.serve.loadgen import LoadReport, LoadSpec, make_requests, run_load

__all__ = [
    "Bucket", "BucketLadder", "bucket_cells",
    "Engine", "Request", "RequestQueue", "StepInfo",
    "VirtualClock", "WallClock",
    "QUEUED", "ACTIVE", "DONE", "SHED", "TIMEOUT",
    "LoadSpec", "LoadReport", "make_requests", "run_load",
]
