"""Continuous-batching serving engine over the bucket ladder.

The loop (DESIGN.md Sec. 8 has the state machine):

  QUEUED -> ACTIVE   admit up to the free KV-slot count, pad the group to
                     the nearest covering bucket, run that bucket's
                     warmup-compiled prefill, scatter the cache rows into
                     free slots (first token comes from the prefill
                     logits);
  ACTIVE -> ACTIVE   one per-slot decode step over the whole slot pool
                     per engine step (each slot at its own position);
  ACTIVE -> DONE     length / EOS reached: retire, free the slot, and the
                     next admit backfills it;
  * -> SHED/TIMEOUT  graceful degradation: the queue sheds on overflow,
                     deadlines expire both queued and active requests.

Everything shape-dependent — bucket schedules through ``plan.autotune``,
jit compilation of the bucket prefills and the slot decode — happens in
:meth:`Engine.warmup`, once.  The request path (submit/step) never plans,
tunes, or traces a new shape; tests/test_serve.py spies on the
autotuner's timing path to prove it.
"""

from __future__ import annotations

import dataclasses
import itertools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.machine import TPU_V5E, MachineModel
from repro.models.registry import init_cache_slots
from repro.runtime.serve import make_bucket_prefill_step, make_slot_decode_step
from repro.serve.bucket import Bucket, BucketLadder

# Request lifecycle states.
QUEUED = "queued"
ACTIVE = "active"
DONE = "done"
SHED = "shed"          # queue overflow or oversize prompt at submit
TIMEOUT = "timeout"    # deadline expired (queued or mid-generation)


class WallClock:
    """Real time; ``advance`` is a no-op (the world advances itself) and
    ``advance_to`` sleeps until the target."""

    virtual = False

    def now(self) -> float:
        return time.monotonic()

    def advance(self, dt: float) -> None:
        pass

    def advance_to(self, t: float) -> None:
        delta = t - self.now()
        if delta > 0:
            time.sleep(min(delta, 0.05))


class VirtualClock:
    """Deterministic time for the load generator: the loop advances it by
    the ladder's modeled step seconds, so batching composition, padding
    waste, and latency percentiles are reproducible bit-for-bit."""

    virtual = True

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        self._t += max(0.0, float(dt))

    def advance_to(self, t: float) -> None:
        self._t = max(self._t, float(t))


@dataclasses.dataclass
class Request:
    """One generation request and its lifecycle record."""

    rid: str
    prompt: np.ndarray  # 1-D int32 token ids
    max_new_tokens: int
    deadline: float | None = None  # absolute clock time; None = no deadline
    state: str = QUEUED
    tokens: list = dataclasses.field(default_factory=list)
    slot: int | None = None
    t_submit: float | None = None
    t_admit: float | None = None
    t_first: float | None = None
    t_done: float | None = None

    @property
    def latency(self) -> float | None:
        if self.t_done is None or self.t_submit is None:
            return None
        return self.t_done - self.t_submit

    @property
    def ttft(self) -> float | None:
        if self.t_first is None or self.t_submit is None:
            return None
        return self.t_first - self.t_submit


class RequestQueue:
    """Bounded FIFO admission queue: overflow sheds (never blocks), and
    deadline-expired requests are dropped at the head before admit."""

    def __init__(self, max_depth: int = 64):
        self.max_depth = int(max_depth)
        self._q: list[Request] = []

    def __len__(self) -> int:
        return len(self._q)

    def submit(self, req: Request, now: float) -> bool:
        req.t_submit = now if req.t_submit is None else req.t_submit
        if len(self._q) >= self.max_depth:
            req.state = SHED
            return False
        if req.deadline is not None and now >= req.deadline:
            req.state = TIMEOUT
            req.t_done = now
            return False
        req.state = QUEUED
        self._q.append(req)
        return True

    def expire(self, now: float) -> list[Request]:
        """Drop (and return) every queued request whose deadline passed."""
        dead = [r for r in self._q if r.deadline is not None and now >= r.deadline]
        for r in dead:
            r.state = TIMEOUT
            r.t_done = now
        self._q = [r for r in self._q if r.state == QUEUED]
        return dead

    def peek(self, k: int) -> list[Request]:
        return self._q[:k]

    def pop(self, k: int) -> list[Request]:
        got, self._q = self._q[:k], self._q[k:]
        return got


@dataclasses.dataclass(frozen=True)
class StepInfo:
    """What one engine step did — the load generator's clock advances by
    the modeled cost of exactly these events."""

    prefills: tuple = ()       # (bucket, rows_admitted, true_prompt_tokens)
    decode_ran: bool = False
    decode_active: int = 0
    retired: tuple = ()        # rids finished this step
    timed_out: tuple = ()      # rids expired this step


class Engine:
    """Continuous-batching engine: bucket-planned prefill into a KV slot
    pool, per-slot decode over the active set, retire-and-backfill.

    ``warmup()`` must run before ``submit``/``step``; it resolves every
    bucket's schedules through the autotune cache (cache-only in
    production, tune on first boot), compiles the bucket prefills and the
    slot decode, and allocates the slot pool via the family registry."""

    def __init__(self, cfg: ModelConfig, params, ladder: BucketLadder, *,
                 n_slots: int | None = None, queue_depth: int = 64,
                 compute_dtype="float32", cache_dtype=None,
                 machine: MachineModel = TPU_V5E, clock=None,
                 eos_id: int | None = None):
        self.cfg = cfg
        self.params = params
        self.ladder = ladder
        self.n_slots = int(n_slots if n_slots is not None else ladder.max_batch)
        self.queue = RequestQueue(queue_depth)
        self.compute_dtype = compute_dtype
        self.cache_dtype = cache_dtype or compute_dtype
        self.machine = machine
        self.clock = clock if clock is not None else WallClock()
        self.eos_id = eos_id
        self._rid = itertools.count()
        self._warmed = False
        self._slots: list[Request | None] = [None] * self.n_slots
        self.retired: list[Request] = []
        self.rejected: list[Request] = []
        # Padding-waste accounting: padded vs true token slots dispatched.
        self.stats = {"prefill_padded": 0, "prefill_true": 0,
                      "decode_slots": 0, "decode_active": 0, "steps": 0}

    # -- boot -------------------------------------------------------------

    def warmup(self, *, policy: str | None = None, cache=None) -> dict:
        """Resolve + compile everything shape-dependent, once.  Returns the
        ladder's cell provenance map (bucket -> cell -> cached/tuned/
        modeled)."""
        sources = self.ladder.warmup(
            self.cfg, policy=policy, cache=cache,
            dtype=np.dtype(self.compute_dtype))
        self._prefill = {
            b: jax.jit(make_bucket_prefill_step(
                self.cfg, self.ladder.max_seq, self.compute_dtype,
                self.cache_dtype, schedules=self.ladder.plans[b],
                machine=self.machine))
            for b in self.ladder.buckets
        }
        decode_plans = self.ladder.plans[max(self.ladder.buckets,
                                             key=lambda b: b.batch)]
        self._decode = jax.jit(make_slot_decode_step(
            self.cfg, self.compute_dtype, schedules={
                k: v for k, v in decode_plans.items()
                if k.startswith("decode.")},
            machine=self.machine))
        self.cache = init_cache_slots(self.cfg, self.n_slots,
                                      self.ladder.max_seq,
                                      jnp.dtype(self.cache_dtype))
        self.tok = jnp.zeros((self.n_slots,), jnp.int32)
        self.pos = jnp.zeros((self.n_slots,), jnp.int32)
        # Compile every bucket prefill and the decode step now, against
        # throwaway inputs, so no request ever waits on a trace.
        for b in self.ladder.buckets:
            zt = jnp.zeros((b.batch, b.seq), jnp.int32)
            zl = jnp.ones((b.batch,), jnp.int32)
            jax.block_until_ready(self._prefill[b](self.params, zt, zl)[1])
        jax.block_until_ready(
            self._decode(self.params, self.cache, self.tok, self.pos)[1])
        self._warmed = True
        return sources

    # -- request intake ----------------------------------------------------

    def submit(self, req: Request | None = None, *, prompt=None,
               max_new_tokens: int = 16, deadline: float | None = None) -> Request:
        """Queue one request (or build one from ``prompt=``).  Oversize
        prompts and queue overflow shed immediately — check
        ``req.state``."""
        if not self._warmed:
            raise RuntimeError("Engine.warmup() has not run")
        now = self.clock.now()
        if req is None:
            req = Request(rid=f"r{next(self._rid)}",
                          prompt=np.asarray(prompt, np.int32).reshape(-1),
                          max_new_tokens=int(max_new_tokens),
                          deadline=deadline)
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if len(req.prompt) > self.ladder.max_prompt:
            req.state = SHED
            req.t_submit = now
            self.rejected.append(req)
            return req
        if not self.queue.submit(req, now):
            self.rejected.append(req)
        return req

    # -- the loop ----------------------------------------------------------

    @property
    def active(self) -> list[Request]:
        return [r for r in self._slots if r is not None]

    @property
    def idle(self) -> bool:
        return not self.active and not len(self.queue)

    def step(self) -> StepInfo:
        """One engine iteration: expire, admit+prefill, decode, retire."""
        if not self._warmed:
            raise RuntimeError("Engine.warmup() has not run")
        now = self.clock.now()
        timed_out = [r.rid for r in self.queue.expire(now)]
        timed_out += [r.rid for r in self._expire_active(now)]
        prefills, retired = self._admit(now)
        decode_ran, n_active, dec_retired = self._decode_step(now)
        retired += dec_retired
        self.stats["steps"] += 1
        return StepInfo(prefills=tuple(prefills), decode_ran=decode_ran,
                        decode_active=n_active, retired=tuple(retired),
                        timed_out=tuple(timed_out))

    def run_until_idle(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if self.idle:
                return
            self.step()
        raise RuntimeError(f"engine not idle after {max_steps} steps")

    # -- internals ---------------------------------------------------------

    def _expire_active(self, now: float) -> list[Request]:
        dead = []
        for i, r in enumerate(self._slots):
            if r is not None and r.deadline is not None and now >= r.deadline:
                r.state = TIMEOUT
                r.t_done = now
                r.slot = None
                self._slots[i] = None
                self.retired.append(r)
                dead.append(r)
        return dead

    def _admit(self, now: float):
        """Admit queued requests into free slots, one padded bucket
        dispatch per group, until slots or queue run out."""
        prefills, retired = [], []
        while True:
            free = [i for i, r in enumerate(self._slots) if r is None]
            if not free or not len(self.queue):
                break
            cand = self.queue.peek(min(len(free), self.ladder.max_batch))
            bucket = self.ladder.route(
                len(cand), max(len(r.prompt) for r in cand))
            # route() only returns None for oversize prompts, which
            # submit() already shed.
            grp = self.queue.pop(min(len(cand), bucket.batch))
            bucket = self.ladder.route(len(grp),
                                       max(len(r.prompt) for r in grp))
            slots = free[:len(grp)]
            self._prefill_group(grp, bucket, slots, now)
            prefills.append((bucket, len(grp),
                             sum(len(r.prompt) for r in grp)))
            retired += [r.rid for r in grp if r.state == DONE]
        return prefills, retired

    def _prefill_group(self, grp: list[Request], bucket: Bucket,
                       slots: list[int], now: float) -> None:
        n = len(grp)
        toks = np.zeros((bucket.batch, bucket.seq), np.int32)
        lens = np.ones((bucket.batch,), np.int32)
        for i, r in enumerate(grp):
            toks[i, :len(r.prompt)] = r.prompt
            lens[i] = len(r.prompt)
        cache_b, logits = self._prefill[bucket](
            self.params, jnp.asarray(toks), jnp.asarray(lens))
        first = np.asarray(jnp.argmax(logits, -1))[:n]
        idx = jnp.asarray(np.asarray(slots, np.int32))
        self.cache = jax.tree.map(
            lambda full, part: full.at[:, idx].set(
                part[:, :n].astype(full.dtype)),
            self.cache, cache_b)
        self.tok = self.tok.at[idx].set(jnp.asarray(first, jnp.int32))
        self.pos = self.pos.at[idx].set(jnp.asarray(lens[:n], jnp.int32))
        self.stats["prefill_padded"] += bucket.batch * bucket.seq
        self.stats["prefill_true"] += int(lens[:n].sum())
        for i, r in enumerate(grp):
            r.state = ACTIVE
            r.slot = slots[i]
            r.t_admit = now
            r.t_first = now
            r.tokens.append(int(first[i]))
            self._slots[slots[i]] = r
            if self._finished(r):
                self._retire(r, now)

    def _decode_step(self, now: float):
        act = [(i, r) for i, r in enumerate(self._slots) if r is not None]
        if not act:
            return False, 0, []
        self.cache, logits = self._decode(self.params, self.cache,
                                          self.tok, self.pos)
        nxt = np.asarray(jnp.argmax(logits, -1))
        self.tok = jnp.asarray(nxt, jnp.int32)
        live = np.zeros((self.n_slots,), np.int32)
        retired = []
        for i, r in act:
            live[i] = 1
            r.tokens.append(int(nxt[i]))
            if self._finished(r):
                self._retire(r, now)
                retired.append(r.rid)
        # Only live slots advance; freed/empty slots keep their position
        # (their cache rows are fully overwritten at the next prefill).
        self.pos = self.pos + jnp.asarray(live)
        self.stats["decode_slots"] += self.n_slots
        self.stats["decode_active"] += len(act)
        return True, len(act), retired

    def _finished(self, r: Request) -> bool:
        if len(r.tokens) >= r.max_new_tokens:
            return True
        return self.eos_id is not None and r.tokens[-1] == self.eos_id

    def _retire(self, r: Request, now: float) -> None:
        r.state = DONE
        r.t_done = now
        if r.slot is not None:
            self._slots[r.slot] = None
            r.slot = None
        self.retired.append(r)

    # -- the deterministic service-time model ------------------------------

    def modeled_step_seconds(self, info: StepInfo) -> float:
        """Modeled wall seconds of one step's dispatches — what a
        ``VirtualClock`` load run advances by (see loadgen)."""
        sec = 0.0
        for bucket, _, _ in info.prefills:
            sec += self.ladder.modeled_seconds(bucket, "prefill")
        if info.decode_ran:
            decode_bucket = max(self.ladder.buckets, key=lambda b: b.batch)
            sec += self.ladder.modeled_seconds(decode_bucket, "decode")
        return sec

    def padding_waste(self) -> float:
        """Fraction of dispatched token slots that were padding (prefill
        pad rows/columns + idle decode slots)."""
        padded = self.stats["prefill_padded"] + self.stats["decode_slots"]
        true = self.stats["prefill_true"] + self.stats["decode_active"]
        return 0.0 if padded == 0 else 1.0 - true / padded
