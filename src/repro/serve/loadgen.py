"""Load generator for the serving engine: seeded Poisson arrivals at an
offered QPS, driven against either clock.

  * ``WallClock`` — real time; what the example and the serve smoke use.
  * ``VirtualClock`` — the loop advances time by the ladder's *modeled*
    step seconds (schedule words over machine bandwidth), so arrival
    interleaving, batching composition, padding waste, and latency
    percentiles are deterministic — what ``benchmarks/run.py serve``
    gates against the committed baseline.

CLI (the tier1.sh --serve-smoke gate): ``python -m repro.serve.loadgen
--smoke`` boots the engine twice against the configured autotune cache —
first boot tunes the bucket cells, second boot must replay every tuned
winner cache-only — pushes a handful of ragged requests through a
2-bucket ladder each time, and asserts all complete with identical
tokens.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.serve.engine import DONE, SHED, TIMEOUT, Engine, Request

# Re-exported for callers configuring the engine clock.
from repro.serve.engine import VirtualClock, WallClock  # noqa: F401


@dataclasses.dataclass(frozen=True)
class LoadSpec:
    """One offered-load experiment: ``n_requests`` Poisson arrivals at
    ``qps``, ragged prompts/gen lengths drawn from the given inclusive
    ranges, all from ``seed``."""

    qps: float
    n_requests: int = 32
    prompt_len: tuple = (4, 24)
    new_tokens: tuple = (4, 8)
    deadline_s: float | None = None
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class LoadReport:
    """What one load run measured, in the driving clock's seconds."""

    offered_qps: float
    n_requests: int
    completed: int
    shed: int
    timed_out: int
    p50_s: float
    p99_s: float
    ttft_p50_s: float
    tokens_per_sec: float
    padding_waste: float
    clock_seconds: float
    engine_steps: int
    generated_tokens: int


def make_requests(spec: LoadSpec, vocab: int, start: float = 0.0):
    """Seeded ``[(arrival_time, Request)]`` — identical across runs."""
    rng = np.random.default_rng(spec.seed)
    gaps = rng.exponential(1.0 / spec.qps, spec.n_requests)
    arrivals = start + np.cumsum(gaps)
    out = []
    for i in range(spec.n_requests):
        plen = int(rng.integers(spec.prompt_len[0], spec.prompt_len[1] + 1))
        gen = int(rng.integers(spec.new_tokens[0], spec.new_tokens[1] + 1))
        req = Request(
            rid=f"load{i}",
            prompt=rng.integers(0, vocab, plen).astype(np.int32),
            max_new_tokens=gen,
            deadline=(None if spec.deadline_s is None
                      else float(arrivals[i]) + spec.deadline_s))
        out.append((float(arrivals[i]), req))
    return out


def _pct(xs, q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


def run_load(engine: Engine, spec: LoadSpec, *,
             max_steps: int = 200_000) -> LoadReport:
    """Drive ``engine`` through ``spec``: submit arrivals as the engine's
    clock passes them, step until every request resolves.  On a
    ``VirtualClock`` each step advances time by the engine's modeled step
    seconds (deterministic); on a ``WallClock`` time just passes."""
    clock = engine.clock
    t0 = clock.now()
    pending = make_requests(spec, engine.cfg.vocab, start=t0)
    reqs = [r for _, r in pending]
    i, steps = 0, 0
    while True:
        now = clock.now()
        while i < len(pending) and pending[i][0] <= now:
            engine.submit(pending[i][1])
            i += 1
        if engine.idle:
            if i >= len(pending):
                break
            clock.advance_to(pending[i][0])
            continue
        info = engine.step()
        if clock.virtual:
            clock.advance(engine.modeled_step_seconds(info))
        steps += 1
        if steps > max_steps:
            raise RuntimeError(f"load run not drained after {max_steps} steps")
    elapsed = max(clock.now() - t0, 1e-12)
    done = [r for r in reqs if r.state == DONE]
    lat = [r.latency for r in done if r.latency is not None]
    ttft = [r.ttft for r in done if r.ttft is not None]
    gen = sum(len(r.tokens) for r in reqs)
    return LoadReport(
        offered_qps=spec.qps,
        n_requests=len(reqs),
        completed=len(done),
        shed=sum(r.state == SHED for r in reqs),
        timed_out=sum(r.state == TIMEOUT for r in reqs),
        p50_s=_pct(lat, 50), p99_s=_pct(lat, 99),
        ttft_p50_s=_pct(ttft, 50),
        tokens_per_sec=gen / elapsed,
        padding_waste=engine.padding_waste(),
        clock_seconds=elapsed,
        engine_steps=steps,
        generated_tokens=gen,
    )


# ---------------------------------------------------------------------------
# CLI: the tier1.sh --serve-smoke gate
# ---------------------------------------------------------------------------


def _boot(cfg, params, *, policy: str, cache) -> tuple[Engine, dict]:
    from repro.serve.bucket import BucketLadder

    ladder = BucketLadder([(2, 8), (4, 16)], max_seq=24)
    engine = Engine(cfg, params, ladder, queue_depth=16)
    sources = engine.warmup(policy=policy, cache=cache)
    return engine, sources


def _smoke() -> int:
    """Boot the engine on the smoke config against the configured autotune
    cache (tier1.sh points $REPRO_AUTOTUNE_CACHE at a mktemp dir): first
    boot tunes the 2-bucket ladder's cells, second boot must replay every
    tuned winner from the cache without timing anything; both boots push
    the same handful of ragged requests and must complete all of them
    with identical tokens."""
    import jax
    import jax.numpy as jnp

    from repro.configs.registry import smoke_config
    from repro.models.module import init_params
    from repro.models.registry import get_family
    from repro.plan import autotune

    cfg = smoke_config("qwen3-1.7b")
    fam = get_family(cfg.family)
    params = init_params(fam.param_defs(cfg), jax.random.PRNGKey(0),
                         jnp.float32)
    cache_path = autotune.get_cache().path
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, int(n)).astype(np.int32)
               for n in (3, 7, 12, 5, 9, 15)]

    outputs = []
    for boot, policy in ((1, "tune"), (2, "cache-only")):
        # A fresh cache object per boot: boot 2 must replay from *disk*.
        cache = autotune.AutotuneCache(cache_path)
        engine, sources = _boot(cfg, params, policy=policy, cache=cache)
        flat = {(b, c): s for b, cells in sources.items()
                for c, s in cells.items()}
        counts = {s: sum(v == s for v in flat.values())
                  for s in ("cached", "tuned", "modeled")}
        print(f"boot{boot} policy={policy} cells={len(flat)} "
              f"cached={counts['cached']} tuned={counts['tuned']} "
              f"modeled={counts['modeled']}")
        if boot == 1:
            tuned = {k for k, v in flat.items() if v == "tuned"}
            assert tuned, "first boot tuned nothing — smoke is vacuous"
        else:
            missed = {k for k in tuned if flat[k] != "cached"}
            assert not missed, (
                f"winners not replayed on the cache-only boot: {missed}")
            assert counts["tuned"] == 0, "cache-only boot must never tune"
        reqs = [engine.submit(prompt=p, max_new_tokens=5) for p in prompts]
        engine.run_until_idle()
        assert all(r.state == DONE for r in reqs), (
            f"unfinished requests: {[(r.rid, r.state) for r in reqs]}")
        outputs.append([tuple(r.tokens) for r in reqs])
        print(f"boot{boot} completed={len(reqs)} "
              f"pad_waste={engine.padding_waste():.3f} "
              f"steps={engine.stats['steps']}")
    assert outputs[0] == outputs[1], (
        "token streams diverged between the tuned and cache-only boots")
    print(f"serve smoke ok (winners replayed from {cache_path})")
    return 0


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="2-boot engine smoke against the configured "
                         "autotune cache (CI gate)")
    args = ap.parse_args(argv)
    if args.smoke:
        return _smoke()
    ap.error("--smoke required (see examples/serve_lm.py for ad-hoc runs)")
    return 2


if __name__ == "__main__":
    raise SystemExit(main())
