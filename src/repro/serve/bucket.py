"""Bucket ladder: the serving plan artifact.

A server sees arbitrary (batch, prompt-length) request shapes, but the
paper's whole argument is that the winning blocking schedule is
shape-dependent — so per-request planning is wasted work and unplanned
XLA dispatch leaves the plan layer on the floor.  The standard move
(vLLM/TGI-style serving, here built on ``repro.plan``) is a small ladder
of pre-planned (batch, seq) buckets:

  * every bucket's prefill and decode cells (qkv/attention/mlp/logits as
    planner shapes) are resolved **once at warmup** through
    :func:`repro.plan.autotune.warm` — cache-only in production, tune on
    first boot — so the request path never plans, times, or traces a new
    shape;
  * request batches are padded up and routed to the nearest covering
    bucket (:meth:`BucketLadder.route`), trading padded tokens for a
    bounded plan-cache/compile-cache size (DESIGN.md Sec. 8);
  * the resolved schedules' ``modeled_words`` give a deterministic
    service-time model (:meth:`modeled_seconds`) — what the virtual-clock
    load generator and the committed serve benchmark gate on.

On a mesh, cells resolve to ``ShardedSchedule``s (the planner's
partition argmin per bucket shape) the same way.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.machine import TPU_V5E, MachineModel


@dataclasses.dataclass(frozen=True, order=True)
class Bucket:
    """One rung of the ladder: requests are padded up to this shape."""

    batch: int
    seq: int  # padded prompt length (positions beyond it are decode-only)

    def __post_init__(self):
        if self.batch < 1 or self.seq < 1:
            raise ValueError(f"bucket dims must be >= 1, got {self}")


# The per-layer cells of one bucket, as planner shapes.  Prefill runs the
# bucket's padded [batch, seq] token block against the full cache extent;
# decode runs one token per slot.  The logits head only projects the last
# position per row in prefill (the step builder gathers it), so its m is
# the row count, not batch*seq.
def bucket_cells(cfg: ModelConfig, bucket: Bucket, max_seq: int,
                 in_bytes: int = 4) -> dict[str, tuple[str, dict]]:
    """``{cell_name: (op_name, planner_shape)}`` for one bucket — the unit
    :func:`repro.plan.autotune.warm` resolves at server boot."""
    d, v = cfg.d_model, cfg.vocab
    hq = cfg.n_heads or 1
    hkv = cfg.n_kv_heads or hq
    dh = cfg.resolved_head_dim
    cells: dict[str, tuple[str, dict]] = {}
    for phase, sq in (("prefill", bucket.seq), ("decode", 1)):
        m = bucket.batch * sq
        cells[f"{phase}.qkv"] = ("matmul", dict(
            m=m, n=(hq + 2 * hkv) * dh, k=d, in_bytes=in_bytes))
        cells[f"{phase}.attn"] = ("flash_attention", dict(
            seq_q=sq, seq_kv=max_seq, head_dim=dh, n_q_heads=hq,
            n_kv_heads=hkv, batch=bucket.batch, in_bytes=in_bytes,
            causal=True))
        cells[f"{phase}.mlp"] = ("matmul", dict(
            m=m, n=cfg.d_ff, k=d, in_bytes=in_bytes))
        cells[f"{phase}.logits"] = ("matmul", dict(
            m=bucket.batch, n=v, k=d, in_bytes=in_bytes))
    return cells


class BucketLadder:
    """A sorted ladder of :class:`Bucket` rungs with warmup-resolved plans.

    ``warmup(cfg)`` must run before :attr:`plans` / ``modeled_seconds`` are
    usable; the Engine calls it at boot and never resolves afterwards.
    """

    def __init__(self, buckets, *, max_seq: int,
                 machine: MachineModel = TPU_V5E, mesh=None,
                 axis: str = "model", in_bytes: int = 4):
        rungs = sorted({b if isinstance(b, Bucket) else Bucket(*b)
                        for b in buckets}, key=lambda b: (b.seq, b.batch))
        if not rungs:
            raise ValueError("a BucketLadder needs at least one bucket")
        for b in rungs:
            if b.seq > max_seq:
                raise ValueError(f"bucket {b} exceeds max_seq={max_seq}")
        self.buckets: tuple[Bucket, ...] = tuple(rungs)
        self.max_seq = int(max_seq)
        self.machine = machine
        self.mesh = mesh
        self.axis = axis
        self.in_bytes = int(in_bytes)
        self.plans: dict[Bucket, dict] = {}
        self.sources: dict[Bucket, dict] = {}
        self._n_layers: int | None = None

    # -- routing ----------------------------------------------------------

    @property
    def max_batch(self) -> int:
        return max(b.batch for b in self.buckets)

    @property
    def max_prompt(self) -> int:
        return max(b.seq for b in self.buckets)

    def route(self, n: int, prompt_len: int) -> Bucket | None:
        """The cheapest rung covering ``n`` rows of ``prompt_len`` tokens:
        the smallest covering (seq, batch); when no rung has enough rows,
        the widest rung that covers the length (callers admit ``batch``
        rows now and come back for the rest).  ``None`` when the prompt is
        longer than every rung (reject at submit)."""
        covers = [b for b in self.buckets if b.seq >= prompt_len]
        if not covers:
            return None
        roomy = [b for b in covers if b.batch >= n]
        if roomy:
            return min(roomy, key=lambda b: (b.seq, b.batch))
        return max(covers, key=lambda b: (b.batch, -b.seq))

    # -- warmup resolution -------------------------------------------------

    def warmup(self, cfg: ModelConfig, *, policy: str | None = None,
               cache=None, dtype=np.float32) -> dict[Bucket, dict]:
        """Resolve every bucket's cells once through the autotune cache
        (``plan.autotune.warm``).  Returns ``sources``: per bucket, each
        cell's resolution provenance ("cached" / "tuned" / "modeled")."""
        from repro.plan import autotune

        self._n_layers = cfg.n_layers
        for b in self.buckets:
            cells = bucket_cells(cfg, b, self.max_seq, self.in_bytes)
            plans, sources = autotune.warm(
                cells, machine=self.machine, mesh=self.mesh, axis=self.axis,
                policy=policy, cache=cache, dtype=dtype)
            self.plans[b] = plans
            self.sources[b] = sources
        return self.sources

    @property
    def planned(self) -> bool:
        return len(self.plans) == len(self.buckets)

    # -- the deterministic service-time model ------------------------------

    def modeled_words(self, bucket: Bucket, phase: str) -> int:
        """Modeled main-memory words of one full ``phase`` step on one
        bucket: per-layer cells (qkv/attn/mlp) times n_layers, plus the
        one logits projection."""
        if not self.planned or self._n_layers is None:
            raise RuntimeError("BucketLadder.warmup(cfg) has not run")
        plans = self.plans[bucket]
        per_layer = sum(plans[f"{phase}.{c}"].modeled_words
                        for c in ("qkv", "attn", "mlp"))
        return per_layer * self._n_layers + plans[f"{phase}.logits"].modeled_words

    def modeled_seconds(self, bucket: Bucket, phase: str) -> float:
        """Modeled wall seconds of one step (words x word size over the
        machine's main-memory bandwidth) — the virtual clock's increment."""
        words = self.modeled_words(bucket, phase)
        return words * self.in_bytes / self.machine.main_mem_bw
