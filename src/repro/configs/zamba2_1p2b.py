"""zamba2-1.2b [hybrid]: 38L d_model=2048, Mamba2 (ssm_state=64) + shared
attention blocks (32H MHA, d_ff=8192), vocab=32000. [arXiv:2411.15242; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="zamba2",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, conv_width=4,
    shared_attn_every=6, tie_embeddings=True, max_seq=524288,
)
