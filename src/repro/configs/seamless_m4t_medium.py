"""seamless-m4t-medium [audio]: 12L enc + 12L dec, d_model=1024 16H (MHA)
d_ff=4096 vocab=256206; multimodal enc-dec, audio frontend stubbed
(precomputed frame embeddings). [arXiv:2308.11596; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, n_enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    head_dim=64, d_ff=4096, vocab=256206,
    act="relu", tie_embeddings=True, enc_seq=4096, max_seq=32768,
)
