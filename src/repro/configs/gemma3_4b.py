"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144;
5:1 local:global attention, 128k+ context. [hf:google/gemma-3-1b-pt; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=10240, vocab=262144,
    qk_norm=True, act="gelu", tie_embeddings=True, scale_embed=True,
    local_window=1024, global_every=6,  # 5 local : 1 global
    rope_theta=1e4, rope_theta_global=1e6,
    max_seq=524288,
)
