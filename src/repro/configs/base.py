"""Config system: model / training / run configs and the 4 shape presets."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | rwkv6 | zamba2 | encdec | cnn
    n_layers: int
    d_model: int
    vocab: int
    d_ff: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int | None = None  # None -> d_model // n_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    act: str = "silu"
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    scale_embed: bool = False
    rope_theta: float = 1e4
    # gemma3-style local:global attention
    local_window: int | None = None
    global_every: int = 0  # every Nth layer is global; 0 = all global
    rope_theta_global: float | None = None
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    shared_attn_every: int = 0  # zamba2: shared attn block cadence
    # encoder-decoder
    n_enc_layers: int = 0
    enc_seq: int = 4096  # stub audio-frontend frame count
    max_seq: int = 524288

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def attention_free(self) -> bool:
        return self.family == "rwkv6"


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


# The assigned shape set (applies to every architecture).
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    microbatch: int = 0  # 0 = no gradient accumulation
    remat: str = "block"  # none | dots | block
    zero1: bool = True  # shard optimizer state over the data axis
    seed: int = 0
    loss_chunks: int = 8  # chunked cross-entropy over tokens
    grad_compression: str = "none"  # none | int8_ef
    # Run the family's planned Pallas kernels (forward AND planned
    # backward) in the train step instead of the XLA reference path:
    # cnn = fused conv + dgrad/wgrad + dX/dW matmul, transformer = every
    # block GEMM + flash attention + dX/dW (the family's make_loss_fn
    # hook owns the dispatch).  Slow in interpret mode off-TPU; the hot
    # path on TPU.
    planned_kernels: bool = False


@dataclasses.dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    train: TrainConfig
    shape: ShapeConfig
