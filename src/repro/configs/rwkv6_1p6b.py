"""rwkv6-1.6b [ssm]: 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536; Finch data-dependent decay. [arXiv:2404.05892; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="rwkv6",
    n_layers=24, d_model=2048, d_ff=7168, vocab=65536,
    ssm_head_dim=64, tie_embeddings=False, max_seq=524288,
)
