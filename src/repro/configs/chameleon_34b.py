"""chameleon-34b [vlm]: 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 (early-fusion VQ image + text tokens; frontend is a stub per
the assignment — inputs are token ids in the shared vocab).
[arXiv:2405.09818; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="dense",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22016, vocab=65536,
    qk_norm=True, act="silu", tie_embeddings=False,
    rope_theta=1e4, max_seq=32768,
)
