"""The paper's own domain: VGG-style CNN on 32x32x3 images, built from
core.conv_layer / core.fc_layer (Algs 1-5)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="cnn-vgg11", family="cnn",
    n_layers=4, d_model=64, d_ff=4096, vocab=1000,
)
