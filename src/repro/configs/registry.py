"""Architecture registry: ``--arch <id>`` -> ModelConfig, plus reduced
smoke-config derivation (same family features, tiny dims)."""

from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import ModelConfig, SHAPES, ShapeConfig

ARCH_IDS = [
    "gemma3-4b",
    "qwen3-1.7b",
    "qwen3-32b",
    "qwen1.5-0.5b",
    "grok-1-314b",
    "qwen3-moe-235b-a22b",
    "chameleon-34b",
    "rwkv6-1.6b",
    "seamless-m4t-medium",
    "zamba2-1.2b",
    "cnn-vgg11",  # the paper's own domain
]

_MODULE_FOR = {a: a.replace("-", "_").replace(".", "p") for a in ARCH_IDS}

# The reference arch per model family: what ``launch/train.py --family X``
# trains when no --arch is named (always as the reduced smoke config).
FAMILY_DEFAULT_ARCH = {
    "dense": "qwen1.5-0.5b",
    "transformer": "qwen1.5-0.5b",  # the planned wing's family name
    "moe": "qwen3-moe-235b-a22b",
    "rwkv6": "rwkv6-1.6b",
    "zamba2": "zamba2-1.2b",
    "encdec": "seamless-m4t-medium",
    "cnn": "cnn-vgg11",
}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULE_FOR:
        raise ValueError(f"unknown arch {arch!r}; have {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch]}")
    return mod.CONFIG


def smoke_config(arch: str) -> ModelConfig:
    """Reduced config of the same family: small layers/width, few experts,
    tiny vocab — runnable on CPU in one forward/train step."""
    cfg = get_config(arch)
    changes: dict = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.family != "zamba2" else 5),
        d_model=128,
        vocab=256,
        d_ff=256,
        max_seq=512,
    )
    if cfg.n_heads:
        changes.update(n_heads=4, n_kv_heads=max(1, 4 * cfg.n_kv_heads // cfg.n_heads),
                       head_dim=32)
    if cfg.n_experts:
        changes.update(n_experts=4, moe_top_k=min(cfg.moe_top_k, 2))
    if cfg.n_enc_layers:
        changes.update(n_enc_layers=2, enc_seq=64)
    if cfg.family == "zamba2":
        changes.update(ssm_state=16, ssm_head_dim=32, shared_attn_every=2)
    if cfg.family == "rwkv6":
        changes.update(ssm_head_dim=32)
    if cfg.family == "cnn":
        changes.update(n_layers=2, d_model=8, d_ff=64, vocab=10)
    if cfg.local_window:
        changes.update(local_window=64, global_every=cfg.global_every)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **changes)


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


# long_500k needs sub-quadratic attention over the context. Runnable for
# recurrent/hybrid/local-attention archs; skipped (and documented) for pure
# full-attention archs per the assignment.
LONG_CONTEXT_OK = {"rwkv6-1.6b", "zamba2-1.2b", "gemma3-4b"}
# Decode shapes apply to everything here (all archs have a decoder);
# the CNN family has its own (image) shapes.
CNN_ARCHS = {"cnn-vgg11"}


def cells(arch: str) -> list[str]:
    """The assigned (shape) cells for an arch, with documented skips."""
    if arch in CNN_ARCHS:
        return ["train_4k"]  # batch-256 image training; seq axes n/a
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in LONG_CONTEXT_OK:
        shapes.append("long_500k")
    return shapes
