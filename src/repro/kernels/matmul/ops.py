"""Public wrapper for the FC matmul kernel — a thin registration against
the ``repro.plan`` scheduling layer.

Blocks come from :class:`repro.plan.MatmulPlanner`: the paper's capacity
argument (Sec. 3.1.2) maximizing the output stack (block_n, the Delta_O
analogue) subject to the working set + double-buffers fitting local
memory.  The registered ``sharded_impl`` executes the planner's
multi-device strategies (Alg 4's psum tree, Alg 3's ring) from a
:class:`repro.plan.ShardedSchedule` — shard_map specs come from the
schedule's partition, never from the call site.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.machine import TPU_V5E, MachineModel
from repro.core.ring import ring_matmul_local
from repro.core.shard_compat import shard_map
from repro.kernels.matmul.matmul import matmul_pallas
from repro.kernels.matmul.ref import fc_matmul_ref  # noqa: F401
from repro.plan import (
    MatmulPlanner, Schedule, pad_dim, pallas_op, partition_specs,
)
from repro.plan.planners import round_up as _round_up

_LANE = 128


def _shape_args(x, w, *, block_m=None, block_n=None, block_k=None):
    k, n = w.shape
    m = 1
    for d in x.shape[:-1]:
        m *= d
    return dict(m=m, n=n, k=k, in_bytes=x.dtype.itemsize,
                block_m=block_m, block_n=block_n, block_k=block_k)


@functools.partial(jax.jit, static_argnames=("schedule", "out_dtype", "interpret"))
def _fc_matmul_impl(x, w, *, schedule, out_dtype, interpret):
    lead = x.shape[:-1]
    k, n = w.shape
    x2 = x.reshape(-1, k)
    m = x2.shape[0]

    # Missing blocks in hand-built schedules default to legal sizes.
    bm = min(schedule.block("block_m", _LANE), _round_up(m, _LANE))
    bn = schedule.block("block_n", _LANE)
    bk = schedule.block("block_k", min(_round_up(k, _LANE), 512))

    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    x2 = pad_dim(pad_dim(x2, 0, mp), 1, kp)
    wp = pad_dim(pad_dim(w, 0, kp), 1, np_)
    out = matmul_pallas(
        x2, wp, block_m=bm, block_n=bn, block_k=bk,
        out_dtype=out_dtype, interpret=interpret,
    )
    return out[:m, :n].reshape(*lead, n)


def _impl(x, w, *, schedule, out_dtype, interpret,
          block_m=None, block_n=None, block_k=None):
    del block_m, block_n, block_k  # consumed by the planner
    return _fc_matmul_impl(
        x, w, schedule=schedule, out_dtype=out_dtype, interpret=interpret
    )


def _sharded_impl(x, w, *, schedule, mesh, out_dtype, interpret,
                  block_m=None, block_n=None, block_k=None):
    """Run a ShardedSchedule's multi-device strategy: every spec below is
    read off ``schedule.partition`` — the planner owns the partitioning.

      * "psum": K sharded, each device runs the *planned local kernel* on
        its shard, private partial outputs merge by one psum (Alg 4's tree
        reduction lowered to the collective);
      * "ring": Alg 3's ring reuse (core/ring.py) — the resident X shard
        permutes around the mesh axis while each device's full-K weight
        columns stay put;
      * "batch" / "tp": the planned local layer per device — batch
        shards M (X rows) with W replicated, tp (megatron column split)
        shards N (W columns) with X replicated, the activation
        all-gather charged by the planner riding on the output spec.
    """
    del block_m, block_n, block_k  # consumed by the planner
    *in_specs, out_spec = partition_specs(schedule)
    axis = schedule.axis
    if schedule.strategy == "psum":

        def fn(xl, wl):
            # The per-device compute is the planned *layer* (custom_vjp:
            # Pallas forward, planned dX/dW backward) so jax.grad through
            # the sharded call stays on planned kernels — the raw kernel
            # has no JVP rule to differentiate through.
            from repro.core.fc_layer import fc_layer

            yl = fc_layer(xl, wl, schedule=schedule.schedule)
            return jax.lax.psum(yl.astype(jnp.float32), axis).astype(out_dtype)

    elif schedule.strategy == "ring":

        def fn(xl, wl):
            return ring_matmul_local(xl, wl, axis=axis).astype(out_dtype)

    elif schedule.strategy in ("batch", "tp"):

        def fn(xl, wl):
            from repro.core.fc_layer import fc_layer

            return fc_layer(xl, wl, schedule=schedule.schedule).astype(out_dtype)

    else:
        raise NotImplementedError(
            f"matmul sharded strategy {schedule.strategy!r}")
    return shard_map(fn, mesh=mesh, in_specs=tuple(in_specs),
                     out_specs=out_spec, check_vma=False)(x, w)


matmul_op = pallas_op(
    "matmul",
    planner=MatmulPlanner,
    shape_args=_shape_args,
    impl=_impl,
    reference=fc_matmul_ref,
    sharded_impl=_sharded_impl,
)


def fc_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    schedule: Schedule | None = None,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    out_dtype=None,
    interpret: bool | None = None,
    machine: MachineModel = TPU_V5E,
) -> jax.Array:
    """O = X @ W via the Alg 4/5 Pallas kernel; arbitrary shapes (padded).

    ``x``: [..., K]; ``w``: [K, N].  Leading dims of ``x`` are flattened
    into M (the batch dimension of the paper's FC layer).  Blocking:
    ``schedule`` > ``block_*`` pins > planner.
    """
    return matmul_op(
        x, w, schedule=schedule, machine=machine, interpret=interpret,
        out_dtype=out_dtype or x.dtype,
        block_m=block_m, block_n=block_n, block_k=block_k,
    )
