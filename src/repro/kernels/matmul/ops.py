"""Jit'd public wrapper for the FC matmul kernel: padding, block choice.

Block sizes are chosen by the *paper's* capacity argument (Sec. 3.1.2)
against the TPU machine model: maximize the output stack (block_n, the
Delta_O analogue) subject to the working set + double-buffers fitting VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.machine import TPU_V5E, MachineModel
from repro.kernels.matmul.matmul import matmul_pallas

_LANE = 128  # MXU/VPU lane width: all blocks are multiples of 128


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def choose_blocks(
    m: int,
    n: int,
    k: int,
    in_bytes: int = 2,
    machine: MachineModel = TPU_V5E,
) -> tuple[int, int, int]:
    """Paper-style Delta_O chooser for matmul blocks.

    Working set per grid step: x block (bm*bk), w block (bk*bn), f32
    accumulator (bm*bn*4), double-buffered in/out streams.  We fix
    bm, bk at MXU-friendly sizes and grow bn (the output stack) until the
    budget is exhausted - the Alg 5 strategy verbatim.
    """
    bm = min(_round_up(m, _LANE), 512)
    bk = min(_round_up(k, _LANE), 512)
    budget = machine.usable_for_working_set(streams=2)
    bn = _LANE
    while True:
        nxt = bn + _LANE
        working = (bm * bk + bk * nxt) * in_bytes * 2 + bm * nxt * 4
        if nxt > 2048 or nxt > _round_up(n, _LANE) or working > budget:
            break
        bn = nxt
    return bm, min(bn, _round_up(n, _LANE)), bk


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype", "interpret"),
)
def fc_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    out_dtype=None,
    interpret: bool | None = None,
) -> jax.Array:
    """O = X @ W via the Alg 4/5 Pallas kernel; arbitrary shapes (padded).

    ``x``: [..., K]; ``w``: [K, N].  Leading dims of ``x`` are flattened
    into M (the batch dimension of the paper's FC layer).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    out_dtype = out_dtype or x.dtype
    lead = x.shape[:-1]
    k, n = w.shape
    x2 = x.reshape(-1, k)
    m = x2.shape[0]

    bm, bn, bk = choose_blocks(m, n, k, in_bytes=x.dtype.itemsize)
    bm = block_m or min(bm, _round_up(m, _LANE))
    bn = block_n or bn
    bk = block_k or bk

    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    x2 = jnp.pad(x2, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    out = matmul_pallas(
        x2, wp, block_m=bm, block_n=bn, block_k=bk,
        out_dtype=out_dtype, interpret=interpret,
    )
    return out[:m, :n].reshape(*lead, n)
