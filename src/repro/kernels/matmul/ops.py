"""Public wrapper for the FC matmul kernel — a thin registration against
the ``repro.plan`` scheduling layer.

Blocks come from :class:`repro.plan.MatmulPlanner`: the paper's capacity
argument (Sec. 3.1.2) maximizing the output stack (block_n, the Delta_O
analogue) subject to the working set + double-buffers fitting local
memory.  ``choose_blocks`` survives only as a deprecated shim.
"""

from __future__ import annotations

import functools

import jax

from repro.core.machine import TPU_V5E, MachineModel
from repro.kernels.matmul.matmul import matmul_pallas
from repro.kernels.matmul.ref import fc_matmul_ref  # noqa: F401
from repro.plan import MatmulPlanner, Schedule, pad_dim, pallas_op
from repro.plan.planners import round_up as _round_up

_LANE = 128


def _shape_args(x, w, *, block_m=None, block_n=None, block_k=None):
    k, n = w.shape
    m = 1
    for d in x.shape[:-1]:
        m *= d
    return dict(m=m, n=n, k=k, in_bytes=x.dtype.itemsize,
                block_m=block_m, block_n=block_n, block_k=block_k)


@functools.partial(jax.jit, static_argnames=("schedule", "out_dtype", "interpret"))
def _fc_matmul_impl(x, w, *, schedule, out_dtype, interpret):
    lead = x.shape[:-1]
    k, n = w.shape
    x2 = x.reshape(-1, k)
    m = x2.shape[0]

    # Missing blocks in hand-built schedules default to legal sizes.
    bm = min(schedule.block("block_m", _LANE), _round_up(m, _LANE))
    bn = schedule.block("block_n", _LANE)
    bk = schedule.block("block_k", min(_round_up(k, _LANE), 512))

    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    x2 = pad_dim(pad_dim(x2, 0, mp), 1, kp)
    wp = pad_dim(pad_dim(w, 0, kp), 1, np_)
    out = matmul_pallas(
        x2, wp, block_m=bm, block_n=bn, block_k=bk,
        out_dtype=out_dtype, interpret=interpret,
    )
    return out[:m, :n].reshape(*lead, n)


def _impl(x, w, *, schedule, out_dtype, interpret,
          block_m=None, block_n=None, block_k=None):
    del block_m, block_n, block_k  # consumed by the planner
    return _fc_matmul_impl(
        x, w, schedule=schedule, out_dtype=out_dtype, interpret=interpret
    )


matmul_op = pallas_op(
    "matmul",
    planner=MatmulPlanner,
    shape_args=_shape_args,
    impl=_impl,
    reference=fc_matmul_ref,
)


def fc_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    schedule: Schedule | None = None,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    out_dtype=None,
    interpret: bool | None = None,
    machine: MachineModel = TPU_V5E,
) -> jax.Array:
    """O = X @ W via the Alg 4/5 Pallas kernel; arbitrary shapes (padded).

    ``x``: [..., K]; ``w``: [K, N].  Leading dims of ``x`` are flattened
    into M (the batch dimension of the paper's FC layer).  Blocking:
    ``schedule`` > ``block_*`` pins > planner.
    """
    return matmul_op(
        x, w, schedule=schedule, machine=machine, interpret=interpret,
        out_dtype=out_dtype or x.dtype,
        block_m=block_m, block_n=block_n, block_k=block_k,
    )


def choose_blocks(
    m: int,
    n: int,
    k: int,
    in_bytes: int = 2,
    machine: MachineModel = TPU_V5E,
) -> tuple[int, int, int]:
    """Deprecated: use ``repro.plan.MatmulPlanner``.  Returns the planner's
    (block_m, block_n, block_k)."""
    s = MatmulPlanner(machine).plan(m=m, n=n, k=k, in_bytes=in_bytes)
    return s.block("block_m"), s.block("block_n"), s.block("block_k")
