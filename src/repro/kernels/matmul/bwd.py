"""Planned backward kernels for the FC matmul (DESIGN.md Sec. 4).

Two first-class ``pallas_op`` registrations:

* ``matmul_dx`` — dX[M, K] = dY[M, N] @ W[K, N]^T.  The kernel contracts
  the *last* axis of both operands block-by-block (no W^T ever
  materializes in HBM); the resident output stack is a (block_m x
  block_k) tile of dX while N streams through — Alg 5's capacity rule
  with the output stack on the K dimension.
* ``matmul_dw`` — dW[K, N] = X[M, K]^T @ dY[M, N].  Contracts the *first*
  axis of both operands; a (block_k x block_n) tile of dW stays resident
  while the batch dimension M streams through as the contraction — the
  private-partial-output accumulation of Alg 4, flushed once.

:func:`matmul_dx_dw` additionally fuses the pair into ONE kernel that
reads each dY tile exactly once and feeds it to both contractions — run
separately, each kernel streams the full dY once per K-block, so the
fusion saves one entire dY stream (``n_k * M * N`` words).  The dX
accumulator covers all M rows of the current K-block (whole-M resident),
which is the fusion's VMEM price; ``MatmulDxPlanner`` models it under
``algorithm="fused_dxdw"`` and the FC layer dispatches on that tag.

Blocking comes from :class:`repro.plan.MatmulDxPlanner` /
:class:`repro.plan.MatmulDwPlanner` (block names use the *forward* roles:
block_m = batch tile, block_k = input-feature tile, block_n = output tile).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.machine import TPU_V5E, MachineModel
from repro.kernels.pallas_compat import tpu_compiler_params
from repro.plan import MatmulDwPlanner, MatmulDxPlanner, Schedule, pad_dim, pallas_op
from repro.plan.planners import round_up as _round_up

_LANE = 128


# ---------------------------------------------------------------------------
# dX = dY @ W^T  (contract the last axis of both operands)
# ---------------------------------------------------------------------------


def matmul_dx_ref(g, w, out_dtype=None):
    """XLA oracle: dX = dY @ W^T with f32 accumulation."""
    out_dtype = out_dtype or g.dtype
    return jax.lax.dot_general(
        g, w, (((g.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(out_dtype)


def _mm_nt_kernel(g_ref, w_ref, o_ref, acc_ref, *, n_n: int):
    nn = pl.program_id(2)

    @pl.when(nn == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # [bm, bn] x [bk, bn] -> [bm, bk]: contract the shared N axis.
    acc_ref[...] += jax.lax.dot_general(
        g_ref[...], w_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(nn == n_n - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_nt_pallas(
    g: jax.Array,
    w: jax.Array,
    *,
    block_m: int,
    block_k: int,
    block_n: int,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """O[M, K] = G[M, N] @ W[K, N]^T; shapes must be block multiples."""
    m, n = g.shape
    kdim, n2 = w.shape
    assert n == n2, (g.shape, w.shape)
    assert m % block_m == 0 and kdim % block_k == 0 and n % block_n == 0
    out_dtype = out_dtype or g.dtype
    n_n = n // block_n

    return pl.pallas_call(
        functools.partial(_mm_nt_kernel, n_n=n_n),
        grid=(m // block_m, kdim // block_k, n_n),
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda i, j, nn: (i, nn)),
            pl.BlockSpec((block_k, block_n), lambda i, j, nn: (j, nn)),
        ],
        out_specs=pl.BlockSpec((block_m, block_k), lambda i, j, nn: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, kdim), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_k), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(g, w)


def _dx_shape_args(g, w, *, block_m=None, block_n=None, block_k=None,
                   algorithm=None):
    k, n = w.shape
    m = 1
    for d in g.shape[:-1]:
        m *= d
    return dict(m=m, n=n, k=k, in_bytes=g.dtype.itemsize,
                block_m=block_m, block_n=block_n, block_k=block_k,
                algorithm=algorithm)


def _interp_clamp(block: int, extent: int) -> int:
    """Interpret mode has no 128-lane MXU: a block that already covers its
    extent shrinks to it so off-TPU runs skip the lane-padding zeros.  The
    grid extent along that dim was already 1, so step counts (and
    critical_path_steps) are unchanged."""
    return max(1, extent) if block >= extent else block


@functools.partial(jax.jit, static_argnames=("schedule", "out_dtype", "interpret"))
def _dx_impl_jit(g, w, *, schedule, out_dtype, interpret):
    lead = g.shape[:-1]
    k, n = w.shape
    g2 = g.reshape(-1, n)
    m = g2.shape[0]

    bm = min(schedule.block("block_m", _LANE), _round_up(m, _LANE))
    bk = schedule.block("block_k", _LANE)
    bn = schedule.block("block_n", min(_round_up(n, _LANE), 512))
    if interpret:
        bm, bk, bn = (_interp_clamp(bm, m), _interp_clamp(bk, k),
                      _interp_clamp(bn, n))

    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    g2 = pad_dim(pad_dim(g2, 0, mp), 1, np_)
    wp = pad_dim(pad_dim(w, 0, kp), 1, np_)
    out = matmul_nt_pallas(
        g2, wp, block_m=bm, block_k=bk, block_n=bn,
        out_dtype=out_dtype, interpret=interpret,
    )
    return out[:m, :k].reshape(*lead, k)


def _dx_impl(g, w, *, schedule, out_dtype, interpret,
             block_m=None, block_n=None, block_k=None, algorithm=None):
    del block_m, block_n, block_k, algorithm  # consumed by the planner
    if getattr(schedule, "algorithm", None) == "fused_dxdw":
        # A fused schedule reaching the dx-only op (the autotuner timing a
        # fused candidate on the matmul_dx cell's (dY, W) signature): run
        # the real fused kernel on a zero X so the measurement pays the
        # kernel's true cost; the dW half is discarded.  Planned layer
        # code dispatches to matmul_dx_dw directly and never lands here.
        x0 = jnp.zeros((*g.shape[:-1], w.shape[0]), g.dtype)
        return _dxdw_impl_jit(g, w, x0, schedule=schedule,
                              out_dtype=out_dtype, interpret=interpret)[0]
    return _dx_impl_jit(g, w, schedule=schedule, out_dtype=out_dtype,
                        interpret=interpret)


dx_op = pallas_op(
    "matmul_dx",
    planner=MatmulDxPlanner,
    shape_args=_dx_shape_args,
    impl=_dx_impl,
    reference=matmul_dx_ref,
)


def matmul_dx(
    g: jax.Array,
    w: jax.Array,
    *,
    schedule: Schedule | None = None,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    out_dtype=None,
    interpret: bool | None = None,
    machine: MachineModel = TPU_V5E,
) -> jax.Array:
    """Input gradient of :func:`repro.kernels.matmul.ops.fc_matmul`.

    ``g``: [..., N] cotangent of the FC output; ``w``: [K, N] the forward
    weights.  Leading dims of ``g`` flatten into M.  Blocking:
    ``schedule`` > ``block_*`` pins > MatmulDxPlanner.
    """
    return dx_op(
        g, w, schedule=schedule, machine=machine, interpret=interpret,
        out_dtype=out_dtype or g.dtype,
        block_m=block_m, block_n=block_n, block_k=block_k,
    )


# ---------------------------------------------------------------------------
# dW = X^T @ dY  (contract the first axis of both operands)
# ---------------------------------------------------------------------------


def matmul_dw_ref(x, g, out_dtype=None):
    """XLA oracle: dW = X^T @ dY with f32 accumulation (leading dims of
    both operands flatten into M)."""
    out_dtype = out_dtype or x.dtype
    x2 = x.reshape(-1, x.shape[-1])
    g2 = g.reshape(-1, g.shape[-1])
    return jax.lax.dot_general(
        x2, g2, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(out_dtype)


def _mm_tn_kernel(x_ref, g_ref, o_ref, acc_ref, *, n_m: int):
    mm = pl.program_id(2)

    @pl.when(mm == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # [bm, bk] x [bm, bn] -> [bk, bn]: contract the shared M axis.
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], g_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(mm == n_m - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_tn_pallas(
    x: jax.Array,
    g: jax.Array,
    *,
    block_m: int,
    block_k: int,
    block_n: int,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """O[K, N] = X[M, K]^T @ G[M, N]; shapes must be block multiples."""
    m, kdim = x.shape
    m2, n = g.shape
    assert m == m2, (x.shape, g.shape)
    assert m % block_m == 0 and kdim % block_k == 0 and n % block_n == 0
    out_dtype = out_dtype or x.dtype
    n_m = m // block_m

    return pl.pallas_call(
        functools.partial(_mm_tn_kernel, n_m=n_m),
        grid=(kdim // block_k, n // block_n, n_m),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, mm: (mm, i)),
            pl.BlockSpec((block_m, block_n), lambda i, j, mm: (mm, j)),
        ],
        out_specs=pl.BlockSpec((block_k, block_n), lambda i, j, mm: (i, j)),
        out_shape=jax.ShapeDtypeStruct((kdim, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_k, block_n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, g)


def _dw_shape_args(x, g, *, block_m=None, block_n=None, block_k=None):
    k, n = x.shape[-1], g.shape[-1]
    m = 1
    for d in x.shape[:-1]:
        m *= d
    return dict(m=m, n=n, k=k, in_bytes=x.dtype.itemsize,
                block_m=block_m, block_n=block_n, block_k=block_k)


@functools.partial(jax.jit, static_argnames=("schedule", "out_dtype", "interpret"))
def _dw_impl_jit(x, g, *, schedule, out_dtype, interpret):
    k, n = x.shape[-1], g.shape[-1]
    x2 = x.reshape(-1, k)
    g2 = g.reshape(-1, n)
    m = x2.shape[0]

    bk = min(schedule.block("block_k", _LANE), _round_up(k, _LANE))
    bn = min(schedule.block("block_n", _LANE), _round_up(n, _LANE))
    bm = schedule.block("block_m", min(_round_up(m, _LANE), 512))
    if interpret:
        bm, bk, bn = (_interp_clamp(bm, m), _interp_clamp(bk, k),
                      _interp_clamp(bn, n))

    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    x2 = pad_dim(pad_dim(x2, 0, mp), 1, kp)
    g2 = pad_dim(pad_dim(g2, 0, mp), 1, np_)
    out = matmul_tn_pallas(
        x2, g2, block_m=bm, block_k=bk, block_n=bn,
        out_dtype=out_dtype, interpret=interpret,
    )
    return out[:k, :n]


def _dw_impl(x, g, *, schedule, out_dtype, interpret,
             block_m=None, block_n=None, block_k=None):
    del block_m, block_n, block_k  # consumed by the planner
    return _dw_impl_jit(x, g, schedule=schedule, out_dtype=out_dtype,
                        interpret=interpret)


dw_op = pallas_op(
    "matmul_dw",
    planner=MatmulDwPlanner,
    shape_args=_dw_shape_args,
    impl=_dw_impl,
    reference=matmul_dw_ref,
)


def matmul_dw(
    x: jax.Array,
    g: jax.Array,
    *,
    schedule: Schedule | None = None,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    out_dtype=None,
    interpret: bool | None = None,
    machine: MachineModel = TPU_V5E,
) -> jax.Array:
    """Weight gradient of :func:`repro.kernels.matmul.ops.fc_matmul`.

    ``x``: [..., K] the forward activations; ``g``: [..., N] the matching
    output cotangent (same leading dims, flattened into M).  Blocking:
    ``schedule`` > ``block_*`` pins > MatmulDwPlanner.
    """
    return dw_op(
        x, g, schedule=schedule, machine=machine, interpret=interpret,
        out_dtype=out_dtype or x.dtype,
        block_m=block_m, block_n=block_n, block_k=block_k,
    )


# ---------------------------------------------------------------------------
# Fused dX/dW: one kernel, one dY stream for both contractions
# ---------------------------------------------------------------------------


def _mm_dxdw_kernel(g_ref, w_ref, x_ref, odx_ref, odw_ref,
                    accdx_ref, accdw_ref, *, n_n: int, n_m: int,
                    block_m: int):
    nn, i = pl.program_id(1), pl.program_id(2)

    @pl.when(i == 0)
    def _init_dw():
        accdw_ref[...] = jnp.zeros_like(accdw_ref)

    g = g_ref[...]  # ONE fetch of the dY tile feeds both contractions
    # dX rows for this m-block: contract the shared N axis of g and w.
    dx = jax.lax.dot_general(
        g, w_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    rows = pl.ds(i * block_m, block_m)

    @pl.when(nn == 0)
    def _set_dx():  # first n-block initializes this m-block's rows
        accdx_ref[rows, :] = dx

    @pl.when(nn > 0)
    def _acc_dx():
        accdx_ref[rows, :] += dx

    # dW tile: contract the shared M axis of x and the SAME g.
    accdw_ref[...] += jax.lax.dot_general(
        x_ref[...], g, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(i == n_m - 1)
    def _flush_dw():
        odw_ref[...] = accdw_ref[...].astype(odw_ref.dtype)

    @pl.when((nn == n_n - 1) & (i == n_m - 1))
    def _flush_dx():  # whole-M column strip of dX for this k-block
        odx_ref[...] = accdx_ref[...].astype(odx_ref.dtype)


def matmul_dx_dw_pallas(
    g: jax.Array,
    w: jax.Array,
    x: jax.Array,
    *,
    block_m: int,
    block_k: int,
    block_n: int,
    out_dtype=None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """(dX[M, K], dW[K, N]) from G[M, N], W[K, N], X[M, K] in one kernel.

    Grid (k-blocks, n-blocks, m-blocks), m innermost: each G tile is read
    once and contracted both ways.  The dX accumulator holds ALL M rows of
    the current k-block (whole-M resident, the fusion's VMEM price) and
    flushes once per k-block; the dW tile flushes once per (k, n) block.
    Shapes must be block multiples.
    """
    m, n = g.shape
    kdim, n2 = w.shape
    m2, k2 = x.shape
    assert n == n2 and m == m2 and kdim == k2, (g.shape, w.shape, x.shape)
    assert m % block_m == 0 and kdim % block_k == 0 and n % block_n == 0
    out_dtype = out_dtype or g.dtype
    n_n, n_m = n // block_n, m // block_m

    return pl.pallas_call(
        functools.partial(_mm_dxdw_kernel, n_n=n_n, n_m=n_m,
                          block_m=block_m),
        grid=(kdim // block_k, n_n, n_m),
        in_specs=[
            pl.BlockSpec((block_m, block_n), lambda j, nn, i: (i, nn)),
            pl.BlockSpec((block_k, block_n), lambda j, nn, i: (j, nn)),
            pl.BlockSpec((block_m, block_k), lambda j, nn, i: (i, j)),
        ],
        out_specs=[
            # dX: the whole-M column strip of the current k-block stays
            # resident across the (nn, i) sweep and writes back on j change.
            pl.BlockSpec((m, block_k), lambda j, nn, i: (0, j)),
            pl.BlockSpec((block_k, block_n), lambda j, nn, i: (j, nn)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, kdim), out_dtype),
            jax.ShapeDtypeStruct((kdim, n), out_dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((m, block_k), jnp.float32),
            pltpu.VMEM((block_k, block_n), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(g, w, x)


@functools.partial(jax.jit, static_argnames=("schedule", "out_dtype", "interpret"))
def _dxdw_impl_jit(g, w, x, *, schedule, out_dtype, interpret):
    lead = g.shape[:-1]
    k, n = w.shape
    g2 = g.reshape(-1, n)
    x2 = x.reshape(-1, k)
    m = g2.shape[0]

    bm = min(schedule.block("block_m", _LANE), _round_up(m, _LANE))
    bk = schedule.block("block_k", _LANE)
    bn = schedule.block("block_n", min(_round_up(n, _LANE), 512))
    if interpret:
        bm, bk, bn = (_interp_clamp(bm, m), _interp_clamp(bk, k),
                      _interp_clamp(bn, n))

    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    g2 = pad_dim(pad_dim(g2, 0, mp), 1, np_)
    wp = pad_dim(pad_dim(w, 0, kp), 1, np_)
    x2 = pad_dim(pad_dim(x2, 0, mp), 1, kp)
    dx, dw = matmul_dx_dw_pallas(
        g2, wp, x2, block_m=bm, block_k=bk, block_n=bn,
        out_dtype=out_dtype, interpret=interpret,
    )
    return dx[:m, :k].reshape(*lead, k), dw[:k, :n]


def matmul_dx_dw(
    g: jax.Array,
    w: jax.Array,
    x: jax.Array,
    *,
    schedule: Schedule | None = None,
    out_dtype=None,
    interpret: bool | None = None,
    machine: MachineModel = TPU_V5E,
) -> tuple[jax.Array, jax.Array]:
    """Both FC gradients from one fused kernel sharing the single dY read.

    ``g``: [..., N] the output cotangent; ``w``: [K, N]; ``x``: [..., K]
    (leading dims flatten into M).  ``schedule`` is a ``matmul_dx``
    Schedule — normally the ``algorithm="fused_dxdw"`` variant from
    MatmulDxPlanner, whose vmem model covers the whole-M dX accumulator;
    when omitted the planner builds one.  Not a registered pallas_op: the
    FC layer dispatches here off the dx schedule's algorithm tag.
    """
    from repro.plan import default_interpret

    if schedule is None:
        schedule = dx_op.plan(g, w, machine=machine, algorithm="fused_dxdw")
    return _dxdw_impl_jit(
        g, w, x, schedule=schedule,
        out_dtype=out_dtype or g.dtype,
        interpret=default_interpret(interpret),
    )
