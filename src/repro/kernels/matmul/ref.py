"""Pure-jnp oracle for the FC/blocked matmul kernel."""

import jax.numpy as jnp


def fc_matmul_ref(x, w, out_dtype=None):
    """O = X @ W with f32 accumulation.

    ``x``: [M, K] activations (M = batch-like dim, K = W_I^2 * D_I).
    ``w``: [K, N] filter parameters (N = D_O).
    """
    out_dtype = out_dtype or x.dtype
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(out_dtype)
