"""Pallas TPU kernel for the paper's FC layers (Algorithms 4/5).

Mapping from the paper to the kernel (see DESIGN.md Sec. 2):

* the FC layer is a matmul  O[M, N] = X[M, K] @ W[K, N]  with
  M = batch B, K = W_I^2 * D_I (flattened input volume), N = D_O;
* Alg 5's Delta_O output stacking  ->  the N-dimension block ``block_n``:
  one grid step keeps a (block_m x block_n) output stack resident in VMEM
  while K streams through, exactly like a cluster keeping its Delta_O
  output slices in L1 while input slices stream through;
* Alg 4's "parallelize input depth slices + private outputs + reduction"
  -> the K grid dimension with an f32 VMEM accumulator (private partial
  output), flushed once on the last K step (the "tree reduction" happens
  in-register/VMEM instead of over the NoC when K is on one chip, and as
  a psum over the mesh when K is sharded - see core/fc_layer.py);
* the paper's double-buffered DmaLoad/DmaWait  ->  Pallas's implicit
  cross-grid-step pipelining of HBM->VMEM block copies.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params


def _mm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU matmul on the resident blocks; f32 accumulation.
    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(
    x: jax.Array,
    w: jax.Array,
    *,
    block_m: int,
    block_n: int,
    block_k: int,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Blocked matmul; shapes must already be multiples of the blocks."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    out_dtype = out_dtype or x.dtype
    n_k = k // block_k

    return pl.pallas_call(
        functools.partial(_mm_kernel, n_k=n_k),
        grid=(m // block_m, n // block_n, n_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w)
