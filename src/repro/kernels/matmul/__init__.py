from repro.kernels.matmul.bwd import (
    dw_op,
    dx_op,
    matmul_dw,
    matmul_dw_ref,
    matmul_dx,
    matmul_dx_ref,
)
from repro.kernels.matmul.ops import fc_matmul, matmul_op
from repro.kernels.matmul.ref import fc_matmul_ref

__all__ = [
    "dw_op", "dx_op", "fc_matmul", "fc_matmul_ref", "matmul_dw",
    "matmul_dw_ref", "matmul_dx", "matmul_dx_ref", "matmul_op",
]
