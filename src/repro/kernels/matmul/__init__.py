from repro.kernels.matmul.ops import fc_matmul, choose_blocks
from repro.kernels.matmul.ref import fc_matmul_ref
