from repro.kernels.matmul.ops import choose_blocks, fc_matmul, matmul_op
from repro.kernels.matmul.ref import fc_matmul_ref
