# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# Every kernel here is registered behind the repro.plan scheduling layer
# (Schedule/Planner/pallas_op; blocking AND device partitioning come from
# the planners — the old choose_* shims are gone).
# Re-exports are lazy (PEP 562) so importing one kernel package — e.g. via
# repro.plan.get_op("conv2d") — does not pull in the other two.  The
# callables `conv2d` and `flash_attention` are NOT re-exported here (those
# names are this package's subpackages); import them from
# repro.kernels.conv2d / repro.kernels.flash_attention.
_EXPORTS = {
    "conv2d_op": "repro.kernels.conv2d.ops",
    "conv2d_fused_ref": "repro.kernels.conv2d.ref",
    "conv2d_ref": "repro.kernels.conv2d.ref",
    "maxpool_ref": "repro.kernels.conv2d.ref",
    "fc_matmul": "repro.kernels.matmul.ops",
    "matmul_op": "repro.kernels.matmul.ops",
    "fc_matmul_ref": "repro.kernels.matmul.ref",
    "attention_op": "repro.kernels.flash_attention.ops",
    "attention_ref": "repro.kernels.flash_attention.ref",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        import importlib

        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
