"""Version-compatibility shims for Pallas TPU across jax releases.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (and
back again across versions); every kernel in this package routes through
:func:`tpu_compiler_params` so they run on whichever this install provides.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

TPUCompilerParams = getattr(
    pltpu, "TPUCompilerParams", getattr(pltpu, "CompilerParams", None)
)


def tpu_compiler_params(**kwargs):
    """Build compiler params for ``pl.pallas_call`` (None if unavailable)."""
    if TPUCompilerParams is None:  # pragma: no cover - ancient jax
        return None
    return TPUCompilerParams(**kwargs)
