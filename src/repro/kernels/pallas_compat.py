"""Version-compatibility shims for Pallas TPU across jax releases.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (and
back again across versions); every kernel in this package routes through
:func:`tpu_compiler_params` so they run on whichever this install provides.

The pipelined backward kernels additionally need the manual-DMA surface
(``pltpu.make_async_copy`` + ``pltpu.SemaphoreType`` + ``pl.run_scoped``)
for their double-buffered input streams; :func:`dma_pipeline_supported`
probes it so call sites can fall back to the plain BlockSpec pipeline
(identical numerics, serialized streams) on installs without it.
"""

from __future__ import annotations

from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TPUCompilerParams = getattr(
    pltpu, "TPUCompilerParams", getattr(pltpu, "CompilerParams", None)
)


def tpu_compiler_params(**kwargs):
    """Build compiler params for ``pl.pallas_call`` (None if unavailable)."""
    if TPUCompilerParams is None:  # pragma: no cover - ancient jax
        return None
    return TPUCompilerParams(**kwargs)


def dma_pipeline_supported() -> bool:
    """Can kernels double-buffer their own input streams with explicit
    async copies and DMA semaphores?  Requires ``pltpu.make_async_copy``,
    ``pltpu.SemaphoreType`` and ``pl.run_scoped``."""
    return (hasattr(pltpu, "make_async_copy")
            and hasattr(pltpu, "SemaphoreType")
            and hasattr(pl, "run_scoped"))


def has_emit_pipeline() -> bool:
    """Does this install ship ``pltpu.emit_pipeline`` (the managed
    overlapped-copy helper the manual double-buffer emulates)?"""
    return hasattr(pltpu, "emit_pipeline")
