"""Pallas TPU flash-attention kernel (forward) with causal/sliding-window
masking and GQA.

This is a *beyond-paper* kernel, but it is built with the paper's exact
methodology (DESIGN.md Sec. 2): the query block with its f32 accumulator is
the VMEM-resident "output stack" (Alg 2's Delta_O), the KV sequence streams
through VMEM like the paper's input depth slices, and the online-softmax
running (m, l) statistics play the role of the private partial outputs that
Alg 4 keeps per cluster.  Pallas double-buffers the KV block streaming, the
paper's DmaLoad/DmaWait pipeline.

Training uses the differentiable chunked-attention in models/attention.py;
this kernel is the serving/prefill hot path on the TPU target and is
validated against ref.py in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params

_NEG = -1e30


def _fa_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, n_kv: int, block_q: int, block_kv: int, scale: float,
    causal: bool, window: int | None, q_len: int, kv_len: int,
):
    qb = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qb * block_q
    k_start = kb * block_kv
    # Block-level skips: causal -> KV blocks entirely in the future; sliding
    # window -> KV blocks entirely before the window. Skipped blocks do no
    # MXU work (the paper's "only load what you compute on").
    run = jnp.bool_(True)
    if causal:
        run &= k_start <= q_start + block_q - 1
    if window is not None:
        run &= k_start + block_kv - 1 > q_start - window

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)  # [bq, D]
        k = k_ref[0].astype(jnp.float32)  # [bkv, D]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [bq, bkv]

        q_ids = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        k_ids = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        mask = (q_ids < q_len) & (k_ids < kv_len)
        if causal:
            mask &= k_ids <= q_ids
        if window is not None:
            mask &= q_ids - k_ids < window
        s = jnp.where(mask, s, _NEG)

        m_prev = m_ref[...]  # [bq, 1]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)  # [bq, 1]
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(kb == n_kv - 1)
    def _flush():
        l = l_ref[...]
        # Fully-masked rows -> 0.  This covers sequence padding AND real
        # rows whose causal/window mask admits no key (e.g. a window
        # entirely past kv_len when q_len > kv_len): the kernel defines
        # their attention as zero, where a dense softmax-of--inf would
        # spread uniform weights.  ref.py matches only the non-degenerate
        # rows; callers wanting uniform semantics must not feed such rows.
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, block_q: int, block_kv: int, scale: float,
    causal: bool, window: int | None,
    q_len: int, kv_len: int, interpret: bool = False,
) -> jax.Array:
    """q: [BHq, Sq, D]; k/v: [BHkv, Skv, D]; heads pre-flattened with batch.
    Sq % block_q == 0, Skv % block_kv == 0 (pad in ops.py)."""
    BHq, Sq, D = q.shape
    BHkv, Skv, _ = k.shape
    assert BHq % BHkv == 0
    group = BHq // BHkv
    n_kv = Skv // block_kv

    def kv_index(h, qb, kb):
        # Clamp the kv block index into this q block's run range: grid
        # steps the `run` predicate skips revisit an adjacent block, so the
        # pipeline issues NO new copy for them — the causal/window skips
        # save HBM traffic, not just MXU work, and AttentionPlanner's words
        # model counts exactly these fetches (give or take one boundary
        # copy when consecutive q blocks' ranges touch).  The clamped
        # fetch is never read: the kernel's compute body is off for
        # skipped steps.
        if causal:  # run: k_start <= q_start + block_q - 1
            kb = jnp.minimum(kb, (qb * block_q + block_q - 1) // block_kv)
        if window is not None:  # run: k_start + block_kv - 1 > q_start - window
            lo = jnp.maximum(0, -(-(qb * block_q - window + 2 - block_kv)
                                  // block_kv))
            # A fully-masked q block (lo past the end) pins to the last
            # block; its one fetch is the +-1 boundary slack of the model.
            kb = jnp.minimum(jnp.maximum(kb, lo), n_kv - 1)
        return (h // group, kb, 0)
    return pl.pallas_call(
        functools.partial(
            _fa_kernel, n_kv=n_kv, block_q=block_q, block_kv=block_kv,
            scale=scale, causal=causal, window=window, q_len=q_len, kv_len=kv_len,
        ),
        grid=(BHq, Sq // block_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda h, qb, kb: (h, qb, 0)),
            pl.BlockSpec((1, block_kv, D), kv_index),
            pl.BlockSpec((1, block_kv, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda h, qb, kb: (h, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((BHq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
