"""Pure-jnp oracle for flash attention (full / causal / sliding-window, GQA)."""

import jax.numpy as jnp


def mask_logits(s, q_ids, k_ids, *, causal: bool, window: int | None):
    """Apply causal / sliding-window masking to logits ``s`` [..., Sq, Skv]."""
    mask = jnp.ones(s.shape[-2:], dtype=bool)
    if causal:
        mask &= k_ids[None, :] <= q_ids[:, None]
    if window is not None:
        mask &= q_ids[:, None] - k_ids[None, :] < window
    return jnp.where(mask, s, -1e30)


def attention_ref(q, k, v, *, causal=True, window=None, scale=None, out_dtype=None):
    """Dense softmax attention.

    ``q``: [B, Hq, Sq, D]; ``k``/``v``: [B, Hkv, Skv, D] with Hkv | Hq (GQA).
    """
    out_dtype = out_dtype or q.dtype
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    scale = scale if scale is not None else D**-0.5
    group = Hq // Hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    q_ids = jnp.arange(Sq)
    k_ids = jnp.arange(Skv)
    s = mask_logits(s, q_ids, k_ids, causal=causal, window=window)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(out_dtype)
