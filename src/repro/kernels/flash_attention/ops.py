"""Public wrapper for the flash-attention kernel — a thin registration
against the ``repro.plan`` scheduling layer.

The block choice that used to live implicitly in this wrapper (hard 128
defaults clamped to the rounded sequence) is now
:class:`repro.plan.AttentionPlanner`: the q block + f32 accumulator is the
VMEM-resident output stack, K/V stream through, and blocks halve until the
working set fits the machine budget.
"""

from __future__ import annotations

import functools

import jax

from repro.core.machine import TPU_V5E, MachineModel
from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref  # noqa: F401
from repro.plan import AttentionPlanner, Schedule, pad_dim, pallas_op
from repro.plan.planners import round_up as _round_up


def _shape_args(q, k, v, *, causal=True, window=None, scale=None,
                block_q=None, block_kv=None):
    del scale  # never changes blocking or traffic
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    return dict(
        seq_q=Sq, seq_kv=Skv, head_dim=D, n_q_heads=Hq, n_kv_heads=Hkv,
        batch=B, in_bytes=q.dtype.itemsize, block_q=block_q, block_kv=block_kv,
        causal=causal, window=window,  # modeled: block-level skips
    )


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "schedule", "out_dtype", "interpret"),
)
def _flash_attention_impl(
    q, k, v, *, causal, window, scale, schedule, out_dtype, interpret,
):
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    scale = scale if scale is not None else D**-0.5
    # Missing blocks in hand-built schedules default to the MXU sweet spot.
    bq = min(schedule.block("block_q", 128), _round_up(Sq, 8))
    bkv = min(schedule.block("block_kv", 128), _round_up(Skv, 8))
    Sqp, Skvp = _round_up(Sq, bq), _round_up(Skv, bkv)

    qp = pad_dim(q, 2, Sqp).reshape(B * Hq, Sqp, D)
    kp = pad_dim(k, 2, Skvp).reshape(B * Hkv, Skvp, D)
    vp = pad_dim(v, 2, Skvp).reshape(B * Hkv, Skvp, D)

    out = flash_attention_pallas(
        qp, kp, vp, block_q=bq, block_kv=bkv, scale=scale,
        causal=causal, window=window, q_len=Sq, kv_len=Skv, interpret=interpret,
    )
    return out.reshape(B, Hq, Sqp, D)[:, :, :Sq, :].astype(out_dtype)


def _impl(q, k, v, *, schedule, out_dtype, interpret,
          causal=True, window=None, scale=None, block_q=None, block_kv=None):
    del block_q, block_kv  # consumed by the planner
    return _flash_attention_impl(
        q, k, v, causal=causal, window=window, scale=scale,
        schedule=schedule, out_dtype=out_dtype, interpret=interpret,
    )


attention_op = pallas_op(
    "flash_attention",
    planner=AttentionPlanner,
    shape_args=_shape_args,
    impl=_impl,
    reference=attention_ref,
)


def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, causal: bool = True, window: int | None = None, scale: float | None = None,
    schedule: Schedule | None = None,
    block_q: int | None = None, block_kv: int | None = None,
    out_dtype=None, interpret: bool | None = None,
    machine: MachineModel = TPU_V5E,
) -> jax.Array:
    """Blockwise attention. q: [B, Hq, Sq, D]; k/v: [B, Hkv, Skv, D].

    Pads sequences to block multiples; GQA via Hkv | Hq head grouping.
    Blocking: ``schedule`` > ``block_q``/``block_kv`` pins > planner.
    """
    return attention_op(
        q, k, v, schedule=schedule, machine=machine, interpret=interpret,
        out_dtype=out_dtype or q.dtype,
        causal=causal, window=window, scale=scale,
        block_q=block_q, block_kv=block_kv,
    )
