"""Jit'd public wrapper for the flash-attention kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_kv", "interpret"),
)
def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, causal: bool = True, window: int | None = None, scale: float | None = None,
    block_q: int = 128, block_kv: int = 128, interpret: bool | None = None,
) -> jax.Array:
    """Blockwise attention. q: [B, Hq, Sq, D]; k/v: [B, Hkv, Skv, D].

    Pads sequences to block multiples; GQA via Hkv | Hq head grouping.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    scale = scale if scale is not None else D**-0.5
    bq = min(block_q, _round_up(Sq, 8))
    bkv = min(block_kv, _round_up(Skv, 8))
    Sqp, Skvp = _round_up(Sq, bq), _round_up(Skv, bkv)

    qp = jnp.pad(q, ((0, 0), (0, 0), (0, Sqp - Sq), (0, 0))).reshape(B * Hq, Sqp, D)
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, Skvp - Skv), (0, 0))).reshape(B * Hkv, Skvp, D)
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, Skvp - Skv), (0, 0))).reshape(B * Hkv, Skvp, D)

    out = flash_attention_pallas(
        qp, kp, vp, block_q=bq, block_kv=bkv, scale=scale,
        causal=causal, window=window, q_len=Sq, kv_len=Skv, interpret=interpret,
    )
    return out.reshape(B, Hq, Sqp, D)[:, :, :Sq, :]
