from repro.kernels.flash_attention.ops import attention_op, flash_attention
from repro.kernels.flash_attention.ref import attention_ref

__all__ = ["attention_op", "attention_ref", "flash_attention"]
