"""im2col-GEMM conv2d: the direct strip kernel's rival algorithm family.

Each strip of ``block_h`` output rows expands its receptive fields into a
patch matrix of ``[batch * rows * W_O, F*F*d_in]`` — strip-at-a-time, so
the whole patch matrix never materializes in HBM — and multiplies it
against the reshaped ``[F*F*d_in, d_out]`` filter matrix with the blocked
Pallas matmul (kernels/matmul): the GEMM core whose blocking
:class:`repro.plan.Im2colConvPlanner` *delegates* to ``MatmulPlanner``,
the repo's first compound planner.  bias/ReLU apply on the GEMM output;
pooling runs as an unfused epilogue (the direct kernel fuses it into the
flush), which the traffic model charges (``ccr.conv_im2col_traffic``, the
``F*F/S^2`` patch read amplification per strip).

Registered both as its own op (``conv2d_im2col``) and as the execution
target the ``conv2d`` op dispatches to when a schedule carries
``algorithm="im2col"`` — the two-level ``algorithm x blocking`` argmin's
other branch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.machine import TPU_V5E, MachineModel
from repro.core.shard_compat import shard_map
from repro.kernels.conv2d.ops import _fused_pool, conv_out_extent
from repro.kernels.conv2d.ref import conv2d_fused_ref, maxpool_ref
from repro.kernels.matmul.matmul import matmul_pallas
from repro.plan import Schedule, pad_dim, pallas_op, partition_specs
from repro.plan.planners import Im2colConvPlanner
from repro.plan.planners import round_up as _round_up

_LANE = 128


def _shape_args(
    x, f, bias=None, *, stride=1, padding=0, relu=False, pool=1,
    block_h=None, block_m=None, block_n=None, block_k=None,
):
    """Planner shapes from concrete operands (the op registry contract).
    Same geometry extraction as the direct op; the tunable knobs are the
    strip height plus the delegated GEMM's blocks."""
    batched = x.ndim == 4
    B = x.shape[0] if batched else 1
    H, W, d_in = x.shape[-3], x.shape[-2], x.shape[-1]
    F, d_out = f.shape[0], f.shape[3]
    H_O = conv_out_extent(H, padding, F, stride)
    W_O = conv_out_extent(W, padding, F, stride)
    return dict(
        H_O=H_O, W_O=W_O, F=F, S=stride, d_in=d_in, d_out=d_out,
        in_bytes=x.dtype.itemsize, pool=_fused_pool(H_O, W_O, pool), batch=B,
        padding=padding, H_I=H, W_I=W,
        block_h=block_h, block_m=block_m, block_n=block_n, block_k=block_k,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "stride", "padding", "relu", "pool", "schedule", "out_dtype", "interpret",
    ),
)
def _conv2d_im2col_impl(
    x, f, bias, *, stride, padding, relu, pool, schedule, out_dtype, interpret,
):
    batched = x.ndim == 4
    if not batched:
        x = x[None]
    B, H, W, d_in = x.shape
    F = f.shape[0]
    d_out = f.shape[3]
    S = stride
    H_O = conv_out_extent(H, padding, F, S)
    W_O = conv_out_extent(W, padding, F, S)
    assert H_O > 0 and W_O > 0, "receptive field larger than padded input"

    # Blocking comes from the Schedule; default missing blocks and clamp
    # defensively (legality is ours, fidelity is the planner's).
    hb = max(1, min(schedule.block("block_h", H_O), H_O))
    k = F * F * d_in
    bm = schedule.block("block_m", min(_round_up(B * hb * W_O, _LANE), 512))
    bn = schedule.block("block_n", min(_round_up(d_out, _LANE), 2048))
    bk = schedule.block("block_k", min(_round_up(k, _LANE), 512))

    # Pad spatially so every strip's halo'd window and the right-most
    # receptive column exist (mirrors the direct wrapper's padding).
    n_h = -(-H_O // hb)
    rows_needed = (n_h * hb - 1) * S + F
    pad_bottom = padding + max(0, rows_needed - (H + 2 * padding))
    cols_needed = (W_O - 1) * S + F
    pad_right = padding + max(0, cols_needed - (W + 2 * padding))
    xp = jnp.pad(x, ((0, 0), (padding, pad_bottom), (padding, pad_right), (0, 0)))

    kp, np_ = _round_up(k, bk), _round_up(d_out, bn)
    # Filter matrix [F*F*d_in, d_out]: (fy, fx, d_i) row order matches the
    # patch stacking below.
    wmat = pad_dim(pad_dim(f.reshape(k, d_out), 0, kp), 1, np_)

    strips = []
    for h0 in range(0, H_O, hb):
        rows = min(hb, H_O - h0)
        win = jax.lax.slice_in_dim(
            xp, h0 * S, h0 * S + (rows - 1) * S + F, axis=1)
        cols = []
        for fy in range(F):
            for fx in range(F):
                cols.append(jax.lax.slice(
                    win, (0, fy, fx, 0),
                    (B, fy + (rows - 1) * S + 1, fx + (W_O - 1) * S + 1, d_in),
                    (1, S, S, 1)))  # [B, rows, W_O, d_in] per filter tap
        # The strip's patch matrix: [B * rows * W_O, F*F*d_in].
        a = jnp.stack(cols, axis=3).reshape(B * rows * W_O, k)
        m = B * rows * W_O
        ap = pad_dim(pad_dim(a, 0, _round_up(m, bm)), 1, kp)
        o = matmul_pallas(ap, wmat, block_m=bm, block_n=bn, block_k=bk,
                          out_dtype=jnp.float32, interpret=interpret)
        strips.append(o[:m, :d_out].reshape(B, rows, W_O, d_out))
    out = jnp.concatenate(strips, axis=1)
    out = out + bias.astype(jnp.float32)
    if relu:
        out = jnp.maximum(out, 0.0)
    if pool > 1:  # unfused epilogue (the direct kernel fuses this)
        out = maxpool_ref(out, pool)
    out = out.astype(out_dtype)
    return out if batched else out[0]


def _impl(
    x, f, bias, *, schedule, out_dtype, interpret,
    stride=1, padding=0, relu=False, pool=1,
    block_h=None, block_m=None, block_n=None, block_k=None,  # planner knobs
):
    del block_h, block_m, block_n, block_k
    return _conv2d_im2col_impl(
        x, f, bias, stride=stride, padding=padding, relu=relu, pool=int(pool),
        schedule=schedule, out_dtype=out_dtype, interpret=interpret,
    )


def _sharded_impl(x, f, bias, *, schedule, mesh, out_dtype, interpret,
                  stride=1, padding=0, relu=False, pool=1,
                  block_h=None, block_m=None, block_n=None, block_k=None):
    """Data-parallel im2col conv from a ShardedSchedule: the same
    "batch"/"stack" partitions as the direct op (each device runs the
    planned per-shard GEMM schedule), specs from ``schedule.partition``."""
    del block_h, block_m, block_n, block_k  # consumed by the planner
    if schedule.strategy not in ("batch", "stack"):
        raise NotImplementedError(
            f"conv2d_im2col sharded strategy {schedule.strategy!r}")
    *in_specs, out_spec = partition_specs(schedule)
    batched = x.ndim == 4
    if not batched:
        x = x[None]

    def fn(xl, fl, bl):
        return _conv2d_im2col_impl(
            xl, fl, bl, stride=stride, padding=padding, relu=relu,
            pool=int(pool), schedule=schedule.schedule, out_dtype=out_dtype,
            interpret=interpret,
        )

    out = shard_map(fn, mesh=mesh, in_specs=tuple(in_specs),
                    out_specs=out_spec, check_vma=False)(x, f, bias)
    return out if batched else out[0]


conv2d_im2col_op = pallas_op(
    "conv2d_im2col",
    planner=Im2colConvPlanner,
    shape_args=_shape_args,
    impl=_impl,
    reference=conv2d_fused_ref,
    sharded_impl=_sharded_impl,
)


def conv2d_im2col(
    x: jax.Array,
    f: jax.Array,
    *,
    stride: int = 1,
    padding: int = 0,
    bias: jax.Array | None = None,
    relu: bool = False,
    pool: int | None = None,
    schedule: Schedule | None = None,
    block_h: int | None = None,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    out_dtype=None,
    interpret: bool | None = None,
    machine: MachineModel = TPU_V5E,
) -> jax.Array:
    """im2col-GEMM convolutional forward for arbitrary shapes.

    Same contract as :func:`repro.kernels.conv2d.ops.conv2d` (``x``:
    [B, H, W, D_I] or unbatched; ``f``: [F, F, D_I, D_O]; fused bias/ReLU,
    unfused pool), executed as per-strip patch-matrix GEMMs.  Blocking:
    ``schedule`` > ``block_*`` pins > the delegating planner.
    """
    d_out = f.shape[3]
    if bias is None:
        bias = jnp.zeros((d_out,), jnp.float32)
    return conv2d_im2col_op(
        x, f, bias,
        schedule=schedule, machine=machine, interpret=interpret,
        out_dtype=out_dtype or x.dtype,
        stride=stride, padding=padding, relu=relu, pool=int(pool or 1),
        block_h=block_h, block_m=block_m, block_n=block_n, block_k=block_k,
    )
