"""Jit'd public wrapper for the batched, strip-tiled stacked conv2d kernel.

``block_do`` (the paper's Delta_O) and ``block_h`` (the spatial strip
height) default to the capacity chooser: the same VMEM budget rule that
gives Delta_O <= 24/12 on Manticore (core/ccr.py) now also trades strip
height against output-channel stacking — a taller strip means less halo
re-streaming, a wider stack means fewer passes over the input volume
(Eq. 7), and the chooser picks the pair minimizing modeled main-memory
words among those whose working set fits VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.machine import TPU_V5E, MachineModel
from repro.kernels.conv2d.conv2d import conv2d_fused_pallas, conv2d_pallas  # noqa: F401
from repro.kernels.conv2d.ref import conv2d_ref, maxpool_ref  # noqa: F401

_LANE = 128


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _fits(
    hb: int, bdo: int, W_O: int, W_in: int, F: int, S: int,
    in_bytes: int, block_di: int, budget: int,
) -> bool:
    """Does the strip working set fit VMEM?  f32 accumulator strip plus the
    double-buffered input-strip and filter streams (paper Sec. 2.2.2)."""
    h_halo = (hb - 1) * S + F
    stream = (h_halo * W_in * block_di + F * F * block_di * bdo) * in_bytes * 2
    return stream + hb * W_O * bdo * 4 <= budget


def _schedule_words(
    hb: int, bdo: int, H_O: int, W_O: int, W_in: int, F: int, S: int,
    d_in: int, d_out: int, pool: int,
) -> int:
    """Modeled main-memory words of the strip-tiled schedule (the device-
    level analogue of ccr.alg2_strip_traffic): every output stack re-streams
    each strip's halo'd input rows once, filters stream once per
    (stack, d_i), outputs store once."""
    n_h = -(-H_O // hb)
    n_stacks = -(-d_out // bdo)
    h_halo = (hb - 1) * S + F
    loads = n_stacks * n_h * h_halo * W_in * d_in + d_out * d_in * F * F
    stores = (H_O // pool) * (W_O // pool) * d_out
    return loads + stores


def choose_schedule(
    H_O: int, W_O: int, F: int, S: int, d_in: int, d_out: int,
    in_bytes: int = 2, block_di: int = _LANE, pool: int = 1,
    machine: MachineModel = TPU_V5E,
) -> tuple[int, int]:
    """Pick (block_h, block_do): the (strip height, Delta_O) pair whose
    working set fits VMEM and whose modeled traffic is smallest.

    Candidate strips are H_O and its power-of-two fractions (rounded up to
    the pool granularity); for each, the largest lane-aligned output stack
    that still fits is considered.  Ties break toward taller strips (less
    halo re-streaming) — the paper's Delta_O argument, now two-dimensional.
    """
    budget = machine.usable_for_working_set(streams=2)
    W_in = (W_O - 1) * S + F
    dop = _round_up(d_out, _LANE)
    cands = []
    k = 1
    while True:
        hb = _round_up(-(-H_O // k), pool)
        if not cands or hb < cands[-1]:
            cands.append(hb)
        if hb <= pool or k >= 64:
            break
        k *= 2
    best = None
    for hb in cands:
        bdo = min(dop, 2048)
        while bdo > _LANE and not _fits(
            hb, bdo, W_O, W_in, F, S, in_bytes, block_di, budget
        ):
            bdo -= _LANE
        if not _fits(hb, bdo, W_O, W_in, F, S, in_bytes, block_di, budget):
            continue
        words = _schedule_words(hb, bdo, H_O, W_O, W_in, F, S, d_in, d_out, pool)
        if best is None or words < best[0]:
            best = (words, hb, bdo)
    if best is None:  # nothing fits the model; smallest legal tile anyway
        return _round_up(min(8, H_O), pool), _LANE
    return best[1], best[2]


def choose_stack(
    H_O: int, W_O: int, W_Ipad: int, F: int, d_out: int,
    in_bytes: int = 2, block_di: int = _LANE,
    machine: MachineModel = TPU_V5E,
) -> int:
    """Legacy Delta_O-only chooser (full-plane strip): largest output stack
    whose f32 accumulator plus streamed blocks fit VMEM (Sec. 2.2.2)."""
    budget = machine.usable_for_working_set(streams=2)
    stream = (W_Ipad**2 * block_di + F * F * block_di * _LANE) * in_bytes * 2
    bdo = _LANE
    while True:
        nxt = bdo + _LANE
        if nxt > _round_up(d_out, _LANE) or nxt > 2048:
            break
        if stream + H_O * W_O * nxt * 4 > budget:
            break
        bdo = nxt
    return bdo


@functools.partial(
    jax.jit,
    static_argnames=(
        "stride", "padding", "relu", "pool",
        "block_do", "block_di", "block_h", "out_dtype", "interpret",
    ),
)
def _conv2d_impl(
    x, f, bias, *, stride, padding, relu, pool,
    block_do, block_di, block_h, out_dtype, interpret,
):
    batched = x.ndim == 4
    if not batched:
        x = x[None]
    B, H, W, d_in = x.shape
    F = f.shape[0]
    d_out = f.shape[3]
    S = stride
    H_O = (H + 2 * padding - F) // S + 1
    W_O = (W + 2 * padding - F) // S + 1
    assert H_O > 0 and W_O > 0, "receptive field larger than padded input"

    # Pool fuses into the kernel flush only when the output plane tiles
    # evenly; otherwise the kernel still fuses bias+ReLU and the (rare)
    # ragged pool runs as a tail op.
    fused_pool = pool if (pool > 1 and H_O % pool == 0 and W_O % pool == 0) else 1

    bdi = block_di or min(_round_up(d_in, _LANE), 512)
    if block_h is None or block_do is None:
        hb_auto, bdo_auto = choose_schedule(
            H_O, W_O, F, S, d_in, d_out,
            in_bytes=x.dtype.itemsize, block_di=bdi, pool=fused_pool,
        )
        hb = block_h or hb_auto
        bdo = block_do or bdo_auto
    else:
        hb, bdo = block_h, block_do
    hb = _round_up(min(hb, _round_up(H_O, fused_pool)), fused_pool)
    bdo = min(bdo, _round_up(d_out, _LANE))

    n_h = -(-H_O // hb)
    rows_needed = (n_h * hb - 1) * S + F
    pad_bottom = padding + max(0, rows_needed - (H + 2 * padding))
    dip, dop = _round_up(d_in, bdi), _round_up(d_out, bdo)
    xp = jnp.pad(
        x,
        ((0, 0), (padding, pad_bottom), (padding, padding), (0, dip - d_in)),
    )
    fp = jnp.pad(f, ((0, 0), (0, 0), (0, dip - d_in), (0, dop - d_out)))
    bp = jnp.pad(bias.astype(jnp.float32), (0, dop - d_out))[None]

    out = conv2d_fused_pallas(
        xp, fp, bp,
        stride=S, block_h=hb, block_do=bdo, block_di=bdi,
        H_O=H_O, W_O=W_O, relu=relu, pool=fused_pool,
        out_dtype=out_dtype, interpret=interpret,
    )
    out = out[:, : H_O // fused_pool, :, :d_out]
    if pool > 1 and fused_pool == 1:  # ragged tail pool (odd H_O/W_O)
        out = maxpool_ref(out, pool)
    return out if batched else out[0]


def conv2d(
    x: jax.Array,
    f: jax.Array,
    *,
    stride: int = 1,
    padding: int = 0,
    bias: jax.Array | None = None,
    relu: bool = False,
    pool: int | None = None,
    block_do: int | None = None,
    block_di: int | None = None,
    block_h: int | None = None,
    out_dtype=None,
    interpret: bool | None = None,
) -> jax.Array:
    """Convolutional layer forward (paper Algs 1/2) for arbitrary shapes.

    ``x``: [H, W, D_I] or [B, H, W, D_I]; ``f``: [F, F, D_I, D_O].  One
    batched ``pallas_call`` serves the whole batch (grid axis, not vmap);
    any stride runs in-kernel.  ``bias`` ([D_O]), ``relu`` and ``pool``
    (2 = fused 2x2 max-pool) execute in the kernel's flush step on the
    VMEM-resident output strip — no HBM round-trip between the conv and
    its epilogue.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    out_dtype = out_dtype or x.dtype
    d_out = f.shape[3]
    if bias is None:
        bias = jnp.zeros((d_out,), jnp.float32)
    return _conv2d_impl(
        x, f, bias,
        stride=stride, padding=padding, relu=relu, pool=int(pool or 1),
        block_do=block_do, block_di=block_di, block_h=block_h,
        out_dtype=out_dtype, interpret=interpret,
    )
