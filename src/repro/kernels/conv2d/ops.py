"""Public wrapper for the batched, strip-tiled stacked conv2d kernel — a
thin registration against the ``repro.plan`` scheduling layer.

Blocking comes from :class:`repro.plan.ConvPlanner` (the same capacity rule
that gives Delta_O <= 24/12 on Manticore in core/ccr.py): pass nothing and
the planner trades strip height against output-channel stacking by modeled
main-memory words; pass ``block_*`` to pin individual blocks; or pass a
full explicit :class:`repro.plan.Schedule` to override the planner
entirely (``schedule=``).  The registered ``sharded_impl`` executes the
mesh-aware planner's data-parallel strategies ("batch"/"stack") from a
:class:`repro.plan.ShardedSchedule`, specs read off its partition.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.machine import TPU_V5E, MachineModel
from repro.core.shard_compat import shard_map
from repro.kernels.conv2d.conv2d import conv2d_fused_pallas, conv2d_pallas  # noqa: F401
from repro.kernels.conv2d.ref import conv2d_fused_ref, conv2d_ref, maxpool_ref  # noqa: F401
from repro.plan import ConvPlanner, Schedule, pad_dim, pallas_op, partition_specs
from repro.plan.planners import round_up as _round_up

_LANE = 128


def conv_out_extent(extent: int, padding: int, F: int, stride: int) -> int:
    """Output rows/cols of one spatial axis: (E + 2P - F)//S + 1 (Sec. 1.1).
    The single source of this formula for wrapper, planner and layers."""
    return (extent + 2 * padding - F) // stride + 1


def _fused_pool(H_O: int, W_O: int, pool: int) -> int:
    """Pool fuses into the kernel flush only when the output plane tiles
    evenly; otherwise bias+ReLU stay fused and the (rare) ragged pool runs
    as a tail op."""
    return pool if (pool > 1 and H_O % pool == 0 and W_O % pool == 0) else 1


def _shape_args(
    x, f, bias=None, *, stride=1, padding=0, relu=False, pool=1,
    block_do=None, block_di=None, block_h=None,
    algorithm=None, block_m=None, block_n=None, block_k=None,
):
    """Planner shapes from concrete operands (the op registry contract).
    ``algorithm`` pins one family of the two-level argmin ("direct" /
    "im2col"); block_m/n/k pin the im2col GEMM's delegated blocking the
    way block_do/di/h pin the direct kernel's."""
    batched = x.ndim == 4
    B = x.shape[0] if batched else 1
    H, W, d_in = x.shape[-3], x.shape[-2], x.shape[-1]
    F, d_out = f.shape[0], f.shape[3]
    H_O = conv_out_extent(H, padding, F, stride)
    W_O = conv_out_extent(W, padding, F, stride)
    return dict(
        H_O=H_O, W_O=W_O, F=F, S=stride, d_in=d_in, d_out=d_out,
        in_bytes=x.dtype.itemsize, block_di=block_di,
        pool=_fused_pool(H_O, W_O, pool), batch=B,
        padding=padding, H_I=H, W_I=W,
        block_h=block_h, block_do=block_do,
        algorithm=algorithm, block_m=block_m, block_n=block_n,
        block_k=block_k,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "stride", "padding", "relu", "pool", "schedule", "out_dtype",
        "interpret", "emit_mask",
    ),
)
def _conv2d_impl(
    x, f, bias, *, stride, padding, relu, pool, schedule, out_dtype, interpret,
    emit_mask=False,
):
    batched = x.ndim == 4
    if not batched:
        x = x[None]
    B, H, W, d_in = x.shape
    F = f.shape[0]
    d_out = f.shape[3]
    S = stride
    H_O = conv_out_extent(H, padding, F, S)
    W_O = conv_out_extent(W, padding, F, S)
    assert H_O > 0 and W_O > 0, "receptive field larger than padded input"
    fused_pool = _fused_pool(H_O, W_O, pool)

    # Blocking comes from the Schedule; default missing blocks and clamp
    # defensively so a hand-built (possibly partial) schedule still runs
    # (fidelity of the plan is the planner's job, legality is ours).
    bdi = schedule.block("block_di", min(_round_up(d_in, _LANE), 512))
    hb = _round_up(
        min(schedule.block("block_h", H_O), _round_up(H_O, fused_pool)), fused_pool
    )
    bdo = min(schedule.block("block_do", _LANE), _round_up(d_out, _LANE))
    if interpret:
        # Interpreted wall time scales with the 128-lane channel pad; when a
        # block already spans its extent (grid dim 1) shrink it to the true
        # extent — step counts and grids are unchanged.
        if bdi >= d_in:
            bdi = max(1, d_in)
        if bdo >= d_out:
            bdo = max(1, d_out)

    n_h = -(-H_O // hb)
    rows_needed = (n_h * hb - 1) * S + F
    pad_bottom = padding + max(0, rows_needed - (H + 2 * padding))
    dip, dop = _round_up(d_in, bdi), _round_up(d_out, bdo)
    xp = jnp.pad(x, ((0, 0), (padding, pad_bottom), (padding, padding), (0, 0)))
    xp = pad_dim(xp, 3, dip)
    fp = pad_dim(pad_dim(f, 2, dip), 3, dop)
    bp = pad_dim(bias.astype(jnp.float32), 0, dop)[None]

    out = conv2d_fused_pallas(
        xp, fp, bp,
        stride=S, block_h=hb, block_do=bdo, block_di=bdi,
        H_O=H_O, W_O=W_O, relu=relu, pool=fused_pool,
        emit_mask=emit_mask, out_dtype=out_dtype, interpret=interpret,
    )
    if emit_mask:
        out, mask = out
        out = out[:, : H_O // fused_pool, :, :d_out]
        mask = mask[:, : H_O // fused_pool, :, :d_out]
        assert not (pool > 1 and fused_pool == 1), (
            "ragged pool cannot emit the epilogue-VJP mask")
        if not batched:
            return out[0], mask[0]
        return out, mask
    out = out[:, : H_O // fused_pool, :, :d_out]
    if pool > 1 and fused_pool == 1:  # ragged tail pool (odd H_O/W_O)
        out = maxpool_ref(out, pool)
    return out if batched else out[0]


def _local_impl(x, f, bias, *, schedule, **kw):
    """Algorithm dispatch off the schedule tag: a two-level-argmin (or
    cache-replayed) schedule carrying ``algorithm="im2col"`` executes the
    patch-matrix GEMM kernel; everything else runs the direct strip
    kernel.  The import is lazy so the two conv modules stay acyclic."""
    if getattr(schedule, "algorithm", "direct") == "im2col":
        from repro.kernels.conv2d.im2col import _conv2d_im2col_impl

        return _conv2d_im2col_impl(x, f, bias, schedule=schedule, **kw)
    return _conv2d_impl(x, f, bias, schedule=schedule, **kw)


def _impl(
    x, f, bias, *, schedule, out_dtype, interpret,
    stride=1, padding=0, relu=False, pool=1,
    block_do=None, block_di=None, block_h=None,  # consumed by the planner
    algorithm=None, block_m=None, block_n=None, block_k=None,
):
    del block_do, block_di, block_h, algorithm, block_m, block_n, block_k
    return _local_impl(
        x, f, bias, stride=stride, padding=padding, relu=relu, pool=int(pool),
        schedule=schedule, out_dtype=out_dtype, interpret=interpret,
    )


def _sharded_impl(x, f, bias, *, schedule, mesh, out_dtype, interpret,
                  stride=1, padding=0, relu=False, pool=1,
                  block_do=None, block_di=None, block_h=None,
                  algorithm=None, block_m=None, block_n=None, block_k=None):
    """Data-parallel conv from a ShardedSchedule: "batch" shards images,
    "stack" shards output channels (each device runs the planned local
    kernel on its shard); no interconnect traffic either way — the specs
    come from ``schedule.partition``, the blocking (and algorithm tag)
    from the per-device local Schedule, so both partitions apply to both
    algorithm families."""
    del block_do, block_di, block_h  # consumed by the planner
    del algorithm, block_m, block_n, block_k
    if schedule.strategy not in ("batch", "stack"):
        raise NotImplementedError(
            f"conv2d sharded strategy {schedule.strategy!r}")
    *in_specs, out_spec = partition_specs(schedule)
    batched = x.ndim == 4
    if not batched:
        x = x[None]

    def fn(xl, fl, bl):
        return _local_impl(
            xl, fl, bl, stride=stride, padding=padding, relu=relu,
            pool=int(pool), schedule=schedule.schedule, out_dtype=out_dtype,
            interpret=interpret,
        )

    out = shard_map(fn, mesh=mesh, in_specs=tuple(in_specs),
                    out_specs=out_spec, check_vma=False)(x, f, bias)
    return out if batched else out[0]


conv2d_op = pallas_op(
    "conv2d",
    planner=ConvPlanner,
    shape_args=_shape_args,
    impl=_impl,
    reference=conv2d_fused_ref,
    sharded_impl=_sharded_impl,
)


def conv2d(
    x: jax.Array,
    f: jax.Array,
    *,
    stride: int = 1,
    padding: int = 0,
    bias: jax.Array | None = None,
    relu: bool = False,
    pool: int | None = None,
    schedule: Schedule | None = None,
    block_do: int | None = None,
    block_di: int | None = None,
    block_h: int | None = None,
    algorithm: str | None = None,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    out_dtype=None,
    interpret: bool | None = None,
    machine: MachineModel = TPU_V5E,
) -> jax.Array:
    """Convolutional layer forward (paper Algs 1/2) for arbitrary shapes.

    ``x``: [H, W, D_I] or [B, H, W, D_I]; ``f``: [F, F, D_I, D_O].  One
    batched ``pallas_call`` serves the whole batch (grid axis, not vmap);
    any stride runs in-kernel.  ``bias`` ([D_O]), ``relu`` and ``pool``
    (2 = fused 2x2 max-pool) execute in the kernel's flush step on the
    VMEM-resident output strip — no HBM round-trip between the conv and
    its epilogue.  Blocking: ``schedule`` > ``block_*`` pins > planner.

    The planner's argmin is *two-level*: algorithm x blocking.  When the
    im2col-GEMM family wins (or ``algorithm="im2col"`` pins it), the call
    executes the patch-matrix GEMM kernel (kernels/conv2d/im2col.py) with
    its delegated ``block_m/n/k`` blocking instead of the strip kernel.
    """
    d_out = f.shape[3]
    if bias is None:
        bias = jnp.zeros((d_out,), jnp.float32)
    return conv2d_op(
        x, f, bias,
        schedule=schedule, machine=machine, interpret=interpret,
        out_dtype=out_dtype or x.dtype,
        stride=stride, padding=padding, relu=relu, pool=int(pool or 1),
        block_do=block_do, block_di=block_di, block_h=block_h,
        algorithm=algorithm, block_m=block_m, block_n=block_n,
        block_k=block_k,
    )


def conv2d_with_mask(
    x: jax.Array,
    f: jax.Array,
    *,
    bias: jax.Array | None = None,
    stride: int = 1,
    padding: int = 0,
    pool: int = 1,
    schedule: Schedule | None = None,
    out_dtype=None,
    interpret: bool | None = None,
    machine: MachineModel = TPU_V5E,
) -> tuple[jax.Array, jax.Array | None]:
    """Conv + ReLU (+ fused pool) that also emits the epilogue-VJP mask.

    Identical output to :func:`conv2d` with ``relu=True``, plus the int8
    per-output-pixel mask (pool argmax position, or the ReLU liveness bit
    when ``pool == 1``) the backward pass scatters ``dY`` through instead
    of recomputing the convolution.  Returns ``(out, None)`` — same
    ``out``, no mask — on the paths the strip kernel's flush can't serve:
    an ``algorithm="im2col"`` schedule and the ragged-pool tail (``H_O`` or
    ``W_O`` not divisible by ``pool``); callers fall back to the recompute
    backward there.
    """
    from repro.plan import default_interpret

    pool = int(pool or 1)
    if bias is None:
        bias = jnp.zeros((f.shape[3],), jnp.float32)
    F = f.shape[0]
    H_O = conv_out_extent(x.shape[-3], padding, F, stride)
    W_O = conv_out_extent(x.shape[-2], padding, F, stride)
    if schedule is None:
        schedule = conv2d_op.plan(
            x, f, bias, machine=machine,
            stride=stride, padding=padding, relu=True, pool=pool,
        )
    ragged = pool > 1 and _fused_pool(H_O, W_O, pool) == 1
    if ragged or getattr(schedule, "algorithm", "direct") == "im2col":
        out = conv2d(
            x, f, bias=bias, stride=stride, padding=padding, relu=True,
            pool=pool, schedule=schedule, out_dtype=out_dtype,
            interpret=interpret, machine=machine,
        )
        return out, None
    return _conv2d_impl(
        x, f, bias, stride=stride, padding=padding, relu=True, pool=pool,
        schedule=schedule, out_dtype=out_dtype or x.dtype,
        interpret=default_interpret(interpret), emit_mask=True,
    )
