"""Jit'd public wrapper for the stacked conv2d kernel.

``block_do`` (the paper's Delta_O) defaults to the capacity chooser from
core/ccr.py evaluated against the TPU VMEM model — the same rule that gives
Delta_O <= 24/12 on Manticore picks the output stack here.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.machine import TPU_V5E, MachineModel
from repro.kernels.conv2d.conv2d import conv2d_pallas
from repro.kernels.conv2d.ref import conv2d_ref

_LANE = 128


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def choose_stack(
    H_O: int, W_O: int, W_Ipad: int, F: int, d_out: int,
    in_bytes: int = 2, block_di: int = _LANE,
    machine: MachineModel = TPU_V5E,
) -> int:
    """Delta_O for TPU: largest output-channel stack whose f32 accumulator
    plus streamed input/filter blocks fit VMEM (paper Sec. 2.2.2 argument)."""
    budget = machine.usable_for_working_set(streams=2)
    stream = (W_Ipad**2 * block_di + F * F * block_di * _LANE) * in_bytes * 2
    bdo = _LANE
    while True:
        nxt = bdo + _LANE
        if nxt > _round_up(d_out, _LANE) or nxt > 2048:
            break
        if stream + H_O * W_O * nxt * 4 > budget:
            break
        bdo = nxt
    return bdo


@functools.partial(
    jax.jit,
    static_argnames=("stride", "padding", "block_do", "block_di", "out_dtype", "interpret"),
)
def conv2d(
    x: jax.Array,
    f: jax.Array,
    *,
    stride: int = 1,
    padding: int = 0,
    block_do: int | None = None,
    block_di: int | None = None,
    out_dtype=None,
    interpret: bool | None = None,
) -> jax.Array:
    """Convolutional layer forward (paper Algs 1/2) for arbitrary shapes.

    ``x``: [H, W, D_I] or [B, H, W, D_I]; ``f``: [F, F, D_I, D_O].
    Stride 1 runs the Pallas kernel; strided convs use the XLA reference
    (the paper's running examples are all S = 1).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    out_dtype = out_dtype or x.dtype
    if stride != 1:
        return conv2d_ref(x, f, stride=stride, padding=padding, out_dtype=out_dtype)

    batched = x.ndim == 4
    if not batched:
        x = x[None]
    F = f.shape[0]
    d_in, d_out = f.shape[2], f.shape[3]

    bdi = block_di or min(_round_up(d_in, _LANE), 512)
    H_O = x.shape[1] + 2 * padding - F + 1
    W_O = x.shape[2] + 2 * padding - F + 1
    bdo = block_do or choose_stack(
        H_O, W_O, x.shape[2] + 2 * padding, F, d_out,
        in_bytes=x.dtype.itemsize, block_di=bdi,
    )
    bdo = min(bdo, _round_up(d_out, _LANE))

    dip, dop = _round_up(d_in, bdi), _round_up(d_out, bdo)
    xp = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, dip - d_in)))
    fp = jnp.pad(f, ((0, 0), (0, 0), (0, dip - d_in), (0, dop - d_out)))

    run = functools.partial(
        conv2d_pallas, block_do=bdo, block_di=bdi,
        out_dtype=out_dtype, interpret=interpret,
    )
    out = jax.vmap(lambda xi: run(xi, fp))(xp)[..., :d_out]
    return out if batched else out[0]
