from repro.kernels.conv2d.ops import conv2d, choose_stack
from repro.kernels.conv2d.ref import conv2d_ref
