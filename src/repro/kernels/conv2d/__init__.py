from repro.kernels.conv2d.bwd import (
    conv2d_dgrad,
    conv2d_dgrad_ref,
    conv2d_wgrad,
    conv2d_wgrad_ref,
    dgrad_op,
    wgrad_op,
)
from repro.kernels.conv2d.ops import conv2d, conv2d_op
from repro.kernels.conv2d.ref import conv2d_fused_ref, conv2d_ref, maxpool_ref

__all__ = [
    "conv2d", "conv2d_dgrad", "conv2d_dgrad_ref", "conv2d_fused_ref",
    "conv2d_op", "conv2d_ref", "conv2d_wgrad", "conv2d_wgrad_ref",
    "dgrad_op", "maxpool_ref", "wgrad_op",
]
