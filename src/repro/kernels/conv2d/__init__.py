from repro.kernels.conv2d.ops import choose_schedule, choose_stack, conv2d, conv2d_op
from repro.kernels.conv2d.ref import conv2d_fused_ref, conv2d_ref, maxpool_ref
