"""Planned backward kernels for the strip-tiled conv2d pipeline.

Two first-class ``pallas_op`` registrations (DESIGN.md Sec. 4):

* ``conv2d_dgrad`` — the input gradient.  dX is a *stride-1* strip conv
  over the S-dilated gradient with spatially flipped, channel-swapped
  filters, so it runs the forward kernel (:func:`conv2d_fused_pallas`)
  verbatim on that transposed geometry: halo-overlapped gradient strips,
  Delta_I output stacking, same VMEM accumulator discipline.  The
  dilation + transposed zero padding happen in one ``lax.pad``.
* ``conv2d_wgrad`` — the filter gradient.  dW[ky, kx] accumulates
  X_strip^T @ dY_strip over a (d_i-block, d_o-stack, batch, strip) grid;
  the F^2 x block_di x block_do f32 accumulator is the VMEM-resident
  output stack (it never round-trips HBM between batch elements or
  strips) and flushes exactly once on the last (batch, strip) step.

Blocking comes from :class:`repro.plan.ConvDgradPlanner` /
:class:`repro.plan.ConvWgradPlanner`; an explicit ``schedule=`` overrides
the planner, mirroring the forward wrapper contract.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.machine import TPU_V5E, MachineModel
from repro.kernels.conv2d.conv2d import conv2d_fused_pallas
from repro.kernels.pallas_compat import tpu_compiler_params
from repro.plan import ConvDgradPlanner, ConvWgradPlanner, Schedule, pad_dim, pallas_op
from repro.plan.planners import round_up as _round_up

_LANE = 128


# ---------------------------------------------------------------------------
# dgrad: dX via the forward strip kernel on the transposed geometry
# ---------------------------------------------------------------------------


def dgrad_out_extent(out: int, F: int, stride: int, padding: int) -> int:
    """Default dX extent for one axis: the exact-cover forward input
    (H_O - 1)*S + F - 2P.  A forward input larger than this (ragged
    stride) still back-propagates into its first min(H_I, (H_O-1)S+F-P)
    rows — pass the true extent via ``out_hw`` and the kernel computes
    those rows (the rest are zeros it also produces)."""
    return (out - 1) * stride + F - 2 * padding


def conv2d_dgrad_ref(dy, f, *, stride: int = 1, padding: int = 0, out_hw=None):
    """XLA oracle: the VJP of conv2d_ref with respect to its input."""
    from repro.kernels.conv2d.ref import conv2d_ref

    F = f.shape[0]
    d_in = f.shape[2]
    H_O, W_O = dy.shape[-3], dy.shape[-2]
    H_I, W_I = out_hw if out_hw is not None else (
        dgrad_out_extent(H_O, F, stride, padding),
        dgrad_out_extent(W_O, F, stride, padding),
    )
    shape = dy.shape[:-3] + (H_I, W_I, d_in)
    x0 = jnp.zeros(shape, jnp.float32)
    _, vjp = jax.vjp(
        lambda x: conv2d_ref(x, f, stride=stride, padding=padding,
                             out_dtype=jnp.float32), x0)
    return vjp(dy.astype(jnp.float32))[0]


def _dgrad_shape_args(dy, f, *, stride=1, padding=0, out_hw=None,
                      block_h=None, block_do=None, block_di=None):
    """Planner shapes (forward-layer terms) from concrete operands;
    ``out_hw`` is the dX extent the kernel actually produces."""
    batched = dy.ndim == 4
    B = dy.shape[0] if batched else 1
    H_O, W_O, d_out = dy.shape[-3], dy.shape[-2], dy.shape[-1]
    H_I, W_I = out_hw if out_hw is not None else (None, None)
    return dict(
        H_O=H_O, W_O=W_O, F=f.shape[0], S=stride, P=padding,
        d_in=f.shape[2], d_out=d_out, in_bytes=dy.dtype.itemsize, batch=B,
        H_I=H_I, W_I=W_I,
        block_h=block_h, block_do=block_do, block_di=block_di,
    )


@functools.partial(
    jax.jit,
    static_argnames=("stride", "padding", "out_hw", "schedule", "out_dtype",
                     "interpret"),
)
def _dgrad_impl_jit(dy, f, *, stride, padding, out_hw, schedule, out_dtype,
                    interpret):
    batched = dy.ndim == 4
    if not batched:
        dy = dy[None]
    B, H_O, W_O, d_out = dy.shape
    F = f.shape[0]
    d_in = f.shape[2]
    S, P = stride, padding
    assert P <= F - 1, f"dgrad needs padding <= F-1, got {P} for F={F}"
    H_I, W_I = out_hw if out_hw is not None else (
        dgrad_out_extent(H_O, F, S, P), dgrad_out_extent(W_O, F, S, P))
    pt = F - 1 - P  # transposed padding

    bdi = schedule.block("block_di", min(_round_up(d_out, _LANE), 512))
    hb = max(1, min(schedule.block("block_h", H_I), H_I))
    bdo = min(schedule.block("block_do", _LANE), _round_up(d_in, _LANE))

    n_h = -(-H_I // hb)
    H_dil, W_dil = (H_O - 1) * S + 1, (W_O - 1) * S + 1
    # The stride-1 conv over the dilated gradient produces all H_I rows of
    # dX directly: rows past the dilated extent read pure zero padding and
    # come out zero (a ragged-stride forward input leaves such rows).
    rows_needed = (n_h * hb - 1) + F
    pad_bottom = pt + max(0, rows_needed - (H_dil + 2 * pt))
    pad_right = pt + max(0, (W_I - 1) + F - (W_dil + 2 * pt))
    # One lax.pad: S-1 interior zeros (dilation) + transposed edge padding.
    xp = jax.lax.pad(dy, jnp.zeros((), dy.dtype),
                     ((0, 0, 0), (pt, pad_bottom, S - 1), (pt, pad_right, S - 1),
                      (0, 0, 0)))
    dip, dop = _round_up(d_out, bdi), _round_up(d_in, bdo)
    xp = pad_dim(xp, 3, dip)
    # Spatially flipped, channel-swapped filters: [F, F, D_O, D_I].
    ft = jnp.flip(f, (0, 1)).transpose(0, 1, 3, 2)
    ftp = pad_dim(pad_dim(ft, 2, dip), 3, dop)
    bias = jnp.zeros((1, dop), jnp.float32)

    out = conv2d_fused_pallas(
        xp, ftp, bias, stride=1, block_h=hb, block_do=bdo, block_di=bdi,
        H_O=H_I, W_O=W_I, relu=False, pool=1,
        out_dtype=out_dtype, interpret=interpret,
    )
    dx = out[:, :H_I, :, :d_in]
    return dx if batched else dx[0]


def _dgrad_impl(dy, f, *, schedule, out_dtype, interpret, stride=1, padding=0,
                out_hw=None, block_h=None, block_do=None, block_di=None):
    del block_h, block_do, block_di  # consumed by the planner
    return _dgrad_impl_jit(
        dy, f, stride=stride, padding=padding, out_hw=out_hw,
        schedule=schedule, out_dtype=out_dtype, interpret=interpret,
    )


dgrad_op = pallas_op(
    "conv2d_dgrad",
    planner=ConvDgradPlanner,
    shape_args=_dgrad_shape_args,
    impl=_dgrad_impl,
    reference=conv2d_dgrad_ref,
)


def conv2d_dgrad(
    dy: jax.Array,
    f: jax.Array,
    *,
    stride: int = 1,
    padding: int = 0,
    out_hw: tuple[int, int] | None = None,
    schedule: Schedule | None = None,
    block_h: int | None = None,
    block_do: int | None = None,
    block_di: int | None = None,
    out_dtype=None,
    interpret: bool | None = None,
    machine: MachineModel = TPU_V5E,
) -> jax.Array:
    """Input gradient of :func:`repro.kernels.conv2d.ops.conv2d`.

    ``dy``: [B, H_O, W_O, D_O] or [H_O, W_O, D_O] cotangent of the conv
    output; ``f``: [F, F, D_I, D_O] the forward filters.  Runs the forward
    strip kernel on the S-dilated, (F-1-P)-padded gradient with flipped,
    channel-swapped filters.  ``out_hw`` = (H_I, W_I) of the forward input
    pads the result up to the true input extent (ragged strides leave
    trailing zero-gradient rows).  Blocking: ``schedule`` > ``block_*``
    pins > ConvDgradPlanner.
    """
    return dgrad_op(
        dy, f, schedule=schedule, machine=machine, interpret=interpret,
        out_dtype=out_dtype or dy.dtype, stride=stride, padding=padding,
        out_hw=out_hw, block_h=block_h, block_do=block_do, block_di=block_di,
    )


# ---------------------------------------------------------------------------
# wgrad: dW accumulated over the (batch, strip) grid
# ---------------------------------------------------------------------------


def conv2d_wgrad_ref(x, dy, *, F: int, stride: int = 1, padding: int = 0):
    """XLA oracle: the VJP of conv2d_ref with respect to its filters."""
    from repro.kernels.conv2d.ref import conv2d_ref

    f0 = jnp.zeros((F, F, x.shape[-1], dy.shape[-1]), jnp.float32)
    _, vjp = jax.vjp(
        lambda f: conv2d_ref(x, f, stride=stride, padding=padding,
                             out_dtype=jnp.float32), f0)
    return vjp(dy.astype(jnp.float32))[0]


def _wgrad_kernel(x_ref, g_ref, o_ref, acc_ref, *,
                  n_b: int, n_h: int, F: int, S: int, block_h: int, W_O: int):
    b, h = pl.program_id(2), pl.program_id(3)

    @pl.when((b == 0) & (h == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)  # dW stack starts at zero

    x = x_ref[0]  # [(block_h-1)*S+F, W_in, bdi] halo'd input strip block
    bdi = x.shape[-1]
    g = g_ref[0].reshape(block_h * W_O, -1)  # [strip rows, bdo] gradient
    # dW[ky, kx] += win^T @ g — F^2 transposed MXU matmuls per strip.
    for ky in range(F):
        for kx in range(F):
            win = jax.lax.slice(
                x,
                (ky, kx, 0),
                (ky + (block_h - 1) * S + 1, kx + (W_O - 1) * S + 1, bdi),
                (S, S, 1),
            ).reshape(block_h * W_O, bdi)
            acc_ref[ky, kx] += jax.lax.dot_general(
                win, g, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )

    @pl.when((b == n_b - 1) & (h == n_h - 1))
    def _flush():  # single DmaStore of the accumulated filter gradient
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def conv2d_wgrad_pallas(
    x_pad: jax.Array,
    dy: jax.Array,
    *,
    F: int,
    stride: int,
    block_h: int,
    block_do: int,
    block_di: int,
    H_O: int,
    W_O: int,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Filter gradient over the (d_i, d_o, batch, strip) grid.

    ``x_pad``: [B, H_in, W_in, D_I] spatially pre-padded inputs with
    H_in >= (n_h*block_h - 1)*stride + F; ``dy``: [B, n_h*block_h, W_O,
    D_O] with rows beyond H_O zero-padded (zero rows contribute nothing).
    D_I, D_O must be multiples of the channel blocks.  Returns
    [F, F, D_I, D_O].
    """
    B, H_in, W_in, d_in = x_pad.shape
    B2, H_g, W_g, d_out = dy.shape
    assert B == B2 and W_g == W_O, (x_pad.shape, dy.shape, W_O)
    n_h = H_g // block_h
    assert n_h * block_h == H_g and n_h == -(-H_O // block_h)
    assert d_in % block_di == 0 and d_out % block_do == 0
    assert H_in >= (n_h * block_h - 1) * stride + F
    assert W_in >= (W_O - 1) * stride + F
    h_halo = (block_h - 1) * stride + F
    out_dtype = out_dtype or x_pad.dtype

    kernel = functools.partial(
        _wgrad_kernel, n_b=B, n_h=n_h, F=F, S=stride,
        block_h=block_h, W_O=W_O,
    )
    return pl.pallas_call(
        kernel,
        grid=(d_in // block_di, d_out // block_do, B, n_h),
        in_specs=[
            # Halo-overlapped input strip block (element-granular), indexed
            # by (batch, strip, d_i-block): re-streamed once per d_o stack.
            pl.BlockSpec(
                (1, h_halo, W_in, block_di),
                lambda di, do, b, h: (b, h * block_h * stride, 0,
                                      di * block_di),
                indexing_mode=pl.unblocked,
            ),
            # Gradient strip for the d_o stack: re-streamed once per
            # d_i-block.
            pl.BlockSpec((1, block_h, W_O, block_do),
                         lambda di, do, b, h: (b, h, 0, do)),
        ],
        # The dW block ignores (b, h): it stays VMEM-resident across the
        # whole batch/strip sweep and is written once at the flush.
        out_specs=pl.BlockSpec((F, F, block_di, block_do),
                               lambda di, do, b, h: (0, 0, di, do)),
        out_shape=jax.ShapeDtypeStruct((F, F, d_in, d_out), out_dtype),
        scratch_shapes=[pltpu.VMEM((F, F, block_di, block_do), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(x_pad, dy)


def _wgrad_shape_args(x, dy, *, F, stride=1, padding=0,
                      block_h=None, block_do=None, block_di=None):
    batched = x.ndim == 4
    B = x.shape[0] if batched else 1
    H, W, d_in = x.shape[-3], x.shape[-2], x.shape[-1]
    H_O, W_O, d_out = dy.shape[-3], dy.shape[-2], dy.shape[-1]
    return dict(
        H_O=H_O, W_O=W_O, F=F, S=stride, d_in=d_in, d_out=d_out,
        in_bytes=x.dtype.itemsize, batch=B, padding=padding, H_I=H, W_I=W,
        block_h=block_h, block_do=block_do, block_di=block_di,
    )


@functools.partial(
    jax.jit,
    static_argnames=("F", "stride", "padding", "schedule", "out_dtype",
                     "interpret"),
)
def _wgrad_impl_jit(x, dy, *, F, stride, padding, schedule, out_dtype,
                    interpret):
    batched = x.ndim == 4
    if not batched:
        x, dy = x[None], dy[None]
    B, H, W, d_in = x.shape
    _, H_O, W_O, d_out = dy.shape
    S, P = stride, padding

    bdi = schedule.block("block_di", min(_round_up(d_in, _LANE), 512))
    hb = max(1, min(schedule.block("block_h", H_O), H_O))
    bdo = min(schedule.block("block_do", _LANE), _round_up(d_out, _LANE))

    n_h = -(-H_O // hb)
    rows_needed = (n_h * hb - 1) * S + F
    pad_bottom = P + max(0, rows_needed - (H + 2 * P))
    dip, dop = _round_up(d_in, bdi), _round_up(d_out, bdo)
    xp = jnp.pad(x, ((0, 0), (P, pad_bottom), (P, P), (0, 0)))
    xp = pad_dim(xp, 3, dip)
    gp = pad_dim(pad_dim(dy, 1, n_h * hb), 3, dop)

    dw = conv2d_wgrad_pallas(
        xp, gp, F=F, stride=S, block_h=hb, block_do=bdo, block_di=bdi,
        H_O=H_O, W_O=W_O, out_dtype=out_dtype, interpret=interpret,
    )
    return dw[:, :, :d_in, :d_out]


def _wgrad_impl(x, dy, *, schedule, out_dtype, interpret, F, stride=1,
                padding=0, block_h=None, block_do=None, block_di=None):
    del block_h, block_do, block_di  # consumed by the planner
    return _wgrad_impl_jit(
        x, dy, F=F, stride=stride, padding=padding,
        schedule=schedule, out_dtype=out_dtype, interpret=interpret,
    )


wgrad_op = pallas_op(
    "conv2d_wgrad",
    planner=ConvWgradPlanner,
    shape_args=_wgrad_shape_args,
    impl=_wgrad_impl,
    reference=conv2d_wgrad_ref,
)


def conv2d_wgrad(
    x: jax.Array,
    dy: jax.Array,
    *,
    F: int,
    stride: int = 1,
    padding: int = 0,
    schedule: Schedule | None = None,
    block_h: int | None = None,
    block_do: int | None = None,
    block_di: int | None = None,
    out_dtype=None,
    interpret: bool | None = None,
    machine: MachineModel = TPU_V5E,
) -> jax.Array:
    """Filter gradient of :func:`repro.kernels.conv2d.ops.conv2d`.

    ``x``: [B, H, W, D_I] or [H, W, D_I] the forward input; ``dy``: the
    matching conv-output cotangent; ``F`` the filter extent.  One batched
    ``pallas_call`` accumulates dW in VMEM over the whole (batch, strip)
    grid and stores it once.  Blocking: ``schedule`` > ``block_*`` pins >
    ConvWgradPlanner.
    """
    return wgrad_op(
        x, dy, schedule=schedule, machine=machine, interpret=interpret,
        out_dtype=out_dtype or x.dtype, F=F, stride=stride, padding=padding,
        block_h=block_h, block_do=block_do, block_di=block_di,
    )
