"""Planned backward kernels for the strip-tiled conv2d pipeline.

Two first-class ``pallas_op`` registrations (DESIGN.md Sec. 4):

* ``conv2d_dgrad`` — the input gradient.  dX is a *stride-1* strip conv
  over the S-dilated gradient with spatially flipped, channel-swapped
  filters, so it runs the forward kernel (:func:`conv2d_fused_pallas`)
  verbatim on that transposed geometry: halo-overlapped gradient strips,
  Delta_I output stacking, same VMEM accumulator discipline.  The
  dilation + transposed zero padding happen in one ``lax.pad``.
* ``conv2d_wgrad`` — the filter gradient.  dW[ky, kx] accumulates
  X_strip^T @ dY_strip over a (d_i-block, d_o-stack, batch, strip) grid;
  the F^2 x block_di x block_do f32 accumulator is the VMEM-resident
  output stack (it never round-trips HBM between batch elements or
  strips) and flushes exactly once on the last (batch, strip) step.

Both ops take an optional ``mask``/``pool`` pair — the int8 pool-argmax/
ReLU mask the forward fused kernel emitted as a residual.  When given,
:func:`epilogue_scatter` runs as the kernel's *prologue inside the same
jit*: the pooled cotangent scatters through the mask into the full-rate
dY both kernels then stream, replacing the old recompute path's full
forward-conv re-run (XLA CSE de-duplicates the scatter between dgrad and
wgrad, so the cost model charges it once, on the dgrad schedule).

Two pipelined execution variants ride on the schedules' ``algorithm``
tag when the install has the manual-DMA surface
(:func:`repro.kernels.pallas_compat.dma_pipeline_supported`):

* dgrad ``"fused_epilogue"`` folds the d_out stream inside each grid step
  behind a double-buffered async-copy loop (the dY-strip fetch overlaps
  the filter stream), dropping the grid's stream dimension;
* wgrad ``"pipelined"`` folds the whole (batch, strip) accumulation sweep
  inside each (d_i, d_o) step the same way.

Without the DMA surface both fall back to the plain BlockSpec pipeline —
identical numerics, serialized streams.

Blocking comes from :class:`repro.plan.ConvDgradPlanner` /
:class:`repro.plan.ConvWgradPlanner`; an explicit ``schedule=`` overrides
the planner, mirroring the forward wrapper contract.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.machine import TPU_V5E, MachineModel
from repro.kernels.conv2d.conv2d import conv2d_fused_pallas
from repro.kernels.pallas_compat import (dma_pipeline_supported,
                                         tpu_compiler_params)
from repro.plan import ConvDgradPlanner, ConvWgradPlanner, Schedule, pad_dim, pallas_op
from repro.plan.planners import round_up as _round_up

_LANE = 128


# ---------------------------------------------------------------------------
# Fused epilogue VJP: scatter dY through the saved pool-argmax/ReLU mask
# ---------------------------------------------------------------------------


def epilogue_scatter(g: jax.Array, mask: jax.Array, pool: int) -> jax.Array:
    """The epilogue VJP from the saved mask: route the pooled cotangent
    ``g`` [..., Hp, Wp, C] to the argmax position of each pool window
    (zero elsewhere, per the int8 mask — index in [0, pool^2), or pool^2
    for a dead all-ReLU-clamped window), returning the full-rate dY
    [..., Hp*pool, Wp*pool, C] in f32.  With ``pool == 1`` the mask is the
    ReLU liveness bit (0 alive, 1 dead).  Winner-take-all on exact
    pool-window ties, where the XLA reference VJP splits evenly — a
    measure-zero difference off the ReLU-dead case (which both zero)."""
    m = mask.astype(jnp.int32)
    g = g.astype(jnp.float32)
    if pool == 1:
        return jnp.where(m == 0, g, 0.0)
    p2 = pool * pool
    oh = jax.nn.one_hot(m, p2, dtype=g.dtype)  # dead index p2 -> zero row
    d = g[..., None] * oh
    *lead, hp, wp, c, _ = d.shape
    d = d.reshape(*lead, hp, wp, c, pool, pool)
    # (..., Hp, Wp, C, py, px) -> (..., Hp, py, Wp, px, C)
    off = len(lead)
    perm = tuple(range(off)) + tuple(off + i for i in (0, 3, 1, 4, 2))
    return d.transpose(perm).reshape(*lead, hp * pool, wp * pool, c)


# ---------------------------------------------------------------------------
# dgrad: dX via the forward strip kernel on the transposed geometry
# ---------------------------------------------------------------------------


def dgrad_out_extent(out: int, F: int, stride: int, padding: int) -> int:
    """Default dX extent for one axis: the exact-cover forward input
    (H_O - 1)*S + F - 2P.  A forward input larger than this (ragged
    stride) still back-propagates into its first min(H_I, (H_O-1)S+F-P)
    rows — pass the true extent via ``out_hw`` and the kernel computes
    those rows (the rest are zeros it also produces)."""
    return (out - 1) * stride + F - 2 * padding


def conv2d_dgrad_ref(dy, f, *, stride: int = 1, padding: int = 0, out_hw=None):
    """XLA oracle: the VJP of conv2d_ref with respect to its input."""
    from repro.kernels.conv2d.ref import conv2d_ref

    F = f.shape[0]
    d_in = f.shape[2]
    H_O, W_O = dy.shape[-3], dy.shape[-2]
    H_I, W_I = out_hw if out_hw is not None else (
        dgrad_out_extent(H_O, F, stride, padding),
        dgrad_out_extent(W_O, F, stride, padding),
    )
    shape = dy.shape[:-3] + (H_I, W_I, d_in)
    x0 = jnp.zeros(shape, jnp.float32)
    _, vjp = jax.vjp(
        lambda x: conv2d_ref(x, f, stride=stride, padding=padding,
                             out_dtype=jnp.float32), x0)
    return vjp(dy.astype(jnp.float32))[0]


def _dgrad_shape_args(dy, f, *, stride=1, padding=0, out_hw=None,
                      mask=None, pool=1,
                      block_h=None, block_do=None, block_di=None):
    """Planner shapes (forward-layer terms) from concrete operands;
    ``out_hw`` is the dX extent the kernel actually produces.  With a
    mask residual ``dy`` is the *pooled* cotangent: the full-rate extents
    are scaled back up and the pool factor (never the traced mask array —
    plans are cached on hashable shapes) rides into the planner, which
    then defaults to the fused_epilogue variant."""
    batched = dy.ndim == 4
    B = dy.shape[0] if batched else 1
    H_O, W_O, d_out = dy.shape[-3], dy.shape[-2], dy.shape[-1]
    if mask is not None:
        H_O, W_O = H_O * pool, W_O * pool
    H_I, W_I = out_hw if out_hw is not None else (None, None)
    return dict(
        H_O=H_O, W_O=W_O, F=f.shape[0], S=stride, P=padding,
        d_in=f.shape[2], d_out=d_out, in_bytes=dy.dtype.itemsize, batch=B,
        H_I=H_I, W_I=W_I, pool=pool if mask is not None else None,
        block_h=block_h, block_do=block_do, block_di=block_di,
    )


def _dgrad_dma_kernel(x_hbm, f_hbm, o_ref, acc_ref, *, n_di: int, F: int,
                      block_h: int, W_O: int, block_di: int, block_do: int,
                      h_halo: int):
    """The fused_epilogue dgrad step: the d_out stream runs *inside* the
    grid step as a manually double-buffered async-copy loop — the dY-strip
    slab for the next d_out block is in flight while the current slab's
    F^2 matmuls accumulate (DmaLoad/DmaWait prefetch, by hand)."""
    b, h, do = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    def body(xs, fs, sem):
        def copies(di, slot):
            return (
                pltpu.make_async_copy(
                    x_hbm.at[b, pl.ds(h * block_h, h_halo), :,
                             pl.ds(di * block_di, block_di)],
                    xs.at[slot], sem.at[slot, 0]),
                pltpu.make_async_copy(
                    f_hbm.at[:, :, pl.ds(di * block_di, block_di),
                             pl.ds(do * block_do, block_do)],
                    fs.at[slot], sem.at[slot, 1]),
            )

        def start(di, slot):
            for c in copies(di, slot):
                c.start()

        def wait(di, slot):
            for c in copies(di, slot):
                c.wait()

        acc_ref[...] = jnp.zeros_like(acc_ref)
        start(0, 0)  # pipeline fill: warm-up fetch of the first slab

        def step(di, carry):
            slot = jax.lax.rem(di, 2)

            @pl.when(di + 1 < n_di)
            def _prefetch():  # next slab's DMA overlaps this slab's MACs
                start(di + 1, jax.lax.rem(di + 1, 2))

            wait(di, slot)
            x = xs[slot]
            fblk = fs[slot]
            for ky in range(F):  # stride-1 conv: F^2 shifted MXU matmuls
                for kx in range(F):
                    win = jax.lax.slice(
                        x, (ky, kx, 0),
                        (ky + block_h, kx + W_O, block_di),
                    ).reshape(block_h * W_O, block_di)
                    acc_ref[...] += jnp.dot(
                        win, fblk[ky, kx],
                        preferred_element_type=jnp.float32)
            return carry

        jax.lax.fori_loop(0, n_di, step, 0)
        o_ref[0] = acc_ref[...].reshape(block_h, W_O, -1).astype(o_ref.dtype)

    pl.run_scoped(
        body,
        xs=pltpu.VMEM((2, h_halo, x_hbm.shape[2], block_di), x_hbm.dtype),
        fs=pltpu.VMEM((2, F, F, block_di, block_do), f_hbm.dtype),
        sem=pltpu.SemaphoreType.DMA((2, 2)),
    )


def _dgrad_dma_pallas(x_pad, f, *, block_h: int, block_do: int,
                      block_di: int, H_O: int, W_O: int, out_dtype,
                      interpret: bool):
    """Double-buffered fused_epilogue dgrad: grid (B, strip, dX stack)
    with the d_in-side stream folded in-kernel.  Same operands and result
    as stride-1 relu/pool-free :func:`conv2d_fused_pallas` (which remains
    the fallback when the DMA surface is missing)."""
    B, H_in, W_in, d_in = x_pad.shape
    F, F2, d_in2, d_out = f.shape
    assert F == F2 and d_in == d_in2
    assert d_in % block_di == 0 and d_out % block_do == 0
    n_h = -(-H_O // block_h)
    assert H_in >= (n_h * block_h - 1) + F
    assert W_in >= (W_O - 1) + F
    kernel = functools.partial(
        _dgrad_dma_kernel, n_di=d_in // block_di, F=F, block_h=block_h,
        W_O=W_O, block_di=block_di, block_do=block_do,
        h_halo=block_h - 1 + F,
    )
    return pl.pallas_call(
        kernel,
        grid=(B, n_h, d_out // block_do),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),  # streamed by hand
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec(
            (1, block_h, W_O, block_do), lambda b, h, do: (b, h, 0, do)),
        out_shape=jax.ShapeDtypeStruct(
            (B, n_h * block_h, W_O, d_out), out_dtype or x_pad.dtype),
        scratch_shapes=[pltpu.VMEM((block_h * W_O, block_do), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x_pad, f)


@functools.partial(
    jax.jit,
    static_argnames=("stride", "padding", "out_hw", "pool", "schedule",
                     "out_dtype", "interpret"),
)
def _dgrad_impl_jit(dy, f, mask, *, stride, padding, out_hw, pool, schedule,
                    out_dtype, interpret):
    batched = dy.ndim == 4
    if not batched:
        dy = dy[None]
        if mask is not None:
            mask = mask[None]
    if mask is not None:
        # Fused epilogue VJP prologue: rebuild the full-rate dY from the
        # pooled cotangent and the saved mask, inside this jit (the twin
        # scatter in the wgrad jit CSEs away when both run under one
        # enclosing backward jit).
        dy = epilogue_scatter(dy, mask, pool).astype(dy.dtype)
    B, H_O, W_O, d_out = dy.shape
    F = f.shape[0]
    d_in = f.shape[2]
    S, P = stride, padding
    assert P <= F - 1, f"dgrad needs padding <= F-1, got {P} for F={F}"
    H_I, W_I = out_hw if out_hw is not None else (
        dgrad_out_extent(H_O, F, S, P), dgrad_out_extent(W_O, F, S, P))
    pt = F - 1 - P  # transposed padding

    bdi = schedule.block("block_di", min(_round_up(d_out, _LANE), 512))
    hb = max(1, min(schedule.block("block_h", H_I), H_I))
    bdo = min(schedule.block("block_do", _LANE), _round_up(d_in, _LANE))
    if interpret:
        # Interpret mode has no 128-lane MXU: clamp channel blocks that
        # already cover their extent down to it, so off-TPU runs don't
        # multiply lane-padding zeros (128x waste at CNN channel counts).
        # Only a covering block shrinks, so every grid extent — and with
        # it critical_path_steps — is unchanged.
        if bdi >= d_out:
            bdi = max(1, d_out)
        if bdo >= d_in:
            bdo = max(1, d_in)

    n_h = -(-H_I // hb)
    H_dil, W_dil = (H_O - 1) * S + 1, (W_O - 1) * S + 1
    # The stride-1 conv over the dilated gradient produces all H_I rows of
    # dX directly: rows past the dilated extent read pure zero padding and
    # come out zero (a ragged-stride forward input leaves such rows).
    rows_needed = (n_h * hb - 1) + F
    pad_bottom = pt + max(0, rows_needed - (H_dil + 2 * pt))
    pad_right = pt + max(0, (W_I - 1) + F - (W_dil + 2 * pt))
    # One lax.pad: S-1 interior zeros (dilation) + transposed edge padding.
    xp = jax.lax.pad(dy, jnp.zeros((), dy.dtype),
                     ((0, 0, 0), (pt, pad_bottom, S - 1), (pt, pad_right, S - 1),
                      (0, 0, 0)))
    dip, dop = _round_up(d_out, bdi), _round_up(d_in, bdo)
    xp = pad_dim(xp, 3, dip)
    # Spatially flipped, channel-swapped filters: [F, F, D_O, D_I].
    ft = jnp.flip(f, (0, 1)).transpose(0, 1, 3, 2)
    ftp = pad_dim(pad_dim(ft, 2, dip), 3, dop)
    bias = jnp.zeros((1, dop), jnp.float32)

    if (getattr(schedule, "algorithm", "direct") == "fused_epilogue"
            and dma_pipeline_supported()):
        out = _dgrad_dma_pallas(
            xp, ftp, block_h=hb, block_do=bdo, block_di=bdi,
            H_O=H_I, W_O=W_I, out_dtype=out_dtype, interpret=interpret,
        )
    else:
        out = conv2d_fused_pallas(
            xp, ftp, bias, stride=1, block_h=hb, block_do=bdo, block_di=bdi,
            H_O=H_I, W_O=W_I, relu=False, pool=1,
            out_dtype=out_dtype, interpret=interpret,
        )
    dx = out[:, :H_I, :, :d_in]
    return dx if batched else dx[0]


def _dgrad_impl(dy, f, *, schedule, out_dtype, interpret, stride=1, padding=0,
                out_hw=None, mask=None, pool=1, block_h=None, block_do=None,
                block_di=None):
    del block_h, block_do, block_di  # consumed by the planner
    return _dgrad_impl_jit(
        dy, f, mask, stride=stride, padding=padding, out_hw=out_hw,
        pool=pool, schedule=schedule, out_dtype=out_dtype,
        interpret=interpret,
    )


dgrad_op = pallas_op(
    "conv2d_dgrad",
    planner=ConvDgradPlanner,
    shape_args=_dgrad_shape_args,
    impl=_dgrad_impl,
    reference=conv2d_dgrad_ref,
)


def conv2d_dgrad(
    dy: jax.Array,
    f: jax.Array,
    *,
    stride: int = 1,
    padding: int = 0,
    out_hw: tuple[int, int] | None = None,
    mask: jax.Array | None = None,
    pool: int = 1,
    schedule: Schedule | None = None,
    block_h: int | None = None,
    block_do: int | None = None,
    block_di: int | None = None,
    out_dtype=None,
    interpret: bool | None = None,
    machine: MachineModel = TPU_V5E,
) -> jax.Array:
    """Input gradient of :func:`repro.kernels.conv2d.ops.conv2d`.

    ``dy``: [B, H_O, W_O, D_O] or [H_O, W_O, D_O] cotangent of the conv
    output; ``f``: [F, F, D_I, D_O] the forward filters.  Runs the forward
    strip kernel on the S-dilated, (F-1-P)-padded gradient with flipped,
    channel-swapped filters.  ``out_hw`` = (H_I, W_I) of the forward input
    pads the result up to the true input extent (ragged strides leave
    trailing zero-gradient rows).

    With ``mask``/``pool`` (the forward fused kernel's int8 epilogue-VJP
    residual), ``dy`` is the *pooled* post-epilogue cotangent:
    :func:`epilogue_scatter` rebuilds the full-rate conv-output gradient
    in-jit before the kernel runs — no recompute conv.  Blocking:
    ``schedule`` > ``block_*`` pins > ConvDgradPlanner.
    """
    return dgrad_op(
        dy, f, schedule=schedule, machine=machine, interpret=interpret,
        out_dtype=out_dtype or dy.dtype, stride=stride, padding=padding,
        out_hw=out_hw, mask=mask, pool=pool,
        block_h=block_h, block_do=block_do, block_di=block_di,
    )


# ---------------------------------------------------------------------------
# wgrad: dW accumulated over the (batch, strip) grid
# ---------------------------------------------------------------------------


def conv2d_wgrad_ref(x, dy, *, F: int, stride: int = 1, padding: int = 0):
    """XLA oracle: the VJP of conv2d_ref with respect to its filters."""
    from repro.kernels.conv2d.ref import conv2d_ref

    f0 = jnp.zeros((F, F, x.shape[-1], dy.shape[-1]), jnp.float32)
    _, vjp = jax.vjp(
        lambda f: conv2d_ref(x, f, stride=stride, padding=padding,
                             out_dtype=jnp.float32), f0)
    return vjp(dy.astype(jnp.float32))[0]


def _wgrad_kernel(x_ref, g_ref, o_ref, acc_ref, *,
                  n_b: int, n_h: int, F: int, S: int, block_h: int, W_O: int):
    b, h = pl.program_id(2), pl.program_id(3)

    @pl.when((b == 0) & (h == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)  # dW stack starts at zero

    x = x_ref[0]  # [(block_h-1)*S+F, W_in, bdi] halo'd input strip block
    bdi = x.shape[-1]
    g = g_ref[0].reshape(block_h * W_O, -1)  # [strip rows, bdo] gradient
    # dW[ky, kx] += win^T @ g — F^2 transposed MXU matmuls per strip.
    for ky in range(F):
        for kx in range(F):
            win = jax.lax.slice(
                x,
                (ky, kx, 0),
                (ky + (block_h - 1) * S + 1, kx + (W_O - 1) * S + 1, bdi),
                (S, S, 1),
            ).reshape(block_h * W_O, bdi)
            acc_ref[ky, kx] += jax.lax.dot_general(
                win, g, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )

    @pl.when((b == n_b - 1) & (h == n_h - 1))
    def _flush():  # single DmaStore of the accumulated filter gradient
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def conv2d_wgrad_pallas(
    x_pad: jax.Array,
    dy: jax.Array,
    *,
    F: int,
    stride: int,
    block_h: int,
    block_do: int,
    block_di: int,
    H_O: int,
    W_O: int,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Filter gradient over the (d_i, d_o, batch, strip) grid.

    ``x_pad``: [B, H_in, W_in, D_I] spatially pre-padded inputs with
    H_in >= (n_h*block_h - 1)*stride + F; ``dy``: [B, n_h*block_h, W_O,
    D_O] with rows beyond H_O zero-padded (zero rows contribute nothing).
    D_I, D_O must be multiples of the channel blocks.  Returns
    [F, F, D_I, D_O].
    """
    B, H_in, W_in, d_in = x_pad.shape
    B2, H_g, W_g, d_out = dy.shape
    assert B == B2 and W_g == W_O, (x_pad.shape, dy.shape, W_O)
    n_h = H_g // block_h
    assert n_h * block_h == H_g and n_h == -(-H_O // block_h)
    assert d_in % block_di == 0 and d_out % block_do == 0
    assert H_in >= (n_h * block_h - 1) * stride + F
    assert W_in >= (W_O - 1) * stride + F
    h_halo = (block_h - 1) * stride + F
    out_dtype = out_dtype or x_pad.dtype

    kernel = functools.partial(
        _wgrad_kernel, n_b=B, n_h=n_h, F=F, S=stride,
        block_h=block_h, W_O=W_O,
    )
    return pl.pallas_call(
        kernel,
        grid=(d_in // block_di, d_out // block_do, B, n_h),
        in_specs=[
            # Halo-overlapped input strip block (element-granular), indexed
            # by (batch, strip, d_i-block): re-streamed once per d_o stack.
            pl.BlockSpec(
                (1, h_halo, W_in, block_di),
                lambda di, do, b, h: (b, h * block_h * stride, 0,
                                      di * block_di),
                indexing_mode=pl.unblocked,
            ),
            # Gradient strip for the d_o stack: re-streamed once per
            # d_i-block.
            pl.BlockSpec((1, block_h, W_O, block_do),
                         lambda di, do, b, h: (b, h, 0, do)),
        ],
        # The dW block ignores (b, h): it stays VMEM-resident across the
        # whole batch/strip sweep and is written once at the flush.
        out_specs=pl.BlockSpec((F, F, block_di, block_do),
                               lambda di, do, b, h: (0, 0, di, do)),
        out_shape=jax.ShapeDtypeStruct((F, F, d_in, d_out), out_dtype),
        scratch_shapes=[pltpu.VMEM((F, F, block_di, block_do), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(x_pad, dy)


def _wgrad_shape_args(x, dy, *, F, stride=1, padding=0, mask=None, pool=1,
                      block_h=None, block_do=None, block_di=None):
    batched = x.ndim == 4
    B = x.shape[0] if batched else 1
    H, W, d_in = x.shape[-3], x.shape[-2], x.shape[-1]
    H_O, W_O, d_out = dy.shape[-3], dy.shape[-2], dy.shape[-1]
    if mask is not None:
        # dy is the pooled cotangent: the kernel streams the scattered
        # full-rate gradient, so the planner models the scaled extents.
        # (The mask array itself never enters the dict — plans are cached
        # on hashable shapes; the scatter is charged on the dgrad side.)
        H_O, W_O = H_O * pool, W_O * pool
    return dict(
        H_O=H_O, W_O=W_O, F=F, S=stride, d_in=d_in, d_out=d_out,
        in_bytes=x.dtype.itemsize, batch=B, padding=padding, H_I=H, W_I=W,
        block_h=block_h, block_do=block_do, block_di=block_di,
    )


def _wgrad_dma_kernel(x_hbm, g_hbm, o_ref, acc_ref, *, n_b: int, n_h: int,
                      F: int, S: int, block_h: int, W_O: int, block_di: int,
                      block_do: int, h_halo: int):
    """The pipelined wgrad step: the whole (batch, strip) accumulation
    sweep is folded inside each (d_i, d_o) grid step as a manually
    double-buffered async-copy loop — the next strip's X/dY slabs are in
    flight while the current strip's F^2 transposed matmuls accumulate."""
    di, do = pl.program_id(0), pl.program_id(1)

    def body(xs, gs, sem):
        def copies(t, slot):
            b = t // n_h
            h = jax.lax.rem(t, n_h)
            return (
                pltpu.make_async_copy(
                    x_hbm.at[b, pl.ds(h * block_h * S, h_halo), :,
                             pl.ds(di * block_di, block_di)],
                    xs.at[slot], sem.at[slot, 0]),
                pltpu.make_async_copy(
                    g_hbm.at[b, pl.ds(h * block_h, block_h), :,
                             pl.ds(do * block_do, block_do)],
                    gs.at[slot], sem.at[slot, 1]),
            )

        def start(t, slot):
            for c in copies(t, slot):
                c.start()

        def wait(t, slot):
            for c in copies(t, slot):
                c.wait()

        acc_ref[...] = jnp.zeros_like(acc_ref)
        T = n_b * n_h
        start(0, 0)  # pipeline fill: warm-up fetch of the first strip

        def step(t, carry):
            slot = jax.lax.rem(t, 2)

            @pl.when(t + 1 < T)
            def _prefetch():  # next strip's DMA overlaps this strip's MACs
                start(t + 1, jax.lax.rem(t + 1, 2))

            wait(t, slot)
            x = xs[slot]
            g = gs[slot].reshape(block_h * W_O, block_do)
            for ky in range(F):
                for kx in range(F):
                    win = jax.lax.slice(
                        x, (ky, kx, 0),
                        (ky + (block_h - 1) * S + 1,
                         kx + (W_O - 1) * S + 1, block_di),
                        (S, S, 1),
                    ).reshape(block_h * W_O, block_di)
                    acc_ref[ky, kx] += jax.lax.dot_general(
                        win, g, (((0,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32)
            return carry

        jax.lax.fori_loop(0, T, step, 0)
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)

    pl.run_scoped(
        body,
        xs=pltpu.VMEM((2, h_halo, x_hbm.shape[2], block_di), x_hbm.dtype),
        gs=pltpu.VMEM((2, block_h, W_O, block_do), g_hbm.dtype),
        sem=pltpu.SemaphoreType.DMA((2, 2)),
    )


def _wgrad_dma_pallas(x_pad, dy, *, F: int, stride: int, block_h: int,
                      block_do: int, block_di: int, H_O: int, W_O: int,
                      out_dtype, interpret: bool):
    """Double-buffered pipelined wgrad: grid (d_i, d_o) with the whole
    (batch, strip) sweep folded in-kernel.  Same operands and result as
    :func:`conv2d_wgrad_pallas` (which remains the fallback when the DMA
    surface is missing)."""
    B, H_in, W_in, d_in = x_pad.shape
    B2, H_g, W_g, d_out = dy.shape
    assert B == B2 and W_g == W_O, (x_pad.shape, dy.shape, W_O)
    n_h = H_g // block_h
    assert n_h * block_h == H_g and n_h == -(-H_O // block_h)
    assert d_in % block_di == 0 and d_out % block_do == 0
    assert H_in >= (n_h * block_h - 1) * stride + F
    assert W_in >= (W_O - 1) * stride + F
    kernel = functools.partial(
        _wgrad_dma_kernel, n_b=B, n_h=n_h, F=F, S=stride, block_h=block_h,
        W_O=W_O, block_di=block_di, block_do=block_do,
        h_halo=(block_h - 1) * stride + F,
    )
    return pl.pallas_call(
        kernel,
        grid=(d_in // block_di, d_out // block_do),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),  # streamed by hand
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((F, F, block_di, block_do),
                               lambda di, do: (0, 0, di, do)),
        out_shape=jax.ShapeDtypeStruct(
            (F, F, d_in, d_out), out_dtype or x_pad.dtype),
        scratch_shapes=[pltpu.VMEM((F, F, block_di, block_do), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(x_pad, dy)


@functools.partial(
    jax.jit,
    static_argnames=("F", "stride", "padding", "pool", "schedule",
                     "out_dtype", "interpret"),
)
def _wgrad_impl_jit(x, dy, mask, *, F, stride, padding, pool, schedule,
                    out_dtype, interpret):
    batched = x.ndim == 4
    if not batched:
        x, dy = x[None], dy[None]
        if mask is not None:
            mask = mask[None]
    if mask is not None:
        # Fused epilogue VJP prologue (see _dgrad_impl_jit; under one
        # enclosing backward jit, XLA CSEs this with the dgrad twin).
        dy = epilogue_scatter(dy, mask, pool).astype(dy.dtype)
    B, H, W, d_in = x.shape
    _, H_O, W_O, d_out = dy.shape
    S, P = stride, padding

    bdi = schedule.block("block_di", min(_round_up(d_in, _LANE), 512))
    hb = max(1, min(schedule.block("block_h", H_O), H_O))
    bdo = min(schedule.block("block_do", _LANE), _round_up(d_out, _LANE))
    if interpret:
        # See _dgrad_impl_jit: shrink covering channel blocks off-TPU so
        # interpret mode doesn't grind through 128-lane padding; grid
        # extents (and critical_path_steps) are unchanged.
        if bdi >= d_in:
            bdi = max(1, d_in)
        if bdo >= d_out:
            bdo = max(1, d_out)

    n_h = -(-H_O // hb)
    rows_needed = (n_h * hb - 1) * S + F
    pad_bottom = P + max(0, rows_needed - (H + 2 * P))
    dip, dop = _round_up(d_in, bdi), _round_up(d_out, bdo)
    xp = jnp.pad(x, ((0, 0), (P, pad_bottom), (P, P), (0, 0)))
    xp = pad_dim(xp, 3, dip)
    gp = pad_dim(pad_dim(dy, 1, n_h * hb), 3, dop)

    if (getattr(schedule, "algorithm", "direct") == "pipelined"
            and dma_pipeline_supported()):
        dw = _wgrad_dma_pallas(
            xp, gp, F=F, stride=S, block_h=hb, block_do=bdo, block_di=bdi,
            H_O=H_O, W_O=W_O, out_dtype=out_dtype, interpret=interpret,
        )
    else:
        dw = conv2d_wgrad_pallas(
            xp, gp, F=F, stride=S, block_h=hb, block_do=bdo, block_di=bdi,
            H_O=H_O, W_O=W_O, out_dtype=out_dtype, interpret=interpret,
        )
    return dw[:, :, :d_in, :d_out]


def _wgrad_impl(x, dy, *, schedule, out_dtype, interpret, F, stride=1,
                padding=0, mask=None, pool=1, block_h=None, block_do=None,
                block_di=None):
    del block_h, block_do, block_di  # consumed by the planner
    return _wgrad_impl_jit(
        x, dy, mask, F=F, stride=stride, padding=padding, pool=pool,
        schedule=schedule, out_dtype=out_dtype, interpret=interpret,
    )


wgrad_op = pallas_op(
    "conv2d_wgrad",
    planner=ConvWgradPlanner,
    shape_args=_wgrad_shape_args,
    impl=_wgrad_impl,
    reference=conv2d_wgrad_ref,
)


def conv2d_wgrad(
    x: jax.Array,
    dy: jax.Array,
    *,
    F: int,
    stride: int = 1,
    padding: int = 0,
    mask: jax.Array | None = None,
    pool: int = 1,
    schedule: Schedule | None = None,
    block_h: int | None = None,
    block_do: int | None = None,
    block_di: int | None = None,
    out_dtype=None,
    interpret: bool | None = None,
    machine: MachineModel = TPU_V5E,
) -> jax.Array:
    """Filter gradient of :func:`repro.kernels.conv2d.ops.conv2d`.

    ``x``: [B, H, W, D_I] or [H, W, D_I] the forward input; ``dy``: the
    matching conv-output cotangent; ``F`` the filter extent.  One batched
    ``pallas_call`` accumulates dW in VMEM over the whole (batch, strip)
    grid and stores it once.  With ``mask``/``pool``, ``dy`` is the pooled
    post-epilogue cotangent and the in-jit scatter rebuilds the full-rate
    gradient first (see :func:`conv2d_dgrad`).  Blocking: ``schedule`` >
    ``block_*`` pins > ConvWgradPlanner.
    """
    return wgrad_op(
        x, dy, schedule=schedule, machine=machine, interpret=interpret,
        out_dtype=out_dtype or x.dtype, F=F, stride=stride, padding=padding,
        mask=mask, pool=pool,
        block_h=block_h, block_do=block_do, block_di=block_di,
    )
