"""Pallas TPU kernel for the paper's convolutional layers (Algs 1/2).

Faithful mapping, extended with batching + spatial strip tiling
(DESIGN.md Sec. 2):

* grid = (B, h_strips, output-channel stacks, input-channel steps) — the
  innermost grid step is one iteration of the paper's ``for d_i`` loop for
  one stack of Delta_O output depth slices (``block_do``) over one spatial
  strip of one image.  ``block_do = 1`` *is* Algorithm 1; ``block_do =
  Delta_O > 1`` *is* Algorithm 2.  The whole batch is served by a single
  ``pallas_call`` — batch is a parallel grid axis, not a vmap of per-image
  launches.
* spatial strip tiling: the f32 VMEM accumulator holds an ``block_h x W_O``
  strip of the output stack, not the full ``H_O x W_O`` plane, so VMEM no
  longer bounds the image size and the capacity chooser can trade strip
  height against Delta_O.  Input blocks are halo-overlapped (``pl.unblocked``
  index maps at element granularity): strip ``h`` reads padded input rows
  ``[h*block_h*S, h*block_h*S + (block_h-1)*S + F)``.
* the strip accumulator lives in VMEM across all d_i steps (the cluster's
  L1-resident ``O[y0:y1, :, D_begin:D_end]``), initialized at d_i = 0 and
  flushed to HBM once at d_i = D_I-1 (the paper's final ``DmaStore``).
* the flush step carries the *fused epilogue*: bias add, ReLU, and an
  optional 2x2 max-pool all happen on the VMEM-resident strip before the
  single store, so the activation never round-trips HBM between the conv
  and its pointwise/pooling tail.
* HBM->VMEM block streaming is double-buffered by the Pallas pipeline —
  the DmaLoad/DmaWait prefetch structure of the pseudocode.

The conv itself is computed as F*F shifted MXU matmuls (any stride S,
in-kernel — no reference fallback for S = 2):
  acc[hb*W_O, bdo] += X_pad[ky : ky+(hb-1)S+1 : S,
                            kx : kx+(W_O-1)S+1 : S, :].reshape(hb*W_O, bdi)
                      @ F[ky, kx]  (bdi, bdo)
which keeps every MAC on the MXU (no im2col materialization in HBM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import tpu_compiler_params


def _conv_kernel(
    x_ref, f_ref, b_ref, o_ref, *rest, n_di: int, F: int, S: int,
    block_h: int, W_O: int, relu: bool, pool: int, emit_mask: bool = False,
):
    if emit_mask:
        mask_ref, acc_ref = rest
    else:
        (acc_ref,) = rest
    d_i = pl.program_id(3)

    @pl.when(d_i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)  # initialize O strip to zero

    x = x_ref[0]  # [(block_h-1)*S+F, W_in, bdi] halo'd input strip block
    bdi = x.shape[-1]
    # Conv() as F^2 shifted (strided) matmuls on the MXU.
    for ky in range(F):
        for kx in range(F):
            win = jax.lax.slice(
                x,
                (ky, kx, 0),
                (ky + (block_h - 1) * S + 1, kx + (W_O - 1) * S + 1, bdi),
                (S, S, 1),
            ).reshape(block_h * W_O, bdi)
            acc_ref[...] += jnp.dot(
                win, f_ref[ky, kx], preferred_element_type=jnp.float32
            )

    @pl.when(d_i == n_di - 1)
    def _flush():  # fused epilogue + DmaStore(O[y0:y1, :, D_begin:D_end])
        out = acc_ref[...].reshape(block_h, W_O, -1)
        out = out + b_ref[0][None, None, :]
        if relu:
            out = jnp.maximum(out, 0.0)
        if pool > 1:
            win = out.reshape(
                block_h // pool, pool, W_O // pool, pool, out.shape[-1]
            )
            out = win.max(axis=(1, 3))
            if emit_mask:
                # int8 epilogue-VJP mask per pooled pixel: the flattened
                # argmax position in [0, pool^2) of the surviving (ReLU-
                # positive) element, or pool^2 = "dead window" (all inputs
                # clamped to zero — the gradient routes nowhere).  Ties pick
                # the first occurrence (descending-position overwrite);
                # the backward scatter is winner-take-all, matching the
                # reference VJP up to measure-zero exact ties.
                idx = jnp.full(out.shape, pool * pool, jnp.int32)
                for pos in reversed(range(pool * pool)):
                    py, px = divmod(pos, pool)
                    v = win[:, py, :, px, :]
                    idx = jnp.where((v == out) & (out > 0), pos, idx)
                mask_ref[0] = idx.astype(jnp.int8)
        elif emit_mask:
            # pool == 1: the ReLU liveness bit alone (0 alive, 1 dead).
            mask_ref[0] = jnp.where(out > 0, 0, 1).astype(jnp.int8)
        o_ref[0] = out.astype(o_ref.dtype)


def conv2d_fused_pallas(
    x_pad: jax.Array,
    f: jax.Array,
    bias: jax.Array,
    *,
    stride: int,
    block_h: int,
    block_do: int,
    block_di: int,
    H_O: int,
    W_O: int,
    relu: bool = False,
    pool: int = 1,
    emit_mask: bool = False,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Batched, strip-tiled stacked direct conv with fused epilogue.

    ``x_pad``: [B, H_in, W_in, D_I] spatially pre-padded input volumes with
      H_in >= (n_h*block_h - 1)*stride + F and W_in >= (W_O - 1)*stride + F
      where n_h = ceil(H_O / block_h).
    ``f``: [F, F, D_I, D_O]; ``bias``: [1, D_O] (zeros when unused).
    D_I, D_O must be multiples of the channel blocks; ``pool`` of 1 or 2
    (2 requires block_h and W_O even).
    Returns [B, n_h*block_h // pool, W_O // pool, D_O] — rows beyond H_O
    (strip padding) are garbage and must be sliced off by the caller.

    With ``emit_mask=True`` (requires ``relu=True``) the flush additionally
    stores the int8 epilogue-VJP mask — pool-argmax position or pool^2 for
    a dead window (ReLU liveness bit when pool == 1) — and the call returns
    ``(out, mask)`` with the mask the same [B, rows, cols, D_O] extent as
    ``out``.  A few bits per output pixel, saved as a residual, replace the
    backward pass's full recompute conv.
    """
    B, H_in, W_in, d_in = x_pad.shape
    F, F2, d_in2, d_out = f.shape
    assert F == F2 and d_in == d_in2
    assert d_in % block_di == 0 and d_out % block_do == 0
    if pool > 1:
        assert block_h % pool == 0 and W_O % pool == 0, (
            f"fused {pool}x{pool} pool needs block_h ({block_h}) and "
            f"W_O ({W_O}) divisible by it"
        )
    n_h = -(-H_O // block_h)
    assert H_in >= (n_h * block_h - 1) * stride + F
    assert W_in >= (W_O - 1) * stride + F
    if emit_mask:
        assert relu, "the epilogue-VJP mask encodes ReLU liveness"
    out_dtype = out_dtype or x_pad.dtype
    n_di = d_in // block_di
    h_halo = (block_h - 1) * stride + F  # input rows per halo'd strip

    kernel = functools.partial(
        _conv_kernel,
        n_di=n_di, F=F, S=stride, block_h=block_h, W_O=W_O,
        relu=relu, pool=pool, emit_mask=emit_mask,
    )
    out_spec = pl.BlockSpec(
        (1, block_h // pool, W_O // pool, block_do),
        lambda b, h, do, di: (b, h, 0, do),
    )
    out_struct = jax.ShapeDtypeStruct(
        (B, n_h * block_h // pool, W_O // pool, d_out), out_dtype
    )
    if emit_mask:  # second output: the int8 mask, same extent as out
        out_specs = [out_spec, pl.BlockSpec(
            (1, block_h // pool, W_O // pool, block_do),
            lambda b, h, do, di: (b, h, 0, do),
        )]
        out_shape = [out_struct, jax.ShapeDtypeStruct(out_struct.shape,
                                                      jnp.int8)]
    else:
        out_specs, out_shape = out_spec, out_struct
    return pl.pallas_call(
        kernel,
        grid=(B, n_h, d_out // block_do, n_di),
        in_specs=[
            # Halo-overlapped input strip block: element-granular (unblocked)
            # index map; streamed over d_i; ignores the stack index do, so
            # the strip's input rows are re-streamed once per output stack —
            # exactly the traffic Eq. (7) charges, per strip.
            pl.BlockSpec(
                (1, h_halo, W_in, block_di),
                lambda b, h, do, di: (b, h * block_h * stride, 0, di * block_di),
                indexing_mode=pl.unblocked,
            ),
            # Filter parameters for the (d_i, d_o-stack) pair.
            pl.BlockSpec((F, F, block_di, block_do), lambda b, h, do, di: (0, 0, di, do)),
            # Bias slice for the d_o stack (fused into the flush).
            pl.BlockSpec((1, block_do), lambda b, h, do, di: (0, do)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((block_h * W_O, block_do), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x_pad, f, bias)


def conv2d_pallas(
    x_pad: jax.Array,
    f: jax.Array,
    *,
    block_do: int,
    block_di: int,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Back-compat single-image entry point (stride 1, no epilogue).

    ``x_pad``: [H + 2P, W + 2P, D_I]; ``f``: [F, F, D_I, D_O].
    Returns [H_O, W_O, D_O].  Kept for callers of the pre-strip API; new
    code should use :func:`conv2d_fused_pallas` (batched, strip-tiled).
    """
    Hp, Wp, d_in = x_pad.shape
    F = f.shape[0]
    H_O, W_O = Hp - F + 1, Wp - F + 1
    bias = jnp.zeros((1, f.shape[3]), jnp.float32)
    out = conv2d_fused_pallas(
        x_pad[None], f, bias,
        stride=1, block_h=H_O, block_do=block_do, block_di=block_di,
        H_O=H_O, W_O=W_O, relu=False, pool=1,
        out_dtype=out_dtype, interpret=interpret,
    )
    return out[0, :H_O]
