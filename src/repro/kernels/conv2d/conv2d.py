"""Pallas TPU kernel for the paper's convolutional layers (Algs 1/2).

Faithful mapping (DESIGN.md Sec. 2):

* grid = (output-channel stacks, input-channel steps) — one grid step is
  one iteration of the paper's ``for d_i`` loop for one stack of Delta_O
  output depth slices (``block_do``).  ``block_do = 1`` *is* Algorithm 1;
  ``block_do = Delta_O > 1`` *is* Algorithm 2.  The input block's index map
  ignores the stack index, so the input volume is re-streamed once per
  stack — exactly the traffic Eq. (7) charges.
* the output stack lives in an f32 VMEM accumulator across all d_i steps
  (the cluster's L1-resident ``O[:, :, D_begin:D_end]``), initialized at
  d_i = 0 and flushed to HBM once at d_i = D_I-1 (the paper's final
  ``DmaStore``).
* HBM->VMEM block streaming is double-buffered by the Pallas pipeline —
  the DmaLoad/DmaWait prefetch structure of the pseudocode.

The conv itself is computed as F*F shifted MXU matmuls:
  acc[HW, bdo] += X_pad[ky:ky+H_O, kx:kx+W_O, :].reshape(HW, bdi)
                  @ F[ky, kx]  (bdi, bdo)
which keeps every MAC on the MXU (no im2col materialization in HBM).
Stride 1 in-kernel (the paper's running case); strided convs lower via the
reference path in ops.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _conv_kernel(x_ref, f_ref, o_ref, acc_ref, *, n_di: int, F: int, H_O: int, W_O: int):
    d_i = pl.program_id(1)

    @pl.when(d_i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)  # initialize O stack to zero

    x = x_ref[...]  # [H_O+F-1, W_O+F-1, bdi] padded input slice block
    bdi = x.shape[-1]
    # Conv() as F^2 shifted matmuls on the MXU.
    for ky in range(F):
        for kx in range(F):
            win = jax.lax.slice(
                x, (ky, kx, 0), (ky + H_O, kx + W_O, bdi)
            ).reshape(H_O * W_O, bdi)
            acc_ref[...] += jnp.dot(
                win, f_ref[ky, kx], preferred_element_type=jnp.float32
            )

    @pl.when(d_i == n_di - 1)
    def _flush():  # DmaStore(O[:, :, D_begin:D_end])
        o_ref[...] = acc_ref[...].reshape(H_O, W_O, -1).astype(o_ref.dtype)


def conv2d_pallas(
    x_pad: jax.Array,
    f: jax.Array,
    *,
    block_do: int,
    block_di: int,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Stacked direct conv, stride 1.

    ``x_pad``: [H + 2P, W + 2P, D_I] spatially pre-padded input volume.
    ``f``: [F, F, D_I, D_O].  D_I, D_O must be multiples of the blocks.
    Returns [H_O, W_O, D_O].
    """
    Hp, Wp, d_in = x_pad.shape
    F, F2, d_in2, d_out = f.shape
    assert F == F2 and d_in == d_in2
    assert d_in % block_di == 0 and d_out % block_do == 0
    H_O, W_O = Hp - F + 1, Wp - F + 1
    out_dtype = out_dtype or x_pad.dtype
    n_di = d_in // block_di

    return pl.pallas_call(
        functools.partial(_conv_kernel, n_di=n_di, F=F, H_O=H_O, W_O=W_O),
        grid=(d_out // block_do, n_di),
        in_specs=[
            # Input depth-slice block: whole spatial extent, streamed over
            # d_i; index map ignores the stack index (re-streamed per stack).
            pl.BlockSpec((Hp, Wp, block_di), lambda do, di: (0, 0, di)),
            # Filter parameters for the (d_i, d_o-stack) pair.
            pl.BlockSpec((F, F, block_di, block_do), lambda do, di: (0, 0, di, do)),
        ],
        out_specs=pl.BlockSpec((H_O, W_O, block_do), lambda do, di: (0, 0, do)),
        out_shape=jax.ShapeDtypeStruct((H_O, W_O, d_out), out_dtype),
        scratch_shapes=[pltpu.VMEM((H_O * W_O, block_do), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x_pad, f)
