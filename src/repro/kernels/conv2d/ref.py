"""Pure-jnp oracle for the stacked conv2d kernel."""

import jax.numpy as jnp
from jax import lax


def conv2d_ref(x, f, *, stride: int = 1, padding: int = 0, out_dtype=None):
    """Direct 2D convolution (cross-correlation, CNN convention).

    ``x``: [H, W, D_I] or [B, H, W, D_I] input volume(s).
    ``f``: [F, F, D_I, D_O] filter parameters.
    Returns [H_O, W_O, D_O] (or batched), H_O = (H + 2P - F)//S + 1.
    """
    out_dtype = out_dtype or x.dtype
    squeeze = x.ndim == 3
    if squeeze:
        x = x[None]
    out = lax.conv_general_dilated(
        x.astype(jnp.float32),
        f.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ).astype(out_dtype)
    return out[0] if squeeze else out
