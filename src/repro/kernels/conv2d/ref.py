"""Pure-jnp oracle for the stacked conv2d kernel."""

import jax.numpy as jnp
from jax import lax


def conv2d_ref(x, f, *, stride: int = 1, padding: int = 0, out_dtype=None):
    """Direct 2D convolution (cross-correlation, CNN convention).

    ``x``: [H, W, D_I] or [B, H, W, D_I] input volume(s).
    ``f``: [F, F, D_I, D_O] filter parameters.
    Returns [H_O, W_O, D_O] (or batched), H_O = (H + 2P - F)//S + 1.
    """
    out_dtype = out_dtype or x.dtype
    squeeze = x.ndim == 3
    if squeeze:
        x = x[None]
    out = lax.conv_general_dilated(
        x.astype(jnp.float32),
        f.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ).astype(out_dtype)
    return out[0] if squeeze else out


def maxpool_ref(x, pool: int = 2):
    """Non-overlapping ``pool x pool`` max-pool (floor semantics) over the
    spatial dims of [..., H, W, C]."""
    *lead, H, W, C = x.shape
    Hc, Wc = H - H % pool, W - W % pool
    x = x[..., :Hc, :Wc, :]
    return x.reshape(*lead, Hc // pool, pool, Wc // pool, pool, C).max((-4, -2))


def conv2d_fused_ref(
    x, f, bias=None, *, stride: int = 1, padding: int = 0,
    relu: bool = False, pool: int = 1, out_dtype=None,
):
    """Oracle for the fused conv + bias + ReLU + max-pool epilogue path."""
    out_dtype = out_dtype or x.dtype
    y = conv2d_ref(x, f, stride=stride, padding=padding, out_dtype=jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if relu:
        y = jnp.maximum(y, 0.0)
    if pool > 1:
        y = maxpool_ref(y, pool)
    return y.astype(out_dtype)
