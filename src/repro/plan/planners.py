"""Per-op planners: the paper's capacity argument, written once.

Every planner implements the same contract (:class:`Planner`): given layer
shapes and a :class:`~repro.core.machine.MachineModel`, emit the
:class:`~repro.plan.schedule.Schedule` whose working set fits the machine's
local memory (after the DMA-stream reservation, paper Sec. 2.2.2) and whose
modeled main-memory words are smallest.  The same code path therefore
yields the paper's Manticore quotes — ConvPlanner on MANTICORE at the
full-plane strip picks Delta_O = alg2_max_stack (24 sp / 12 dp on the
running example), MatmulPlanner picks block_n = alg45_max_stack (768/384)
— and the Pallas BlockSpec blocks on TPU_V5E.

Traffic models are kernel-faithful: the conv model is exactly
``ccr.alg2_strip_traffic`` generalized to rectangular planes, pooling and
batch (filters re-stream once per strip — the filter BlockSpec's index
changes whenever the strip index does — and zero-padding rows are free);
the matmul model degenerates to Alg 5's Eqs. (12-13) when block_m covers
the batch.  Explicit ``block_*`` overrides are honored verbatim (clamped
to legal ranges) so a schedule can also *describe* a hand-picked blocking.
"""

from __future__ import annotations

import dataclasses
from typing import ClassVar, Protocol, runtime_checkable

from repro.core import ccr
from repro.core.machine import TPU_V5E, MachineModel
from repro.plan.schedule import Schedule
from repro.plan.sharded import MeshSpec, ShardCandidate, ShardedSchedule


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _align_down(x: int, m: int) -> int:
    return x // m * m


def _strip_ladder(H_O: int, floor: int) -> list[int]:
    """Strip-height candidates: H_O and its power-of-two fractions, rounded
    up to ``floor`` granularity, tallest first — the same ladder every
    conv-family plan_local searches."""
    cands, k = [], 1
    while True:
        hb = round_up(-(-H_O // k), floor)
        if not cands or hb < cands[-1]:
            cands.append(hb)
        if hb <= floor:
            break
        k *= 2
    return cands


@runtime_checkable
class Planner(Protocol):
    """The planner contract: shapes in, one best Schedule out (a
    ShardedSchedule when the planner was constructed with a mesh)."""

    op: ClassVar[str]
    machine: MachineModel

    def plan(self, **shape) -> Schedule:  # pragma: no cover - protocol
        ...


@dataclasses.dataclass(frozen=True)
class ShardablePlanner:
    """Shared planner base: a machine, an optional mesh, and the sharded
    argmin.

    With ``mesh=None`` (the default) ``plan`` is the single-device
    capacity argument unchanged.  With a mesh, ``plan`` returns a
    :class:`~repro.plan.sharded.ShardedSchedule`: the op's partition
    candidates (:meth:`_shard_candidates`, e.g. batch/stack for conv,
    psum/ring for matmul) are each planned locally on their per-device
    shapes, their mesh-total words split into HBM and interconnect, and
    the candidate with the fewest total modeled words wins — the paper's
    capacity argument, extended with a mesh axis.  ``strategy=`` pins one
    candidate the way ``block_*`` pins pin a block.  A single-device mesh
    degenerates to today's Schedule inside a trivial wrapper.
    """

    machine: MachineModel = TPU_V5E
    mesh: MeshSpec | None = None
    shard_axis: str = "model"
    strategy: str | None = None

    def plan(self, **shape):
        if self.mesh is None:
            return self.plan_local(**shape)
        return self.plan_sharded(**shape)

    def plan_local(self, **shape) -> Schedule:  # pragma: no cover - abstract
        raise NotImplementedError

    # -- candidate enumeration (the argmin's search space, exposed) -------

    def local_candidates(self, **shape) -> list[Schedule]:
        """The single-device Schedules the argmin chooses between, one per
        point of the op's tunable ladder (each completed to its best
        remaining blocking).  The base planner has a one-point space; ops
        with a real search override this.  Used by ``candidates()`` and
        the measured-time autotuner (repro.plan.autotune)."""
        return [self.plan_local(**shape)]

    def _ladder_candidates(self, name: str, floor: int, **shape) -> list[Schedule]:
        """Halving ladder over one block kwarg: the argmin's pick, then
        ``floor``-aligned halvings down to ``floor`` — each re-planned so
        the remaining blocks adapt.  An explicit pin collapses the ladder."""
        base = self.plan_local(**shape)
        if shape.get(name) is not None:
            return [base]
        out, seen = [], set()
        v = base.block(name)
        while True:
            s = self.plan_local(**{**shape, name: v})
            if s.blocks not in seen and s.fits(self.machine):
                out.append(s)
                seen.add(s.blocks)
            if v <= floor:
                break
            v = max(floor, _align_down(v // 2, floor) or floor)
        return out or [base]

    def candidates(self, **shape) -> list:
        """Every (Sharded)Schedule the planner's argmin considers, sorted
        by modeled words (the plan() winner first).  Meshless planners
        enumerate the local blocking ladder; mesh-bound planners enumerate
        one locally-argmin'd ShardedSchedule per partition strategy
        (psum vs ring vs batch/stack...), honoring a ``strategy=`` pin —
        the search space the measured-time autotuner benchmarks."""
        if self.mesh is None:
            cands = self.local_candidates(**shape)
        elif self.shard_group == 1:
            cands = [self.plan_sharded(**shape)]
        else:
            pin = self.strategy
            strategies = []
            for c in self._shard_candidates(self.shard_group, **shape):
                if c.strategy not in strategies and (pin is None
                                                     or c.strategy == pin):
                    strategies.append(c.strategy)
            if not strategies:
                # An unsatisfiable pin: raise the argmin's informative
                # error rather than returning an empty enumeration.
                self.plan_sharded(**shape)
                raise AssertionError("plan_sharded must raise here")
            cands = [dataclasses.replace(self, strategy=st).plan_sharded(**shape)
                     for st in strategies]
        def _rank(s):
            loc = s if isinstance(s, Schedule) else s.schedule
            return (s.modeled_words, loc.critical_path_steps)

        out, seen = [], set()
        for s in sorted(cands, key=_rank):
            loc = s if isinstance(s, Schedule) else s.schedule
            key = (getattr(s, "strategy", None),
                   getattr(loc, "algorithm", None), loc.grid, loc.blocks)
            if key not in seen:
                seen.add(key)
                out.append(s)
        return out

    @property
    def shard_group(self) -> int:
        """Extent of the partitioned mesh axis (1 when the mesh lacks it —
        the degenerate replicated case)."""
        if self.mesh is None or self.shard_axis not in self.mesh.axis_names:
            return 1
        return self.mesh.axis_size(self.shard_axis)

    def _shard_candidates(self, group: int, **shape) -> list[ShardCandidate]:
        """Partitionings this op can run; overridden per planner.  The
        base offers only full replication, so any op degenerates safely."""
        del group, shape
        return [ShardCandidate(strategy="single", local_shape={}, partition=())]

    def plan_sharded(self, **shape) -> ShardedSchedule:
        assert self.mesh is not None, "plan_sharded needs a mesh-bound planner"
        group = self.shard_group
        local_planner = dataclasses.replace(self, mesh=None, strategy=None)
        # A 1-wide shard group has nothing to partition: every strategy
        # degenerates to "single", so a pin is satisfied vacuously (the
        # promised single-device degeneracy of sharded call sites).
        pin = self.strategy if group > 1 else None
        best = None
        for cand in self._shard_candidates(group, **shape):
            if pin is not None and cand.strategy != pin:
                continue
            local = local_planner.plan(**{**shape, **cand.local_shape})
            if cand.hbm_override is not None:
                loads, stores = cand.hbm_override
            else:
                loads, stores = group * local.loads, group * local.stores
            macs = (cand.macs_override if cand.macs_override is not None
                    else group * local.macs)
            ss = ShardedSchedule(
                schedule=local, mesh=self.mesh, axis=self.shard_axis,
                strategy=cand.strategy, partition=cand.partition,
                hbm_loads=loads, hbm_stores=stores,
                ici_words=cand.ici_words, macs=macs,
            )
            if best is None or ss.modeled_words < best.modeled_words:
                best = ss
        if best is None:
            raise ValueError(
                f"no {self.strategy!r} partitioning of {self.op!r} over mesh "
                f"axis {self.shard_axis!r} (group={group}) fits shapes {shape}")
        return best


# ---------------------------------------------------------------------------
# Conv (Algs 1/2 + strip tiling)
# ---------------------------------------------------------------------------


def conv_strip_words(
    *, H_O: int, W_O: int, H_I: int, W_I: int, F: int, S: int, P: int,
    d_in: int, d_out: int, block_h: int, block_do: int,
    pool: int = 1, batch: int = 1,
) -> tuple[int, int]:
    """(loads, stores) of the strip-tiled stacked schedule — the
    rectangular/pooled/batched generalization of ccr.alg2_strip_traffic.

    Each of the ceil(H_O/block_h) strips re-streams its halo'd input rows
    once per output stack (zero-padding rows cost nothing) and its filter
    slabs once per (strip, d_i, d_o); pooled outputs store once.  On a
    square plane with pool=1 and batch=1 this equals
    ``ccr.alg2_strip_traffic(shape, block_do, block_h).main_loads/stores``
    exactly.
    """
    n_stacks = -(-d_out // block_do)
    n_strips = -(-H_O // block_h)
    h_in = (block_h - 1) * S + F
    rows = 0
    for h0 in range(0, H_O, block_h):
        lo = h0 * S - P
        rows += max(0, min(lo + h_in, H_I) - max(lo, 0))
    loads = n_stacks * d_in * rows * W_I + n_strips * d_out * d_in * F * F
    stores = (H_O // pool) * (W_O // pool) * d_out
    return batch * loads, batch * stores


@dataclasses.dataclass(frozen=True)
class ConvPlanner(ShardablePlanner):
    """The two-level conv argmin: ``algorithm x blocking``.

    Two rival algorithm families compete on modeled words:

    * **direct** — the strip-tiled stacked kernel.  Candidate strips are
      H_O and its power-of-two fractions (rounded up to the pool
      granularity); for each, the largest lane-aligned output stack whose
      working set fits is considered — the paper's Delta_O argument,
      two-dimensional.
    * **im2col** — the patch-matrix GEMM (kernels/conv2d/im2col.py).  Its
      blocking is *delegated* to :class:`MatmulPlanner` on the per-strip
      GEMM ``[batch*block_h*W_O, F*F*d_in] @ [F*F*d_in, d_out]`` — the
      compound-planner pattern — and its traffic is
      ``ccr.conv_im2col_traffic`` (the F*F/S^2 patch read amplification,
      charged per strip).

    The fitting schedule with the fewest modeled words wins, ties toward
    direct.  ``algorithm=`` pins one family the way ``block_*`` pins pin a
    blocking; a direct-family pin (``block_do``/``block_di``) or a
    GEMM-family pin (``block_m``/``block_n``/``block_k``) implies its
    family, so autotune-cached blocks replay into the algorithm that
    produced them.

    On a mesh the forward conv shards as pure data parallelism: "batch"
    (each device convolves batch/P images) or "stack" (each device owns
    D_O/P output slices), no interconnect words either way — both
    partitions apply to both algorithms (the local re-plan runs the same
    two-level argmin on the shard's shape).
    """

    op: ClassVar[str] = "conv2d"

    _BDO_CAP: ClassVar[int] = 2048
    _BDI_CAP: ClassVar[int] = 512

    def default_block_di(self, d_in: int) -> int:
        lane = self.machine.lane
        if lane == 1:
            return 1  # the paper's per-slice `for d_i` loop
        return min(round_up(d_in, lane), self._BDI_CAP)

    def _stream_bytes(self, hb: int, bdo: int, bdi: int, W_stream: int,
                      F: int, S: int, in_bytes: int) -> int:
        """Double-buffered input-strip + filter streams, when the machine
        holds streamed blocks in the budget (Pallas does; Manticore's ride
        the reserved DMA buffers)."""
        if not self.machine.charge_stream_blocks:
            return 0
        h_halo = (hb - 1) * S + F
        return (h_halo * W_stream * bdi + F * F * bdi * bdo) * in_bytes * 2

    def _vmem_bytes(self, hb: int, bdo: int, bdi: int, W_O: int, W_stream: int,
                    F: int, S: int, in_bytes: int) -> int:
        acc_word = max(4, in_bytes)  # f32 accumulator (dp on dp machines)
        return (self._stream_bytes(hb, bdo, bdi, W_stream, F, S, in_bytes)
                + hb * W_O * bdo * acc_word)

    def _max_stack(self, hb: int, bdi: int, W_O: int, W_stream: int,
                   F: int, S: int, in_bytes: int, d_out: int) -> int:
        """Largest lane-aligned block_do fitting the budget at strip hb
        (0 when not even one lane of output slices fits)."""
        m = self.machine
        lane = m.lane
        budget = m.usable_for_working_set(streams=2)
        acc_word = max(4, in_bytes)
        fixed = per_bdo_stream = 0
        if m.charge_stream_blocks:
            h_halo = (hb - 1) * S + F
            fixed = h_halo * W_stream * bdi * in_bytes * 2
            per_bdo_stream = F * F * bdi * in_bytes * 2
        per_bdo = per_bdo_stream + hb * W_O * acc_word
        bdo = _align_down((budget - fixed) // per_bdo, lane) if budget > fixed else 0
        return min(bdo, self._BDO_CAP, round_up(d_out, lane))

    def _shard_candidates(self, group: int, *, d_out: int, batch: int = 1,
                          **shape) -> list[ShardCandidate]:
        # "single" (replicated compute) is never cheaper than a partition
        # that applies — and with sharded inputs it would need an unmodeled
        # all-gather — so it is only the fallback when nothing divides.
        del shape
        ax = self.shard_axis
        rep4 = (None, None, None, None)
        cands = []
        if group > 1 and batch % group == 0:
            cands.append(ShardCandidate(
                "batch", {"batch": batch // group},
                ((ax, None, None, None), rep4, (None,),
                 (ax, None, None, None))))
        if group > 1 and d_out % group == 0:
            cands.append(ShardCandidate(
                "stack", {"d_out": d_out // group},
                (rep4, (None, None, None, ax), (ax,),
                 (None, None, None, ax))))
        return cands or [
            ShardCandidate("single", {}, (rep4, rep4, (None,), rep4))]

    def plan_local(
        self, *, H_O: int, W_O: int, F: int, S: int = 1, d_in: int, d_out: int,
        in_bytes: int = 2, block_di: int | None = None, pool: int = 1,
        batch: int = 1, padding: int | None = None,
        H_I: int | None = None, W_I: int | None = None,
        block_h: int | None = None, block_do: int | None = None,
        algorithm: str | None = None, block_m: int | None = None,
        block_n: int | None = None, block_k: int | None = None,
    ) -> Schedule:
        """The two-level argmin: each family's best blocking, then the
        fitting family with fewer modeled words (ties toward direct)."""
        if algorithm not in (None, "direct", "im2col"):
            raise ValueError(f"unknown conv algorithm {algorithm!r}; "
                             "expected 'direct' or 'im2col'")
        direct_pins = block_do is not None or block_di is not None
        gemm_pins = (block_m is not None or block_n is not None
                     or block_k is not None)
        if direct_pins and gemm_pins:
            raise ValueError(
                "block_do/block_di pin the direct kernel and "
                "block_m/block_n/block_k pin the im2col GEMM — they cannot "
                "be combined in one conv plan")
        if algorithm is None:  # a family-specific pin implies its family
            if direct_pins:
                algorithm = "direct"
            elif gemm_pins:
                algorithm = "im2col"
        if algorithm == "direct" and gemm_pins:
            raise ValueError("direct conv has no block_m/block_n/block_k")
        if algorithm == "im2col" and direct_pins:
            raise ValueError("im2col conv has no block_do/block_di")
        shape = dict(H_O=H_O, W_O=W_O, F=F, S=S, d_in=d_in, d_out=d_out,
                     in_bytes=in_bytes, pool=pool, batch=batch,
                     padding=padding, H_I=H_I, W_I=W_I, block_h=block_h)
        if algorithm == "im2col":
            return self._plan_im2col(**shape, block_m=block_m,
                                     block_n=block_n, block_k=block_k)
        direct = self._plan_direct(**shape, block_di=block_di,
                                   block_do=block_do)
        if algorithm == "direct":
            return direct
        im2col = self._plan_im2col(**shape, block_m=block_m,
                                   block_n=block_n, block_k=block_k)
        if im2col.fits(self.machine) and (
                im2col.modeled_words < direct.modeled_words
                or not direct.fits(self.machine)):
            return im2col
        return direct

    def _plan_direct(
        self, *, H_O: int, W_O: int, F: int, S: int = 1, d_in: int, d_out: int,
        in_bytes: int = 2, block_di: int | None = None, pool: int = 1,
        batch: int = 1, padding: int | None = None,
        H_I: int | None = None, W_I: int | None = None,
        block_h: int | None = None, block_do: int | None = None,
    ) -> Schedule:
        m = self.machine
        lane = m.lane
        # Real input extents for the traffic model; callers that only know
        # the output extent get the exact-cover derivation (no padding).
        P = 0 if padding is None else padding
        H_I = H_I if H_I is not None else (H_O - 1) * S + F - 2 * P
        W_I = W_I if W_I is not None else (W_O - 1) * S + F - 2 * P
        W_stream = (W_O - 1) * S + F  # streamed (padded) strip width
        bdi = block_di or self.default_block_di(d_in)

        def words(hb: int, bdo: int) -> int:
            loads, stores = conv_strip_words(
                H_O=H_O, W_O=W_O, H_I=H_I, W_I=W_I, F=F, S=S, P=P,
                d_in=d_in, d_out=d_out, block_h=hb, block_do=bdo,
                pool=pool, batch=batch,
            )
            return loads + stores

        def clamp_h(hb: int) -> int:
            return round_up(min(hb, round_up(H_O, pool)), pool)

        if block_h is not None and block_do is not None:
            hb, bdo = block_h, block_do
        else:
            # Candidate strips: H_O and its power-of-two fractions down to
            # the pool granularity, tallest first — or just the pinned
            # strip when block_h is given (e.g. full-plane Alg 2, where the
            # search at that strip *is* the paper's Delta_O rule).  The
            # floor matters: a plane much larger than the budget only fits
            # at single-digit strips, and stopping early would strand the
            # plan on a non-fitting fallback.
            if block_h is not None:
                cands = [clamp_h(block_h)]
            else:
                cands = _strip_ladder(H_O, pool)
            budget = m.usable_for_working_set(streams=2)
            best = None
            for hb in cands:
                if block_do is not None:
                    bdo = min(block_do, round_up(d_out, lane))
                    if self._vmem_bytes(hb, bdo, bdi, W_O, W_stream, F, S,
                                        in_bytes) > budget:
                        continue  # pinned stack doesn't fit at this strip
                else:
                    bdo = self._max_stack(hb, bdi, W_O, W_stream, F, S,
                                          in_bytes, d_out)
                    if bdo < max(lane, 1):
                        continue  # nothing fits at this strip height
                w = words(hb, bdo)
                if best is None or w < best[0]:
                    best = (w, hb, bdo)
            if best is None:  # nothing fits the model; smallest legal tile
                hb = block_h if block_h is not None else round_up(min(8, H_O), pool)
                bdo = block_do if block_do is not None else lane
            else:
                _, hb, bdo = best
        # Clamp to legal ranges (explicit overrides may exceed them).
        hb = clamp_h(hb)
        bdo = min(bdo, round_up(d_out, lane))

        loads, stores = conv_strip_words(
            H_O=H_O, W_O=W_O, H_I=H_I, W_I=W_I, F=F, S=S, P=P,
            d_in=d_in, d_out=d_out, block_h=hb, block_do=bdo,
            pool=pool, batch=batch,
        )
        n_h = -(-H_O // hb)
        grid = (batch, n_h, round_up(d_out, bdo) // bdo, round_up(d_in, bdi) // bdi)
        return Schedule(
            op=self.op,
            grid=grid,
            blocks=(("block_di", bdi), ("block_do", bdo), ("block_h", hb)),
            halo=max(0, F - S),
            macs=batch * H_O * W_O * F * F * d_in * d_out,
            loads=loads,
            stores=stores,
            vmem_bytes=self._vmem_bytes(hb, bdo, bdi, W_O, W_stream, F, S, in_bytes),
            machine=m.name,
            critical_path_steps=ccr.grid_steps(grid),
        )

    def _plan_im2col(
        self, *, H_O: int, W_O: int, F: int, S: int = 1, d_in: int,
        d_out: int, in_bytes: int = 2, pool: int = 1, batch: int = 1,
        padding: int | None = None, H_I: int | None = None,
        W_I: int | None = None, block_h: int | None = None,
        block_m: int | None = None, block_n: int | None = None,
        block_k: int | None = None,
    ) -> Schedule:
        """The im2col-GEMM family's best blocking: per candidate strip, the
        GEMM blocking is delegated to :class:`MatmulPlanner` on the strip's
        patch matmul — the compound-planner pattern.  The patch matrix
        charges every patch word (padding pixels included), so padding and
        the real input extents don't enter this family's traffic model."""
        del padding, H_I, W_I
        mm = MatmulPlanner(self.machine)
        k = F * F * d_in

        def build(hb: int) -> Schedule:
            hb = round_up(min(hb, round_up(H_O, pool)), pool)
            inner = mm.plan_local(
                m=batch * min(hb, H_O) * W_O, n=d_out, k=k,
                in_bytes=in_bytes, block_m=block_m, block_n=block_n,
                block_k=block_k)
            t = ccr.conv_im2col_traffic(
                H_O=H_O, W_O=W_O, F=F, S=S, d_in=d_in, d_out=d_out,
                block_h=hb, block_m=inner.block("block_m"),
                block_n=inner.block("block_n"),
                block_k=inner.block("block_k"), pool=pool, batch=batch)
            grid = (-(-H_O // hb),) + inner.grid
            return Schedule(
                op=self.op,
                grid=grid,
                blocks=tuple(sorted((("block_h", hb),) + inner.blocks)),
                halo=0,
                macs=t.macs,
                loads=t.main_loads,
                stores=t.main_stores,
                vmem_bytes=inner.vmem_bytes,
                machine=self.machine.name,
                algorithm="im2col",
                critical_path_steps=ccr.grid_steps(grid),
            )

        if block_h is not None:
            return build(block_h)
        best = None
        for hb in _strip_ladder(H_O, pool):
            s = build(hb)
            if not s.fits(self.machine):
                continue
            if best is None or s.modeled_words < best.modeled_words:
                best = s
        return best or build(_strip_ladder(H_O, pool)[-1])

    def local_candidates(self, **shape) -> list[Schedule]:
        """Both families' ladders: one candidate per (algorithm, strip
        height) of the two-level search, each completed to its family's
        best remaining blocking, fits-filtered — the crossover autotune
        measures for real.  An ``algorithm=`` pin (explicit or implied by
        a family-specific block pin) collapses to one family."""
        if shape.get("block_h") is not None:
            return [self.plan_local(**shape)]
        alg = shape.get("algorithm")
        if alg is None:
            if shape.get("block_do") is not None or shape.get("block_di") is not None:
                alg = "direct"
            elif any(shape.get(b) is not None
                     for b in ("block_m", "block_n", "block_k")):
                alg = "im2col"
        algs = ("direct", "im2col") if alg is None else (alg,)
        pool = shape.get("pool") or 1
        out, seen = [], set()
        for hb in _strip_ladder(shape["H_O"], pool):
            for a in algs:
                s = self.plan_local(**{**shape, "block_h": hb,
                                       "algorithm": a})
                key = (s.algorithm, s.blocks)
                if key not in seen and s.fits(self.machine):
                    out.append(s)
                    seen.add(key)
        return out or [self.plan_local(**shape)]


@dataclasses.dataclass(frozen=True)
class Im2colConvPlanner(ConvPlanner):
    """The im2col-GEMM conv as its own first-class op: the ConvPlanner
    with the algorithm pinned to "im2col", so ``conv2d_im2col`` plans,
    autotunes and shards like any other op while ``conv2d`` keeps the
    two-level argmin over both families."""

    op: ClassVar[str] = "conv2d_im2col"

    def plan_local(self, **shape) -> Schedule:
        return super().plan_local(**{**shape, "algorithm": "im2col"})

    def local_candidates(self, **shape) -> list[Schedule]:
        return super().local_candidates(**{**shape, "algorithm": "im2col"})


# ---------------------------------------------------------------------------
# Conv backward: dgrad (input gradient) and wgrad (filter gradient)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ConvDgradPlanner(ShardablePlanner):
    """Plans the conv backward-data (dgrad) kernel.

    dX is a stride-1 strip conv over the S-dilated gradient with spatially
    flipped, channel-swapped filters — exactly the forward kernel on a
    transposed geometry — so the planner delegates to :class:`ConvPlanner`
    on that geometry and relabels the schedule.  Kwargs are the *forward*
    layer's shapes: ``(H_O, W_O)`` is the gradient extent, ``d_in/d_out``
    the forward channel counts (dgrad streams d_out slices and stacks
    Delta_I = ``block_do`` output slices of dX, the same capacity rule that
    bounds the forward Delta_O).

    With a ``pool=`` factor (the forward layer saved its pool-argmax/ReLU
    mask as a residual) the default variant is **fused_epilogue**: a
    mask-scatter prologue rebuilds the full-rate dY from the pooled
    gradient (``ccr.epilogue_scatter_traffic`` — charged here, shared by
    wgrad through CSE), and the kernel streams d_out through a
    double-buffered DMA loop folded *inside* each grid step, so the grid
    drops its stream dimension and the critical path shortens to
    ``ccr.conv_dgrad_fused_steps``.  The scatter words it adds are bought
    back many times over at the layer level: the recompute path's full
    forward-conv re-run disappears.  ``algorithm="direct"`` pins the plain
    delegated schedule; both variants appear in ``candidates()`` so
    autotune measures the crossover.
    """

    op: ClassVar[str] = "conv2d_dgrad"

    def _shard_candidates(self, group: int, *, batch: int = 1,
                          **shape) -> list[ShardCandidate]:
        del shape
        ax = self.shard_axis
        rep4 = (None, None, None, None)
        cands = []
        if group > 1 and batch % group == 0:  # dX shards with the batch
            cands.append(ShardCandidate(
                "batch", {"batch": batch // group},
                ((ax, None, None, None), rep4, (ax, None, None, None))))
        return cands or [ShardCandidate("single", {}, (rep4, rep4, rep4))]

    def plan_local(
        self, *, H_O: int, W_O: int, F: int, S: int = 1, P: int = 0,
        d_in: int, d_out: int, in_bytes: int = 2, batch: int = 1,
        H_I: int | None = None, W_I: int | None = None,
        block_h: int | None = None, block_do: int | None = None,
        block_di: int | None = None, pool: int | None = None,
        algorithm: str | None = None,
    ) -> Schedule:
        if P > F - 1:
            raise ValueError(f"dgrad needs padding <= F-1, got P={P} for F={F}")
        if algorithm not in (None, "direct", "fused_epilogue"):
            raise ValueError(f"unknown dgrad algorithm {algorithm!r}; "
                             "expected 'direct' or 'fused_epilogue'")
        if algorithm == "fused_epilogue" and not pool:
            raise ValueError("fused_epilogue dgrad needs the forward pool "
                             "factor (pool=)")
        if algorithm is None:
            # The mask residual exists whenever the forward layer fused its
            # epilogue (pool given): default to consuming it — the scatter
            # words it adds are a fraction of the recompute pass it kills.
            algorithm = "fused_epilogue" if pool else "direct"
        H_dil, W_dil = (H_O - 1) * S + 1, (W_O - 1) * S + 1  # dilated grad
        pt = F - 1 - P  # transposed padding
        # dX extent: exact cover by default; a ragged-stride forward input
        # is larger — the kernel then computes (zero) rows past the cover.
        H_I = H_I if H_I is not None else H_dil + 2 * pt - F + 1
        W_I = W_I if W_I is not None else W_dil + 2 * pt - F + 1
        # The dgrad kernel is the *direct* strip kernel on the transposed
        # geometry — pin the family so the delegated two-level argmin can't
        # hand back im2col GEMM blocks the dgrad kernel doesn't speak.
        inner = ConvPlanner(self.machine).plan(
            H_O=H_I, W_O=W_I,
            F=F, S=1, d_in=d_out, d_out=d_in, in_bytes=in_bytes,
            batch=batch, padding=pt, H_I=H_dil, W_I=W_dil,
            block_h=block_h, block_do=block_do, block_di=block_di,
            algorithm="direct",
        )
        if algorithm == "direct":
            return dataclasses.replace(inner, op=self.op)
        # fused_epilogue: charge the mask-scatter prologue (it rebuilds the
        # full-rate dY both backward kernels then stream — charged once,
        # here) and fold the d_out stream inside each grid step: the DMA
        # double-buffer hides it, so the grid drops its last (stream)
        # dimension and the critical path is the fused closed form.
        sc = ccr.epilogue_scatter_traffic(
            H_O=H_O, W_O=W_O, d_out=d_out, pool=pool, batch=batch,
            in_bytes=in_bytes)
        return dataclasses.replace(
            inner, op=self.op, algorithm="fused_epilogue",
            grid=inner.grid[:3],
            loads=inner.loads + sc.main_loads,
            stores=inner.stores + sc.main_stores,
            critical_path_steps=ccr.conv_dgrad_fused_steps(
                H_I=H_I, d_in=d_in, block_h=inner.block("block_h"),
                block_do=inner.block("block_do"), batch=batch),
        )

    def local_candidates(self, **shape) -> list[Schedule]:
        """Strip ladder over the dX extent (the transposed geometry's
        output plane), each delegated through the forward search — and,
        when the forward saved a mask residual (``pool=``), both the
        fused_epilogue and direct variants per strip, so autotune measures
        the scatter-vs-stream crossover for real."""
        if shape.get("block_h") is not None:
            return [self.plan_local(**shape)]
        F, S, P = shape["F"], shape.get("S", 1), shape.get("P", 0)
        H_I = shape.get("H_I")
        if H_I is None:
            H_I = (shape["H_O"] - 1) * S + 1 + 2 * (F - 1 - P) - F + 1
        alg = shape.get("algorithm")
        if alg is not None:
            algs = (alg,)
        elif shape.get("pool"):
            algs = ("fused_epilogue", "direct")
        else:
            algs = ("direct",)
        out, seen = [], set()
        for hb in _strip_ladder(H_I, 1):
            for a in algs:
                s = self.plan_local(**{**shape, "block_h": hb,
                                       "algorithm": a})
                key = (s.algorithm, s.blocks)
                if key not in seen and s.fits(self.machine):
                    out.append(s)
                    seen.add(key)
        return out or [self.plan_local(**shape)]


def conv_wgrad_words(
    *, H_O: int, W_O: int, H_I: int, W_I: int, F: int, S: int, P: int,
    d_in: int, d_out: int, block_h: int, block_di: int, block_do: int,
    batch: int = 1,
) -> tuple[int, int]:
    """(loads, stores) of the wgrad accumulation schedule: the F^2 x
    Delta_I x Delta_O filter-gradient accumulator is the resident stack;
    each of the ceil(d_out/block_do) gradient stacks re-streams every
    halo'd input strip (zero-padding rows free) and each of the
    ceil(d_in/block_di) input blocks re-streams the whole gradient; dW
    stores exactly once (accumulated over batch and strips in VMEM)."""
    n_do = -(-d_out // block_do)
    n_di = -(-d_in // block_di)
    h_in = (block_h - 1) * S + F
    rows = 0
    for h0 in range(0, H_O, block_h):
        lo = h0 * S - P
        rows += max(0, min(lo + h_in, H_I) - max(lo, 0))
    loads = n_do * d_in * rows * W_I + n_di * d_out * H_O * W_O
    stores = F * F * d_in * d_out
    return batch * loads, stores


@dataclasses.dataclass(frozen=True)
class ConvWgradPlanner(ShardablePlanner):
    """Picks (block_h, block_do, block_di) for the wgrad accumulation
    kernel: dW[ky, kx] += X_strip^T @ dY_strip over the (batch, strip)
    grid.  The resident output stack is the F^2 * block_di * block_do f32
    accumulator; the input and gradient strips stream through.  The same
    two-dimensional search as the forward planner: strip candidates are
    H_O and its power-of-two fractions, the largest fitting lane-aligned
    gradient stack per strip, fewest modeled words wins.

    Two execution variants share that blocking and its words: **direct**
    walks the whole (d_i, d_o, batch, strip) grid sequentially, while
    **pipelined** folds the (batch, strip) accumulation sweep inside each
    (d_i, d_o) step behind double-buffered strip DMA — the MPNA
    dataflow-overlap argument applied to our strip schedule.  The words
    tie, so the argmin over ``modeled_words + critical_path_steps``
    (``ccr.conv_wgrad_steps``) picks pipelined whenever the folded sweep
    is longer than one step; ``algorithm=`` pins a variant and both appear
    in ``candidates()``.

    On a mesh, "batch" shards the *contraction* (each device accumulates a
    private dW over batch/P images), so the sharded plan charges the Alg-4
    tree reduction of the F^2 x D_I x D_O gradient as ici_words.
    """

    op: ClassVar[str] = "conv2d_wgrad"

    _BDO_CAP: ClassVar[int] = 2048
    _BDI_CAP: ClassVar[int] = 512

    def default_block_di(self, d_in: int) -> int:
        lane = self.machine.lane
        if lane == 1:
            return 1  # the paper's per-slice loop granularity
        return min(round_up(d_in, lane), self._BDI_CAP)

    def _vmem_bytes(self, hb: int, bdo: int, bdi: int, F: int, S: int,
                    W_O: int, W_stream: int, in_bytes: int) -> int:
        acc_word = max(4, in_bytes)
        stream = 0
        if self.machine.charge_stream_blocks:
            h_halo = (hb - 1) * S + F
            stream = (h_halo * W_stream * bdi + hb * W_O * bdo) * in_bytes * 2
        return F * F * bdi * bdo * acc_word + stream

    def _max_stack(self, hb: int, bdi: int, F: int, S: int, W_O: int,
                   W_stream: int, in_bytes: int, d_out: int) -> int:
        m = self.machine
        lane = m.lane
        budget = m.usable_for_working_set(streams=2)
        acc_word = max(4, in_bytes)
        fixed = 0
        per_bdo = F * F * bdi * acc_word
        if m.charge_stream_blocks:
            h_halo = (hb - 1) * S + F
            fixed = h_halo * W_stream * bdi * in_bytes * 2
            per_bdo += hb * W_O * in_bytes * 2
        bdo = _align_down((budget - fixed) // per_bdo, lane) if budget > fixed else 0
        return min(bdo, self._BDO_CAP, round_up(d_out, lane))

    def _shard_candidates(self, group: int, *, F: int, d_in: int, d_out: int,
                          batch: int = 1, **shape) -> list[ShardCandidate]:
        del shape
        ax = self.shard_axis
        rep4 = (None, None, None, None)
        cands = []
        if group > 1 and batch % group == 0:
            cands.append(ShardCandidate(
                "batch", {"batch": batch // group},
                ((ax, None, None, None), (ax, None, None, None), rep4),
                ici_words=ccr.tree_reduce_words(group, F * F * d_in * d_out)))
        return cands or [ShardCandidate("single", {}, (rep4, rep4, rep4))]

    def plan_local(
        self, *, H_O: int, W_O: int, F: int, S: int = 1, d_in: int,
        d_out: int, in_bytes: int = 2, batch: int = 1,
        padding: int | None = None, H_I: int | None = None,
        W_I: int | None = None, block_h: int | None = None,
        block_do: int | None = None, block_di: int | None = None,
        algorithm: str | None = None,
    ) -> Schedule:
        if algorithm not in (None, "direct", "pipelined"):
            raise ValueError(f"unknown wgrad algorithm {algorithm!r}; "
                             "expected 'direct' or 'pipelined'")
        m = self.machine
        lane = m.lane
        P = 0 if padding is None else padding
        H_I = H_I if H_I is not None else (H_O - 1) * S + F - 2 * P
        W_I = W_I if W_I is not None else (W_O - 1) * S + F - 2 * P
        W_stream = (W_O - 1) * S + F
        bdi = block_di or self.default_block_di(d_in)

        def words(hb: int, bdo: int) -> int:
            loads, stores = conv_wgrad_words(
                H_O=H_O, W_O=W_O, H_I=H_I, W_I=W_I, F=F, S=S, P=P,
                d_in=d_in, d_out=d_out, block_h=hb, block_di=bdi,
                block_do=bdo, batch=batch,
            )
            return loads + stores

        if block_h is not None and block_do is not None:
            hb, bdo = block_h, block_do
        else:
            cands = ([block_h] if block_h is not None
                     else _strip_ladder(H_O, 1))
            budget = m.usable_for_working_set(streams=2)
            best = None
            for hb in cands:
                if block_do is not None:
                    bdo = min(block_do, round_up(d_out, lane))
                    if self._vmem_bytes(hb, bdo, bdi, F, S, W_O, W_stream,
                                        in_bytes) > budget:
                        continue
                else:
                    bdo = self._max_stack(hb, bdi, F, S, W_O, W_stream,
                                          in_bytes, d_out)
                    if bdo < max(lane, 1):
                        continue
                w = words(hb, bdo)
                if best is None or w < best[0]:
                    best = (w, hb, bdo)
            if best is None:
                hb = block_h if block_h is not None else min(8, H_O)
                bdo = block_do if block_do is not None else lane
            else:
                _, hb, bdo = best
        hb = max(1, min(hb, H_O))
        bdo = min(bdo, round_up(d_out, lane))

        loads, stores = conv_wgrad_words(
            H_O=H_O, W_O=W_O, H_I=H_I, W_I=W_I, F=F, S=S, P=P,
            d_in=d_in, d_out=d_out, block_h=hb, block_di=bdi,
            block_do=bdo, batch=batch,
        )
        step_kw = dict(H_O=H_O, d_in=d_in, d_out=d_out, block_h=hb,
                       block_di=bdi, block_do=bdo, batch=batch)
        if algorithm is None:
            # words are identical for both variants, so the argmin over
            # (modeled_words + critical_path_steps) reduces to the step
            # term: pipelined wins whenever the folded (batch, strip)
            # sweep is longer than one step.
            pipelined = (ccr.conv_wgrad_steps(**step_kw, pipelined=True)
                         < ccr.conv_wgrad_steps(**step_kw, pipelined=False))
            algorithm = "pipelined" if pipelined else "direct"
        n_di = round_up(d_in, bdi) // bdi
        n_do = round_up(d_out, bdo) // bdo
        if algorithm == "pipelined":
            grid = (n_di, n_do)
        else:
            grid = (n_di, n_do, batch, -(-H_O // hb))
        return Schedule(
            op=self.op,
            grid=grid,
            blocks=(("block_di", bdi), ("block_do", bdo), ("block_h", hb)),
            halo=max(0, F - S),
            macs=batch * H_O * W_O * F * F * d_in * d_out,
            loads=loads,
            stores=stores,
            vmem_bytes=self._vmem_bytes(hb, bdo, bdi, F, S, W_O, W_stream,
                                        in_bytes),
            machine=m.name,
            algorithm=algorithm,
            critical_path_steps=ccr.conv_wgrad_steps(
                **step_kw, pipelined=(algorithm == "pipelined")),
        )

    def local_candidates(self, **shape) -> list[Schedule]:
        """One candidate per (gradient-strip height, variant): each strip
        with its best fitting gradient stack, in both the pipelined and
        direct execution variants — the wgrad argmin's search space."""
        if shape.get("block_h") is not None:
            return [self.plan_local(**shape)]
        alg = shape.get("algorithm")
        algs = ("pipelined", "direct") if alg is None else (alg,)
        out, seen = [], set()
        for hb in _strip_ladder(shape["H_O"], 1):
            for a in algs:
                s = self.plan_local(**{**shape, "block_h": hb,
                                       "algorithm": a})
                key = (s.algorithm, s.blocks)
                if key not in seen and s.fits(self.machine):
                    out.append(s)
                    seen.add(key)
        return out or [self.plan_local(**shape)]


# ---------------------------------------------------------------------------
# Matmul (Algs 4/5)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MatmulPlanner(ShardablePlanner):
    """Picks (block_m, block_n, block_k) for the FC matmul kernel.

    block_m/block_k sit at MXU-friendly sizes; block_n — the Delta_O
    output stack — grows until the working set (x block + w block streams,
    f32 accumulator) exhausts the budget: the Alg 5 strategy verbatim.  On
    MANTICORE (streams uncharged, lane 1) the same rule is exactly
    ``ccr.alg45_max_stack``: block_n <= 768 (sp) / 384 (dp) at batch 32.

    On a mesh four multi-device dataflows compete: "batch" (data
    parallelism over the rows — zero ici, but every device re-streams the
    full weight), "psum" (Alg 4 — K sharded, private partial outputs
    tree-reduced; ``ccr.fc_psum_traffic``), "ring" (Alg 3 — K-sharded X
    permuted around the ring while each device keeps its full-K weight
    columns; ``ccr.ring_traffic``, every X word loaded from main memory
    exactly once) and "tp" (megatron-style tensor parallelism — W column
    (N) sharded with X replicated, the private activation shards
    all-gathered as ici words; ``ccr.tp_matmul_traffic``).  The tp-vs-
    batch trade is weight words against activation words: at small m the
    full-weight re-stream dominates and tp wins, at large m batch's zero
    ici wins.  Fewest total modeled words (HBM + ICI) wins;
    ``strategy=`` pins one.
    """

    op: ClassVar[str] = "matmul"

    _BN_CAP: ClassVar[int] = 2048
    _BMK_CAP: ClassVar[int] = 512

    def _vmem_bytes(self, bm: int, bn: int, bk: int, in_bytes: int) -> int:
        acc_word = max(4, in_bytes)
        stream = (bm * bk + bk * bn) * in_bytes * 2 if self.machine.charge_stream_blocks else 0
        return stream + bm * bn * acc_word

    def _shard_candidates(self, group: int, *, m: int, n: int, k: int,
                          **shape) -> list[ShardCandidate]:
        del shape
        ax = self.shard_axis
        rep2 = (None, None)
        cands = []
        if group > 1 and m % group == 0:  # data parallelism over the rows
            cands.append(ShardCandidate(
                "batch", {"m": m // group},
                ((ax, None), rep2, (ax, None))))
        if group > 1 and k % group == 0:
            cands.append(ShardCandidate(
                "psum", {"k": k // group},
                ((None, ax), (ax, None), rep2),
                ici_words=ccr.tree_reduce_words(group, m * n)))
        if group > 1 and k % group == 0 and n % group == 0:
            ring = ccr.ring_traffic(m=m, n=n, k=k, devices=group)
            cands.append(ShardCandidate(
                "ring", {"n": n // group},
                ((None, ax), (None, ax), (None, ax)),
                ici_words=ring.intercluster,
                hbm_override=(ring.main_loads, ring.main_stores),
                macs_override=ring.macs))
        if group > 1 and n % group == 0:  # megatron column split
            cands.append(ShardCandidate(
                "tp", {"n": n // group},
                ((None, None), (None, ax), (None, ax)),
                ici_words=ccr.tree_reduce_words(group, m * n)))
        return cands or [ShardCandidate("single", {}, (rep2, rep2, rep2))]

    def plan_local(
        self, *, m: int, n: int, k: int, in_bytes: int = 2,
        block_m: int | None = None, block_n: int | None = None,
        block_k: int | None = None,
    ) -> Schedule:
        mm = self.machine
        lane = mm.lane
        budget = mm.usable_for_working_set(streams=2)
        bm = block_m or min(round_up(m, lane), self._BMK_CAP)
        bk = block_k or min(round_up(k, lane), self._BMK_CAP)
        if block_n is not None:
            bn = block_n
        else:
            acc_word = max(4, in_bytes)
            fixed = per_bn = 0
            if mm.charge_stream_blocks:
                fixed = bm * bk * in_bytes * 2
                per_bn = bk * in_bytes * 2
            per_bn += bm * acc_word
            bn = _align_down(max(0, budget - fixed) // per_bn, lane)
            bn = max(lane, min(bn, self._BN_CAP, round_up(n, lane)))

        mp, np_, kp = round_up(m, bm), round_up(n, bn), round_up(k, bk)
        # Alg 5 device analogue: x re-streams once per output stack
        # (n-block), w once per m-block, outputs store once — with a single
        # m-block this is Eqs. (12)-(13) on the padded problem.
        loads = (np_ // bn) * mp * kp + (mp // bm) * kp * np_
        stores = mp * np_
        grid = (mp // bm, np_ // bn, kp // bk)
        return Schedule(
            op=self.op,
            grid=grid,
            blocks=(("block_k", bk), ("block_m", bm), ("block_n", bn)),
            halo=0,
            macs=mp * np_ * kp,
            loads=loads,
            stores=stores,
            vmem_bytes=self._vmem_bytes(bm, bn, bk, in_bytes),
            machine=mm.name,
            critical_path_steps=ccr.grid_steps(grid),
        )

    def local_candidates(self, **shape) -> list[Schedule]:
        """Halving ladder over block_n — the Delta_O output stack the
        capacity argument maximizes (the budget max, then halves)."""
        return self._ladder_candidates("block_n", self.machine.lane, **shape)


# ---------------------------------------------------------------------------
# Matmul backward: dX = G @ W^T and dW = X^T @ G
# ---------------------------------------------------------------------------


def _relabel_matmul(inner: Schedule, op: str, names: dict[str, str]) -> Schedule:
    """Rename an inner MatmulPlanner schedule's blocks into the backward
    kernel's own (forward-role) names; grid and model fields carry over."""
    blocks = tuple(sorted((names[k], v) for k, v in inner.blocks))
    return dataclasses.replace(inner, op=op, blocks=blocks)


@dataclasses.dataclass(frozen=True)
class MatmulDxPlanner(ShardablePlanner):
    """Plans dX = dY @ W^T for the FC layer.

    A matmul whose resident output stack is the K (input-feature) dimension
    while N streams through as the contraction — the Alg 5 capacity rule
    with the roles transposed — so the planner delegates to
    :class:`MatmulPlanner` on ``(m, k, n)`` and relabels the blocks back
    into forward names: ``block_k`` is the output stack (the Delta_O
    analogue, 768/384 on MANTICORE at batch 32), ``block_n`` the streamed
    contraction step.  Kwargs are the *forward* shapes (x: [m, k],
    w: [k, n], dY: [m, n]).  On a mesh, dX shards with the batch (no
    collective — each device back-propagates its own rows).

    ``algorithm="fused_dxdw"`` models the fused dX/dW kernel instead: one
    grid (k-blocks, n-blocks, m-blocks) reads each dY tile once and feeds
    both contractions, saving dW's entire dY stream but paying a whole-M
    dX accumulator strip in VMEM.  The schedule carries the *combined*
    cost of both gradients, so it is never the per-op argmin — the FC
    layer opts in by pinning the algorithm in plan_bwd, and
    ``local_candidates`` exposes both variants to the autotuner.
    """

    op: ClassVar[str] = "matmul_dx"

    def _fuse_dxdw(self, sched: Schedule, *, m: int, n: int, k: int,
                   in_bytes: int) -> Schedule:
        """Re-model a direct dX schedule as the fused dX/dW kernel.

        Grid (k-blocks, n-blocks, m-blocks), m innermost; the dY tile is
        charged once per step (n_k * M * N — the stream dW no longer pays
        separately), W re-streams per m-block, X per n-block; both
        gradients store once.  VMEM holds the whole-M f32 dX strip for the
        current k-block plus the dW tile — the fusion's capacity price.
        """
        blocks = dict(sched.blocks)
        bm, bk, bn = blocks["block_m"], blocks["block_k"], blocks["block_n"]
        mp, kp, np_ = round_up(m, bm), round_up(k, bk), round_up(n, bn)
        n_k, n_n, n_m = kp // bk, np_ // bn, mp // bm
        grid = (n_k, n_n, n_m)
        stream = 0
        if self.machine.charge_stream_blocks:
            stream = (bm * bn + bk * bn + bm * bk) * in_bytes * 2
        return dataclasses.replace(
            sched,
            algorithm="fused_dxdw",
            grid=grid,
            macs=2 * mp * np_ * kp,
            loads=n_k * mp * np_ + n_m * kp * np_ + n_n * mp * kp,
            stores=mp * kp + kp * np_,
            vmem_bytes=stream + (mp * bk + bk * bn) * 4,
            critical_path_steps=ccr.grid_steps(grid),
        )

    def _shard_candidates(self, group: int, *, m: int,
                          **shape) -> list[ShardCandidate]:
        del shape
        ax = self.shard_axis
        rep2 = (None, None)
        cands = []
        if group > 1 and m % group == 0:
            cands.append(ShardCandidate(
                "batch", {"m": m // group},
                ((ax, None), rep2, (ax, None))))
        return cands or [ShardCandidate("single", {}, (rep2, rep2, rep2))]

    def plan_local(
        self, *, m: int, n: int, k: int, in_bytes: int = 2,
        block_m: int | None = None, block_n: int | None = None,
        block_k: int | None = None, algorithm: str | None = None,
    ) -> Schedule:
        if algorithm not in (None, "direct", "fused_dxdw"):
            raise ValueError(
                f"matmul_dx algorithm must be 'direct' or 'fused_dxdw', "
                f"got {algorithm!r}")
        inner = MatmulPlanner(self.machine).plan(
            m=m, n=k, k=n, in_bytes=in_bytes,
            block_m=block_m, block_n=block_k, block_k=block_n,
        )
        sched = _relabel_matmul(inner, self.op, {
            "block_m": "block_m", "block_n": "block_k", "block_k": "block_n",
        })
        if (algorithm or "direct") == "direct":
            return sched
        return self._fuse_dxdw(sched, m=m, n=n, k=k, in_bytes=in_bytes)

    def local_candidates(self, **shape) -> list[Schedule]:
        """Halving ladder over block_k — dX's resident output stack (the
        forward role of the transposed Delta_O) — for the direct kernel
        and the fused dX/dW variant (a pinned ``algorithm`` collapses to
        that variant's ladder)."""
        pin = shape.pop("algorithm", None)
        algs = ("direct", "fused_dxdw") if pin is None else (pin,)
        out, seen = [], set()
        for alg in algs:
            for s in self._ladder_candidates(
                    "block_k", self.machine.lane, algorithm=alg, **shape):
                key = (s.algorithm, s.blocks)
                if key not in seen:
                    seen.add(key)
                    out.append(s)
        return out


@dataclasses.dataclass(frozen=True)
class MatmulDwPlanner(ShardablePlanner):
    """Plans dW = X^T @ dY for the FC layer: output [k, n] tiles resident
    while the M (batch) dimension streams as the contraction.  Delegates to
    :class:`MatmulPlanner` on ``(k, n, m)``; ``block_m`` is the streamed
    contraction step in the relabeled schedule.  Kwargs are the *forward*
    shapes.  On a mesh, "batch" shards the contraction — each device
    accumulates a private dW over its rows, tree-reduced as ici_words."""

    op: ClassVar[str] = "matmul_dw"

    def _shard_candidates(self, group: int, *, m: int, n: int, k: int,
                          **shape) -> list[ShardCandidate]:
        del shape
        ax = self.shard_axis
        rep2 = (None, None)
        cands = []
        if group > 1 and m % group == 0:
            cands.append(ShardCandidate(
                "batch", {"m": m // group},
                ((ax, None), (ax, None), rep2),
                ici_words=ccr.tree_reduce_words(group, k * n)))
        return cands or [ShardCandidate("single", {}, (rep2, rep2, rep2))]

    def plan_local(
        self, *, m: int, n: int, k: int, in_bytes: int = 2,
        block_m: int | None = None, block_n: int | None = None,
        block_k: int | None = None,
    ) -> Schedule:
        inner = MatmulPlanner(self.machine).plan(
            m=k, n=n, k=m, in_bytes=in_bytes,
            block_m=block_k, block_n=block_n, block_k=block_m,
        )
        return _relabel_matmul(inner, self.op, {
            "block_m": "block_k", "block_n": "block_n", "block_k": "block_m",
        })

    def local_candidates(self, **shape) -> list[Schedule]:
        """Halving ladder over block_n — the streamed half of dW's
        resident [block_k, block_n] accumulator tile."""
        return self._ladder_candidates("block_n", self.machine.lane, **shape)


# ---------------------------------------------------------------------------
# Flash attention (beyond-paper, same methodology)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttentionPlanner(ShardablePlanner):
    """Picks (block_q, block_kv) for the flash-attention kernel.

    The q block with its f32 accumulator and (m, l) statistics is the
    VMEM-resident output stack; K/V stream through like the paper's input
    depth slices.  Blocks start at the MXU sweet spot (128, clamped to the
    sequence rounded up to the 8-row sublane) and halve until the working
    set fits — the capacity rule, downward.  Explicit blocks are honored
    (clamped to the rounded sequence, as the old wrapper did).
    """

    op: ClassVar[str] = "flash_attention"

    _SUBLANE: ClassVar[int] = 8
    _CAP: ClassVar[int] = 128

    def _vmem_bytes(self, bq: int, bkv: int, head_dim: int, in_bytes: int) -> int:
        stream = 0
        if self.machine.charge_stream_blocks:
            # q block + double-buffered k and v blocks.
            stream = (bq * head_dim + 2 * bkv * head_dim) * in_bytes * 2
        return stream + bq * head_dim * 4 + 2 * bq * 4  # acc + (m, l)

    @staticmethod
    def kv_blocks_run(q0: int, bq: int, bkv: int, n_kvb: int,
                      causal: bool, window: int | None) -> int:
        """KV blocks the kernel's `run` predicate executes for the q block
        starting at row ``q0`` — the closed-form mirror of the kernel's
        block-level causal/window skips (validated against the executed
        walk in core/schedule_sim.simulate_attention_blocks)."""
        hi = n_kvb - 1
        if causal:  # kernel: k_start <= q_start + bq - 1
            hi = min(hi, (q0 + bq - 1) // bkv)
        lo = 0
        if window is not None:  # kernel: k_start + bkv - 1 > q_start - window
            lo = max(0, -(-(q0 - window + 2 - bkv) // bkv))
        return max(0, hi - lo + 1)

    def plan_local(
        self, *, seq_q: int, seq_kv: int, head_dim: int,
        n_q_heads: int = 1, n_kv_heads: int = 1, batch: int = 1,
        in_bytes: int = 4, block_q: int | None = None,
        block_kv: int | None = None, causal: bool = False,
        window: int | None = None,
    ) -> Schedule:
        sub = self._SUBLANE
        auto = block_q is None and block_kv is None
        bq = min(block_q or self._CAP, round_up(seq_q, sub))
        bkv = min(block_kv or self._CAP, round_up(seq_kv, sub))
        if auto:
            budget = self.machine.usable_for_working_set(streams=2)
            while (self._vmem_bytes(bq, bkv, head_dim, in_bytes) > budget
                   and max(bq, bkv) > sub):
                if bkv >= bq:
                    bkv = max(sub, round_up(bkv // 2, sub))
                else:
                    bq = max(sub, round_up(bq // 2, sub))

        sqp, skvp = round_up(seq_q, bq), round_up(seq_kv, bkv)
        bhq = batch * n_q_heads
        n_qb = sqp // bq
        n_kvb = skvp // bkv
        # q loads once per row-block; every q block of every *query* head
        # streams its KV head's K and V blocks that survive the kernel's
        # block-level causal/window skips — real DMA savings: the kernel's
        # kv BlockSpec clamps its index into the run range, so skipped grid
        # steps revisit an adjacent block and the pipeline issues no new
        # copy (give or take one boundary copy when consecutive q blocks'
        # ranges touch).  GQA sharing saves no HBM traffic — the grid
        # re-fetches per query head.  With no mask this degenerates to the
        # dense n_qb * skvp upper bound.
        run_blocks = sum(
            self.kv_blocks_run(qi * bq, bq, bkv, n_kvb, causal, window)
            for qi in range(n_qb)
        )
        loads = bhq * (sqp * head_dim + run_blocks * bkv * head_dim * 2)
        stores = bhq * sqp * head_dim
        return Schedule(
            op=self.op,
            critical_path_steps=ccr.grid_steps((bhq, n_qb, n_kvb)),
            grid=(bhq, n_qb, n_kvb),
            blocks=(("block_kv", bkv), ("block_q", bq)),
            halo=0,
            macs=bhq * run_blocks * bq * bkv * head_dim * 2,
            loads=loads,
            stores=stores,
            vmem_bytes=self._vmem_bytes(bq, bkv, head_dim, in_bytes),
            machine=self.machine.name,
        )

    def local_candidates(self, **shape) -> list[Schedule]:
        """The argmin's (block_q, block_kv) pick plus the sublane-aligned
        halvings of each — the small 2-D neighbourhood the downward
        capacity rule walks."""
        if (shape.get("block_q") is not None
                or shape.get("block_kv") is not None):
            return [self.plan_local(**shape)]
        base = self.plan_local(**shape)
        bq, bkv = base.block("block_q"), base.block("block_kv")
        sub = self._SUBLANE
        out, seen = [base], {base.blocks}
        for q2, kv2 in ((bq, bkv // 2), (bq // 2, bkv), (bq // 2, bkv // 2)):
            if q2 < sub or kv2 < sub:
                continue
            s = self.plan_local(**{**shape, "block_q": round_up(q2, sub),
                                   "block_kv": round_up(kv2, sub)})
            if s.blocks not in seen and s.fits(self.machine):
                out.append(s)
                seen.add(s.blocks)
        return out


# ---------------------------------------------------------------------------
# MoE expert FFN (the expert-parallel wing)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoeFfnPlanner(ShardablePlanner):
    """Plans the MoE expert-FFN block: E experts, each a two-GEMM FFN on
    its capacity rows.

    The capacity-factor dispatch of models/moe.py fixes each expert's row
    count at ``cap = ceil(top_k * tokens / n_experts * capacity_factor)``
    (the balanced slot-major argsort), so the local schedule is E
    repetitions of two delegated :class:`MatmulPlanner` GEMMs — up
    ``[cap, d_model] @ [d_model, d_ff]`` and down ``[cap, d_ff] @
    [d_ff, d_model]`` — the compound-planner pattern again.

    On a mesh two partitionings compete: "batch" (tokens sharded, experts
    replicated — every device re-streams *all* E experts' weights on its
    token shard, zero ici) and "ep" (expert parallelism — experts sharded
    E/P per device, weights streamed once, the routed rows crossing the
    interconnect twice as the all-to-all; ``ccr.moe_all_to_all_words``,
    pinned against the executed dispatch walker).  The trade mirrors
    tp-vs-batch: expert *weight* words against routed *activation* words.
    """

    op: ClassVar[str] = "moe_ffn"

    @staticmethod
    def expert_capacity(tokens: int, n_experts: int, top_k: int,
                        capacity_factor: float) -> int:
        """Rows per expert under the balanced capacity dispatch — the
        models/moe.py formula verbatim."""
        import math as _math
        return max(1, _math.ceil(top_k * tokens / n_experts
                                 * capacity_factor))

    def _shard_candidates(self, group: int, *, tokens: int, n_experts: int,
                          d_model: int, top_k: int = 2,
                          **shape) -> list[ShardCandidate]:
        del shape
        ax = self.shard_axis
        rep2, rep3 = (None, None), (None, None, None)
        cands = []
        if group > 1 and tokens % group == 0:
            cands.append(ShardCandidate(
                "batch", {"tokens": tokens // group},
                ((ax, None), rep3, (ax, None))))
        if (group > 1 and tokens % group == 0 and n_experts % group == 0
                and (tokens // group * top_k) % n_experts == 0):
            cands.append(ShardCandidate(
                "ep", {"tokens": tokens // group,
                       "n_experts": n_experts // group},
                ((ax, None), (ax, None, None), (ax, None)),
                ici_words=ccr.moe_all_to_all_words(
                    tokens=tokens, d_model=d_model, top_k=top_k,
                    n_experts=n_experts, devices=group)))
        return cands or [ShardCandidate("single", {}, (rep2, rep3, rep2))]

    def plan_local(
        self, *, tokens: int, d_model: int, d_ff: int, n_experts: int,
        top_k: int = 2, capacity_factor: float = 1.0, in_bytes: int = 4,
        block_m: int | None = None, block_n: int | None = None,
        block_k: int | None = None,
    ) -> Schedule:
        cap = self.expert_capacity(tokens, n_experts, top_k,
                                   capacity_factor)
        mm = MatmulPlanner(self.machine)
        up = mm.plan_local(m=cap, n=d_ff, k=d_model, in_bytes=in_bytes,
                           block_m=block_m, block_n=block_n,
                           block_k=block_k)
        down = mm.plan_local(m=cap, n=d_model, k=d_ff, in_bytes=in_bytes,
                             block_m=block_m, block_n=block_n,
                             block_k=block_k)
        # The expert loop wraps both GEMMs back-to-back with one shared
        # pipeline fill; the grid records the up GEMM's walk under the
        # expert dimension (the down GEMM's steps ride the critical path).
        grid = (n_experts,) + up.grid
        steps = 1 + n_experts * ((ccr.grid_steps(up.grid) - 1)
                                 + (ccr.grid_steps(down.grid) - 1))
        return Schedule(
            op=self.op,
            grid=grid,
            blocks=up.blocks,
            halo=0,
            macs=n_experts * (up.macs + down.macs),
            loads=n_experts * (up.loads + down.loads),
            stores=n_experts * (up.stores + down.stores),
            vmem_bytes=max(up.vmem_bytes, down.vmem_bytes),
            machine=self.machine.name,
            critical_path_steps=steps,
        )

    def local_candidates(self, **shape) -> list[Schedule]:
        """Halving ladder over block_n — the delegated GEMMs' Delta_O
        output stack."""
        return self._ladder_candidates("block_n", self.machine.lane, **shape)


# ---------------------------------------------------------------------------
# Transformer block (compound planner: the whole wing through delegation)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TransformerBlockPlanner(ShardablePlanner):
    """Plans a transformer block as a dict of delegated cells — the
    compound-planner pattern of :class:`Im2colConvPlanner`, one level up.

    Every matmul cell (qkv projection, attention output projection, the
    gate+up and down MLP GEMMs, the tied logits head) delegates to
    :class:`MatmulPlanner` on its ``[tokens, k] @ [k, n]`` shape; the
    attention cell delegates to :class:`AttentionPlanner`; with
    ``n_experts > 0`` the MLP cells are replaced by one
    :class:`MoeFfnPlanner` cell.  Mesh, shard axis and a ``strategy=`` pin
    pass straight through to the sub-planners, so on a mesh every cell is
    its own ShardedSchedule argmin (tp vs batch vs psum/ring for the
    GEMMs, ep vs batch for the MoE FFN) — the paper's joint
    algorithm-and-partitioning choice, per cell.

    ``plan()`` returns ``{cell_name: (Sharded)Schedule}`` keyed the way
    ``models/transformer.py`` consumes them (qkv/attn/wo/mlp_up/mlp_down
    [+ logits, or moe]), mirroring ``cnn.plan_forward``'s stage dict.
    """

    op: ClassVar[str] = "transformer_block"

    def cell_planners(self, *, batch: int, seq: int, d_model: int,
                       n_heads: int, d_ff: int, n_kv_heads: int | None = None,
                       vocab: int = 0, n_experts: int = 0, top_k: int = 2,
                       capacity_factor: float = 1.0, in_bytes: int = 4,
                       causal: bool = True) -> dict[str, tuple]:
        """(planner, shape-kwargs) per cell — the delegation table."""
        hq = n_heads
        hkv = n_kv_heads or n_heads
        dh = d_model // hq
        m = batch * seq
        bind = dict(machine=self.machine, mesh=self.mesh,
                    shard_axis=self.shard_axis, strategy=self.strategy)
        mm = MatmulPlanner(**bind)
        cells: dict[str, tuple] = {
            "qkv": (mm, dict(m=m, n=(hq + 2 * hkv) * dh, k=d_model,
                             in_bytes=in_bytes)),
            "attn": (AttentionPlanner(**bind),
                     dict(seq_q=seq, seq_kv=seq, head_dim=dh,
                          n_q_heads=hq, n_kv_heads=hkv, batch=batch,
                          in_bytes=in_bytes, causal=causal)),
            "wo": (mm, dict(m=m, n=d_model, k=hq * dh, in_bytes=in_bytes)),
        }
        if n_experts:
            cells["moe"] = (MoeFfnPlanner(**bind),
                            dict(tokens=m, d_model=d_model, d_ff=d_ff,
                                 n_experts=n_experts, top_k=top_k,
                                 capacity_factor=capacity_factor,
                                 in_bytes=in_bytes))
        else:
            # gate and up share one fused GEMM (models/layers.py computes
            # both projections of the gated MLP from the same x stream).
            cells["mlp_up"] = (mm, dict(m=m, n=2 * d_ff, k=d_model,
                                        in_bytes=in_bytes))
            cells["mlp_down"] = (mm, dict(m=m, n=d_model, k=d_ff,
                                          in_bytes=in_bytes))
        if vocab:
            cells["logits"] = (mm, dict(m=m, n=vocab, k=d_model,
                                        in_bytes=in_bytes))
        return cells

    def plan(self, **shape) -> dict:
        return {name: planner.plan(**kw)
                for name, (planner, kw)
                in self.cell_planners(**shape).items()}

    def candidates(self, **shape) -> dict:
        """Per-cell candidate enumeration: ``{cell: [ranked candidates]}``
        — each cell's own argmin search space (the autotuner tunes cells
        independently, exactly as it does conv stages)."""
        return {name: planner.candidates(**kw)
                for name, (planner, kw)
                in self.cell_planners(**shape).items()}


PLANNERS: dict[str, type] = {
    ConvPlanner.op: ConvPlanner,
    Im2colConvPlanner.op: Im2colConvPlanner,
    ConvDgradPlanner.op: ConvDgradPlanner,
    ConvWgradPlanner.op: ConvWgradPlanner,
    MatmulPlanner.op: MatmulPlanner,
    MatmulDxPlanner.op: MatmulDxPlanner,
    MatmulDwPlanner.op: MatmulDwPlanner,
    AttentionPlanner.op: AttentionPlanner,
    MoeFfnPlanner.op: MoeFfnPlanner,
    TransformerBlockPlanner.op: TransformerBlockPlanner,
}


def planner_for(op: str, machine: MachineModel = TPU_V5E, mesh=None,
                shard_axis: str = "model",
                strategy: str | None = None) -> Planner:
    """The registered planner for an op name, bound to a machine — and,
    when ``mesh`` is given (a MeshSpec, jax Mesh, dict or (name, size)
    pairs), to a mesh: its ``plan`` then emits a ShardedSchedule whose
    partitioning over ``shard_axis`` is chosen by modeled words (or pinned
    with ``strategy=``)."""
    from repro.plan.sharded import mesh_spec

    try:
        cls = PLANNERS[op]
    except KeyError:
        raise KeyError(f"no planner registered for op {op!r}; "
                       f"known: {sorted(PLANNERS)}") from None
    if mesh is None:
        return cls(machine)
    return cls(machine, mesh_spec(mesh), shard_axis, strategy)
