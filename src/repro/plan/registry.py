"""`pallas_op`: the registry that puts one Schedule/Planner layer behind
every Pallas kernel in the repo.

Each kernel package registers itself once — a planner, a shape extractor,
and a schedule-driven implementation — and inherits the boilerplate the
three ``ops.py`` files used to duplicate in diverging dialects:

  * interpret-mode fallback (``interpret=None`` -> interpret off-TPU),
  * output-dtype promotion (``out_dtype=None`` -> first operand's dtype),
  * schedule resolution (explicit ``Schedule`` beats the planner),
  * lane padding/unpadding helpers (:func:`pad_dim`),
  * reference-VJP ``custom_vjp`` wiring (:func:`with_reference_vjp`).

Ops resolve lazily by name (:func:`get_op`), so ``repro.plan`` never
imports kernel code at module load.
"""

from __future__ import annotations

import dataclasses
import functools
import importlib
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.machine import TPU_V5E, MachineModel
from repro.plan.planners import Planner, planner_for, round_up
from repro.plan.schedule import Schedule
from repro.plan.sharded import ShardedSchedule, local_schedule, mesh_spec

# ---------------------------------------------------------------------------
# Shared boilerplate
# ---------------------------------------------------------------------------


def default_interpret(interpret: bool | None) -> bool:
    """Pallas interpret-mode fallback: run interpreted anywhere but on TPU."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def pad_dim(x: jax.Array, axis: int, size: int) -> jax.Array:
    """Zero-pad one axis up to ``size`` (no-op when already there)."""
    have = x.shape[axis]
    if have == size:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, size - have)
    return jnp.pad(x, widths)


def freeze_schedules(schedules) -> tuple | None:
    """Normalize a ``{name: Schedule}`` mapping into the hashable
    sorted-tuple form that ``custom_vjp`` nondiff arguments require
    (tuples and ``None`` pass through unchanged)."""
    if schedules is None or isinstance(schedules, tuple):
        return schedules
    return tuple(sorted(schedules.items()))


def with_reference_vjp(kernel_fn, ref_fn, *, nondiff_argnums: tuple[int, ...] = (),
                       bwd_fn: Callable | None = None,
                       fwd_fn: Callable | None = None):
    """``custom_vjp`` wiring shared by every layer module: forward runs the
    Pallas kernel, backward runs ``bwd_fn`` (planned backward kernels) when
    given, else differentiates the XLA reference composition.

    ``nondiff_argnums`` must be the *trailing* positional arguments of
    ``kernel_fn``; ``ref_fn`` takes the same positional arguments.
    ``bwd_fn`` is called as ``bwd_fn(*diff_args, cotangent, *nondiff_args)``
    and must return one cotangent per differentiable argument.  Backward
    Schedules ride as a trailing nondiff argument (``bwd_schedules``,
    frozen via :func:`freeze_schedules`) so ``bwd_fn`` can honor them —
    closing the old gap where a user-passed schedule was silently ignored
    on the backward call because the reference VJP has no schedule knob.

    ``fwd_fn`` (same signature as ``kernel_fn``) is the *differentiated*
    forward: it returns ``(out, aux)`` where ``aux`` is a cheap auxiliary
    residual (e.g. the fused kernel's epilogue-VJP mask) — or ``None``
    when the kernel couldn't produce one.  The aux rides as the trailing
    residual, so ``bwd_fn`` becomes ``bwd_fn(*diff_args, aux, cotangent,
    *nondiff_args)``.  Primal-only calls still run plain ``kernel_fn`` and
    never pay for the aux output.
    """
    for i, j in zip(nondiff_argnums, nondiff_argnums[1:]):
        assert j == i + 1, "nondiff_argnums must be contiguous and trailing"

    @functools.partial(jax.custom_vjp, nondiff_argnums=nondiff_argnums)
    def op(*args):
        return kernel_fn(*args)

    def fwd(*args):
        assert not nondiff_argnums or nondiff_argnums[-1] == len(args) - 1, (
            "nondiff_argnums must be the trailing arguments of kernel_fn: "
            f"got {nondiff_argnums} for {len(args)} args"
        )
        diff = tuple(a for i, a in enumerate(args) if i not in nondiff_argnums)
        if fwd_fn is not None:
            out, aux = fwd_fn(*args)
            return out, diff + (aux,)
        return kernel_fn(*args), diff

    def bwd(*call):
        n = len(nondiff_argnums)
        nondiff, (res, g) = call[:n], call[n:]
        if bwd_fn is not None:
            return tuple(bwd_fn(*res, g, *nondiff))
        _, vjp = jax.vjp(lambda *d: ref_fn(*d, *nondiff), *res)
        return vjp(g)

    op.defvjp(fwd, bwd)
    return op


# ---------------------------------------------------------------------------
# The op registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PallasOp:
    """One registered kernel: planner + shape extraction + implementation.

    ``shape_args(*arrays, **params)`` maps concrete operands to the
    planner's keyword shapes; ``impl(*arrays, schedule=, out_dtype=,
    interpret=, **params)`` runs the (jit'd) kernel from a Schedule.
    """

    name: str
    planner: type  # Planner class, constructed per machine
    shape_args: Callable[..., dict[str, Any]]
    impl: Callable[..., jax.Array]
    reference: Callable[..., jax.Array] | None = None
    # Multi-device execution of a ShardedSchedule's strategy (shard_map
    # dataflow); ops without one still *plan* sharded, they just can't
    # execute the collective strategies through the registry.
    sharded_impl: Callable[..., jax.Array] | None = None

    def planner_for(self, machine: MachineModel = TPU_V5E, mesh=None,
                    shard_axis: str = "model",
                    strategy: str | None = None) -> Planner:
        if mesh is None:
            return self.planner(machine)
        return self.planner(machine, mesh_spec(mesh), shard_axis, strategy)

    def plan(self, *arrays, machine: MachineModel = TPU_V5E,
             autotune: str | None = None, **params) -> Schedule:
        """Plan from concrete operands (shapes/dtypes only are read).
        Cached per (planner, shapes): eager call loops re-plan for free.
        ``autotune`` overrides the process policy for this resolution —
        under "cache-only"/"tune" a measured winner beats the argmin."""
        shape = self.shape_args(*arrays, **params)
        tuned = _tuned(self.name, shape, machine, None, "model", None,
                       autotune, arrays[0].dtype)
        if tuned is not None:
            return tuned
        return _cached_plan(self.planner(machine), tuple(sorted(shape.items())))

    def plan_sharded(
        self, *arrays, mesh, machine: MachineModel = TPU_V5E,
        axis: str = "model", strategy: str | None = None,
        autotune: str | None = None, **params,
    ) -> ShardedSchedule:
        """Plan from concrete operands against a ``(machine, mesh)`` pair:
        the returned ShardedSchedule carries the device partitioning and
        the HBM/ICI word split (cached like :meth:`plan`; a tuned winner
        for the ``(op, shapes, machine, mesh)`` cell overrides the
        modeled psum-vs-ring-vs-batch pick)."""
        shape = self.shape_args(*arrays, **params)
        tuned = _tuned(self.name, shape, machine, mesh_spec(mesh), axis,
                       strategy, autotune, arrays[0].dtype)
        if tuned is not None:
            return tuned
        planner = self.planner_for(machine, mesh, axis, strategy)
        return _cached_plan(planner, tuple(sorted(shape.items())))

    def __call__(
        self, *arrays, schedule: Schedule | ShardedSchedule | None = None,
        machine: MachineModel = TPU_V5E, interpret: bool | None = None,
        out_dtype=None, autotune: str | None = None, **params,
    ) -> jax.Array:
        interpret = default_interpret(interpret)
        out_dtype = out_dtype or arrays[0].dtype
        schedule = local_schedule(schedule)  # degenerate sharded plans run local
        if schedule is None:
            schedule = local_schedule(
                self.plan(*arrays, machine=machine, autotune=autotune,
                          **params))
        return self.impl(
            *arrays, schedule=schedule, out_dtype=out_dtype,
            interpret=interpret, **params,
        )

    def sharded(
        self, *arrays, schedule: ShardedSchedule, mesh,
        interpret: bool | None = None, out_dtype=None, **params,
    ) -> jax.Array:
        """Execute a ShardedSchedule's multi-device strategy on a live
        ``jax.sharding.Mesh``: the registered ``sharded_impl`` builds the
        shard_map dataflow (psum tree / ring permutes / data parallelism)
        from the schedule's partition — call sites never hand-wire specs.
        The "single" strategy (and any 1-wide shard group) falls back to
        the plain per-device impl."""
        if schedule.strategy == "single" or schedule.devices == 1:
            return self(*arrays, schedule=schedule.schedule,
                        interpret=interpret, out_dtype=out_dtype, **params)
        if self.sharded_impl is None:
            raise NotImplementedError(
                f"op {self.name!r} registered no sharded_impl; strategy "
                f"{schedule.strategy!r} cannot execute through the registry")
        interpret = default_interpret(interpret)
        out_dtype = out_dtype or arrays[0].dtype
        return self.sharded_impl(
            *arrays, schedule=schedule, mesh=mesh, out_dtype=out_dtype,
            interpret=interpret, **params,
        )


@functools.lru_cache(maxsize=4096)
def _cached_plan(planner: Planner, shape_items: tuple) -> Schedule:
    """Planners are frozen dataclasses and shape kwargs are hashable ints,
    so identical (planner, shapes) pairs return the memoized Schedule."""
    return planner.plan(**dict(shape_items))


def _tuned(name, shape, machine, mesh, axis, strategy, policy, dtype):
    """The measured-time override for one schedule resolution (see
    repro.plan.autotune), or ``None`` when the modeled argmin stands —
    policy "off" short-circuits before the autotuner is even imported."""
    from repro.plan import autotune as _at

    if (policy or _at.get_policy()) == "off":
        return None
    return _at.tuned_schedule(name, shape, machine=machine, mesh=mesh,
                              axis=axis, strategy=strategy, policy=policy,
                              dtype=dtype)


_OPS: dict[str, PallasOp] = {}

# Ops register at import of their kernel package; get_op() imports lazily so
# `repro.plan` stays importable without (and before) any kernel code.
_PROVIDERS = {
    "conv2d": "repro.kernels.conv2d.ops",
    "conv2d_im2col": "repro.kernels.conv2d.im2col",
    "conv2d_dgrad": "repro.kernels.conv2d.bwd",
    "conv2d_wgrad": "repro.kernels.conv2d.bwd",
    "matmul": "repro.kernels.matmul.ops",
    "matmul_dx": "repro.kernels.matmul.bwd",
    "matmul_dw": "repro.kernels.matmul.bwd",
    "flash_attention": "repro.kernels.flash_attention.ops",
}


def pallas_op(
    name: str, *, planner: type, shape_args: Callable, impl: Callable,
    reference: Callable | None = None, sharded_impl: Callable | None = None,
) -> PallasOp:
    """Register a kernel behind the plan layer (returns the op handle)."""
    op = PallasOp(name=name, planner=planner, shape_args=shape_args,
                  impl=impl, reference=reference, sharded_impl=sharded_impl)
    _OPS[name] = op
    return op


def get_op(name: str) -> PallasOp:
    """Look up a registered op, importing its provider module if needed."""
    if name not in _OPS and name in _PROVIDERS:
        importlib.import_module(_PROVIDERS[name])
    try:
        return _OPS[name]
    except KeyError:
        raise KeyError(f"unknown pallas op {name!r}; known: "
                       f"{sorted(set(_OPS) | set(_PROVIDERS))}") from None


def registered_ops() -> tuple[str, ...]:
    """All op names the registry can resolve."""
    return tuple(sorted(set(_OPS) | set(_PROVIDERS)))


__all__ = [
    "PallasOp", "default_interpret", "freeze_schedules", "get_op", "pad_dim",
    "pallas_op", "planner_for", "registered_ops", "round_up",
    "with_reference_vjp",
]
