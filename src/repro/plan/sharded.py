"""`ShardedSchedule`: device partitioning as a planner *output*.

PRs 2-3 made the paper's capacity argument a single `repro.plan` layer for
every forward and backward kernel — but only within one device.  The
multi-cluster half of the paper (Alg 3's ring reuse of input depth slices,
Alg 4's tree reduction of private FC outputs) stayed hand-wired at call
sites.  This module closes that gap: a planner handed a ``(MachineModel,
MeshSpec)`` pair emits a :class:`ShardedSchedule` — a per-device
:class:`~repro.plan.schedule.Schedule` plus the mesh shape, the chosen
partitioning of every operand, and the modeled words split into per-mesh
main-memory (``hbm_*``) and interconnect (``ici_words``) counts — so
``core/ring.py``'s ring and ``fc_layer_sharded``'s psum are *consumed*
from the plan, not re-derived at each call site.

Conventions:

  * ``hbm_loads``/``hbm_stores`` are **shard-group totals**: summed over
    the ``devices`` of the partitioned mesh axis.  Every strategy here is
    device-symmetric, so per-device counts are the totals divided by
    ``devices``.  Other mesh axes replicate the plan — a caller spreading
    it over an orthogonal axis (e.g. model-parallel replicas of a
    data-sharded conv) multiplies the totals itself.
  * ``ici_words`` is the shard-group-total interconnect traffic:
    ring-permute words for the "ring" strategy, the Alg-4 tree-reduction
    words for the "psum"/batch-contraction strategies, zero for pure
    data/stack parallelism.
  * A **single-device mesh degenerates exactly**: the wrapped ``schedule``
    equals the meshless planner's Schedule, ``hbm_* == schedule.loads/
    stores`` and ``ici_words == 0`` (pinned in tests/test_plan.py).

Like `Schedule`, everything here is frozen and hashable so sharded plans
ride through ``jax.jit`` static arguments and the registry's plan cache.
"""

from __future__ import annotations

import dataclasses

from repro.core import ccr
from repro.core.machine import MachineModel
from repro.plan.schedule import Schedule

# Per-operand partition entries: one tuple per operand (outputs last), one
# entry per array dimension — ``None`` (replicated) or the mesh axis name.
Partition = tuple[tuple[str | None, ...], ...]


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Hashable description of a device mesh (names and sizes only).

    The plan layer never touches concrete jax devices: a MeshSpec is to
    ``jax.sharding.Mesh`` what a Schedule is to a ``pallas_call`` — the
    model side.  Build one from a live mesh with :func:`mesh_spec`.
    """

    axes: tuple[tuple[str, int], ...]

    def __post_init__(self):
        for _, n in self.axes:
            if n <= 0:
                raise ValueError(f"mesh axis sizes must be positive: {self.axes}")

    @property
    def devices(self) -> int:
        n = 1
        for _, s in self.axes:
            n *= s
        return n

    def axis_size(self, name: str) -> int:
        for k, s in self.axes:
            if k == name:
                return s
        raise KeyError(f"mesh {self.axes} has no axis {name!r}")

    @property
    def axis_names(self) -> tuple[str, ...]:
        return tuple(k for k, _ in self.axes)

    def with_axis(self, name: str, size: int) -> "MeshSpec":
        """A copy with one axis resized (elastic re-mesh: the data axis
        shrinks to the survivors, everything else is untouched)."""
        if name not in self.axis_names:
            raise KeyError(f"mesh {self.axes} has no axis {name!r}")
        return MeshSpec(axes=tuple(
            (k, size if k == name else s) for k, s in self.axes))

    def shrink_to(self, n_devices: int,
                  preserve: tuple[str, ...] = ("model",)) -> "MeshSpec":
        """The largest mesh of the same axes fitting ``n_devices``
        survivors, preserving the extent of every ``preserve`` axis (TP
        groups must stay intact — runtime/fault_tolerance.py's restart
        protocol).  Non-preserved axes shrink outermost-first: an axis
        whose extent no longer divides the survivors collapses to 1 and
        the innermost non-preserved axis absorbs the rest (mirrors
        ``shrink_mesh_shape``'s (pod, data, model) behavior)."""
        keep = 1
        for k, s in self.axes:
            if k in preserve:
                keep *= s
        if n_devices <= 0 or n_devices % keep:
            raise ValueError(
                f"survivors ({n_devices}) not divisible by preserved axes "
                f"{[(k, s) for k, s in self.axes if k in preserve]}")
        rest = n_devices // keep
        free = [k for k in self.axis_names if k not in preserve]
        if not free:
            if rest != 1:
                raise ValueError(
                    f"all axes preserved but {rest} spare devices")
            return self
        sizes = dict(self.axes)
        new = dict(self.axes)
        for k in free[:-1]:
            if rest % sizes[k] == 0 and sizes[k] <= rest:
                new[k] = sizes[k]
            else:
                new[k] = 1
            rest //= new[k]
        new[free[-1]] = rest
        return MeshSpec(axes=tuple((k, new[k]) for k in self.axis_names))


def mesh_spec(mesh) -> MeshSpec:
    """Normalize a mesh-like value into a :class:`MeshSpec`.

    Accepts a MeshSpec (pass-through), a ``jax.sharding.Mesh`` (or anything
    with a ``.shape`` name->size mapping), a dict, or an iterable of
    ``(name, size)`` pairs.
    """
    if isinstance(mesh, MeshSpec):
        return mesh
    shape = getattr(mesh, "shape", mesh)
    if hasattr(shape, "items"):
        return MeshSpec(axes=tuple((str(k), int(v)) for k, v in shape.items()))
    return MeshSpec(axes=tuple((str(k), int(v)) for k, v in shape))


@dataclasses.dataclass(frozen=True)
class ShardedSchedule:
    """One planned execution of one kernel across a device mesh.

    ``schedule`` is the per-device local Schedule (its blocks drive the
    local ``pallas_call``); ``partition`` records how every operand (and
    the output, last) is split over ``axis``; ``strategy`` names the
    multi-device dataflow the registry's sharded impl executes.
    """

    schedule: Schedule  # the per-device local schedule
    mesh: MeshSpec
    axis: str  # the partitioned mesh axis ("model", "data", ...)
    strategy: str  # "single" | "batch" | "stack" | "psum" | "ring" | "tp" | "ep"
    partition: Partition
    hbm_loads: int  # shard-group-total main-memory words loaded
    hbm_stores: int  # shard-group-total main-memory words stored
    ici_words: int = 0  # shard-group-total interconnect words moved
    macs: int = 0  # shard-group-total multiply-accumulates

    # -- derived accounting ----------------------------------------------

    @property
    def op(self) -> str:
        return self.schedule.op

    @property
    def algorithm(self) -> str:
        """The per-device schedule's algorithm family — sharded plans of
        the two-level conv argmin keep their tag visible (batch/stack
        partitions apply to both families identically)."""
        return getattr(self.schedule, "algorithm", "direct")

    @property
    def devices(self) -> int:
        """Extent of the partitioned axis — the shard group every word
        total is summed over (NOT the whole mesh: orthogonal axes
        replicate this plan)."""
        if self.axis not in self.mesh.axis_names:
            return 1
        return self.mesh.axis_size(self.axis)

    @property
    def hbm_words(self) -> int:
        return self.hbm_loads + self.hbm_stores

    @property
    def modeled_words(self) -> int:
        """All modeled words, on- and off-mesh — the argmin quantity."""
        return self.hbm_words + self.ici_words

    def per_device(self, words: int) -> int:
        """Shard-group total -> per-device words (strategies are
        symmetric across the group)."""
        return words // self.devices

    @property
    def traffic(self) -> ccr.Traffic:
        """The paper's accounting: HBM words are main-memory traffic, ICI
        words are inter-cluster traffic (so ``.ccr`` / ``.ccr_offchip``
        reproduce the Sec. 2.3.4 style on/off-chip split directly)."""
        return ccr.Traffic(macs=self.macs, main_loads=self.hbm_loads,
                           main_stores=self.hbm_stores,
                           intercluster=self.ici_words)

    def fits(self, machine: MachineModel, streams: int = 2) -> bool:
        """Per-device working set vs the machine budget (Sec. 2.2.2)."""
        return self.schedule.fits(machine, streams)

    def block(self, name: str, default: int | None = None) -> int:
        return self.schedule.block(name, default)


def local_schedule(s) -> Schedule | None:
    """The per-device Schedule of either schedule flavor (``None`` passes
    through) — the unwrap every kernel wrapper and layer uses so explicit
    ``schedule=`` arguments accept both."""
    if s is None or isinstance(s, Schedule):
        return s
    if isinstance(s, ShardedSchedule):
        return s.schedule
    raise TypeError(f"expected Schedule or ShardedSchedule, got {type(s)!r}")


def partition_specs(sharded: ShardedSchedule):
    """Lower a ShardedSchedule's partition into ``jax.sharding
    .PartitionSpec`` objects, ``(*operand_specs, out_spec)`` — the single
    place plan-layer partitions become shard_map/pjit specs."""
    from jax.sharding import PartitionSpec as P

    return tuple(P(*entry) for entry in sharded.partition)


# Schedule-key stems per model family (the part before any ".dx"/".dw"
# backward suffix).  A plan set must come from ONE family's plan_training:
# mixing, say, a cnn "conv1" with a transformer "qkv" means two re-plans
# were spliced together and neither family's forward will find its stages.
_FAMILY_STEMS: dict[str, tuple[str, ...]] = {
    "cnn": ("conv", "fc"),
    "transformer": ("qkv", "attn", "wo", "mlp_up", "mlp_down", "logits",
                    "moe"),
}


def _stem_family(key: str) -> str | None:
    stem = key.split(".")[0]
    for fam, prefixes in _FAMILY_STEMS.items():
        if any(stem == p or (stem.startswith(p) and stem[len(p):].isdigit())
               for p in prefixes):
            return fam
    return None


def validate_sharded_plan(schedules: dict, mesh, machine: MachineModel | None = None) -> int:
    """Assert a plan set (e.g. ``cnn.plan_training(mesh=...)``) is valid
    for ``mesh`` — the recovery gate after an elastic re-mesh: every entry
    is a ShardedSchedule planned against exactly this MeshSpec, its
    partitioned axis exists, and (with ``machine``) its per-device working
    set fits.  Schedule keys must all belong to one model family's stage
    namespace (cnn conv*/fc* vs transformer qkv/attn/...): a mixed set is
    two spliced re-plans, not a plan.  Raises ValueError naming the
    offending stage; returns the number of schedules checked."""
    ms = mesh_spec(mesh)
    families = {f for f in map(_stem_family, schedules) if f is not None}
    if len(families) > 1:
        raise ValueError(
            f"mixed-family schedule keys {sorted(schedules)}: stages from "
            f"{sorted(families)} cannot share one plan set")
    for name, s in schedules.items():
        if not isinstance(s, ShardedSchedule):
            raise ValueError(
                f"{name}: expected a ShardedSchedule for mesh {ms.axes}, "
                f"got {type(s).__name__} (re-plan did not thread mesh=?)")
        if s.mesh != ms:
            raise ValueError(
                f"{name}: planned for mesh {s.mesh.axes}, not {ms.axes} — "
                "stale plan from before the re-mesh")
        if s.axis not in ms.axis_names:
            raise ValueError(
                f"{name}: partitioned axis {s.axis!r} not in mesh "
                f"{ms.axes}")
        if min(s.hbm_loads, s.hbm_stores, s.ici_words) < 0:
            raise ValueError(f"{name}: negative modeled words")
        if machine is not None and not s.fits(machine):
            raise ValueError(
                f"{name}: per-device working set exceeds {machine.name} "
                f"vmem on mesh {ms.axes}")
    return len(schedules)


@dataclasses.dataclass(frozen=True)
class ShardCandidate:
    """One partitioning a planner considers: which strategy, how the local
    (per-device) shapes shrink, how operands split, and what the mesh pays
    in interconnect words.  ``hbm_override`` replaces the default
    ``devices * local_schedule.modeled`` accounting (the ring's reuse means
    its HBM words are *not* the local plan's words)."""

    strategy: str
    local_shape: dict
    partition: Partition
    ici_words: int = 0
    hbm_override: tuple[int, int] | None = None  # (loads, stores) totals
    macs_override: int | None = None
