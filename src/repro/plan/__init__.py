"""repro.plan — the unified scheduling API behind every Pallas kernel.

One `Schedule` dataclass (grid, blocks, halo, modeled HBM words, VMEM
working set), one `Planner` protocol with per-op implementations that
encode the paper's capacity argument against a `MachineModel` (MANTICORE
or TPU_V5E), and one `pallas_op` registry that owns the wrapper
boilerplate.  See DESIGN.md Sec. 3.
"""

from repro.plan.planners import (
    PLANNERS,
    AttentionPlanner,
    ConvDgradPlanner,
    ConvPlanner,
    ConvWgradPlanner,
    MatmulDwPlanner,
    MatmulDxPlanner,
    MatmulPlanner,
    Planner,
    conv_strip_words,
    conv_wgrad_words,
    planner_for,
)
from repro.plan.registry import (
    PallasOp,
    default_interpret,
    freeze_schedules,
    get_op,
    pad_dim,
    pallas_op,
    registered_ops,
    with_reference_vjp,
)
from repro.plan.schedule import Blocks, Schedule, to_roofline

__all__ = [
    "AttentionPlanner",
    "Blocks",
    "ConvDgradPlanner",
    "ConvPlanner",
    "ConvWgradPlanner",
    "MatmulDwPlanner",
    "MatmulDxPlanner",
    "MatmulPlanner",
    "PLANNERS",
    "PallasOp",
    "Planner",
    "Schedule",
    "conv_strip_words",
    "conv_wgrad_words",
    "default_interpret",
    "freeze_schedules",
    "get_op",
    "pad_dim",
    "pallas_op",
    "planner_for",
    "registered_ops",
    "to_roofline",
    "with_reference_vjp",
]
