"""repro.plan — the unified scheduling API behind every Pallas kernel.

One `Schedule` dataclass (grid, blocks, halo, modeled HBM words, VMEM
working set), one `Planner` protocol with per-op implementations that
encode the paper's capacity argument against a `MachineModel` (MANTICORE
or TPU_V5E), and one `pallas_op` registry that owns the wrapper
boilerplate.  Planners constructed with a `MeshSpec` additionally emit
`ShardedSchedule`s: the device partitioning (Alg 3's ring, Alg 4's psum,
batch/stack data parallelism) becomes a planner output with the modeled
words split into per-mesh HBM and interconnect counts.  See DESIGN.md
Secs. 3-5.
"""

from repro.plan.planners import (
    PLANNERS,
    AttentionPlanner,
    ConvDgradPlanner,
    ConvPlanner,
    ConvWgradPlanner,
    Im2colConvPlanner,
    MatmulDwPlanner,
    MatmulDxPlanner,
    MatmulPlanner,
    MoeFfnPlanner,
    Planner,
    ShardablePlanner,
    TransformerBlockPlanner,
    conv_strip_words,
    conv_wgrad_words,
    planner_for,
)
from repro.plan.registry import (
    PallasOp,
    default_interpret,
    freeze_schedules,
    get_op,
    pad_dim,
    pallas_op,
    registered_ops,
    with_reference_vjp,
)
from repro.plan.schedule import Blocks, Schedule, to_roofline
from repro.plan.sharded import (
    MeshSpec,
    ShardCandidate,
    ShardedSchedule,
    local_schedule,
    mesh_spec,
    partition_specs,
    validate_sharded_plan,
)
# The autotuner (repro.plan.autotune: tune/resolve/set_policy/AutotuneCache)
# is deliberately NOT imported here: it is its own CLI entry point
# (`python -m repro.plan.autotune`), and importing it from the package
# __init__ would shadow that runpy execution.  Import the submodule
# directly: ``from repro.plan import autotune``.

__all__ = [
    "AttentionPlanner",
    "Blocks",
    "ConvDgradPlanner",
    "ConvPlanner",
    "ConvWgradPlanner",
    "Im2colConvPlanner",
    "MatmulDwPlanner",
    "MatmulDxPlanner",
    "MatmulPlanner",
    "MeshSpec",
    "MoeFfnPlanner",
    "PLANNERS",
    "PallasOp",
    "Planner",
    "Schedule",
    "ShardCandidate",
    "ShardablePlanner",
    "ShardedSchedule",
    "TransformerBlockPlanner",
    "conv_strip_words",
    "conv_wgrad_words",
    "default_interpret",
    "freeze_schedules",
    "get_op",
    "local_schedule",
    "mesh_spec",
    "pad_dim",
    "pallas_op",
    "partition_specs",
    "planner_for",
    "registered_ops",
    "to_roofline",
    "validate_sharded_plan",
    "with_reference_vjp",
]
