"""`Schedule`: the single value every Pallas kernel in this repo runs from.

The paper's contribution is a *capacity argument* — pick the output stack
Delta_O (and strip height) that maximizes reuse subject to on-cluster
memory.  A `Schedule` is one concrete outcome of that argument: the grid,
the block shapes, and the *model* behind the choice (HBM words, VMEM
working set), so the same object drives a `pallas_call`, reproduces the
paper's Manticore quotes (core/ccr.py), and feeds the roofline in
analysis/roofline.py.

Schedules are frozen and hashable: kernel wrappers pass them straight
through `jax.jit` as static arguments.
"""

from __future__ import annotations

import dataclasses

from repro.core import ccr
from repro.core.machine import MachineModel, word_bytes

# Block shapes as a sorted tuple of (name, size) pairs — hashable, so a
# Schedule can be a jit static argument.
Blocks = tuple[tuple[str, int], ...]


@dataclasses.dataclass(frozen=True)
class Schedule:
    """One planned execution of one kernel on one machine."""

    op: str  # registry name of the kernel this schedule drives
    grid: tuple[int, ...]  # pallas_call grid (or its machine analogue)
    blocks: Blocks  # block shapes by name, e.g. (("block_do", 256), ...)
    halo: int = 0  # input rows re-read between adjacent spatial tiles
    macs: int = 0  # modeled multiply-accumulates of the whole call
    loads: int = 0  # modeled main-memory (HBM) words loaded
    stores: int = 0  # modeled main-memory words stored
    vmem_bytes: int = 0  # modeled working set incl. double-buffered streams
    machine: str = "tpu_v5e"  # name of the MachineModel planned against
    algorithm: str = "direct"  # which algorithm family the blocks belong to
    critical_path_steps: int = 0  # sequential grid steps on the pipeline's
    # critical path (incl. fill); 0 means "not modeled" for legacy schedules

    # -- block access -----------------------------------------------------

    def block(self, name: str, default: int | None = None) -> int:
        for k, v in self.blocks:
            if k == name:
                return v
        if default is None:
            raise KeyError(f"schedule for {self.op!r} has no block {name!r}")
        return default

    def block_dict(self) -> dict[str, int]:
        return dict(self.blocks)

    def evolve(self, **block_updates: int) -> "Schedule":
        """Copy with some block sizes replaced (model fields unchanged —
        re-plan through the op's Planner to refresh them)."""
        merged = {**dict(self.blocks), **block_updates}
        return dataclasses.replace(self, blocks=tuple(sorted(merged.items())))

    # -- the capacity argument -------------------------------------------

    @property
    def modeled_words(self) -> int:
        """Modeled main-memory words moved (the quantity planners minimize;
        for the conv strip schedule this equals ccr.alg2_strip_traffic)."""
        return self.loads + self.stores

    @property
    def traffic(self) -> ccr.Traffic:
        """This schedule's traffic in the paper's accounting framework."""
        return ccr.Traffic(macs=self.macs, main_loads=self.loads,
                           main_stores=self.stores)

    def fits(self, machine: MachineModel, streams: int = 2) -> bool:
        """Does the modeled working set fit the machine's local memory after
        the DMA-stream reservation (the paper's Sec. 2.2.2 rule)?"""
        return self.vmem_bytes <= machine.usable_for_working_set(streams)

    # -- analysis hooks ---------------------------------------------------

    def bound_kind(self, machine: MachineModel, precision: str = "sp") -> str:
        """compute- vs memory-bound under this machine's balance point."""
        return ccr.bound_kind(self.traffic, machine, precision)

    def arithmetic_intensity(self, precision: str = "sp") -> float:
        """flop/B against main memory (2 flops per MAC)."""
        return self.traffic.flops_per_byte(precision, offchip_only=True)


def to_roofline(schedule: Schedule, *, precision: str = "sp", chips: int = 1):
    """Lower a Schedule into analysis.roofline.Roofline so planned kernels
    and compiled dry-run programs report through the same terms.

    The schedule's modeled words become `bytes_hbm`, its MACs become both
    `flops` and `model_flops` (a kernel does no dispatch overhead), and a
    single-chip kernel moves no collective bytes.
    """
    from repro.analysis.roofline import Roofline

    flops = 2.0 * schedule.macs
    return Roofline(
        flops=flops,
        bytes_hbm=float(schedule.modeled_words * word_bytes(precision)),
        bytes_coll=0.0,
        chips=chips,
        model_flops=flops,
    )
