"""Measured-time Schedule autotuning with a persistent per-cell cache.

The planners' argmin is a *model*: modeled main-memory words under the
paper's capacity argument.  This module closes the ROADMAP's
"autotuning search over Schedules" item by adding the measured-time mode
on top of it (the standard closing move of kernel schedulers — AutoTVM's
search, Triton's ``@autotune`` — cf. PAPERS.md):

  * every planner exposes its enumeration (``Planner.candidates()`` —
    the blocking ladder locally, one locally-argmin'd ShardedSchedule per
    partition strategy on a mesh);
  * :func:`tune` synthesizes operands for any registered ``pallas_op``
    from planner shapes, times the top-k candidates (interpret mode off
    TPU, real ``jax.block_until_ready`` timing on TPU; warmup +
    median-of-n), and records the winner in a JSON cache keyed by the
    ``(op, shapes, dtype, machine, mesh)`` cell — a schema-versioned,
    hash-stable key, so separate processes and CI runs share winners;
  * :func:`resolve` is the policy-aware schedule resolution every call
    site uses: ``"off"`` is the plain modeled argmin, ``"cache-only"``
    replays a cached winner (never times — safe under ``jax.jit``
    tracing and on CI), ``"tune"`` measures on a miss and caches.

Cached winners are *rebuilt through the planner* (strategy + blocks
pinned), so their model fields (loads/stores/vmem_bytes) stay exact and
the layers' ``fits()`` gating and XLA fallbacks are untouched — a tuned
schedule is just a different point of the same enumeration.

Timing protocol for multi-device candidates without a live mesh (e.g. the
paper's 16-cluster MANTICORE quadrant on a CPU host): each strategy times
its *per-device proxy* — the local kernel on partition-sliced operands
(the ring times one K-chunk step and multiplies by P, since its resident
X shard permutes P times) — plus the modeled interconnect time
``ici_words * word / machine.link_bw``.  With a live ``run_mesh`` whose
devices exist (forced host devices, a TPU slice), the registered
``sharded_impl`` is executed and timed for real.

CLI: ``python -m repro.plan.autotune --smoke`` (the tier1.sh
--autotune-smoke gate) or ``--op matmul --shape m=32,n=4096,k=25088
--machine manticore --mesh cluster=16``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
import warnings

import jax
import numpy as np

from repro.core.machine import TPU_V5E, MachineModel
from repro.plan.planners import planner_for
from repro.plan.schedule import Schedule
from repro.plan.sharded import MeshSpec, ShardedSchedule, local_schedule, mesh_spec

# Bump to invalidate every cached winner (key derivation, record layout,
# or timing-protocol changes all warrant it).
SCHEMA_VERSION = 1

POLICIES = ("off", "cache-only", "tune")

_POLICY = os.environ.get("REPRO_AUTOTUNE", "off")
_CACHE_PATH: str | None = None  # None -> env / default, resolved lazily
_CACHES: dict[str, "AutotuneCache"] = {}
_TUNING = False  # reentrancy guard: never autotune inside a tuning run
_WARNED_CELLS: set[str] = set()  # cells whose degradation was already logged


def _warn_once(digest: str, message: str) -> None:
    """Warn about one cell's silent-degradation path exactly once per
    process — the first fallback is loud, steady-state replays stay
    quiet (per-call warnings in a train loop would either drown the log
    or be deduped into invisibility by the warnings module)."""
    if digest in _WARNED_CELLS:
        return
    _WARNED_CELLS.add(digest)
    warnings.warn(message, stacklevel=3)


def default_cache_path() -> str:
    return os.environ.get("REPRO_AUTOTUNE_CACHE") or os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "autotune.json")


def set_policy(policy: str, cache_path: str | None = None) -> None:
    """Set the process-wide autotune policy (and optionally the cache
    file) — what ``launch/train.py --autotune`` calls.  Explicit
    ``autotune=`` arguments at call sites override it per call."""
    global _POLICY, _CACHE_PATH
    if policy not in POLICIES:
        raise ValueError(f"autotune policy must be one of {POLICIES}, "
                         f"got {policy!r}")
    _POLICY = policy
    if cache_path is not None:
        _CACHE_PATH = cache_path


def get_policy() -> str:
    return _POLICY


def recovery_policy(policy: str | None = None) -> str:
    """The resolution policy for re-planning on a *degraded* (shrunk) mesh
    during failure recovery: never spend recovery time measuring — a
    session that autotunes ("tune"/"cache-only") resolves the new cells
    cache-only (the shrunk MeshSpec keys a different cell, so a miss falls
    back to the planner's modeled argmin inside ``resolve``), while an
    "off" session stays off.  Recovery latency is bounded either way."""
    pol = policy if policy is not None else _POLICY
    if pol not in POLICIES:
        raise ValueError(f"autotune policy must be one of {POLICIES}, "
                         f"got {pol!r}")
    return "off" if pol == "off" else "cache-only"


def get_cache(path: str | None = None) -> "AutotuneCache":
    """The process-wide cache for ``path`` (default: the configured /
    env-derived location); one instance per file."""
    path = path or _CACHE_PATH or default_cache_path()
    if path not in _CACHES:
        _CACHES[path] = AutotuneCache(path)
    return _CACHES[path]


# ---------------------------------------------------------------------------
# Cache key: the (op, shapes, dtype, machine, mesh) cell
# ---------------------------------------------------------------------------


def _canonical_shape(shape: dict) -> list:
    """Sorted ``[name, value]`` pairs with unset (None) knobs dropped —
    two processes asking the same planner question hash identically."""
    return [[k, v] for k, v in sorted(shape.items()) if v is not None]


def cache_key(
    op: str, shape: dict, dtype, machine: MachineModel,
    mesh: MeshSpec | None = None, axis: str = "model",
    strategy: str | None = None,
) -> tuple[str, str]:
    """``(readable, digest)`` for one autotuning cell.  ``readable`` is a
    canonical JSON encoding of ``(schema, op, shapes, dtype, machine,
    mesh, axis, strategy)``; ``digest`` is its sha256 — stable across
    processes and machines (only named model objects enter the key)."""
    ms = mesh_spec(mesh) if mesh is not None else None
    cell = [
        SCHEMA_VERSION, op, _canonical_shape(shape), str(np.dtype(dtype)),
        machine.name,
        None if ms is None else [[a, int(s)] for a, s in ms.axes],
        axis if ms is not None else None,
        strategy,
    ]
    readable = json.dumps(cell, sort_keys=False, separators=(",", ":"))
    return readable, hashlib.sha256(readable.encode()).hexdigest()


class AutotuneCache:
    """Persistent JSON winner cache: ``{"schema": N, "entries": {digest:
    {"key": readable, "strategy": ..., "blocks": {...}, "us": ...}}}``.

    A corrupted or schema-mismatched file is treated as empty (the
    modeled argmin remains correct without it); writes are atomic
    (tmp + rename) and merge with the on-disk state so concurrent
    processes lose at most their own last winner."""

    def __init__(self, path: str):
        self.path = path
        self.generation = 0
        self._entries: dict[str, dict] | None = None
        self._memo: dict[str, Schedule | ShardedSchedule] = {}

    # -- persistence ------------------------------------------------------

    def _read_disk(self) -> dict[str, dict]:
        try:
            with open(self.path) as fh:
                data = json.load(fh)
            if not isinstance(data, dict) or data.get("schema") != SCHEMA_VERSION:
                return {}
            entries = data.get("entries")
            return entries if isinstance(entries, dict) else {}
        except FileNotFoundError:
            return {}
        except (json.JSONDecodeError, OSError, UnicodeDecodeError, ValueError) as e:
            warnings.warn(f"autotune cache {self.path!r} unreadable ({e}); "
                          "treating as empty", stacklevel=3)
            return {}

    def load(self) -> dict[str, dict]:
        if self._entries is None:
            self._entries = self._read_disk()
        return self._entries

    def reload(self) -> None:
        self._entries = None
        self._memo.clear()
        self.generation += 1

    # -- access -----------------------------------------------------------

    def get(self, digest: str) -> dict | None:
        return self.load().get(digest)

    def put(self, digest: str, readable: str, record: dict) -> None:
        entries = {**self._read_disk(), **self.load()}
        entries[digest] = {"key": readable, **record}
        self._entries = entries
        self._memo.clear()
        self.generation += 1
        os.makedirs(os.path.dirname(os.path.abspath(self.path)), exist_ok=True)
        tmp = f"{self.path}.tmp.{os.getpid()}"
        with open(tmp, "w") as fh:
            json.dump({"schema": SCHEMA_VERSION, "entries": entries}, fh,
                      indent=1, sort_keys=True)
        os.replace(tmp, self.path)

    def __len__(self) -> int:
        return len(self.load())


# ---------------------------------------------------------------------------
# Operand synthesis: planner shapes -> concrete arrays for timing
# ---------------------------------------------------------------------------


def _dtype_for(dtype, in_bytes) -> np.dtype:
    if dtype is not None:
        return np.dtype(dtype)
    import jax.numpy as jnp

    table = {2: np.dtype(jnp.bfloat16), 8: np.dtype(np.float64)}
    return table.get(in_bytes, np.dtype(np.float32))


def _conv_input_extent(out: int, F: int, S: int, P: int) -> int:
    return (out - 1) * S + F - 2 * P


def synthesize(op: str, shape: dict, dtype) -> tuple[tuple, dict]:
    """Concrete ``(arrays, call_params)`` for one op's planner shapes —
    what :func:`tune` times candidates on.  Contents are random but
    deterministic; only shapes/dtypes matter to the measurement."""
    import jax.numpy as jnp

    rng = np.random.default_rng(0)

    def arr(*dims):
        return jnp.asarray(rng.standard_normal(dims).astype(np.float32),
                           jnp.dtype(dtype))

    if op in ("conv2d", "conv2d_im2col", "conv2d_dgrad", "conv2d_wgrad"):
        F, S = shape["F"], shape.get("S", 1)
        P = shape.get("padding", shape.get("P", 0)) or 0
        B = shape.get("batch", 1)
        H_O, W_O = shape["H_O"], shape["W_O"]
        d_in, d_out = shape["d_in"], shape["d_out"]
        H_I = shape.get("H_I") or _conv_input_extent(H_O, F, S, P)
        W_I = shape.get("W_I") or _conv_input_extent(W_O, F, S, P)
        if op in ("conv2d", "conv2d_im2col"):
            pool = shape.get("pool", 1) or 1
            # The planner's H_O/W_O describe the pre-pool plane; the
            # traffic model stores pooled outputs, so time the fused form.
            return ((arr(B, H_I, W_I, d_in), arr(F, F, d_in, d_out),
                     arr(d_out)),
                    dict(stride=S, padding=P, relu=pool > 1, pool=pool))
        if op == "conv2d_dgrad":
            pool = shape.get("pool") or 1
            if pool and shape.get("pool") is not None:
                # Fused-epilogue cell: the planner's H_O/W_O are the
                # full-rate conv plane; the kernel's real inputs are the
                # *pooled* cotangent plus the int8 mask residual (argmax
                # position in [0, pool^2], pool^2 = dead window), so fused
                # candidates time on the true signature including the
                # in-jit scatter.
                Hp, Wp = H_O // pool, W_O // pool
                mask = jnp.asarray(
                    rng.integers(0, pool * pool + 1,
                                 (B, Hp, Wp, d_out)).astype(np.int8))
                return ((arr(B, Hp, Wp, d_out), arr(F, F, d_in, d_out)),
                        dict(stride=S, padding=P, out_hw=(H_I, W_I),
                             mask=mask, pool=pool))
            return ((arr(B, H_O, W_O, d_out), arr(F, F, d_in, d_out)),
                    dict(stride=S, padding=P, out_hw=(H_I, W_I)))
        return ((arr(B, H_I, W_I, d_in), arr(B, H_O, W_O, d_out)),
                dict(F=F, stride=S, padding=P))

    if op in ("matmul", "matmul_dx", "matmul_dw"):
        m, n, k = shape["m"], shape["n"], shape["k"]
        if op == "matmul":
            return (arr(m, k), arr(k, n)), {}
        if op == "matmul_dx":  # dX = dY @ W^T
            return (arr(m, n), arr(k, n)), {}
        return (arr(m, k), arr(m, n)), {}  # dW = X^T @ dY

    if op == "flash_attention":
        B = shape.get("batch", 1)
        Hq, Hkv = shape.get("n_q_heads", 1), shape.get("n_kv_heads", 1)
        Sq, Skv, D = shape["seq_q"], shape["seq_kv"], shape["head_dim"]
        return ((arr(B, Hq, Sq, D), arr(B, Hkv, Skv, D), arr(B, Hkv, Skv, D)),
                dict(causal=shape.get("causal", True),
                     window=shape.get("window")))

    raise KeyError(f"autotune has no operand synthesizer for op {op!r}")


# ---------------------------------------------------------------------------
# Timing
# ---------------------------------------------------------------------------


def _measure(fn, iters: int = 3, warmup: int = 1) -> float:
    """Median wall microseconds of ``fn`` (compile excluded via warmup)."""
    for _ in range(max(0, warmup)):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


def _proxy_operands(op: str, ss: ShardedSchedule, arrays: tuple):
    """``(operands, seq, schedule)`` of a sharded candidate's per-device
    proxy (no live mesh).  Default: slice every operand dim partitioned
    on the schedule's axis (one device's shard — psum/batch/stack run
    their whole local work in one call) under the local schedule.  The
    ring is special: its resident X shard permutes P times, so the proxy
    is a single (K/P, N/P) chunk step repeated ``devices`` times — with
    ``block_k`` clamped to the chunk, because the ring's local schedule
    is planned against the *full* K extent and an unclamped block would
    pad the K/P chunk back up to block_k, inflating the measurement."""
    P = ss.devices
    local = ss.schedule
    if ss.strategy == "ring" and op == "matmul":
        x, w = arrays
        k_step = max(1, x.shape[1] // P)
        local = local.evolve(block_k=min(local.block("block_k"), k_step))
        return (x[:, :k_step], w[:k_step, : max(1, w.shape[1] // P)]), P, local
    out = []
    for a, part in zip(arrays, ss.partition):
        idx = [slice(None)] * a.ndim
        for d, ax in enumerate(part[: a.ndim]):
            if ax == ss.axis:
                idx[d] = slice(0, max(1, a.shape[d] // P))
        out.append(a[tuple(idx)])
    return tuple(out), 1, local


def _time_candidate(op, arrays, params, cand, machine: MachineModel,
                    run_mesh, iters: int, warmup: int) -> float:
    """Wall-time one candidate (see the module docstring's protocol)."""
    local = local_schedule(cand)
    sharded = isinstance(cand, ShardedSchedule)
    if sharded and cand.devices > 1 and cand.strategy != "single":
        if run_mesh is not None and op.sharded_impl is not None:
            return _measure(
                lambda: op.sharded(*arrays, schedule=cand, mesh=run_mesh,
                                   **params),
                iters, warmup)
        proxy, seq, proxy_sched = _proxy_operands(op.name, cand, arrays)
        word = arrays[0].dtype.itemsize
        ici_us = cand.ici_words * word / machine.link_bw * 1e6
        us = _measure(lambda: op(*proxy, schedule=proxy_sched, **params),
                      iters, warmup)
        return us * seq + ici_us
    return _measure(lambda: op(*arrays, schedule=local, **params),
                    iters, warmup)


def _label(cand) -> str:
    loc = local_schedule(cand)
    blocks = dict(loc.blocks)
    alg = getattr(loc, "algorithm", "direct")
    tag = f"{alg}:" if alg != "direct" else ""
    if isinstance(cand, ShardedSchedule):
        return f"{cand.strategy}:{tag}{blocks}"
    return f"{tag}{blocks}"


# ---------------------------------------------------------------------------
# tune / lookup / resolve
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TuneReport:
    """One :func:`tune` outcome: the winning schedule, what was measured
    (``(label, us, modeled_words)`` rows, empty on a cache replay), and
    whether it came from the cache without timing."""

    key: str
    schedule: Schedule | ShardedSchedule
    measurements: tuple
    cached: bool


def _rebuild(op: str, shape: dict, rec: dict, machine: MachineModel,
             mesh, axis: str):
    """Reconstruct a cached winner through the planner: strategy + block
    pins (and, for two-level planners, the algorithm tag) re-planned so
    every model field is exact (not deserialized)."""
    blocks = {str(k): int(v) for k, v in rec.get("blocks", {}).items()}
    strategy = rec.get("strategy")
    kwargs = {**shape, **blocks}
    alg = rec.get("algorithm")
    if alg and alg != "direct":
        # Non-default family must be pinned explicitly; "direct" winners
        # need no pin (their block_do/di pins already imply the family),
        # which keeps pre-tag records and non-conv planners untouched.
        kwargs["algorithm"] = str(alg)
    planner = planner_for(op, machine, mesh, axis,
                          strategy if mesh is not None else None)
    return planner.plan(**kwargs)


def tune(
    op, *, machine: MachineModel = TPU_V5E, mesh=None, axis: str = "model",
    strategy: str | None = None, topk: int = 4, iters: int = 3,
    warmup: int = 1, dtype=None, cache: AutotuneCache | None = None,
    run_mesh=None, force: bool = False, **shape,
) -> TuneReport:
    """Measure the top-``topk`` candidate Schedules of one cell and cache
    the winner.

    ``op`` is a registered ``pallas_op`` name (or handle); ``**shape``
    are its planner's keyword shapes (what ``PallasOp.shape_args``
    produces).  Candidates come from ``planner.candidates()`` ranked by
    modeled words; a cached winner short-circuits unless ``force=``.
    ``run_mesh`` (a live ``jax.sharding.Mesh``) executes multi-device
    strategies for real; without one they time through the per-device
    proxy protocol.  Returns a :class:`TuneReport`.
    """
    global _TUNING
    from repro.plan.registry import get_op

    opo = get_op(op) if isinstance(op, str) else op
    if cache is None:  # NB: an empty cache is falsy (len 0) but valid
        cache = get_cache()
    ms = mesh_spec(mesh) if mesh is not None else None
    dt = _dtype_for(dtype, shape.get("in_bytes"))
    readable, digest = cache_key(opo.name, shape, dt, machine, ms, axis,
                                 strategy)
    if not force:
        rec = cache.get(digest)
        if rec is not None:
            return TuneReport(
                key=digest,
                schedule=_rebuild(opo.name, shape, rec, machine, ms, axis),
                measurements=tuple(tuple(m) for m in rec.get("measured", ())),
                cached=True)

    planner = planner_for(opo.name, machine, ms, axis, strategy)
    cands = planner.candidates(**shape)[: max(1, topk)]
    arrays, params = synthesize(opo.name, shape, dt)
    measured, timed = [], []
    _TUNING = True
    try:
        for c in cands:
            us = _time_candidate(opo, arrays, params, c, machine, run_mesh,
                                 iters, warmup)
            measured.append((_label(c), us, c.modeled_words))
            timed.append((us, c))
    finally:
        _TUNING = False
    us, winner = min(timed, key=lambda t: t[0])
    record = {
        "op": opo.name,
        "strategy": winner.strategy if isinstance(winner, ShardedSchedule)
        else None,
        "algorithm": getattr(local_schedule(winner), "algorithm", "direct"),
        "blocks": dict(local_schedule(winner).blocks),
        "us": us,
        "modeled_words": winner.modeled_words,
        "measured": [list(m) for m in measured],
    }
    cache.put(digest, readable, record)
    return TuneReport(key=digest, schedule=winner,
                      measurements=tuple(measured), cached=False)


def lookup(
    op: str, shape: dict, *, machine: MachineModel = TPU_V5E, mesh=None,
    axis: str = "model", strategy: str | None = None,
    cache: AutotuneCache | None = None, dtype=None,
) -> Schedule | ShardedSchedule | None:
    """The cached winner of one cell, rebuilt through the planner — or
    ``None`` on a miss.  Never times anything (``cache-only`` safe)."""
    if cache is None:  # NB: an empty cache is falsy (len 0) but valid
        cache = get_cache()
    ms = mesh_spec(mesh) if mesh is not None else None
    dt = _dtype_for(dtype, shape.get("in_bytes"))
    readable, digest = cache_key(op, shape, dt, machine, ms, axis, strategy)
    memo = cache._memo
    if digest in memo:
        return memo[digest]
    rec = cache.get(digest)
    if rec is None:
        return None
    try:
        sched = _rebuild(op, shape, rec, machine, ms, axis)
    except ValueError as e:
        # Only the *expected* failure — a stale pin the planner now
        # rejects (renamed knob, retired strategy, algorithm/pin clash)
        # — degrades to the modeled argmin, and says so once per cell
        # with the full cell key.  Anything else is a genuine planner
        # bug and propagates: a bare except here silently masked those.
        _warn_once(digest,
                   f"autotune cache entry for {op!r} unusable ({e}); "
                   f"cell {readable}; falling back to the modeled argmin")
        return None
    memo[digest] = sched
    return sched


def tuned_schedule(
    op: str, shape: dict, *, machine: MachineModel = TPU_V5E, mesh=None,
    axis: str = "model", strategy: str | None = None,
    policy: str | None = None, cache: AutotuneCache | None = None,
    dtype=None,
) -> Schedule | ShardedSchedule | None:
    """The autotune override for one resolution, or ``None`` when the
    modeled argmin should stand: policy "off" (or reentrant tuning) is
    always ``None``; "cache-only" is lookup-only; "tune" measures on a
    miss (synthesized operands — safe even while tracing, since the
    timing runs eagerly on its own arrays) but never raises."""
    pol = policy or _POLICY
    if pol == "off" or _TUNING:
        return None
    if pol not in POLICIES:
        raise ValueError(f"autotune policy must be one of {POLICIES}, "
                         f"got {pol!r}")
    got = lookup(op, shape, machine=machine, mesh=mesh, axis=axis,
                 strategy=strategy, cache=cache, dtype=dtype)
    if got is not None or pol == "cache-only":
        return got
    try:
        return tune(op, machine=machine, mesh=mesh, axis=axis,
                    strategy=strategy, cache=cache, dtype=dtype,
                    **shape).schedule
    except ValueError as e:
        # Same contract as lookup(): only the planner's expected shape/pin
        # rejection degrades (once per cell, with the cell key); a missing
        # synthesizer, a kernel crash, a broken cache write all re-raise —
        # the old bare except turned every such bug into a silent slowdown.
        ms = mesh_spec(mesh) if mesh is not None else None
        dt = _dtype_for(dtype, shape.get("in_bytes"))
        readable, digest = cache_key(op, shape, dt, machine, ms, axis,
                                     strategy)
        _warn_once(digest,
                   f"autotuning {op!r} failed ({e}); cell {readable}; "
                   "falling back to the modeled argmin")
        return None


def resolve(
    op: str, shape: dict, *, machine: MachineModel = TPU_V5E, mesh=None,
    axis: str = "model", strategy: str | None = None,
    policy: str | None = None, cache: AutotuneCache | None = None,
    dtype=None,
) -> Schedule | ShardedSchedule:
    """Policy-aware schedule resolution (what every ``plan`` helper and
    the op registry route through): a cached/measured winner when the
    policy provides one, else the planner's modeled argmin."""
    got = tuned_schedule(op, shape, machine=machine, mesh=mesh, axis=axis,
                         strategy=strategy, policy=policy, cache=cache,
                         dtype=dtype)
    if got is not None:
        return got
    return planner_for(op, machine, mesh, axis, strategy).plan(**shape)


def warm(
    cells: dict, *, machine: MachineModel = TPU_V5E, mesh=None,
    axis: str = "model", policy: str | None = None,
    cache: AutotuneCache | None = None, dtype=None,
) -> tuple[dict, dict]:
    """Boot-time (warmup) resolution of a *named set* of cells — the
    serving path (``repro.serve.BucketLadder.warmup``) resolves every
    bucket's prefill/decode schedules here, once, so the request path
    never plans, times, or traces a new shape.

    ``cells`` maps ``name -> (op_name, planner_shape)``.  Returns
    ``(plans, sources)``: the resolved ``Schedule``/``ShardedSchedule``
    per name, and each cell's provenance — ``"cached"`` (replayed from
    the winner cache without timing), ``"tuned"`` (measured this boot
    under policy "tune"), or ``"modeled"`` (the planner's modeled
    argmin: policy "off", a cache-only miss, or a tune that failed and
    fell back).  Production boots run ``policy="cache-only"``: every
    cell is then cached-or-modeled and nothing is ever timed."""
    pol = policy or _POLICY
    if pol not in POLICIES:
        raise ValueError(f"autotune policy must be one of {POLICIES}, "
                         f"got {pol!r}")
    plans: dict = {}
    sources: dict[str, str] = {}
    for name, (op, shape) in cells.items():
        def _hit():
            return lookup(op, shape, machine=machine, mesh=mesh, axis=axis,
                          cache=cache, dtype=dtype) is not None

        pre = pol != "off" and _hit()
        plans[name] = resolve(op, shape, machine=machine, mesh=mesh,
                              axis=axis, policy=pol, cache=cache, dtype=dtype)
        if pre:
            sources[name] = "cached"
        elif pol == "tune" and _hit():
            sources[name] = "tuned"
        else:
            sources[name] = "modeled"
    return plans, sources


# ---------------------------------------------------------------------------
# CLI: the tier1.sh --autotune-smoke gate and ad-hoc cell tuning
# ---------------------------------------------------------------------------


def _smoke() -> int:
    """Tune one tiny conv cell, one FC cell, one fused-epilogue dgrad
    cell (pooled cotangent + mask residual), and one two-algorithm
    MANTICORE conv cell (interpret mode) against
    a throwaway cache (a configured cache — $REPRO_AUTOTUNE_CACHE or
    --cache — is honored, but is *cleared of the smoke cells first* so
    the tune-then-replay assertion stays idempotent), then assert both
    winners replay from it.  Never touches the default user cache."""
    import tempfile

    if _CACHE_PATH or os.environ.get("REPRO_AUTOTUNE_CACHE"):
        cache = get_cache()
    else:
        cache = AutotuneCache(os.path.join(tempfile.mkdtemp(), "autotune.json"))
    cells = [
        ("conv2d", dict(H_O=8, W_O=8, F=3, S=1, d_in=8, d_out=16,
                        in_bytes=4, padding=1, batch=2, pool=2)),
        ("matmul", dict(m=16, n=256, k=64, in_bytes=4)),
        # Fused-epilogue backward cell: pool in the shape makes the dgrad
        # planner default to the fused_epilogue variant, and synthesize()
        # hands the kernel the pooled cotangent + int8 mask residual — the
        # fused-bwd path tunes on its real input signature.
        ("conv2d_dgrad", dict(H_O=8, W_O=8, F=3, S=1, P=1, d_in=8,
                              d_out=16, in_bytes=4, batch=2, pool=2)),
    ]
    print("op,us,cached,blocks")
    for op, shape in cells:
        first = tune(op, topk=3, iters=1, warmup=1, cache=cache,
                     force=True, **shape)
        replay = tune(op, topk=3, iters=1, warmup=1, cache=cache, **shape)
        assert not first.cached and replay.cached, (
            f"{op}: expected tune-then-replay, got cached="
            f"{first.cached},{replay.cached}")
        a, b = local_schedule(first.schedule), local_schedule(replay.schedule)
        assert a.blocks == b.blocks and a.grid == b.grid, (
            f"{op}: cache replay diverged: {a} vs {b}")
        for label, us, words in first.measurements:
            print(f"{op}:{label},{us:.1f},False,words={words}")
        print(f"{op}:winner,{dict(b.blocks)},True,"
              f"replayed_from={cache.path}")

    # Two-algorithm cell: the MANTICORE deep-channel 1x1 stride-2 shape
    # sits at the algorithm crossover, so the candidate list must span
    # both families and the winner's algorithm tag must survive the
    # cache replay (the two-level argmin's whole point).
    from repro.core.machine import MANTICORE

    xshape = dict(H_O=7, W_O=7, F=1, S=2, d_in=512, d_out=256, in_bytes=4)
    first = tune("conv2d", machine=MANTICORE, topk=6, iters=1, warmup=1,
                 cache=cache, force=True, **xshape)
    labels = [m[0] for m in first.measurements]
    assert any(lbl.startswith("im2col:") for lbl in labels) and any(
        not lbl.startswith("im2col:") for lbl in labels), (
        f"conv2d[manticore]: expected candidates from both algorithm "
        f"families, got {labels}")
    replay = tune("conv2d", machine=MANTICORE, topk=6, iters=1, warmup=1,
                  cache=cache, **xshape)
    assert not first.cached and replay.cached, "expected tune-then-replay"
    a, b = local_schedule(first.schedule), local_schedule(replay.schedule)
    assert (a.algorithm, a.blocks, a.grid) == (b.algorithm, b.blocks, b.grid), (
        f"conv2d[manticore]: algorithm-tagged replay diverged: {a} vs {b}")
    for label, us, words in first.measurements:
        print(f"conv2d[manticore]:{label},{us:.1f},False,words={words}")
    print(f"conv2d[manticore]:winner,{b.algorithm}:{dict(b.blocks)},True")
    print(f"autotune smoke ok ({len(cache)} cached cells)")
    return 0


def main(argv=None) -> int:
    import argparse

    from repro.core.machine import MACHINES

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny conv+fc tune against the configured cache; "
                         "assert the winners replay (CI gate)")
    ap.add_argument("--op", default=None, help="registered pallas_op name")
    ap.add_argument("--shape", default="",
                    help="comma-separated planner shapes, e.g. "
                         "m=32,n=4096,k=25088")
    ap.add_argument("--machine", default="tpu_v5e", choices=sorted(MACHINES))
    ap.add_argument("--mesh", default=None,
                    help="mesh axes, e.g. cluster=16 (model-side MeshSpec)")
    ap.add_argument("--axis", default=None,
                    help="partitioned mesh axis (default: first --mesh axis)")
    ap.add_argument("--topk", type=int, default=4)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--cache", default=None, help="cache file override")
    ap.add_argument("--force", action="store_true", help="re-measure")
    args = ap.parse_args(argv)

    if args.cache:
        set_policy(_POLICY if _POLICY in POLICIES else "off", args.cache)
    if args.smoke:
        return _smoke()
    if not args.op:
        ap.error("--op (or --smoke) required")
    shape = {}
    for tok in filter(None, args.shape.split(",")):
        k, _, v = tok.partition("=")
        shape[k.strip()] = int(v)
    mesh = axis = None
    if args.mesh:
        pairs = [tok.partition("=") for tok in args.mesh.split(",")]
        mesh = MeshSpec(tuple((k, int(v)) for k, _, v in pairs))
        axis = args.axis or mesh.axes[0][0]
    rep = tune(args.op, machine=MACHINES[args.machine], mesh=mesh,
               axis=axis or "model", topk=args.topk, iters=args.iters,
               warmup=args.warmup, force=args.force, **shape)
    print(f"cell {rep.key[:16]} cached={rep.cached}")
    for label, us, words in rep.measurements:
        print(f"  {label}: {us:.1f}us modeled_words={words}")
    w = rep.schedule
    strat = w.strategy if isinstance(w, ShardedSchedule) else "local"
    print(f"winner [{strat}] {dict(local_schedule(w).blocks)} -> "
          f"{get_cache().path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
