"""HLO-text cost analyzer with while-loop trip-count handling.

XLA's built-in HloCostAnalysis (what ``compiled.cost_analysis()`` reports on
the CPU backend) visits each while body ONCE, so lax.scan-based programs (layer
stacks, gradient accumulation, token chunks) under-report FLOPs/bytes by the
trip count.  This analyzer parses the optimized HLO text and aggregates
bottom-up:

  * dot/convolution FLOPs from operand/result shapes;
  * bytes accessed under an *ideal-fusion (TPU-like) model*: only ops that
    must touch HBM on a well-fused TPU program are charged — dot/conv
    operands+results (weight/activation streaming), gather/scatter
    (embeddings, MoE dispatch), dynamic-(update-)slice (KV caches), copy/
    transpose/concatenate materializations, and collective payloads.
    Elementwise/convert/broadcast chains are assumed fused (register/VMEM
    resident).  CPU-backend kLoop micro-fusions would otherwise inflate
    bytes by the fusion-chain depth; entry argument/output bytes are added
    separately by the caller (from compiled.memory_analysis());
  * collective result bytes per category (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute);
  * while bodies multiplied by ``known_trip_count`` backend_config
    annotations (scan loops carry them; unannotated loops count once and
    are reported in ``unknown_trip_whiles``).

All numbers are PER DEVICE: the post-SPMD module has shard shapes.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.+\s*\{\s*$")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_TRIP_RE = re.compile(r'known_trip_count[\\"]*\s*:\s*\{[\\"]*n[\\"]*:[\\"]*(\d+)')
_META_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "partition-id", "replica-id", "iota"}
# Ops charged HBM bytes under the ideal-fusion model (see module docstring).
# reduce/reduce-window/dynamic-slice/gather/scatter/DUS have special rules.
_HBM_OPS = {"dot", "convolution", "copy", "transpose", "concatenate",
            "sort", "reverse"}


def shape_dims(shape_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _num_elems(shape_str: str) -> int:
    total = 0
    for _, dims in shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    operands: list[str]
    attrs: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict | None = None
    unknown_trip_whiles: int = 0
    by_op: dict | None = None  # op -> [flops, bytes] attribution

    def __post_init__(self):
        if self.coll is None:
            self.coll = {k: 0.0 for k in COLLECTIVES}
        if self.by_op is None:
            self.by_op = {}

    def bump(self, op: str, flops: float = 0.0, bytes: float = 0.0):
        self.flops += flops
        self.bytes += bytes
        e = self.by_op.setdefault(op, [0.0, 0.0])
        e[0] += flops
        e[1] += bytes

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        for k in COLLECTIVES:
            self.coll[k] += mult * other.coll[k]
        for op, (f, b) in other.by_op.items():
            e = self.by_op.setdefault(op, [0.0, 0.0])
            e[0] += mult * f
            e[1] += mult * b
        self.unknown_trip_whiles += other.unknown_trip_whiles


def _balanced(text: str, start: int) -> int:
    """Index just past the ')' matching the '(' at ``start``."""
    depth = 0
    for j in range(start, len(text)):
        if text[j] == "(":
            depth += 1
        elif text[j] == ")":
            depth -= 1
            if depth == 0:
                return j + 1
    return len(text)


def _split_operands(line: str, op_end: int) -> tuple[list[str], str]:
    """Operand %names inside the first balanced (...) after the opcode."""
    i = line.find("(", op_end)
    if i < 0:
        return [], ""
    j = _balanced(line, i)
    inner = line[i + 1 : j - 1]
    attrs = line[j:]
    return re.findall(r"%([\w.\-]+)", inner), attrs


def parse_module(hlo_text: str) -> dict[str, list[Instr]]:
    comps: dict[str, list[Instr]] = {}
    entry_name = None
    cur: list[Instr] | None = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line)
        if m:
            cur = []
            comps[m.group(2)] = cur
            if m.group(1):
                entry_name = m.group(2)
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        mn = _NAME_RE.match(line)
        if not mn:
            continue
        name = mn.group(1)
        rest_at = mn.end()
        # Shape: either a (tuple ...) — may contain /*index=N*/ comments —
        # or a plain dtype[dims]{layout} token.
        if rest_at < len(line) and line[rest_at] == "(":
            shape_end = _balanced(line, rest_at)
        else:
            ms = re.match(r"\S+", line[rest_at:])
            if not ms:
                continue
            shape_end = rest_at + ms.end()
        shape = line[rest_at:shape_end]
        mo = _OPCODE_RE.match(line[shape_end:])
        if not mo:
            continue
        op = mo.group(1)
        operands, attrs = _split_operands(line, shape_end + mo.end())
        cur.append(Instr(name, shape, op, operands, attrs))
    comps["__entry__"] = comps.get(entry_name, [])
    return comps


def _dot_flops(instr: Instr, env: dict[str, str]) -> float:
    out_elems = _num_elems(instr.shape)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.attrs)
    lhs_shape = env.get(instr.operands[0]) if instr.operands else None
    if not m or not lhs_shape:
        return 2.0 * out_elems  # degenerate fallback
    dims = shape_dims(lhs_shape)
    if not dims:
        return 2.0 * out_elems
    lhs_dims = dims[0][1]
    contract = 1
    for c in m.group(1).split(","):
        if c:
            ci = int(c)
            if ci < len(lhs_dims):
                contract *= lhs_dims[ci]
    return 2.0 * out_elems * contract


def _conv_flops(instr: Instr, env: dict[str, str]) -> float:
    out_elems = _num_elems(instr.shape)
    rhs_shape = env.get(instr.operands[1]) if len(instr.operands) > 1 else None
    if not rhs_shape:
        return 2.0 * out_elems
    dims = shape_dims(rhs_shape)[0][1]
    # dim_labels ...->...: kernel = spatial dims * input features
    m = re.search(r"dim_labels=\w+_(\w+)->", instr.attrs)
    kernel_elems = 1
    if m:
        labels = m.group(1)  # e.g. 01io
        for ch, d in zip(labels, dims):
            if ch != "o":
                kernel_elems *= d
    else:
        kernel_elems = max(1, int(__import__("math").prod(dims)) // dims[-1])
    return 2.0 * out_elems * kernel_elems


def analyze(hlo_text: str) -> Cost:
    comps = parse_module(hlo_text)
    memo: dict[tuple, Cost] = {}

    def comp_cost(name: str, in_fusion: bool = False) -> Cost:
        key = (name, in_fusion)
        if key in memo:
            return memo[key]
        memo[key] = Cost()  # break cycles defensively
        total = Cost()
        env: dict[str, str] = {}
        for ins in comps.get(name, []):
            env[ins.name] = ins.shape
            op = ins.op
            if op in _META_OPS:
                continue
            if op == "while":
                body = cond = None
                mb = re.search(r"body=%?([\w.\-]+)", ins.attrs)
                mc = re.search(r"condition=%?([\w.\-]+)", ins.attrs)
                body = mb.group(1) if mb else None
                cond = mc.group(1) if mc else None
                mt = _TRIP_RE.search(ins.attrs)
                trip = int(mt.group(1)) if mt else 1
                if not mt:
                    total.unknown_trip_whiles += 1
                if body:
                    total.add(comp_cost(body, in_fusion), trip)
                if cond:
                    total.add(comp_cost(cond, in_fusion), trip + 1)
                continue
            if op == "conditional":
                branches = re.findall(r"%([\w.\-]+)", ins.attrs)
                best = Cost()
                for b in branches:
                    if b in comps:
                        c = comp_cost(b, in_fusion)
                        if c.flops + c.bytes > best.flops + best.bytes:
                            best = c
                total.add(best)
                continue
            if op == "call":
                m = re.search(r"to_apply=%?([\w.\-]+)", ins.attrs)
                if m:
                    total.add(comp_cost(m.group(1), in_fusion))
                continue
            if op == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", ins.attrs)
                if m:
                    # flops (+collectives) from inside; fusion-internal
                    # copies/slices stay in registers -> no HBM bytes.
                    total.add(comp_cost(m.group(1), in_fusion=True))
                continue

            ob = shape_bytes(ins.shape)
            base = op[:-6] if op.endswith("-start") else op
            if base in COLLECTIVES:
                total.coll[base] += ob
                total.bump(base, bytes=ob)  # payload also moves through HBM
                continue

            b = 0.0
            if op in ("reduce", "reduce-window"):
                # Reductions fuse into their producer's epilogue on TPU
                # (operand never round-trips HBM); charge the result only.
                b = ob
            elif op == "dynamic-slice":
                b = 2.0 * ob  # read the slice + write it; not the whole buffer
            elif op == "dynamic-update-slice":
                upd = shape_bytes(env.get(ins.operands[1], "")) if len(ins.operands) > 1 else 0
                b = 2.0 * upd  # in-place: read update + write region
            elif op == "gather":
                b = 2.0 * ob  # rows actually touched, not the whole table
            elif op == "scatter":
                upd = shape_bytes(env.get(ins.operands[-1], "")) if ins.operands else 0
                b = 2.0 * upd
            elif op in _HBM_OPS:
                b = ob + sum(shape_bytes(env.get(o, "")) for o in ins.operands)
            if in_fusion:
                b = 0.0  # fused ops live in registers/VMEM
            if op == "dot":
                total.bump(op, _dot_flops(ins, env), b)
            elif op == "convolution":
                total.bump(op, _conv_flops(ins, env), b)
            elif op in ("add", "subtract", "multiply", "divide", "maximum",
                        "minimum", "compare", "select", "exponential", "tanh",
                        "log", "rsqrt", "sqrt", "power", "negate", "abs",
                        "floor", "ceil", "cosine", "sine", "and", "or", "xor"):
                total.bump(op, _num_elems(ins.shape), b)
            elif op == "reduce":
                # ~1 flop per input element reduced
                total.bump(op, sum(_num_elems(env.get(o, "")) for o in ins.operands[: len(ins.operands) // 2]), b)
            elif b:
                total.bump(op, 0.0, b)
        memo[key] = total
        return total

    return comp_cost("__entry__")
