"""Render EXPERIMENTS.md roofline/dry-run tables from experiments/dryrun.json.

    PYTHONPATH=src python -m repro.analysis.report experiments/dryrun.json
"""

from __future__ import annotations

import json
import sys


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}EB"


def dryrun_table(results: dict, mesh: str) -> str:
    lines = [
        "| arch | shape | compile s | bytes/device (args+temp) | HLO FLOPs | HBM bytes | collective bytes |",
        "|---|---|---|---|---|---|---|",
    ]
    for key in sorted(results):
        r = results[key]
        if r.get("mesh") != mesh:
            continue
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | FAIL | {r.get('error','')[:60]} | | | |")
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compile_seconds']} | "
            f"{fmt_bytes(r['bytes_per_device'])} | {rf['flops']:.3e} | "
            f"{rf['bytes_hbm']:.3e} | {rf['bytes_coll']:.3e} |"
        )
    return "\n".join(lines)


def roofline_table(results: dict, mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | t_compute s | t_memory s | t_collective s | bound | MODEL_FLOPS | useful ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for key in sorted(results):
        r = results[key]
        if not r.get("ok") or r.get("mesh") != mesh:
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['t_compute']:.3e} | "
            f"{rf['t_memory']:.3e} | {rf['t_collective']:.3e} | "
            f"**{rf['bottleneck']}** | {rf['model_flops']:.2e} | "
            f"{rf['useful_ratio']:.3f} | {rf['roofline_fraction']:.4f} |"
        )
    return "\n".join(lines)


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun.json"
    with open(path) as f:
        results = json.load(f)
    print("## Dry-run (single-pod 16x16 = 256 chips)\n")
    print(dryrun_table(results, "16x16"))
    print("\n## Dry-run (multi-pod 2x16x16 = 512 chips)\n")
    print(dryrun_table(results, "2x16x16"))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table(results, "16x16"))


if __name__ == "__main__":
    main()
