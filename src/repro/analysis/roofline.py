"""Roofline-term extraction from compiled (dry-run) programs.

  compute    = HLO_FLOPs  / (chips * 197e12 bf16 FLOP/s)
  memory     = HLO_bytes  / (chips * 819e9 B/s HBM)
  collective = coll_bytes / (chips * 50e9 B/s ICI link)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``.
``coll_bytes`` is parsed from the optimized HLO text: the summed *result*
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (result size ~ bytes received per device for AG/AR;
a consistent, reproducible proxy).  MODEL_FLOPS uses 6*N*D (train) or
2*N*D (serve) with N = active body parameters, so the
MODEL_FLOPS/HLO_FLOPs ratio exposes remat/dispatch overhead.
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 per chip (TPU v5e)
HBM_BW = 819e9  # B/s per chip
LINK_BW = 50e9  # B/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLL = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")
# "= <shape or (tuple)> <collective-op>(" — skip async -done halves.
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-category result bytes of collective ops in (optimized) HLO."""
    out = {k: 0 for k in _COLL}
    for m in _OP_RE.finditer(hlo_text):
        out[m.group(2)] += _shape_bytes(m.group(1))
    return out


@dataclasses.dataclass
class Roofline:
    flops: float  # total HLO flops (all devices)
    bytes_hbm: float  # total HLO bytes accessed
    bytes_coll: float  # summed collective result bytes (all devices)
    chips: int
    model_flops: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.bytes_hbm / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.bytes_coll / (self.chips * LINK_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """MODEL_FLOPS/(chips*peak) over the bound time: the MFU this
        program could at best sustain given its dominant roofline term."""
        if not self.t_bound:
            return 0.0
        return (self.model_flops / (self.chips * PEAK_FLOPS)) / self.t_bound

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "bytes_hbm": self.bytes_hbm,
            "bytes_coll": self.bytes_coll, "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(kind: str, n_active_params: int, tokens: int) -> float:
    if kind == "train":
        return 6.0 * n_active_params * tokens
    return 2.0 * n_active_params * tokens  # prefill / decode forward


def from_compiled(compiled, kind: str, n_active: int, tokens: int, chips: int) -> Roofline:
    """All three terms from the post-SPMD (per-device) module via the
    trip-count-aware analyzer in hlo_cost.py; values are scaled back to
    all-device totals (x chips) so Roofline terms divide consistently."""
    from repro.analysis import hlo_cost

    cost = hlo_cost.analyze(compiled.as_text())
    coll = sum(cost.coll.values())
    io_bytes = 0.0
    mem = compiled.memory_analysis()
    if mem is not None:  # entry args + outputs stream HBM once
        io_bytes = float(getattr(mem, "argument_size_in_bytes", 0)
                         + getattr(mem, "output_size_in_bytes", 0))
    return Roofline(
        flops=cost.flops * chips, bytes_hbm=(cost.bytes + io_bytes) * chips,
        bytes_coll=coll * chips, chips=chips,
        model_flops=model_flops(kind, n_active, tokens),
    )
