"""Benchmark harness: one section per paper table/analysis.

  conv_ccr     - paper Sec. 2.1.4 / 2.2.4 / 2.3.4 numeric intuitions (Algs 1-3)
  fc_ccr       - paper Sec. 3.1.4 / 3.2.4 numeric intuitions (Algs 4-5)
  kernels      - wall-time microbenches of the Pallas kernels vs refs (CPU
                 interpret mode: correctness-path timing, not TPU perf)
  conv_fused   - batched-grid + fused-epilogue conv pipeline vs the seed
                 vmap-per-image + XLA-epilogue path (parity + wall time;
                 BENCH_conv.json holds the committed baseline)
  fc_matmul    - planner-scheduled FC matmul vs a naive block_n=128 blocking
                 (parity + wall time + modeled words; BENCH_fc.json holds
                 the committed baseline)
  conv_algos   - cross-algorithm conv planning: the two-level
                 algorithm x blocking argmin's crossover on MANTICORE —
                 a deep-channel 1x1 stride-2 layer (im2col-GEMM wins) vs
                 an early wide-plane 3x3 layer (direct strip wins); both
                 kernels parity-asserted, both families' modeled words
                 gated (merges into BENCH_conv.json)
  conv_bwd     - planned backward conv kernels (dgrad strip conv + wgrad
                 accumulation) vs jax.grad of the XLA reference (parity +
                 wall time + modeled words; BENCH_bwd.json baseline)
  fc_bwd       - planned dX/dW matmul kernels vs jax.grad of the XLA
                 reference (same; shares BENCH_bwd.json)
  fc_sharded   - sharded FC through the plan layer: psum/ring strategies
                 executed on the 1-device mesh + the mesh-aware planner's
                 modeled HBM/ICI split for 4-way and the paper's quadrant
                 (BENCH_shard.json baseline)
  transformer  - the transformer wing through the plan layer: one tiny
                 planned train step (block GEMMs + flash attention +
                 planned dX/dW) parity-asserted vs the XLA path, plus the
                 quadrant's per-cell TP-vs-batch and MoE EP-vs-batch word
                 accounting (BENCH_tfm.json baseline)
  serve        - the serving engine under seeded Poisson load at three
                 offered-QPS levels on a virtual clock: p50/p99 latency +
                 throughput report-only, deterministic dispatched-token
                 counts gated (BENCH_serve.json baseline)
  smoke        - one tiny planner+kernel case per registered op, interpret
                 mode, parity-asserted (scripts/tier1.sh --bench-smoke)
  schedule_sim - closed forms vs executed-schedule word counts
  roofline     - per-cell roofline terms from experiments/dryrun.json

Measured time comes with the plan layer's model: rows that run a planned
kernel report ``schedule.modeled_words`` (and its roofline t_memory via
repro.plan.to_roofline) alongside ``us_per_call``.

Prints ``name,us_per_call,derived`` CSV rows as required.
"""

from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, iters=3, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / iters * 1e6  # us


_FORCE_BASELINE = False  # set by main() via --write-baseline


def _write_baseline(rows, filename, force=False):
    """Commit ``rows`` as <repo>/<filename> unless a baseline already
    exists (so committed baselines stay stable across reruns; refresh
    with ``benchmarks/run.py <section> --write-baseline``)."""
    path = os.path.join(os.path.dirname(__file__), "..", filename)
    if force or _FORCE_BASELINE or not os.path.exists(path):
        with open(path, "w") as fh:
            json.dump({n: {"us_per_call": us, "derived": d} for n, us, d in rows},
                      fh, indent=2)


def _merge_baseline(rows, filename, force=False):
    """Like :func:`_write_baseline` but merges into an existing file —
    several sections (conv_bwd + fc_bwd) share one committed baseline."""
    path = os.path.join(os.path.dirname(__file__), "..", filename)
    data = {}
    if os.path.exists(path):
        with open(path) as fh:
            data = json.load(fh)
    for n, us, d in rows:
        if force or _FORCE_BASELINE or n not in data:
            data[n] = {"us_per_call": us, "derived": d}
    with open(path, "w") as fh:
        json.dump(data, fh, indent=2)


def bench_conv_ccr():
    from repro.core import ccr
    from repro.core.machine import MANTICORE

    s = ccr.ConvShape(W_I=32, D_I=128, D_O=128, F=3, S=1, P=1)
    rows = []
    t0 = time.perf_counter()
    a1 = ccr.alg1_traffic(s)
    rows.append(("conv_alg1_ccr_macword", a1.ccr, "paper:8.9"))
    for prec, want in (("sp", 141.8), ("dp", 87.8)):
        stack = ccr.alg2_max_stack(s, MANTICORE, prec)
        rows.append((f"conv_alg2_ccr_{prec}", ccr.alg2_traffic(s, stack).ccr,
                     f"paper:{want};stack={stack}"))
    for prec, want in (("sp", 541.4), ("dp", 540.6)):
        stack = ccr.alg3_max_stack(s, MANTICORE, prec)
        rows.append((f"conv_alg3_offchip_ccr_{prec}",
                     ccr.alg3_ccr_offchip_as_quoted(s, stack),
                     f"paper:{want};stack={stack}"))
        rows.append((f"conv_alg3_eq10_ccr_{prec}",
                     ccr.alg3_traffic(s, stack).ccr_offchip,
                     "faithful-eq10"))
    us = (time.perf_counter() - t0) * 1e6 / len(rows)
    return [(n, us, f"{v:.2f};{d}") for n, v, d in rows]


def bench_fc_ccr():
    from repro.core import ccr
    from repro.core.machine import MANTICORE

    rows = []
    t0 = time.perf_counter()
    for prec, do_max, want in (("sp", 768, 30.7), ("dp", 384, 29.5)):
        s = ccr.FCShape(W_I=7, D_I=512, D_O=do_max, B=32)
        cap = ccr.alg45_max_stack(s, MANTICORE, prec)
        rows.append((f"fc_alg4_ccr_{prec}", ccr.alg4_ccr(s),
                     f"paper:{want};do_max={cap}"))
    s = ccr.FCShape(W_I=7, D_I=512, D_O=4096, B=32)
    for prec, stack, want in (("sp", 768, 30.6), ("dp", 384, 29.5)):
        rows.append((f"fc_alg5_ccr_{prec}", ccr.alg5_ccr(s, stack),
                     f"paper:{want};stack={stack}"))
    us = (time.perf_counter() - t0) * 1e6 / len(rows)
    return [(n, us, f"{v:.2f};{d}") for n, v, d in rows]


def bench_schedule_sim():
    from repro.core import ccr
    from repro.core import schedule_sim as sim

    s = ccr.ConvShape(W_I=32, D_I=128, D_O=128, F=3, S=1, P=1)
    fc = ccr.FCShape(W_I=7, D_I=512, D_O=4096, B=32)
    rows = []
    t0 = time.perf_counter()
    pairs = [
        ("sim_alg1", sim.simulate_alg1(s), ccr.alg1_traffic(s)),
        ("sim_alg2", sim.simulate_alg2(s, 24), ccr.alg2_traffic(s, 24)),
        ("sim_alg3", sim.simulate_alg3(s, 23), ccr.alg3_traffic(s, 23)),
        ("sim_alg4", sim.simulate_alg4(fc), ccr.alg4_traffic(fc)),
        ("sim_alg5", sim.simulate_alg5(fc, 768), ccr.alg5_traffic(fc, 768)),
    ]
    us = (time.perf_counter() - t0) * 1e6 / len(pairs)
    for name, got, want in pairs:
        rows.append((name, us, f"match={got == want};ccr={got.ccr:.2f}"))
    return rows


def bench_kernels():
    from repro.kernels.conv2d import conv2d, conv2d_ref
    from repro.kernels.flash_attention import attention_ref, flash_attention
    from repro.kernels.matmul import fc_matmul, fc_matmul_ref

    rng = np.random.default_rng(0)
    rows = []

    x = jnp.asarray(rng.standard_normal((128, 256)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
    rows.append(("matmul_pallas_interp",
                 _time(lambda: fc_matmul(x, w, block_m=64, block_n=64, block_k=64)),
                 "alg5-kernel"))
    rows.append(("matmul_ref_xla", _time(lambda: fc_matmul_ref(x, w)), "oracle"))

    xi = jnp.asarray(rng.standard_normal((16, 16, 32)), jnp.float32)
    f = jnp.asarray(rng.standard_normal((3, 3, 32, 32)), jnp.float32)
    rows.append(("conv2d_pallas_interp",
                 _time(lambda: conv2d(xi, f, padding=1, block_do=16, block_di=16)),
                 "alg2-kernel"))
    rows.append(("conv2d_ref_xla",
                 _time(lambda: conv2d_ref(xi, f, padding=1)), "oracle"))

    q = jnp.asarray(rng.standard_normal((1, 4, 128, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.float32)
    rows.append(("flash_attn_pallas_interp",
                 _time(lambda: flash_attention(q, k, v, block_q=64, block_kv=64)),
                 "blockwise"))
    rows.append(("flash_attn_ref_xla",
                 _time(lambda: attention_ref(q, k, v)), "oracle"))
    return rows


def bench_conv_fused(write_baseline: bool = False):
    """Fused, batched-grid conv pipeline vs the seed-style path.

    seed path  : jax.vmap of a per-image kernel call, then bias + ReLU +
                 2x2 max-pool as separate XLA ops (HBM round-trip).
    fused path : one pallas_call, grid = (B, h_strips, do_stacks, di_steps),
                 epilogue fused into the kernel flush.
    CPU interpret-mode timing — relative ordering, not TPU perf.
    """
    from repro.kernels.conv2d import conv2d, conv2d_fused_ref

    B, H, DI, DO, F, P = 8, 12, 8, 16, 3, 1
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((B, H, H, DI)), jnp.float32)
    f = jnp.asarray(rng.standard_normal((F, F, DI, DO)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((DO,)), jnp.float32)
    blocks = dict(block_do=8, block_di=8)

    def xla_epilogue(y):
        y = jax.nn.relu(y + b)
        Bn, Hn, Wn, C = y.shape
        return y.reshape(Bn, Hn // 2, 2, Wn // 2, 2, C).max((2, 4))

    def seed_vmap():  # the pre-strip call path: per-image kernel + XLA tail
        y = jax.vmap(lambda xi: conv2d(xi, f, padding=P, block_h=H, **blocks))(x)
        return xla_epilogue(y)

    def batched_unfused():  # batched grid, epilogue still in XLA
        return xla_epilogue(conv2d(x, f, padding=P, block_h=H, **blocks))

    def fused_batched():  # the full tentpole path
        return conv2d(x, f, padding=P, bias=b, relu=True, pool=2,
                      block_h=4, **blocks)

    want = conv2d_fused_ref(x, f, b, padding=P, relu=True, pool=2)
    err = float(jnp.abs(fused_batched() - want).max() / jnp.abs(want).max())

    # The plan layer's model for the fused blocking, next to measured time.
    from repro.kernels.conv2d.ops import conv2d_op

    sched = conv2d_op.plan(x, f, b, padding=P, pool=2, block_h=4, **blocks)

    rows = []
    t_seed = _time(seed_vmap)
    t_unfused = _time(batched_unfused)
    t_fused = _time(fused_batched)
    rows.append(("conv_seed_vmap_xla_epilogue", t_seed, f"B={B};per-image+XLA-tail"))
    rows.append(("conv_batched_grid_unfused", t_unfused,
                 f"speedup_vs_seed={t_seed / t_unfused:.2f}x"))
    rows.append(("conv_batched_grid_fused", t_fused,
                 f"speedup_vs_seed={t_seed / t_fused:.2f}x;maxerr={err:.2e};"
                 f"modeled_words={sched.modeled_words}"))
    _write_baseline(rows, "BENCH_conv.json", write_baseline)
    return rows


def bench_conv_algos(write_baseline: bool = False):
    """Cross-algorithm conv planning: the two-level algorithm x blocking
    argmin's measured crossover.

    Two MANTICORE shapes pin it: a deep-channel 1x1 stride-2 layer where
    the patch matrix reads S^2 = 4x fewer input words than the direct
    kernel's full halo'd rows (im2col-GEMM wins), and an early wide-plane
    3x3 layer where the F*F = 9x patch read amplification buries it
    (direct strip wins).  Each case executes the argmin winner and the
    rival family's kernel (interpret mode) with parity vs the XLA
    reference; both families' modeled words gate through --check.
    """
    from repro.core.machine import MANTICORE
    from repro.kernels.conv2d.im2col import conv2d_im2col
    from repro.kernels.conv2d.ops import conv2d, conv_out_extent
    from repro.kernels.conv2d.ref import conv2d_fused_ref
    from repro.plan import planner_for

    rng = np.random.default_rng(13)
    planner = planner_for("conv2d", MANTICORE)
    rows = []
    cases = [
        ("deep_1x1_s2", dict(B=1, H=13, W=13, DI=512, DO=256, F=1, S=2, P=0)),
        ("wide_3x3_s1", dict(B=1, H=32, W=32, DI=3, DO=64, F=3, S=1, P=1)),
    ]
    for name, c in cases:
        x = jnp.asarray(
            rng.standard_normal((c["B"], c["H"], c["W"], c["DI"])), jnp.float32)
        f = jnp.asarray(
            rng.standard_normal((c["F"], c["F"], c["DI"], c["DO"])) * 0.05,
            jnp.float32)
        H_O = conv_out_extent(c["H"], c["P"], c["F"], c["S"])
        W_O = conv_out_extent(c["W"], c["P"], c["F"], c["S"])
        shape = dict(H_O=H_O, W_O=W_O, F=c["F"], S=c["S"], d_in=c["DI"],
                     d_out=c["DO"], in_bytes=4, batch=c["B"], padding=c["P"],
                     H_I=c["H"], W_I=c["W"])
        win = planner.plan(**shape)
        direct = planner.plan(**shape, algorithm="direct")
        im2col = planner.plan(**shape, algorithm="im2col")
        want = conv2d_fused_ref(x, f, stride=c["S"], padding=c["P"])
        got = conv2d(x, f, stride=c["S"], padding=c["P"], schedule=win)
        err = float(jnp.abs(got - want).max())
        assert err < 1e-4, f"conv_algos {name}: winner diverges ({err})"
        rival = conv2d_im2col(x, f, stride=c["S"], padding=c["P"],
                              schedule=im2col)
        err_r = float(jnp.abs(rival - want).max())
        assert err_r < 1e-4, f"conv_algos {name}: im2col diverges ({err_r})"
        t = _time(lambda: conv2d(x, f, stride=c["S"], padding=c["P"],
                                 schedule=win))
        rows.append((f"conv_algos_{name}", t,
                     f"pick={win.algorithm};"
                     f"direct_words={direct.modeled_words};"
                     f"im2col_words={im2col.modeled_words};"
                     f"winner_words={win.modeled_words};maxerr={err:.1e}"))
    _merge_baseline(rows, "BENCH_conv.json", write_baseline)
    return rows


def bench_fc_matmul(write_baseline: bool = False):
    """Planner-scheduled FC matmul vs a naive fixed blocking.

    planner path : MatmulPlanner grows block_n (the Delta_O output stack)
                   to the VMEM budget, so X re-streams fewer times.
    naive path   : block_n = 128 (one lane), maximal X re-streaming.
    CPU interpret-mode timing — relative ordering, not TPU perf.  Each row
    reports the schedule's modeled HBM words and its roofline memory term.
    """
    from repro.core.machine import TPU_V5E
    from repro.kernels.matmul import fc_matmul, fc_matmul_ref
    from repro.kernels.matmul.ops import matmul_op
    from repro.plan import to_roofline

    M, K, N = 64, 512, 1024
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)

    s_plan = matmul_op.plan(x, w)
    s_naive = matmul_op.plan(x, w, block_n=128)
    want = fc_matmul_ref(x, w)

    def planned():
        return fc_matmul(x, w, schedule=s_plan)

    def naive():
        return fc_matmul(x, w, schedule=s_naive)

    err = float(jnp.abs(planned() - want).max() / jnp.abs(want).max())
    t_naive = _time(naive)
    t_plan = _time(planned)
    rows = []
    for name, t, s, extra in (
        ("fc_naive_bn128", t_naive, s_naive, ""),
        ("fc_planner", t_plan, s_plan,
         f";speedup_vs_naive={t_naive / t_plan:.2f}x;maxerr={err:.2e}"),
    ):
        bn = s.block_dict()["block_n"]
        tmem = to_roofline(s).t_memory
        rows.append((name, t,
                     f"block_n={bn};modeled_words={s.modeled_words};"
                     f"t_mem={tmem:.2e}s;fits={s.fits(TPU_V5E)}{extra}"))
    _write_baseline(rows, "BENCH_fc.json", write_baseline)
    return rows


def bench_conv_bwd(write_baseline: bool = False):
    """Planned backward conv kernels vs jax.grad of the XLA reference.

    planned path : jax.grad through conv_block saves the fused forward's
                   int8 epilogue-VJP mask, scatters the pooled cotangent
                   through it, and runs the conv2d_dgrad (fused_epilogue,
                   double-buffered DMA pipeline) and conv2d_wgrad
                   (pipelined) kernels — no recompute conv
                   (recompute_words=0).
    ref path     : jax.grad of the conv2d_fused_ref composition (XLA).
    The per-kernel tokens time the dgrad/wgrad kernels and the epilogue
    scatter in isolation on the same operands the layer backward sees.
    CPU interpret-mode timing — relative ordering, not TPU perf.
    """
    from repro.core import ccr
    from repro.core.conv_layer import conv_block, plan_bwd
    from repro.kernels.conv2d.bwd import (
        conv2d_dgrad, conv2d_wgrad, epilogue_scatter)
    from repro.kernels.conv2d.ops import conv2d_with_mask, conv_out_extent
    from repro.kernels.conv2d.ref import conv2d_fused_ref

    B, H, DI, DO, F, P = 4, 12, 8, 16, 3, 1
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((B, H, H, DI)), jnp.float32)
    f = jnp.asarray(rng.standard_normal((F, F, DI, DO)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((DO,)), jnp.float32)
    bwd = plan_bwd(x.shape, f.shape, stride=1, padding=P, pool=2)

    planned = jax.jit(jax.grad(
        lambda x, f, b: conv_block(x, f, b, 1, P, 2, "strip").sum(),
        argnums=(0, 1, 2)))
    ref = jax.jit(jax.grad(
        lambda x, f, b: conv2d_fused_ref(x, f, b, stride=1, padding=P,
                                         relu=True, pool=2).sum(),
        argnums=(0, 1, 2)))

    gp, gr = planned(x, f, b), ref(x, f, b)
    err = max(float(jnp.abs(a - r).max()) for a, r in zip(gp, gr))
    assert err < 1e-4, f"planned conv backward diverges ({err})"

    t_ref = _time(lambda: ref(x, f, b))
    t_plan = _time(lambda: planned(x, f, b))

    # Per-kernel breakdown on the exact operands the layer backward sees.
    out, mask = conv2d_with_mask(x, f, bias=b, stride=1, padding=P, pool=2)
    dy = jnp.ones_like(out)
    dg = jax.jit(lambda dy, f, mask: conv2d_dgrad(
        dy, f, stride=1, padding=P, out_hw=(H, H), mask=mask, pool=2,
        schedule=bwd["dgrad"], out_dtype=jnp.float32))
    wg = jax.jit(lambda x, dy, mask: conv2d_wgrad(
        x, dy, F=F, stride=1, padding=P, mask=mask, pool=2,
        schedule=bwd["wgrad"], out_dtype=jnp.float32))
    ep = jax.jit(lambda dy, mask: epilogue_scatter(dy, mask, 2))
    t_dg = _time(lambda: dg(dy, f, mask))
    t_wg = _time(lambda: wg(x, dy, mask))
    t_ep = _time(lambda: ep(dy, mask))
    H_O = conv_out_extent(H, P, F, 1)
    sc = ccr.epilogue_scatter_traffic(H_O=H_O, W_O=H_O, d_out=DO, pool=2,
                                      batch=B)
    words = {k: s.modeled_words for k, s in bwd.items()}
    rows = [
        ("conv_bwd_ref_xla", t_ref, f"B={B};jax.grad-of-fused-ref"),
        ("conv_bwd_planned", t_plan,
         f"speedup_vs_ref={t_ref / t_plan:.2f}x;maxerr={err:.2e};"
         f"dgrad_us={t_dg:.1f};wgrad_us={t_wg:.1f};epilogue_us={t_ep:.1f};"
         f"dgrad_words={words['dgrad']};wgrad_words={words['wgrad']};"
         f"epilogue_words={sc.main_loads + sc.main_stores};"
         f"recompute_words={words.get('recompute', 0)}"),
    ]
    _merge_baseline(rows, "BENCH_bwd.json", write_baseline)
    return rows


def bench_fc_bwd(write_baseline: bool = False):
    """Planned dX/dW matmul kernels vs jax.grad of the XLA reference.

    plan_bwd's "dx" cell prefers the fused dX/dW kernel (one kernel, one
    dY stream feeding both contractions — ``dx_alg=fused_dxdw``); the
    per-kernel tokens time the split dX/dW kernels and the fused pair on
    identical operands so the crossover is visible in one row.  CPU
    interpret-mode timing.
    """
    from repro.core.fc_layer import fc_layer, plan_bwd
    from repro.kernels.matmul.bwd import matmul_dw, matmul_dx, matmul_dx_dw
    from repro.kernels.matmul.ref import fc_matmul_ref
    from repro.plan import get_op

    M, K, N = 64, 512, 1024
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((K, N)) * 0.05, jnp.float32)
    bwd = plan_bwd(x.shape, w.shape)

    planned = jax.jit(jax.grad(
        lambda x, w: (fc_layer(x, w, None, bwd) ** 2).sum(), argnums=(0, 1)))
    ref = jax.jit(jax.grad(
        lambda x, w: (fc_matmul_ref(x, w) ** 2).sum(), argnums=(0, 1)))

    gp, gr = planned(x, w), ref(x, w)
    err = max(float(jnp.abs(a - r).max() / jnp.abs(r).max())
              for a, r in zip(gp, gr))
    assert err < 1e-4, f"planned fc backward diverges ({err})"

    t_ref = _time(lambda: ref(x, w))
    t_plan = _time(lambda: planned(x, w))

    # Split vs fused on the same cotangent the layer backward sees.
    g = jnp.asarray(rng.standard_normal((M, N)), jnp.float32)
    s_dx_split = get_op("matmul_dx").plan(g, w)
    dx_k = jax.jit(lambda g, w: matmul_dx(g, w, schedule=s_dx_split,
                                          out_dtype=jnp.float32))
    dw_k = jax.jit(lambda x, g: matmul_dw(x, g, schedule=bwd["dw"],
                                          out_dtype=jnp.float32))
    dxdw_k = jax.jit(lambda g, w, x: matmul_dx_dw(
        g, w, x, schedule=bwd["dx"], out_dtype=jnp.float32))
    t_dx = _time(lambda: dx_k(g, w))
    t_dw = _time(lambda: dw_k(x, g))
    t_dxdw = _time(lambda: dxdw_k(g, w, x))
    alg = getattr(bwd["dx"], "algorithm", None) or "direct"
    rows = [
        ("fc_bwd_ref_xla", t_ref, f"M={M};K={K};N={N};jax.grad-of-ref"),
        ("fc_bwd_planned", t_plan,
         f"speedup_vs_ref={t_ref / t_plan:.2f}x;maxrelerr={err:.2e};"
         f"dx_alg={alg};"
         f"dx_us={t_dx:.1f};dw_us={t_dw:.1f};dxdw_us={t_dxdw:.1f};"
         f"dx_words={bwd['dx'].modeled_words};"
         f"dx_stack={bwd['dx'].block('block_k')};"
         f"dw_words={bwd['dw'].modeled_words}"),
    ]
    _merge_baseline(rows, "BENCH_bwd.json", write_baseline)
    return rows


def bench_fc_sharded(write_baseline: bool = False):
    """Sharded FC through the plan layer (DESIGN.md Sec. 5).

    Executes the registry's sharded dispatch (psum and ring strategies) on
    the 1-device host mesh — the degenerate path every strategy must
    support — and reports the mesh-aware planner's *model* of the real
    meshes next to it: the 4-way host mesh the --dist-smoke tests force,
    and the paper's 16-cluster MANTICORE quadrant where the argmin picks
    Alg 3's ring over Alg 4's psum.  Rows carry hbm/ici modeled words;
    BENCH_shard.json is the committed baseline.
    """
    from repro.core.fc_layer import fc_layer_sharded
    from repro.core.machine import MANTICORE
    from repro.core.shard_compat import make_auto_mesh
    from repro.plan import MatmulPlanner, MeshSpec, get_op

    M, K, N = 32, 4096, 1024
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((K, N)) * 0.05, jnp.float32)
    want = np.asarray(x) @ np.asarray(w)
    mesh1 = make_auto_mesh((1,), ("model",))
    op = get_op("matmul")

    rows = []
    for strategy in ("psum", "ring"):
        ss1 = op.plan_sharded(x, w, mesh=mesh1, axis="model",
                              strategy=strategy)

        def run(ss=ss1):
            with mesh1:
                return fc_layer_sharded(x, w, mesh1, axis="model",
                                        schedule=ss)

        err = float(np.abs(np.asarray(run()) - want).max() / np.abs(want).max())
        t = _time(run)
        # The modeled 4-way split for the same shapes (what --dist-smoke
        # executes) — planning only, no devices touched.
        ss4 = op.plan_sharded(x, w, mesh=MeshSpec((("model", 4),)),
                              axis="model", strategy=strategy)
        rows.append((f"fc_sharded_{strategy}", t,
                     f"maxerr={err:.2e};1dev_strategy={ss1.strategy};"
                     f"hbm4={ss4.hbm_words};ici4={ss4.ici_words}"))

    # The paper quadrant: the planner's pick and the ring-vs-psum split.
    quad = MeshSpec((("cluster", 16),))
    auto = MatmulPlanner(MANTICORE, quad, "cluster").plan(
        m=32, n=4096, k=25088, in_bytes=4)
    psum = MatmulPlanner(MANTICORE, quad, "cluster", "psum").plan(
        m=32, n=4096, k=25088, in_bytes=4)
    rows.append(("fc_sharded_quadrant_pick", 0.0,
                 f"strategy={auto.strategy};hbm={auto.hbm_words};"
                 f"ici={auto.ici_words};psum_hbm={psum.hbm_words};"
                 f"psum_ici={psum.ici_words};"
                 f"hbm_saved={psum.hbm_words - auto.hbm_words}"))
    _write_baseline(rows, "BENCH_shard.json", write_baseline)
    return rows


def bench_transformer(write_baseline: bool = False):
    """The transformer wing through the plan layer (DESIGN.md Sec. 11).

    Executes one tiny planned transformer loss+grad step — every block
    GEMM through the planned fc_layer, attention through the planned
    flash kernel, planned dX/dW backward — parity-asserted against the
    XLA reference path, then reports the plan layer's *model* of the
    paper's quadrant next to it: the block planner's per-cell picks, the
    TP-vs-batch matmul trade at the small-m block shape, and the MoE
    FFN's EP-vs-batch all-to-all trade.  Every word count gates against
    BENCH_tfm.json.
    """
    import dataclasses

    from repro.configs.base import TrainConfig
    from repro.configs.registry import smoke_config
    from repro.core.machine import MANTICORE
    from repro.models import transformer as tf
    from repro.models.module import init_params
    from repro.plan import (
        MatmulPlanner, MeshSpec, MoeFfnPlanner, TransformerBlockPlanner,
    )
    from repro.runtime import train as tr

    cfg = smoke_config("qwen1.5-0.5b")
    cfg = dataclasses.replace(
        cfg, family="transformer", n_layers=2, d_model=64, vocab=128,
        d_ff=128, n_heads=4, n_kv_heads=4, head_dim=16)
    tcfg = TrainConfig(param_dtype="float32", compute_dtype="float32",
                       planned_kernels=True, loss_chunks=2, total_steps=2)
    params = init_params(tf.param_defs(cfg), jax.random.PRNGKey(0),
                         jnp.float32)
    B, S = 2, 32
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                     cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                     cfg.vocab),
    }
    grad_p = jax.jit(jax.value_and_grad(tr.make_loss_fn(cfg, tcfg)))
    grad_x = jax.jit(jax.value_and_grad(tr.make_loss_fn(
        cfg, dataclasses.replace(tcfg, planned_kernels=False))))
    lp, gp = grad_p(params, batch)
    lx, gx = grad_x(params, batch)
    assert abs(float(lp) - float(lx)) < 1e-4, "planned loss diverges"
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(gp), jax.tree.leaves(gx)))
    assert err < 1e-2, f"planned transformer grads diverge ({err})"
    t_p = _time(lambda: grad_p(params, batch), iters=1)
    t_x = _time(lambda: grad_x(params, batch), iters=1)
    sched = tf.plan_training(cfg, B, S, loss_chunks=tcfg.loss_chunks)
    step_words = sum(s.modeled_words for s in sched.values())
    rows = [("tfm_train_step_planned", t_p,
             f"xla_us={t_x:.1f};maxerr={err:.1e};cells={len(sched)};"
             f"modeled_step_words={step_words}")]

    # The paper quadrant: the block planner's per-cell argmin — each cell
    # delegated to its family planner (matmul/attention), every count a
    # ShardedSchedule's ccr closed form (walker-pinned in tests).
    quad = MeshSpec((("cluster", 16),))
    tb = TransformerBlockPlanner(MANTICORE, quad, "cluster")
    picks = tb.plan(batch=4, seq=128, d_model=256, n_heads=8, d_ff=1024,
                    vocab=1024, in_bytes=4)
    parts = []
    for name, s in picks.items():
        strat = getattr(s, "strategy", "single")
        ici = getattr(s, "ici_words", 0)
        parts.append(f"{name}={strat};{name}_words={s.modeled_words};"
                     f"{name}_ici_words={ici}")
    rows.append(("tfm_quadrant_block", 0.0, ";".join(parts)))

    # TP-vs-batch at the small-m decode-ish matmul (megatron column split
    # pays one activation all-gather; batch replicates the whole W), and
    # EP-vs-batch for the MoE FFN (EP pays the top_k all-to-all; batch
    # replicates every expert's weights).
    mm = MatmulPlanner(MANTICORE, quad, "cluster")
    mc = {c.strategy: c for c in mm.candidates(m=16, n=4096, k=4096,
                                               in_bytes=4)}
    moe = MoeFfnPlanner(MANTICORE, quad, "cluster")
    ec = {c.strategy: c for c in moe.candidates(
        tokens=4096, d_model=512, d_ff=2048, n_experts=16, top_k=2,
        in_bytes=4)}
    rows.append(("tfm_tp_ep_quadrant", 0.0,
                 f"tp_words={mc['tp'].modeled_words};"
                 f"tp_ici_words={mc['tp'].ici_words};"
                 f"mm_batch_words={mc['batch'].modeled_words};"
                 f"ep_words={ec['ep'].modeled_words};"
                 f"ep_ici_words={ec['ep'].ici_words};"
                 f"moe_batch_words={ec['batch'].modeled_words}"))
    _write_baseline(rows, "BENCH_tfm.json", write_baseline)
    return rows


def bench_serve(write_baseline: bool = False):
    """The serving subsystem under offered load (DESIGN.md Sec. 8).

    Boots the continuous-batching engine on the smoke config — a 2-bucket
    ladder whose prefill/decode schedules resolve once at warmup — and
    drives seeded Poisson traffic at three offered-QPS levels on a
    ``VirtualClock``: time advances by the ladder's *modeled* step seconds
    (schedule words over machine bandwidth), so batching composition,
    dispatched-token counts, and latency percentiles are deterministic.
    Latency/throughput are report-only; the ``*_words`` token-slot counts
    (prefill padding, true prompt tokens, decode slot-steps) gate against
    BENCH_serve.json — a regression there means the router pads more or
    the engine needs more steps for the same traffic.
    """
    from repro.configs.registry import smoke_config
    from repro.models.module import init_params
    from repro.models.registry import get_family
    from repro.serve import BucketLadder, Engine, LoadSpec, VirtualClock, run_load

    cfg = smoke_config("qwen3-1.7b")
    fam = get_family(cfg.family)
    params = init_params(fam.param_defs(cfg), jax.random.PRNGKey(0),
                        jnp.float32)
    buckets, max_seq = [(2, 8), (4, 16)], 24

    rows = []
    # One ladder-model row: the warmup-resolved schedules' modeled words
    # per bucket/phase (pure plan output — catches planner regressions
    # even before any traffic runs).
    plan_ladder = BucketLadder(buckets, max_seq=max_seq)
    plan_ladder.warmup(cfg, policy="off")
    parts = []
    for b in plan_ladder.buckets:
        for phase in ("prefill", "decode"):
            parts.append(f"b{b.batch}x{b.seq}_{phase}_words="
                         f"{plan_ladder.modeled_words(b, phase)}")
    rows.append(("serve_plan", 0.0, ";".join(parts)))

    for qps in (2_000, 20_000, 200_000):
        ladder = BucketLadder(buckets, max_seq=max_seq)
        engine = Engine(cfg, params, ladder, clock=VirtualClock(),
                        queue_depth=16)
        t0 = time.perf_counter()
        engine.warmup(policy="off")
        t_warm = (time.perf_counter() - t0) * 1e6  # boot cost, report-only
        spec = LoadSpec(qps=qps, n_requests=24, prompt_len=(3, 14),
                        new_tokens=(3, 6), seed=2)
        rep = run_load(engine, spec)
        s = engine.stats
        rows.append((
            f"serve_qps_{qps}", t_warm,
            f"qps={qps};completed={rep.completed};shed={rep.shed};"
            f"p50_us={rep.p50_s * 1e6:.1f};p99_us={rep.p99_s * 1e6:.1f};"
            f"ttft_p50_us={rep.ttft_p50_s * 1e6:.1f};"
            f"tok_s={rep.tokens_per_sec:.0f};"
            f"pad_pct={rep.padding_waste * 100:.1f};"
            f"steps={rep.engine_steps};"
            f"prefill_pad_words={s['prefill_padded']};"
            f"prefill_true_words={s['prefill_true']};"
            f"decode_slot_words={s['decode_slots']}"))
    _write_baseline(rows, "BENCH_serve.json", write_baseline)
    return rows


def bench_smoke():
    """One tiny planner+kernel case per registered op, parity-asserted
    against the op's registered XLA reference (the tier1.sh --bench-smoke
    gate — exercises `repro.plan.get_op` end to end)."""
    from repro.plan import get_op, registered_ops

    rng = np.random.default_rng(0)
    rows = []

    def case(name, args, ref_kw, kw=None, tol=2e-4):
        op = get_op(name)
        kw = kw or {}
        sched = op.plan(*args, **kw)
        t = _time(lambda: op(*args, schedule=sched, **kw), iters=1)
        got = op(*args, schedule=sched, **kw)
        want = op.reference(*args, **ref_kw)
        err = float(jnp.abs(jnp.asarray(got, jnp.float32)
                            - jnp.asarray(want, jnp.float32)).max())
        assert err < tol, f"{name}: planner-scheduled kernel diverges ({err})"
        rows.append((f"smoke_{name}", t,
                     f"modeled_words={sched.modeled_words};"
                     f"blocks={dict(sched.blocks)};maxerr={err:.1e}"))

    x = jnp.asarray(rng.standard_normal((8, 8, 4)), jnp.float32)
    f = jnp.asarray(rng.standard_normal((3, 3, 4, 4)), jnp.float32)
    b = jnp.zeros((4,), jnp.float32)
    case("conv2d", (x, f, b), dict(padding=1),
         kw=dict(padding=1, block_do=2, block_di=2, block_h=4))
    case("conv2d_im2col", (x, f, b), dict(padding=1),
         kw=dict(padding=1, block_h=4, block_m=8, block_n=8, block_k=8))

    dy = jnp.asarray(rng.standard_normal((8, 8, 4)), jnp.float32)
    case("conv2d_dgrad", (dy, f), dict(padding=1),
         kw=dict(padding=1, block_do=2, block_di=2, block_h=4))
    case("conv2d_wgrad", (x, dy), dict(F=3, padding=1),
         kw=dict(F=3, padding=1, block_do=2, block_di=2, block_h=4))

    xm = jnp.asarray(rng.standard_normal((16, 24)), jnp.float32)
    wm = jnp.asarray(rng.standard_normal((24, 16)), jnp.float32)
    case("matmul", (xm, wm), {}, kw=dict(block_m=8, block_n=8, block_k=8))

    gm = jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)
    case("matmul_dx", (gm, wm), {}, kw=dict(block_m=8, block_n=8, block_k=8))
    case("matmul_dw", (xm, gm), {}, kw=dict(block_m=8, block_n=8, block_k=8))

    q = jnp.asarray(rng.standard_normal((1, 2, 24, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 24, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 24, 16)), jnp.float32)
    case("flash_attention", (q, k, v), dict(causal=True),
         kw=dict(causal=True, block_q=8, block_kv=8), tol=2e-3)

    assert set(registered_ops()) == {
        "conv2d", "conv2d_im2col", "conv2d_dgrad", "conv2d_wgrad",
        "matmul", "matmul_dx", "matmul_dw", "flash_attention",
    }
    return rows


def bench_roofline():
    path = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun.json")
    if not os.path.exists(path):
        return [("roofline_table", 0.0, "missing:experiments/dryrun.json (run dryrun first)")]
    with open(path) as f:
        results = json.load(f)
    rows = []
    for key, res in sorted(results.items()):
        if not res.get("ok"):
            rows.append((f"roofline:{key}", 0.0, f"FAILED:{str(res.get('error','?'))[:60]}"))
            continue
        r = res["roofline"]
        rows.append((
            f"roofline:{key}", res.get("compile_seconds", 0) * 1e6,
            f"bound={r['bottleneck']};tC={r['t_compute']:.2e};tM={r['t_memory']:.2e};"
            f"tX={r['t_collective']:.2e};frac={r['roofline_fraction']:.4f}",
        ))
    return rows


SECTIONS = {
    "conv_ccr": bench_conv_ccr,
    "fc_ccr": bench_fc_ccr,
    "schedule_sim": bench_schedule_sim,
    "kernels": bench_kernels,
    "conv_fused": bench_conv_fused,
    "conv_algos": bench_conv_algos,
    "fc_matmul": bench_fc_matmul,
    "conv_bwd": bench_conv_bwd,
    "fc_bwd": bench_fc_bwd,
    "fc_sharded": bench_fc_sharded,
    "transformer": bench_transformer,
    "serve": bench_serve,
    "smoke": bench_smoke,
    "roofline": bench_roofline,
}

# Which sections feed each committed baseline (conv_bwd and fc_bwd merge
# into one file) — the --check regression gate walks this map.
BASELINES = {
    "BENCH_conv.json": ("conv_fused", "conv_algos"),
    "BENCH_fc.json": ("fc_matmul",),
    "BENCH_bwd.json": ("conv_bwd", "fc_bwd"),
    "BENCH_shard.json": ("fc_sharded",),
    "BENCH_tfm.json": ("transformer",),
    "BENCH_serve.json": ("serve",),
}

# Modeled-word regressions above this gate a CI failure; wall-time moves
# are report-only by default (CI runners are too noisy to gate on a tight
# bound) — opt into a wall gate with ``--check --wall-tolerance <frac>``,
# which fails any row slower than (1 + frac) x its committed baseline.
# The stable CI runner enables it with a generous fraction.
CHECK_TOLERANCE = 0.10


def _word_metrics(derived: str) -> dict[str, int]:
    """The modeled-word metrics of one ``derived`` cell: every integer
    ``key=value`` token whose key names a word count (``*_words``,
    ``hbm*``/``ici*`` splits).  More words is always worse."""
    out = {}
    for tok in derived.split(";"):
        key, _, val = tok.partition("=")
        if not val or not val.lstrip("-").isdigit():
            continue
        if key.endswith("words") or key in (
                "hbm", "ici", "hbm4", "ici4", "psum_hbm", "psum_ici"):
            out[key] = int(val)
    return out


def _us_metrics(derived: str) -> dict[str, float]:
    """The per-kernel wall tokens of one ``derived`` cell (``*_us=<float>``
    — the bwd rows' dgrad/wgrad/epilogue and dx/dw/dxdw breakdowns).
    Gated only under ``--wall-tolerance``, like the row's own
    us_per_call."""
    out = {}
    for tok in derived.split(";"):
        key, _, val = tok.partition("=")
        if not key.endswith("_us") or not val:
            continue
        try:
            out[key] = float(val)
        except ValueError:
            continue
    return out


def check(baseline_files, wall_tolerance: float | None = None) -> int:
    """Compare current runs against the committed baselines: fail (return
    the failure count) on modeled-word regressions > CHECK_TOLERANCE;
    timing deltas are reported without gating unless ``wall_tolerance``
    opts in, in which case ``us > (1 + wall_tolerance) * base_us`` also
    fails.  The CI bench-regression step is ``benchmarks/run.py --check
    BENCH_*.json --wall-tolerance <frac>``."""
    failures = 0
    for path in baseline_files:
        fname = os.path.basename(path)
        sections = BASELINES.get(fname)
        if sections is None:
            print(f"check:{fname},0.0,SKIP:no sections registered")
            continue
        with open(os.path.join(os.path.dirname(__file__), "..", fname)) as fh:
            base = json.load(fh)
        rows = [r for s in sections for r in SECTIONS[s]()]
        for name, us, derived in rows:
            if name not in base:
                print(f"check:{name},{us:.1f},NEW:not in {fname}")
                continue
            want = base[name]
            base_words = _word_metrics(want.get("derived", ""))
            now_words = _word_metrics(derived)
            verdicts = []
            for key, now in sorted(now_words.items()):
                was = base_words.get(key)
                if was is None or was <= 0:
                    continue
                ratio = now / was
                if ratio > 1.0 + CHECK_TOLERANCE:
                    failures += 1
                    verdicts.append(f"REGRESSION:{key}={now}vs{was}"
                                    f"({ratio:.2f}x)")
                elif now != was:
                    verdicts.append(f"changed:{key}={now}vs{was}")
            base_us = want.get("us_per_call") or 0.0
            gated = wall_tolerance is not None and base_us > 1e-9
            if base_us <= 1e-9:
                dt = "t=report-only"
            else:
                dt = f"t={us / base_us:.2f}x" + ("" if gated else "(report)")
            if gated and us > (1.0 + wall_tolerance) * base_us:
                failures += 1
                verdicts.append(
                    f"WALL-REGRESSION:{us:.0f}us>"
                    f"{(1 + wall_tolerance) * base_us:.0f}us")
            if wall_tolerance is not None:
                # Per-kernel wall gate: the bwd rows' dgrad/wgrad/epilogue
                # (and dx/dw/dxdw) breakdown tokens regress individually.
                base_kus = _us_metrics(want.get("derived", ""))
                for key, now_us in sorted(_us_metrics(derived).items()):
                    was_us = base_kus.get(key)
                    if was_us is None or was_us <= 1e-9:
                        continue
                    if now_us > (1.0 + wall_tolerance) * was_us:
                        failures += 1
                        verdicts.append(
                            f"WALL-REGRESSION:{key}={now_us:.0f}us>"
                            f"{(1 + wall_tolerance) * was_us:.0f}us")
            print(f"check:{name},{us:.1f},{dt};"
                  f"{';'.join(verdicts) or 'words-ok'}")
    print(f"check:summary,0.0,failures={failures};"
          f"tolerance={CHECK_TOLERANCE:.0%}")
    return failures


def main() -> None:
    global _FORCE_BASELINE
    argv = sys.argv[1:]
    wall_tolerance = None
    if "--wall-tolerance" in argv:
        i = argv.index("--wall-tolerance")
        try:
            wall_tolerance = float(argv[i + 1])
        except (IndexError, ValueError):
            sys.exit("--wall-tolerance needs a fractional slowdown "
                     "(e.g. --wall-tolerance 2.0 fails rows >3x baseline)")
        del argv[i:i + 2]
    if "--check" in argv:
        files = [a for a in argv if a != "--check"]
        files = files or sorted(BASELINES)
        print("name,us_per_call,derived")
        sys.exit(1 if check(files, wall_tolerance) else 0)
    args = [a for a in argv if a != "--write-baseline"]
    _FORCE_BASELINE = "--write-baseline" in argv
    only = args[0] if args else None
    print("name,us_per_call,derived")
    for name, fn in SECTIONS.items():
        if only and name != only:
            continue
        for row, us, derived in fn():
            print(f"{row},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
