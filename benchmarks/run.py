"""Benchmark harness: one section per paper table/analysis.

  conv_ccr     - paper Sec. 2.1.4 / 2.2.4 / 2.3.4 numeric intuitions (Algs 1-3)
  fc_ccr       - paper Sec. 3.1.4 / 3.2.4 numeric intuitions (Algs 4-5)
  kernels      - wall-time microbenches of the Pallas kernels vs refs (CPU
                 interpret mode: correctness-path timing, not TPU perf)
  conv_fused   - batched-grid + fused-epilogue conv pipeline vs the seed
                 vmap-per-image + XLA-epilogue path (parity + wall time;
                 BENCH_conv.json holds the committed baseline)
  schedule_sim - closed forms vs executed-schedule word counts
  roofline     - per-cell roofline terms from experiments/dryrun.json

Prints ``name,us_per_call,derived`` CSV rows as required.
"""

from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, iters=3, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bench_conv_ccr():
    from repro.core import ccr
    from repro.core.machine import MANTICORE

    s = ccr.ConvShape(W_I=32, D_I=128, D_O=128, F=3, S=1, P=1)
    rows = []
    t0 = time.perf_counter()
    a1 = ccr.alg1_traffic(s)
    rows.append(("conv_alg1_ccr_macword", a1.ccr, "paper:8.9"))
    for prec, want in (("sp", 141.8), ("dp", 87.8)):
        stack = ccr.alg2_max_stack(s, MANTICORE, prec)
        rows.append((f"conv_alg2_ccr_{prec}", ccr.alg2_traffic(s, stack).ccr,
                     f"paper:{want};stack={stack}"))
    for prec, want in (("sp", 541.4), ("dp", 540.6)):
        stack = ccr.alg3_max_stack(s, MANTICORE, prec)
        rows.append((f"conv_alg3_offchip_ccr_{prec}",
                     ccr.alg3_ccr_offchip_as_quoted(s, stack),
                     f"paper:{want};stack={stack}"))
        rows.append((f"conv_alg3_eq10_ccr_{prec}",
                     ccr.alg3_traffic(s, stack).ccr_offchip,
                     "faithful-eq10"))
    us = (time.perf_counter() - t0) * 1e6 / len(rows)
    return [(n, us, f"{v:.2f};{d}") for n, v, d in rows]


def bench_fc_ccr():
    from repro.core import ccr
    from repro.core.machine import MANTICORE

    rows = []
    t0 = time.perf_counter()
    for prec, do_max, want in (("sp", 768, 30.7), ("dp", 384, 29.5)):
        s = ccr.FCShape(W_I=7, D_I=512, D_O=do_max, B=32)
        cap = ccr.alg45_max_stack(s, MANTICORE, prec)
        rows.append((f"fc_alg4_ccr_{prec}", ccr.alg4_ccr(s),
                     f"paper:{want};do_max={cap}"))
    s = ccr.FCShape(W_I=7, D_I=512, D_O=4096, B=32)
    for prec, stack, want in (("sp", 768, 30.6), ("dp", 384, 29.5)):
        rows.append((f"fc_alg5_ccr_{prec}", ccr.alg5_ccr(s, stack),
                     f"paper:{want};stack={stack}"))
    us = (time.perf_counter() - t0) * 1e6 / len(rows)
    return [(n, us, f"{v:.2f};{d}") for n, v, d in rows]


def bench_schedule_sim():
    from repro.core import ccr
    from repro.core import schedule_sim as sim

    s = ccr.ConvShape(W_I=32, D_I=128, D_O=128, F=3, S=1, P=1)
    fc = ccr.FCShape(W_I=7, D_I=512, D_O=4096, B=32)
    rows = []
    t0 = time.perf_counter()
    pairs = [
        ("sim_alg1", sim.simulate_alg1(s), ccr.alg1_traffic(s)),
        ("sim_alg2", sim.simulate_alg2(s, 24), ccr.alg2_traffic(s, 24)),
        ("sim_alg3", sim.simulate_alg3(s, 23), ccr.alg3_traffic(s, 23)),
        ("sim_alg4", sim.simulate_alg4(fc), ccr.alg4_traffic(fc)),
        ("sim_alg5", sim.simulate_alg5(fc, 768), ccr.alg5_traffic(fc, 768)),
    ]
    us = (time.perf_counter() - t0) * 1e6 / len(pairs)
    for name, got, want in pairs:
        rows.append((name, us, f"match={got == want};ccr={got.ccr:.2f}"))
    return rows


def bench_kernels():
    from repro.kernels.conv2d import conv2d, conv2d_ref
    from repro.kernels.flash_attention import attention_ref, flash_attention
    from repro.kernels.matmul import fc_matmul, fc_matmul_ref

    rng = np.random.default_rng(0)
    rows = []

    x = jnp.asarray(rng.standard_normal((128, 256)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32)
    rows.append(("matmul_pallas_interp",
                 _time(lambda: fc_matmul(x, w, block_m=64, block_n=64, block_k=64)),
                 "alg5-kernel"))
    rows.append(("matmul_ref_xla", _time(lambda: fc_matmul_ref(x, w)), "oracle"))

    xi = jnp.asarray(rng.standard_normal((16, 16, 32)), jnp.float32)
    f = jnp.asarray(rng.standard_normal((3, 3, 32, 32)), jnp.float32)
    rows.append(("conv2d_pallas_interp",
                 _time(lambda: conv2d(xi, f, padding=1, block_do=16, block_di=16)),
                 "alg2-kernel"))
    rows.append(("conv2d_ref_xla",
                 _time(lambda: conv2d_ref(xi, f, padding=1)), "oracle"))

    q = jnp.asarray(rng.standard_normal((1, 4, 128, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.float32)
    rows.append(("flash_attn_pallas_interp",
                 _time(lambda: flash_attention(q, k, v, block_q=64, block_kv=64)),
                 "blockwise"))
    rows.append(("flash_attn_ref_xla",
                 _time(lambda: attention_ref(q, k, v)), "oracle"))
    return rows


def bench_conv_fused(write_baseline: bool = False):
    """Fused, batched-grid conv pipeline vs the seed-style path.

    seed path  : jax.vmap of a per-image kernel call, then bias + ReLU +
                 2x2 max-pool as separate XLA ops (HBM round-trip).
    fused path : one pallas_call, grid = (B, h_strips, do_stacks, di_steps),
                 epilogue fused into the kernel flush.
    CPU interpret-mode timing — relative ordering, not TPU perf.
    """
    from repro.kernels.conv2d import conv2d, conv2d_fused_ref

    B, H, DI, DO, F, P = 8, 12, 8, 16, 3, 1
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((B, H, H, DI)), jnp.float32)
    f = jnp.asarray(rng.standard_normal((F, F, DI, DO)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((DO,)), jnp.float32)
    blocks = dict(block_do=8, block_di=8)

    def xla_epilogue(y):
        y = jax.nn.relu(y + b)
        Bn, Hn, Wn, C = y.shape
        return y.reshape(Bn, Hn // 2, 2, Wn // 2, 2, C).max((2, 4))

    def seed_vmap():  # the pre-strip call path: per-image kernel + XLA tail
        y = jax.vmap(lambda xi: conv2d(xi, f, padding=P, block_h=H, **blocks))(x)
        return xla_epilogue(y)

    def batched_unfused():  # batched grid, epilogue still in XLA
        return xla_epilogue(conv2d(x, f, padding=P, block_h=H, **blocks))

    def fused_batched():  # the full tentpole path
        return conv2d(x, f, padding=P, bias=b, relu=True, pool=2,
                      block_h=4, **blocks)

    want = conv2d_fused_ref(x, f, b, padding=P, relu=True, pool=2)
    err = float(jnp.abs(fused_batched() - want).max() / jnp.abs(want).max())

    rows = []
    t_seed = _time(seed_vmap)
    t_unfused = _time(batched_unfused)
    t_fused = _time(fused_batched)
    rows.append(("conv_seed_vmap_xla_epilogue", t_seed, f"B={B};per-image+XLA-tail"))
    rows.append(("conv_batched_grid_unfused", t_unfused,
                 f"speedup_vs_seed={t_seed / t_unfused:.2f}x"))
    rows.append(("conv_batched_grid_fused", t_fused,
                 f"speedup_vs_seed={t_seed / t_fused:.2f}x;maxerr={err:.2e}"))
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_conv.json")
    if write_baseline or not os.path.exists(path):
        with open(path, "w") as fh:
            json.dump({n: {"us_per_call": us, "derived": d} for n, us, d in rows},
                      fh, indent=2)
    return rows


def bench_roofline():
    path = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun.json")
    if not os.path.exists(path):
        return [("roofline_table", 0.0, "missing:experiments/dryrun.json (run dryrun first)")]
    with open(path) as f:
        results = json.load(f)
    rows = []
    for key, res in sorted(results.items()):
        if not res.get("ok"):
            rows.append((f"roofline:{key}", 0.0, f"FAILED:{str(res.get('error','?'))[:60]}"))
            continue
        r = res["roofline"]
        rows.append((
            f"roofline:{key}", res.get("compile_seconds", 0) * 1e6,
            f"bound={r['bottleneck']};tC={r['t_compute']:.2e};tM={r['t_memory']:.2e};"
            f"tX={r['t_collective']:.2e};frac={r['roofline_fraction']:.4f}",
        ))
    return rows


SECTIONS = {
    "conv_ccr": bench_conv_ccr,
    "fc_ccr": bench_fc_ccr,
    "schedule_sim": bench_schedule_sim,
    "kernels": bench_kernels,
    "conv_fused": bench_conv_fused,
    "roofline": bench_roofline,
}


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for name, fn in SECTIONS.items():
        if only and name != only:
            continue
        for row, us, derived in fn():
            print(f"{row},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
