"""Gradient-and-traffic harness for the planned backward kernels (ISSUE 3).

What it pins:

* parity — planned dgrad/wgrad/dX/dW vs ``jax.grad`` of the XLA reference
  across stride/padding/ragged-strip/odd-channel cases, within 1e-4 (f32);
* execution — ``jax.grad`` through :func:`conv_block` / :func:`fc_layer`
  actually runs the planned Pallas backward kernels, not the XLA fallback,
  and a user-passed ``bwd_schedules=`` reaches them (the old
  ``with_reference_vjp`` gap);
* capacity — pinned Manticore-model backward Schedules: the transposed
  ops respect the same Delta_O <= 24/12-style fit bounds as the forward
  (dgrad on the running example *is* the Sec. 2.2.2 rule; dX reproduces
  the 768/384 FC stack);
* traffic — backward ``modeled_words`` equals the closed forms in
  core/ccr.py equals the executed word counts in core/schedule_sim.py for
  every pinned case.

``scripts/tier1.sh --grad-smoke`` runs only :class:`TestGradSmoke`; the
default tier-1 invocation runs it first so backward regressions fail fast.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ccr
from repro.core import schedule_sim as sim
from repro.core.conv_layer import conv_block, conv_layer
from repro.core.conv_layer import plan_bwd as conv_plan_bwd
from repro.core.fc_layer import fc_layer
from repro.core.fc_layer import plan_bwd as fc_plan_bwd
from repro.core.machine import MANTICORE, TPU_V5E, word_bytes
from repro.kernels.conv2d.bwd import (
    conv2d_dgrad,
    conv2d_dgrad_ref,
    conv2d_wgrad,
    conv2d_wgrad_ref,
)
from repro.kernels.conv2d.ref import conv2d_fused_ref, conv2d_ref
from repro.kernels.matmul.bwd import matmul_dw, matmul_dw_ref, matmul_dx, matmul_dx_ref
from repro.kernels.matmul.ref import fc_matmul_ref
from repro.plan import (
    ConvDgradPlanner,
    ConvWgradPlanner,
    MatmulDwPlanner,
    MatmulDxPlanner,
    with_reference_vjp,
)

TOL = 1e-4
S32 = ccr.ConvShape(W_I=32, D_I=128, D_O=128, F=3, S=1, P=1)

# (B, H, W, d_in, d_out, F, S, P): stride, padding, ragged planes (stride
# does not divide the extent), odd channel counts, 1x1 and 5x5 filters.
CONV_CASES = [
    (1, 8, 8, 4, 4, 3, 1, 1),
    (2, 9, 7, 3, 5, 3, 1, 1),     # ragged rectangular plane, odd channels
    (1, 10, 10, 4, 6, 3, 2, 1),   # stride 2
    (2, 7, 7, 5, 3, 5, 1, 2),     # F=5, P=2
    (1, 8, 8, 3, 4, 3, 2, 0),     # stride 2, no padding, ragged cover
    (1, 11, 10, 7, 5, 3, 2, 1),   # stride 2 over an odd extent
    (1, 5, 5, 2, 3, 1, 1, 0),     # 1x1 filter
]


def _rand(rng, shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


def _conv_operands(case, seed=0):
    B, H, W, di, do, F, S, P = case
    rng = np.random.default_rng(seed)
    x = _rand(rng, (B, H, W, di))
    f = _rand(rng, (F, F, di, do))
    H_O, W_O = (H + 2 * P - F) // S + 1, (W + 2 * P - F) // S + 1
    dy = _rand(rng, (B, H_O, W_O, do))
    return x, f, dy


def _ref_conv_grads(x, f, dy, S, P):
    _, vjp = jax.vjp(
        lambda xx, ff: conv2d_ref(xx, ff, stride=S, padding=P), x, f)
    return vjp(dy)


# ---------------------------------------------------------------------------
# Fast subset: scripts/tier1.sh --grad-smoke (and first in default tier-1)
# ---------------------------------------------------------------------------


class TestGradSmoke:
    def test_conv_block_grad_parity(self):
        rng = np.random.default_rng(42)
        x, f, b = _rand(rng, (2, 8, 8, 3)), _rand(rng, (3, 3, 3, 4)), _rand(rng, (4,))
        got = jax.grad(lambda x, f, b: conv_block(x, f, b, 1, 1, 2, "strip").sum(),
                       argnums=(0, 1, 2))(x, f, b)
        want = jax.grad(
            lambda x, f, b: conv2d_fused_ref(x, f, b, stride=1, padding=1,
                                             relu=True, pool=2).sum(),
            argnums=(0, 1, 2))(x, f, b)
        for g, r in zip(got, want):
            assert float(jnp.abs(g - r).max()) < TOL

    def test_fc_layer_grad_parity(self):
        rng = np.random.default_rng(43)
        x, w = _rand(rng, (5, 24)), _rand(rng, (24, 13))
        got = jax.grad(lambda x, w: (fc_layer(x, w) ** 2).sum(),
                       argnums=(0, 1))(x, w)
        want = jax.grad(lambda x, w: (fc_matmul_ref(x, w) ** 2).sum(),
                        argnums=(0, 1))(x, w)
        for g, r in zip(got, want):
            assert float(jnp.abs(g - r).max() / jnp.abs(r).max()) < TOL

    def test_grad_runs_planned_kernels(self, monkeypatch):
        """jax.grad through conv_block / fc_layer must execute the planned
        Pallas backward ops, not the XLA fallback (acceptance criterion).
        Unique shapes defeat jit caching so the spies see the trace."""
        import repro.core.conv_layer as cl
        import repro.core.fc_layer as fl

        calls = []

        def spy(name, orig):
            def wrapped(*a, **k):
                calls.append(name)
                return orig(*a, **k)
            return wrapped

        monkeypatch.setattr(cl, "conv2d_dgrad", spy("dgrad", cl.conv2d_dgrad))
        monkeypatch.setattr(cl, "conv2d_wgrad", spy("wgrad", cl.conv2d_wgrad))
        monkeypatch.setattr(fl, "matmul_dx", spy("dx", fl.matmul_dx))
        monkeypatch.setattr(fl, "matmul_dw", spy("dw", fl.matmul_dw))

        rng = np.random.default_rng(44)
        x, f, b = _rand(rng, (1, 13, 13, 2)), _rand(rng, (3, 3, 2, 3)), _rand(rng, (3,))
        jax.grad(lambda x, f, b: conv_block(x, f, b, 1, 1, 1, "strip").sum(),
                 argnums=(0, 1, 2))(x, f, b)
        xm, wm = _rand(rng, (3, 29)), _rand(rng, (29, 17))
        jax.grad(lambda x, w: fc_layer(x, w).sum(), argnums=(0, 1))(xm, wm)
        assert {"dgrad", "wgrad", "dx", "dw"} <= set(calls), calls

    def test_manticore_dgrad_is_the_paper_capacity_rule(self):
        """dgrad of the running example is the same Sec. 2.2.2 geometry, so
        its stack bound is the paper's Delta_O <= 24 (sp) / 12 (dp)."""
        for prec, want in (("sp", 24), ("dp", 12)):
            sched = ConvDgradPlanner(MANTICORE).plan(
                H_O=32, W_O=32, F=3, S=1, P=1, d_in=128, d_out=128,
                in_bytes=word_bytes(prec), block_h=32)
            assert sched.block("block_do") == want
            assert sched.fits(MANTICORE)


# ---------------------------------------------------------------------------
# Op-level parity: planned kernels vs the XLA oracles
# ---------------------------------------------------------------------------


class TestBackwardOpParity:
    @pytest.mark.parametrize("case", CONV_CASES)
    def test_dgrad_matches_ref(self, case):
        B, H, W, di, do, F, S, P = case
        x, f, dy = _conv_operands(case)
        dx_ref, _ = _ref_conv_grads(x, f, dy, S, P)
        dx = conv2d_dgrad(dy, f, stride=S, padding=P, out_hw=(H, W))
        assert dx.shape == x.shape
        assert float(jnp.abs(dx - dx_ref).max()) < TOL
        # ... and the registered reference oracle agrees with jax.vjp.
        np.testing.assert_allclose(
            np.asarray(conv2d_dgrad_ref(dy, f, stride=S, padding=P, out_hw=(H, W))),
            np.asarray(dx_ref), rtol=TOL, atol=TOL)

    @pytest.mark.parametrize("case", CONV_CASES)
    def test_wgrad_matches_ref(self, case):
        B, H, W, di, do, F, S, P = case
        x, f, dy = _conv_operands(case)
        _, df_ref = _ref_conv_grads(x, f, dy, S, P)
        df = conv2d_wgrad(x, dy, F=F, stride=S, padding=P)
        assert df.shape == f.shape
        assert float(jnp.abs(df - df_ref).max()) < TOL
        np.testing.assert_allclose(
            np.asarray(conv2d_wgrad_ref(x, dy, F=F, stride=S, padding=P)),
            np.asarray(df_ref), rtol=TOL, atol=TOL)

    def test_ragged_strips(self):
        """Explicit block_h that does not divide the plane (ragged strips)
        keeps both backward kernels exact."""
        case = (2, 9, 7, 3, 5, 3, 1, 1)
        B, H, W, di, do, F, S, P = case
        x, f, dy = _conv_operands(case)
        dx_ref, df_ref = _ref_conv_grads(x, f, dy, S, P)
        for hb in (2, 4, 5):
            dx = conv2d_dgrad(dy, f, stride=S, padding=P, out_hw=(H, W), block_h=hb)
            df = conv2d_wgrad(x, dy, F=F, stride=S, padding=P, block_h=hb)
            assert float(jnp.abs(dx - dx_ref).max()) < TOL, hb
            assert float(jnp.abs(df - df_ref).max()) < TOL, hb

    def test_unbatched_operands(self):
        x, f, dy = _conv_operands((1, 8, 8, 4, 4, 3, 1, 1))
        dx_ref, df_ref = _ref_conv_grads(x, f, dy, 1, 1)
        dx = conv2d_dgrad(dy[0], f, stride=1, padding=1, out_hw=(8, 8))
        df = conv2d_wgrad(x[0], dy[0], F=3, stride=1, padding=1)
        assert dx.shape == x.shape[1:]
        assert float(jnp.abs(dx - dx_ref[0]).max()) < TOL
        assert float(jnp.abs(df - df_ref).max()) < TOL

    @pytest.mark.parametrize("m,k,n", [(8, 16, 8), (37, 70, 90), (1, 17, 300),
                                       (130, 257, 129)])
    def test_matmul_dx_dw_match_ref(self, m, k, n):
        rng = np.random.default_rng(m * 1000 + n)
        x, w, g = _rand(rng, (m, k)), _rand(rng, (k, n)), _rand(rng, (m, n))
        _, vjp = jax.vjp(fc_matmul_ref, x, w)
        dx_ref, dw_ref = vjp(g)
        scale = max(float(jnp.abs(dx_ref).max()), float(jnp.abs(dw_ref).max()))
        assert float(jnp.abs(matmul_dx(g, w) - dx_ref).max()) / scale < TOL
        assert float(jnp.abs(matmul_dw(x, g) - dw_ref).max()) / scale < TOL
        np.testing.assert_allclose(np.asarray(matmul_dx_ref(g, w)),
                                   np.asarray(dx_ref), rtol=TOL, atol=TOL)
        np.testing.assert_allclose(np.asarray(matmul_dw_ref(x, g)),
                                   np.asarray(dw_ref), rtol=TOL, atol=TOL)

    def test_matmul_bwd_leading_dims(self):
        rng = np.random.default_rng(77)
        x, w = _rand(rng, (2, 3, 10)), _rand(rng, (10, 7))
        g = _rand(rng, (2, 3, 7))
        _, vjp = jax.vjp(fc_matmul_ref, x, w)
        dx_ref, dw_ref = vjp(g)
        assert float(jnp.abs(matmul_dx(g, w) - dx_ref).max()) < TOL
        assert float(jnp.abs(matmul_dw(x, g) - dw_ref).max()) < TOL


# ---------------------------------------------------------------------------
# Layer-level parity: jax.grad through the rewired custom_vjps
# ---------------------------------------------------------------------------


class TestLayerGradParity:
    @pytest.mark.parametrize("case", CONV_CASES[:5])
    def test_conv_layer_grads(self, case):
        B, H, W, di, do, F, S, P = case
        x, f, _ = _conv_operands(case, seed=1)
        got = jax.grad(lambda x, f: (conv_layer(x, f, S, P, "strip") ** 2).sum(),
                       argnums=(0, 1))(x, f)
        want = jax.grad(
            lambda x, f: (conv2d_ref(x, f, stride=S, padding=P) ** 2).sum(),
            argnums=(0, 1))(x, f)
        for g, r in zip(got, want):
            assert float(jnp.abs(g - r).max() / max(1.0, jnp.abs(r).max())) < TOL

    @pytest.mark.parametrize("pool", [1, 2])
    def test_conv_block_grads_pool(self, pool):
        """Fused bias+ReLU+pool epilogue backprop, even (8) and ragged-pool
        (pool over an odd H_O handled by the XLA tail) planes."""
        rng = np.random.default_rng(11)
        for H in (8, 9):
            x, f, b = (_rand(rng, (2, H, H, 3)), _rand(rng, (3, 3, 3, 4)),
                       _rand(rng, (4,)))
            got = jax.grad(
                lambda x, f, b: (conv_block(x, f, b, 1, 1, pool, "strip") ** 2).sum(),
                argnums=(0, 1, 2))(x, f, b)
            want = jax.grad(
                lambda x, f, b: (conv2d_fused_ref(x, f, b, stride=1, padding=1,
                                                  relu=True, pool=pool) ** 2).sum(),
                argnums=(0, 1, 2))(x, f, b)
            for g, r in zip(got, want):
                scale = max(1.0, float(jnp.abs(r).max()))
                assert float(jnp.abs(g - r).max()) / scale < TOL, (H, pool)

    def test_fc_layer_grads_leading_dims(self):
        rng = np.random.default_rng(12)
        x, w = _rand(rng, (2, 3, 20)), _rand(rng, (20, 11))
        got = jax.grad(lambda x, w: (fc_layer(x, w) ** 2).sum(), argnums=(0, 1))(x, w)
        want = jax.grad(lambda x, w: (fc_matmul_ref(x, w) ** 2).sum(),
                        argnums=(0, 1))(x, w)
        for g, r in zip(got, want):
            assert float(jnp.abs(g - r).max() / jnp.abs(r).max()) < TOL

    def test_bwd_schedules_reach_the_kernels(self, monkeypatch):
        """A user-passed bwd_schedules= must be the exact Schedule the
        backward ops execute (the with_reference_vjp gap this PR closes)."""
        import repro.core.conv_layer as cl

        seen = {}
        orig_dg, orig_wg = cl.conv2d_dgrad, cl.conv2d_wgrad

        def spy_dg(*a, **k):
            seen["dgrad"] = k.get("schedule")
            return orig_dg(*a, **k)

        def spy_wg(*a, **k):
            seen["wgrad"] = k.get("schedule")
            return orig_wg(*a, **k)

        monkeypatch.setattr(cl, "conv2d_dgrad", spy_dg)
        monkeypatch.setattr(cl, "conv2d_wgrad", spy_wg)

        rng = np.random.default_rng(13)
        x, f = _rand(rng, (1, 14, 14, 3)), _rand(rng, (3, 3, 3, 4))
        bwd = conv_plan_bwd(x.shape, f.shape, stride=1, padding=1)
        bwd = {"dgrad": bwd["dgrad"].evolve(block_h=3),
               "wgrad": bwd["wgrad"].evolve(block_h=5)}
        got = jax.grad(
            lambda x, f: conv_layer(x, f, 1, 1, "strip", None, bwd).sum(),
            argnums=(0, 1))(x, f)
        assert seen["dgrad"] is bwd["dgrad"] and seen["wgrad"] is bwd["wgrad"]
        want = jax.grad(
            lambda x, f: conv2d_ref(x, f, stride=1, padding=1).sum(),
            argnums=(0, 1))(x, f)
        for g, r in zip(got, want):  # pinned odd blocking stays exact
            assert float(jnp.abs(g - r).max()) < TOL

    def test_fc_bwd_schedules_roundtrip(self):
        rng = np.random.default_rng(14)
        x, w = _rand(rng, (6, 20)), _rand(rng, (20, 11))
        bwd = fc_plan_bwd(x.shape, w.shape)
        assert set(bwd) == {"dx", "dw"} and all(
            s.fits(TPU_V5E) for s in bwd.values())
        got = jax.grad(lambda x, w: (fc_layer(x, w, None, bwd) ** 2).sum(),
                       argnums=(0, 1))(x, w)
        want = jax.grad(lambda x, w: (fc_matmul_ref(x, w) ** 2).sum(),
                        argnums=(0, 1))(x, w)
        for g, r in zip(got, want):
            assert float(jnp.abs(g - r).max() / jnp.abs(r).max()) < TOL

    def test_unfit_bwd_schedule_falls_back_to_reference(self):
        """A pinned backward Schedule that does not fit the machine it was
        planned for must trigger the XLA reference VJP (checked against its
        *own* machine, not a hard-coded one) — gradients stay correct."""
        import dataclasses

        rng = np.random.default_rng(15)
        x, f = _rand(rng, (1, 15, 15, 3)), _rand(rng, (3, 3, 3, 4))
        bwd = conv_plan_bwd(x.shape, f.shape, stride=1, padding=1,
                            machine=MANTICORE)
        assert bwd["dgrad"].machine == "manticore"
        # Blow the modeled working set past the 128 KiB cluster budget.
        bwd = {k: dataclasses.replace(s, vmem_bytes=1 << 30)
               for k, s in bwd.items()}
        got = jax.grad(
            lambda x, f: conv_layer(x, f, 1, 1, "strip", None, bwd).sum(),
            argnums=(0, 1))(x, f)
        want = jax.grad(
            lambda x, f: conv2d_ref(x, f, stride=1, padding=1).sum(),
            argnums=(0, 1))(x, f)
        for g, r in zip(got, want):
            assert float(jnp.abs(g - r).max()) < TOL
        # conv_block: the unfit recompute schedule must be dropped (the
        # planner re-plans) and the epilogue backward stays correct too.
        bb = _rand(rng, (4,))
        got = jax.grad(
            lambda x, f, bb: conv_block(x, f, bb, 1, 1, 2, "strip", None,
                                        bwd).sum(),
            argnums=(0, 1, 2))(x, f, bb)
        want = jax.grad(
            lambda x, f, bb: conv2d_fused_ref(x, f, bb, stride=1, padding=1,
                                              relu=True, pool=2).sum(),
            argnums=(0, 1, 2))(x, f, bb)
        for g, r in zip(got, want):
            assert float(jnp.abs(g - r).max()) < TOL

    def test_unfit_schedule_warns_once_per_cell(self):
        """The fit gates' silent-fallback fix: the first unfit (role,
        schedule) cell warns, steady-state replays stay quiet (the
        autotune _warn_once discipline applied to the layers)."""
        import dataclasses
        import warnings as pywarn

        from repro.core.conv_layer import warn_unfit_schedule

        bwd = conv_plan_bwd((1, 8, 8, 3), (3, 3, 3, 4), stride=1, padding=1)
        big = dataclasses.replace(bwd["wgrad"], vmem_bytes=1 << 30)
        with pywarn.catch_warnings(record=True) as rec:
            pywarn.simplefilter("always")
            warn_unfit_schedule("wgrad", big, TPU_V5E)
            warn_unfit_schedule("wgrad", big, TPU_V5E)  # replay: quiet
        assert len(rec) == 1
        assert "overflows VMEM" in str(rec[0].message)

    def test_with_reference_vjp_threads_bwd_schedules(self):
        """Unit check of the registry fix: bwd_fn receives the trailing
        nondiff bwd_schedules argument verbatim."""
        seen = []

        def kern(x, sched, bwd_schedules):
            return x * 2.0

        def bwd(x, g, sched, bwd_schedules):
            seen.append(bwd_schedules)
            return (2.0 * g,)

        op = with_reference_vjp(kern, kern, nondiff_argnums=(1, 2), bwd_fn=bwd)
        frozen = (("dgrad", "sentinel"),)
        g = jax.grad(lambda x: op(x, "sched", frozen).sum())(jnp.ones(3))
        assert seen == [frozen]
        np.testing.assert_allclose(np.asarray(g), 2.0)


# ---------------------------------------------------------------------------
# Pinned Manticore/TPU backward Schedules + modeled == simulated words
# ---------------------------------------------------------------------------


class TestPinnedBackwardSchedules:
    @pytest.mark.parametrize("prec,want", [("sp", 24), ("dp", 12)])
    def test_dgrad_words_match_ccr_and_sim(self, prec, want):
        """Full-plane dgrad of the running example: the paper stack bound,
        and Schedule words == ccr closed form == executed walk."""
        sched = ConvDgradPlanner(MANTICORE).plan(
            H_O=32, W_O=32, F=3, S=1, P=1, d_in=128, d_out=128,
            in_bytes=word_bytes(prec), block_h=32)
        assert sched.block("block_do") == want
        assert sched.block("block_do") == ccr.alg2_max_stack(S32, MANTICORE, prec)
        t_ccr = ccr.conv_dgrad_traffic(S32, want, 32)
        t_sim = sim.simulate_conv_dgrad(S32, want, 32)
        assert sched.loads == t_ccr.main_loads == t_sim.main_loads
        assert sched.stores == t_ccr.main_stores == t_sim.main_stores

    @pytest.mark.parametrize("block_h", [32, 16, 8, 5])
    def test_dgrad_strip_words(self, block_h):
        sched = ConvDgradPlanner(MANTICORE).plan(
            H_O=32, W_O=32, F=3, S=1, P=1, d_in=128, d_out=128,
            in_bytes=4, block_h=block_h, batch=3)
        stack = sched.block("block_do")
        t_ccr = ccr.conv_dgrad_traffic(S32, stack, block_h, batch=3)
        t_sim = sim.simulate_conv_dgrad(S32, stack, block_h, batch=3)
        assert (sched.loads, sched.stores) == (t_ccr.main_loads, t_ccr.main_stores)
        assert (sched.loads, sched.stores) == (t_sim.main_loads, t_sim.main_stores)

    @pytest.mark.parametrize("block_h", [32, 16, 8, 5])
    def test_wgrad_words_match_ccr_and_sim(self, block_h):
        sched = ConvWgradPlanner(MANTICORE).plan(
            H_O=32, W_O=32, F=3, S=1, d_in=128, d_out=128, in_bytes=4,
            padding=1, H_I=32, W_I=32, block_h=block_h, batch=2)
        stack, bdi = sched.block("block_do"), sched.block("block_di")
        t_ccr = ccr.conv_wgrad_traffic(S32, stack, block_h, di_block=bdi, batch=2)
        t_sim = sim.simulate_conv_wgrad(S32, stack, block_h, di_block=bdi, batch=2)
        assert sched.fits(MANTICORE)
        assert (sched.loads, sched.stores, sched.macs) == (
            t_ccr.main_loads, t_ccr.main_stores, t_ccr.macs)
        assert (t_ccr.main_loads, t_ccr.main_stores, t_ccr.macs) == (
            t_sim.main_loads, t_sim.main_stores, t_sim.macs)

    @pytest.mark.parametrize("prec,want", [("sp", 768), ("dp", 384)])
    def test_fc_dx_reproduces_alg5_stack(self, prec, want):
        """dX's resident output stack on MANTICORE is the Sec. 3.1.2 bound:
        768 (sp) / 384 (dp) at batch 32 — the transposed Alg 5 rule."""
        fc = ccr.FCShape(W_I=7, D_I=512, D_O=4096, B=32)
        sched = MatmulDxPlanner(MANTICORE).plan(
            m=32, n=4096, k=7 * 7 * 512, in_bytes=word_bytes(prec))
        assert sched.block("block_k") == want
        assert sched.block("block_k") == ccr.alg45_max_stack(fc, MANTICORE, prec)
        assert sched.fits(MANTICORE)
        t = sim.simulate_matmul_blocks(
            32, 7 * 7 * 512, 4096, sched.block("block_m"),
            sched.block("block_k"), sched.block("block_n"))
        assert (sched.loads, sched.stores, sched.macs) == (
            t.main_loads, t.main_stores, t.macs)

    @pytest.mark.parametrize("m,n,k,ib", [(32, 4096, 25088, 4),
                                          (32, 4096, 25088, 8),
                                          (64, 1024, 512, 4),
                                          (1, 300, 17, 4)])
    def test_fc_dw_words_match_sim(self, m, n, k, ib):
        sched = MatmulDwPlanner(MANTICORE if ib == 8 else TPU_V5E).plan(
            m=m, n=n, k=k, in_bytes=ib)
        t = sim.simulate_matmul_blocks(
            k, n, m, sched.block("block_k"), sched.block("block_n"),
            sched.block("block_m"))
        assert (sched.loads, sched.stores, sched.macs) == (
            t.main_loads, t.main_stores, t.macs)

    def test_tpu_backward_schedules_fit(self):
        """Every backward Schedule of the CNN's training step fits the TPU
        machine model (so jax.grad runs the planned kernels, never the
        fallback)."""
        from repro.configs.base import ModelConfig
        from repro.models import cnn

        cfg = ModelConfig(name="t", family="cnn", n_layers=2, d_model=4,
                          d_ff=16, vocab=10)
        scheds = cnn.plan_training(cfg, batch=2)
        bwd_keys = [k for k in scheds if "." in k]
        # conv: dgrad/wgrad only — the even 32/16 planes plan the
        # fused-epilogue backward, so no recompute entry; fc: dx/dw.
        assert len(bwd_keys) == 2 * 2 + 2 * 2
        assert not any(k.endswith(".recompute") for k in bwd_keys)
        assert all(scheds[k].fits(TPU_V5E) for k in bwd_keys)
        assert all(scheds[k].modeled_words > 0 for k in bwd_keys)


# ---------------------------------------------------------------------------
# Fused epilogue VJP: the int8 mask residual vs the jax.vjp oracle
# ---------------------------------------------------------------------------


class TestFusedEpilogueVJP:
    # (B, H, W, d_in, d_out, F, S, P, pool, block_h): stride, padding,
    # pool 1 (ReLU-bit mask) and 2 (argmax mask), odd channel counts, a
    # strip height that does not divide the plane.
    EPI_CASES = [
        (1, 8, 8, 3, 4, 3, 1, 1, 2, None),
        (2, 9, 9, 3, 5, 3, 1, 1, 1, None),    # pool=1, odd channels
        (1, 11, 11, 4, 6, 3, 2, 1, 2, None),  # stride 2, even pooled plane
        (2, 8, 8, 5, 3, 5, 1, 2, 2, None),    # F=5, P=2
        (1, 9, 7, 7, 5, 3, 2, 0, 1, None),    # pool=1, no padding, ragged
        (1, 12, 12, 3, 4, 3, 1, 1, 2, 8),     # ragged strips (12 = 8 + 4)
    ]

    @staticmethod
    def _mask_and_oracle(case, seed=31):
        from repro.kernels.conv2d.ops import conv2d_with_mask
        from repro.kernels.conv2d.ref import maxpool_ref
        from repro.plan import get_op

        B, H, W, di, do, F, S, P, pool, block_h = case
        rng = np.random.default_rng(seed)
        x, f, b = (_rand(rng, (B, H, W, di)), _rand(rng, (F, F, di, do)),
                   _rand(rng, (do,)))
        schedule = None
        if block_h is not None:
            schedule = get_op("conv2d").plan(
                x, f, b, stride=S, padding=P, relu=True, pool=pool,
                block_h=block_h)
        out, mask = conv2d_with_mask(x, f, bias=b, stride=S, padding=P,
                                     pool=pool, schedule=schedule)
        g = _rand(rng, out.shape)
        y0 = conv2d_ref(x, f, stride=S, padding=P)

        def epi(y):
            y = jnp.maximum(y + b, 0.0)
            return maxpool_ref(y, pool) if pool > 1 else y

        _, vjp = jax.vjp(epi, y0)
        return out, mask, g, epi(y0), vjp(g)[0]

    @pytest.mark.parametrize("case", EPI_CASES)
    def test_scatter_matches_vjp_oracle(self, case):
        """epilogue_scatter(g, mask, pool) == jax.vjp of the epilogue at
        the true pre-pool activation — exact, since both route each pooled
        gradient element to the same (untied, random-data) argmax."""
        from repro.kernels.conv2d.bwd import epilogue_scatter

        pool = case[8]
        out, mask, g, want_out, want_dy = self._mask_and_oracle(case)
        assert mask is not None, "fused forward must emit the mask here"
        assert mask.dtype == jnp.int8
        np.testing.assert_allclose(np.asarray(out), np.asarray(want_out),
                                   rtol=TOL, atol=TOL)
        dy = epilogue_scatter(g, mask, pool)
        assert dy.shape == want_dy.shape
        np.testing.assert_allclose(np.asarray(dy), np.asarray(want_dy),
                                   rtol=TOL, atol=TOL)

    def test_ragged_pool_yields_no_mask(self):
        """A pool that does not tile the output plane keeps the XLA pool
        tail — conv2d_with_mask must return mask=None (the backward then
        recomputes as before)."""
        from repro.kernels.conv2d.ops import conv2d_with_mask

        rng = np.random.default_rng(32)
        x, f, b = (_rand(rng, (1, 9, 9, 3)), _rand(rng, (3, 3, 3, 4)),
                   _rand(rng, (4,)))
        out, mask = conv2d_with_mask(x, f, bias=b, stride=1, padding=1, pool=2)
        assert mask is None
        want = conv2d_fused_ref(x, f, b, stride=1, padding=1, relu=True, pool=2)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=TOL, atol=TOL)

    def test_mask_path_skips_recompute_conv(self, monkeypatch):
        """With the mask residual saved, the conv_block backward must not
        launch the recompute conv (recompute_words = 0); the ragged-pool
        geometry still does."""
        import repro.core.conv_layer as cl

        calls = []
        orig_conv, orig_sc = cl.conv2d, cl.epilogue_scatter
        monkeypatch.setattr(cl, "conv2d", lambda *a, **k: (
            calls.append("conv2d"), orig_conv(*a, **k))[1])
        monkeypatch.setattr(cl, "epilogue_scatter", lambda *a, **k: (
            calls.append("scatter"), orig_sc(*a, **k))[1])

        rng = np.random.default_rng(33)
        f, b = _rand(rng, (3, 3, 3, 4)), _rand(rng, (4,))

        def run(H):
            x = _rand(rng, (1, H, H, 3))
            out, vjp = jax.vjp(
                lambda x, f, b: conv_block(x, f, b, 1, 1, 2, "strip"), x, f, b)
            calls.clear()
            vjp(jnp.ones_like(out))
            return list(calls)

        even = run(8)   # mask residual: scatter, no recompute conv
        assert "scatter" in even and "conv2d" not in even, even
        ragged = run(9)  # no mask: the old recompute path
        assert "conv2d" in ragged and "scatter" not in ragged, ragged

    def test_fc_bwd_schedules_dispatch_fused_dxdw(self, monkeypatch):
        """fc plan_bwd pins the fused dX/dW kernel; the layer backward must
        run it (one dY read for both gradients) instead of the split pair,
        and stay exact."""
        import repro.core.fc_layer as fl

        calls = []
        for name in ("matmul_dx", "matmul_dw", "matmul_dx_dw"):
            orig = getattr(fl, name)
            monkeypatch.setattr(fl, name, (lambda o, n: lambda *a, **k: (
                calls.append(n), o(*a, **k))[1])(orig, name))

        rng = np.random.default_rng(34)
        x, w = _rand(rng, (6, 24)), _rand(rng, (24, 18))
        bwd = fc_plan_bwd(x.shape, w.shape)
        assert getattr(bwd["dx"], "algorithm", None) == "fused_dxdw"
        got = jax.grad(lambda x, w: (fc_layer(x, w, None, bwd) ** 2).sum(),
                       argnums=(0, 1))(x, w)
        assert "matmul_dx_dw" in calls, calls
        assert "matmul_dx" not in calls and "matmul_dw" not in calls, calls
        want = jax.grad(lambda x, w: (fc_matmul_ref(x, w) ** 2).sum(),
                        argnums=(0, 1))(x, w)
        for g, r in zip(got, want):
            assert float(jnp.abs(g - r).max() / jnp.abs(r).max()) < TOL


# ---------------------------------------------------------------------------
# Overlap-aware cost model: critical_path_steps == the executed walker
# ---------------------------------------------------------------------------


class TestCriticalPathSteps:
    """House rule for the new overlap objective: every emitted backward
    Schedule's ``critical_path_steps`` closed form must equal an executed
    ``schedule_sim`` walk of the same pipeline."""

    def _pin_conv(self, sched, *, H_I, H_O, d_in, d_out, batch):
        if sched.op == "conv2d_dgrad" and sched.algorithm == "fused_epilogue":
            kw = dict(H_I=H_I, d_in=d_in, block_h=sched.block("block_h"),
                      block_do=sched.block("block_do"), batch=batch)
            want = ccr.conv_dgrad_fused_steps(**kw)
            assert want == sim.simulate_conv_dgrad_fused_steps(**kw)
        elif sched.op == "conv2d_wgrad":
            kw = dict(H_O=H_O, d_in=d_in, d_out=d_out,
                      block_h=sched.block("block_h"),
                      block_di=sched.block("block_di"),
                      block_do=sched.block("block_do"), batch=batch,
                      pipelined=(sched.algorithm == "pipelined"))
            want = ccr.conv_wgrad_steps(**kw)
            assert want == sim.simulate_conv_wgrad_steps(**kw)
        else:
            want = ccr.grid_steps(sched.grid)
            assert want == sim.simulate_grid_steps(sched.grid)
        assert sched.critical_path_steps == want, (sched.op, sched.algorithm)

    @pytest.mark.parametrize("pool", [None, 2])
    def test_conv_bwd_schedules_match_walker(self, pool):
        bwd = conv_plan_bwd((4, 12, 12, 8), (3, 3, 8, 16), stride=1,
                            padding=1, pool=pool)
        if pool == 2:
            assert bwd["dgrad"].algorithm == "fused_epilogue"
            assert "recompute" not in bwd
        else:
            assert "recompute" in bwd
        for sched in bwd.values():
            self._pin_conv(sched, H_I=12, H_O=12, d_in=8, d_out=16, batch=4)

    def test_conv_bwd_candidates_cover_both_variants(self):
        """The autotuner's search space carries *both* execution variants
        of each backward op, every one walker-checked."""
        shape = dict(H_O=12, W_O=12, F=3, S=1, P=1, d_in=8, d_out=16,
                     in_bytes=4, batch=4, H_I=12, W_I=12)
        dg = ConvDgradPlanner(TPU_V5E).candidates(**shape, pool=2)
        assert {s.algorithm for s in dg} >= {"fused_epilogue", "direct"}
        wg = ConvWgradPlanner(TPU_V5E).candidates(
            **{k: v for k, v in shape.items() if k != "P"}, padding=1)
        assert {s.algorithm for s in wg} >= {"pipelined", "direct"}
        for sched in dg + wg:
            self._pin_conv(sched, H_I=12, H_O=12, d_in=8, d_out=16, batch=4)

    def test_fc_bwd_schedules_match_walker(self):
        from repro.plan import get_op

        rng = np.random.default_rng(35)
        g, w, x = _rand(rng, (64, 1024)), _rand(rng, (512, 1024)), \
            _rand(rng, (64, 512))
        scheds = list(fc_plan_bwd(x.shape, w.shape).values())
        scheds.append(get_op("matmul_dx").plan(g, w))       # direct variant
        scheds.append(get_op("matmul_dw").plan(x, g))
        for c in MatmulDxPlanner(TPU_V5E).candidates(m=64, n=1024, k=512,
                                                     in_bytes=4):
            scheds.append(c)
        algs = {getattr(s, "algorithm", None) for s in scheds}
        assert {"fused_dxdw", None} <= algs or {"fused_dxdw", "direct"} <= algs
        for sched in scheds:
            want = ccr.grid_steps(sched.grid)
            assert want == sim.simulate_grid_steps(sched.grid)
            assert sched.critical_path_steps == want, (sched.op,
                                                       sched.algorithm)


# ---------------------------------------------------------------------------
# Training path: planned kernels end to end under jax.grad
# ---------------------------------------------------------------------------


class TestTrainingPath:
    def _tiny_cnn(self):
        from repro.configs.base import ModelConfig

        cfg = ModelConfig(name="t", family="cnn", n_layers=2, d_model=4,
                          d_ff=16, vocab=10)
        rng = np.random.default_rng(21)
        params = {}
        for i, (ci, co) in enumerate([(3, 4), (4, 8)]):
            params[f"conv{i}"] = _rand(rng, (3, 3, ci, co))
            params[f"bias{i}"] = _rand(rng, (co,))
        flat = 8 * 8 * 8
        params["fc1"] = _rand(rng, (flat, 16)) * 0.05
        params["fc1_b"] = jnp.zeros((16,), jnp.float32)
        params["fc2"] = _rand(rng, (16, 10)) * 0.05
        params["fc2_b"] = jnp.zeros((10,), jnp.float32)
        return cfg, params, _rand(rng, (2, 32, 32, 3))

    def test_cnn_grads_planned_vs_reference(self):
        from repro.models import cnn

        cfg, params, imgs = self._tiny_cnn()
        scheds = cnn.plan_training(cfg, batch=2)
        labels = jnp.array([1, 2])

        def loss(p, **kw):
            lg = cnn.forward(cfg, p, imgs, **kw)
            return -jax.nn.log_softmax(lg)[jnp.arange(2), labels].mean()

        gk = jax.grad(lambda p: loss(p, use_kernels=True, schedules=scheds))(params)
        gr = jax.grad(lambda p: loss(p, use_kernels=False))(params)
        for k in params:
            assert float(jnp.abs(gk[k] - gr[k]).max()) < TOL, k

    def test_planned_train_step(self):
        """make_train_step with planned_kernels=True runs one finite step
        (the launch/train.py --planned-kernels path)."""
        from repro.configs.base import TrainConfig
        from repro.runtime import train as tr

        cfg, params, imgs = self._tiny_cnn()
        tcfg = TrainConfig(compute_dtype="float32", planned_kernels=True,
                           total_steps=2)
        step = jax.jit(tr.make_train_step(cfg, tcfg))
        state = tr.init_state(cfg, tcfg, params)
        state, metrics = step(state, {"images": imgs, "labels": jnp.array([1, 2])})
        assert np.isfinite(float(metrics["loss"]))
        assert np.isfinite(float(metrics["grad_norm"]))
