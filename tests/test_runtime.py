"""Runtime units: optimizer, schedules, data pipeline, compression,
checkpoint retention, fault-tolerance machinery."""

import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import TrainConfig
from repro.data.pipeline import MemmapSource, ShardInfo, SyntheticSource, write_token_file
from repro.optim import adamw
from repro.optim.compression import compress_decompress, compress_tree, init_error_buffers
from repro.runtime.fault_tolerance import (
    Heartbeat, Monitor, StragglerWatchdog, shrink_mesh_shape,
)


class TestAdamW:
    def test_minimizes_quadratic(self):
        tcfg = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=100,
                           weight_decay=0.0, grad_clip=0.0)
        params = {"w": jnp.array([3.0, -2.0])}
        state = adamw.init(params)
        for _ in range(60):
            grads = {"w": 2 * params["w"]}
            params, state, _ = adamw.apply_updates(params, grads, state, tcfg)
        assert float(jnp.abs(params["w"]).max()) < 0.2

    def test_grad_clip_bounds_update(self):
        tcfg = TrainConfig(learning_rate=1.0, warmup_steps=0, grad_clip=1.0,
                           weight_decay=0.0)
        params = {"w": jnp.zeros(4)}
        state = adamw.init(params)
        _, _, metrics = adamw.apply_updates(params, {"w": jnp.full(4, 1e6)}, state, tcfg)
        assert float(metrics["grad_norm"]) == pytest.approx(2e6, rel=1e-3)

    def test_schedule_warmup_and_decay(self):
        tcfg = TrainConfig(learning_rate=1.0, warmup_steps=10, total_steps=100)
        lr0 = float(adamw.lr_schedule(tcfg, jnp.int32(1)))
        lr_w = float(adamw.lr_schedule(tcfg, jnp.int32(10)))
        lr_end = float(adamw.lr_schedule(tcfg, jnp.int32(100)))
        assert lr0 == pytest.approx(0.1, rel=1e-3)
        assert lr_w == pytest.approx(1.0, rel=1e-2)
        assert lr_end == pytest.approx(0.1, rel=1e-2)

    def test_zero1_specs_shard_divisible_dim(self):
        from jax.sharding import PartitionSpec as P

        specs = {"w": P(None, "model")}
        abstract = {"w": jax.ShapeDtypeStruct((64, 32), jnp.float32)}
        st_specs = adamw.zero1_specs(specs, abstract, ("data",), {"data": 16, "model": 16})
        assert st_specs.m["w"] == P("data", "model")


class TestDataPipeline:
    def test_synthetic_deterministic_and_sharded(self):
        a = SyntheticSource(1000, 32, 8, ShardInfo(0, 2), seed=1)
        b = SyntheticSource(1000, 32, 8, ShardInfo(1, 2), seed=1)
        x0, x0b = a(5), a(5)
        np.testing.assert_array_equal(x0["tokens"], x0b["tokens"])  # deterministic
        assert x0["tokens"].shape == (4, 32)  # 8 global / 2 shards
        assert not np.array_equal(x0["tokens"], b(5)["tokens"])  # disjoint shards
        np.testing.assert_array_equal(x0["tokens"][:, 1:], x0["labels"][:, :-1])

    def test_memmap_source(self):
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "tokens.bin")
            write_token_file(path, np.arange(10000) % 777)
            src = MemmapSource(path, vocab=777, seq_len=64, global_batch=4)
            b0, b1 = src(0), src(1)
            assert b0["tokens"].shape == (4, 64)
            assert not np.array_equal(b0["tokens"], b1["tokens"])
            assert b0["tokens"].max() < 777


class TestCompression:
    def test_error_feedback_reduces_bias(self):
        """With error feedback, the *accumulated* quantized sum tracks the
        true sum much better than independent quantization."""
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.standard_normal(512) * 1e-3)
        err = jnp.zeros(512)
        acc = jnp.zeros(512)
        for _ in range(50):
            deq, err = compress_decompress(g, err)
            acc = acc + deq
        drift = float(jnp.abs(acc - 50 * g).max() / jnp.abs(50 * g).max())
        assert drift < 0.05, drift

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 10_000))
    def test_property_error_bounded_by_one_quantum(self, seed):
        rng = np.random.default_rng(seed)
        g = jnp.asarray(rng.standard_normal(64))
        deq, err = compress_decompress(g, jnp.zeros(64))
        scale = float(jnp.max(jnp.abs(g))) / 127.0
        assert float(jnp.abs(err).max()) <= scale * 0.5 + 1e-6

    def test_tree_roundtrip_shapes(self):
        tree = {"a": jnp.ones((4, 4)), "b": {"c": jnp.zeros(7)}}
        errs = init_error_buffers(tree)
        deq, errs2 = compress_tree(tree, errs)
        assert jax.tree.structure(deq) == jax.tree.structure(tree)
        assert jax.tree.structure(errs2) == jax.tree.structure(tree)


class TestCheckpoint:
    def test_retention_and_latest(self):
        with tempfile.TemporaryDirectory() as d:
            for s in (1, 5, 9):
                ckpt.save(d, s, {"x": jnp.ones(3)})
            assert ckpt.latest_step(d) == 9
            ckpt.retain(d, keep=2)
            assert ckpt.latest_step(d) == 9
            assert not os.path.exists(os.path.join(d, "step_0000001"))

    def test_uncommitted_checkpoint_ignored(self):
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 3, {"x": jnp.ones(3)})
            os.makedirs(os.path.join(d, "step_0000009"))  # no COMMIT file
            assert ckpt.latest_step(d) == 3

    def test_async_save(self):
        with tempfile.TemporaryDirectory() as d:
            t = ckpt.save_async(d, 2, {"x": jnp.arange(5)})
            t.join()
            out = ckpt.restore(d, 2, {"x": jax.ShapeDtypeStruct((5,), jnp.int32)})
            np.testing.assert_array_equal(np.asarray(out["x"]), np.arange(5))


class TestFaultTolerance:
    def test_heartbeat_and_stale_detection(self):
        with tempfile.TemporaryDirectory() as d:
            hb = Heartbeat("hostA", d)
            hb.beat(0)
            mon = Monitor(d, timeout=60)
            assert mon.stale_hosts() == []
            assert mon.live_hosts() == ["hostA"]
            assert mon.stale_hosts(now=time.time() + 120) == ["hostA"]

    def test_torn_heartbeat_reads_as_stale(self):
        """A host that dies mid-write leaves a torn/empty hb_*.json — that
        is evidence of failure, so the monitor must treat it as stale, not
        crash the coordinator with a JSONDecodeError."""
        with tempfile.TemporaryDirectory() as d:
            Heartbeat("live", d).beat(0)
            with open(os.path.join(d, "hb_torn.json"), "w") as f:
                f.write('{"step": 3, "tim')  # killed mid-write
            with open(os.path.join(d, "hb_empty.json"), "w"):
                pass  # opened, never written
            with open(os.path.join(d, "hb_weird.json"), "w") as f:
                f.write('{"step": 3, "time": "soon"}')  # non-numeric time
            mon = Monitor(d, timeout=60)
            assert mon.stale_hosts() == ["empty", "torn", "weird"]
            assert mon.live_hosts() == ["live"]

    def test_straggler_watchdog(self):
        w = StragglerWatchdog(factor=2.0)
        for _ in range(10):
            assert not w.observe(1.0)
        assert w.observe(5.0)
        assert not w.observe(1.1)

    def test_shrink_mesh_preserves_tp(self):
        assert shrink_mesh_shape(240, model=16) == (15, 16)
        assert shrink_mesh_shape(480, model=16, pod=2) == (2, 15, 16)
        assert shrink_mesh_shape(496, model=16, pod=2) == (1, 31, 16)
        with pytest.raises(ValueError):
            shrink_mesh_shape(250, model=16)
