"""The transformer/MoE wing through the plan layer (DESIGN.md Sec. 11).

Three pillars, mirroring the conv/FC tests one wing over:

* the two new ShardedSchedule strategies — tensor-parallel ("tp",
  megatron column split) and expert-parallel ("ep", MoE all-to-all) —
  with their ccr closed forms pinned word-for-word against *executed*
  schedule_sim walkers (the house rule) and the paper's 16-cluster
  quadrant picks pinned with absolute word counts;
* the TransformerBlockPlanner's delegation (matmul cells ->
  MatmulPlanner, attention -> AttentionPlanner, MoE -> MoeFfnPlanner)
  and the planned transformer train step it feeds (planned forward +
  planned dX/dW backward == the XLA reference, to float tolerance);
* the family-registry protocol's error paths: unknown family, a
  cache-less family reaching serve, mixed-family schedule keys.
"""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core import ccr
from repro.core import schedule_sim as sim
from repro.core.machine import MANTICORE
from repro.plan import (
    MatmulPlanner, MeshSpec, MoeFfnPlanner, TransformerBlockPlanner,
    validate_sharded_plan,
)

QUAD16 = MeshSpec((("cluster", 16),))  # the paper's 4x4 quadrant

FC_SMALL = dict(m=16, n=4096, k=4096, in_bytes=4)
FC6 = dict(m=32, n=4096, k=25088, in_bytes=4)  # VGG FC6 at batch 32
MOE = dict(tokens=4096, d_model=512, d_ff=2048, n_experts=16, top_k=2,
           in_bytes=4)


def _cand(planner, strategy, **shape):
    c = [c for c in planner.candidates(**shape) if c.strategy == strategy]
    assert c, f"no {strategy!r} candidate for {shape}"
    return c[0]


class TestTpClosedFormVsWalker:
    """House rule: ccr.tp_matmul_traffic == the executed per-device
    block walker + literal ring all-gather, on every count."""

    @pytest.mark.parametrize("shape", [FC_SMALL, FC6])
    @pytest.mark.parametrize("devices", [4, 16])
    def test_modeled_equals_simulated(self, shape, devices):
        loc = MatmulPlanner(MANTICORE).plan(
            m=shape["m"], n=shape["n"] // devices, k=shape["k"],
            in_bytes=shape["in_bytes"])
        blocks = dict(block_m=loc.block("block_m"),
                      block_n=loc.block("block_n"),
                      block_k=loc.block("block_k"))
        t = ccr.tp_matmul_traffic(m=shape["m"], n=shape["n"], k=shape["k"],
                                  devices=devices, **blocks)
        w = sim.simulate_tp_matmul(m=shape["m"], n=shape["n"], k=shape["k"],
                                   devices=devices, **blocks)
        assert t == w  # macs, loads, stores AND intercluster

    def test_indivisible_n_rejected(self):
        with pytest.raises(ValueError):
            ccr.tp_matmul_traffic(m=8, n=100, k=64, devices=16,
                                  block_m=8, block_n=128, block_k=64)
        with pytest.raises(ValueError):
            sim.simulate_tp_matmul(m=8, n=100, k=64, devices=16,
                                   block_m=8, block_n=128, block_k=64)


class TestEpClosedFormVsWalker:
    """House rule for the MoE all-to-all: the closed form equals the
    executed per-(device, expert, row) dispatch walker."""

    @pytest.mark.parametrize("devices", [4, 8, 16])
    def test_modeled_equals_simulated(self, devices):
        kw = dict(tokens=4096, d_model=512, top_k=2, n_experts=16,
                  devices=devices)
        assert ccr.moe_all_to_all_words(**kw) == sim.simulate_moe_all_to_all(**kw)

    def test_quadrant_words(self):
        # tokens/P = 256 rows, each routed to top_k=2 experts; 15/16 of the
        # slots live off-device and cross the wires twice (there and back):
        # 2 * 512 * 2 * 256 * 15 = 7864320 words.
        kw = dict(tokens=4096, d_model=512, top_k=2, n_experts=16, devices=16)
        assert ccr.moe_all_to_all_words(**kw) == 7864320
        assert sim.simulate_moe_all_to_all(**kw) == 7864320

    def test_guards(self):
        for bad in (dict(tokens=4095, d_model=8, top_k=2, n_experts=16,
                         devices=16),        # tokens % devices
                    dict(tokens=4096, d_model=8, top_k=2, n_experts=12,
                         devices=16),        # n_experts % devices
                    dict(tokens=64, d_model=8, top_k=3, n_experts=16,
                         devices=16)):       # slots % n_experts
            with pytest.raises(ValueError):
                ccr.moe_all_to_all_words(**bad)
            with pytest.raises(ValueError):
                sim.simulate_moe_all_to_all(**bad)


class TestQuadrantPicks:
    """The paper's 16-cluster quadrant: absolute modeled word counts and
    the planner's argmin, pinned."""

    def test_tp_vs_batch_small_m(self):
        """At small M the megatron trade is stark: batch re-streams the
        full [K, N] weight per device (P * K * N dominates), tp streams
        it once and pays only the (P-1)-step M*N/P activation ring."""
        mm = MatmulPlanner(MANTICORE, QUAD16, "cluster")
        tp = _cand(mm, "tp", **FC_SMALL)
        batch = _cand(mm, "batch", **FC_SMALL)
        assert tp.modeled_words == 18874368
        assert (tp.hbm_words, tp.ici_words) == (17891328, 983040)
        assert batch.modeled_words == 268632064
        assert batch.ici_words == 0
        assert tp.modeled_words < batch.modeled_words
        # tp's ici charge IS the pinned tree/ring all-gather closed form.
        assert tp.ici_words == ccr.tree_reduce_words(16, 16 * 4096)

    def test_tp_partition(self):
        tp = _cand(MatmulPlanner(MANTICORE, QUAD16, "cluster"), "tp",
                   **FC_SMALL)
        # x replicated; w and out column-sharded over the quadrant.
        assert tp.partition == ((None, None), (None, "cluster"),
                                (None, "cluster"))
        # The local schedule is the per-device [m, n/P, k] plan.
        assert tp.schedule == MatmulPlanner(MANTICORE).plan(
            m=16, n=4096 // 16, k=4096, in_bytes=4)

    def test_fc6_ring_still_wins(self):
        """Adding tp must not flip FC6's recorded ring pick: ring reuses
        the resident X shard (lower HBM) and its larger ici bill still
        beats tp's weight-restream savings at this K."""
        mm = MatmulPlanner(MANTICORE, QUAD16, "cluster")
        ranked = {c.strategy: c.modeled_words for c in mm.candidates(**FC6)}
        assert ranked["ring"] == 115736576
        assert ranked["tp"] == 117702656
        assert ranked["psum"] == 161611776
        assert ranked["batch"] == 1645903872
        assert mm.plan(**FC6).strategy == "ring"

    def test_ep_vs_batch(self):
        """MoE on the quadrant: ep streams each expert's FFN weights once
        (E/P experts resident per device) and pays the all-to-all; batch
        re-streams all E experts' weights on every device's token shard."""
        mo = MoeFfnPlanner(MANTICORE, QUAD16, "cluster")
        ep = _cand(mo, "ep", **MOE)
        batch = _cand(mo, "batch", **MOE)
        assert ep.modeled_words == 428212224
        assert (ep.hbm_words, ep.ici_words) == (420347904, 7864320)
        assert batch.modeled_words == 622854144
        assert mo.plan(**MOE).strategy == "ep"
        # tokens AND experts shard together; the all-to-all rides as ici.
        assert ep.partition == (("cluster", None), ("cluster", None, None),
                                ("cluster", None))

    def test_block_planner_quadrant_picks(self):
        """The whole block's per-cell joint algorithm-and-partitioning
        argmin on the quadrant, pinned with its word counts."""
        tb = TransformerBlockPlanner(MANTICORE, QUAD16, "cluster")
        plans = tb.plan(batch=4, seq=128, d_model=256, n_heads=8,
                        d_ff=1024, vocab=1024, in_bytes=4)
        picks = {name: (getattr(s, "strategy", None), s.modeled_words)
                 for name, s in plans.items()}
        assert picks == {
            "qkv": ("ring", 2686976),
            "attn": ("single", 8388608),
            "wo": ("batch", 1310720),
            "mlp_up": ("ring", 3670016),
            "mlp_down": ("batch", 4849664),
            "logits": ("ring", 2883584),
        }


class TestBlockPlannerDelegation:
    """The compound planner delegates exactly as Im2colConvPlanner does
    its GEMM core: each cell is its sub-planner's own plan."""

    SHAPE = dict(batch=2, seq=64, d_model=128, n_heads=4, d_ff=256,
                 in_bytes=4)

    def test_cells_match_delegated_planners(self):
        tb = TransformerBlockPlanner(MANTICORE)
        plans = tb.plan(**self.SHAPE)
        assert set(plans) == {"qkv", "attn", "wo", "mlp_up", "mlp_down"}
        mm = MatmulPlanner(MANTICORE)
        m = 2 * 64
        assert plans["qkv"] == mm.plan(m=m, n=3 * 128, k=128, in_bytes=4)
        assert plans["mlp_up"] == mm.plan(m=m, n=2 * 256, k=128, in_bytes=4)
        assert plans["attn"].op == "flash_attention"

    def test_moe_replaces_mlp_cells(self):
        tb = TransformerBlockPlanner(MANTICORE)
        plans = tb.plan(**self.SHAPE, n_experts=8, top_k=2)
        assert "moe" in plans and "mlp_up" not in plans
        assert plans["moe"].op == "moe_ffn"

    def test_candidates_are_per_cell(self):
        tb = TransformerBlockPlanner(MANTICORE, QUAD16, "cluster")
        cands = tb.candidates(**self.SHAPE)
        assert set(cands) == {"qkv", "attn", "wo", "mlp_up", "mlp_down"}
        strategies = {c.strategy for c in cands["qkv"]}
        assert {"tp", "batch"} <= strategies


class TestPlannedTransformerTraining:
    """The planned train step: plan_training's schedule set drives the
    fused-GEMM forward + planned dX/dW backward, numerically equal to the
    XLA reference path."""

    @staticmethod
    def _cfg():
        from repro.configs.registry import smoke_config

        cfg = smoke_config("qwen1.5-0.5b")
        return dataclasses.replace(
            cfg, family="transformer", n_layers=2, d_model=64, vocab=128,
            d_ff=128, n_heads=4, n_kv_heads=4, head_dim=16)

    def test_plan_training_keys(self):
        from repro.models import transformer as tf

        cfg = self._cfg()
        sched = tf.plan_training(cfg, 2, 32, loss_chunks=2)
        cells = {"qkv", "attn", "wo", "mlp_up", "mlp_down", "logits"}
        assert set(sched) == cells | {
            f"{c}.{g}" for c in cells - {"attn"} for g in ("dx", "dw")}
        # The logits cell is planned at chunked_ce's chunk M (B * S/n),
        # not the full B*S token count.
        from repro.core.machine import TPU_V5E

        chunk_m = 2 * (32 // 2)
        assert sched["logits"] == MatmulPlanner(TPU_V5E).plan(
            m=chunk_m, n=cfg.vocab, k=cfg.d_model, in_bytes=4)

    def test_planned_step_matches_xla(self):
        from repro.configs.base import TrainConfig
        from repro.models import transformer as tf
        from repro.models.module import init_params
        from repro.runtime import train as tr

        cfg = self._cfg()
        tcfg = TrainConfig(param_dtype="float32", compute_dtype="float32",
                           planned_kernels=True, loss_chunks=2,
                           total_steps=2)
        params = init_params(tf.param_defs(cfg), jax.random.PRNGKey(0),
                             jnp.float32)
        B, S = 2, 32
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                         cfg.vocab),
            "labels": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                         cfg.vocab),
        }
        lp, gp = jax.value_and_grad(tr.make_loss_fn(cfg, tcfg))(params, batch)
        lx, gx = jax.value_and_grad(tr.make_loss_fn(
            cfg, dataclasses.replace(tcfg, planned_kernels=False)))(params,
                                                                    batch)
        assert abs(float(lp) - float(lx)) < 1e-4
        err = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))), gp, gx)
        assert max(jax.tree.leaves(err)) < 1e-2

    def test_planned_forward_rejects_per_layer_windows(self):
        from repro.models import transformer as tf

        cfg = dataclasses.replace(self._cfg(), local_window=16,
                                  global_every=2)
        params = jax.eval_shape(lambda: None)  # never reached
        with pytest.raises(ValueError, match="global_every"):
            tf._forward_planned(cfg, params,
                                jnp.zeros((1, 8), jnp.int32), jnp.float32,
                                None)


class TestFamilyRegistryErrors:
    def test_unknown_family_rejected(self):
        from repro.models.registry import get_family

        with pytest.raises(ValueError, match="unknown model family"):
            get_family("no-such-family")

    def test_launcher_rejects_unregistered_family(self, monkeypatch):
        """--family is validated against the registry before anything
        runs (argparse choices come straight from FAMILIES)."""
        import sys

        from repro.launch import train as lt

        monkeypatch.setattr(sys, "argv",
                            ["train", "--family", "no-such-family"])
        with pytest.raises(SystemExit):
            lt.main()

    def test_cacheless_family_cannot_serve(self):
        from repro.configs.registry import smoke_config
        from repro.models.registry import init_cache_slots

        cfg = smoke_config("cnn-vgg11")
        with pytest.raises(ValueError, match="init_cache"):
            init_cache_slots(cfg, 4, 128, jnp.bfloat16)

    def test_mixed_family_plan_rejected(self):
        from repro.models import transformer as tf

        cfg = TestPlannedTransformerTraining._cfg()
        splan = tf.plan_training(cfg, 2, 32, mesh=QUAD16,
                                 shard_axis="cluster")
        validate_sharded_plan(splan, QUAD16)  # pure-transformer: fine
        conv = MatmulPlanner(MANTICORE, QUAD16, "cluster").plan(
            m=32, n=64, k=64, in_bytes=4)
        with pytest.raises(ValueError, match="mixed-family"):
            validate_sharded_plan(dict(splan, **{"fc1": conv}), QUAD16)
