"""Paper-faithfulness tests: every numeric claim in the paper, pinned.

Sections referenced: 2.1.2, 2.1.4, 2.2.2, 2.2.4, 2.3.2, 2.3.4, 3.1.2,
3.1.4, 3.2.2, 3.2.4.
"""


import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core import ccr
from repro.core.machine import MANTICORE
from repro.core import schedule_sim as sim

# The paper's running conv example: W_I = W_O = 32, F = 3, D_I = D_O = 128.
CONV = ccr.ConvShape(W_I=32, D_I=128, D_O=128, F=3, S=1, P=1)
# The paper's running FC example: W_I = 7, D_O = 4096, B = 32.
FC = ccr.FCShape(W_I=7, D_I=512, D_O=4096, B=32)


class TestPaperConvClaims:
    def test_output_width(self):
        assert CONV.W_O == 32  # S=1, P=1, F=3 -> same size

    def test_alg1_ccr_8p9(self):
        """Sec. 2.1.4: CCR ca. 8.9 MAC/word; 4.4 spflop/B; 2.2 dpflop/B."""
        t = ccr.alg1_traffic(CONV)
        assert t.ccr == pytest.approx(8.9, abs=0.05)
        assert t.ccr == pytest.approx(ccr.alg1_ccr(CONV))
        assert t.flops_per_byte("sp") == pytest.approx(4.4, abs=0.05)
        assert t.flops_per_byte("dp") == pytest.approx(2.2, abs=0.05)

    def test_alg1_ccr_approx_F_squared(self):
        """Eq. (6): CCR ~= F^2 for typical shapes."""
        assert ccr.alg1_ccr_approx(CONV) == 9.0
        assert ccr.alg1_ccr(CONV) == pytest.approx(9.0, rel=0.02)

    def test_alg1_space(self):
        """Sec. 2.1.2: 2057 words; <8.1 KiB sp, <16.1 KiB dp."""
        words = ccr.alg1_space_words(CONV)
        assert words == 2057
        assert words * 4 / 1024 < 8.1
        assert words * 8 / 1024 < 16.1

    def test_alg2_max_stack(self):
        """Sec. 2.2.2: Delta_O <= 24 (sp), <= 12 (dp) for W_O = 32."""
        assert ccr.alg2_max_stack(CONV, MANTICORE, "sp") == 24
        assert ccr.alg2_max_stack(CONV, MANTICORE, "dp") == 12

    def test_alg2_ccr(self):
        """Sec. 2.2.4: 141.8 MAC/word (70.9 spflop/B) sp; 87.8 (21.9) dp."""
        t_sp = ccr.alg2_traffic(CONV, stack=24)
        assert t_sp.ccr == pytest.approx(141.8, abs=0.05)
        assert t_sp.flops_per_byte("sp") == pytest.approx(70.9, abs=0.05)
        t_dp = ccr.alg2_traffic(CONV, stack=12)
        assert t_dp.ccr == pytest.approx(87.8, abs=0.05)
        assert t_dp.flops_per_byte("dp") == pytest.approx(21.9, abs=0.05)

    def test_alg2_becomes_compute_bound_on_manticore(self):
        """Sec. 2.2.4: stacking flips Alg 1's memory-bound into compute-bound."""
        assert ccr.bound_kind(ccr.alg1_traffic(CONV), MANTICORE, "sp") == "memory-bound"
        t = ccr.alg2_traffic(CONV, stack=24)
        assert ccr.bound_kind(t, MANTICORE, "sp") == "compute-bound"

    def test_alg3_max_stack(self):
        """Sec. 2.3.2: Delta_O <= 23 (sp), <= 11 (dp)."""
        assert ccr.alg3_max_stack(CONV, MANTICORE, "sp") == 23
        assert ccr.alg3_max_stack(CONV, MANTICORE, "dp") == 11

    def test_alg3_quoted_ccr(self):
        """Sec. 2.3.4 quoted numbers: 541.4 MAC/word (270.7 spflop/B) sp,
        540.6 (135.2) dp — reproduced via the reconstructed formula."""
        q_sp = ccr.alg3_ccr_offchip_as_quoted(CONV, stack=23)
        assert q_sp == pytest.approx(541.4, abs=0.05)
        assert q_sp * 2 / 4 == pytest.approx(270.7, abs=0.05)
        q_dp = ccr.alg3_ccr_offchip_as_quoted(CONV, stack=11)
        assert q_dp == pytest.approx(540.6, abs=0.05)
        assert q_dp * 2 / 8 == pytest.approx(135.2, abs=0.05)

    def test_alg3_eq10_faithful(self):
        """Eq. (10) evaluated faithfully (documents the paper's slip):
        the off-chip CCR is 460.8 (sp) / 400.7 (dp), not 541.4/540.6."""
        t_sp = ccr.alg3_traffic(CONV, stack=23)
        assert t_sp.ccr_offchip == pytest.approx(460.8, abs=0.05)
        t_dp = ccr.alg3_traffic(CONV, stack=11)
        assert t_dp.ccr_offchip == pytest.approx(400.67, abs=0.05)

    def test_alg3_overall_ccr_unchanged(self):
        """Sec. 2.3.4: the *overall* CCR equals Alg 2's (same total words)."""
        a2 = ccr.alg2_traffic(CONV, stack=23)
        a3 = ccr.alg3_traffic(CONV, stack=23)
        assert a3.ccr == pytest.approx(a2.ccr)

    def test_alg2_no_extra_macs(self):
        """Sec. 2.2.1: Alg 2 adds no MACs vs Alg 1."""
        assert ccr.alg2_traffic(CONV, 24).macs == ccr.alg1_traffic(CONV).macs


class TestPaperFCClaims:
    def test_alg4_space(self):
        """Sec. 3.1.2: 132689 words; ~519 KiB sp; ~1037 KiB dp."""
        words = ccr.alg4_space_words(FC)
        assert words == 132689
        assert words * 4 / 1024 == pytest.approx(519, abs=1)
        assert words * 8 / 1024 == pytest.approx(1037, abs=1)

    def test_alg4_max_do(self):
        """Sec. 3.1.2: D_O <= 768 (sp), <= 384 (dp) at B = 32, W_I = 7."""
        assert ccr.alg45_max_stack(FC, MANTICORE, "sp") == 768
        assert ccr.alg45_max_stack(FC, MANTICORE, "dp") == 384

    def test_alg4_ccr(self):
        """Sec. 3.1.4: CCR 30.7 (15.4 spflop/B) sp; 29.5 (7.4 dpflop/B) dp."""
        sp = ccr.alg4_ccr(ccr.FCShape(W_I=7, D_I=512, D_O=768, B=32))
        assert sp == pytest.approx(30.7, abs=0.05)
        assert sp * 2 / 4 == pytest.approx(15.4, abs=0.05)
        dp = ccr.alg4_ccr(ccr.FCShape(W_I=7, D_I=512, D_O=384, B=32))
        assert dp == pytest.approx(29.5, abs=0.05)
        assert dp * 2 / 8 == pytest.approx(7.4, abs=0.05)

    def test_alg5_ccr(self):
        """Sec. 3.2.4: CCR 30.6 (sp, Delta=768) / 29.5 (dp, Delta=384)
        at D_O = 4096."""
        assert ccr.alg5_ccr(FC, stack=768) == pytest.approx(30.6, abs=0.05)
        assert ccr.alg5_ccr(FC, stack=384) == pytest.approx(29.5, abs=0.05)

    def test_alg4_tree_reduction_words(self):
        """Sec. 3.1.3: 127 * D_O * B words over 128 clusters."""
        t = ccr.alg4_traffic(FC, clusters=128)
        assert t.intercluster == 127 * FC.D_O * FC.B

    def test_alg5_no_extra_macs(self):
        assert ccr.alg5_traffic(FC, 768).macs == ccr.alg4_traffic(FC).macs


# ---------------------------------------------------------------------------
# Closed forms == executed schedules (hypothesis-randomized)
# ---------------------------------------------------------------------------

conv_shapes = st.builds(
    ccr.ConvShape,
    W_I=st.integers(4, 40),
    D_I=st.integers(1, 96),
    D_O=st.integers(1, 96),
    F=st.sampled_from([1, 3, 5, 7]),
    S=st.just(1),
    P=st.integers(0, 3),
).filter(lambda s: s.F <= s.W_I + 2 * s.P)

fc_shapes = st.builds(
    ccr.FCShape,
    W_I=st.integers(1, 12),
    D_I=st.integers(1, 48),
    D_O=st.integers(1, 300),
    B=st.integers(1, 48),
)


@settings(max_examples=40, deadline=None)
@given(conv_shapes)
def test_sim_matches_alg1(s):
    t_sim, t_eq = sim.simulate_alg1(s), ccr.alg1_traffic(s)
    assert t_sim == t_eq
    assert t_sim.ccr == pytest.approx(ccr.alg1_ccr(s))


@settings(max_examples=40, deadline=None)
@given(conv_shapes, st.integers(1, 32))
def test_sim_matches_alg2(s, stack):
    assert sim.simulate_alg2(s, stack) == ccr.alg2_traffic(s, stack)


@settings(max_examples=40, deadline=None)
@given(conv_shapes.filter(lambda s: s.D_I % 16 == 0), st.integers(1, 32))
def test_sim_matches_alg3(s, stack):
    """Eq. (9)/(10) assume each quadrant cycles whole slices; exact when
    16 | D_I (paper's typical shapes)."""
    assert sim.simulate_alg3(s, stack) == ccr.alg3_traffic(s, stack)


@settings(max_examples=40, deadline=None)
@given(fc_shapes)
def test_sim_matches_alg4(s):
    t = sim.simulate_alg4(s)
    assert t == ccr.alg4_traffic(s)
    # Eq. (11) describes the in-parallel-region CCR: MACs / parallel loads.
    assert t.macs / t.main_loads == pytest.approx(ccr.alg4_ccr(s))


@settings(max_examples=40, deadline=None)
@given(fc_shapes, st.integers(1, 512))
def test_sim_matches_alg5(s, stack):
    t = sim.simulate_alg5(s, stack)
    assert t == ccr.alg5_traffic(s, stack)
    assert t.macs / t.main_loads == pytest.approx(ccr.alg5_ccr(s, stack))


@settings(max_examples=30, deadline=None)
@given(conv_shapes, st.integers(1, 31))
def test_stacking_monotone_improves_ccr(s, stack):
    """Property: a larger stack never lowers the CCR (the paper's core
    insight: Delta_O reuse is monotone)."""
    assert ccr.alg2_traffic(s, stack + 1).ccr >= ccr.alg2_traffic(s, stack).ccr - 1e-9


@settings(max_examples=30, deadline=None)
@given(conv_shapes, st.integers(1, 32))
def test_space_bounds_are_respected(s, stack):
    """Property: the Delta_O chooser's pick always fits the budget, and
    +1 never does (maximality)."""
    for prec, wb in (("sp", 4), ("dp", 8)):
        cap = ccr.alg2_max_stack(s, MANTICORE, prec)
        budget = MANTICORE.usable_for_working_set(2)
        if cap >= 1:
            assert cap * s.W_O**2 * wb <= budget
        assert (cap + 1) * s.W_O**2 * wb > budget
