"""Distributed correctness on 8 virtual CPU devices.

Each test runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_
device_count=8 (jax locks the device count at first init, and the main
pytest process must keep seeing 1 device for the smoke tests)."""

import os
import subprocess
import sys


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(script: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


PRELUDE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core.shard_compat import make_auto_mesh
mesh = make_auto_mesh((2, 4), ("data", "model"))
"""


def test_ring_matmul_equals_dense():
    """Alg 3 ring matmul == X @ W (the paper's claim: reuse changes traffic,
    not results)."""
    run_sub(PRELUDE + """
from repro.core.ring import ring_matmul
rng = np.random.default_rng(0)
x = rng.standard_normal((16, 32)).astype(np.float32)
w = rng.standard_normal((32, 24)).astype(np.float32)
with mesh:
    out = ring_matmul(jnp.asarray(x), jnp.asarray(w), mesh, axis="model")
np.testing.assert_allclose(np.asarray(out), x @ w, rtol=1e-5, atol=1e-5)
print("ring ok")
""")


def test_fc_layer_sharded_equals_dense():
    """Alg 4 contraction sharding + psum == X @ W."""
    run_sub(PRELUDE + """
from repro.core.fc_layer import fc_layer_sharded
rng = np.random.default_rng(1)
x = rng.standard_normal((8, 64)).astype(np.float32)
w = rng.standard_normal((64, 40)).astype(np.float32)
with mesh:
    out = fc_layer_sharded(jnp.asarray(x), jnp.asarray(w), mesh, axis="model")
np.testing.assert_allclose(np.asarray(out), x @ w, rtol=1e-4, atol=1e-4)
print("fc sharded ok")
""")


def test_sharded_train_step_matches_single_device():
    """One pjit train step on the 2x4 mesh == the same step on 1 device."""
    run_sub(PRELUDE + """
import dataclasses
from repro.configs.registry import smoke_config
from repro.configs.base import TrainConfig
from repro.models.registry import get_family
from repro.models.module import init_params, param_specs
from repro.runtime import train as tr
from repro.runtime.parallel import ParallelCtx
from repro.launch.specs import fsdp_specs
from repro.optim import adamw

cfg = dataclasses.replace(smoke_config("qwen3-1.7b"), n_layers=2, vocab=128)
tcfg = TrainConfig(param_dtype="float32", compute_dtype="float32",
                   remat="none", loss_chunks=2)
fam = get_family(cfg.family)
params = init_params(fam.param_defs(cfg), jax.random.PRNGKey(0), jnp.float32)
state = tr.init_state(cfg, tcfg, params)
rngb = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rngb.integers(0, 128, (8, 32)), jnp.int32),
         "labels": jnp.asarray(rngb.integers(0, 128, (8, 32)), jnp.int32)}

# single device
step1 = jax.jit(tr.make_train_step(cfg, tcfg, parallel=None))
s1, m1 = step1(state, batch)

# sharded
ctx = ParallelCtx(mesh=mesh, dp_axes=("data",), tp_axis="model")
specs = param_specs(fam.param_defs(cfg))
import jax.tree_util as jtu
ns = lambda tree: jax.tree.map(lambda sp: NamedSharding(mesh, sp), tree)
pspec = fsdp_specs(specs, params, ctx)
sstate = tr.TrainState(params=ns(pspec),
                       opt=adamw.AdamWState(step=ns(P()), m=ns(pspec), v=ns(pspec)),
                       err=None)
bspec = {"tokens": ns(P("data", None)), "labels": ns(P("data", None))}
with mesh:
    step8 = jax.jit(tr.make_train_step(cfg, tcfg, parallel=ctx),
                    in_shardings=(sstate, bspec))
    s8, m8 = step8(state, batch)

np.testing.assert_allclose(float(m1["loss"]), float(m8["loss"]), rtol=2e-4)
l1 = jax.tree.leaves(s1.params); l8 = jax.tree.leaves(s8.params)
for a, b in zip(l1, l8):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-3, atol=3e-4)
print("train step parity ok")
""")


def test_moe_shard_map_matches_local():
    """Expert-parallel shard_map MoE == local (single-device) dispatch."""
    run_sub(PRELUDE + """
import dataclasses
from repro.configs.registry import smoke_config
from repro.models import moe
from repro.models.module import init_params
from repro.runtime.parallel import ParallelCtx

cfg = dataclasses.replace(smoke_config("qwen3-moe-235b-a22b"),
                          n_layers=1, capacity_factor=64.0)
params = init_params(moe.param_defs(cfg), jax.random.PRNGKey(0), jnp.float32)
rng = np.random.default_rng(0)
toks = jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)

h_local, _ = moe.forward(cfg, params, toks, compute_dtype=jnp.float32)
ctx = ParallelCtx(mesh=mesh, dp_axes=("data",), tp_axis="model")
with mesh:
    h_shard = jax.jit(lambda p, t: moe.forward(cfg, p, t,
        compute_dtype=jnp.float32, parallel=ctx)[0])(params, toks)
np.testing.assert_allclose(np.asarray(h_local), np.asarray(h_shard),
                           rtol=2e-3, atol=2e-3)
print("moe parity ok")
""")


def test_checkpoint_reshard_roundtrip():
    """Save sharded on the 2x4 mesh, restore with a different sharding."""
    run_sub(PRELUDE + """
import tempfile, os
from repro.checkpoint import checkpoint as ckpt
tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
        "b": {"x": jnp.ones((4,), jnp.bfloat16)}, "step": jnp.int32(7)}
with tempfile.TemporaryDirectory() as d:
    ckpt.save(d, 7, tree, n_chunks=4)
    assert ckpt.latest_step(d) == 7
    abstract = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    sh = {"w": NamedSharding(mesh, P("model", None)),
          "b": {"x": NamedSharding(mesh, P(None))}, "step": None}
    out = ckpt.restore(d, 7, abstract, sh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
    np.testing.assert_array_equal(np.asarray(out["b"]["x"], np.float32),
                                  np.asarray(tree["b"]["x"], np.float32))
    assert int(out["step"]) == 7
    assert out["w"].sharding.spec == P("model", None)
print("ckpt reshard ok")
""")


def test_int8_psum_close_to_exact():
    run_sub(PRELUDE + """
from repro.optim.compression import int8_psum
rng = np.random.default_rng(0)
x = rng.standard_normal((64, 32)).astype(np.float32)
with mesh:
    approx = int8_psum(jnp.asarray(x), mesh, "data")
exact = 2 * x  # psum over data axis (2) of replicated x
err = np.abs(np.asarray(approx) - exact).max() / np.abs(exact).max()
assert err < 0.02, err
print("int8 psum ok", err)
""")


def test_blockwise_attention_sharded_parity():
    """Blockwise attention under pjit (batch-sharded) == unsharded."""
    run_sub(PRELUDE + """
from repro.models.attention import attention
rng = np.random.default_rng(0)
B, S, H, D = 4, 64, 4, 16
q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
k = jnp.asarray(rng.standard_normal((B, S, 2, D)), jnp.float32)
v = jnp.asarray(rng.standard_normal((B, S, 2, D)), jnp.float32)
pos = jnp.arange(S, dtype=jnp.int32)
f = lambda q, k, v: attention(q, k, v, q_pos=pos, k_pos=pos, causal=True,
                              chunk_q=16, chunk_kv=16)
ref = f(q, k, v)
sh = NamedSharding(mesh, P("data", None, None, None))
with mesh:
    out = jax.jit(f, in_shardings=(sh, sh, sh))(q, k, v)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)
print("attention sharded ok")
""")


# ---------------------------------------------------------------------------
# Sharded planning end to end: partitioning from ShardedSchedules, executed
# on a forced 4-device host mesh (the --dist-smoke subset, DESIGN.md Sec. 5)
# ---------------------------------------------------------------------------

PRELUDE4 = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core.shard_compat import make_auto_mesh
mesh = make_auto_mesh((4,), ("model",))
assert len(jax.devices()) == 4
"""


def test_fc_sharded_psum_from_planner():
    """fc_layer_sharded resolves its psum partitioning from the mesh-aware
    planner (ShardedSchedule.partition drives the shard_map specs) and
    matches X @ W on 4 devices."""
    run_sub(PRELUDE4 + """
from repro.core.fc_layer import fc_layer_sharded
from repro.plan import get_op
rng = np.random.default_rng(1)
x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
w = jnp.asarray(rng.standard_normal((64, 40)), jnp.float32)
ss = get_op("matmul").plan_sharded(x, w, mesh=mesh, axis="model", strategy="psum")
assert ss.strategy == "psum" and ss.devices == 4
assert ss.partition == ((None, "model"), ("model", None), (None, None))
assert ss.ici_words > 0 and ss.hbm_words > 0
with mesh:
    out = fc_layer_sharded(x, w, mesh, axis="model")           # plans inside
    out2 = fc_layer_sharded(x, w, mesh, axis="model", schedule=ss)  # pinned
np.testing.assert_allclose(np.asarray(out), x @ w, rtol=1e-4, atol=1e-4)
np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))
print("fc sharded psum from planner ok")
""", devices=4)


def test_ring_sharded_from_planner():
    """The Alg-3 ring obtains its partitioning from a ShardedSchedule
    (strategy pin through the registry) and matches X @ W; the planner
    left to itself picks the ring here (reuse beats the psum's re-loads)
    and execution follows the pick."""
    run_sub(PRELUDE4 + """
from repro.core.ring import ring_matmul
from repro.plan import get_op
rng = np.random.default_rng(2)
x = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
w = jnp.asarray(rng.standard_normal((32, 24)), jnp.float32)
op = get_op("matmul")
ss = op.plan_sharded(x, w, mesh=mesh, axis="model", strategy="ring")
assert ss.strategy == "ring"
assert ss.partition == ((None, "model"), (None, "model"), (None, "model"))
with mesh:
    out = ring_matmul(x, w, mesh, axis="model")
np.testing.assert_allclose(np.asarray(out), x @ w, rtol=1e-5, atol=1e-5)
auto = op.plan_sharded(x, w, mesh=mesh, axis="model")
assert auto.strategy == "ring", auto.strategy  # the argmin picks the ring
with mesh:
    out2 = op.sharded(x, w, schedule=auto, mesh=mesh)
np.testing.assert_allclose(np.asarray(out2), x @ w, rtol=1e-5, atol=1e-5)
print("ring from planner ok")
""", devices=4)


def test_sharded_grad_parity_vs_single_device():
    """jax.grad through the planner-partitioned FC layer (psum AND ring)
    equals the single-device gradients — the acceptance criterion's
    forward/grad parity on a forced multi-device CPU mesh."""
    run_sub(PRELUDE4 + """
from repro.core.fc_layer import fc_layer_sharded
rng = np.random.default_rng(3)
x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
w = jnp.asarray(rng.standard_normal((64, 40)), jnp.float32)
want = jax.grad(lambda x, w: ((x @ w) ** 2).sum(), argnums=(0, 1))(x, w)
for strategy in ("psum", "ring", None):
    def loss(x, w):
        with mesh:
            return (fc_layer_sharded(x, w, mesh, axis="model",
                                     strategy=strategy) ** 2).sum()
    got = jax.grad(loss, argnums=(0, 1))(x, w)
    for g, r in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   rtol=1e-4, atol=1e-4)
print("sharded grad parity ok")
""", devices=4)


def test_conv_sharded_batch_matches_ref():
    """The conv "batch" partition executes through the registry's sharded
    impl (each device runs the planned local kernel on its images) and
    matches the XLA reference."""
    run_sub(PRELUDE4 + """
from repro.kernels.conv2d.ref import conv2d_fused_ref
from repro.plan import get_op
rng = np.random.default_rng(4)
x = jnp.asarray(rng.standard_normal((8, 8, 8, 3)), jnp.float32)
f = jnp.asarray(rng.standard_normal((3, 3, 3, 6)), jnp.float32)
b = jnp.asarray(rng.standard_normal((6,)), jnp.float32)
op = get_op("conv2d")
ss = op.plan_sharded(x, f, b, mesh=mesh, axis="model", padding=1, pool=2)
assert ss.strategy == "batch" and ss.ici_words == 0
assert ss.partition[0] == ("model", None, None, None)
with mesh:
    got = op.sharded(x, f, b, schedule=ss, mesh=mesh, padding=1, relu=True,
                     pool=2)
want = conv2d_fused_ref(x, f, b, padding=1, relu=True, pool=2)
np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                           rtol=2e-4, atol=2e-4)
print("conv sharded batch ok")
""", devices=4)


def test_sharded_degenerates_on_one_device_mesh():
    """The same sharded call sites on a 1-device mesh run the plain local
    kernel path (single-device degeneracy, no collectives)."""
    run_sub("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.shard_compat import make_auto_mesh
mesh = make_auto_mesh((1,), ("model",))
from repro.core.fc_layer import fc_layer_sharded
from repro.core.ring import ring_matmul
rng = np.random.default_rng(5)
x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
w = jnp.asarray(rng.standard_normal((64, 40)), jnp.float32)
with mesh:
    a = fc_layer_sharded(x, w, mesh, axis="model")
    b = ring_matmul(x, w, mesh, axis="model")
np.testing.assert_allclose(np.asarray(a), x @ w, rtol=1e-4, atol=1e-4)
np.testing.assert_allclose(np.asarray(b), x @ w, rtol=1e-4, atol=1e-4)
print("1-device degenerate ok")
""", devices=1)
