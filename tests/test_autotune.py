"""Measured-time Schedule autotuning (repro.plan.autotune; DESIGN.md
Sec. 6): cache-key stability across processes, schema-version
invalidation, policy semantics (cache-only never times; corrupt caches
fall back to the modeled argmin), candidate enumeration, and the spy
tests asserting a cached winner is what the kernels actually execute —
including ``fc_layer_sharded`` on the forced 4-device host mesh and the
paper's FC6 cell over the 16-cluster MANTICORE quadrant."""

import dataclasses
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.machine import MANTICORE, TPU_V5E
from repro.plan import MeshSpec, ShardedSchedule, local_schedule, planner_for
from repro.plan import autotune as at
from repro.plan.registry import _OPS, get_op

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

FC6 = dict(m=32, n=4096, k=25088, in_bytes=4)  # the paper's FC6 cell
QUAD = MeshSpec((("cluster", 16),))  # one MANTICORE L2 quadrant
TINY_MM = dict(m=16, n=256, k=64, in_bytes=4)
TINY_CONV = dict(H_O=8, W_O=8, F=3, S=1, d_in=8, d_out=16, in_bytes=4,
                 padding=1, batch=2, pool=2)


@pytest.fixture(autouse=True)
def _isolated_cache(tmp_path, monkeypatch):
    """Never let a test read or write the user's real winner cache."""
    monkeypatch.setattr(at, "_CACHE_PATH", str(tmp_path / "global.json"))
    monkeypatch.setattr(at, "_POLICY", "off")


@pytest.fixture
def cache(tmp_path):
    return at.AutotuneCache(str(tmp_path / "autotune.json"))


def _fake_measure(times):
    """A deterministic stopwatch: pops the next scripted microsecond
    value instead of running the kernel (so policy tests never compile)."""
    seq = list(times)

    def m(fn, iters=3, warmup=1):
        del fn, iters, warmup
        return seq.pop(0)

    return m


# ---------------------------------------------------------------------------
# Cache key
# ---------------------------------------------------------------------------


_KEY_SCRIPT = """
import sys
sys.path.insert(0, {root!r} + "/src")
from repro.core.machine import MANTICORE
from repro.plan import MeshSpec
from repro.plan import autotune as at
readable, digest = at.cache_key(
    "matmul", dict(m=32, n=4096, k=25088, in_bytes=4, block_n=None),
    "float32", MANTICORE, MeshSpec((("cluster", 16),)), "cluster", None)
print(digest)
"""


class TestCacheKey:
    def test_stable_across_processes(self):
        """The digest is a pure function of the cell: two fresh
        interpreters agree with each other and with this process."""
        digests = [
            subprocess.run([sys.executable, "-c",
                            _KEY_SCRIPT.format(root=ROOT)],
                           capture_output=True, text=True, check=True,
                           timeout=120).stdout.strip()
            for _ in range(2)
        ]
        _, here = at.cache_key(
            "matmul", dict(m=32, n=4096, k=25088, in_bytes=4, block_n=None),
            "float32", MANTICORE, QUAD, "cluster", None)
        assert digests[0] == digests[1] == here

    def test_none_valued_knobs_do_not_split_cells(self):
        """Unset block pins are dropped from the canonical form — the
        registry's shape_args (which always carries block_*=None keys)
        and a bare shape dict hash to the same cell."""
        _, a = at.cache_key("matmul", dict(TINY_MM), "float32", TPU_V5E)
        _, b = at.cache_key("matmul", dict(TINY_MM, block_n=None, block_m=None),
                            "float32", TPU_V5E)
        assert a == b

    def test_discriminates_every_key_component(self):
        base = ("matmul", dict(TINY_MM), "float32", TPU_V5E, None, "model",
                None)
        variants = [
            ("matmul_dx", dict(TINY_MM), "float32", TPU_V5E, None, "model", None),
            ("matmul", dict(TINY_MM, m=32), "float32", TPU_V5E, None, "model", None),
            ("matmul", dict(TINY_MM), "bfloat16", TPU_V5E, None, "model", None),
            ("matmul", dict(TINY_MM), "float32", MANTICORE, None, "model", None),
            ("matmul", dict(TINY_MM), "float32", TPU_V5E, QUAD, "cluster", None),
            ("matmul", dict(TINY_MM), "float32", TPU_V5E, QUAD, "cluster", "psum"),
        ]
        _, d0 = at.cache_key(*base)
        for v in variants:
            assert at.cache_key(*v)[1] != d0, v

    def test_schema_version_enters_the_key(self, monkeypatch):
        _, d0 = at.cache_key("matmul", dict(TINY_MM), "float32", TPU_V5E)
        monkeypatch.setattr(at, "SCHEMA_VERSION", at.SCHEMA_VERSION + 1)
        _, d1 = at.cache_key("matmul", dict(TINY_MM), "float32", TPU_V5E)
        assert d0 != d1


# ---------------------------------------------------------------------------
# Cache file semantics
# ---------------------------------------------------------------------------


class TestCacheFile:
    def test_winner_persists_and_replays(self, cache, monkeypatch):
        monkeypatch.setattr(at, "_measure", _fake_measure([3.0, 1.0, 2.0] * 4))
        rep = at.tune("matmul", cache=cache, topk=3, **TINY_MM)
        assert not rep.cached and os.path.exists(cache.path)
        # A fresh instance (fresh process, same file) replays the winner.
        fresh = at.AutotuneCache(cache.path)
        rep2 = at.tune("matmul", cache=fresh, topk=3, **TINY_MM)
        assert rep2.cached
        assert rep2.schedule.blocks == rep.schedule.blocks
        assert rep2.schedule.grid == rep.schedule.grid

    def test_schema_mismatch_invalidates_file(self, cache, monkeypatch):
        monkeypatch.setattr(at, "_measure", _fake_measure([1.0] * 8))
        at.tune("matmul", cache=cache, topk=2, **TINY_MM)
        with open(cache.path) as fh:
            data = json.load(fh)
        data["schema"] = at.SCHEMA_VERSION - 1  # a past layout
        with open(cache.path, "w") as fh:
            json.dump(data, fh)
        fresh = at.AutotuneCache(cache.path)
        assert len(fresh) == 0
        assert at.lookup("matmul", dict(TINY_MM), cache=fresh) is None

    def test_corrupt_file_is_empty_not_fatal(self, cache, monkeypatch):
        with open(cache.path, "w") as fh:
            fh.write("{definitely not json")
        with pytest.warns(UserWarning, match="unreadable"):
            assert at.lookup("matmul", dict(TINY_MM), cache=cache) is None
        # resolve still answers — with the modeled argmin.
        s = at.resolve("matmul", dict(TINY_MM), policy="cache-only",
                       cache=at.AutotuneCache(cache.path))
        assert s == planner_for("matmul", TPU_V5E).plan(**TINY_MM)
        # ...and tuning over the corpse rewrites a valid file.
        monkeypatch.setattr(at, "_measure", _fake_measure([1.0] * 8))
        rewrite = at.AutotuneCache(cache.path)
        with pytest.warns(UserWarning, match="unreadable"):
            rep = at.tune("matmul", cache=rewrite, topk=2, **TINY_MM)
        assert not rep.cached
        with open(cache.path) as fh:
            assert json.load(fh)["schema"] == at.SCHEMA_VERSION

    def test_cache_only_never_times(self, cache, monkeypatch):
        """The cache-only policy must be side-effect free: no kernel ever
        launches, a miss just yields the planner's argmin."""
        def boom(fn, iters=3, warmup=1):
            raise AssertionError("cache-only policy measured a candidate")

        monkeypatch.setattr(at, "_measure", boom)
        s = at.resolve("matmul", dict(TINY_MM), policy="cache-only",
                       cache=cache)
        assert s == planner_for("matmul", TPU_V5E).plan(**TINY_MM)
        assert len(cache) == 0 and not os.path.exists(cache.path)


# ---------------------------------------------------------------------------
# Candidate enumeration
# ---------------------------------------------------------------------------


class TestCandidates:
    def test_local_first_candidate_is_the_argmin(self):
        for op, shape in (("conv2d", TINY_CONV), ("matmul", TINY_MM),
                          ("conv2d_wgrad", {k: v for k, v in TINY_CONV.items()
                                            if k != "pool"}),
                          ("matmul_dx", TINY_MM), ("matmul_dw", TINY_MM)):
            p = planner_for(op, TPU_V5E)
            cands = p.candidates(**shape)
            assert cands, op
            assert cands[0].blocks == p.plan(**shape).blocks, op
            words = [c.modeled_words for c in cands]
            assert words == sorted(words), op
            assert all(c.fits(TPU_V5E) for c in cands), op

    def test_quadrant_enumerates_the_strategies(self):
        p = planner_for("matmul", MANTICORE, QUAD, "cluster")
        cands = p.candidates(**FC6)
        strategies = [c.strategy for c in cands]
        assert set(strategies) >= {"ring", "psum", "batch"}
        # The modeled argmin (the ring on this cell, DESIGN.md Sec. 5)
        # ranks first; a strategy pin collapses the enumeration.
        assert strategies[0] == p.plan(**FC6).strategy == "ring"
        pinned = planner_for("matmul", MANTICORE, QUAD, "cluster",
                             "psum").candidates(**FC6)
        assert [c.strategy for c in pinned] == ["psum"]


# ---------------------------------------------------------------------------
# Tuned winners reach the kernels
# ---------------------------------------------------------------------------


class TestWinnerExecution:
    def test_tuned_winner_reaches_the_kernel(self, cache, monkeypatch):
        """Spy on the matmul op's impl: under cache-only policy the
        schedule handed to the kernel is the *measured* winner, not the
        modeled argmin (scripted times make a non-argmin candidate win)."""
        argmin = planner_for("matmul", TPU_V5E).plan(**TINY_MM)
        n = len(planner_for("matmul", TPU_V5E).candidates(**TINY_MM))
        assert n >= 2, "need a real choice for this test"
        # Scripted stopwatch: candidates get faster down the ranking, so
        # the LAST (most-words) candidate wins.
        monkeypatch.setattr(at, "_measure",
                            _fake_measure([float(n - i) for i in range(n)]))
        rep = at.tune("matmul", cache=cache, topk=n, **TINY_MM)
        assert rep.schedule.blocks != argmin.blocks

        monkeypatch.setattr(at, "_CACHE_PATH", cache.path)
        op = get_op("matmul")
        seen = {}
        orig = op.impl

        def spy_impl(*arrays, schedule, **kw):
            seen["schedule"] = schedule
            return orig(*arrays, schedule=schedule, **kw)

        monkeypatch.setitem(_OPS, "matmul",
                            dataclasses.replace(op, impl=spy_impl))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((16, 64)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((64, 256)), jnp.float32)
        out = _OPS["matmul"](x, w, autotune="cache-only")
        assert seen["schedule"].blocks == rep.schedule.blocks
        assert seen["schedule"].blocks != argmin.blocks
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(x) @ np.asarray(w),
                                   rtol=1e-4, atol=1e-4)

    def test_backward_cells_tune_and_replay(self, cache, monkeypatch):
        """The backward ops go through the same path: dgrad/dx cells tune,
        cache, and fc plan_bwd resolves the cached winners."""
        monkeypatch.setattr(at, "_measure", _fake_measure([2.0, 1.0] * 20))
        for op, shape in (("matmul_dx", TINY_MM), ("matmul_dw", TINY_MM),
                          ("conv2d_dgrad",
                           dict(H_O=8, W_O=8, F=3, S=1, P=1, d_in=8,
                                d_out=16, in_bytes=4, batch=2))):
            rep = at.tune(op, cache=cache, topk=2, **shape)
            rep2 = at.tune(op, cache=cache, topk=2, **shape)
            assert rep2.cached and rep2.schedule.blocks == rep.schedule.blocks

        from repro.core import fc_layer as fl

        monkeypatch.setattr(at, "_CACHE_PATH", cache.path)
        bwd = fl.plan_bwd((16, 64), (64, 256), autotune="cache-only")
        want_dx = at.lookup("matmul_dx", dict(TINY_MM), cache=cache)
        assert bwd["dx"].blocks == want_dx.blocks

    def test_plan_helpers_off_policy_unchanged(self):
        """autotune=None/off keeps every plan helper byte-identical to
        the planner argmin (the no-autotune contract)."""
        from repro.core import conv_layer as cl

        x_shape, f_shape = (2, 8, 8, 8), (3, 3, 8, 16)
        a = cl.plan(x_shape, f_shape, padding=1, pool=2)
        b = cl.plan(x_shape, f_shape, padding=1, pool=2, autotune="off")
        assert a == b


# ---------------------------------------------------------------------------
# The paper's FC6 cell over the MANTICORE quadrant (acceptance)
# ---------------------------------------------------------------------------


class TestQuadrantTuning:
    def test_fc6_measures_psum_and_ring_and_caches(self, cache):
        """tune() on FC6 over the 16-cluster quadrant really times both
        the Alg-4 psum and Alg-3 ring dataflows (per-device proxy: no
        16-device host here) and its winner replays from the cache."""
        rep = at.tune("matmul", machine=MANTICORE, mesh=QUAD, axis="cluster",
                      topk=3, iters=1, warmup=0, cache=cache, **FC6)
        assert not rep.cached
        kinds = {label.split(":")[0] for label, _, _ in rep.measurements}
        assert {"psum", "ring"} <= kinds
        assert all(us > 0 for _, us, _ in rep.measurements)
        assert isinstance(rep.schedule, ShardedSchedule)

        rep2 = at.tune("matmul", machine=MANTICORE, mesh=QUAD, axis="cluster",
                       topk=3, iters=1, warmup=0, cache=cache, **FC6)
        assert rep2.cached
        assert rep2.schedule.strategy == rep.schedule.strategy
        assert rep2.schedule.schedule.blocks == rep.schedule.schedule.blocks
        # ...and resolution under cache-only hands back the same winner.
        got = at.resolve("matmul", dict(FC6), machine=MANTICORE, mesh=QUAD,
                         axis="cluster", policy="cache-only", cache=cache)
        assert got.strategy == rep.schedule.strategy


SHARDED_SPY = """
import sys
sys.path.insert(0, {root!r} + "/src")
import dataclasses
import numpy as np
import jax.numpy as jnp
from repro.core.fc_layer import fc_layer_sharded
from repro.core.machine import TPU_V5E
from repro.core.shard_compat import make_auto_mesh
from repro.plan import MeshSpec, planner_for
from repro.plan import autotune as at
from repro.plan.registry import _OPS, get_op

M, K, N = 8, 64, 32
shape = dict(m=M, n=N, k=K, in_bytes=4)
spec = MeshSpec((("model", 4),))
cache = at.AutotuneCache({cache!r})

# Scripted stopwatch: the LAST-ranked strategy wins, so the cached winner
# provably differs from the modeled argmin the planner would re-derive.
cands = planner_for("matmul", TPU_V5E, spec, "model").candidates(**shape)
assert len(cands) >= 2, cands
times = [float(len(cands) - i) for i in range(len(cands))]
at._measure = lambda fn, iters=3, warmup=1: times.pop(0)
rep = at.tune("matmul", mesh=spec, axis="model", topk=len(cands),
              cache=cache, **shape)
assert not rep.cached
assert rep.schedule.strategy == cands[-1].strategy
assert rep.schedule.strategy != cands[0].strategy

# Next run: cache-only policy, live 4-device mesh, spy on the sharded impl.
at.set_policy("cache-only", {cache!r})
op = get_op("matmul")
seen = {{}}
orig = op.sharded_impl
def spy(*arrays, schedule, **kw):
    seen["schedule"] = schedule
    return orig(*arrays, schedule=schedule, **kw)
_OPS["matmul"] = dataclasses.replace(op, sharded_impl=spy)

mesh = make_auto_mesh((4,), ("model",))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
w = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
with mesh:
    out = fc_layer_sharded(x, w, mesh, axis="model", strategy=None)
got = seen["schedule"]
assert got.strategy == rep.schedule.strategy, (got.strategy,
                                               rep.schedule.strategy)
assert got.schedule.blocks == rep.schedule.schedule.blocks
np.testing.assert_allclose(np.asarray(out),
                           np.asarray(x) @ np.asarray(w),
                           rtol=1e-4, atol=1e-4)
print("executed", got.strategy)
"""


def test_fc_layer_sharded_executes_cached_winner(tmp_path):
    """End to end on a forced 4-device host mesh (subprocess, like
    tests/test_distributed.py): tune the cell, then a fresh
    ``fc_layer_sharded`` run under cache-only policy hands the *cached*
    winner — not the modeled argmin — to the registry's sharded impl."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    script = SHARDED_SPY.format(root=ROOT,
                                cache=str(tmp_path / "autotune.json"))
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "executed" in r.stdout
