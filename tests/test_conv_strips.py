"""Batched, strip-tiled conv2d pipeline with fused epilogue (DESIGN.md
Sec. 2): kernel parity vs the XLA oracle across batching / odd channels /
padding / stride / ragged strips, gradient checks for the fused
``conv_block`` custom_vjp, and the strip-tiled traffic model cross-checked
against the executed-schedule simulator."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ccr
from repro.core import schedule_sim as sim
from repro.core.conv_layer import conv_block, conv_layer, traffic
from repro.core.machine import MANTICORE
from repro.kernels.conv2d import conv2d, conv2d_fused_ref, conv2d_ref

TOLS = {jnp.float32: dict(rtol=1e-5, atol=1e-5), jnp.bfloat16: dict(rtol=1e-2, atol=1e-2)}


def _rand(rng, shape, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


def _close(got, want, dtype=jnp.float32):
    assert got.shape == want.shape, (got.shape, want.shape)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **TOLS[dtype]
    )


class TestBatchedStripKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B", [1, 3, 8])
    def test_batched_single_call_parity(self, B, dtype):
        """One pallas_call serves the whole batch (batch is a grid axis)."""
        rng = np.random.default_rng(B)
        x = _rand(rng, (B, 10, 10, 6), dtype)
        f = _rand(rng, (3, 3, 6, 8), dtype)
        got = conv2d(x, f, padding=1, block_do=4, block_di=3, block_h=4)
        _close(got, conv2d_ref(x, f, padding=1), dtype)

    @pytest.mark.parametrize(
        "H,di,do,F,P,S,hb",
        [
            (11, 7, 5, 3, 1, 1, 4),   # odd channels, strip !| H_O
            (13, 3, 9, 5, 2, 1, 5),   # F=5, strip !| H_O
            (9, 2, 3, 3, 1, 2, 2),    # stride 2 in-kernel, strips
            (12, 4, 4, 3, 0, 3, 2),   # stride 3, no padding
            (8, 5, 7, 1, 0, 1, 8),    # pointwise conv, single strip
        ],
    )
    def test_shape_matrix(self, H, di, do, F, P, S, hb):
        rng = np.random.default_rng(H * 100 + di * 10 + do + F + P + S)
        x = _rand(rng, (2, H, H, di))
        f = _rand(rng, (F, F, di, do))
        got = conv2d(x, f, stride=S, padding=P, block_do=2, block_di=2, block_h=hb)
        _close(got, conv2d_ref(x, f, stride=S, padding=P))

    def test_chooser_defaults_parity(self):
        """With no blocks given, ConvPlanner picks (block_h, Delta_O)."""
        rng = np.random.default_rng(7)
        x = _rand(rng, (2, 16, 16, 8))
        f = _rand(rng, (5, 5, 8, 16))
        _close(conv2d(x, f, padding=2), conv2d_ref(x, f, padding=2))

    def test_unbatched_matches_batched(self):
        rng = np.random.default_rng(8)
        x = _rand(rng, (10, 10, 4))
        f = _rand(rng, (3, 3, 4, 6))
        a = conv2d(x, f, padding=1, block_do=3, block_di=2, block_h=5)
        b = conv2d(x[None], f, padding=1, block_do=3, block_di=2, block_h=5)[0]
        _close(a, b)


class TestFusedEpilogue:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_bias_relu_pool_fused(self, dtype):
        rng = np.random.default_rng(20)
        x = _rand(rng, (4, 12, 12, 6), dtype)
        f = _rand(rng, (3, 3, 6, 8), dtype)
        b = _rand(rng, (8,), np.float32)
        got = conv2d(x, f, padding=1, bias=b, relu=True, pool=2,
                     block_do=4, block_di=3, block_h=4)
        _close(got, conv2d_fused_ref(x, f, b, padding=1, relu=True, pool=2), dtype)

    def test_odd_plane_pool_tail(self):
        """Odd H_O/W_O can't tile the fused 2x2 pool; bias+ReLU stay fused
        and the ragged pool runs as a tail op with floor semantics."""
        rng = np.random.default_rng(21)
        x = _rand(rng, (2, 9, 9, 4))
        f = _rand(rng, (3, 3, 4, 6))
        b = _rand(rng, (6,), np.float32)
        got = conv2d(x, f, padding=1, bias=b, relu=True, pool=2,
                     block_do=3, block_di=2)
        _close(got, conv2d_fused_ref(x, f, b, padding=1, relu=True, pool=2))

    def test_strided_fused(self):
        rng = np.random.default_rng(22)
        x = _rand(rng, (2, 17, 17, 4))
        f = _rand(rng, (3, 3, 4, 6))
        b = _rand(rng, (6,), np.float32)
        got = conv2d(x, f, stride=2, padding=1, bias=b, relu=True, pool=2,
                     block_do=3, block_di=2, block_h=4)
        _close(got, conv2d_fused_ref(x, f, b, stride=2, padding=1, relu=True, pool=2))


class TestConvBlockVjp:
    def test_conv_block_forward(self):
        rng = np.random.default_rng(30)
        x = _rand(rng, (2, 8, 8, 4))
        f = _rand(rng, (3, 3, 4, 6))
        b = _rand(rng, (6,), np.float32)
        got = conv_block(x, f, b, 1, 1, 2, "strip")
        _close(got, conv2d_fused_ref(x, f, b, padding=1, relu=True, pool=2))

    def test_conv_block_grads_match_xla(self):
        """custom_vjp of the fused block == autodiff of the pure-XLA ref."""
        rng = np.random.default_rng(31)
        x = _rand(rng, (2, 8, 8, 4))
        f = _rand(rng, (3, 3, 4, 6))
        b = _rand(rng, (6,), np.float32)

        def loss_kern(x, f, b):
            return jnp.sum(conv_block(x, f, b, 1, 1, 2, "strip") ** 2)

        def loss_ref(x, f, b):
            return jnp.sum(
                conv2d_fused_ref(x, f, b, padding=1, relu=True, pool=2) ** 2
            )

        gk = jax.grad(loss_kern, argnums=(0, 1, 2))(x, f, b)
        gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, f, b)
        for a, c in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                       rtol=1e-4, atol=1e-4)
            assert jnp.isfinite(a).all()

    def test_conv_layer_still_differentiable(self):
        rng = np.random.default_rng(32)
        x = _rand(rng, (7, 7, 3))
        f = _rand(rng, (3, 3, 3, 4))
        g = jax.grad(lambda xx: jnp.sum(conv_layer(xx, f, 1, 1, "alg2")))(x)
        gr = jax.grad(lambda xx: jnp.sum(conv2d_ref(xx, f, padding=1)))(x)
        np.testing.assert_allclose(np.asarray(g), np.asarray(gr), rtol=1e-4, atol=1e-4)


class TestStripTrafficModel:
    S = ccr.ConvShape(W_I=32, D_I=128, D_O=128, F=3, S=1, P=1)

    @pytest.mark.parametrize("hb", [32, 16, 8, 5, 3, 1])
    @pytest.mark.parametrize("stack", [24, 7, 128])
    def test_closed_form_equals_simulator(self, hb, stack):
        assert ccr.alg2_strip_traffic(self.S, stack, hb) == sim.simulate_alg2_strip(
            self.S, stack, hb
        )

    def test_degenerates_to_eq7_at_full_plane(self):
        """h_block = H_O is exactly Alg 2 / Eq. (7)."""
        for stack in (1, 12, 24, 128):
            assert ccr.alg2_strip_traffic(self.S, stack, 32) == ccr.alg2_traffic(
                self.S, stack
            )

    def test_strided_shape_simulates(self):
        s = ccr.ConvShape(W_I=33, D_I=16, D_O=32, F=3, S=2, P=1)
        for hb in (17, 8, 4, 3):
            assert ccr.alg2_strip_traffic(s, 8, hb) == sim.simulate_alg2_strip(s, 8, hb)

    def test_capacity_tradeoff(self):
        """Shrinking the strip grows the Delta_O the capacity rule allows
        (Sec. 2.2.2 argument, now two-dimensional), and the strip working
        set is never above the full-plane one."""
        full = ccr.alg2_strip_max_stack(self.S, MANTICORE, "sp", 32)
        half = ccr.alg2_strip_max_stack(self.S, MANTICORE, "sp", 16)
        eighth = ccr.alg2_strip_max_stack(self.S, MANTICORE, "sp", 4)
        assert full == ccr.alg2_max_stack(self.S, MANTICORE, "sp")
        assert full < half < eighth
        assert (
            ccr.alg2_strip_space_words(self.S, 24, 8)
            < ccr.alg2_space_words(self.S, 24)
        )

    def test_traffic_strategy_hook(self):
        t = traffic(self.S, "strip", "sp", h_block=16)
        assert t.main_words > 0 and t.macs == ccr.conv_macs(self.S)

    def test_choose_schedule_fits_and_trades(self):
        """The TPU planner returns a working set that fits VMEM and prefers
        full-plane strips when they fit."""
        from repro.core.machine import TPU_V5E
        from repro.plan import ConvPlanner

        sched = ConvPlanner(TPU_V5E).plan(
            H_O=32, W_O=32, F=3, S=1, d_in=128, d_out=256,
            in_bytes=4, block_di=128,
        )
        hb, bdo = sched.block("block_h"), sched.block("block_do")
        assert hb % 1 == 0 and bdo % 128 == 0
        assert sched.fits(TPU_V5E)
        # a plane too large for VMEM at any stack forces a partial strip
        sched2 = ConvPlanner(TPU_V5E).plan(
            H_O=4096, W_O=4096, F=3, S=1, d_in=128, d_out=256,
            in_bytes=4, block_di=512,
        )
        assert sched2.block("block_h") < 4096
