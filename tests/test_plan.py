"""The repro.plan scheduling layer (DESIGN.md Secs. 3 and 5).

Covers the ISSUE acceptance criteria: planner picks are lane-aligned and
fit the machine budget; ConvPlanner reproduces the paper's Delta_O <= 24/12
on MANTICORE (core/ccr.py parity) and the recorded pre-plan chooser picks
on TPU_V5E; planner-emitted modeled words equal ccr.alg2_strip_traffic on
the strip schedule; an explicit Schedule round-trips through
conv2d/fc_matmul; and the mesh-aware planners' ShardedSchedules pin their
HBM/ICI word split against the executed schedule_sim walkers, with the
1-device mesh degenerating to today's Schedules exactly.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core import ccr
from repro.core import schedule_sim as sim
from repro.core.machine import MANTICORE, TPU_V5E, word_bytes
from repro.kernels.conv2d import conv2d, conv2d_ref
from repro.kernels.matmul import fc_matmul, fc_matmul_ref
from repro.plan import (
    AttentionPlanner,
    ConvPlanner,
    ConvWgradPlanner,
    MatmulDwPlanner,
    MatmulPlanner,
    MeshSpec,
    Planner,
    Schedule,
    ShardedSchedule,
    get_op,
    local_schedule,
    planner_for,
    registered_ops,
    to_roofline,
)

S32 = ccr.ConvShape(W_I=32, D_I=128, D_O=128, F=3, S=1, P=1)


def _rand(rng, shape, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


# ---------------------------------------------------------------------------
# Manticore parity: ccr quotes and device plans from the same code path
# ---------------------------------------------------------------------------


class TestManticoreParity:
    @pytest.mark.parametrize("prec,want", [("sp", 24), ("dp", 12)])
    def test_paper_delta_o(self, prec, want):
        """ConvPlanner at the full-plane strip == the paper's Sec. 2.2.2
        capacity rule: Delta_O = 24 (sp) / 12 (dp) on the running example."""
        sched = ConvPlanner(MANTICORE).plan(
            H_O=32, W_O=32, F=3, S=1, d_in=128, d_out=128,
            in_bytes=word_bytes(prec), padding=1, H_I=32, W_I=32, block_h=32,
        )
        assert sched.block("block_do") == want
        assert sched.block("block_do") == ccr.alg2_max_stack(S32, MANTICORE, prec)
        assert sched.fits(MANTICORE)
        # Full-plane strip words degenerate to Eq. (7) exactly.
        assert sched.modeled_words == ccr.alg2_traffic(S32, want).main_words

    @pytest.mark.parametrize("block_h", [32, 16, 8, 5])
    def test_strip_words_match_ccr(self, block_h):
        """Planner-emitted modeled words == ccr.alg2_strip_traffic at any
        pinned strip height (the acceptance criterion)."""
        sched = ConvPlanner(MANTICORE).plan(
            H_O=32, W_O=32, F=3, S=1, d_in=128, d_out=128,
            in_bytes=4, padding=1, H_I=32, W_I=32, block_h=block_h,
        )
        t = ccr.alg2_strip_traffic(S32, sched.block("block_do"), block_h)
        assert sched.modeled_words == t.main_words
        assert sched.loads == t.main_loads and sched.stores == t.main_stores

    def test_strip_words_match_ccr_auto_and_strided(self):
        """Parity holds when the planner chooses the strip itself, and on a
        strided shape."""
        sched = ConvPlanner(MANTICORE).plan(
            H_O=32, W_O=32, F=3, S=1, d_in=128, d_out=128,
            in_bytes=4, padding=1, H_I=32, W_I=32,
        )
        hb, bdo = sched.block("block_h"), sched.block("block_do")
        assert sched.modeled_words == ccr.alg2_strip_traffic(S32, bdo, hb).main_words

        s2 = ccr.ConvShape(W_I=33, D_I=16, D_O=32, F=3, S=2, P=1)
        sched2 = ConvPlanner(MANTICORE).plan(
            H_O=s2.W_O, W_O=s2.W_O, F=3, S=2, d_in=16, d_out=32,
            in_bytes=4, padding=1, H_I=33, W_I=33, block_h=4,
        )
        t2 = ccr.alg2_strip_traffic(s2, sched2.block("block_do"), 4)
        assert sched2.modeled_words == t2.main_words

    @pytest.mark.parametrize("prec,want", [("sp", 768), ("dp", 384)])
    def test_fc_delta_o(self, prec, want):
        """MatmulPlanner's block_n growth on MANTICORE == alg45_max_stack:
        D_O <= 768 (sp) / 384 (dp) at B = 32 (paper Sec. 3.1.2)."""
        fc = ccr.FCShape(W_I=7, D_I=512, D_O=4096, B=32)
        sched = MatmulPlanner(MANTICORE).plan(
            m=32, n=4096, k=7 * 7 * 512, in_bytes=word_bytes(prec)
        )
        assert sched.block("block_n") == want
        assert sched.block("block_n") == ccr.alg45_max_stack(fc, MANTICORE, prec)
        assert sched.fits(MANTICORE)


# ---------------------------------------------------------------------------
# TPU parity: the planners reproduce the pre-plan choosers' picks
# ---------------------------------------------------------------------------


class TestTpuParity:
    # (H_O, W_O, F, S, d_in, d_out, in_bytes, block_di, pool) -> (hb, bdo),
    # recorded from the pre-refactor choose_schedule on this machine model.
    OLD_CONV_PICKS = {
        (32, 32, 3, 1, 128, 256, 4, 128, 1): (32, 256),
        (32, 32, 3, 1, 64, 512, 2, 128, 2): (32, 512),
        (112, 112, 7, 2, 3, 64, 4, 128, 1): (56, 128),
        (224, 224, 3, 1, 64, 64, 2, 128, 1): (224, 128),
        # Deliberate divergence from the old chooser: its strip candidates
        # stopped at H_O/64, so on this plane it emitted a non-fitting
        # (8, 128) fallback; the planner keeps halving to the pool floor
        # and finds the single-row strip that actually fits VMEM.
        (4096, 4096, 3, 1, 128, 256, 4, 512, 1): (1, 128),
        (16, 16, 5, 1, 8, 16, 4, 128, 1): (16, 128),
        (56, 56, 3, 1, 256, 256, 2, 256, 1): (56, 256),
    }
    # (m, n, k, in_bytes) -> (bm, bn, bk), recorded from choose_blocks.
    OLD_MM_PICKS = {
        (4096, 16384, 8192, 2): (512, 2048, 512),
        (128, 256, 512, 4): (128, 256, 512),
        (32, 4096, 25088, 4): (128, 2048, 512),
        (1, 300, 17, 4): (128, 384, 128),
    }

    def test_conv_planner_reproduces_old_picks(self):
        for (H_O, W_O, F, S, di, do, ib, bdi, pool), want in self.OLD_CONV_PICKS.items():
            sched = ConvPlanner(TPU_V5E).plan(
                H_O=H_O, W_O=W_O, F=F, S=S, d_in=di, d_out=do,
                in_bytes=ib, block_di=bdi, pool=pool,
            )
            assert (sched.block("block_h"), sched.block("block_do")) == want
            assert sched.fits(TPU_V5E)

    def test_matmul_planner_reproduces_old_picks(self):
        for (m, n, k, ib), want in self.OLD_MM_PICKS.items():
            sched = MatmulPlanner(TPU_V5E).plan(m=m, n=n, k=k, in_bytes=ib)
            got = (sched.block("block_m"), sched.block("block_n"),
                   sched.block("block_k"))
            assert got == want


# ---------------------------------------------------------------------------
# Schedule properties: lane alignment, budget, model consistency
# ---------------------------------------------------------------------------

CONV_GRID = [
    (32, 32, 3, 1, 16, 64, 2, 1),
    (15, 15, 5, 1, 7, 40, 4, 1),
    (64, 64, 3, 2, 32, 128, 2, 2),
    (224, 224, 7, 2, 3, 64, 4, 1),
    (512, 512, 3, 1, 256, 512, 2, 2),
    (4096, 4096, 3, 1, 128, 256, 4, 1),  # only fits at single-row strips
    (9, 9, 1, 1, 3, 5, 4, 1),
]


class TestScheduleProperties:
    @pytest.mark.parametrize("H_O,W_O,F,S,di,do,ib,pool", CONV_GRID)
    def test_conv_schedules_aligned_and_fit(self, H_O, W_O, F, S, di, do, ib, pool):
        m = TPU_V5E
        sched = ConvPlanner(m).plan(
            H_O=H_O, W_O=W_O, F=F, S=S, d_in=di, d_out=do, in_bytes=ib, pool=pool
        )
        hb, bdo, bdi = (sched.block("block_h"), sched.block("block_do"),
                        sched.block("block_di"))
        assert bdo % m.lane == 0 and bdi % m.lane == 0
        assert hb % pool == 0 and 0 < hb <= -(-H_O // pool) * pool + pool
        assert sched.fits(m), "auto plans on fitting shapes must fit VMEM"
        assert sched.grid[1] == -(-H_O // hb)
        assert sched.modeled_words == sched.loads + sched.stores > 0
        assert sched.macs > 0 and sched.vmem_bytes > 0

    @pytest.mark.parametrize(
        "m,n,k,ib", [(8, 8, 8, 4), (37, 70, 90, 2), (4096, 16384, 8192, 2),
                     (1, 300, 17, 4), (130, 129, 257, 4)]
    )
    def test_matmul_schedules_aligned_and_fit(self, m, n, k, ib):
        sched = MatmulPlanner(TPU_V5E).plan(m=m, n=n, k=k, in_bytes=ib)
        for name in ("block_m", "block_n", "block_k"):
            assert sched.block(name) % TPU_V5E.lane == 0
        assert sched.fits(TPU_V5E)
        assert len(sched.grid) == 3 and all(g > 0 for g in sched.grid)

    @pytest.mark.parametrize("machine", [TPU_V5E, MANTICORE])
    @pytest.mark.parametrize("sq,skv,d", [(300, 300, 64), (33, 47, 16), (8, 2048, 128)])
    def test_attention_schedules_aligned_and_fit(self, machine, sq, skv, d):
        sched = AttentionPlanner(machine).plan(
            seq_q=sq, seq_kv=skv, head_dim=d, n_q_heads=4, n_kv_heads=2,
            batch=2, in_bytes=4,
        )
        assert sched.block("block_q") % 8 == 0
        assert sched.block("block_kv") % 8 == 0
        assert sched.fits(machine), "auto attention plans shrink to fit"

    @settings(max_examples=20, deadline=None)
    @given(st.integers(2, 300), st.integers(1, 512), st.integers(1, 1024),
           st.sampled_from([1, 3, 5, 7]), st.sampled_from([1, 2]),
           st.sampled_from([2, 4]))
    def test_property_conv_plan_always_legal(self, H_O, di, do, F, S, ib):
        """Whatever the shape, an auto conv plan is lane-aligned, within
        caps, and words-consistent with its own loads/stores split."""
        sched = ConvPlanner(TPU_V5E).plan(
            H_O=H_O, W_O=H_O, F=F, S=S, d_in=di, d_out=do, in_bytes=ib
        )
        assert sched.block("block_do") % TPU_V5E.lane == 0
        assert 0 < sched.block("block_h") <= H_O + 1
        assert sched.modeled_words == sched.loads + sched.stores
        if sched.fits(TPU_V5E):
            assert sched.vmem_bytes <= TPU_V5E.usable_for_working_set(2)

    def test_planner_protocol_and_registry(self):
        assert set(registered_ops()) >= {"conv2d", "matmul", "flash_attention"}
        for name in ("conv2d", "matmul", "flash_attention"):
            p = planner_for(name, TPU_V5E)
            assert isinstance(p, Planner) and p.op == name
            assert get_op(name).planner_for(TPU_V5E).op == name

    def test_to_roofline(self):
        sched = MatmulPlanner(TPU_V5E).plan(m=256, n=1024, k=512, in_bytes=4)
        roof = to_roofline(sched)
        assert roof.flops == 2 * sched.macs
        assert roof.bytes_hbm == sched.modeled_words * 4
        assert roof.t_memory > 0 and roof.bottleneck in ("compute", "memory")
        assert sched.bound_kind(TPU_V5E, "sp") in ("compute-bound", "memory-bound")


# ---------------------------------------------------------------------------
# Attention words validation: closed form == executed block walk (ROADMAP)
# ---------------------------------------------------------------------------


class TestAttentionWords:
    """AttentionPlanner's traffic model vs the schedule_sim block walker —
    the same closed-form == executed-count pin done for Algs 1-5, with the
    kernel's causal/window block-level skips included."""

    # Includes seq_q > seq_kv, where a small window leaves trailing q
    # blocks with zero KV fetches (the kernel's clamped BlockSpec pins one
    # residual fetch for such blocks — the model's documented +-1 boundary
    # slack; their rows are defined as zero output, flash_attention.py).
    CASES = [(256, 256, 64, 64), (120, 200, 32, 48), (8, 2048, 8, 128),
             (64, 64, 16, 24), (128, 64, 32, 32), (256, 40, 16, 8)]

    @pytest.mark.parametrize("sq,skv,bq,bkv", CASES)
    @pytest.mark.parametrize("causal,window", [(False, None), (True, None),
                                               (True, 64), (False, 33),
                                               (True, 7)])
    def test_closed_form_matches_walker(self, sq, skv, bq, bkv, causal, window):
        from repro.core import schedule_sim as sim

        sched = AttentionPlanner(TPU_V5E).plan(
            seq_q=sq, seq_kv=skv, head_dim=32, n_q_heads=2, n_kv_heads=1,
            batch=2, in_bytes=4, block_q=bq, block_kv=bkv,
            causal=causal, window=window)
        t = sim.simulate_attention_blocks(
            seq_q=sq, seq_kv=skv, head_dim=32, n_q_heads=2, batch=2,
            block_q=sched.block("block_q"), block_kv=sched.block("block_kv"),
            causal=causal, window=window)
        assert sched.loads == t.main_loads
        assert sched.stores == t.main_stores
        assert sched.macs == t.macs

    def test_dense_degenerates_to_upper_bound(self):
        """No mask -> the original dense closed form (q once per row block,
        every q block streams the whole padded KV twice)."""
        sched = AttentionPlanner(TPU_V5E).plan(
            seq_q=300, seq_kv=300, head_dim=64, n_q_heads=4, n_kv_heads=2,
            batch=2, in_bytes=4)
        bq, bkv = sched.block("block_q"), sched.block("block_kv")
        sqp = -(-300 // bq) * bq
        skvp = -(-300 // bkv) * bkv
        bhq = 2 * 4
        assert sched.loads == bhq * (sqp * 64 + (sqp // bq) * skvp * 64 * 2)
        assert sched.macs == bhq * sqp * skvp * 64 * 2

    def test_causal_and_window_reduce_words(self):
        kw = dict(seq_q=512, seq_kv=512, head_dim=32, block_q=64, block_kv=64)
        p = AttentionPlanner(TPU_V5E)
        dense = p.plan(**kw)
        causal = p.plan(**kw, causal=True)
        windowed = p.plan(**kw, causal=True, window=64)
        assert dense.loads > causal.loads > windowed.loads
        assert dense.macs > causal.macs > windowed.macs
        assert dense.stores == causal.stores == windowed.stores


# ---------------------------------------------------------------------------
# Explicit Schedule round-trips through the kernels (acceptance)
# ---------------------------------------------------------------------------


class TestExplicitScheduleRoundtrip:
    def test_conv2d_roundtrip(self):
        rng = np.random.default_rng(0)
        x = _rand(rng, (2, 10, 10, 6))
        f = _rand(rng, (3, 3, 6, 8))
        b = jnp.zeros((8,), jnp.float32)
        op = get_op("conv2d")
        auto = conv2d(x, f, padding=1)
        sched = op.plan(x, f, b, padding=1)
        via_sched = conv2d(x, f, padding=1, schedule=sched)
        np.testing.assert_array_equal(np.asarray(auto), np.asarray(via_sched))
        np.testing.assert_allclose(
            np.asarray(via_sched), np.asarray(conv2d_ref(x, f, padding=1)),
            rtol=2e-4, atol=2e-4,
        )
        # A hand-built schedule (non-default blocking) also runs & matches.
        hand = sched.evolve(block_h=3, block_do=2, block_di=3)
        np.testing.assert_allclose(
            np.asarray(conv2d(x, f, padding=1, schedule=hand)),
            np.asarray(conv2d_ref(x, f, padding=1)), rtol=2e-4, atol=2e-4,
        )
        # ... even a *partial* one: missing blocks default to legal sizes.
        partial = Schedule(op="conv2d", grid=(), blocks=(("block_do", 2),))
        np.testing.assert_allclose(
            np.asarray(conv2d(x, f, padding=1, schedule=partial)),
            np.asarray(conv2d_ref(x, f, padding=1)), rtol=2e-4, atol=2e-4,
        )

    def test_fc_matmul_roundtrip(self):
        rng = np.random.default_rng(1)
        x = _rand(rng, (37, 70))
        w = _rand(rng, (70, 90))
        op = get_op("matmul")
        sched = op.plan(x, w)
        auto = fc_matmul(x, w)
        via_sched = fc_matmul(x, w, schedule=sched)
        np.testing.assert_array_equal(np.asarray(auto), np.asarray(via_sched))
        np.testing.assert_allclose(
            np.asarray(via_sched), np.asarray(fc_matmul_ref(x, w)),
            rtol=2e-4, atol=2e-4,
        )

    def test_schedule_is_static_and_hashable(self):
        s1 = MatmulPlanner(TPU_V5E).plan(m=8, n=8, k=8, in_bytes=4)
        s2 = MatmulPlanner(TPU_V5E).plan(m=8, n=8, k=8, in_bytes=4)
        assert s1 == s2 and hash(s1) == hash(s2)
        assert isinstance(s1, Schedule)

    def test_layers_accept_schedule(self):
        from repro.core.conv_layer import conv_block, conv_layer
        from repro.core.conv_layer import plan as conv_plan
        from repro.core.fc_layer import fc_layer
        from repro.core.fc_layer import plan as fc_plan

        rng = np.random.default_rng(2)
        x = _rand(rng, (2, 8, 8, 4))
        f = _rand(rng, (3, 3, 4, 6))
        b = _rand(rng, (6,), np.float32)
        sched = conv_plan(x.shape, f.shape, padding=1, pool=2)
        got = conv_block(x, f, b, 1, 1, 2, "strip", sched)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(conv_block(x, f, b, 1, 1, 2, "strip"))
        )
        np.testing.assert_array_equal(
            np.asarray(conv_layer(x, f, 1, 1, "strip", conv_plan(x.shape, f.shape, padding=1))),
            np.asarray(conv_layer(x, f, 1, 1, "strip")),
        )
        xf = _rand(rng, (4, 24))
        wf = _rand(rng, (24, 16))
        np.testing.assert_array_equal(
            np.asarray(fc_layer(xf, wf, fc_plan(xf.shape, wf.shape))),
            np.asarray(fc_layer(xf, wf)),
        )

    def test_cnn_plan_forward(self):
        """models/cnn.plan_forward emits a fitting schedule per stage and
        forward(schedules=...) reproduces the planner-default numerics."""
        from repro.configs.base import ModelConfig
        from repro.models import cnn

        cfg = ModelConfig(name="t", family="cnn", n_layers=2, d_model=4,
                          d_ff=16, vocab=10)
        scheds = cnn.plan_forward(cfg, batch=2)
        assert set(scheds) == {"conv0", "conv1", "fc1", "fc2"}
        assert all(s.fits(TPU_V5E) for s in scheds.values())
        assert sum(s.modeled_words for s in scheds.values()) > 0

        rng = np.random.default_rng(3)
        params = {}
        for i, (ci, co) in enumerate([(3, 4), (4, 8)]):
            params[f"conv{i}"] = _rand(rng, (3, 3, ci, co))
            params[f"bias{i}"] = _rand(rng, (co,), np.float32)
        flat = 8 * 8 * 8
        params["fc1"] = _rand(rng, (flat, 16))
        params["fc1_b"] = _rand(rng, (16,), np.float32)
        params["fc2"] = _rand(rng, (16, 10))
        params["fc2_b"] = _rand(rng, (10,), np.float32)
        images = _rand(rng, (2, 32, 32, 3))
        a = cnn.forward(cfg, params, images)
        b = cnn.forward(cfg, params, images, schedules=scheds)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Sharded planning: partitioning as a planner output (DESIGN.md Sec. 5)
# ---------------------------------------------------------------------------

# The paper's running shapes on the 16-cluster L2 quadrant.
QUAD16 = MeshSpec((("cluster", 16),))
MESH4 = MeshSpec((("model", 4),))
MESH1 = MeshSpec((("model", 1),))
FC_SHAPE = dict(m=32, n=4096, k=25088, in_bytes=4)  # FC6-like, B=32


class TestShardedPlans:
    def test_one_device_mesh_degenerates_to_schedule(self):
        """A 1-device mesh must reproduce today's pinned Schedules exactly
        (wrapped in a trivial ShardedSchedule)."""
        base = ConvPlanner(MANTICORE).plan(
            H_O=32, W_O=32, F=3, S=1, d_in=128, d_out=128,
            in_bytes=4, padding=1, H_I=32, W_I=32, block_h=32)
        ss = ConvPlanner(MANTICORE, MESH1).plan(
            H_O=32, W_O=32, F=3, S=1, d_in=128, d_out=128,
            in_bytes=4, padding=1, H_I=32, W_I=32, block_h=32)
        assert isinstance(ss, ShardedSchedule)
        assert ss.schedule == base and local_schedule(ss) == base
        assert ss.strategy == "single" and ss.devices == 1
        assert ss.block("block_do") == 24  # the paper's Delta_O, unchanged
        assert (ss.hbm_loads, ss.hbm_stores) == (base.loads, base.stores)
        assert ss.ici_words == 0 and ss.macs == base.macs
        assert ss.modeled_words == base.modeled_words

        mb = MatmulPlanner(TPU_V5E).plan(**FC_SHAPE)
        ms = MatmulPlanner(TPU_V5E, MESH1).plan(**FC_SHAPE)
        assert ms.schedule == mb and ms.ici_words == 0
        assert ms.hbm_words == mb.modeled_words

    def test_one_device_strategy_pin_degenerates(self):
        """Pinning psum/ring on a 1-wide group degenerates to single —
        sharded call sites must keep working on one device."""
        for pin in ("psum", "ring"):
            ss = MatmulPlanner(TPU_V5E, MESH1, "model", pin).plan(**FC_SHAPE)
            assert ss.strategy == "single" and ss.ici_words == 0

    def test_manticore_quadrant_picks_ring(self):
        """On the paper's 16-cluster quadrant the argmin picks Alg 3's
        ring: its reuse converts ~1/3 of the psum strategy's main-memory
        words into neighbour hops — the Sec. 2.3 story, now a planner
        decision.  Counts pinned against ccr.ring_traffic."""
        ss = MatmulPlanner(MANTICORE, QUAD16, "cluster").plan(**FC_SHAPE)
        assert ss.strategy == "ring"
        assert ss.axis == "cluster" and ss.devices == 16
        t = ccr.ring_traffic(m=32, n=4096, k=25088, devices=16)
        assert (ss.hbm_loads, ss.hbm_stores) == (t.main_loads, t.main_stores)
        assert ss.ici_words == t.intercluster == 15 * 32 * 25088
        assert ss.macs == t.macs
        # vs the pinned psum alternative: ring moves fewer total words.
        ps = MatmulPlanner(MANTICORE, QUAD16, "cluster", "psum").plan(**FC_SHAPE)
        assert ps.strategy == "psum"
        assert ss.modeled_words < ps.modeled_words
        assert ss.hbm_words < ps.hbm_words  # the reuse is an HBM saving
        # partitioning is part of the plan: X K-sharded, W N-sharded, out
        # N-sharded for the ring; K/K/replicated for the psum.
        assert ss.partition == ((None, "cluster"), (None, "cluster"),
                                (None, "cluster"))
        assert ps.partition == ((None, "cluster"), ("cluster", None),
                                (None, None))

    def test_ring_words_equal_executed_walk(self):
        """modeled == simulated for the ring, at several mesh widths."""
        for devices in (2, 4, 16):
            mesh = MeshSpec((("model", devices),))
            ss = MatmulPlanner(MANTICORE, mesh, "model", "ring").plan(
                m=8, n=64, k=128, in_bytes=4)
            w = sim.simulate_ring(m=8, n=64, k=128, devices=devices)
            assert ss.hbm_loads == w.main_loads
            assert ss.hbm_stores == w.main_stores
            assert ss.ici_words == w.intercluster
            assert ss.macs == w.macs

    def test_psum_words_equal_executed_walk(self):
        ss = MatmulPlanner(TPU_V5E, MESH4, "model", "psum").plan(
            m=37, n=300, k=512, in_bytes=4)
        bd = ss.schedule.block_dict()
        w = sim.simulate_fc_psum(
            m=37, n=300, k=128, devices=4, block_m=bd["block_m"],
            block_n=bd["block_n"], block_k=bd["block_k"])
        # NB the walker takes the *local* k (the planner planned k/4).
        assert ss.hbm_loads == w.main_loads
        assert ss.hbm_stores == w.main_stores
        assert ss.ici_words == w.intercluster == ccr.tree_reduce_words(4, 37 * 300)

    def test_sharded_conv_words_equal_executed_walk(self):
        """The conv "batch" partition: mesh totals equal the per-device
        strip walks summed (and the unsharded words — pure data
        parallelism moves no extra HBM word)."""
        s = ccr.ConvShape(W_I=32, D_I=16, D_O=32, F=3, S=1, P=1)
        ss = ConvPlanner(MANTICORE, MeshSpec((("data", 4),)), "data").plan(
            H_O=32, W_O=32, F=3, S=1, d_in=16, d_out=32, in_bytes=4,
            padding=1, H_I=32, W_I=32, block_h=8, batch=8)
        assert ss.strategy == "batch"
        stack = ss.block("block_do")
        w = sim.simulate_sharded_conv_strip(s, stack, 8, devices=4,
                                            strategy="batch", batch=8)
        t = ccr.conv_sharded_traffic(s, stack, 8, devices=4,
                                     strategy="batch", batch=8)
        assert (ss.hbm_loads, ss.hbm_stores) == (w.main_loads, w.main_stores)
        assert (t.main_loads, t.main_stores) == (w.main_loads, w.main_stores)
        assert ss.ici_words == 0
        # == the unsharded schedule's words (data parallelism is free in
        # HBM terms; the win is 4x the bandwidth).
        base = ConvPlanner(MANTICORE).plan(
            H_O=32, W_O=32, F=3, S=1, d_in=16, d_out=32, in_bytes=4,
            padding=1, H_I=32, W_I=32, block_h=8, batch=8)
        assert ss.hbm_words == base.modeled_words

    def test_sharded_wgrad_charges_gradient_allreduce(self):
        """Data-parallel wgrad accumulates private dW per device: the
        sharded plan must charge the Alg-4 tree reduction as ici_words and
        one private dW store per device."""
        ss = ConvWgradPlanner(TPU_V5E, MeshSpec((("data", 4),)), "data").plan(
            H_O=8, W_O=8, F=3, d_in=8, d_out=16, in_bytes=4, batch=8,
            padding=1, H_I=8, W_I=8)
        assert ss.strategy == "batch"
        assert ss.ici_words == ccr.tree_reduce_words(4, 3 * 3 * 8 * 16)
        local = ss.schedule
        assert ss.hbm_stores == 4 * local.stores  # private dW per device
        dw = MatmulDwPlanner(TPU_V5E, MeshSpec((("data", 4),)), "data").plan(
            m=32, n=64, k=128, in_bytes=4)
        assert dw.strategy == "batch"
        assert dw.ici_words == ccr.tree_reduce_words(4, 128 * 64)

    def test_sharded_schedule_traffic_and_fits(self):
        ss = MatmulPlanner(MANTICORE, QUAD16, "cluster").plan(**FC_SHAPE)
        t = ss.traffic
        assert isinstance(t, ccr.Traffic)
        assert t.main_words == ss.hbm_words and t.intercluster == ss.ici_words
        assert t.ccr_offchip > t.ccr  # ring traffic is mostly on-chip
        assert ss.fits(MANTICORE) == ss.schedule.fits(MANTICORE)

    def test_plan_sharded_through_registry(self):
        """PallasOp.plan_sharded resolves the same cached ShardedSchedule
        the planner emits, from concrete operands."""
        rng = np.random.default_rng(0)
        x = _rand(rng, (8, 64))
        w = _rand(rng, (64, 40))
        op = get_op("matmul")
        ss = op.plan_sharded(x, w, mesh=MESH4, axis="model", strategy="ring")
        assert isinstance(ss, ShardedSchedule) and ss.strategy == "ring"
        ss2 = op.plan_sharded(x, w, mesh=MESH4, axis="model", strategy="ring")
        assert ss is ss2  # the plan cache covers sharded plans too
        # and a dict-shaped mesh resolves identically
        ss3 = op.plan_sharded(x, w, mesh={"model": 4}, axis="model",
                              strategy="ring")
        assert ss3 == ss

    def test_cnn_sharded_plan_training(self):
        """models/cnn.plan_training(mesh=) returns ShardedSchedules whose
        forward entries move no ICI words while wgrad/dw charge the
        gradient all-reduce; the 1-device mesh reproduces the meshless
        plans exactly."""
        from repro.configs.base import ModelConfig
        from repro.models import cnn

        cfg = ModelConfig(name="t", family="cnn", n_layers=2, d_model=4,
                          d_ff=16, vocab=10)
        mesh = MeshSpec((("data", 4),))
        scheds = cnn.plan_training(cfg, batch=8, mesh=mesh)
        assert all(isinstance(s, ShardedSchedule) for s in scheds.values())
        for name, s in scheds.items():
            if name.endswith(".wgrad") or name.endswith(".dw"):
                assert s.ici_words > 0, name  # gradient all-reduce
            elif name.startswith("conv") and "." not in name:
                assert s.strategy == "batch" and s.ici_words == 0, name
            elif "." not in name:  # FC forward: planner-chosen dataflow
                assert s.strategy in ("batch", "psum", "ring"), name
        base = cnn.plan_training(cfg, batch=8)
        one = cnn.plan_training(cfg, batch=8, mesh=MeshSpec((("data", 1),)))
        assert {k: s.schedule for k, s in one.items()} == base
