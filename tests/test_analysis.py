"""Analyzer correctness: trip-count handling, FLOPs exactness, collective
parsing, roofline classification."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo_cost
from repro.analysis.roofline import Roofline, collective_bytes, model_flops


def _hlo(fn, *avals):
    return jax.jit(fn).lower(*avals).compile().as_text()


class TestHloCost:
    def test_matmul_flops_exact(self):
        M, K, N = 128, 256, 512
        txt = _hlo(lambda a, b: a @ b,
                   jax.ShapeDtypeStruct((M, K), jnp.float32),
                   jax.ShapeDtypeStruct((K, N), jnp.float32))
        c = hlo_cost.analyze(txt)
        assert c.flops == pytest.approx(2 * M * K * N, rel=0.01)

    def test_scan_trip_count_multiplies(self):
        M, n = 64, 12

        def f(a, bs):
            return jax.lax.scan(lambda x, b: (x @ b, ()), a, bs)[0]

        txt = _hlo(f, jax.ShapeDtypeStruct((M, M), jnp.float32),
                   jax.ShapeDtypeStruct((n, M, M), jnp.float32))
        c = hlo_cost.analyze(txt)
        assert c.flops == pytest.approx(n * 2 * M**3, rel=0.02)
        assert c.unknown_trip_whiles == 0

    def test_nested_scan_trip_counts(self):
        M, n, m = 32, 5, 7

        def inner(x, bs):
            return jax.lax.scan(lambda y, b: (y @ b, ()), x, bs)[0]

        def f(a, bs):
            return jax.lax.scan(lambda x, _: (inner(x, bs), ()), a,
                                jnp.arange(n, dtype=jnp.float32))[0]

        txt = _hlo(f, jax.ShapeDtypeStruct((M, M), jnp.float32),
                   jax.ShapeDtypeStruct((m, M, M), jnp.float32))
        c = hlo_cost.analyze(txt)
        assert c.flops == pytest.approx(n * m * 2 * M**3, rel=0.05)

    def test_tuple_shapes_with_index_comments_parse(self):
        """Instructions whose tuple shapes contain /*index=N*/ comments must
        not be dropped (the original 30000x FLOPs undercount bug)."""
        comps = hlo_cost.parse_module(
            "%c (p: (s32[], f32[8])) -> s32[] {\n"
            "  %w.1 = (s32[], f32[8,8]{1,0}, f32[8]{0}, f32[8]{0}, f32[8]{0}, "
            "/*index=5*/f32[8]{0}) while(%t), condition=%c1, body=%b1\n"
            "}\n"
        )
        assert any(i.op == "while" for i in comps["c"])

    def test_shape_bytes(self):
        assert hlo_cost.shape_bytes("f32[4,8]{1,0}") == 128
        assert hlo_cost.shape_bytes("bf16[10]{0}") == 20
        assert hlo_cost.shape_bytes("(f32[2]{0}, s8[4]{0})") == 12

    def test_collective_regex(self):
        txt = ("  %ag = f32[64,32]{1,0} all-gather(%x), dimensions={0}\n"
               "  %ar = bf16[128]{0} all-reduce-start(%y)\n"
               "  %cp = f32[16]{0} collective-permute(%z)\n")
        out = collective_bytes(txt)
        assert out["all-gather"] == 64 * 32 * 4
        assert out["all-reduce"] == 128 * 2
        assert out["collective-permute"] == 64


class TestRoofline:
    def test_bottleneck_classification(self):
        r = Roofline(flops=197e12 * 256, bytes_hbm=1e9, bytes_coll=1e9,
                     chips=256, model_flops=197e12 * 256)
        assert r.bottleneck == "compute"
        assert r.t_compute == pytest.approx(1.0)
        assert r.roofline_fraction == pytest.approx(1.0)

    def test_memory_bound(self):
        r = Roofline(flops=1e12, bytes_hbm=819e9 * 256 * 5, bytes_coll=0,
                     chips=256, model_flops=1e12)
        assert r.bottleneck == "memory"
        assert r.t_bound == pytest.approx(5.0)

    def test_model_flops_conventions(self):
        assert model_flops("train", 1e9, 1e6) == 6e15
        assert model_flops("prefill", 1e9, 1e6) == 2e15
        assert model_flops("decode", 1e9, 128) == pytest.approx(2.56e11)
