"""The serving subsystem (src/repro/serve): bucket-ladder routing and
warmup resolution, the continuous-batching engine's bit-identity against
the reference greedy loop, the never-tune-at-request-time contract,
queue/deadline degradation, and load-generator determinism."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import smoke_config
from repro.models.module import init_params
from repro.models.registry import get_family, init_cache_slots
from repro.plan import MeshSpec, Schedule, ShardedSchedule
from repro.plan import autotune
from repro.runtime.serve import greedy_generate
from repro.serve import (
    DONE, QUEUED, SHED, TIMEOUT,
    Bucket, BucketLadder, Engine, LoadSpec, Request, RequestQueue,
    VirtualClock, bucket_cells, make_requests, run_load,
)


@pytest.fixture(scope="module")
def cfg():
    return smoke_config("qwen3-1.7b")


@pytest.fixture(scope="module")
def params(cfg):
    fam = get_family(cfg.family)
    base = init_params(fam.param_defs(cfg), jax.random.PRNGKey(0),
                       jnp.float32)
    # Perturb so greedy decoding produces *varied* token streams — an
    # untrained model repeating one token would make the bit-identity
    # test vacuous.
    rng = np.random.default_rng(7)
    return jax.tree.map(
        lambda l: jnp.asarray(
            np.asarray(l) + rng.standard_normal(l.shape).astype(np.float32) * 0.5),
        base)


def _boot(cfg, params, buckets, max_seq, **kw):
    kw.setdefault("queue_depth", 32)
    ladder = BucketLadder(buckets, max_seq=max_seq)
    engine = Engine(cfg, params, ladder, **kw)
    engine.warmup(policy="off")
    return engine


# ---------------------------------------------------------------------------
# BucketLadder: rungs, routing, warmup resolution
# ---------------------------------------------------------------------------


class TestBucketLadder:
    def test_rungs_sorted_and_deduped(self):
        lad = BucketLadder([(4, 16), (2, 8), Bucket(2, 8)], max_seq=32)
        assert lad.buckets == (Bucket(2, 8), Bucket(4, 16))
        assert lad.max_batch == 4 and lad.max_prompt == 16

    def test_validation(self):
        with pytest.raises(ValueError, match="at least one bucket"):
            BucketLadder([], max_seq=32)
        with pytest.raises(ValueError, match="exceeds max_seq"):
            BucketLadder([(2, 64)], max_seq=32)
        with pytest.raises(ValueError, match=">= 1"):
            Bucket(0, 8)

    def test_route_picks_smallest_covering_rung(self):
        lad = BucketLadder([(2, 8), (4, 16), (8, 16)], max_seq=32)
        assert lad.route(1, 5) == Bucket(2, 8)
        assert lad.route(2, 8) == Bucket(2, 8)
        # longer prompt forces the next seq rung even for few rows
        assert lad.route(1, 9) == Bucket(4, 16)
        # more rows than the small rung holds
        assert lad.route(3, 5) == Bucket(4, 16)
        assert lad.route(7, 12) == Bucket(8, 16)

    def test_route_widest_when_no_rung_has_enough_rows(self):
        lad = BucketLadder([(2, 8), (4, 16)], max_seq=32)
        # 9 rows fit nowhere: take the widest covering rung, admit 4 now.
        assert lad.route(9, 10) == Bucket(4, 16)

    def test_route_none_for_oversize_prompt(self):
        lad = BucketLadder([(2, 8), (4, 16)], max_seq=32)
        assert lad.route(1, 17) is None

    def test_bucket_cells_shapes(self, cfg):
        cells = bucket_cells(cfg, Bucket(2, 8), max_seq=32)
        assert set(cells) == {f"{p}.{c}" for p in ("prefill", "decode")
                              for c in ("qkv", "attn", "mlp", "logits")}
        op, shp = cells["prefill.qkv"]
        assert op == "matmul" and shp["m"] == 2 * 8 and shp["k"] == cfg.d_model
        op, shp = cells["decode.attn"]
        assert op == "flash_attention"
        assert shp["seq_q"] == 1 and shp["seq_kv"] == 32 and shp["causal"]
        # the logits head projects one position per row, not batch*seq
        assert cells["prefill.logits"][1]["m"] == 2

    def test_warmup_resolves_plans_and_model(self, cfg):
        lad = BucketLadder([(2, 8), (4, 16)], max_seq=24)
        with pytest.raises(RuntimeError, match="warmup"):
            lad.modeled_words(Bucket(2, 8), "prefill")
        sources = lad.warmup(cfg, policy="off")
        assert lad.planned
        for b in lad.buckets:
            assert all(isinstance(p, Schedule) for p in lad.plans[b].values())
            assert set(sources[b].values()) <= {"modeled"}  # policy off
            for phase in ("prefill", "decode"):
                assert lad.modeled_words(b, phase) > 0
                assert lad.modeled_seconds(b, phase) > 0
        # prefill moves more words than single-token decode
        assert (lad.modeled_words(Bucket(4, 16), "prefill")
                > lad.modeled_words(Bucket(4, 16), "decode"))

    def test_warmup_on_mesh_resolves_sharded_schedules(self, cfg):
        lad = BucketLadder([(2, 8)], max_seq=16,
                           mesh=MeshSpec((("model", 4),)), axis="model")
        lad.warmup(cfg, policy="off")
        plans = lad.plans[Bucket(2, 8)]
        assert all(isinstance(p, ShardedSchedule) for p in plans.values())


# ---------------------------------------------------------------------------
# The slot pool: family-dispatched allocation
# ---------------------------------------------------------------------------


class TestInitCacheSlots:
    def test_dense_slot_axis_contract(self, cfg):
        cache = init_cache_slots(cfg, n_slots=3, max_seq=16,
                                 dtype=jnp.float32)
        for leaf in jax.tree.leaves(cache):
            assert leaf.shape[1] == 3  # slots on axis 1 of every leaf

    def test_family_without_cache_raises(self):
        ccfg = smoke_config("cnn-vgg11")
        with pytest.raises(ValueError, match="cnn"):
            init_cache_slots(ccfg, n_slots=2, max_seq=16, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# Satellite: bucketed/padded dispatch is BIT-IDENTICAL to the reference
# greedy loop, across ragged prompt lengths and bucket-straddling batches
# ---------------------------------------------------------------------------


class TestBitIdentity:
    def test_bucketed_engine_matches_greedy_generate(self, cfg, params):
        max_seq = 32
        engine = _boot(cfg, params, [(2, 8), (4, 24)], max_seq)
        rng = np.random.default_rng(3)
        # Lengths straddle the seq rungs (<=8 and >8 up to a full rung);
        # 7 requests straddle every batch boundary (2 and 4).
        lens = [3, 8, 11, 17, 5, 24, 6]
        gen = 6
        prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
                   for n in lens]
        reqs = [engine.submit(prompt=p, max_new_tokens=gen) for p in prompts]
        engine.run_until_idle()
        assert all(r.state == DONE for r in reqs)

        for r, p in zip(reqs, prompts):
            ref = greedy_generate(cfg, params, jnp.asarray(p)[None, :],
                                  steps=gen, max_seq=max_seq)
            ref = np.asarray(ref)[0]
            got = np.asarray(r.tokens, ref.dtype)
            assert np.array_equal(got, ref), (
                f"{r.rid} (len {len(p)}): engine {got} != reference {ref}")
        # the streams vary (perturbed params): identity is not vacuous
        assert len({tuple(r.tokens) for r in reqs}) > 1

    def test_slot_backfill_keeps_identity(self, cfg, params):
        """Retire-and-backfill: a second wave lands in freed slots whose
        cache rows still hold the first wave's state."""
        engine = _boot(cfg, params, [(2, 16)], 24)
        rng = np.random.default_rng(4)
        prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
                   for n in (4, 9, 13, 6)]
        reqs = [engine.submit(prompt=p, max_new_tokens=3 + i)
                for i, p in enumerate(prompts)]
        engine.run_until_idle()
        assert all(r.state == DONE for r in reqs)
        for r, p in zip(reqs, prompts):
            ref = np.asarray(greedy_generate(
                cfg, params, jnp.asarray(p)[None, :],
                steps=r.max_new_tokens, max_seq=24))[0]
            assert np.array_equal(np.asarray(r.tokens, ref.dtype), ref)


# ---------------------------------------------------------------------------
# Acceptance: a warmed engine never calls the autotuner's timing path at
# request time (REPRO_AUTOTUNE=cache-only boot, spy on tune/_measure)
# ---------------------------------------------------------------------------


class TestNeverTuneAtRequestTime:
    def test_cache_only_engine_with_timing_path_disabled(
            self, cfg, params, tmp_path, monkeypatch):
        cache_path = str(tmp_path / "serve_cache.json")
        buckets, max_seq = [(2, 8), (4, 16)], 24

        # First boot: tune fills the cache.
        lad = BucketLadder(buckets, max_seq=max_seq)
        e1 = Engine(cfg, params, lad)
        src1 = e1.warmup(policy="tune",
                         cache=autotune.AutotuneCache(cache_path))
        assert any(s == "tuned" for cells in src1.values()
                   for s in cells.values())

        # Production boot: cache-only, with the timing path rigged to
        # blow up — warmup AND every request must complete without it.
        def _no_timing(*a, **k):
            raise AssertionError("autotuner timing path hit after warmup")

        monkeypatch.setattr(autotune, "_measure", _no_timing)
        monkeypatch.setattr(autotune, "tune", _no_timing)
        monkeypatch.setenv("REPRO_AUTOTUNE", "cache-only")

        lad2 = BucketLadder(buckets, max_seq=max_seq)
        e2 = Engine(cfg, params, lad2)
        src2 = e2.warmup(policy="cache-only",
                         cache=autotune.AutotuneCache(cache_path))
        flat = [s for cells in src2.values() for s in cells.values()]
        assert "tuned" not in flat
        assert "cached" in flat  # winners replayed, not re-modeled

        rng = np.random.default_rng(5)
        reqs = [e2.submit(prompt=rng.integers(0, cfg.vocab, n).astype(np.int32),
                          max_new_tokens=4)
                for n in (3, 10, 7, 14, 5)]
        e2.run_until_idle()
        assert all(r.state == DONE for r in reqs)


# ---------------------------------------------------------------------------
# Graceful degradation: queue bound, oversize prompts, deadlines
# ---------------------------------------------------------------------------


class TestQueueAndDeadlines:
    def test_queue_sheds_on_overflow(self):
        q = RequestQueue(max_depth=2)
        rs = [Request(rid=f"r{i}", prompt=np.zeros(2, np.int32),
                      max_new_tokens=1) for i in range(3)]
        assert q.submit(rs[0], now=0.0) and q.submit(rs[1], now=0.0)
        assert not q.submit(rs[2], now=0.0)
        assert rs[2].state == SHED and len(q) == 2
        assert rs[0].state == QUEUED

    def test_queue_expires_deadlines(self):
        q = RequestQueue()
        r1 = Request(rid="a", prompt=np.zeros(2, np.int32),
                     max_new_tokens=1, deadline=1.0)
        r2 = Request(rid="b", prompt=np.zeros(2, np.int32),
                     max_new_tokens=1)
        q.submit(r1, now=0.0)
        q.submit(r2, now=0.0)
        dead = q.expire(now=2.0)
        assert [r.rid for r in dead] == ["a"] and r1.state == TIMEOUT
        assert len(q) == 1  # the deadline-free request survives

    def test_engine_sheds_oversize_and_overflow(self, cfg, params):
        engine = _boot(cfg, params, [(2, 8)], 16, queue_depth=3)
        too_long = engine.submit(prompt=np.zeros(9, np.int32),
                                 max_new_tokens=2)
        assert too_long.state == SHED  # longer than every rung
        subs = [engine.submit(prompt=np.zeros(4, np.int32), max_new_tokens=2)
                for _ in range(5)]
        states = [r.state for r in subs]
        assert states.count(SHED) == 2 and states.count(QUEUED) == 3
        assert len(engine.rejected) == 3
        engine.run_until_idle()
        assert all(r.state == DONE for r in subs if r not in engine.rejected)

    def test_deadline_expires_mid_generation(self, cfg, params):
        clock = VirtualClock()
        engine = _boot(cfg, params, [(2, 8)], 16, clock=clock)
        r = engine.submit(prompt=np.arange(4, dtype=np.int32),
                          max_new_tokens=50, deadline=1.0)
        info = engine.step()  # admitted + first decode, t=0
        assert r.state == "active" and info.prefills
        clock.advance(2.0)  # the deadline passes while r is mid-stream
        info = engine.step()
        assert r.rid in info.timed_out
        assert r.state == TIMEOUT and r.slot is None
        assert engine.idle  # slot freed, nothing queued

    def test_modeled_step_seconds_drives_virtual_clock(self, cfg, params):
        clock = VirtualClock()
        engine = _boot(cfg, params, [(2, 8)], 16, clock=clock)
        engine.submit(prompt=np.arange(4, dtype=np.int32), max_new_tokens=3)
        t0 = clock.now()
        info = engine.step()
        dt = engine.modeled_step_seconds(info)
        assert dt > 0
        clock.advance(dt)
        assert clock.now() == t0 + dt


# ---------------------------------------------------------------------------
# Load generator: seeded arrivals, deterministic virtual-clock reports
# ---------------------------------------------------------------------------


class TestLoadgen:
    def test_make_requests_seeded(self, cfg):
        spec = LoadSpec(qps=100.0, n_requests=8, seed=3)
        a = make_requests(spec, cfg.vocab)
        b = make_requests(spec, cfg.vocab)
        assert [t for t, _ in a] == [t for t, _ in b]
        for (_, ra), (_, rb) in zip(a, b):
            assert np.array_equal(ra.prompt, rb.prompt)
            assert ra.max_new_tokens == rb.max_new_tokens
        assert len({len(r.prompt) for _, r in a}) > 1  # ragged

    def test_virtual_clock_run_is_deterministic(self, cfg, params):
        spec = LoadSpec(qps=50_000.0, n_requests=10, prompt_len=(3, 14),
                        new_tokens=(2, 4), seed=1)

        def once():
            engine = _boot(cfg, params, [(2, 8), (4, 16)], 24,
                           clock=VirtualClock())
            return run_load(engine, spec)

        a, b = once(), once()
        assert a == b  # frozen dataclass: field-wise equality
        assert a.completed == spec.n_requests
        assert a.p99_s >= a.p50_s > 0
        assert a.tokens_per_sec > 0
        assert 0.0 <= a.padding_waste < 1.0
