"""The dry-run machinery end-to-end on a small virtual mesh (subprocess,
8 devices): build_cell -> lower -> compile -> roofline for a reduced arch,
both train and decode kinds, plus input_specs sanity for every arch."""

import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(script: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["TF_CPP_MIN_LOG_LEVEL"] = "2"
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=580)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout[-4000:]}\nSTDERR:\n{r.stderr[-4000:]}"
    return r.stdout


def test_lower_compile_roofline_small_mesh():
    run_sub("""
import dataclasses, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.registry import smoke_config
from repro.configs.base import TrainConfig
from repro.models.registry import get_family
from repro.models.module import abstract_params, param_specs
from repro.optim import adamw
from repro.runtime import train as tr, serve as sv
from repro.runtime.parallel import ParallelCtx, cache_specs, batch_spec
from repro.analysis import roofline as rl

from repro.core.shard_compat import make_auto_mesh
mesh = make_auto_mesh((2, 4), ("data", "model"))
ctx = ParallelCtx(mesh=mesh, dp_axes=("data",), tp_axis="model")
cfg = dataclasses.replace(smoke_config("qwen3-1.7b"), n_layers=2)
tcfg = TrainConfig(param_dtype="float32", remat="block", loss_chunks=2)
fam = get_family(cfg.family)
defs = fam.param_defs(cfg)

# NB: production specs assume tp=16; rebuild specs for tp=4 via defaults.
aparams = abstract_params(defs, jnp.float32)
specs = param_specs(defs)
ns = lambda t: jax.tree.map(lambda sp: NamedSharding(mesh, sp), t)

# train step lower+compile
astate = tr.TrainState(params=aparams, opt=adamw.abstract_state(aparams), err=None)
sstate = tr.TrainState(params=ns(specs),
                       opt=adamw.AdamWState(step=ns(P()), m=ns(specs), v=ns(specs)),
                       err=None)
batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
         "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
bs = {k: ns(P("data", None)) for k in batch}
step = tr.make_train_step(cfg, tcfg, parallel=ctx)
with mesh:
    compiled = jax.jit(step, in_shardings=(sstate, bs)).lower(astate, batch).compile()
roof = rl.from_compiled(compiled, "train", 1_000_000, 8 * 64, 8)
assert roof.flops > 0 and roof.bytes_hbm > 0
assert roof.bottleneck in ("compute", "memory", "collective")
print("train cell ok:", roof.bottleneck)

# decode step lower+compile with cache specs
acache = jax.eval_shape(lambda: fam.init_cache(cfg, 8, 128, jnp.bfloat16))
cs = jax.tree.map(lambda sp: NamedSharding(mesh, sp), cache_specs(ctx, acache))
tok = jax.ShapeDtypeStruct((8, 1), jnp.int32)
pos = jax.ShapeDtypeStruct((), jnp.int32)
dec = sv.make_decode_step(cfg, parallel=ctx)
with mesh:
    c2 = jax.jit(dec, in_shardings=(ns(specs), cs, ns(batch_spec(ctx, 8, 2)), ns(P()))
                 ).lower(abstract_params(defs, jnp.bfloat16), acache, tok, pos).compile()
ma = c2.memory_analysis()
assert ma is None or ma.temp_size_in_bytes >= 0
print("decode cell ok")
""")


def test_moe_cell_small_mesh():
    run_sub("""
import dataclasses, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.registry import smoke_config
from repro.configs.base import TrainConfig
from repro.models.registry import get_family
from repro.models.module import abstract_params, param_specs
from repro.optim import adamw
from repro.runtime import train as tr
from repro.runtime.parallel import ParallelCtx

from repro.core.shard_compat import make_auto_mesh
mesh = make_auto_mesh((2, 4), ("data", "model"))
ctx = ParallelCtx(mesh=mesh, dp_axes=("data",), tp_axis="model")
cfg = dataclasses.replace(smoke_config("qwen3-moe-235b-a22b"), n_layers=2)
tcfg = TrainConfig(param_dtype="float32", remat="none", loss_chunks=2)
fam = get_family(cfg.family)
defs = fam.param_defs(cfg)
aparams = abstract_params(defs, jnp.float32)
specs = param_specs(defs)
ns = lambda t: jax.tree.map(lambda sp: NamedSharding(mesh, sp), t)
astate = tr.TrainState(params=aparams, opt=adamw.abstract_state(aparams), err=None)
sstate = tr.TrainState(params=ns(specs),
                       opt=adamw.AdamWState(step=ns(P()), m=ns(specs), v=ns(specs)),
                       err=None)
batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
         "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
bs = {k: ns(P("data", None)) for k in batch}
step = tr.make_train_step(cfg, tcfg, parallel=ctx)
with mesh:
    jax.jit(step, in_shardings=(sstate, bs)).lower(astate, batch).compile()
print("moe EP train cell ok")
""")
