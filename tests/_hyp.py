"""Optional-hypothesis shim (see requirements-dev.txt).

``from _hyp import given, settings, st`` gives the real hypothesis API when
it is installed.  When it is not, ``@given(...)`` marks the test as skipped
at collection time instead of blowing up the whole module import — so the
non-property tests in a file keep running on minimal environments.
"""

# Re-exports (the shim's whole API — keeps F401 quiet on the real branch).
__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for ``hypothesis.strategies``: every strategy builder
        returns an inert placeholder, so module-level strategy definitions
        still evaluate."""

        def __getattr__(self, name):
            return lambda *a, **k: self

        def __call__(self, *a, **k):
            return self

    st = _AnyStrategy()

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed")
