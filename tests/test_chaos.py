"""Elastic fault-tolerant training: seeded chaos injection, the recovery
state machine (runtime/train.py run_elastic), re-plan-on-shrunk-mesh
through the plan layer, and the forced multi-device end-to-end recovery
test (scripts/tier1.sh --fault-smoke)."""

import math
import os
import subprocess
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import TrainConfig
from repro.configs.registry import smoke_config
from repro.models import cnn
from repro.models.module import init_params
from repro.plan import MeshSpec, validate_sharded_plan
from repro.plan.autotune import recovery_policy
from repro.runtime import train as tr
from repro.runtime.chaos import ChaosConfig, ChaosMonkey
from repro.runtime.fault_tolerance import (
    Heartbeat, Monitor, shrink_mesh_shape,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def fake_source(step):
    return {"x": np.zeros((1,), np.float32)}


# ---------------------------------------------------------------------------
# ChaosConfig / ChaosMonkey: deterministic seeded injection
# ---------------------------------------------------------------------------


class TestChaosConfig:
    def test_parse_full_grammar(self):
        c = ChaosConfig.parse("kill@5x2, straggle@3x0.25, corrupt@10, nan@7x3",
                              seed=11)
        assert c.kill_at_step == 5 and c.kill_hosts == 2
        assert c.straggle_at_step == 3 and c.straggle_seconds == 0.25
        assert c.corrupt_at_step == 10
        assert c.nan_at_step == 7 and c.nan_steps == 3
        assert c.seed == 11
        # round-trips through str for the launcher banner
        assert ChaosConfig.parse(str(c), seed=11) == c

    def test_parse_rejects_unknown_and_malformed(self):
        with pytest.raises(ValueError, match="unknown chaos event"):
            ChaosConfig.parse("explode@3")
        with pytest.raises(ValueError, match="NAME@STEP"):
            ChaosConfig.parse("kill")

    def test_host_death_fires_once_with_survivor_math(self):
        m = ChaosMonkey(ChaosConfig(kill_at_step=3, kill_hosts=1),
                        devices_per_host=2)
        assert m.host_death(2, 8) is None
        dead, survivors = m.host_death(3, 8)
        assert survivors == 6 and len(dead) == 1
        assert m.host_death(3, 8) is None  # a one-off hardware failure

    def test_host_death_refuses_zero_survivors(self):
        m = ChaosMonkey(ChaosConfig(kill_at_step=0, kill_hosts=2),
                        devices_per_host=2)
        with pytest.raises(ValueError, match="no survivors"):
            m.host_death(0, 4)

    def test_poison_loss_burst(self):
        m = ChaosMonkey(ChaosConfig(nan_at_step=4, nan_steps=2))
        assert m.poison_loss(3, 1.0) == 1.0
        assert math.isnan(m.poison_loss(4, 1.0))
        assert math.isnan(m.poison_loss(5, 1.0))
        assert m.poison_loss(6, 1.0) == 1.0  # burst exhausted
        assert m.poison_loss(4, 1.0) == 1.0  # replay after rollback: clean


# ---------------------------------------------------------------------------
# The recovery state machine, against a fake step function
# ---------------------------------------------------------------------------


def counting_build(record, start_from=0, **run_kw):
    """build() whose state counts committed steps (v) — recovery resets it,
    so v observes exactly the committed-update semantics."""

    def build(n_devices):
        n = 4 if n_devices is None else n_devices
        record.append(n)

        def step_fn(state, batch):
            return {"v": state["v"] + 1}, {"loss": 1.0}

        return tr.ElasticRun(step_fn=step_fn, state={"v": 0},
                             start=start_from, n_devices=n,
                             devices_per_host=2, **run_kw)

    return build


class TestRecoveryStateMachine:
    def test_host_death_shrinks_and_resumes(self):
        record, logs = [], []
        chaos = ChaosMonkey(ChaosConfig(kill_at_step=3), devices_per_host=2)
        state, hist = tr.run_elastic(counting_build(record), fake_source, 6,
                                     chaos=chaos, log=logs.append)
        assert record == [4, 2]  # initial mesh, then the survivors
        assert state["v"] == 6  # post-recovery incarnation ran all 6 steps
        # steps 0-2 ran pre-kill, step 3 aborted, 0-5 replayed after
        assert [h["step"] for h in hist] == [0, 1, 2, 0, 1, 2, 3, 4, 5]
        assert any("recover #1" in line and "host failure" in line
                   for line in logs)

    def test_consecutive_recovery_cap_gives_up(self):
        """A perpetually-stale host (torn heartbeat included) must not
        re-mesh forever: bounded consecutive recoveries, then raise."""
        record = []
        with tempfile.TemporaryDirectory() as d:
            hb = Heartbeat("host0", d)
            with open(os.path.join(d, "hb_dead.json"), "w") as f:
                f.write('{"step": 0, "ti')  # torn mid-write -> stale
            mon = Monitor(d, timeout=60)
            build = counting_build(record, heartbeat=hb, monitor=mon)
            with pytest.raises(RuntimeError, match="giving up after 2"):
                tr.run_elastic(build, fake_source, 6,
                               policy=tr.RecoveryPolicy(max_recoveries=2),
                               log=lambda s: None)
        assert record == [4, 2, 2]  # initial + 2 bounded recoveries

    def test_nonfinite_skips_then_rolls_back(self):
        record, logs = [], []
        chaos = ChaosMonkey(ChaosConfig(nan_at_step=2, nan_steps=2))
        state, hist = tr.run_elastic(
            counting_build(record), fake_source, 6,
            policy=tr.RecoveryPolicy(nonfinite_patience=2), chaos=chaos,
            log=logs.append)
        assert record == [4, 4]  # rollback re-builds on the SAME mesh
        skipped = [h for h in hist if h["skipped"]]
        assert [h["step"] for h in skipped] == [2, 3]
        assert state["v"] == 6  # poisoned updates never reached the state
        assert any("non-finite" in line for line in logs)

    def test_nonfinite_below_patience_only_skips(self):
        record = []
        chaos = ChaosMonkey(ChaosConfig(nan_at_step=2, nan_steps=1))
        state, hist = tr.run_elastic(
            counting_build(record), fake_source, 6,
            policy=tr.RecoveryPolicy(nonfinite_patience=3), chaos=chaos,
            log=lambda s: None)
        assert record == [4]  # no rollback
        assert state["v"] == 5  # one update skipped, never committed
        assert [h["step"] for h in hist if h["skipped"]] == [2]

    def test_straggler_injection_trips_watchdog(self):
        from repro.runtime.fault_tolerance import StragglerWatchdog

        logs = []
        chaos = ChaosMonkey(ChaosConfig(straggle_at_step=9,
                                        straggle_seconds=0.2))
        build = counting_build([], watchdog=StragglerWatchdog(factor=3.0))
        tr.run_elastic(build, fake_source, 12, chaos=chaos, log=logs.append)
        assert any("[watchdog] step 9" in line for line in logs)


# ---------------------------------------------------------------------------
# Straggler escalation: after straggler_patience consecutive watchdog
# trips the slow host is treated as failed (HostFailure -> eviction),
# instead of the old log-and-limp-forever behavior.
# ---------------------------------------------------------------------------


class _ScriptedWatchdog:
    """A watchdog double: pops the next scripted verdict (the
    StragglerWatchdog interface minus the 8-sample warmup and timers)."""

    def __init__(self, verdicts):
        self.verdicts = list(verdicts)

    def observe(self, dt):
        return self.verdicts.pop(0) if self.verdicts else False


class TestStragglerEscalation:
    def test_patience_zero_stays_report_only(self):
        """The back-compat default: every step trips, nothing is evicted,
        and the trip counter keeps climbing in the log."""
        record, logs = [], []
        build = counting_build(record, watchdog=_ScriptedWatchdog([True] * 6))
        state, hist = tr.run_elastic(build, fake_source, 6, log=logs.append)
        assert record == [4]  # never rebuilt
        assert state["v"] == 6
        assert any("trip 6" in line for line in logs)
        assert not any("recover" in line for line in logs)

    def test_escalates_after_patience_consecutive_trips(self):
        """Three consecutive trips at patience=3: the tripping step is
        aborted (never committed), the run rebuilds on the survivors and
        replays to completion."""
        record, logs = [], []
        build = counting_build(
            record, watchdog=_ScriptedWatchdog([False, True, True, True]))
        state, hist = tr.run_elastic(
            build, fake_source, 6,
            policy=tr.RecoveryPolicy(straggler_patience=3), log=logs.append)
        # devices_per_host=2 of 4: the slow host's 2 devices are evicted.
        assert record == [4, 2]
        assert state["v"] == 6
        # steps 1-2 tripped below patience and committed; step 3's third
        # consecutive trip escalated before commit, then 0-5 replayed.
        assert [h["step"] for h in hist] == [0, 1, 2, 0, 1, 2, 3, 4, 5]
        assert any("host failure: dead=['straggler']" in line
                   for line in logs)

    def test_clean_step_resets_the_patience_counter(self):
        """Alternating trip/clean never reaches patience=2 — only
        CONSECUTIVE trips mean a persistently slow host."""
        record = []
        build = counting_build(
            record,
            watchdog=_ScriptedWatchdog([True, False, True, False, True]))
        state, _ = tr.run_elastic(
            build, fake_source, 6,
            policy=tr.RecoveryPolicy(straggler_patience=2),
            log=lambda s: None)
        assert record == [4]  # no eviction
        assert state["v"] == 6

    def test_perpetually_slow_step_fn_is_evicted(self):
        """End to end with the real StragglerWatchdog and real step
        timing: a step_fn that turns perpetually slow after the
        watchdog's warmup gets its host evicted, and the rebuilt (fast)
        incarnation finishes the run."""
        from repro.runtime.fault_tolerance import StragglerWatchdog

        record, logs = [], []
        wd = StragglerWatchdog(factor=3.0)

        def build(n_devices):
            n = 4 if n_devices is None else n_devices
            record.append(n)
            evicted = len(record) > 1  # the rebuild runs without the slug

            def step_fn(state, batch):
                # Fast through the watchdog's 8-sample warmup, then the
                # straggling host surfaces: every step 25x the median.
                time.sleep(0.25 if not evicted and state["v"] >= 8
                           else 0.01)
                return {"v": state["v"] + 1}, {"loss": 1.0}

            return tr.ElasticRun(step_fn=step_fn, state={"v": 0}, start=0,
                                 n_devices=n, devices_per_host=2,
                                 watchdog=wd)

        state, hist = tr.run_elastic(
            build, fake_source, 12,
            policy=tr.RecoveryPolicy(straggler_patience=2), log=logs.append)
        assert record == [4, 2]
        assert state["v"] == 12
        assert any("[watchdog]" in line for line in logs)
        assert any("host failure: dead=['straggler']" in line
                   for line in logs)


# ---------------------------------------------------------------------------
# Async checkpoint commits in the elastic loop: run_elastic overlaps the
# write with training and joins the previous handle before the next
# commit — writer failures surface at the join point, never silently.
# ---------------------------------------------------------------------------


class _FailingHandle:
    """An AsyncSave-shaped handle whose writer thread died."""

    def __init__(self, step):
        self.step = step

    def join(self, timeout=None):
        raise RuntimeError(f"disk full writing step {self.step}")


class TestAsyncCheckpointCommit:
    def test_writer_failure_surfaces_at_the_join_point(self):
        saves = []

        def save(step, st):
            saves.append(step)
            return _FailingHandle(step)

        build = counting_build([], save=save, ckpt_every=1)
        with pytest.raises(RuntimeError, match="disk full writing step 1"):
            tr.run_elastic(build, fake_source, 5, log=lambda *_: None)
        # The step-1 handle's failure surfaced at the join *before* the
        # step-2 commit started — not swallowed, not at process exit.
        assert saves == [1]

    def test_real_async_saves_commit_and_final_join(self):
        with tempfile.TemporaryDirectory() as d:

            def save(step, st):
                return ckpt.save_async(d, step, st, n_chunks=1)

            build = counting_build([], save=save, ckpt_every=2, ckpt_dir=d)
            state, hist = tr.run_elastic(build, fake_source, 5,
                                         log=lambda *_: None)
            assert state["v"] == 5
            # In-loop commits at steps 2 and 4 plus the final commit (also
            # step 4), all joined by the time run_elastic returns.
            assert ckpt.committed_steps(d) == [2, 4]

    def test_sync_save_protocol_still_supported(self):
        committed = []

        def save(step, st):
            committed.append((step, st["v"]))
            return None  # old synchronous protocol

        build = counting_build([], save=save, ckpt_every=2)
        tr.run_elastic(build, fake_source, 5, log=lambda *_: None)
        # v counts executed steps: after step 2, v=3; after step 4, v=5.
        # The trailing entry is run_elastic's final commit (same step/state).
        assert committed == [(2, 3), (4, 5), (4, 5)]


# ---------------------------------------------------------------------------
# Recovery is a plan-layer operation: shrunk MeshSpec -> re-planned set
# ---------------------------------------------------------------------------


class TestReplanOnShrunkMesh:
    def test_shrink_to_matches_restart_protocol(self):
        spec = MeshSpec((("data", 15), ("model", 16)))
        assert MeshSpec((("data", 16), ("model", 16))).shrink_to(240) == spec
        pod = MeshSpec((("pod", 2), ("data", 16), ("model", 16)))
        assert pod.shrink_to(480).axes == (("pod", 2), ("data", 15), ("model", 16))
        assert pod.shrink_to(496).axes == (("pod", 1), ("data", 31), ("model", 16))
        # agrees with the host-count version used by the launcher
        assert shrink_mesh_shape(480, model=16, pod=2) == (2, 15, 16)
        with pytest.raises(ValueError, match="not divisible"):
            MeshSpec((("data", 4), ("model", 16))).shrink_to(250)

    def test_with_axis(self):
        spec = MeshSpec((("data", 4), ("model", 2)))
        assert spec.with_axis("data", 1).axes == (("data", 1), ("model", 2))
        with pytest.raises(KeyError):
            spec.with_axis("pod", 2)

    def test_plan_training_revalidates_on_shrunk_mesh(self):
        """The recovery gate: after a shrink, plan_training(mesh=...) must
        emit a full ShardedSchedule set valid for the NEW MeshSpec."""
        cfg = smoke_config("cnn-vgg11")
        full = MeshSpec((("data", 4), ("model", 1)))
        plan_full = cnn.plan_training(cfg, 8, mesh=full, shard_axis="data")
        assert validate_sharded_plan(plan_full, full) == len(plan_full)

        shrunk = full.shrink_to(2)
        assert shrunk.axes == (("data", 2), ("model", 1))
        plan_shrunk = cnn.plan_training(cfg, 8, mesh=shrunk, shard_axis="data")
        assert validate_sharded_plan(plan_shrunk, shrunk) == len(plan_shrunk)
        for s in plan_shrunk.values():
            assert s.mesh == shrunk
        # a stale (pre-shrink) plan must be rejected, not silently reused
        with pytest.raises(ValueError, match="stale plan"):
            validate_sharded_plan(plan_full, shrunk)

    def test_degenerate_one_device_replan(self):
        """Losing everything but one device still plans: the degenerate
        mesh carries zero interconnect words and the meshless modeled
        words exactly."""
        cfg = smoke_config("cnn-vgg11")
        one = MeshSpec((("data", 4), ("model", 1))).shrink_to(1)
        assert one.devices == 1
        local = cnn.plan_training(cfg, 8)
        sharded = cnn.plan_training(cfg, 8, mesh=one, shard_axis="data")
        assert validate_sharded_plan(sharded, one) == len(sharded)
        assert set(sharded) == set(local)
        for name, s in sharded.items():
            assert s.ici_words == 0
            assert s.devices == 1
            assert s.hbm_words == local[name].modeled_words

    def test_validate_rejects_local_schedule(self):
        cfg = smoke_config("cnn-vgg11")
        mesh = MeshSpec((("data", 2), ("model", 1)))
        local = cnn.plan_training(cfg, 8)  # meshless -> plain Schedules
        with pytest.raises(ValueError, match="expected a ShardedSchedule"):
            validate_sharded_plan(local, mesh)

    def test_recovery_policy_never_tunes(self):
        assert recovery_policy("off") == "off"
        assert recovery_policy("cache-only") == "cache-only"
        assert recovery_policy("tune") == "cache-only"  # never measure mid-recovery
        with pytest.raises(ValueError):
            recovery_policy("frobnicate")


# ---------------------------------------------------------------------------
# Non-finite loss + corrupt chunk, end to end on a real (1-device) train
# ---------------------------------------------------------------------------


def _cnn_build(cfg, tcfg, ckpt_dir, starts):
    """Launcher-shaped build() for a single-device cnn run: fresh init,
    then restore from the newest intact committed step."""

    def build(n_devices):
        params = init_params(cnn.param_defs(cfg), jax.random.PRNGKey(0),
                             jnp.float32)
        state = tr.init_state(cfg, tcfg, params)
        start = 0
        astate = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        restored, last = ckpt.restore_latest(ckpt_dir, astate)
        if restored is not None:
            state, start = restored, last + 1
        starts.append(start)
        step_fn = jax.jit(tr.make_train_step(cfg, tcfg))

        def save(step, st):
            ckpt.save(ckpt_dir, step, st, n_chunks=2)

        return tr.ElasticRun(step_fn=step_fn, state=state, start=start,
                             save=save, ckpt_dir=ckpt_dir, ckpt_every=1,
                             log_every=100)

    return build


class TestNonFiniteAndCorruptEndToEnd:
    def test_nan_rollback_falls_back_past_corrupt_chunk_bit_for_bit(self):
        """The acceptance scenario: a chunk of the latest checkpoint is
        torn, then the loss goes non-finite.  The guard skips the poisoned
        updates, rolls back, restore falls back past the corrupt step 3 to
        step 2 (logged), and the recovered tail matches a clean
        from-checkpoint run bit-for-bit — params AND optimizer state."""
        cfg = smoke_config("cnn-vgg11")
        tcfg = TrainConfig(param_dtype="float32", compute_dtype="float32",
                           learning_rate=1e-3, warmup_steps=1, total_steps=6,
                           loss_chunks=2, seed=0)
        from repro.data.pipeline import ShardInfo, SyntheticImageSource

        source = SyntheticImageSource(cnn.IMG, cnn.IN_CH, cfg.vocab, 4,
                                      ShardInfo(0, 1), seed=0)
        chaos = ChaosMonkey(ChaosConfig(corrupt_at_step=3, nan_at_step=4,
                                        nan_steps=2, seed=0))
        starts: list = []
        with tempfile.TemporaryDirectory() as d:
            with pytest.warns(UserWarning, match="corrupt"):
                state, hist = tr.run_elastic(
                    _cnn_build(cfg, tcfg, d, starts), source, 6,
                    policy=tr.RecoveryPolicy(nonfinite_patience=2),
                    chaos=chaos, log=lambda s: None)
            # fresh start, then rollback resumed at 3 = corrupt step 3
            # fell back to committed step 2 (not silent: warned above)
            assert starts == [0, 3]
            assert [h["step"] for h in hist if h["skipped"]] == [4, 5]

            # Reference: a clean run from the same step-2 checkpoint.
            params = init_params(cnn.param_defs(cfg), jax.random.PRNGKey(0),
                                 jnp.float32)
            ref = tr.init_state(cfg, tcfg, params)
            astate = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), ref)
            ref = ckpt.restore(d, 2, astate)
            step_fn = jax.jit(tr.make_train_step(cfg, tcfg))
            ref_losses = []
            for i in range(3, 6):
                batch = {k: jnp.asarray(v) for k, v in source(i).items()}
                ref, m = step_fn(ref, batch)
                ref_losses.append(float(m["loss"]))

            replay = [h["loss"] for h in hist if not h["skipped"]][-3:]
            assert replay == ref_losses  # bit-for-bit
            for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(ref)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# The tentpole acceptance test: injected host death on a forced 4-device
# mesh recovers without operator input (test_distributed.py pattern)
# ---------------------------------------------------------------------------


def run_sub(script: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


ELASTIC_SCRIPT = """
import jax, jax.numpy as jnp, numpy as np, tempfile, os
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core.shard_compat import make_auto_mesh
from repro.configs.registry import smoke_config
from repro.configs.base import TrainConfig
from repro.models import cnn
from repro.models.module import abstract_params, init_params, param_specs
from repro.models.registry import batch_shard_specs
from repro.runtime import train as tr
from repro.runtime.chaos import ChaosConfig, ChaosMonkey
from repro.runtime.fault_tolerance import shrink_mesh_shape
from repro.runtime.parallel import ParallelCtx
from repro.launch.specs import fsdp_specs
from repro.checkpoint import checkpoint as ckpt
from repro.data.pipeline import ShardInfo, SyntheticImageSource
from repro.optim import adamw
from repro.plan import validate_sharded_plan

assert len(jax.devices()) == 4
cfg = smoke_config("cnn-vgg11")
tcfg = TrainConfig(param_dtype="float32", compute_dtype="float32",
                   learning_rate=1e-3, warmup_steps=1, total_steps=8,
                   loss_chunks=2, seed=0)
defs = cnn.param_defs(cfg)
BATCH, STEPS, MODEL = 8, 8, 2
source = SyntheticImageSource(cnn.IMG, cnn.IN_CH, cfg.vocab, BATCH,
                              ShardInfo(0, 1), seed=0)
built = []  # (n_devices, mesh_axes, start)

def make_step_and_shardings(mesh, ctx, use_sharding):
    specs = param_specs(defs)
    aparams = abstract_params(defs, jnp.float32)
    pspecs = fsdp_specs(specs, aparams, ctx) if use_sharding else None
    shardings = None
    if use_sharding:
        ns = lambda t: jax.tree.map(lambda sp: NamedSharding(mesh, sp), t)
        shardings = tr.TrainState(
            params=ns(pspecs),
            opt=adamw.AdamWState(step=NamedSharding(mesh, P()),
                                 m=ns(pspecs), v=ns(pspecs)),
            err=None)
    step_fn = tr.make_train_step(cfg, tcfg,
                                 parallel=ctx if use_sharding else None,
                                 grad_specs=pspecs)
    if use_sharding:
        bspec = {k: NamedSharding(mesh, s)
                 for k, s in batch_shard_specs(cfg, "data").items()}
        step_fn = jax.jit(step_fn, in_shardings=(shardings, bspec))
    else:
        step_fn = jax.jit(step_fn)
    return step_fn, shardings

def make_build(ckpt_dir):
    def build(n_devices):
        n = 4 if n_devices is None else n_devices
        shape = shrink_mesh_shape(n, model=MODEL)
        mesh = make_auto_mesh(shape, ("data", "model"))
        ctx = ParallelCtx(mesh=mesh, dp_axes=("data",), tp_axis="model")
        use_sharding = n > 1
        step_fn, shardings = make_step_and_shardings(mesh, ctx, use_sharding)

        # THE recovery invariant: the full schedule set re-planned through
        # plan_training against THIS mesh, every ShardedSchedule valid for
        # the new MeshSpec (ring/psum argmin re-run at the new count).
        ms = ctx.plan_mesh()
        splan = cnn.plan_training(cfg, BATCH, mesh=ms, shard_axis="data")
        assert validate_sharded_plan(splan, ms) == len(splan) > 0
        for s in splan.values():
            assert s.mesh.axis_size("data") == shape[0]

        params = init_params(defs, jax.random.PRNGKey(0), jnp.float32)
        state = tr.init_state(cfg, tcfg, params)
        start = 0
        astate = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        restored, last = ckpt.restore_latest(ckpt_dir, astate, shardings)
        if restored is not None:
            state, start = restored, last + 1
        built.append((n, dict(mesh.shape), start))

        def save(step, st):
            ckpt.save(ckpt_dir, step, st, n_chunks=4)

        return tr.ElasticRun(step_fn=step_fn, state=state, start=start,
                             n_devices=n, mesh=mesh, save=save,
                             ckpt_dir=ckpt_dir, ckpt_every=2,
                             devices_per_host=MODEL, log_every=100)
    return build

with tempfile.TemporaryDirectory() as d:
    chaos = ChaosMonkey(ChaosConfig(kill_at_step=5, kill_hosts=1, seed=0),
                        devices_per_host=MODEL)
    state, hist = tr.run_elastic(make_build(d), source, STEPS, chaos=chaos)

    # Recovered without operator input: initial 4-device mesh, then the
    # shrunk 2-device mesh resuming from last committed step 4 (+1).
    assert built[0] == (4, {"data": 2, "model": 2}, 0), built
    assert built[1] == (2, {"data": 1, "model": 2}, 5), built
    assert [h["step"] for h in hist] == [0, 1, 2, 3, 4, 5, 6, 7]

    # Bit-for-bit: the post-recovery tail must equal a no-failure run
    # started from the same committed checkpoint on the same shrunk mesh.
    mesh2 = make_auto_mesh((1, MODEL), ("data", "model"))
    ctx2 = ParallelCtx(mesh=mesh2, dp_axes=("data",), tp_axis="model")
    step_fn2, shardings2 = make_step_and_shardings(mesh2, ctx2, True)
    params = init_params(defs, jax.random.PRNGKey(0), jnp.float32)
    astate = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          tr.init_state(cfg, tcfg, params))
    ref = ckpt.restore(d, 4, astate, shardings2)
    ref_losses = []
    with mesh2:
        for i in range(5, STEPS):
            batch = {k: jnp.asarray(v) for k, v in source(i).items()}
            ref, m = step_fn2(ref, batch)
            ref_losses.append(float(jax.block_until_ready(m["loss"])))
    tail = [h["loss"] for h in hist[-3:]]
    assert tail == ref_losses, (tail, ref_losses)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("elastic recovery ok", built)
"""


class TestElasticRecovery:
    def test_host_death_recovers_on_shrunk_mesh_bit_for_bit(self):
        out = run_sub(ELASTIC_SCRIPT, devices=4)
        assert "elastic recovery ok" in out


class TestLauncherFaultSmoke:
    def test_launcher_chaos_kill_recovers(self):
        """The CLI path: --chaos kill@5 on a 2x2 mesh shrinks to 1x2 and
        resumes from the last committed checkpoint (the CI fault smoke)."""
        with tempfile.TemporaryDirectory() as d:
            env = dict(os.environ)
            env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
            env["PYTHONPATH"] = os.path.join(ROOT, "src")
            r = subprocess.run(
                [sys.executable, "-m", "repro.launch.train",
                 "--arch", "cnn-vgg11", "--smoke", "--mesh", "2x2",
                 "--steps", "8", "--batch", "8", "--ckpt",
                 os.path.join(d, "ckpt"), "--ckpt-every", "2",
                 "--log-every", "1", "--chaos", "kill@5",
                 "--max-recoveries", "2"],
                capture_output=True, text=True, env=env, timeout=600)
            assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
            assert "[recover #1]" in r.stdout
            assert "resumed from step 4" in r.stdout
            assert "degraded" in r.stdout
            assert "sharded plan" in r.stdout
            assert "done: 8 steps executed" in r.stdout
