"""Checkpoint integrity and round-trip coverage the elastic restart path
depends on: sha256 chunk digests + verify-on-restore, fallback to the
previous committed step on a torn chunk, the bf16 bits-view path,
multi-chunk reshard-on-restore onto a different device count, retain()
pruning, descriptive mismatch errors, and async-save error propagation."""

import json
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ckpt
from repro.checkpoint.checkpoint import (
    CheckpointCorruptError, CheckpointMismatchError,
)
from repro.runtime.chaos import corrupt_chunk

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def tree_eq(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestIntegrity:
    def test_save_records_chunk_digests(self):
        with tempfile.TemporaryDirectory() as d:
            path = ckpt.save(d, 1, {"w": jnp.arange(12.0).reshape(6, 2)},
                             n_chunks=3)
            with open(os.path.join(path, "index.json")) as f:
                index = json.load(f)
            chunks = index["leaves"]["w"]["chunks"]
            assert len(chunks) == 3
            assert all(len(c["sha256"]) == 64 for c in chunks)
            ckpt.verify_step(d, 1)  # intact -> no raise

    def test_torn_chunk_fails_verification_and_restore(self):
        with tempfile.TemporaryDirectory() as d:
            tree = {"w": jnp.arange(64.0).reshape(8, 8)}
            ckpt.save(d, 2, tree, n_chunks=4)
            torn = corrupt_chunk(d, 2, seed=3)
            assert os.path.exists(torn)
            with pytest.raises(CheckpointCorruptError, match="sha256"):
                ckpt.verify_step(d, 2)
            abstract = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
            with pytest.raises(CheckpointCorruptError):
                ckpt.restore(d, 2, abstract)
            # Same (seed, step) -> same victim chunk: the injection is
            # deterministic, which the bit-for-bit recovery tests rely on.
            assert corrupt_chunk(d, 2, seed=3) == torn

    def test_missing_chunk_is_corrupt(self):
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 1, {"w": jnp.ones((4, 2))}, n_chunks=2)
            step_dir = os.path.join(d, "step_0000001")
            victim = [f for f in os.listdir(step_dir) if f.endswith(".npy")][0]
            os.remove(os.path.join(step_dir, victim))
            with pytest.raises(CheckpointCorruptError, match="missing"):
                ckpt.verify_step(d, 1)

    def test_restore_latest_falls_back_to_previous_committed_step(self):
        """The elastic restart guarantee: a chunk torn by a mid-write host
        death makes restore fall back to the previous committed step —
        logged via warnings, never silent, never garbage."""
        with tempfile.TemporaryDirectory() as d:
            for s in (1, 2, 3):
                ckpt.save(d, s, {"w": jnp.full((4,), float(s))}, n_chunks=2)
            corrupt_chunk(d, 3, seed=0)
            abstract = {"w": jax.ShapeDtypeStruct((4,), jnp.float32)}
            with pytest.warns(UserWarning, match="corrupt"):
                tree, step = ckpt.restore_latest(d, abstract)
            assert step == 2
            np.testing.assert_array_equal(np.asarray(tree["w"]),
                                          np.full((4,), 2.0))

    def test_restore_latest_no_checkpoints(self):
        with tempfile.TemporaryDirectory() as d:
            tree, step = ckpt.restore_latest(
                d, {"w": jax.ShapeDtypeStruct((1,), jnp.float32)})
            assert tree is None and step is None

    def test_restore_latest_does_not_mask_mismatch(self):
        """A wrong abstract tree is a caller bug — older steps would
        mismatch identically, so falling back would hide it."""
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 1, {"w": jnp.ones((4,))})
            ckpt.save(d, 2, {"w": jnp.ones((4,))})
            with pytest.raises(CheckpointMismatchError):
                ckpt.restore_latest(
                    d, {"w": jax.ShapeDtypeStruct((5,), jnp.float32)})

    def test_mismatch_error_names_leaf_and_shapes(self):
        """The bare assert this replaces vanished under python -O; the
        error must name the leaf path and both shapes."""
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 1, {"a": {"w": jnp.ones((8, 4))}})
            abstract = {"a": {"w": jax.ShapeDtypeStruct((8, 5), jnp.float32)}}
            with pytest.raises(CheckpointMismatchError) as ei:
                ckpt.restore(d, 1, abstract)
            msg = str(ei.value)
            assert "a/w" in msg and "(8, 4)" in msg and "(8, 5)" in msg

    def test_missing_leaf_is_mismatch(self):
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 1, {"w": jnp.ones((2,))})
            with pytest.raises(CheckpointMismatchError, match="nope"):
                ckpt.restore(d, 1, {"nope": jax.ShapeDtypeStruct((2,), jnp.float32)})

    def test_pre_digest_checkpoints_still_verify(self):
        """Checkpoints written before digests existed (no "sha256" key)
        must restore cleanly — verification skips them."""
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 1, {"w": jnp.arange(4.0)})
            idx_path = os.path.join(d, "step_0000001", "index.json")
            with open(idx_path) as f:
                index = json.load(f)
            for meta in index["leaves"].values():
                for ch in meta["chunks"]:
                    ch.pop("sha256")
            with open(idx_path, "w") as f:
                json.dump(index, f)
            ckpt.verify_step(d, 1)
            out = ckpt.restore(d, 1,
                               {"w": jax.ShapeDtypeStruct((4,), jnp.float32)})
            np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(4.0))


class TestRobustListing:
    def test_latest_step_skips_unreadable_index(self):
        """COMMIT present but index.json torn (host died between the two
        writes after a partial rename): not a resume candidate."""
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 3, {"x": jnp.ones(3)})
            bad = os.path.join(d, "step_0000009")
            os.makedirs(bad)
            with open(os.path.join(bad, "COMMIT"), "w") as f:
                f.write("ok")
            with open(os.path.join(bad, "index.json"), "w") as f:
                f.write('{"step": 9, "leaves": {tru')  # torn mid-write
            assert ckpt.latest_step(d) == 3
            assert ckpt.committed_steps(d) == [3]

    def test_retain_prunes_oldest_keeps_newest(self):
        with tempfile.TemporaryDirectory() as d:
            for s in (1, 2, 5, 8, 9):
                ckpt.save(d, s, {"x": jnp.ones(2)})
            ckpt.retain(d, keep=2)
            assert ckpt.committed_steps(d) == [8, 9]
            out = ckpt.restore(d, 8, {"x": jax.ShapeDtypeStruct((2,), jnp.float32)})
            np.testing.assert_array_equal(np.asarray(out["x"]), np.ones(2))


class TestAsyncSave:
    def test_join_reraises_background_failure(self):
        """A silently-swallowed writer exception means the trainer keeps
        running believing a checkpoint exists; join() must re-raise."""
        with tempfile.TemporaryDirectory() as d:
            blocker = os.path.join(d, "ckpt")
            with open(blocker, "w") as f:  # a FILE where the dir must go
                f.write("x")
            handle = ckpt.save_async(blocker, 1, {"x": jnp.ones(2)})
            with pytest.raises(OSError):
                handle.join()

    def test_join_returns_path_on_success(self):
        with tempfile.TemporaryDirectory() as d:
            handle = ckpt.save_async(d, 4, {"x": jnp.arange(3)})
            path = handle.join()
            assert path == os.path.join(d, "step_0000004")
            assert ckpt.latest_step(d) == 4
            assert not handle.is_alive()


class TestRoundTrips:
    def test_bf16_bits_view_roundtrip_multichunk(self):
        """bf16 survives the u16 bits-view path (ml_dtypes don't survive
        np memmap casts) across a multi-chunk split."""
        rng = np.random.default_rng(0)
        tree = {"w": jnp.asarray(rng.standard_normal((9, 4)), jnp.bfloat16),
                "s": jnp.bfloat16(0.5)}  # scalar bf16 leaf too
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 1, tree, n_chunks=3)
            with open(os.path.join(d, "step_0000001", "index.json")) as f:
                index = json.load(f)
            assert index["leaves"]["w"]["bits"] is True
            assert index["leaves"]["w"]["dtype"] == "bfloat16"
            abstract = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
            out = ckpt.restore(d, 1, abstract)
            assert out["w"].dtype == jnp.bfloat16
            tree_eq(out, tree)

    def test_more_chunks_than_rows_degrades(self):
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 1, {"w": jnp.arange(2.0)}, n_chunks=8)
            out = ckpt.restore(d, 1, {"w": jax.ShapeDtypeStruct((2,), jnp.float32)})
            np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(2.0))

    def test_reshard_restore_onto_different_device_count(self):
        """A checkpoint chunked as if by 3 saver shards restores onto a
        4-device mesh (forced host devices, test_distributed.py pattern):
        chunk boundaries and device-slice boundaries disagree, so the
        lazy reassembly path does real cross-chunk reads."""
        script = """
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core.shard_compat import make_auto_mesh
from repro.checkpoint import checkpoint as ckpt
assert len(jax.devices()) == 4
mesh = make_auto_mesh((4,), ("model",))
rng = np.random.default_rng(0)
tree = {"w": jnp.asarray(rng.standard_normal((12, 6)), jnp.float32),
        "b": jnp.asarray(rng.standard_normal((8,)), jnp.bfloat16)}
with tempfile.TemporaryDirectory() as d:
    ckpt.save(d, 5, tree, n_chunks=3)   # "3 hosts" wrote it
    abstract = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    sh = {"w": NamedSharding(mesh, P("model", None)),
          "b": NamedSharding(mesh, P("model"))}
    out = ckpt.restore(d, 5, abstract, sh)
    assert out["w"].sharding.spec == P("model", None)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
    np.testing.assert_array_equal(
        np.asarray(out["b"], np.float32), np.asarray(tree["b"], np.float32))
print("reshard-different-count ok")
"""
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        env["PYTHONPATH"] = os.path.join(ROOT, "src")
        r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                           text=True, env=env, timeout=600)
        assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
        assert "reshard-different-count ok" in r.stdout
