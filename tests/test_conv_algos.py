"""Cross-algorithm conv planning (DESIGN.md Sec. 9): the two-level
``algorithm x blocking`` argmin.  Numeric parity of the im2col-GEMM
kernel against the direct kernel and the XLA reference, the im2col
closed form (ccr.conv_im2col_traffic) pinned word-for-word against the
schedule walker, the measured MANTICORE crossover (deep strided 1x1
picks im2col, wide 3x3 plane picks direct), pin-implies-family
semantics, and the autotune cache replaying the winning algorithm tag
through to the kernel that actually executes."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ccr
from repro.core import schedule_sim as sim
from repro.core.machine import MANTICORE, TPU_V5E
from repro.kernels.conv2d.im2col import conv2d_im2col
from repro.kernels.conv2d.ops import conv2d
from repro.kernels.conv2d.ref import conv2d_fused_ref
from repro.plan import MeshSpec, local_schedule, planner_for
from repro.plan import autotune as at
from repro.plan.registry import _OPS, get_op

# The two sides of the measured MANTICORE crossover (benchmarks/run.py
# conv_algos pins the same cells end to end, wall clock included).
DEEP = dict(H_O=7, W_O=7, F=1, S=2, d_in=512, d_out=256, in_bytes=4)
WIDE = dict(H_O=32, W_O=32, F=3, S=1, d_in=3, d_out=64, in_bytes=4,
            padding=1)


@pytest.fixture(autouse=True)
def _isolated_cache(tmp_path, monkeypatch):
    """Never let a test read or write the user's real winner cache."""
    monkeypatch.setattr(at, "_CACHE_PATH", str(tmp_path / "global.json"))
    monkeypatch.setattr(at, "_POLICY", "off")


def _operands(H, d_in, d_out, F, batch=2, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((batch, H, H, d_in)), jnp.float32)
    f = jnp.asarray(rng.standard_normal((F, F, d_in, d_out)) / (F * F),
                    jnp.float32)
    return x, f


# ---------------------------------------------------------------------------
# Numeric parity: im2col vs direct vs the XLA reference
# ---------------------------------------------------------------------------


class TestParity:
    @pytest.mark.parametrize(
        "H,d_in,d_out,F,S,P",
        [
            (9, 5, 7, 3, 1, 1),    # odd channels, odd plane
            (12, 8, 16, 3, 2, 0),  # strided 3x3
            (13, 6, 10, 1, 2, 0),  # strided 1x1 (im2col's home turf)
            (8, 3, 5, 5, 1, 2),    # large filter, deep padding
        ],
    )
    def test_both_algorithms_match_reference(self, H, d_in, d_out, F, S, P):
        x, f = _operands(H, d_in, d_out, F)
        ref = conv2d_fused_ref(x, f, stride=S, padding=P)
        direct = conv2d(x, f, stride=S, padding=P, algorithm="direct")
        gemm = conv2d_im2col(x, f, stride=S, padding=P)
        np.testing.assert_allclose(np.asarray(direct), np.asarray(ref),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(gemm), np.asarray(ref),
                                   atol=1e-4, rtol=1e-4)

    def test_epilogue_parity_bias_relu_pool(self):
        """The unfused im2col epilogue (bias + ReLU + pool after the GEMM)
        matches the direct kernel's fused flush."""
        x, f = _operands(8, 4, 6, 3)
        b = jnp.asarray(np.linspace(-1.0, 1.0, 6), jnp.float32)
        ref = conv2d_fused_ref(x, f, b, stride=1, padding=1, relu=True,
                               pool=2)
        direct = conv2d(x, f, bias=b, stride=1, padding=1, relu=True,
                        pool=2, algorithm="direct")
        gemm = conv2d_im2col(x, f, bias=b, stride=1, padding=1, relu=True,
                             pool=2)
        np.testing.assert_allclose(np.asarray(direct), np.asarray(ref),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(gemm), np.asarray(ref),
                                   atol=1e-4, rtol=1e-4)

    def test_two_level_argmin_executes_its_winner(self):
        """conv2d with no pins runs whichever family the planner picked —
        and the result still matches the reference either way."""
        x, f = _operands(13, 32, 16, 1, batch=1)
        ref = conv2d_fused_ref(x, f, stride=2, padding=0)
        out = conv2d(x, f, stride=2, padding=0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# The im2col closed form == the executed schedule walk (house rule)
# ---------------------------------------------------------------------------


SHAPES = [
    dict(H_O=8, W_O=8, F=3, S=1, d_in=8, d_out=16, in_bytes=4, pool=2,
         batch=2),
    dict(H_O=7, W_O=7, F=1, S=2, d_in=512, d_out=256, in_bytes=4),
    dict(H_O=16, W_O=16, F=5, S=3, d_in=12, d_out=24, in_bytes=4, batch=3),
]


class TestIm2colClosedForm:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("machine", [MANTICORE, TPU_V5E],
                             ids=lambda m: m.name)
    def test_modeled_equals_simulated(self, shape, machine):
        s = planner_for("conv2d", machine).plan(**shape, algorithm="im2col")
        assert s.algorithm == "im2col"
        kw = dict(
            H_O=shape["H_O"], W_O=shape["W_O"], F=shape["F"], S=shape["S"],
            d_in=shape["d_in"], d_out=shape["d_out"],
            pool=shape.get("pool", 1), batch=shape.get("batch", 1),
            block_h=s.block("block_h"), block_m=s.block("block_m"),
            block_n=s.block("block_n"), block_k=s.block("block_k"),
        )
        t_ccr = ccr.conv_im2col_traffic(**kw)
        t_sim = sim.simulate_conv_im2col(**kw)
        assert t_ccr == t_sim
        assert (s.loads, s.stores, s.macs) == (
            t_ccr.main_loads, t_ccr.main_stores, t_ccr.macs)
        assert s.modeled_words == t_ccr.main_loads + t_ccr.main_stores

    @pytest.mark.parametrize("shape", SHAPES)
    def test_first_class_op_planner_agrees(self, shape):
        """conv2d_im2col's own planner is the pinned family of the
        two-level argmin: same blocking, same words."""
        pinned = planner_for("conv2d", MANTICORE).plan(**shape,
                                                       algorithm="im2col")
        own = planner_for("conv2d_im2col", MANTICORE).plan(**shape)
        assert own.op == "conv2d_im2col" and own.algorithm == "im2col"
        assert own.blocks == pinned.blocks
        assert own.modeled_words == pinned.modeled_words


# ---------------------------------------------------------------------------
# The crossover, pinned on MANTICORE
# ---------------------------------------------------------------------------


class TestCrossover:
    def test_deep_strided_1x1_picks_im2col(self):
        """S > F: the patch matrix reads only the pixels its patches use,
        the strip kernel streams whole rows — im2col wins the argmin."""
        p = planner_for("conv2d", MANTICORE)
        win = p.plan(**DEEP)
        assert win.algorithm == "im2col"
        assert win.modeled_words == 168704
        direct = p.plan(**DEEP, algorithm="direct")
        assert direct.algorithm == "direct"
        assert direct.modeled_words == 230144

    def test_wide_3x3_plane_picks_direct(self):
        """F > S: the F*F/S^2 patch read amplification prices im2col out;
        the direct strip kernel keeps its structural edge."""
        p = planner_for("conv2d", MANTICORE)
        win = p.plan(**WIDE)
        assert win.algorithm == "direct"
        assert win.modeled_words == 75520
        gemm = p.plan(**WIDE, algorithm="im2col")
        assert gemm.algorithm == "im2col"
        assert gemm.modeled_words == 100096

    def test_candidates_expose_both_families_argmin_first(self):
        for shape in (DEEP, WIDE):
            p = planner_for("conv2d", MANTICORE)
            cands = p.candidates(**shape)
            assert {c.algorithm for c in cands} == {"direct", "im2col"}
            words = [c.modeled_words for c in cands]
            assert words == sorted(words)
            assert cands[0] == p.plan(**shape)
            assert all(c.fits(MANTICORE) for c in cands)

    def test_family_pins_imply_their_algorithm(self):
        p = planner_for("conv2d", MANTICORE)
        assert p.plan(**DEEP, block_do=256).algorithm == "direct"
        assert p.plan(**WIDE, block_m=128).algorithm == "im2col"
        with pytest.raises(ValueError, match="cannot be combined"):
            p.plan(**DEEP, block_do=256, block_m=128)
        with pytest.raises(ValueError, match="no block_m"):
            p.plan(**DEEP, algorithm="direct", block_m=128)
        with pytest.raises(ValueError, match="no block_do"):
            p.plan(**DEEP, algorithm="im2col", block_do=256)
        with pytest.raises(ValueError, match="unknown conv algorithm"):
            p.plan(**DEEP, algorithm="winograd")

    def test_sharded_plan_keeps_the_tag(self):
        """A batch-partitioned conv plan of the two-level argmin carries
        the per-device winner's algorithm tag through ShardedSchedule."""
        mesh = MeshSpec((("data", 2),))
        ss = planner_for("conv2d", MANTICORE, mesh, "data").plan(
            **DEEP, batch=2)
        assert ss.strategy in ("batch", "stack")  # pure data parallelism
        assert ss.algorithm == local_schedule(ss).algorithm
        assert ss.algorithm == "im2col"


# ---------------------------------------------------------------------------
# The cached winner's algorithm tag reaches the executed kernel
# ---------------------------------------------------------------------------


def _fake_measure(times):
    seq = list(times)

    def m(fn, iters=3, warmup=1):
        del fn, iters, warmup
        return seq.pop(0)

    return m


class TestAutotuneReplay:
    # Matches _shape_args for x=[1,13,13,64], f=[1,1,64,32], stride=2:
    # the tune cell and the executing call must hash to the same digest.
    CELL = dict(H_O=7, W_O=7, F=1, S=2, d_in=64, d_out=32, in_bytes=4,
                pool=1, batch=1, padding=0, H_I=13, W_I=13)

    def test_algorithm_tag_replays_to_the_executed_impl(self, tmp_path,
                                                        monkeypatch):
        """Spy on the conv2d op's impl: scripted times make an im2col
        candidate win the tune; under cache-only policy the schedule the
        kernel executes carries the cached ``algorithm="im2col"`` tag —
        the tag survived the record, the rebuild, and the dispatch."""
        cache = at.AutotuneCache(str(tmp_path / "autotune.json"))
        p = planner_for("conv2d", TPU_V5E)
        cands = p.candidates(**self.CELL)
        idx = next(i for i, c in enumerate(cands)
                   if c.algorithm == "im2col")
        assert any(c.algorithm == "direct" for c in cands), \
            "need both families competing for this test"
        times = [0.5 if i == idx else 10.0 + i for i in range(len(cands))]
        monkeypatch.setattr(at, "_measure", _fake_measure(times))
        rep = at.tune("conv2d", cache=cache, topk=len(cands), **self.CELL)
        win = local_schedule(rep.schedule)
        assert win.algorithm == "im2col"

        # A fresh cache instance (fresh process, same file) rebuilds the
        # winner with its tag intact.
        got = at.lookup("conv2d", dict(self.CELL),
                        cache=at.AutotuneCache(cache.path))
        assert got is not None and got.algorithm == "im2col"
        assert got.blocks == win.blocks

        monkeypatch.setattr(at, "_CACHE_PATH", cache.path)
        op = get_op("conv2d")
        seen = {}
        orig = op.impl

        def spy_impl(*arrays, schedule, **kw):
            seen["schedule"] = schedule
            return orig(*arrays, schedule=schedule, **kw)

        monkeypatch.setitem(_OPS, "conv2d",
                            dataclasses.replace(op, impl=spy_impl))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((1, 13, 13, 64)), jnp.float32)
        f = jnp.asarray(rng.standard_normal((1, 1, 64, 32)), jnp.float32)
        b = jnp.zeros((32,), jnp.float32)
        out = _OPS["conv2d"](x, f, b, stride=2, autotune="cache-only")
        assert seen["schedule"].algorithm == "im2col"
        assert seen["schedule"].blocks == win.blocks
        np.testing.assert_allclose(
            np.asarray(out),
            np.asarray(conv2d_fused_ref(x, f, b, stride=2)),
            atol=1e-4, rtol=1e-4)

    def test_stale_pin_degrades_once_with_cell_context(self, tmp_path,
                                                       monkeypatch):
        """The hardened replay path: a cached record whose pins the
        planner now rejects warns ONCE (naming the cell) and falls back
        to the modeled argmin — while a genuine planner bug propagates."""
        import warnings

        cache = at.AutotuneCache(str(tmp_path / "autotune.json"))
        monkeypatch.setattr(at, "_measure", _fake_measure([1.0] * 32))
        at.tune("conv2d", cache=cache, topk=2, **self.CELL)

        def broken_rebuild(*args):
            raise ValueError("retired knob 'block_zz'")

        monkeypatch.setattr(at, "_rebuild", broken_rebuild)
        monkeypatch.setattr(at, "_WARNED_CELLS", set())
        # A fresh instance per lookup: the tune above memoized its winner,
        # and replay must go through the (now broken) rebuild path.
        fresh = at.AutotuneCache(cache.path)
        with pytest.warns(UserWarning, match='"H_O",7'):
            assert at.lookup("conv2d", dict(self.CELL), cache=fresh) is None
        # Second lookup of the same cell: silent (already warned).
        with warnings.catch_warnings(record=True) as record:
            warnings.simplefilter("always")
            assert at.lookup("conv2d", dict(self.CELL), cache=fresh) is None
        assert not record

        def buggy_rebuild(*args):
            raise KeyError("planner bug")

        monkeypatch.setattr(at, "_rebuild", buggy_rebuild)
        with pytest.raises(KeyError, match="planner bug"):
            at.lookup("conv2d", dict(self.CELL),
                      cache=at.AutotuneCache(cache.path))
