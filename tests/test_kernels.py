"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and dtypes, plus hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.kernels.conv2d import conv2d, conv2d_ref
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.matmul import fc_matmul, fc_matmul_ref

TOLS = {jnp.float32: dict(rtol=2e-4, atol=2e-4), jnp.bfloat16: dict(rtol=5e-2, atol=5e-2)}


def _rand(rng, shape, dtype):
    return rng.standard_normal(shape).astype(dtype)


class TestMatmulKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "m,k,n", [(8, 8, 8), (37, 70, 90), (128, 256, 128), (1, 300, 17), (130, 129, 257)]
    )
    def test_matches_ref(self, m, k, n, dtype):
        rng = np.random.default_rng(m * 1000 + k * 10 + n)
        x, w = _rand(rng, (m, k), dtype), _rand(rng, (k, n), dtype)
        got = fc_matmul(x, w, block_m=32, block_n=32, block_k=32)
        want = fc_matmul_ref(x, w)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), **TOLS[dtype]
        )

    def test_leading_dims_flattened(self):
        rng = np.random.default_rng(0)
        x = _rand(rng, (2, 3, 40), jnp.float32)
        w = _rand(rng, (40, 9), jnp.float32)
        got = fc_matmul(x, w, block_m=8, block_n=8, block_k=8)
        assert got.shape == (2, 3, 9)
        np.testing.assert_allclose(got, fc_matmul_ref(x, w), rtol=2e-4, atol=2e-4)

    def test_block_chooser_respects_vmem(self):
        from repro.core.machine import TPU_V5E
        from repro.plan import MatmulPlanner

        s = MatmulPlanner(TPU_V5E).plan(m=4096, n=16384, k=8192, in_bytes=2)
        bm, bn, bk = (s.block("block_m"), s.block("block_n"),
                      s.block("block_k"))
        working = (bm * bk + bk * bn) * 2 * 2 + bm * bn * 4
        assert working <= TPU_V5E.usable_for_working_set(2)
        assert bm % 128 == 0 and bn % 128 == 0 and bk % 128 == 0

    @settings(max_examples=10, deadline=None)
    @given(st.integers(1, 50), st.integers(1, 50), st.integers(1, 50))
    def test_property_random_shapes(self, m, k, n):
        rng = np.random.default_rng(m + 51 * k + 2601 * n)
        x, w = _rand(rng, (m, k), np.float32), _rand(rng, (k, n), np.float32)
        np.testing.assert_allclose(
            fc_matmul(x, w, block_m=16, block_n=16, block_k=16),
            fc_matmul_ref(x, w), rtol=2e-4, atol=2e-4,
        )


class TestConv2dKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "H,di,do,F,P",
        [(8, 4, 4, 3, 1), (12, 7, 5, 3, 1), (16, 8, 16, 5, 2), (9, 3, 2, 1, 0), (7, 2, 3, 7, 3)],
    )
    def test_matches_ref(self, H, di, do, F, P, dtype):
        rng = np.random.default_rng(H + di + do + F)
        x = _rand(rng, (H, H, di), dtype)
        f = _rand(rng, (F, F, di, do), dtype)
        got = conv2d(x, f, padding=P, block_do=2, block_di=2)
        want = conv2d_ref(x, f, padding=P)
        assert got.shape == want.shape
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), **TOLS[dtype]
        )

    def test_batched(self):
        rng = np.random.default_rng(1)
        x = _rand(rng, (3, 10, 10, 6), np.float32)
        f = _rand(rng, (3, 3, 6, 8), np.float32)
        got = conv2d(x, f, padding=1, block_do=4, block_di=3)
        np.testing.assert_allclose(got, conv2d_ref(x, f, padding=1), rtol=2e-4, atol=2e-4)

    def test_alg1_is_block_do_1(self):
        """block_do=1 is Algorithm 1 (one output slice at a time): identical
        numerics, worse traffic — the schedule knob is purely a perf choice."""
        rng = np.random.default_rng(2)
        x = _rand(rng, (8, 8, 4), np.float32)
        f = _rand(rng, (3, 3, 4, 6), np.float32)
        a1 = conv2d(x, f, padding=1, block_do=1, block_di=1)
        a2 = conv2d(x, f, padding=1, block_do=3, block_di=2)
        np.testing.assert_allclose(a1, a2, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("stride", [2, 3])
    def test_strided_runs_in_kernel(self, stride):
        """Strided convs run the Pallas kernel (shifted strided matmuls),
        no reference fallback."""
        rng = np.random.default_rng(3)
        x = _rand(rng, (9, 9, 4), np.float32)
        f = _rand(rng, (3, 3, 4, 5), np.float32)
        got = conv2d(x, f, stride=stride, padding=1, block_do=5, block_di=4)
        want = conv2d_ref(x, f, stride=stride, padding=1)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_strip_height_invariance(self):
        """Any strip height gives identical numerics — block_h is purely a
        capacity/perf knob, including heights that don't divide H_O."""
        rng = np.random.default_rng(4)
        x = _rand(rng, (2, 11, 11, 5), np.float32)
        f = _rand(rng, (3, 3, 5, 4), np.float32)
        full = conv2d(x, f, padding=1, block_do=4, block_di=5, block_h=11)
        for hb in (1, 3, 4, 16):
            got = conv2d(x, f, padding=1, block_do=4, block_di=5, block_h=hb)
            np.testing.assert_allclose(got, full, rtol=1e-6, atol=1e-6)

    @settings(max_examples=8, deadline=None)
    @given(
        st.integers(4, 14), st.integers(1, 8), st.integers(1, 8),
        st.sampled_from([1, 3, 5]), st.integers(0, 2),
    )
    def test_property_random_shapes(self, H, di, do, F, P):
        if F > H + 2 * P:
            return
        rng = np.random.default_rng(H * 100 + di * 10 + do + F + P)
        x = _rand(rng, (H, H, di), np.float32)
        f = _rand(rng, (F, F, di, do), np.float32)
        np.testing.assert_allclose(
            conv2d(x, f, padding=P, block_do=2, block_di=2),
            conv2d_ref(x, f, padding=P), rtol=2e-4, atol=2e-4,
        )


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("causal,window", [(True, None), (False, None), (True, 16), (True, 4)])
    @pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
    def test_matches_ref(self, causal, window, hq, hkv, dtype):
        rng = np.random.default_rng(hq * 10 + hkv)
        q = _rand(rng, (2, hq, 48, 32), dtype)
        k = _rand(rng, (2, hkv, 48, 32), dtype)
        v = _rand(rng, (2, hkv, 48, 32), dtype)
        got = flash_attention(q, k, v, causal=causal, window=window, block_q=16, block_kv=16)
        want = attention_ref(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **(dict(rtol=2e-3, atol=2e-3) if dtype == jnp.float32 else TOLS[dtype]),
        )

    def test_ragged_seq_lengths(self):
        rng = np.random.default_rng(9)
        q = _rand(rng, (1, 2, 33, 16), np.float32)
        k = _rand(rng, (1, 2, 47, 16), np.float32)
        v = _rand(rng, (1, 2, 47, 16), np.float32)
        got = flash_attention(q, k, v, causal=False, block_q=16, block_kv=16)
        want = attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)

    def test_finite_on_fully_masked_rows(self):
        """Sliding-window + padding can fully mask padded rows; output must
        stay finite (guarded l==0 division)."""
        rng = np.random.default_rng(10)
        q = _rand(rng, (1, 1, 5, 8), np.float32)
        k = _rand(rng, (1, 1, 5, 8), np.float32)
        v = _rand(rng, (1, 1, 5, 8), np.float32)
        out = flash_attention(q, k, v, causal=True, window=2, block_q=8, block_kv=8)
        assert np.isfinite(np.asarray(out)).all()

    def test_block_size_invariance(self):
        rng = np.random.default_rng(11)
        q = _rand(rng, (1, 2, 64, 16), np.float32)
        k = _rand(rng, (1, 2, 64, 16), np.float32)
        v = _rand(rng, (1, 2, 64, 16), np.float32)
        a = flash_attention(q, k, v, block_q=16, block_kv=16)
        b = flash_attention(q, k, v, block_q=64, block_kv=32)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
