"""Per-architecture smoke tests (reduced configs): one forward + one
gradient step on CPU, asserting shapes and finiteness; decode-vs-full
consistency for the serving path."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, smoke_config
from repro.models import cnn
from repro.models.module import init_params
from repro.models.registry import get_family

LM_ARCHS = [a for a in ARCH_IDS if a != "cnn-vgg11"]


def _setup(arch, seed=0, **overrides):
    cfg = smoke_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    fam = get_family(cfg.family)
    params = init_params(fam.param_defs(cfg), jax.random.PRNGKey(seed), jnp.float32)
    return cfg, fam, params


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    kw = {}
    if cfg.family == "encdec":
        kw["frames"] = jnp.full((B, cfg.enc_seq, cfg.d_model), 0.1, jnp.float32)
    return toks, kw


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_forward_shapes_finite(arch):
    cfg, fam, params = _setup(arch)
    toks, kw = _batch(cfg)
    h, _ = fam.forward(cfg, params, toks, compute_dtype=jnp.bfloat16, **kw)
    logits = fam.logits(cfg, params, h)
    assert h.shape == (2, 32, cfg.d_model)
    assert logits.shape == (2, 32, cfg.vocab)
    assert jnp.isfinite(logits.astype(jnp.float32)).all()


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_one_train_step(arch):
    """One cross-entropy gradient step: loss finite, grads finite and at
    least 90% of leaves nonzero."""
    cfg, fam, params = _setup(arch)
    toks, kw = _batch(cfg, S=16)
    labels = jnp.roll(toks, -1, axis=1)

    def loss_fn(p):
        h, _ = fam.forward(cfg, p, toks, compute_dtype=jnp.float32, **kw)
        lg = fam.logits(cfg, p, h).astype(jnp.float32)
        lp = jax.nn.log_softmax(lg, -1)
        return -jnp.take_along_axis(lp, labels[..., None], -1).mean()

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert jnp.isfinite(loss)
    leaves = jax.tree.leaves(grads)
    assert all(jnp.isfinite(g).all() for g in leaves)
    nonzero = sum(bool(jnp.any(g != 0)) for g in leaves)
    assert nonzero / len(leaves) > 0.9, f"{nonzero}/{len(leaves)} grads nonzero"


@pytest.mark.parametrize(
    "arch",
    ["qwen3-1.7b", "gemma3-4b", "qwen1.5-0.5b", "rwkv6-1.6b", "zamba2-1.2b",
     "seamless-m4t-medium"],
)
def test_decode_matches_full_forward(arch):
    cfg, fam, params = _setup(arch, seed=1)
    B, S = 2, 16
    toks, kw = _batch(cfg, B=B, S=S, seed=1)
    h_full, _ = fam.forward(cfg, params, toks, compute_dtype=jnp.float32, **kw)
    lg_full = fam.logits(cfg, params, h_full)

    cache = fam.init_cache(cfg, B, 64, jnp.float32)
    _, cache = fam.forward(cfg, params, toks[:, : S - 1], pos0=0, cache=cache,
                           compute_dtype=jnp.float32, **kw)
    h_dec, _ = fam.forward(cfg, params, toks[:, S - 1 :], pos0=S - 1, cache=cache,
                           compute_dtype=jnp.float32)
    lg_dec = fam.logits(cfg, params, h_dec)
    np.testing.assert_allclose(lg_dec[:, 0], lg_full[:, -1], rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("arch", ["grok-1-314b", "qwen3-moe-235b-a22b"])
def test_moe_decode_matches_when_no_drops(arch):
    """Capacity-based MoE is only step-consistent when capacity is not
    binding (drops depend on the token set); assert exactness there."""
    cfg, fam, params = _setup(arch, seed=1, capacity_factor=64.0)
    B, S = 2, 16
    toks, _ = _batch(cfg, B=B, S=S, seed=1)
    h_full, _ = fam.forward(cfg, params, toks, compute_dtype=jnp.float32)
    lg_full = fam.logits(cfg, params, h_full)
    cache = fam.init_cache(cfg, B, 64, jnp.float32)
    _, cache = fam.forward(cfg, params, toks[:, : S - 1], pos0=0, cache=cache,
                           compute_dtype=jnp.float32)
    h_dec, _ = fam.forward(cfg, params, toks[:, S - 1 :], pos0=S - 1, cache=cache,
                           compute_dtype=jnp.float32)
    lg_dec = fam.logits(cfg, params, h_dec)
    np.testing.assert_allclose(lg_dec[:, 0], lg_full[:, -1], rtol=1e-4, atol=1e-4)


def test_gemma3_local_global_pattern():
    """Every 6th layer is global (window -1), others carry the local window."""
    from repro.models.transformer import layer_meta
    from repro.configs.registry import get_config

    meta = layer_meta(get_config("gemma3-4b"))
    w = np.asarray(meta["window"])
    assert (w[5::6] == -1).all()
    mask = np.ones(len(w), bool)
    mask[5::6] = False
    assert (w[mask] == 1024).all()


def test_cnn_forward_and_grad():
    cfg = smoke_config("cnn-vgg11")
    params = init_params(cnn.param_defs(cfg), jax.random.PRNGKey(0), jnp.float32)
    imgs = jnp.asarray(np.random.default_rng(0).standard_normal((2, 32, 32, 3)), jnp.float32)
    logits = cnn.forward(cfg, params, imgs)
    assert logits.shape == (2, cfg.vocab)
    assert jnp.isfinite(logits).all()

    labels = jnp.array([1, 2])

    def loss_fn(p):
        lg = cnn.forward(cfg, p, imgs)
        return -jax.nn.log_softmax(lg)[jnp.arange(2), labels].mean()

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert jnp.isfinite(loss)
    assert all(jnp.isfinite(g).all() for g in jax.tree.leaves(grads))


def test_cnn_kernel_matches_ref_path():
    cfg = smoke_config("cnn-vgg11")
    params = init_params(cnn.param_defs(cfg), jax.random.PRNGKey(0), jnp.float32)
    imgs = jnp.asarray(np.random.default_rng(1).standard_normal((2, 32, 32, 3)), jnp.float32)
    a = cnn.forward(cfg, params, imgs, use_kernels=True)
    b = cnn.forward(cfg, params, imgs, use_kernels=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)
