"""System-invariant property tests (hypothesis)."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.kernels.flash_attention.ref import attention_ref
from repro.models.attention import attention
from repro.models.layers import rms_norm, rope
from repro.models.module import ParamDef, abstract_params, count_params, init_params, param_specs


class TestPlannerInvariants:
    """Every planner-produced Schedule — forward AND backward — fits the
    machine it was planned against and round-trips through its analysis
    hooks (traffic / to_roofline) without error."""

    @staticmethod
    def _check(sched, machine):
        from repro.plan import to_roofline

        assert sched.fits(machine), (sched.op, dict(sched.blocks))
        assert sched.modeled_words == sched.loads + sched.stores > 0
        assert sched.macs > 0 and sched.vmem_bytes > 0
        assert all(g > 0 for g in sched.grid)
        t = sched.traffic
        assert t.main_words == sched.modeled_words and t.ccr > 0
        r = to_roofline(sched)
        assert r.flops == 2.0 * sched.macs and r.bytes_hbm > 0
        assert r.t_memory > 0 and r.bottleneck in ("compute", "memory")
        assert sched.bound_kind(machine) in ("compute-bound", "memory-bound")

    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 3), st.integers(3, 48), st.integers(3, 48),
           st.integers(1, 12), st.integers(1, 24), st.sampled_from([1, 2]))
    def test_conv_fwd_and_bwd_schedules(self, B, H, W, C_I, C_O, stride):
        from repro.core.machine import MANTICORE, TPU_V5E
        from repro.plan import ConvDgradPlanner, ConvPlanner, ConvWgradPlanner

        F, P = 3, 1
        H_O = (H + 2 * P - F) // stride + 1
        W_O = (W + 2 * P - F) // stride + 1
        for machine in (TPU_V5E, MANTICORE):
            fwd = ConvPlanner(machine).plan(
                H_O=H_O, W_O=W_O, F=F, S=stride, d_in=C_I, d_out=C_O,
                in_bytes=4, batch=B, padding=P, H_I=H, W_I=W)
            dgrad = ConvDgradPlanner(machine).plan(
                H_O=H_O, W_O=W_O, F=F, S=stride, P=P, d_in=C_I, d_out=C_O,
                in_bytes=4, batch=B, H_I=H, W_I=W)
            wgrad = ConvWgradPlanner(machine).plan(
                H_O=H_O, W_O=W_O, F=F, S=stride, d_in=C_I, d_out=C_O,
                in_bytes=4, batch=B, padding=P, H_I=H, W_I=W)
            for sched in (fwd, dgrad, wgrad):
                self._check(sched, machine)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 300), st.integers(1, 2048), st.integers(1, 2048),
           st.sampled_from([2, 4]))
    def test_matmul_fwd_and_bwd_schedules(self, B, n, k, ib):
        from repro.core.machine import MANTICORE, TPU_V5E
        from repro.plan import MatmulDwPlanner, MatmulDxPlanner, MatmulPlanner

        for machine in (TPU_V5E, MANTICORE):
            for planner in (MatmulPlanner, MatmulDxPlanner, MatmulDwPlanner):
                sched = planner(machine).plan(m=B, n=n, k=k, in_bytes=ib)
                self._check(sched, machine)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(1, 512), st.integers(1, 2048), st.sampled_from([16, 64]),
           st.booleans(), st.sampled_from([None, 32, 128]))
    def test_attention_schedules(self, sq, skv, d, causal, window):
        from repro.core.machine import TPU_V5E
        from repro.plan import AttentionPlanner

        sched = AttentionPlanner(TPU_V5E).plan(
            seq_q=sq, seq_kv=skv, head_dim=d, n_q_heads=2, n_kv_heads=1,
            batch=2, in_bytes=4, causal=causal, window=window)
        # A fully-skipped KV stream (tiny window) legally zeroes macs; the
        # rest of the invariants still hold.
        assert sched.fits(TPU_V5E)
        assert sched.modeled_words == sched.loads + sched.stores > 0
        assert sched.traffic.main_words == sched.modeled_words


class TestAttentionInvariants:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(1, 3), st.integers(2, 5), st.integers(0, 1000))
    def test_blockwise_equals_ref(self, B, nchunks, seed):
        """Chunked online-softmax == dense softmax for any chunking."""
        S, H, D = nchunks * 8, 2, 8
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
        pos = jnp.arange(S, dtype=jnp.int32)
        got = attention(q, k, v, q_pos=pos, k_pos=pos, causal=True,
                        chunk_q=8, chunk_kv=8)
        want = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), causal=True)
        np.testing.assert_allclose(got.transpose(0, 2, 1, 3), want,
                                   rtol=2e-4, atol=2e-4)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 500))
    def test_causal_prefix_invariance(self, seed):
        """Causal attention of a prefix == the prefix of the full result
        (the property that makes KV-cache decode correct)."""
        B, S, H, D = 1, 24, 2, 8
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
        pos = jnp.arange(S, dtype=jnp.int32)
        full = attention(q, k, v, q_pos=pos, k_pos=pos, causal=True)
        half = attention(q[:, :12], k[:, :12], v[:, :12],
                         q_pos=pos[:12], k_pos=pos[:12], causal=True)
        np.testing.assert_allclose(half, full[:, :12], rtol=1e-4, atol=1e-4)

    def test_window_one_attends_self_only(self):
        """window=1 means each token sees only itself: output == V row."""
        B, S, H, D = 1, 8, 1, 4
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
        pos = jnp.arange(S, dtype=jnp.int32)
        out = attention(q, k, v, q_pos=pos, k_pos=pos, causal=True, window=1)
        np.testing.assert_allclose(out, v, rtol=1e-5, atol=1e-5)


class TestRope:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 300))
    def test_preserves_norm(self, seed):
        """RoPE is a rotation: vector norms are preserved."""
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((1, 8, 2, 16)), jnp.float32)
        y = rope(x, jnp.arange(8, dtype=jnp.int32), 1e4)
        np.testing.assert_allclose(
            jnp.linalg.norm(y, axis=-1), jnp.linalg.norm(x, axis=-1),
            rtol=1e-5, atol=1e-5)

    def test_relative_position_property(self):
        """<rope(q,m), rope(k,n)> depends only on m-n."""
        rng = np.random.default_rng(1)
        q = jnp.asarray(rng.standard_normal((1, 1, 1, 16)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, 1, 1, 16)), jnp.float32)

        def dot_at(m, n):
            qm = rope(q, jnp.array([m], jnp.int32), 1e4)
            kn = rope(k, jnp.array([n], jnp.int32), 1e4)
            return float(jnp.sum(qm * kn))

        assert dot_at(5, 3) == pytest.approx(dot_at(102, 100), rel=1e-4)
        assert dot_at(7, 0) == pytest.approx(dot_at(57, 50), rel=1e-4)


class TestModuleSystem:
    def test_init_is_path_stable(self):
        """Adding an unrelated param doesn't change other params' values."""
        defs1 = {"a": ParamDef((4, 4)), "b": {"c": ParamDef((2, 2))}}
        defs2 = {"a": ParamDef((4, 4)), "b": {"c": ParamDef((2, 2))},
                 "z": ParamDef((3,), init="zeros")}
        key = jax.random.PRNGKey(0)
        p1 = init_params(defs1, key)
        p2 = init_params(defs2, key)
        np.testing.assert_array_equal(p1["a"], p2["a"])
        np.testing.assert_array_equal(p1["b"]["c"], p2["b"]["c"])

    def test_abstract_matches_concrete(self):
        defs = {"w": ParamDef((8, 16), (None, "model")), "b": ParamDef((16,), init="zeros")}
        concrete = init_params(defs, jax.random.PRNGKey(0), jnp.bfloat16)
        abstract = abstract_params(defs, jnp.bfloat16)
        for c, a in zip(jax.tree.leaves(concrete), jax.tree.leaves(abstract)):
            assert c.shape == a.shape and c.dtype == a.dtype
        assert count_params(defs) == 8 * 16 + 16
        from jax.sharding import PartitionSpec as P

        assert param_specs(defs)["w"] == P(None, "model")

    def test_rms_norm_scale_invariance_direction(self):
        """rms_norm(a*x) == rms_norm(x) for a > 0 (scale invariance)."""
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((2, 8)), jnp.float32)
        w = jnp.zeros(8)
        np.testing.assert_allclose(rms_norm(3.0 * x, w), rms_norm(x, w),
                                   rtol=1e-4, atol=1e-5)


class TestSsdChunkInvariance:
    @settings(max_examples=6, deadline=None)
    @given(st.integers(0, 100))
    def test_mamba2_chunk_size_invariance(self, seed):
        """SSD output must not depend on the chunk size (chunked == scan)."""
        import repro.models.mamba2 as m2

        rng = np.random.default_rng(seed)
        B, S, H, Pd, N = 1, 16, 2, 4, 4
        x = jnp.asarray(rng.standard_normal((B, S, H, Pd)) * 0.5, jnp.float32)
        dt = jnp.asarray(rng.uniform(0.1, 0.9, (B, S, H)), jnp.float32)
        A = jnp.asarray(rng.uniform(-0.5, 0.5, (H,)), jnp.float32)
        Bc = jnp.asarray(rng.standard_normal((B, S, N)) * 0.5, jnp.float32)
        Cc = jnp.asarray(rng.standard_normal((B, S, N)) * 0.5, jnp.float32)
        D = jnp.ones((H,), jnp.float32)
        s0 = jnp.zeros((B, H, Pd, N), jnp.float32)

        old = m2.CHUNK
        try:
            m2.CHUNK = 4
            y4, f4 = m2.ssd_chunked(x, dt, A, Bc, Cc, D, s0)
            m2.CHUNK = 16
            y16, f16 = m2.ssd_chunked(x, dt, A, Bc, Cc, D, s0)
        finally:
            m2.CHUNK = old
        np.testing.assert_allclose(y4, y16, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(f4, f16, rtol=2e-4, atol=2e-4)
